"""The serving stack's typed error taxonomy, rooted at `ServeError`.

One leaf module with no dependencies, importable from anywhere in the
tree (the decision layer `runtime/fault.py`, the serving layer
`serve/*.py`, tests, benches) without layering cycles. Every typed
error the serving stack raises derives from `ServeError`, so a caller
holding a `ServeFrontend` can catch the whole family with one clause —
or any of the historical bases (`ValueError` for `PromptTooLong`,
`RuntimeError` for the backpressure/outage family) that pre-taxonomy
code already handles. The classes are re-exported from their original
homes (`serve/engine.py`, `serve/engine_fault.py`, `serve/fault.py`,
`runtime/fault.py`) so existing imports keep working.

Two deliberate exceptions to the RuntimeError mixin:

* `ColumnDeadError` / `ColumnHungError` are NOT `RuntimeError`s: retry
  loops whose ``retry_on`` covers `RuntimeError`
  (`runtime.fault.Supervisor.call`) must never swallow a death or a
  wedge — those resolve through drain/requeue and heartbeat timeout
  respectively, not through a retry.
* `PromptTooLong` and `PagedCacheUnsupported` are admission-boundary
  rejections of the REQUEST/MODEL, not engine outages, and keep their
  `ValueError`/`TypeError` bases.
"""
from __future__ import annotations

__all__ = [
    "ServeError", "PromptTooLong", "EngineStalled", "QueueFull",
    "RequestExpired", "InsufficientHealthyWorkers",
    "TransientDispatchError", "ColumnDeadError", "ColumnHungError",
    "InsufficientPages", "PagedCacheUnsupported", "TicketNotReady",
]


class ServeError(Exception):
    """Root of the serving error taxonomy (`serve/errors.py`)."""


class PromptTooLong(ServeError, ValueError):
    """A submitted prompt exceeds the engine's cache length (``max_len``).

    Raised at admission (`Engine.add_request`) — admitting it would blow
    up mid-bucket with a raw NumPy broadcast error (the bucket width is
    capped at ``max_len`` but the prompt row write is not) and wedge
    every request sharing the admission bucket. Rejecting at the
    boundary keeps one bad request from taking down a batch."""

    def __init__(self, rid, n_tokens: int, max_len: int):
        self.rid = rid
        self.n_tokens = int(n_tokens)
        self.max_len = int(max_len)
        super().__init__(
            f"request {rid}: prompt of {n_tokens} tokens exceeds the "
            f"engine cache length max_len={max_len}")


class EngineStalled(ServeError, RuntimeError):
    """`Engine.run_to_completion` exhausted ``max_steps`` with requests
    still queued or live. Carries the unfinished ``rids`` and the
    ``done`` subset — the caller decides whether to resubmit, extend the
    budget, or surface the outage; silently returning only the finished
    subset (the old behaviour) dropped work on the floor."""

    def __init__(self, unfinished, done=None):
        self.unfinished = list(unfinished)
        self.done = list(done) if done is not None else []
        super().__init__(
            f"engine stalled with {len(self.unfinished)} unfinished "
            f"request(s) after the step budget: rids {self.unfinished}")


class QueueFull(ServeError, RuntimeError):
    """The bounded admission queue is at capacity — typed backpressure.

    The caller sheds load or retries later; the engine never grows the
    queue past ``max_queue``. Carries the rejected ``rid`` and the queue
    ``depth`` at rejection time."""

    def __init__(self, rid, depth: int, max_queue: int):
        self.rid = rid
        self.depth = int(depth)
        self.max_queue = int(max_queue)
        super().__init__(
            f"request {rid} rejected: admission queue at capacity "
            f"({depth}/{max_queue})")


class RequestExpired(ServeError, RuntimeError):
    """A request's TTL elapsed before it could be admitted.

    Raised at `FaultTolerantEngine.add_request` for a dead-on-arrival
    TTL; requests that expire while QUEUED are dropped into
    `FaultTolerantEngine.expired` at the next step instead (there is no
    caller on the stack to throw to)."""

    def __init__(self, rid, ttl: float):
        self.rid = rid
        self.ttl = float(ttl)
        super().__init__(f"request {rid} expired (ttl {ttl:g}s)")


class InsufficientHealthyWorkers(ServeError, RuntimeError):
    """Too few healthy workers/columns/slots to satisfy the requested
    plan.

    Raised by `runtime.fault.elastic_plan` when the healthy-chip count
    cannot cover the fixed model axis, by the serving layer when every
    column of a fleet is dead (`serve/engine.py:ColumnScheduler`), and
    by the LM supervision layer when no healthy slot remains with work
    pending (`serve/engine_fault.py`) — the caller decides whether to
    shrink the plan, wait for capacity, or surface the outage."""


class TransientDispatchError(ServeError, RuntimeError):
    """A retryable dispatch failure (flaky link, preempted worker slot).

    The worker/column is expected to survive; `Supervisor.call` retries
    these with capped exponential backoff."""


class ColumnDeadError(ServeError):
    """A column died and will never answer again.

    NOT a `RuntimeError` on purpose: retry loops whose `retry_on`
    includes `RuntimeError` must not swallow a death. The serving layer
    reacts by draining the column and requeuing its unretired work
    (`serve/fault.py`)."""

    def __init__(self, column: int, message: str = ""):
        self.column = int(column)
        super().__init__(message or f"column {column} died")


class ColumnHungError(ServeError):
    """A simulated WEDGED column: the dispatch neither completes nor
    errors (no retire, so no heartbeat). Only the injector raises this —
    a real hung dispatch just never returns — and only the supervision
    loop's heartbeat timeout can declare the column dead. NOT a
    `RuntimeError` for the same no-swallowing reason as
    `ColumnDeadError`."""

    def __init__(self, column: int):
        self.column = int(column)
        super().__init__(f"column {column} is hung (no retire, no error)")


class InsufficientPages(ServeError, RuntimeError):
    """The page pool cannot cover an allocation.

    Raised at `PagedEngine.add_request` when a request's worst-case page
    footprint exceeds the POOL CAPACITY (it could never be admitted —
    rejecting at the boundary mirrors `PromptTooLong`), and by
    `serve.paged.PagePool.alloc` on a direct over-allocation. A request
    that merely exceeds the FREE count right now is not an error: it
    waits in the queue until decoding frees pages (that wait is the
    admission backpressure)."""

    def __init__(self, need: int, free: int, capacity: int):
        self.need = int(need)
        self.free = int(free)
        self.capacity = int(capacity)
        super().__init__(
            f"page pool cannot cover {need} page(s): {free} free of "
            f"{capacity} total")


class PagedCacheUnsupported(ServeError, TypeError):
    """The model's cache cannot be paged.

    Paging requires every cache leaf to carry named "batch" and "seq"
    axes (attention K/V rings and linear caches do); recurrent state
    leaves (rwkv/mamba) have no sequence axis — their state IS the whole
    history — and enc-dec decoders admit token-at-a-time. Those serve on
    the dense `Engine` path instead."""


class TicketNotReady(ServeError, RuntimeError):
    """`Ticket.result()` was called before the work completed — drive
    the front-end (`ServeFrontend.run` / `pump`) first."""

    def __init__(self, tid, status: str):
        self.tid = tid
        self.status = str(status)
        super().__init__(
            f"ticket {tid} is not done (status {status!r}); run the "
            f"front-end before reading results")

"""Quickstart: the VWR2A core library in 60 seconds.

  1. the four shuffle-unit primitives,
  2. the shuffle-dataflow FFT (+ real-FFT packing) and the FIR kernel,
  3. the cycle-accurate archsim reproducing a paper Table-2 row,
  4. one forward/train step of an assigned LM architecture.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

print("== 1. shuffle unit (paper §3.3.1) ==")
from repro.core.shuffle import interleave, prune, bit_reverse, circular_shift

a = jnp.arange(8.0)
b = jnp.arange(8.0) + 100
print("interleave :", interleave(a, b)[:8])
print("prune even :", prune(a, b, drop="even"))
print("bit_reverse:", bit_reverse(a, b, half="lower"))
print("circ shift :", circular_shift(a, b, amount=4, half="lower"))

print("\n== 2. FFT on the shuffle dataflow + FIR (Pallas kernels) ==")
from repro.kernels.fft.ops import rfft
from repro.kernels.fir.ops import fir
from repro.core.fir import lowpass_taps

x = np.random.default_rng(0).normal(size=(4, 512)).astype(np.float32)
Xr, Xi = rfft(jnp.asarray(x))
ref = np.fft.rfft(x)
print("rfft kernel vs numpy rel err:",
      float(np.abs(Xr + 1j * Xi - ref).max() / np.abs(ref).max()))
y = fir(jnp.asarray(x), jnp.asarray(lowpass_taps(11)))
print("fir kernel out:", y.shape, "finite:", bool(jnp.isfinite(y).all()))

print("\n== 3. archsim: paper Table 2, 512-pt real FFT ==")
from repro.archsim.programs.fft import run_rfft
from repro.archsim.energy import vwr2a_energy_uj

X, counters, cycles = run_rfft(512, x[0] * 0.3)
print(f"simulated cycles: {cycles} (paper VWR2A: 3666)  "
      f"energy: {vwr2a_energy_uj(counters):.3f} uJ")

print("\n== 4. one LM train step (assigned arch, reduced config) ==")
from repro.configs import get_config, reduced
from repro.models import build_model, init_model_params

cfg = reduced(get_config("deepseek-moe-16b"))
model = build_model(cfg)
params = init_model_params(model)
batch = {"tokens": jnp.ones((2, 64), jnp.int32),
         "labels": jnp.ones((2, 64), jnp.int32)}
loss, metrics = jax.jit(model.loss)(params, batch)
print("deepseek-moe-16b (reduced) loss:", float(loss))
print("\nquickstart OK")

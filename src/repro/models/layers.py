"""Parameter-schema system + common layers (pure JAX, no flax).

Every module describes its parameters as a *schema*: a nested dict whose
leaves are :class:`P` entries carrying (shape, logical_axes, init_std).
A single schema drives three things:

  * ``init_params``      — materialize a pytree of arrays,
  * ``axes_tree``        — matching pytree of logical-axis tuples (for sharding),
  * ``abstract_params``  — matching pytree of ShapeDtypeStruct (for dry-run).

Logical axis names used throughout (mapped to mesh axes by sharding/rules.py):
  layers, embed, vocab, heads, kv_heads, head_dim, mlp, experts, expert_mlp,
  conv, state, pos, None (replicated).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    """Schema leaf: one parameter tensor."""

    shape: tuple
    axes: tuple  # logical axis name (str) or None per dim
    std: Any = 0.02  # float stddev | 0.0 => zeros | "ones" | ("uniform", lo, hi)
    dtype: Any = None  # None => use param_dtype passed to init

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def fanin_std(fan_in: int) -> float:
    return 1.0 / math.sqrt(max(1, fan_in))


def stack_schema(n: int, schema):
    """Prepend a 'layers' dim of size n to every P in `schema`."""

    def _stack(p: P) -> P:
        return P((n,) + p.shape, ("layers",) + p.axes, p.std, p.dtype)

    return jax.tree.map(_stack, schema, is_leaf=lambda x: isinstance(x, P))


def _init_leaf(key, p: P, param_dtype):
    dtype = p.dtype or param_dtype
    if p.std == "ones":
        return jnp.ones(p.shape, dtype)
    if isinstance(p.std, tuple) and p.std and p.std[0] == "uniform":
        _, lo, hi = p.std
        return jax.random.uniform(key, p.shape, dtype, lo, hi)
    std = float(p.std)
    if std == 0.0:
        return jnp.zeros(p.shape, dtype)
    return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(dtype)


def init_params(key, schema, param_dtype=jnp.float32):
    """Materialize the parameter pytree for `schema`."""
    leaves, treedef = jax.tree.flatten(schema, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, p, param_dtype) for k, p in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def axes_tree(schema):
    """Pytree of logical-axis tuples matching the parameter pytree."""
    return jax.tree.map(
        lambda p: p.axes, schema, is_leaf=lambda x: isinstance(x, P)
    )


def abstract_params(schema, param_dtype=jnp.float32):
    """ShapeDtypeStruct pytree matching the parameter pytree (no allocation)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype or param_dtype),
        schema,
        is_leaf=lambda x: isinstance(x, P),
    )


def param_count(schema) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=lambda x: isinstance(x, P))
    return sum(int(np.prod(p.shape)) for p in leaves)


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def norm_schema(d: int, kind: str = "rmsnorm"):
    if kind == "rmsnorm":
        return {"scale": P((d,), ("embed",), "ones")}
    return {"scale": P((d,), ("embed",), "ones"), "bias": P((d,), ("embed",), 0.0)}


def apply_norm(params, x, *, kind: str = "rmsnorm", eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    else:  # layernorm
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
            jnp.float32
        )
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_schema(vocab: int, d: int):
    return {"embedding": P((vocab, d), ("vocab", "embed"), fanin_std(d))}


def embed(params, tokens):
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed(params, x):
    # logits in f32 for a numerically stable softmax/loss
    return jnp.einsum(
        "...d,vd->...v", x.astype(jnp.float32),
        params["embedding"].astype(jnp.float32),
    )


def linear_head_schema(d: int, vocab: int):
    return {"w": P((d, vocab), ("embed", "vocab"), fanin_std(d))}


def linear_head(params, x):
    return jnp.einsum(
        "...d,dv->...v", x.astype(jnp.float32), params["w"].astype(jnp.float32)
    )


# ---------------------------------------------------------------------------
# MLP (gated or plain), optionally biased
# ---------------------------------------------------------------------------

def mlp_schema(d: int, d_ff: int, *, gated: bool = True, bias: bool = False):
    s = {"w_in": P((d, d_ff), ("embed", "mlp"), fanin_std(d)),
         "w_out": P((d_ff, d), ("mlp", "embed"), fanin_std(d_ff))}
    if gated:
        s["w_gate"] = P((d, d_ff), ("embed", "mlp"), fanin_std(d))
    if bias:
        s["b_in"] = P((d_ff,), ("mlp",), 0.0)
        s["b_out"] = P((d,), ("embed",), 0.0)
    return s


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def apply_mlp(params, x, *, act: str = "silu"):
    dt = x.dtype
    h = jnp.einsum("...d,df->...f", x, params["w_in"].astype(dt))
    if "b_in" in params:
        h = h + params["b_in"].astype(dt)
    if "w_gate" in params:
        g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(dt))
        h = _act(act)(g) * h
    else:
        h = _act(act)(h)
    out = jnp.einsum("...f,fd->...d", h, params["w_out"].astype(dt))
    if "b_out" in params:
        out = out + params["b_out"].astype(dt)
    return out


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def sinusoidal_positions(seq_len: int, d: int, dtype=jnp.float32):
    pos = np.arange(seq_len)[:, None]
    dim = np.arange(d // 2)[None, :]
    inv = 1.0 / (10000 ** (2 * dim / d))
    ang = pos * inv
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, dtype)


def cross_entropy_loss(logits, labels, *, z_loss: float = 0.0):
    """Mean next-token CE. labels == -1 are masked out."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

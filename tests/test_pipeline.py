"""GPipe pipeline parallelism: semantics vs sequential execution."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sharding.pipeline import bubble_fraction, gpipe_apply

ROOT = Path(__file__).resolve().parent.parent

_DRIVER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.sharding.pipeline import gpipe_apply

mesh = jax.make_mesh((4, 2), ("pipe", "data"))
rng = np.random.default_rng(0)
L, S, d = 8, 4, 16            # 8 layers over 4 stages
W = jnp.asarray(rng.normal(size=(L, d, d)).astype(np.float32) * 0.3)
x = jnp.asarray(rng.normal(size=(8, d)).astype(np.float32))

def layer(w, h):
    return jnp.tanh(h @ w)

# sequential reference
ref = x
for i in range(L):
    ref = layer(W[i], ref)

stage_params = W.reshape(4, 2, d, d)
with mesh:
    out = gpipe_apply(layer, stage_params, x, mesh=mesh, microbatches=4)
err = float(jnp.abs(out - ref).max())
assert err < 1e-5, err

# gradients flow through the pipeline
def loss_pp(Wf):
    return jnp.sum(gpipe_apply(layer, Wf.reshape(4, 2, d, d), x,
                               mesh=mesh, microbatches=4) ** 2)
def loss_seq(Wf):
    h = x
    for i in range(L):
        h = layer(Wf[i], h)
    return jnp.sum(h ** 2)
with mesh:
    g_pp = jax.grad(loss_pp)(W)
g_seq = jax.grad(loss_seq)(W)
gerr = float(jnp.abs(g_pp - g_seq).max() / (jnp.abs(g_seq).max() + 1e-9))
assert gerr < 1e-4, gerr
print("PIPELINE_OK", err, gerr)
"""


def test_bubble_fraction():
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert bubble_fraction(1, 8) == 0.0


def test_gpipe_single_stage_identity(rng):
    """stages=1 degenerates to a plain scan (runs on the real 1-CPU mesh)."""
    mesh = jax.make_mesh((1,), ("pipe",))
    W = jnp.asarray(rng.normal(size=(4, 8, 8)).astype(np.float32) * 0.3)
    x = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))

    def layer(w, h):
        return jnp.tanh(h @ w)

    ref = x
    for i in range(4):
        ref = layer(W[i], ref)
    with mesh:
        out = gpipe_apply(layer, W.reshape(1, 4, 8, 8), x, mesh=mesh,
                          microbatches=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


@pytest.mark.slow
def test_gpipe_multistage_subprocess():
    """4-stage pipeline on 8 forced host devices: forward + grad parity."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run([sys.executable, "-c", _DRIVER], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "PIPELINE_OK" in r.stdout

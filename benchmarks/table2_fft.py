"""Table 2 — FFT kernel performance for various sizes (paper §5.1.1).

Reproduces the VWR2A column of Table 2 from the cycle-accurate simulator;
CPU and FFT-accelerator columns are the paper's measurements (they are
physical-SoC numbers we cannot re-measure). Derived: sim/paper cycle ratio
and the speed-up over the paper's CPU baseline.

Beyond the paper: a column-scaling sweep (the paper's machine is the
2-column instance; Ara/STRELA-style parameterization lets us sweep
n_columns) and the vectorized-vs-scalar simulator engine speedup — the
perf-trajectory numbers CI tracks via the BENCH_*.json artifact.
"""
from __future__ import annotations

import time

import numpy as np

PAPER = {
    # n: (cpu_cycles, accel_cycles, vwr2a_cycles)
    "complex": {512: (47926, 7099, 7125), 1024: (84753, 13629, 12405),
                2048: (219667, 31299, 30217)},
    "real": {512: (24927, 3523, 3666), 1024: (62326, 8007, 7133),
             2048: (113489, 16490, 14427)},
}
F_HZ = 80e6


def run():
    from repro.archsim.programs.fft import run_fft, run_rfft

    rows = []
    rng = np.random.default_rng(0)
    for kind, sizes in PAPER.items():
        for n, (cpu, accel, vwr2a) in sizes.items():
            if kind == "complex":
                x = (rng.normal(size=n) + 1j * rng.normal(size=n)) * 0.3
                X, counters, cycles = run_fft(n, x)
                ref = np.fft.fft(x)
            else:
                x = rng.normal(size=n) * 0.3
                X, counters, cycles = run_rfft(n, x)
                ref = np.fft.rfft(x)
            rel = float(np.abs(X - ref).max() / np.abs(ref).max())
            us = cycles / F_HZ * 1e6
            rows.append((f"table2/{kind}_fft_{n}", us,
                         f"sim_cycles={cycles};paper_vwr2a={vwr2a};"
                         f"ratio={cycles / vwr2a:.2f};"
                         f"speedup_vs_cpu={cpu / cycles:.1f}x;"
                         f"q15_rel_err={rel:.1e}"))
    rows += _column_sweep(rng)
    rows += _engine_speedup(rng)
    return rows


def _column_sweep(rng, n: int = 512):
    """Wall-cycle scaling of the 512-pt complex FFT over machine width."""
    from repro.archsim.programs.fft import run_fft

    rows, base = [], None
    x = (rng.normal(size=n) + 1j * rng.normal(size=n)) * 0.3
    ref = np.fft.fft(x)
    for nc in (1, 2, 4):
        X, _, cycles = run_fft(n, x, n_columns=nc)
        rel = float(np.abs(X - ref).max() / np.abs(ref).max())
        base = base or cycles
        rows.append((f"table2/cfft_{n}_ncols{nc}", cycles / F_HZ * 1e6,
                     f"sim_cycles={cycles};scaling={base / cycles:.2f}x;"
                     f"q15_rel_err={rel:.1e}"))
    return rows


def _engine_speedup(rng, n: int = 512):
    """Vectorized vs scalar interpreter wall time (identical results)."""
    from repro.archsim.machine import VWR2A
    from repro.archsim.programs.fft import run_fft

    x = (rng.normal(size=n) + 1j * rng.normal(size=n)) * 0.3
    times = {}
    for engine in ("scalar", "vector"):
        run_fft(n, x, machine=VWR2A(engine=engine))       # warm caches
        t0 = time.perf_counter()
        run_fft(n, x, machine=VWR2A(engine=engine))
        times[engine] = (time.perf_counter() - t0) * 1e6
    return [(f"archsim/engine_vector_cfft_{n}", times["vector"],
             f"scalar_us={times['scalar']:.0f};"
             f"speedup={times['scalar'] / times['vector']:.1f}x")]

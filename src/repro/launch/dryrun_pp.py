import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Pipeline-parallel dry-run: compile a GPipe'd dense stack on the 512-chip
mesh re-axed as (pipe=8, data=64) — the PP strategy proof of DESIGN.md §5.

    PYTHONPATH=src python -m repro.launch.dryrun_pp
"""
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.analysis.hlo_cost import analyze
from repro.sharding.pipeline import bubble_fraction, gpipe_apply


def main():
    mesh = jax.make_mesh((8, 64), ("pipe", "data"))
    d, d_ff = 1024, 2816                 # qwen1.5-0.5b-scale dense layer
    L, stages = 24, 8
    B, S = 256, 512                      # microbatched 8x inside the pipe

    def layer(p, h):
        w1, w2 = p
        return h + jnp.tanh(h @ w1) @ w2

    params = (
        jax.ShapeDtypeStruct((stages, L // stages, d, d_ff), jnp.bfloat16),
        jax.ShapeDtypeStruct((stages, L // stages, d_ff, d), jnp.bfloat16),
    )
    x = jax.ShapeDtypeStruct((B, S, d), jnp.bfloat16)

    def step(params, x):
        def lf(p, h):
            return layer(p, h)
        return gpipe_apply(lf, params, x, mesh=mesh, microbatches=4,
                           batch_axis="data")

    t0 = time.time()
    with mesh:
        lowered = jax.jit(step).lower(params, x)
        compiled = lowered.compile()
    hlo = compiled.as_text()
    rec = {
        "mesh": {"pipe": 8, "data": 64},
        "layers": L, "stages": stages, "microbatches": 4,
        "bubble_fraction": bubble_fraction(stages, 4),
        "compile_s": round(time.time() - t0, 1),
        "hlo_cost": analyze(hlo),
        "status": "ok",
    }
    n_perm = rec["hlo_cost"]["collectives"].get("collective-permute",
                                                {"count": 0})
    out = Path("results/dryrun/pp__dense24__pipe8.json")
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=1))
    print(f"[dryrun_pp] ok compile={rec['compile_s']}s "
          f"bubble={rec['bubble_fraction']:.2f} "
          f"collective-permutes={n_perm['count']}")


if __name__ == "__main__":
    main()

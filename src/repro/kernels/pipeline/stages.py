"""Stage registry for the fused pipeline graph compiler.

The paper's core claim is flexibility — ONE substrate (columns of RCs
fed from very-wide registers) accelerating MANY kernels. The code-level
analogue is this registry: a *stage* is one fused-kernel building block
(FIR, delineation, windowing, packed rFFT, a matmul epilogue, ...) with
a declared VMEM operand signature, and a *stage graph*
(`graph.py:StageGraph`) chains registered stages into ONE `pallas_call`
body — single VMEM residency, in-kernel framing, `outputs=` elision and
the ring grid all shared across workloads. The biosignal app
(`kernel.py`) and the streaming ASR front-end (`asr.py`) are two graphs
over this one registry; `docs/STAGE_GRAPHS.md` is the authoring guide.

A stage declares four things:

* ``kind`` — ``"fir"`` for the mandatory FIRST stage (a causal k-tap
  FIR; the stream/ring framing machinery keys its frame-local head
  patch off this stage's tap count), ``"map"`` for everything else;
* ``operands`` — the names of the staged VMEM table operands its body
  reads (FIR taps, twiddles, Hann window, mel weights, the odd-even
  sort masks — the paper keeps such tables in the SPM). A graph binds
  each name to a concrete array once, outside the kernel;
* ``requires`` / ``produces`` — the state keys (per-frame tensors that
  NEVER leave VMEM) the body consumes and defines. The graph compiler
  checks the dataflow at build time and uses it for output elision:
  a stage only runs when a *requested* output transitively depends on
  it (`graph.py:stages_to_run`);
* ``body`` — ``body(state, tables, params) -> dict`` of new state
  entries, pure jnp on VMEM-resident values.

Error taxonomy (all rooted at `StageGraphError`, a `ValueError` so
legacy ``except ValueError`` call sites still catch):
`UnknownStageError` (a graph names a stage that was never registered),
`OperandMismatchError` (a stage's operand signature is not satisfied by
the graph's operand list, or the dataflow is unsatisfiable), and
`UnknownGraphError` (`graph.py:get_graph_factory` lookup miss).
`tests/test_stage_graph.py` pins all three.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

__all__ = ["Stage", "StageGraphError", "UnknownStageError",
           "OperandMismatchError", "UnknownGraphError", "register_stage",
           "get_stage", "registered_stages"]


class StageGraphError(ValueError):
    """Root of the stage-graph error taxonomy (a `ValueError`: graph
    construction errors are bad-argument errors to the caller)."""


class UnknownStageError(StageGraphError):
    """A graph referenced a stage name that is not in the registry."""


class OperandMismatchError(StageGraphError):
    """A stage's declared operand signature (or state dataflow) is not
    satisfied by the graph binding it."""


class UnknownGraphError(StageGraphError):
    """`get_graph_factory` was asked for a graph name never registered."""


@dataclasses.dataclass(frozen=True)
class Stage:
    """One fused-kernel building block (see the module docstring).

    Frozen + hashable so a `StageGraph` holding stages can be a STATIC
    jit argument of the graph entries (`graph.py:graph_stream_pallas`);
    the ``body`` callable hashes by identity, which is stable for the
    module-level registrations this registry holds.
    """
    name: str
    kind: str                       # "fir" | "map"
    operands: tuple                 # staged VMEM table names the body reads
    requires: tuple                 # state keys consumed
    produces: tuple                 # state keys defined
    body: Callable                  # body(state, tables, params) -> dict

    def __post_init__(self):
        if self.kind not in ("fir", "map"):
            raise StageGraphError(
                f"stage {self.name!r}: kind must be 'fir' or 'map', "
                f"got {self.kind!r}")
        if self.kind == "fir" and len(self.operands) != 1:
            raise OperandMismatchError(
                f"fir stage {self.name!r} must declare exactly one "
                f"operand (its tap table), got {self.operands}")


_REGISTRY: dict[str, Stage] = {}


def register_stage(name: str, *, kind: str = "map", operands=(),
                   requires=(), produces=()):
    """Decorator registering ``fn`` as the body of stage ``name``.

    >>> @register_stage("hann", operands=("hann",),
    ...                 requires=("filtered",), produces=("windowed",))
    ... def _hann(state, tables, params): ...

    Re-registering an existing name raises `StageGraphError` — stages
    are process-wide singletons shared by every graph that names them
    (the biosignal and ASR graphs share ``"fir"``).
    """
    def deco(fn):
        if name in _REGISTRY:
            raise StageGraphError(f"stage {name!r} is already registered")
        _REGISTRY[name] = Stage(name=name, kind=kind,
                                operands=tuple(operands),
                                requires=tuple(requires),
                                produces=tuple(produces), body=fn)
        return fn
    return deco


def get_stage(name: str) -> Stage:
    """Registry lookup; raises the typed `UnknownStageError` on a miss."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownStageError(
            f"unknown stage {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def registered_stages() -> tuple:
    """Registered stage names, sorted (docs/tests introspection)."""
    return tuple(sorted(_REGISTRY))

"""Chaos tests for the fault-tolerant LM engine (`serve/engine_fault.py`).

THE INVARIANT under test everywhere — the LM-side twin of
`tests/test_chaos.py`'s column property: for ANY injected fault schedule
(slot kills at prefill or any decode step, transient prefill/decode
faults, hang -> heartbeat eviction, straggler eviction), every submitted
request completes and its token sequence is **bit-identical** to the
fault-free run, greedy AND temperature-sampled. Every scenario runs on
the injected `VirtualClock` so heartbeat timeouts and straggler medians
replay deterministically. Admission backpressure (`QueueFull`, TTL
expiry) and graceful degradation (`InsufficientHealthyWorkers` only when
no healthy slot remains) ride along.
"""
import dataclasses

import pytest

from repro.configs import get_config, reduced
from repro.models import build_model, init_model_params
from repro.runtime.fault import (InsufficientHealthyWorkers,
                                 StragglerDetector, Supervisor)
from repro.serve.engine import Engine, EngineStalled, Request
from repro.serve.engine_fault import (FaultInjector, FaultTolerantEngine,
                                      QueueFull, RequestExpired,
                                      VirtualClock)

SLOTS, MAX_LEN, MAX_NEW = 4, 64, 6
PROMPTS = {0: [3, 1, 4, 1], 1: [5, 9, 2], 2: [6, 5], 3: [8, 9, 7, 9, 3],
           4: [2, 3, 8], 5: [4, 6, 2, 6]}


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(reduced(get_config("qwen1.5-0.5b")),
                              vocab_size=64)
    model = build_model(cfg)
    params = init_model_params(model, seed=3)
    compiled = Engine.compile_model(model)
    return model, params, compiled


@pytest.fixture(scope="module")
def reference(setup):
    """Fault-free outputs {rid: tokens}, keyed by temperature."""
    cache = {}

    def get(temperature: float):
        if temperature not in cache:
            done, _ = _serve(setup, Engine, temperature)
            cache[temperature] = done
        return cache[temperature]

    return get


def _engine(setup, cls, temperature, **kw):
    model, params, compiled = setup
    return cls(model, params, slots=SLOTS, max_len=MAX_LEN,
               temperature=temperature, seed=7, compiled=compiled, **kw)


def _serve(setup, cls, temperature, rids=tuple(PROMPTS), **kw):
    eng = _engine(setup, cls, temperature, **kw)
    for rid in rids:
        eng.submit(Request(rid, list(PROMPTS[rid]), max_new=MAX_NEW))
    done = eng.run_to_completion(max_steps=500)
    assert sorted(r.rid for r in done) == sorted(rids)
    return {r.rid: tuple(r.out) for r in done}, eng


def _ft(temperature=0.8, **inj_kw):
    clk = VirtualClock()
    inj = FaultInjector(dispatch_s=0.01, clock=clk, **inj_kw)
    return inj, clk


# ------------------------------------------------------------ no faults

@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_fault_free_matches_base_engine(setup, reference, temperature):
    """Supervision with no injected faults is a no-op on the tokens."""
    out, eng = _serve(setup, FaultTolerantEngine, temperature,
                      injector=FaultInjector(clock=VirtualClock()),
                      heartbeat_timeout=10.0)
    assert out == reference(temperature)
    assert eng.evictions == 0 and eng.replays == 0


# ---------------------------------------------------------- kill sweeps

# per-slot dispatch seq: the admission prefill is seq 0, decode steps
# follow — so seq 0 kills the slot AT PREFILL, seq 1 at its first decode
# step, seq k mid-decode.
@pytest.mark.parametrize("temperature", [0.0, 0.8])
@pytest.mark.parametrize("slot,seq", [(0, 0), (1, 0), (0, 1), (2, 1),
                                      (0, 3), (3, 5)])
def test_killed_slot_recovers_bit_identical(setup, reference, temperature,
                                            slot, seq):
    inj, clk = _ft(kill={slot: seq})
    out, eng = _serve(setup, FaultTolerantEngine, temperature, injector=inj)
    assert out == reference(temperature)
    assert eng.dead_slots == {slot}
    assert eng.evictions == 1 and eng.replays == 1


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_multi_kill_recovers_bit_identical(setup, reference, temperature):
    inj, clk = _ft(kill={0: 2, 2: 0, 3: 4})
    out, eng = _serve(setup, FaultTolerantEngine, temperature, injector=inj)
    assert out == reference(temperature)
    assert eng.dead_slots == {0, 2, 3}
    # the engine finished everything on the single surviving slot
    assert eng.healthy_slots() == [1]


def test_replayed_request_marked_and_requeued_deterministically(setup):
    inj, clk = _ft(kill={0: 1, 1: 1})
    eng = _engine(setup, FaultTolerantEngine, 0.0, injector=inj)
    for rid in PROMPTS:
        eng.submit(Request(rid, list(PROMPTS[rid]), max_new=MAX_NEW))
    eng.step()
    # both evicted requests sit at the queue FRONT in rid order
    assert [r.rid for r in eng.queue[:2]] == [0, 1]
    assert all(r.replayed for r in eng.queue[:2])
    assert not any(r.replayed for r in eng.queue[2:])


# ----------------------------------------------------------- transients

@pytest.mark.parametrize("temperature", [0.0, 0.8])
@pytest.mark.parametrize("faults", [
    {(0, 0)},                    # at prefill
    {(1, 1)},                    # at first decode step
    {(2, 3), (2, 4)},            # two in a row mid-decode
    {(0, 0), (1, 2), (3, 3)},    # spread across slots
])
def test_transient_faults_absorbed_in_place(setup, reference, temperature,
                                            faults):
    """Retryable faults never evict: the Supervisor's backoff absorbs
    them (each retry consumes the slot's next injector seq)."""
    inj, clk = _ft(transient=faults)
    out, eng = _serve(setup, FaultTolerantEngine, temperature, injector=inj)
    assert out == reference(temperature)
    assert eng.evictions == 0 and eng.dead_slots == set()


def test_transient_budget_exhausted_escalates_to_eviction(setup, reference):
    """More consecutive transients than the retry budget: the slot is
    evicted and the request replays — still bit-identical, never lost."""
    inj, clk = _ft(transient={(0, s) for s in range(10)})
    out, eng = _serve(setup, FaultTolerantEngine, 0.8, injector=inj,
                      retry=Supervisor(max_retries=2))
    assert out == reference(0.8)
    assert eng.dead_slots == {0} and eng.replays == 1


# ---------------------------------------------------------------- hangs

@pytest.mark.parametrize("temperature", [0.0, 0.8])
@pytest.mark.parametrize("slot,seq", [(0, 0), (1, 1), (2, 4)])
def test_hung_slot_evicted_by_heartbeat_timeout(setup, reference,
                                                temperature, slot, seq):
    """A wedged slot neither errors nor retires — only the decode-progress
    heartbeat going quiet can resolve it (token retires beat the monitor;
    a hung slot stops beating)."""
    inj, clk = _ft(hang_from={slot: seq})
    out, eng = _serve(setup, FaultTolerantEngine, temperature, injector=inj,
                      heartbeat_timeout=0.1)
    assert out == reference(temperature)
    assert eng.dead_slots == {slot}
    assert eng.evictions == 1 and eng.replays == 1


def test_hang_without_supervision_stalls_loudly(setup):
    """No heartbeat monitor: the wedged slot can never be declared dead,
    so the engine runs out of steps and raises the typed EngineStalled
    naming the wedged request — loud, not a silent drop."""
    inj, clk = _ft(hang_from={0: 1})
    eng = _engine(setup, FaultTolerantEngine, 0.0, injector=inj)
    for rid in (0, 1):
        eng.submit(Request(rid, list(PROMPTS[rid]), max_new=MAX_NEW))
    with pytest.raises(EngineStalled) as ei:
        eng.run_to_completion(max_steps=40)
    assert 0 in ei.value.unfinished


# ------------------------------------------------------------ stragglers

def test_straggler_slot_evicted_and_replayed(setup, reference):
    """A persistently slow slot (injected per-dispatch delay) is evicted
    by the median-of-medians straggler vote before it ever fails."""
    inj, clk = _ft(slow={1: 0.5})
    out, eng = _serve(
        setup, FaultTolerantEngine, 0.8, injector=inj,
        straggler=StragglerDetector(window=4, straggler_factor=3.0,
                                    evict_after=2))
    assert out == reference(0.8)
    assert 1 in eng.dead_slots


# -------------------------------------------------- degradation to zero

def test_all_slots_dead_raises_insufficient_healthy_workers(setup):
    inj, clk = _ft(kill={s: 0 for s in range(SLOTS)})
    eng = _engine(setup, FaultTolerantEngine, 0.0, injector=inj)
    for rid in (0, 1):
        eng.submit(Request(rid, list(PROMPTS[rid]), max_new=MAX_NEW))
    with pytest.raises(InsufficientHealthyWorkers):
        eng.run_to_completion(max_steps=100)
    assert eng.dead_slots == set(range(SLOTS))


# ------------------------------------------------- admission backpressure

def test_queue_full_rejects_typed(setup):
    eng = _engine(setup, FaultTolerantEngine, 0.0, max_queue=2)
    eng.submit(Request(0, [1, 2], max_new=2))
    eng.submit(Request(1, [1, 2], max_new=2))
    with pytest.raises(QueueFull) as ei:
        eng.submit(Request(2, [1, 2], max_new=2))
    assert ei.value.rid == 2 and ei.value.max_queue == 2
    # admission drains the queue; capacity frees up again
    eng.run_to_completion()
    eng.submit(Request(2, [1, 2], max_new=2))


def test_ttl_expiry_drops_queued_requests_typed(setup):
    """Requests whose deadline passes while QUEUED are shed into
    `engine.expired` (and a dead-on-arrival TTL raises at submit);
    admitted requests still finish."""
    clk = VirtualClock()
    inj = FaultInjector(dispatch_s=1.0, clock=clk)
    eng = _engine(setup, FaultTolerantEngine, 0.0, injector=inj)
    for rid in range(SLOTS):            # fill every slot
        eng.submit(Request(rid, list(PROMPTS[rid]), max_new=MAX_NEW))
    eng.submit(Request(9, [1, 2], max_new=2), ttl=0.5)   # queued, will age
    with pytest.raises(RequestExpired):
        eng.submit(Request(10, [1, 2], max_new=2), ttl=0.0)
    done = eng.run_to_completion()
    assert sorted(r.rid for r in done) == list(range(SLOTS))
    assert [r.rid for r in eng.expired] == [9]
    assert 9 not in eng.deadlines


# ----------------------------------------------------- injector sharing

def test_injector_determinism_across_reset(setup, reference):
    """`FaultInjector.reset` rewinds the per-slot seq counters (not the
    clock): one schedule replays identically across reps — the property
    the bench gate's paired reps lean on."""
    inj, clk = _ft(kill={0: 2})
    out1, e1 = _serve(setup, FaultTolerantEngine, 0.8, injector=inj)
    inj.reset()
    out2, e2 = _serve(setup, FaultTolerantEngine, 0.8, injector=inj)
    assert out1 == out2 == reference(0.8)
    assert e1.evictions == e2.evictions == 1


# ------------------------------------------------- paged + supervision

def test_paged_killed_slot_recovers_bit_identical(setup, reference):
    """The full stack — paged KV + supervision: a slot killed mid-decode
    frees its pages, its request replays into FRESH pages, and the
    continuation is bit-identical to the fault-free DENSE run (greedy
    and temperature, prefill-kill and mid-decode kill)."""
    from repro.serve.engine_fault import FaultTolerantPagedEngine
    for temperature in (0.0, 0.8):
        for slot, seq in ((1, 0), (0, 3)):
            inj, clk = _ft(kill={slot: seq})
            out, eng = _serve(setup, FaultTolerantPagedEngine, temperature,
                              injector=inj, page_size=8)
            assert out == reference(temperature)
            assert eng.evictions == 1 and eng.replays == 1
            # the dead slot's pages were reclaimed, none leaked
            assert eng.pool.n_free == eng.pool.capacity


def test_paged_eviction_frees_pages_for_waiting_admissions(setup,
                                                           reference):
    """Fragmentation-after-eviction: mixed-size requests oversubscribe a
    SMALL pool, a mid-decode eviction punches holes in it, and the
    waiting admissions reuse the freed (non-contiguous) pages — the
    block-table indirection makes fragmentation harmless. Tokens stay
    bit-identical to dense; the pool drains to empty."""
    from repro.serve.engine_fault import FaultTolerantPagedEngine
    inj, clk = _ft(kill={2: 3})
    eng = _engine(setup, FaultTolerantPagedEngine, 0.8, injector=inj,
                  page_size=4, n_pages=13)   # < slots*ceil(64/4): tight
    for rid in PROMPTS:
        eng.add_request(Request(rid, list(PROMPTS[rid]), max_new=MAX_NEW))
    done = eng.run_to_completion(max_steps=500)
    out = {r.rid: tuple(r.out) for r in done}
    assert out == reference(0.8)
    assert eng.evictions == 1 and eng.replays == 1
    assert eng.peak_admitted > 0
    assert eng.pool.n_free == eng.pool.capacity

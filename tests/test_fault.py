"""Unit tests for the fault-tolerance decision layer (`runtime/fault.py`):
heartbeat timeout edges, straggler strike/reset hysteresis, the typed
`elastic_plan` error path, and `Supervisor` retry semantics (consecutive
budget, configurable `retry_on`, capped-backoff `call`, restore-replay
determinism). The end-to-end serving loop built on these lives in
`tests/test_chaos.py`."""
import jax.numpy as jnp
import pytest

from repro.runtime.fault import (ColumnDeadError, HeartbeatMonitor,
                                 InsufficientHealthyWorkers,
                                 StragglerDetector, Supervisor,
                                 TransientDispatchError, elastic_plan)


# ------------------------------------------------------------ heartbeats

def test_heartbeat_timeout_edges():
    hb = HeartbeatMonitor(timeout_s=10.0)
    hb.beat(0, t=100.0)
    # the timeout is STRICT: exactly timeout_s of silence is still alive
    assert hb.dead(now=110.0) == []
    assert hb.alive(now=110.0) == [0]
    assert hb.dead(now=110.0 + 1e-9) == [0]
    # a fresh beat resurrects the worker before anyone observed it dead
    hb.beat(0, t=111.0)
    assert hb.dead(now=120.0) == []


def test_heartbeat_forget_removes_from_both_lists():
    hb = HeartbeatMonitor(timeout_s=5.0)
    hb.beat(0, t=0.0)
    hb.beat(1, t=0.0)
    hb.forget(0)
    assert hb.alive(now=1.0) == [1]
    assert hb.dead(now=100.0) == [1]       # forgotten != dead
    hb.forget(99)                          # unknown worker: no-op


# ------------------------------------------------------------ stragglers

def test_straggler_strikes_accumulate_then_evict():
    det = StragglerDetector(straggler_factor=2.0, evict_after=3)
    for w in range(4):
        det.record(w, 1.0 if w != 3 else 5.0)
    # strikes 1 and 2 are below the eviction threshold
    assert det.stragglers() == []
    assert det.stragglers() == []
    assert det.stragglers() == [3]         # strike 3 evicts


def test_straggler_strikes_reset_on_recovery():
    det = StragglerDetector(window=4, straggler_factor=2.0, evict_after=2)
    for w in range(3):
        det.record(w, 1.0)
    det.record(3, 9.0)
    assert det.stragglers() == []          # strike 1
    # the worker recovers: fast samples push the slow one out of the
    # rolling window, the strike counter resets to zero
    for _ in range(4):
        det.record(3, 1.0)
    assert det.stragglers() == []
    assert det.stragglers() == []          # still zero strikes, not one


def test_straggler_forget_drops_samples_and_strikes():
    det = StragglerDetector(straggler_factor=2.0, evict_after=1)
    for w in range(3):
        det.record(w, 1.0)
    det.record(3, 9.0)
    det.forget(3)
    assert det.stragglers() == []          # no sample left to strike on


# ---------------------------------------------------------- elastic plan

def test_elastic_plan_raises_typed_error_below_model_axis():
    with pytest.raises(InsufficientHealthyWorkers):
        elastic_plan(15, model_axis=16)
    # the boundary itself is satisfiable: one model shard, data=1
    plan = elastic_plan(16, model_axis=16)
    assert plan == {"pod": 1, "data": 1, "model": 16, "chips": 16,
                    "spare": 0}


def test_elastic_plan_caller_can_degrade_on_typed_error():
    """The caller-side pattern the typed exception exists for: shrink the
    model axis instead of crashing on an assert."""
    def plan_or_degrade(chips, model_axis):
        while True:
            try:
                return elastic_plan(chips, model_axis=model_axis)
            except InsufficientHealthyWorkers:
                assert model_axis > 1, "no plan fits"
                model_axis //= 2

    plan = plan_or_degrade(12, model_axis=16)
    assert plan["model"] == 8 and plan["chips"] <= 12
    assert plan["data"] & (plan["data"] - 1) == 0


def test_elastic_plan_data_axis_is_largest_pow2():
    plan = elastic_plan(16 * 5, model_axis=16, pods_of=256)
    assert plan["data"] == 4               # 5 rounds down to 4
    assert plan["spare"] == 16
    assert plan["chips"] == plan["pod"] * plan["data"] * plan["model"]


# ------------------------------------------------------------ supervisor

def _replay_harness():
    store = {}

    def save_fn(state, step):
        store[step] = float(state)

    def restore_fn(step):
        return jnp.asarray(store.get(step, 0.0))

    save_fn(jnp.asarray(0.0), 0)
    return store, save_fn, restore_fn


def test_supervisor_retries_reset_on_any_successful_step():
    """max_retries bounds CONSECUTIVE failures: with progress between
    failures, a long run tolerates arbitrarily many of them. The old
    reset-on-checkpoint-only behavior would exhaust the budget here (4
    failures > max_retries=3, all within one ckpt_every=100 interval)."""
    _, save_fn, restore_fn = _replay_harness()
    failures = {3, 5, 7, 9}

    def inject(step):
        if step in failures:
            failures.discard(step)
            raise RuntimeError("node lost")

    sup = Supervisor(save_fn=save_fn, restore_fn=restore_fn,
                     ckpt_every=100, max_retries=3)
    state, step, _ = sup.run(jnp.asarray(0.0),
                             lambda s, b: (s + b, {}),
                             lambda s: jnp.asarray(1.0), 12,
                             inject_failure=inject)
    assert step == 12 and float(state) == 12.0


def test_supervisor_consecutive_failures_exhaust_budget():
    _, save_fn, restore_fn = _replay_harness()

    def inject(step):
        if step == 2:
            raise RuntimeError("persistent fault")

    sup = Supervisor(save_fn=save_fn, restore_fn=restore_fn,
                     ckpt_every=100, max_retries=2)
    with pytest.raises(RuntimeError, match="persistent"):
        sup.run(jnp.asarray(0.0), lambda s, b: (s + b, {}),
                lambda s: jnp.asarray(1.0), 5, inject_failure=inject)


def test_supervisor_retry_on_is_configurable():
    """Only the configured exception types are retried; a ColumnDeadError
    is not a RuntimeError, so the default policy never swallows it."""
    assert not issubclass(ColumnDeadError, RuntimeError)
    _, save_fn, restore_fn = _replay_harness()

    def inject(step):
        if step == 1:
            raise ValueError("not retryable by default")

    sup = Supervisor(save_fn=save_fn, restore_fn=restore_fn, ckpt_every=2)
    with pytest.raises(ValueError):
        sup.run(jnp.asarray(0.0), lambda s, b: (s + b, {}),
                lambda s: jnp.asarray(1.0), 4, inject_failure=inject)

    once = [True]

    def inject2(step):
        if step == 1 and once:
            once.pop()
            raise ValueError("now retryable")

    sup2 = Supervisor(save_fn=save_fn, restore_fn=restore_fn, ckpt_every=2,
                      retry_on=(ValueError,))
    state, step, _ = sup2.run(jnp.asarray(0.0), lambda s, b: (s + b, {}),
                              lambda s: jnp.asarray(1.0), 4,
                              inject_failure=inject2)
    assert step == 4 and float(state) == 4.0


def test_supervisor_restore_replay_is_deterministic():
    """Replay from checkpoint is exact: the state after a crashy run
    equals the fault-free run bit for bit (batches are a pure function
    of step, so re-executed steps consume identical inputs)."""
    def batches(step):
        return jnp.asarray(float(step % 3 + 1))

    def step_fn(s, b):
        return s * 1.5 + b, {}

    def run(failures):
        _, save_fn, restore_fn = _replay_harness()

        def inject(step):
            if step in failures:
                failures.discard(step)
                raise RuntimeError("lost")

        sup = Supervisor(save_fn=save_fn, restore_fn=restore_fn,
                         ckpt_every=4)
        state, _, _ = sup.run(jnp.asarray(0.0), step_fn, batches, 17,
                              inject_failure=inject)
        return float(state)

    assert run(set()) == run({5, 6, 13})


def test_supervisor_call_retries_with_capped_backoff():
    sleeps = []
    sup = Supervisor(max_retries=4, retry_on=(TransientDispatchError,),
                     backoff_base_s=1.0, backoff_factor=2.0,
                     backoff_cap_s=3.0, sleep=sleeps.append)
    attempts = []

    def flaky():
        attempts.append(len(attempts))
        if len(attempts) < 5:
            raise TransientDispatchError("flaky link")
        return "ok"

    assert sup.call(flaky) == "ok"
    # exponential 1, 2, 4, 8 clamped at the 3s cap
    assert sleeps == [1.0, 2.0, 3.0, 3.0]


def test_supervisor_call_exhausts_and_reraises():
    sup = Supervisor(max_retries=2, retry_on=(TransientDispatchError,))
    calls = []

    def always_fails():
        calls.append(1)
        raise TransientDispatchError("down")

    with pytest.raises(TransientDispatchError):
        sup.call(always_fails)
    assert len(calls) == 3                 # initial + 2 retries


def test_supervisor_call_does_not_retry_column_death():
    sup = Supervisor(max_retries=5)        # default retry_on=(RuntimeError,)
    calls = []

    def dies():
        calls.append(1)
        raise ColumnDeadError(2)

    with pytest.raises(ColumnDeadError) as ei:
        sup.call(dies)
    assert len(calls) == 1 and ei.value.column == 2

"""Pure-jnp oracle for the FIR kernel."""
from __future__ import annotations

from repro.core.fir import fir_direct as fir_ref  # noqa: F401
from repro.core.fir import fir_reference          # noqa: F401

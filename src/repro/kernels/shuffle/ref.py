"""Pure-jnp oracle for the shuffle-unit kernel: delegates to core/shuffle
(the semantic source of truth for the paper's four permutations)."""
from __future__ import annotations

from repro.core.shuffle import (  # noqa: F401
    bit_reverse,
    circular_shift,
    interleave,
    prune,
)


def shuffle_ref(a, b, op: str, **kw):
    if op == "interleave":
        return interleave(a, b, kw.get("half", "both"))
    if op == "prune_even":
        return prune(a, b, drop="even")
    if op == "prune_odd":
        return prune(a, b, drop="odd")
    if op == "bit_reverse":
        return bit_reverse(a, b, kw.get("half", "both"))
    if op == "circular_shift":
        return circular_shift(a, b, kw.get("amount", 32), kw.get("half", "both"))
    raise ValueError(op)

"""Compatibility shims for optional third-party dependencies."""

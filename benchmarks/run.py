"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call: simulated kernels run
at the paper's 80 MHz clock; Pallas kernels report interpret-mode wall time
on CPU — the structural stand-in for the TPU target).

``--json PATH`` additionally writes the rows as a BENCH_*.json artifact
(the perf-trajectory record CI uploads per commit); ``--only`` selects a
comma-separated subset of table modules for the CI smoke run.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def main() -> None:
    from benchmarks import (kernel_bench, table2_fft, table3_power,
                            table4_fir, table5_app)

    mods = {m.__name__.split(".")[-1]: m
            for m in (table2_fft, table3_power, table4_fir, table5_app,
                      kernel_bench)}
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset, e.g. "
                         "table2_fft,table4_fir (default: all)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows to a BENCH_*.json artifact")
    ap.add_argument("--check-fused", action="store_true",
                    help="fail if any */pipeline_fused row is slower than "
                         "its */pipeline_staged sibling (interpret-mode "
                         "regression gate for the fused application kernel)")
    ap.add_argument("--check-stream", action="store_true",
                    help="fail unless the raw-signal in-kernel-framing row "
                         "(*/stream_fused) beats its host-framed fused "
                         "sibling (*/stream_framed_fused) by >= the "
                         "--stream-ratio threshold — the single-residency "
                         "streaming gate (rows are timed paired, "
                         "alternating min-of-reps)")
    ap.add_argument("--stream-ratio", type=float, default=1.25,
                    metavar="R", help="--check-stream threshold (default "
                    "1.25; the multi-device CI leg gates at 1.05 — "
                    "splitting the host thread pool across 8 fake devices "
                    "thins the margin without touching the property)")
    ap.add_argument("--check-asr", action="store_true",
                    help="fail unless the fused ASR feature front-end "
                         "(*/asr_fused — ONE pallas_call, 'asr' stage "
                         "graph with in-kernel framing) beats the staged "
                         "4-launch reference (*/asr_staged) by >= the "
                         "--asr-ratio threshold — the second-workload "
                         "stage-graph gate (rows are timed paired)")
    ap.add_argument("--asr-ratio", type=float, default=1.2,
                    metavar="R", help="--check-asr threshold "
                    "(default 1.2)")
    ap.add_argument("--check-hetero", action="store_true",
                    help="fail unless the telemetry-driven dynamic deal "
                         "(*/stream_hetero_dynamic) beats the static equal "
                         "deal (*/stream_hetero_static) by >= the "
                         "--hetero-ratio threshold when one of D=4 columns "
                         "carries a 2x background load — the load-aware "
                         "scheduler gate")
    ap.add_argument("--hetero-ratio", type=float, default=1.15,
                    metavar="R", help="--check-hetero threshold "
                    "(default 1.15)")
    ap.add_argument("--check-resident", action="store_true",
                    help="fail unless the device-resident steady-state "
                         "loop (*/stream_resident) is at least as fast as "
                         "the host-driven per-batch dispatch loop "
                         "(*/stream_perbatch) — the on-device control-flow "
                         "gate (rows are timed paired)")
    ap.add_argument("--resident-ratio", type=float, default=1.0,
                    metavar="R", help="--check-resident threshold "
                    "(default 1.0: resident must not lose to per-batch "
                    "dispatch)")
    ap.add_argument("--check-fault", action="store_true",
                    help="fail unless killing one of D=4 columns mid-run "
                         "(*/stream_fault_recovered) keeps the modelled "
                         "dispatch wall within --fault-ratio of the "
                         "fault-free run (*/stream_faultfree) AND the "
                         "recovered outputs are bit-identical — the "
                         "fault-tolerant requeue gate (rows are timed "
                         "paired)")
    ap.add_argument("--fault-ratio", type=float, default=1.5,
                    metavar="R", help="--check-fault threshold (default "
                    "1.5: the ideal one-column-kill requeue costs ~5/4 "
                    "in modelled wall, measured ~1.2x; 1.5 leaves noise "
                    "margin without tolerating a second requeue pass)")
    ap.add_argument("--check-engine-fault", action="store_true",
                    help="fail unless killing one of 4 LM engine slots "
                         "mid-decode (*/engine_fault_recovered) keeps the "
                         "serving wall within --engine-fault-ratio of the "
                         "fault-free run (*/engine_faultfree) AND every "
                         "request's tokens are bit-identical — the "
                         "deterministic-replay gate (rows are timed "
                         "paired)")
    ap.add_argument("--engine-fault-ratio", type=float, default=1.5,
                    metavar="R", help="--check-engine-fault threshold "
                    "(default 1.5: one slot of 4 poisoned mid-decode "
                    "costs ~1.4x in decode steps; 1.5 leaves noise "
                    "margin without tolerating a second eviction)")
    ap.add_argument("--check-paged", action="store_true",
                    help="fail unless the paged-KV engine "
                         "(*/engine_paged) at oversubscribed admission "
                         "keeps its wall within --paged-ratio of the "
                         "dense-slot engine (*/engine_dense) AND every "
                         "request's tokens are bit-identical — the "
                         "paging-is-invisible gate (rows are timed "
                         "paired)")
    ap.add_argument("--paged-ratio", type=float, default=1.0,
                    metavar="R", help="--check-paged threshold (default "
                    "1.0: page views are narrower than dense max_len "
                    "attention, so paged must not LOSE to dense — "
                    "measured ~1.15x faster, the margin absorbs noise)")
    ap.add_argument("--check-columns", action="store_true",
                    help="fail unless the */stream_ncols{D} column-scaling "
                         "sweep is monotone: per-column latency must drop "
                         "as the frame deal widens (work per column ~1/D); "
                         "5%% tolerance absorbs timer noise")
    ap.add_argument("--autotune-json", default=None, metavar="PATH",
                    help="warm-start the autotune cache from PATH (if it "
                         "exists) and write the measured winners back — "
                         "the cross-commit record CI uploads and diffs")
    args = ap.parse_args()

    selected = list(mods)
    if args.only:
        selected = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = [s for s in selected if s not in mods]
        if unknown:
            raise SystemExit(f"unknown bench module(s) {unknown}; "
                             f"choose from {sorted(mods)}")

    if args.autotune_json:
        from repro.core import autotune

        loaded = autotune.load_cache(args.autotune_json)
        if loaded:
            print(f"autotune: warm-started {loaded} winners from "
                  f"{args.autotune_json}", file=sys.stderr)

    print("name,us_per_call,derived")
    rows, failed = [], 0
    for name in selected:
        t0 = time.perf_counter()
        try:
            for row in mods[name].run():
                rname, us, derived = row
                print(f"{rname},{us:.1f},{derived}")
                rows.append({"name": rname, "us_per_call": us,
                             "derived": derived, "module": name})
        except Exception as e:  # pragma: no cover
            failed += 1
            print(f"{name},nan,ERROR:{type(e).__name__}:{e}",
                  file=sys.stderr)
            traceback.print_exc()
        rows.append({"name": f"{name}/_wall_s", "module": name,
                     "us_per_call": (time.perf_counter() - t0) * 1e6,
                     "derived": "harness wall time"})

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "failed": failed,
                       "modules": selected}, f, indent=1)
    if args.autotune_json:
        from repro.core import autotune

        saved = autotune.save_cache(args.autotune_json)
        print(f"autotune: saved {saved} winners to {args.autotune_json}",
              file=sys.stderr)
    if args.check_stream:
        by_name = {r["name"]: r["us_per_call"] for r in rows}
        pairs = [(n, n.rsplit("stream_fused", 1)[0] + "stream_framed_fused")
                 for n in by_name if n.endswith("stream_fused")]
        if not pairs:
            print("check-stream: no stream_fused rows found",
                  file=sys.stderr)
            raise SystemExit(1)
        for stream, framed in pairs:
            us, uf = by_name[stream], by_name.get(framed)
            if uf is None or uf < args.stream_ratio * us:
                print(f"check-stream FAILED: {stream}={us:.1f}us vs "
                      f"{framed}={uf}us (need >= {args.stream_ratio}x)",
                      file=sys.stderr)
                raise SystemExit(1)
            print(f"check-stream ok: {stream} {us:.1f}us, {framed} "
                  f"{uf:.1f}us ({uf / us:.2f}x)")
    if args.check_asr:
        by_name = {r["name"]: r["us_per_call"] for r in rows}
        pairs = [(n, n.rsplit("asr_fused", 1)[0] + "asr_staged")
                 for n in by_name if n.endswith("asr_fused")]
        if not pairs:
            print("check-asr: no asr_fused rows found", file=sys.stderr)
            raise SystemExit(1)
        for fused, staged in pairs:
            uf, us = by_name[fused], by_name.get(staged)
            if us is None or us < args.asr_ratio * uf:
                print(f"check-asr FAILED: {fused}={uf:.1f}us vs "
                      f"{staged}={us}us (need >= {args.asr_ratio}x)",
                      file=sys.stderr)
                raise SystemExit(1)
            print(f"check-asr ok: {fused} {uf:.1f}us, {staged} "
                  f"{us:.1f}us ({us / uf:.2f}x)")
    if args.check_hetero:
        by_name = {r["name"]: r["us_per_call"] for r in rows}
        pairs = [(n, n.rsplit("stream_hetero_dynamic", 1)[0] +
                  "stream_hetero_static")
                 for n in by_name if n.endswith("stream_hetero_dynamic")]
        if not pairs:
            print("check-hetero: no stream_hetero rows found",
                  file=sys.stderr)
            raise SystemExit(1)
        for dyn, stat in pairs:
            ud, us = by_name[dyn], by_name.get(stat)
            if us is None or us < args.hetero_ratio * ud:
                print(f"check-hetero FAILED: {dyn}={ud:.1f}us vs "
                      f"{stat}={us}us (dynamic deal must be >= "
                      f"{args.hetero_ratio}x faster under a loaded column)",
                      file=sys.stderr)
                raise SystemExit(1)
            print(f"check-hetero ok: {dyn} {ud:.1f}us, {stat} {us:.1f}us "
                  f"({us / ud:.2f}x)")
    if args.check_resident:
        by_name = {r["name"]: r["us_per_call"] for r in rows}
        pairs = [(n, n.rsplit("stream_resident", 1)[0] + "stream_perbatch")
                 for n in by_name if n.endswith("stream_resident")]
        if not pairs:
            print("check-resident: no stream_resident rows found",
                  file=sys.stderr)
            raise SystemExit(1)
        for res, host in pairs:
            ur, uh = by_name[res], by_name.get(host)
            if uh is None or uh < args.resident_ratio * ur:
                print(f"check-resident FAILED: {res}={ur:.1f}us vs "
                      f"{host}={uh}us (resident must be >= "
                      f"{args.resident_ratio}x per-batch dispatch)",
                      file=sys.stderr)
                raise SystemExit(1)
            print(f"check-resident ok: {res} {ur:.1f}us, {host} "
                  f"{uh:.1f}us ({uh / ur:.2f}x)")
    if args.check_fault:
        by_name = {r["name"]: r for r in rows}
        pairs = [(n, n.rsplit("stream_fault_recovered", 1)[0] +
                  "stream_faultfree")
                 for n in by_name if n.endswith("stream_fault_recovered")]
        if not pairs:
            print("check-fault: no stream_fault rows found",
                  file=sys.stderr)
            raise SystemExit(1)
        for rec, free in pairs:
            ur = by_name[rec]["us_per_call"]
            free_row = by_name.get(free)
            uf = free_row["us_per_call"] if free_row else None
            identical = "bit_identical=True" in by_name[rec]["derived"]
            if uf is None or ur > args.fault_ratio * uf or not identical:
                print(f"check-fault FAILED: {rec}={ur:.1f}us vs "
                      f"{free}={uf}us (recovered wall must stay <= "
                      f"{args.fault_ratio}x fault-free) "
                      f"bit_identical={identical}", file=sys.stderr)
                raise SystemExit(1)
            print(f"check-fault ok: {rec} {ur:.1f}us <= "
                  f"{args.fault_ratio}x {free} {uf:.1f}us "
                  f"({ur / uf:.2f}x), outputs bit-identical")
    if args.check_engine_fault:
        by_name = {r["name"]: r for r in rows}
        pairs = [(n, n.rsplit("engine_fault_recovered", 1)[0] +
                  "engine_faultfree")
                 for n in by_name if n.endswith("engine_fault_recovered")]
        if not pairs:
            print("check-engine-fault: no engine_fault rows found",
                  file=sys.stderr)
            raise SystemExit(1)
        for rec, free in pairs:
            ur = by_name[rec]["us_per_call"]
            free_row = by_name.get(free)
            uf = free_row["us_per_call"] if free_row else None
            identical = "bit_identical=True" in by_name[rec]["derived"]
            if uf is None or ur > args.engine_fault_ratio * uf \
                    or not identical:
                print(f"check-engine-fault FAILED: {rec}={ur:.1f}us vs "
                      f"{free}={uf}us (recovered wall must stay <= "
                      f"{args.engine_fault_ratio}x fault-free) "
                      f"bit_identical={identical}", file=sys.stderr)
                raise SystemExit(1)
            print(f"check-engine-fault ok: {rec} {ur:.1f}us <= "
                  f"{args.engine_fault_ratio}x {free} {uf:.1f}us "
                  f"({ur / uf:.2f}x), tokens bit-identical")
    if args.check_paged:
        by_name = {r["name"]: r for r in rows}
        pairs = [(n, n.rsplit("engine_paged", 1)[0] + "engine_dense")
                 for n in by_name if n.endswith("engine_paged")]
        if not pairs:
            print("check-paged: no engine_paged rows found",
                  file=sys.stderr)
            raise SystemExit(1)
        for paged, dense in pairs:
            up = by_name[paged]["us_per_call"]
            dense_row = by_name.get(dense)
            ud = dense_row["us_per_call"] if dense_row else None
            identical = "bit_identical=True" in by_name[paged]["derived"]
            if ud is None or up > args.paged_ratio * ud or not identical:
                print(f"check-paged FAILED: {paged}={up:.1f}us vs "
                      f"{dense}={ud}us (paged wall must stay <= "
                      f"{args.paged_ratio}x dense) "
                      f"bit_identical={identical}", file=sys.stderr)
                raise SystemExit(1)
            print(f"check-paged ok: {paged} {up:.1f}us <= "
                  f"{args.paged_ratio}x {dense} {ud:.1f}us "
                  f"({ud / up:.2f}x speedup), tokens bit-identical")
    if args.check_columns:
        import re

        sweep = sorted(
            ((int(m.group(1)), r["name"], r["us_per_call"])
             for r in rows
             for m in [re.search(r"stream_ncols(\d+)$", r["name"])] if m))
        if len(sweep) < 2:
            print("check-columns: no stream_ncols sweep rows found",
                  file=sys.stderr)
            raise SystemExit(1)
        ok = True
        for (d0, n0, t0), (d1, n1, t1) in zip(sweep, sweep[1:]):
            if t1 > t0 * 1.05:
                print(f"check-columns FAILED: {n1}={t1:.1f}us not below "
                      f"{n0}={t0:.1f}us (per-column work ~1/D must shrink)",
                      file=sys.stderr)
                ok = False
        if not ok:
            raise SystemExit(1)
        first, last = sweep[0], sweep[-1]
        print(f"check-columns ok: ncols{first[0]} {first[2]:.1f}us -> "
              f"ncols{last[0]} {last[2]:.1f}us "
              f"({first[2] / last[2]:.2f}x per-column scaling, monotone)")
    if args.check_fused:
        by_name = {r["name"]: r["us_per_call"] for r in rows}
        pairs = [(n, n.rsplit("pipeline_fused", 1)[0] + "pipeline_staged")
                 for n in by_name if n.endswith("pipeline_fused")]
        if not pairs:
            print("check-fused: no pipeline_fused rows found", file=sys.stderr)
            raise SystemExit(1)
        for fused, staged in pairs:
            uf, us = by_name[fused], by_name.get(staged)
            if us is None or uf > us:
                print(f"check-fused FAILED: {fused}={uf:.1f}us vs "
                      f"{staged}={us}us", file=sys.stderr)
                raise SystemExit(1)
            print(f"check-fused ok: {fused} {uf:.1f}us <= {staged} {us:.1f}us")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

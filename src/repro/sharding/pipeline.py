"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Alternative to FSDPxTP for very deep models / cross-pod meshes: layers are
split into S contiguous stages along a mesh axis; microbatches stream
through stages with `jax.lax.ppermute` handing activations to the next
stage. The classic GPipe schedule executes S + M - 1 ticks (M microbatches),
bubble fraction (S-1)/(S+M-1).

`gpipe_apply` is deliberately generic: it takes ONE layer function and the
per-stage stacked parameters, so any scanned stack from
models/transformer.py (a Segment's repeats split across stages) can run
under it. Backward works through jax.grad (ppermute is differentiable).

This is the optional PP strategy of DESIGN.md §5; the dry-run proof lives in
tests/test_pipeline.py (subprocess with forced host devices) and can be
driven on the production mesh via launch/dryrun_pp.py.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


def gpipe_apply(layer_fn, stage_params, x, *, mesh, stage_axis: str = "pipe",
                microbatches: int = 4, batch_axis: str | None = None):
    """Run a stacked layer function as a pipeline over `stage_axis`.

    layer_fn(params_slice, x) -> x       one layer
    stage_params: pytree stacked as (n_stages, layers_per_stage, ...) and
        sharded dim0 over `stage_axis`.
    x: (batch, ...) global batch (microbatched internally).
    Returns y with x's shape.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_stages = sizes[stage_axis]
    B = x.shape[0] // (sizes[batch_axis] if batch_axis else 1)   # local batch
    assert B % microbatches == 0
    mb = B // microbatches
    ticks = n_stages + microbatches - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def stage_body(params, xs):
        # params: (1, layers_per_stage, ...) local slice; xs: full batch copy
        params = jax.tree.map(lambda p: p[0], params)
        sid = jax.lax.axis_index(stage_axis)

        def run_stage(h):
            def body(c, lp):
                return layer_fn(lp, c), None
            out, _ = jax.lax.scan(body, h, params)
            return out

        xs_mb = xs.reshape(microbatches, mb, *xs.shape[1:])
        buf = jnp.zeros((mb,) + xs.shape[1:], xs.dtype)   # inter-stage wire
        outs = jnp.zeros_like(xs_mb)

        def tick(carry, t):
            buf, outs = carry
            feed = jnp.clip(t, 0, microbatches - 1)
            # stage 0 consumes microbatch t from the input; others consume
            # the activation handed over by the previous stage
            h_in = jax.lax.cond(sid == 0, lambda: xs_mb[feed], lambda: buf)
            live = (t - sid >= 0) & (t - sid < microbatches)
            h_out = jax.lax.cond(live, run_stage, lambda h: h, h_in)
            # last stage records its finished microbatch
            done_idx = jnp.clip(t - (n_stages - 1), 0, microbatches - 1)
            record = live & (sid == n_stages - 1)
            outs = jax.lax.cond(
                record,
                lambda: outs.at[done_idx].set(h_out),
                lambda: outs)
            buf = jax.lax.ppermute(h_out, stage_axis, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # only the last stage holds real outputs; broadcast them to all
        # stages so the result is replicated over the pipe axis
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs.reshape(xs.shape)

    x_spec = P(batch_axis, *([None] * (x.ndim - 1)))
    in_specs = (
        jax.tree.map(lambda _: P(stage_axis), stage_params),
        x_spec,
    )
    out_specs = x_spec
    fn = shard_map(stage_body, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return fn(stage_params, x)


def bubble_fraction(n_stages: int, microbatches: int) -> float:
    return (n_stages - 1) / (n_stages + microbatches - 1)

"""Cycle-accurate simulator: machine semantics, kernel numerics, paper
Table 2/4 cycle agreement, energy-model calibration closure."""
import numpy as np
import pytest

from repro.archsim.energy import default_model, vwr2a_energy_uj
from repro.archsim.isa import LCUInstr, LSUInstr, MXCUInstr, RCInstr, SlotWord
from repro.archsim.machine import RC_SLICE, VWR2A, from_q15, to_q15
from repro.archsim.programs.fft import run_fft, run_rfft
from repro.archsim.programs.fir import run_fir
from repro.core.fir import fir_reference, lowpass_taps


def test_q15_roundtrip():
    for v in (0.0, 0.5, -0.99, 0.123):
        assert abs(from_q15(to_q15(v)) - v) < 2 ** -14


def test_machine_vector_add():
    m = VWR2A()
    a = np.arange(128, dtype=np.int64)
    b = np.arange(128, dtype=np.int64) * 2
    m.spm[0], m.spm[1] = a, b
    prog = [SlotWord(lsu=LSUInstr("LOAD", "A", ("imm", 0))),
            SlotWord(lsu=LSUInstr("LOAD", "B", ("imm", 1)))]
    ins = RCInstr("ADD", ("vwr", "A"), ("vwr", "B"), ("vwr", "C"))
    for k in range(RC_SLICE):
        prog.append(SlotWord(mxcu=MXCUInstr("SETK", k),
                             rcs=(ins, ins, ins, ins)))
    prog.append(SlotWord(lsu=LSUInstr("STORE", "C", ("imm", 2))))
    m.run([prog, []])
    np.testing.assert_array_equal(m.spm[2], a + b)
    assert m.cols[0].counters.cycles == len(prog)


def test_machine_fxmul_q15():
    m = VWR2A()
    m.spm[0, :] = to_q15(0.5)
    m.spm[1, :] = to_q15(-0.25)
    prog = [SlotWord(lsu=LSUInstr("LOAD", "A", ("imm", 0))),
            SlotWord(lsu=LSUInstr("LOAD", "B", ("imm", 1))),
            SlotWord(mxcu=MXCUInstr("SETK", 0),
                     rcs=tuple(RCInstr("FXMUL", ("vwr", "A"), ("vwr", "B"),
                                       ("vwr", "C")) for _ in range(4)))]
    m.run([prog, []])
    got = from_q15(m.cols[0].vwr["C"][0])
    assert abs(got - (-0.125)) < 2 ** -14


def test_machine_lcu_loop():
    m = VWR2A()
    body = SlotWord(lcu=LCUInstr("ADDI", reg=0, val=1),
                    rcs=(RCInstr("ADD", ("reg", 0), ("imm", 1), ("reg", 0)),
                         RCInstr(), RCInstr(), RCInstr()))
    prog = [SlotWord(lcu=LCUInstr("SETI", reg=0, val=0)),
            body,
            SlotWord(lcu=LCUInstr("BLT", reg=0, val=10, target=1)),
            SlotWord(lcu=LCUInstr("EXIT"))]
    m.run([prog, []])
    assert int(m.cols[0].rc_regs[0, 0]) == 10   # loop body ran 10 times


@pytest.mark.parametrize("n", [64, 256, 512])
def test_sim_fft_numerics(n, rng):
    x = (rng.normal(size=n) + 1j * rng.normal(size=n)) * 0.3
    X, _, cycles = run_fft(n, x)
    ref = np.fft.fft(x)
    assert np.abs(X - ref).max() / np.abs(ref).max() < 0.01
    assert cycles > 0


def test_sim_fft_cycles_track_paper():
    """Table 2: same order and N log N scaling (our mapping is denser;
    ratio in [0.5, 1.1] documented in EXPERIMENTS.md)."""
    paper = {512: 7125, 1024: 12405, 2048: 30217}
    rng = np.random.default_rng(0)
    for n, p in paper.items():
        x = (rng.normal(size=n) + 1j * rng.normal(size=n)) * 0.3
        _, _, cycles = run_fft(n, x)
        assert 0.5 < cycles / p < 1.1, (n, cycles, p)


def test_sim_rfft_matches_numpy(rng):
    x = rng.normal(size=512) * 0.3
    X, _, cycles = run_rfft(512, x)
    ref = np.fft.rfft(x)
    assert np.abs(X - ref).max() / np.abs(ref).max() < 0.01
    assert 0.5 < cycles / 3666 < 1.1    # paper Table 2 real-valued 512


def test_sim_fir_numerics_and_cycles(rng):
    taps = lowpass_taps(11)
    x = np.sin(np.arange(512) * 0.1) * 0.5
    y, counters, cycles = run_fir(x, taps)
    ref = fir_reference(x[None, :], taps)[0]
    assert np.abs(y - ref).max() < 1e-3
    assert cycles < 3260                # paper Table 4 (denser mapping)
    assert counters.dma_words == 1024   # 512 in + 512 out


def test_energy_calibration_closes():
    """Calibrated on the 512-pt rFFT, the model must reproduce the paper's
    Table 3 component shares on that workload."""
    m = default_model()
    rng = np.random.default_rng(0)
    _, counters, cycles = run_rfft(512, rng.normal(size=512) * 0.3)
    e = m.energy_pj(counters)
    assert abs(e["memories"] / e["total"] - 0.64) < 0.03
    assert abs(e["datapath"] / e["total"] - 0.32) < 0.03
    total_mw = e["total"] * 1e-12 / (cycles / 80e6) * 1e3
    assert abs(total_mw - 5.41) < 0.1


def test_energy_scales_with_work(rng):
    taps = lowpass_taps(11)
    e = []
    for n in (256, 512, 1024):
        _, counters, _ = run_fir(np.sin(np.arange(n) * 0.1) * 0.5, taps)
        e.append(vwr2a_energy_uj(counters))
    assert e[0] < e[1] < e[2]
    assert abs(e[2] / e[0] - 4.0) < 0.5     # ~linear in N

"""Radix-2 in-place DIF FFT mapped onto the VWR2A simulator (paper §3.4).

Faithful structure: natural-order input, log2(N) in-place butterfly stages
(14-cycle q16.15 complex butterfly with per-stage /2 scaling — the CMSIS-
style fixed-point discipline; the rival FFT accelerator instead uses 18-bit
dynamic scaling, §4.1), output in BIT-REVERSED order, final shuffle-unit
bit-reversal (paper: "the shuffle unit is again used to reorder the data"),
twiddles staged in the SPM.  The stage's butterfly passes are split
round-robin across however many columns the machine instantiates
(``VWR2A(n_columns=...)``; the paper's Fig. 1 machine is the 2-column
default) — passes within a stage are independent, so wall cycles (the max
over columns) shrink with the column count while total activity is
unchanged.

Mapping notes (DESIGN.md §7):
  * the generator unrolls the per-pair MXCU k pattern; real hardware uses
    nested LCU loops — cycle-equivalent (LCU/MXCU issue in parallel slots);
  * pair strides inside one VWR use mux-network offset indexing (the SRF
    "masking values" of paper §3.2); when the pair stride exceeds an RC
    slice, inactive RCs issue NOPs (their cycles are still charged);
  * the final bit-reversal permutation is applied host-side with the exact
    shuffle/LSU cycle charge (2 LOAD + 2 SHUFFLE + 2 STORE per line pair).

Complex layout: word 2j = Re[j], word 2j+1 = Im[j], q16.15.
Output is scaled by 1/N (per-stage halving), like CMSIS-DSP cfft_q15.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.archsim.isa import LSUInstr, RCInstr, SlotWord, sweep_words
from repro.archsim.machine import (RC_SLICE, VWR_WORDS, VWR2A, split_work,
                                   to_q15_arr)

CPLX_PER_LINE = VWR_WORDS // 2      # 64 complex per SPM line
BFLY_CYCLES = 14
TW_LINES = 4                        # twiddle staging lines (one per column)


@functools.lru_cache(maxsize=None)
def _butterfly_instrs(a_src: str, b_src: str, off_b: int):
    """14 per-cycle RC instructions: scaled q15 butterfly at shared k.
    a=(A[k],A[k+1]); b=({b},[k+off_b],+1); w=(C[k],C[k+1]).
    t0=(a+b)/2 -> a slot; t1=((a-b)/2)*w -> b slot."""
    A0, A1 = ("vwr", a_src, 0), ("vwr", a_src, 1)
    B0, B1 = ("vwr", b_src, off_b), ("vwr", b_src, off_b + 1)
    W0, W1 = ("vwr", "C", 0), ("vwr", "C", 1)
    one = ("imm", 1)
    return (
        RCInstr("SUB", A0, B0, ("reg", 0)),
        RCInstr("SRA", ("reg", 0), one, ("reg", 0)),      # dr/2
        RCInstr("SUB", A1, B1, ("reg", 1)),
        RCInstr("SRA", ("reg", 1), one, ("reg", 1)),      # di/2
        RCInstr("ADD", A0, B0, None),
        RCInstr("SRA", ("rc", 0), one, ("vwr", a_src, 0)),            # t0r
        RCInstr("ADD", A1, B1, None),
        RCInstr("SRA", ("rc", 0), one, ("vwr", a_src, 1)),            # t0i
        RCInstr("FXMUL", ("reg", 0), W0, ("vwr", b_src, off_b)),      # dr*wr
        RCInstr("FXMUL", ("reg", 1), W1, None),                       # di*wi
        RCInstr("SUB", ("vwr", b_src, off_b), ("rc", 0),
                ("vwr", b_src, off_b)),                               # t1r
        RCInstr("FXMUL", ("reg", 0), W1, ("reg", 0)),                 # dr*wi
        RCInstr("FXMUL", ("reg", 1), W0, ("reg", 1)),                 # di*wr
        RCInstr("ADD", ("reg", 0), ("reg", 1),
                ("vwr", b_src, off_b + 1)),                           # t1i
    )


@functools.lru_cache(maxsize=2048)
def gen_pass(a_line: int, b_line: int, w_line: int, *,
             inline_stride_c: int = 0):
    """One butterfly pass. Cross-line (inline_stride_c=0): A[j] pairs B[j]
    elementwise. Inline: pairs (c, c+sc) within line a_line.  Memoized —
    callers must treat the returned list as immutable."""
    words = [
        SlotWord(lsu=LSUInstr("LOAD", "A", ("imm", a_line))),
        SlotWord(lsu=LSUInstr("LOAD", "C", ("imm", w_line))),
    ]
    if inline_stride_c == 0:
        words.insert(1, SlotWord(lsu=LSUInstr("LOAD", "B", ("imm", b_line))))
        instrs = _butterfly_instrs("A", "B", 0)
        for k in range(0, RC_SLICE, 2):           # 16 complex per slice
            words += sweep_words(k, instrs)
        words.append(SlotWord(lsu=LSUInstr("STORE", "A", ("imm", a_line))))
        words.append(SlotWord(lsu=LSUInstr("STORE", "B", ("imm", b_line))))
    else:
        sc = inline_stride_c
        instrs = _butterfly_instrs("A", "A", 2 * sc)
        for k in range(0, RC_SLICE, 2):
            # RC r handles complex c = 16r + k/2; active iff c is pair-lower
            active = tuple(((16 * r + k // 2) % (2 * sc)) < sc
                           for r in range(4))
            if any(active):
                words += sweep_words(k, instrs, active)
        words.append(SlotWord(lsu=LSUInstr("STORE", "A", ("imm", a_line))))
    return words


def _write_twiddles(m: VWR2A, line: int, base_c: int, sc: int):
    c = np.arange(CPLX_PER_LINE) + base_c
    j = c % (2 * sc)
    ang = -2 * np.pi * j / (2 * sc)
    tw = np.zeros(VWR_WORDS, np.int64)
    tw[0::2] = to_q15_arr(np.cos(ang))
    tw[1::2] = to_q15_arr(np.sin(ang))
    m.spm[line] = tw


def run_fft(n: int, x: np.ndarray, *, machine: VWR2A | None = None,
            charge_dma: bool = True, n_columns: int | None = None):
    """Simulate an n-point complex FFT (n complex = 2n words <= data SPM).
    Returns (X (complex, scaled back up), counters, wall_cycles)."""
    m = machine or VWR2A(n_columns or 2)
    nc = m.n_columns
    stages = int(np.log2(n))
    assert 1 << stages == n
    n_lines = max(1, (2 * n) // VWR_WORDS)
    assert n_lines + 2 <= 48, "fits the 32 KiB SPM"

    words = np.zeros(max(2 * n, VWR_WORDS), np.int64)
    words[0: 2 * n: 2] = to_q15_arr(x.real)
    words[1: 2 * n: 2] = to_q15_arr(x.imag)
    if charge_dma:
        for ln in range(n_lines):
            m.dma_in(ln, words[ln * VWR_WORDS: (ln + 1) * VWR_WORDS])
    else:
        m.spm[:n_lines] = words[: n_lines * VWR_WORDS].reshape(
            n_lines, VWR_WORDS)

    TW = 60                                # twiddle staging lines
    for s in range(stages):
        sc = n >> (s + 1)                  # pair stride (complex)
        passes = []
        if 2 * sc >= VWR_WORDS:            # cross-line stage
            # pairs of lines (l, l + sc_lines) within blocks of 2*sc_lines
            sc_l = max(1, sc // CPLX_PER_LINE)
            blk = 2 * sc_l
            for b0 in range(0, n_lines, blk):
                for off in range(sc_l):
                    passes.append(("x", b0 + off, b0 + off + sc_l))
        else:
            for ln in range(n_lines):
                passes.append(("i", ln, sc))

        for pi, p in enumerate(passes):
            ci = pi % nc                   # round-robin over columns
            tl = TW + (ci % TW_LINES)
            if p[0] == "x":
                _, al, bl = p
                _write_twiddles(m, tl, al * CPLX_PER_LINE, sc)
                prog = gen_pass(al, bl, tl)
            else:
                _, ln, scc = p
                _write_twiddles(m, tl, ln * CPLX_PER_LINE, scc)
                prog = gen_pass(ln, ln, tl, inline_stride_c=scc)
            progs = [[] for _ in range(nc)]
            progs[ci] = prog
            m.run(progs)

    # final bit-reversal: exact shuffle-unit cycle charge FIRST (the charge
    # loop executes real LSU ops that scribble over lines 0-1), then the
    # host-side permutation writes the semantically-correct result.  Line
    # pairs are reordered by whichever column is free next.
    flat = m.spm[:n_lines].reshape(-1).copy()
    for it in range(max(1, n_lines // 2)):
        col = m.cols[it % nc]
        for w in [SlotWord(lsu=LSUInstr("LOAD", "A", ("imm", 0))),
                  SlotWord(lsu=LSUInstr("LOAD", "B", ("imm", 1))),
                  SlotWord(lsu=LSUInstr("SHUFFLE", "C",
                                        shuffle_op="bit_reverse",
                                        half="lower")),
                  SlotWord(lsu=LSUInstr("STORE", "C", ("imm", 0))),
                  SlotWord(lsu=LSUInstr("SHUFFLE", "C",
                                        shuffle_op="bit_reverse",
                                        half="upper")),
                  SlotWord(lsu=LSUInstr("STORE", "C", ("imm", 1)))]:
            col.step(w)
    cplx = flat[0: 2 * n: 2] + 1j * flat[1: 2 * n: 2]
    idx = np.arange(n)
    rev = np.zeros(n, np.int64)
    for b in range(stages):
        rev |= ((idx >> b) & 1) << (stages - 1 - b)
    cplx = cplx[rev]
    out = flat.copy()
    out[0: 2 * n: 2], out[1: 2 * n: 2] = cplx.real, cplx.imag
    m.spm[:n_lines] = out.reshape(n_lines, VWR_WORDS)

    res = m.dma_out(0, 2 * n) if charge_dma else \
        m.spm[:n_lines].reshape(-1)[: 2 * n].copy()
    X = (res[0::2] + 1j * res[1::2]).astype(np.complex128) / (1 << 15) * n
    cycles = max(c.counters.cycles for c in m.cols)
    return X, m.counters(), cycles


def run_rfft(n: int, x_real: np.ndarray, *, machine: VWR2A | None = None,
             n_columns: int | None = None):
    """Real FFT via the paper's packing (§3.4): N real -> N/2 complex FFT +
    untangle. Untangle numerics host-side; cycles charged at 12 RC-ops per
    output element spread over all columns x 4 RCs (DESIGN.md §7)."""
    m = machine or VWR2A(n_columns or 2)
    nc = m.n_columns
    z = x_real[0::2] + 1j * x_real[1::2]
    Z, _, _ = run_fft(n // 2, z, machine=m)
    Z = Z / (n // 2)                       # undo decode upscale
    half = n // 2
    k = np.arange(half)
    Zc = np.conj(Z[(-k) % half])
    w = np.exp(-2j * np.pi * k / n)
    X = 0.5 * (Z + Zc) - 0.5j * w * (Z - Zc)
    nyq = np.array([Z[0].real - Z[0].imag])
    X_full = np.concatenate([X, nyq]) * half
    spm_lines = split_work(2 * max(1, half // CPLX_PER_LINE), nc)
    for col, elems, lines in zip(m.cols, split_work(half, nc), spm_lines):
        col.counters.cycles += -(-12 * elems // 4)
        col.counters.rc_ops += 12 * elems
        col.counters.rc_mults += 4 * elems
        col.counters.vwr_reads += 6 * elems
        col.counters.vwr_writes += 2 * elems
        col.counters.spm_line_reads += lines
        col.counters.spm_line_writes += lines
    cycles = max(c.counters.cycles for c in m.cols)
    return X_full, m.counters(), cycles

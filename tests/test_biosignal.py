"""MBioTracker application: delineation properties, feature sanity, SVM
end-to-end accuracy on synthetic respiration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.biosignal import (delineate, extract_features, make_app,
                                  svm_fit_least_squares, svm_predict,
                                  synthetic_respiration)
from repro.core.fir import fir_direct, lowpass_taps


def test_delineate_finds_sine_peaks():
    t = np.arange(512) / 64.0
    x = jnp.asarray(np.sin(2 * np.pi * 0.5 * t).astype(np.float32))[None]
    is_max, is_min = delineate(x)
    # 0.5 Hz over 8 s => ~4 maxima and ~4 minima
    assert 3 <= int(is_max.sum()) <= 5
    assert 3 <= int(is_min.sum()) <= 5
    # maxima are where the signal is high
    assert float(x[is_max].min()) > 0.8


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_delineate_max_min_disjoint(seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(2, 256)).astype(np.float32))
    is_max, is_min = delineate(x)
    assert not bool((is_max & is_min).any())
    assert not bool(is_max[..., 0].any()) and not bool(is_max[..., -1].any())


def test_features_finite_and_fixed_width():
    sig, _ = synthetic_respiration(8, 1024)
    filtered = fir_direct(sig, jnp.asarray(lowpass_taps(11)))
    f = extract_features(filtered)
    assert f.shape == (8, 12)
    assert bool(jnp.isfinite(f).all())


@pytest.mark.slow
def test_svm_learns_rate_classes():
    sig, labels = synthetic_respiration(96, 2048, seed=5)
    filtered = fir_direct(sig, jnp.asarray(lowpass_taps(11)))
    feats = extract_features(filtered)
    w, b = svm_fit_least_squares(feats[:64], labels[:64])
    _, pred = svm_predict(feats[64:], w, b)
    acc = float((pred == labels[64:]).mean())
    assert acc >= 0.7, acc


def test_full_app_jit():
    app = make_app()
    sig, _ = synthetic_respiration(4, 2048)
    out = jax.jit(app.__call__)(sig)
    assert out["class"].shape == (4,)
    assert bool(jnp.isfinite(out["margin"]).all())

"""Raw-signal single-residency streaming: the in-kernel-framing pipeline
must match the host-framed fused kernel to f32 tolerance on every
(window, hop) combination (including non-dividing tails), keep the
one-`pallas_call`-per-batch contract, honour the `outputs` selection, and
the streaming runtime's degenerate paths must return the same keys/dtypes
as the non-empty path."""
import numpy as np
import pytest

from repro.core.biosignal import make_app, synthetic_respiration
from repro.kernels.pipeline.kernel import (min_stream_block_frames,
                                           resolve_stream_block_frames)
from repro.kernels.pipeline.ops import (app_pipeline, app_pipeline_stream,
                                        canonical_outputs)
from repro.serve.stream import (BiosignalStream, StreamConfig, frame_count,
                                frame_signal)


def _assert_matches(out, ref, tol=1e-4, keys=("filtered", "features",
                                              "margin")):
    for k in keys:
        a = np.asarray(ref[k], np.float64)
        b = np.asarray(out[k], np.float64)
        assert a.shape == b.shape, (k, a.shape, b.shape)
        if a.size == 0:
            continue
        scale = max(1.0, float(np.abs(a).max()))
        assert float(np.abs(a - b).max()) / scale < tol, k
    np.testing.assert_array_equal(np.asarray(out["class"]),
                                  np.asarray(ref["class"]))


@pytest.mark.parametrize("window,hop,n_samples", [
    (512, 128, 5000),        # deep overlap
    (512, 512, 3000),        # hop == window (no overlap, no tail specs)
    (1024, 320, 7001),       # hop does not divide window
    (2048, 512, 2048 * 4 + 777),   # the paper-default shape, ragged tail
    (2048, 512, 2048),       # exactly one frame
])
def test_stream_matches_framed(window, hop, n_samples):
    app = make_app()
    sig, _ = synthetic_respiration(1, n_samples, seed=window + hop)
    raw = sig[0]
    out = app_pipeline_stream(app, raw, window=window, hop=hop)
    ref = app_pipeline(app, frame_signal(raw, window, hop))
    assert out["class"].shape == (frame_count(n_samples, window, hop),)
    _assert_matches(out, ref)


@pytest.mark.parametrize("block_frames", [None, 4, 8, 32])
def test_stream_block_frames_tile_without_seams(block_frames):
    """Any frame-block choice (dividing the frame count or not) must give
    the same answer — padded garbage frames are trimmed."""
    app = make_app()
    sig, _ = synthetic_respiration(1, 512 * 22 + 13, seed=7)
    raw = sig[0]
    out = app_pipeline_stream(app, raw, window=512, hop=256,
                              block_frames=block_frames)
    ref = app_pipeline(app, frame_signal(raw, 512, 256))
    _assert_matches(out, ref)


def test_stream_outputs_masking():
    """`outputs` returns exactly the requested keys; values match the
    full run; the filtered HBM write is genuinely elided."""
    app = make_app()
    sig, _ = synthetic_respiration(1, 6000, seed=9)
    raw = sig[0]
    full = app_pipeline_stream(app, raw, window=512, hop=128)
    sub = app_pipeline_stream(app, raw, window=512, hop=128,
                              outputs=("features", "class"))
    assert sorted(sub) == ["class", "features"]
    np.testing.assert_array_equal(np.asarray(sub["features"]),
                                  np.asarray(full["features"]))
    np.testing.assert_array_equal(np.asarray(sub["class"]),
                                  np.asarray(full["class"]))
    # framed path shares the selection machinery
    framed = app_pipeline(app, frame_signal(raw, 512, 128),
                          outputs=("margin",))
    assert sorted(framed) == ["margin"]
    np.testing.assert_allclose(np.asarray(framed["margin"]),
                               np.asarray(full["margin"]), atol=1e-4)


def test_canonical_outputs_validation():
    assert canonical_outputs(None) == ("filtered", "features", "margin",
                                       "class")
    assert canonical_outputs(("class", "filtered")) == ("filtered", "class")
    with pytest.raises(AssertionError):
        canonical_outputs(("bogus",))
    with pytest.raises(AssertionError):
        canonical_outputs(())


def test_stream_single_pallas_call_per_batch(monkeypatch):
    """The raw-chunk runtime keeps the one-pallas_call-per-batch contract:
    a signal spanning 3 batches traces exactly one call (jit reuses it)."""
    import repro.kernels.pipeline.kernel as K

    calls = []
    real = K.pl.pallas_call

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(K.pl, "pallas_call", counting)
    app = make_app()
    # unique shape so the jit cache cannot satisfy the call without tracing
    sig, _ = synthetic_respiration(1, 512 * 11 + 31, seed=23)
    cfg = StreamConfig(window=512, hop=256, batch_windows=8)
    out = BiosignalStream(app, cfg).process(sig[0])
    n = frame_count(512 * 11 + 31, 512, 256)
    assert out["class"].shape == (n,)
    assert len(calls) == 1, f"expected 1 traced pallas_call, got {len(calls)}"


def test_stream_runtime_kernel_matches_host_framing():
    """framing="kernel" (raw chunks) == framing="host" (gather fallback),
    with the frame count deliberately not a multiple of batch_windows."""
    app = make_app()
    sig, _ = synthetic_respiration(1, 1024 * 5 + 333, seed=19)
    raw = sig[0]
    outs = []
    for framing in ("kernel", "host"):
        cfg = StreamConfig(window=1024, hop=320, batch_windows=4,
                           framing=framing)
        outs.append(BiosignalStream(app, cfg).process(raw))
    _assert_matches(outs[0], outs[1])


@pytest.mark.parametrize("outputs", [None, ("features", "class"),
                                     ("margin",)])
@pytest.mark.parametrize("window,hop,n_samples", [
    (2048, 512, 100),       # n_samples < window -> zero frames
    (512, 512, 511),        # zero frames at hop == window
    (512, 512, 1536),       # hop == window, exact tiling
    (512, 256, 1400),       # tail-batch padding
])
def test_stream_degenerate_and_tail_shapes(window, hop, n_samples, outputs):
    """Property-style sweep: for every (window, hop, outputs) combo the
    runtime returns the same key set, dtypes and trailing shapes whether
    or not any frame (or any full batch) exists."""
    app = make_app()
    sig, _ = synthetic_respiration(1, max(n_samples, 1), seed=3)
    raw = sig[0][:n_samples]
    cfg = StreamConfig(window=window, hop=hop, batch_windows=4,
                       outputs=canonical_outputs(outputs))
    out = BiosignalStream(app, cfg).process(raw)
    n = frame_count(n_samples, window, hop)
    assert sorted(out) == sorted(canonical_outputs(outputs))
    expect_dtype = {"filtered": np.float32, "features": np.float32,
                    "margin": np.float32, "class": np.int32}
    expect_trail = {"filtered": (window,), "features": (12,),
                    "margin": (app.svm_w.shape[1],), "class": ()}
    for k, v in out.items():
        assert v.shape == (n,) + expect_trail[k], (k, v.shape)
        assert v.dtype == expect_dtype[k], (k, v.dtype)
    if n:
        ref = app_pipeline(app, frame_signal(raw, window, hop))
        for k in out:
            if k == "class":
                np.testing.assert_array_equal(np.asarray(out[k]),
                                              np.asarray(ref[k]))
            else:
                np.testing.assert_allclose(np.asarray(out[k]),
                                           np.asarray(ref[k]), atol=1e-3)


def test_stream_block_frame_resolution():
    """The frame-block never drops below the tail-coverage floor, no
    matter what the caller pins."""
    assert min_stream_block_frames(2048, 512) == 3
    assert min_stream_block_frames(512, 512) == 1
    assert min_stream_block_frames(1024, 320) == 3
    assert resolve_stream_block_frames(1, 2048, 512, None) >= 3
    assert resolve_stream_block_frames(100, 2048, 512, 1) >= 3
    assert resolve_stream_block_frames(100, 512, 512, 1) == 1


def test_stream_autotune_key_and_persistence(tmp_path):
    """Autotuned stream dispatch caches under the (window, hop, outputs)
    key shape and the winners survive a JSON round trip."""
    from repro.core import autotune

    autotune.clear_cache()
    app = make_app()
    sig, _ = synthetic_respiration(1, 512 * 9, seed=5)
    raw = sig[0]
    out = app_pipeline_stream(app, raw, window=512, hop=128, autotune=True,
                              outputs=("features", "class"))
    ref = app_pipeline(app, frame_signal(raw, 512, 128))
    np.testing.assert_allclose(np.asarray(out["features"]),
                               np.asarray(ref["features"]), atol=1e-3)
    cache = autotune.cache_snapshot()
    (key, rb), = cache.items()
    assert key[0] == "biosignal_pipeline_stream"
    assert key[2:5] == (512, 128, ("features", "class"))
    assert rb in autotune.candidate_stream_block_frames(key[1], 512, 128)
    # second call hits the cache; JSON round trip preserves the winners
    app_pipeline_stream(app, raw, window=512, hop=128, autotune=True,
                        outputs=("features", "class"))
    assert autotune.cache_snapshot() == cache
    path = str(tmp_path / "autotune.json")
    assert autotune.save_cache(path) == 1
    autotune.clear_cache()
    assert autotune.load_cache(path) == 1
    assert autotune.cache_snapshot() == cache
    assert autotune.load_cache(str(tmp_path / "missing.json")) == 0

"""Three-term roofline analysis over the dry-run records.

Per (arch x shape x mesh) cell, from the trip-count-aware HLO cost model
(analysis/hlo_cost.py — XLA's builtin cost_analysis counts scan bodies once
and is kept only as a cross-check):

    compute_s    = per-device MXU FLOPs / 197e12         (v5e bf16 peak)
    memory_s     = per-device HBM bytes  / 819e9
    collective_s = per-device collective bytes (ring-factored) / link BW
                   ICI 50 GB/s per link (+ DCN 6.25 GB/s/chip for the
                   pod-crossing share on the multi-pod mesh)

plus MODEL_FLOPS (6*N_active*D train / 2*N_active*D inference), the
useful-compute ratio MODEL_FLOPS/HLO_FLOPs, the dominant term, and an MFU
upper bound = model FLOPs / (peak * max-term). Emits the EXPERIMENTS.md
tables.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

PEAK_FLOPS = 197e12          # bf16 MXU per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 2 * 50e9            # ring collectives drive both directions of the
                             # 50 GB/s/link torus dimension -> 100 GB/s eff.
DCN_BW = 6.25e9              # bytes/s per chip across pods (assumed; 25 GB/s
                             # per 4-chip host)

_RING = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0,
         "collective-broadcast": 1.0}


def active_param_count(arch: str) -> int:
    """Non-embedding active params (MoE experts scaled by top_k/E)."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.models.layers import P

    cfg = get_config(arch)
    model = build_model(cfg)
    total = 0

    def walk(node, in_moe: bool, path: str):
        nonlocal total
        if isinstance(node, P):
            n = int(np.prod(node.shape))
            leaf = path.rsplit("/", 1)[-1]
            if leaf in ("embedding",) or path.endswith("head/w"):
                return
            if in_moe and leaf in ("w_gate", "w_in", "w_out"):
                n = int(n * cfg.moe.top_k / cfg.moe.num_experts)
            total += n
            return
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, in_moe or k == "moe", f"{path}/{k}")

    # exclude the shared-expert subtree from scaling (always active)
    def walk2(node, path=""):
        pass

    walk(model.schema, False, "")
    return total


def cell_roofline(rec: dict, n_active: int) -> dict:
    hc = rec["hlo_cost"]
    devices = rec["devices"]
    compute_s = hc["flops"] / PEAK_FLOPS
    memory_s = hc["bytes"] / HBM_BW
    ici_s = 0.0
    dcn_s = 0.0
    for op, v in hc["collectives"].items():
        g = max(2, v.get("group_size", 2))
        factor = _RING.get(op, 1.0) * (g - 1) / g
        ici_b = (v["bytes"] - v.get("dcn_bytes", 0.0)) * factor
        dcn_b = v.get("dcn_bytes", 0.0) * factor
        ici_s += ici_b / ICI_BW
        dcn_s += dcn_b / DCN_BW
    collective_s = ici_s + dcn_s

    # model flops per device
    kind = rec["kind"]
    if kind == "train":
        D = rec_tokens(rec)
        model_flops = 6.0 * n_active * D / devices
    elif kind == "prefill":
        model_flops = 2.0 * n_active * rec_tokens(rec) / devices
    else:
        model_flops = 2.0 * n_active * rec_batch(rec) / devices

    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", collective_s), key=lambda t: t[1])
    bound_s = max(compute_s, memory_s, collective_s)
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dcn_s": dcn_s,
        "dominant": dom[0], "bound_s": bound_s,
        "model_flops": model_flops,
        "useful_ratio": model_flops / hc["flops"] if hc["flops"] else 0.0,
        "mfu_bound": model_flops / (PEAK_FLOPS * bound_s) if bound_s else 0.0,
        "compute_fraction": compute_s / bound_s if bound_s else 0.0,
    }


def rec_tokens(rec: dict) -> int:
    from repro.configs import SHAPES
    s = SHAPES[rec["shape"]]
    return s.global_batch * s.seq_len


def rec_batch(rec: dict) -> int:
    from repro.configs import SHAPES
    return SHAPES[rec["shape"]].global_batch


_NOTES = {
    ("train", "compute"): "compute-bound: cut remat recompute / padding waste"
                          " to raise useful-FLOPs share",
    ("train", "memory"): "HBM-bound: fuse optimizer update, bf16 activations,"
                         " larger microbatch per device",
    ("train", "collective"): "collective-bound: reduce-scatter grads in bf16,"
                             " overlap FSDP gathers with layer compute",
    ("prefill", "compute"): "compute-bound: good — push attention chunking to"
                            " MXU-aligned tiles",
    ("prefill", "memory"): "HBM-bound: bf16 activations, wider q-chunks to "
                           "raise attention arithmetic intensity",
    ("prefill", "collective"): "collective-bound: sequence-parallel attention"
                               " instead of TP all-reduce per layer",
    ("decode", "memory"): "HBM-bound (weights+KV stream): expected at batch "
                          "<< arithmetic-intensity knee; grow batch, quantize"
                          " KV, multi-token speculation",
    ("decode", "compute"): "compute-bound decode: batch large enough — check "
                           "padding waste",
    ("decode", "collective"): "collective-bound: TP all-reduce per token "
                              "dominates — fuse collectives, widen DP",
}


def build_tables(dryrun_dir: str = "results/dryrun"):
    recs = []
    for f in sorted(Path(dryrun_dir).glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") == "ok" and "arch" in r and "hlo_cost" in r:
            recs.append(r)
    cache: dict = {}
    rows = []
    for r in recs:
        arch = r["arch"]
        if arch not in cache:
            cache[arch] = active_param_count(arch)
        rl = cell_roofline(r, cache[arch])
        note = _NOTES.get((r["kind"], rl["dominant"]), "")
        rows.append({**{k: r[k] for k in ("arch", "shape", "mesh", "kind",
                                          "devices", "n_params")},
                     "n_active": cache[arch], **rl, "note": note,
                     "compile_s": r.get("compile_s"),
                     "hlo_flops": r["hlo_cost"]["flops"],
                     "hlo_bytes": r["hlo_cost"]["bytes"],
                     "coll_bytes": r["hlo_cost"]["collective_bytes"],
                     "dcn_bytes": r["hlo_cost"]["collective_dcn_bytes"],
                     "memory": r.get("memory", {})})
    return rows


def markdown_table(rows, mesh="single") -> str:
    out = ["| arch | shape | dom | compute_s | memory_s | coll_s | "
           "MFU-bound | useful | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['dominant'][:4]} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['mfu_bound'] * 100:.1f}% "
            f"| {r['useful_ratio']:.2f} | {r['note'][:58]} |")
    return "\n".join(out)


if __name__ == "__main__":
    rows = build_tables()
    Path("results/roofline.json").write_text(json.dumps(rows, indent=1))
    print(markdown_table(rows, "single"))
    print()
    print(markdown_table(rows, "multi"))

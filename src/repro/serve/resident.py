"""Device-resident streaming loop: the steady state runs on-device.

The per-batch runtime (`serve.stream.BiosignalStream`) is host-driven:
every `batch_windows`-frame dispatch is a Python-loop round trip — slice a
chunk, dispatch a `pallas_call`, block for the retire, update telemetry.
ROADMAP named that host-dispatch gap the biggest remaining latency lever
(it is why depth-2 pipelining measures within noise: the gap being hidden
is host overhead, not device work). This module inverts the control flow,
the STRELA direction (streaming *elastic* execution: data flows, control
stays out of the way) and the faithful analogue of VWR2A keeping its
control processor off the hot loop:

* the raw signal stays DEVICE-RESIDENT and a `lax.scan` iterates ring
  sweeps inside ONE jitted computation (`_resident_loop`): each sweep
  slices `ring_depth` dispatch-sized chunks out of the donated signal
  buffer and runs them through the fused ring kernel
  (`kernels/pipeline/kernel.py:pipeline_ring_pallas` — one `pallas_call`
  whose (slot, block) grid reuses the in-kernel framing index_maps), so
  dispatch, frame-block advance, and retire all happen on-device;
* telemetry counters (windows retired, the per-column EWMA inputs) are
  accumulated in device arrays carried through the scan and DRAINED to
  `serve.stream.StreamTelemetry` at a low, configurable frequency
  (`ResidentConfig.drain_interval` sweeps per drain) — one small host
  transfer per drain instead of one blocking readback per batch;
* the signal and counter buffers are DONATED to the loop
  (`jax.jit(donate_argnums=...)`), so XLA reuses the ring memory for
  outputs across sweeps instead of allocating per batch.

Bit-equivalence: for every (n_frames, ring_depth) — dividing or not —
`ResidentStream.process` returns exactly what the host-driven
`BiosignalStream.process` returns, to the last bit, and the drained
counters match the host path's per-batch retire accounting exactly
(`tests/test_resident.py` property-tests both, including the zero-frame
and tail-pad cases). The host-driven path stays as the reference.

See `docs/ARCHITECTURE.md` (serving-runtime control loop) for the
host-driven vs device-resident dataflow side by side, and
`docs/BENCHMARKS.md` for the `run.py --check-resident` gate that pins
resident >= per-batch dispatch throughput.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.biosignal import BiosignalApp, make_app
from repro.kernels.pipeline.graph import (canonical_graph_outputs,
                                          get_graph_factory,
                                          graph_empty_outputs,
                                          graph_ring_pallas,
                                          ring_chunk_samples)
from repro.kernels.pipeline.ops import (OUTPUTS, canonical_outputs,
                                        default_app, stream_frame_count)
from repro.serve.stream import StreamConfig, StreamTelemetry

DEFAULT_RING_DEPTH = 4


@dataclasses.dataclass(frozen=True)
class ResidentConfig:
    """Knobs of the device-resident loop (the per-stream window/hop/batch
    shape stays in `serve.stream.StreamConfig`).

    ``ring_depth`` — dispatch-sized chunks (ring slots) per on-device
    sweep; one sweep = one `pipeline_ring_pallas` call covering
    `ring_depth * batch_windows` frames. `None` picks
    `DEFAULT_RING_DEPTH`, or a measured winner when ``autotune`` is set
    (`core.autotune.tuned_ring_depth`; the cache key carries the
    (window, hop, batch_windows, outputs, drain_interval) shape).
    ``drain_interval`` — ring sweeps between telemetry counter drains:
    the retire counters accumulate on-device and reach
    `StreamTelemetry.record_retire` only every `drain_interval` sweeps
    (plus once at end-of-signal), so the host touches the device
    `drain_interval * ring_depth` batches less often than the per-batch
    path. ``autotune`` — measure ring-depth candidates instead of the
    static default.
    """
    ring_depth: int | None = None
    drain_interval: int = 1
    autotune: bool = False


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit, donate_argnums=(0, 1),
    static_argnames=("graph", "window", "hop", "batch_windows",
                     "ring_depth", "n_sweeps", "interpret", "block_frames",
                     "outputs"))
def _resident_loop(sig, counter, operands, n_frames, *, graph, window: int,
                   hop: int, batch_windows: int, ring_depth: int,
                   n_sweeps: int, interpret: bool,
                   block_frames: int | None, outputs: tuple):
    """ONE compiled computation for the whole steady state: `lax.scan`
    over ring sweeps of the donated signal buffer.

    Each sweep stacks its `ring_depth` chunk views (hop-aligned dynamic
    slices of the resident signal — no host gather, no duplicated bytes
    beyond the `window-hop` slot halos) and dispatches the fused ring
    kernel on them; the retired-window counter advances in the scan carry
    (tail-pad aware: pad frames past `n_frames` never count). Returns the
    per-frame output dict, the final counter, and the per-sweep counter
    snapshots the host drains at `drain_interval` granularity.

    ``graph`` is the STATIC `kernels.pipeline.graph.StageGraph` to run
    (the loop is graph-generic: biosignal and ASR resident streams share
    this one jit) and ``operands`` its staged table arrays. ``sig`` and
    ``counter`` are donated: the loop owns the ring memory.
    """
    span = ring_chunk_samples(window, hop, batch_windows)
    stride = batch_windows * hop
    sweep_frames = ring_depth * batch_windows

    def sweep(carry, s):
        base = s * (ring_depth * stride)
        ring = jnp.stack([
            lax.dynamic_slice(sig, (base + r * stride,), (span,))
            for r in range(ring_depth)])
        out = graph_ring_pallas(ring, operands, graph=graph, window=window,
                                hop=hop, interpret=interpret,
                                block_frames=block_frames,
                                outputs=outputs)
        # frames retired this sweep = valid frames newly covered (the tail
        # sweep's pad frames are excluded by the same min() the host
        # path's per-batch `valid` uses)
        done = jnp.minimum((s + 1) * sweep_frames, n_frames)
        retired = done - jnp.minimum(s * sweep_frames, n_frames)
        counter2 = carry + retired.astype(carry.dtype)
        return counter2, (out, counter2)

    counter, (outs, snaps) = lax.scan(sweep, counter, jnp.arange(n_sweeps))
    # (n_sweeps, ring_depth, bw, ...) -> flat frame-major rows
    flat = {k: v.reshape((n_sweeps * sweep_frames,) + v.shape[3:])
            for k, v in outs.items()}
    return flat, counter, snaps


class ResidentStream:
    """Drives a signal through the fused pipeline with the steady-state
    loop ON-DEVICE — the resident sibling of `serve.stream.BiosignalStream`
    (same `StreamConfig` shape contract, same output dict, bit-identical
    results; construct it directly or via
    `BiosignalStream.process_resident`).

    >>> rs = ResidentStream(make_app(), StreamConfig(hop=256),
    ...                     ResidentConfig(ring_depth=8))
    >>> out = rs.process(signal)       # == BiosignalStream.process(signal)

    Constraints: the resident loop is a raw-chunk path
    (`cfg.framing == "kernel"`) on ONE column (`cfg.n_columns == 1` —
    multi-column serving pins independent resident streams to distinct
    columns via `serve.engine.ColumnScheduler`, exactly like the
    per-batch path). ``telemetry``/``stream_id``/``column`` wire the
    drained counters into `StreamTelemetry.record_retire`: every drain
    reports the windows retired since the previous drain, so the
    scheduler's EWMA inputs are the drained deltas instead of per-batch
    host timestamps — `ColumnScheduler`'s retire-count rebalance trigger
    fires off these drains. ``last_drains`` keeps the most recent
    process() call's cumulative drained counts for introspection/tests.
    """

    def __init__(self, app: BiosignalApp | None = None,
                 cfg: StreamConfig | None = None,
                 rcfg: ResidentConfig | None = None, *, device=None,
                 telemetry: StreamTelemetry | None = None,
                 stream_id=None, column: int = 0,
                 injector=None, retry=None):
        cfg = cfg or StreamConfig()
        if cfg.graph == "biosignal":
            self.app = app or make_app()
            cfg = dataclasses.replace(
                cfg, outputs=canonical_outputs(cfg.outputs))
        else:
            self.app = app if app is not None else default_app(cfg.graph)
            graph, _ = get_graph_factory(cfg.graph)(self.app)
            sel = None if cfg.outputs is OUTPUTS else cfg.outputs
            cfg = dataclasses.replace(
                cfg, outputs=canonical_graph_outputs(graph, sel))
        # the loop is graph-generic: resolve (graph, operands) once here
        self._graph, self._operands = \
            get_graph_factory(cfg.graph)(self.app)
        self.cfg = cfg
        self.rcfg = rcfg or ResidentConfig()
        assert self.cfg.framing == "kernel", \
            "the resident loop is a raw-chunk (framing='kernel') path"
        assert self.cfg.n_columns == 1 and self.cfg.column_weights is None, \
            "resident streams are column-pinned; use ColumnScheduler for D"
        assert self.cfg.window >= self.app.fft_size
        assert 0 < self.cfg.hop <= self.cfg.window
        assert self.cfg.batch_windows > 0
        assert self.rcfg.ring_depth is None or self.rcfg.ring_depth >= 1
        assert self.rcfg.drain_interval >= 1
        self.device = device
        self.telemetry = telemetry
        self.stream_id = stream_id if stream_id is not None else id(self)
        self.column = column
        self.last_drains: list[int] = []
        # fault hooks, mirroring `serve.stream.BiosignalStream`: the
        # injector fires once per loop dispatch (`on_dispatch`, transient
        # faults retried via the supervisor's capped backoff) and once
        # per counter drain (`on_drain` — a ColumnDeadError there is the
        # "death mid-resident-sweep" chaos scenario: earlier drains
        # already fed the telemetry, the outputs are lost with the
        # column, and the serving layer requeues the whole share)
        self.injector = injector
        self._retry = retry
        if injector is not None and retry is None:
            from repro.runtime.fault import (Supervisor,
                                             TransientDispatchError)

            self._retry = Supervisor(max_retries=3,
                                     retry_on=(TransientDispatchError,))
        if telemetry is not None:
            telemetry.attach(self.stream_id, column)

    @property
    def chunk_samples(self) -> int:
        """Raw samples per ring slot (one dispatch's span — identical to
        `BiosignalStream.chunk_samples` for the same config)."""
        return ring_chunk_samples(self.cfg.window, self.cfg.hop,
                                  self.cfg.batch_windows)

    def _ring_depth(self, n_batches: int) -> int:
        if self.rcfg.ring_depth is not None:
            return self.rcfg.ring_depth
        if self.rcfg.autotune and n_batches > 1:
            from repro.core.autotune import tuned_ring_depth

            cfg = self.cfg
            # the biosignal graph keeps its historical cache name; other
            # graphs tune under their own key so winners never leak
            name = "resident_ring" if cfg.graph == "biosignal" \
                else f"{cfg.graph}_resident_ring"
            return tuned_ring_depth(
                name, cfg.window, cfg.hop, cfg.batch_windows,
                cfg.outputs, "float32", self.rcfg.drain_interval, n_batches,
                lambda rd: self._run(
                    jnp.zeros((self.chunk_samples +
                               (n_batches * cfg.batch_windows - 1) * cfg.hop,
                               ), jnp.float32), rd))
        return DEFAULT_RING_DEPTH

    def _run(self, sig, ring_depth: int):
        """Pad + dispatch the compiled resident loop; returns
        (outputs, final counter, per-sweep counter snapshots)."""
        cfg = self.cfg
        n = stream_frame_count(sig.shape[0], cfg.window, cfg.hop)
        stride = cfg.batch_windows * cfg.hop
        n_batches = -(-n // cfg.batch_windows)
        n_sweeps = -(-n_batches // ring_depth)
        total = (n_sweeps * ring_depth - 1) * stride + self.chunk_samples
        sig = sig[:min(sig.shape[0], total)]
        if total > sig.shape[0]:
            sig = jnp.concatenate(
                [sig, jnp.zeros((total - sig.shape[0],), sig.dtype)])
        counter = jnp.zeros((), jnp.int32)
        if self.device is not None:
            sig = jax.device_put(sig, self.device)
            counter = jax.device_put(counter, self.device)

        def dispatch():
            # the injector fires BEFORE the loop consumes its donated
            # buffers, so a retried transient attempt reuses them intact
            if self.injector is not None:
                self.injector.on_dispatch(self.column)
            with warnings.catch_warnings():
                # CPU (and interpret-mode) backends cannot honour buffer
                # donation; the donation is FOR the accelerator target,
                # and the fallback is correct — silence only that advisory
                warnings.filterwarnings(
                    "ignore",
                    message="Some donated buffers were not usable")
                return _resident_loop(
                    sig, counter, self._operands,
                    jnp.asarray(n, jnp.int32), graph=self._graph,
                    window=cfg.window, hop=cfg.hop,
                    batch_windows=cfg.batch_windows,
                    ring_depth=ring_depth, n_sweeps=n_sweeps,
                    interpret=_interpret(), block_frames=cfg.block_rows,
                    outputs=cfg.outputs)
        if self._retry is not None:
            return self._retry.call(dispatch)
        return dispatch()

    def _drain(self, snaps) -> None:
        """Retire the device counters into the telemetry: cumulative
        per-sweep snapshots -> one `record_retire` per drain point (every
        `drain_interval` sweeps, plus the final partial window). The
        drained DELTAS sum to exactly the host path's per-batch retire
        total — the accounting property `tests/test_resident.py` pins."""
        snaps = np.asarray(snaps)
        k = self.rcfg.drain_interval
        points = list(range(k - 1, snaps.shape[0], k))
        # the end-of-signal drain always happens, even when the loop ran
        # fewer sweeps than one drain interval
        if not points or points[-1] != snaps.shape[0] - 1:
            points.append(snaps.shape[0] - 1)
        self.last_drains = [int(snaps[p]) for p in points]
        prev = 0
        for cum in self.last_drains:
            # the injector's per-drain hook fires mid-drain: a
            # ColumnDeadError here leaves the EARLIER drains already
            # recorded (heartbeats kept arriving until the death) but
            # aborts before this one — the chaos tests' death
            # mid-resident-sweep scenario
            if self.injector is not None:
                self.injector.on_drain(self.column)
            if self.telemetry is not None:
                self.telemetry.record_retire(self.stream_id, cum - prev)
            prev = cum

    def process(self, signal) -> dict:
        """All framed outputs for `signal`, bit-identical to the
        host-driven `BiosignalStream.process` — but the whole steady state
        is ONE device dispatch (scan over ring sweeps) instead of one
        round trip per `batch_windows` frames."""
        cfg = self.cfg
        sig = jnp.asarray(signal)
        assert sig.ndim == 1, sig.shape
        n = stream_frame_count(sig.shape[0], cfg.window, cfg.hop)
        if n == 0:
            # same degenerate contract as the host path: no frames, no
            # retires, the kernel's canonical empty dict
            self.last_drains = []
            return graph_empty_outputs(self._graph, cfg.window, sig.dtype,
                                       cfg.outputs)
        n_batches = -(-n // cfg.batch_windows)
        outs, _, snaps = self._run(sig, self._ring_depth(n_batches))
        self._drain(snaps)
        return {k: v[:n] for k, v in outs.items()}

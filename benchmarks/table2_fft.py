"""Table 2 — FFT kernel performance for various sizes (paper §5.1.1).

Reproduces the VWR2A column of Table 2 from the cycle-accurate simulator;
CPU and FFT-accelerator columns are the paper's measurements (they are
physical-SoC numbers we cannot re-measure). Derived: sim/paper cycle ratio
and the speed-up over the paper's CPU baseline.
"""
from __future__ import annotations

import numpy as np

PAPER = {
    # n: (cpu_cycles, accel_cycles, vwr2a_cycles)
    "complex": {512: (47926, 7099, 7125), 1024: (84753, 13629, 12405),
                2048: (219667, 31299, 30217)},
    "real": {512: (24927, 3523, 3666), 1024: (62326, 8007, 7133),
             2048: (113489, 16490, 14427)},
}
F_HZ = 80e6


def run():
    from repro.archsim.programs.fft import run_fft, run_rfft

    rows = []
    rng = np.random.default_rng(0)
    for kind, sizes in PAPER.items():
        for n, (cpu, accel, vwr2a) in sizes.items():
            if kind == "complex":
                x = (rng.normal(size=n) + 1j * rng.normal(size=n)) * 0.3
                X, counters, cycles = run_fft(n, x)
                ref = np.fft.fft(x)
            else:
                x = rng.normal(size=n) * 0.3
                X, counters, cycles = run_rfft(n, x)
                ref = np.fft.rfft(x)
            rel = float(np.abs(X - ref).max() / np.abs(ref).max())
            us = cycles / F_HZ * 1e6
            rows.append((f"table2/{kind}_fft_{n}", us,
                         f"sim_cycles={cycles};paper_vwr2a={vwr2a};"
                         f"ratio={cycles / vwr2a:.2f};"
                         f"speedup_vs_cpu={cpu / cycles:.1f}x;"
                         f"q15_rel_err={rel:.1e}"))
    return rows

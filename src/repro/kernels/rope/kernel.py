"""Pallas TPU kernel: RoPE via the shuffle-unit dataflow (DESIGN.md §3).

Interleaved (GPT-J) rotary IS the paper's shuffle algebra:
    even/odd prune  ->  two streams x1, x2
    rotate          ->  (x1 c - x2 s, x1 s + x2 c)     (VPU FMAs)
    interleave      ->  back to lane-adjacent pairs
The neox (rotate-half) layout replaces prune/interleave with half-splits.
cos/sin are computed in-kernel from the staged position block (transcendental
VPU ops) — no HBM-resident rotary table.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.vwr import VWRSpec


def rope_kernel(x_ref, pos_ref, o_ref, *, theta: float, layout: str):
    x = x_ref[...].astype(jnp.float32)       # (rb, dh)
    pos = pos_ref[...].astype(jnp.float32)   # (rb, 1)
    dh = x.shape[-1]
    # inv-freq built in-kernel (2D iota; no captured constants)
    idx = jax.lax.broadcasted_iota(jnp.float32, (1, dh // 2), 1)
    inv = jnp.exp(idx * (2.0 / dh) * (-np.log(theta)))
    ang = pos * inv                          # (rb, dh/2)
    c, s = jnp.cos(ang), jnp.sin(ang)
    if layout == "interleaved":
        xp = x.reshape(x.shape[0], dh // 2, 2)
        x1, x2 = xp[..., 0], xp[..., 1]      # even/odd prune
        o1 = x1 * c - x2 * s
        o2 = x1 * s + x2 * c
        out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)  # interleave
    else:
        x1, x2 = x[..., : dh // 2], x[..., dh // 2:]
        out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("theta", "layout", "interpret"))
def rope_pallas(x, positions, *, theta: float = 10000.0,
                layout: str = "interleaved", interpret: bool = True):
    """x: (R, dh); positions: (R,) int32. Returns rotated x."""
    R, dh = x.shape
    spec = VWRSpec()
    rb = max(1, min(R, spec.max_block_bytes(4) // max(1, dh * 4)))
    while R % rb:
        rb -= 1
    pos2 = positions.reshape(R, 1).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(rope_kernel, theta=theta, layout=layout),
        out_shape=jax.ShapeDtypeStruct((R, dh), x.dtype),
        in_specs=[
            pl.BlockSpec((rb, dh), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((rb, 1), lambda i: (i, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((rb, dh), lambda i: (i, 0),
                               memory_space=pltpu.VMEM),
        grid=(R // rb,),
        interpret=interpret,
    )(x, pos2)

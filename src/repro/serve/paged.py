"""Paged KV cache: fixed-size pages in one preallocated pool, per-request
block tables, and the jitted gather/scatter that turns pages back into
dense attention views.

THE PAPER MAPPING: the pool is the software analogue of VWR2A's
scratchpad banks — one fixed physical memory, time-shared between
tenants through an indirection table — where the dense engine's
per-slot caches were per-tenant private SPMs sized for the worst case
(``slots * max_len`` rows each, mostly empty). Under paging a request
holds exactly ``ceil(need / page_size)`` pages, so ADMISSION IS BOUNDED
BY FREE PAGES, not by the decode batch width: the engine oversubscribes
its lanes (`serve/engine.py:PagedEngine`) the way the vLLM/levanter
`PageTable` design oversubscribes sequence slots.

LAYOUT. One logical page-id space is shared by ALL cache leaves: page j
is row j of every pool leaf (`models.transformer.paged_pool_schema`
shapes each leaf ``(n_pages, page_size, *rest)``). A request holding
pages ``(p0, p1, ...)`` stores the K/V of absolute positions
``[i*page_size, (i+1)*page_size)`` in page ``p_i`` — for a ring/SWA
leaf the positions are the W ring slots, so the ring decode path works
unchanged on the gathered view. PAGE 0 IS SCRATCH: never allocated,
block-table padding for empty lanes and positions past a request's
allocation points at it, and those positions are always masked — their
softmax contribution is exactly zero, which is why paged output is
BIT-identical to the dense path (pinned in `tests/test_paged.py`).

DISPATCH. `paged_prefill` / `paged_decode` are module-level jits keyed
on (model fn, treedef, leaf specs) so every engine over the same model
shares one compilation, exactly like `Engine.compile_model`. Each is
ONE dispatch per engine step — gather, model, scatter fused in a single
jit — so the paged engine pays the same dispatch count as dense while
its decode attends over the allocated span instead of ``max_len``
(`docs/BENCHMARKS.md`, the ``--check-paged`` gate).

Alloc is lowest-id-first off a heap, free returns pages for immediate
reuse, and `PageTable.defrag` compacts the allocated set back to the
lowest ids (one jitted row permutation per pool leaf) — allocation
never fragments (any free page serves any request through the table),
so defrag is a locality/compaction pass, not a correctness one, and the
tests pin that decoding straight through a defrag stays bit-identical.
"""
from __future__ import annotations

import dataclasses
import functools
import heapq

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as att
from repro.models import transformer as tfm
from repro.models.layers import P
from repro.serve.errors import InsufficientPages, PagedCacheUnsupported

SCRATCH_PAGE = 0


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Static per-leaf paging metadata (hashable — it keys the jits).

    ``shape``/``dtype`` are the per-request dense leaf (batch size 1);
    ``seq_len`` its sequence capacity (max_len, or W for a ring leaf);
    ``ring`` whether the leaf is a sliding-window ring (its view must be
    sliced to exactly W for the ring decode path to trigger)."""
    batch_ax: int
    seq_ax: int
    seq_len: int
    ring: bool
    shape: tuple
    dtype: str


def leaf_specs(model, max_len: int):
    """(treedef, specs) for a model's cache tree; raises the typed
    `PagedCacheUnsupported` for models whose cache cannot be paged
    (recurrent state has no seq axis; enc-dec admits token-at-a-time)."""
    cfg = model.cfg
    if getattr(cfg, "ssm", None) is not None:
        raise PagedCacheUnsupported(
            "recurrent state (rwkv/mamba) has no sequence axis to page "
            "over; serve SSM models on the dense Engine")
    if getattr(cfg, "is_encdec", False):
        raise PagedCacheUnsupported(
            "enc-dec decoders admit token-at-a-time against an encoder "
            "context; serve them on the dense Engine")
    schema = model.cache_schema(1, max_len)
    leaves, treedef = jax.tree.flatten(
        schema, is_leaf=lambda x: isinstance(x, P))
    specs = []
    for p in leaves:
        if "batch" not in p.axes or "seq" not in p.axes:
            raise PagedCacheUnsupported(
                f"cache leaf with axes {p.axes} has no (batch, seq) pair")
        b, s = p.axes.index("batch"), p.axes.index("seq")
        assert b < s, (p.axes, "paged gather assumes batch before seq")
        seq_len = p.shape[s]
        specs.append(LeafSpec(b, s, seq_len, seq_len < max_len,
                              tuple(p.shape),
                              np.dtype(p.dtype or np.float32).name))
    return treedef, tuple(specs)


class PagePool:
    """The preallocated physical pool: one leaf per cache leaf, a shared
    free list over the logical page-id space, page 0 reserved as
    scratch. ``capacity`` is the allocatable page count."""

    def __init__(self, model, *, page_size: int = 16, n_pages: int = 64,
                 max_len: int = 256):
        assert page_size >= 1 and n_pages >= 2, (page_size, n_pages)
        self.page_size = int(page_size)
        self.n_pages = int(n_pages)
        self.max_len = int(max_len)
        self.treedef, self.specs = leaf_specs(model, max_len)
        pool_schema = tfm.paged_pool_schema(
            model.cfg, model.plan, n_pages=n_pages, page_size=page_size,
            max_len=max_len)
        flat = jax.tree.flatten(pool_schema,
                                is_leaf=lambda x: isinstance(x, P))[0]
        self.leaves = [jnp.zeros(p.shape, p.dtype or jnp.float32)
                       for p in flat]
        self._free: list[int] = list(range(1, n_pages))  # heap, 0=scratch
        self._held: set[int] = set()

    @property
    def capacity(self) -> int:
        return self.n_pages - 1          # page 0 is scratch

    @property
    def n_free(self) -> int:
        return len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        """Worst-case page footprint of a sequence of ``n_tokens``: the
        max over leaves of the pages covering the leaf's share of it (a
        ring leaf never needs more than its W slots)."""
        ps = self.page_size
        return max(-(-min(int(n_tokens), sp.seq_len) // ps)
                   for sp in self.specs)

    def alloc(self, n: int) -> tuple[int, ...]:
        """Allocate ``n`` pages, lowest ids first (deterministic: the
        same admission order always yields the same tables). Raises the
        typed `InsufficientPages` on over-allocation."""
        if n > len(self._free):
            raise InsufficientPages(n, len(self._free), self.capacity)
        ids = tuple(heapq.heappop(self._free) for _ in range(n))
        self._held.update(ids)
        return ids

    def free(self, ids) -> None:
        for i in ids:
            assert i in self._held, f"freeing unallocated page {i}"
            self._held.discard(i)
            heapq.heappush(self._free, i)


class PageTable:
    """Per-request block tables over a `PagePool`: who holds which
    pages, and the (lanes, Q) int32 tables the jitted dispatches gather
    through."""

    def __init__(self, pool: PagePool):
        self.pool = pool
        self._pages: dict = {}          # rid -> tuple of page ids

    def assign(self, rid, n_pages: int) -> tuple[int, ...]:
        assert rid not in self._pages, f"rid {rid} already holds pages"
        ids = self.pool.alloc(n_pages)
        self._pages[rid] = ids
        return ids

    def release(self, rid) -> None:
        self.pool.free(self._pages.pop(rid))

    def pages(self, rid) -> tuple[int, ...]:
        return self._pages[rid]

    def holds(self, rid) -> bool:
        return rid in self._pages

    def holders(self) -> list:
        return sorted(self._pages)

    def block_table(self, rids, width: int | None = None) -> np.ndarray:
        """(len(rids), width) int32 table; ``None`` entries (empty
        lanes) and columns past a request's allocation pad with the
        scratch page. ``width`` defaults to the widest holder present
        (min 1)."""
        rows = [self._pages.get(r, ()) if r is not None else ()
                for r in rids]
        q = width if width is not None else max(
            [len(r) for r in rows] + [1])
        bt = np.full((len(rows), q), SCRATCH_PAGE, np.int32)
        for i, r in enumerate(rows):
            k = min(len(r), q)     # a prefill table may be narrower
            bt[i, :k] = r[:k]      # than a request's full allocation
        return bt

    def defrag(self) -> dict[int, int]:
        """Compact the allocated set onto the lowest page ids.

        Returns the ``{old: new}`` moves applied; block tables are
        rewritten and every pool leaf's moved rows are copied in one
        jitted permutation. Allocation itself never fragments (the
        table indirection makes pages interchangeable), so this is a
        compaction/locality pass — decode through a mid-stream defrag
        is bit-identical (pinned in `tests/test_paged.py`)."""
        held = sorted(self.pool._held)
        targets = list(range(1, len(held) + 1))
        moves = {old: new for old, new in zip(held, targets) if old != new}
        if not moves:
            return moves
        src = jnp.asarray(list(moves.keys()), jnp.int32)
        dst = jnp.asarray(list(moves.values()), jnp.int32)
        self.pool.leaves = list(_permute_pages(tuple(self.pool.leaves),
                                               src, dst))
        self._pages = {rid: tuple(moves.get(p, p) for p in pages)
                       for rid, pages in self._pages.items()}
        self.pool._held = set(targets)
        self.pool._free = [p for p in range(1, self.pool.n_pages)
                           if p not in self.pool._held]
        heapq.heapify(self.pool._free)
        return moves


@jax.jit
def _permute_pages(pools, src, dst):
    """Copy rows ``src`` onto rows ``dst`` in every pool leaf (defrag's
    data movement; the gather of ``src`` is evaluated before the
    scatter, so overlapping src/dst sets permute correctly)."""
    return tuple(pool.at[dst].set(pool[src]) for pool in pools)


# ---------------------------------------------------------------------------
# The two dispatches (module-level jits: shared across engine instances)
# ---------------------------------------------------------------------------


def _view_len(spec: LeafSpec, q: int, ps: int) -> int:
    # ring leaves MUST view exactly W (that is what triggers the ring
    # decode path); linear leaves view the allocated page span, capped
    # at their dense capacity — the paged compute saving
    return min(spec.seq_len, q * ps)


def _gather_views(pools, bt, specs):
    return [att.gather_page_view(pool, bt, batch_ax=sp.batch_ax,
                                 seq_ax=sp.seq_ax, seq_len=sp.seq_len)
            for pool, sp in zip(pools, specs)]


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def paged_decode(decode_fn, treedef, specs, params, batch, pools, bt):
    """One fused decode step through the block table: gather per-leaf
    views, run the model's decode on them (linear and ring cache paths
    unchanged), scatter each lane's newly written row back to its page.
    Returns ``(logits, new_pools)``."""
    views = _gather_views(pools, bt, specs)
    cache = jax.tree.unflatten(treedef, views)
    logits, new_cache = decode_fn(params, batch, cache)
    new_views = jax.tree.flatten(new_cache)[0]
    pos = jnp.broadcast_to(jnp.atleast_1d(batch["cache_len"]),
                           (bt.shape[0],))
    new_pools = tuple(
        att.scatter_page_token(pool, v, bt, pos, batch_ax=sp.batch_ax,
                               seq_ax=sp.seq_ax)
        for pool, v, sp in zip(pools, new_views, specs))
    return logits, new_pools


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def paged_prefill(prefill_fn, treedef, specs, params, batch, pools, bt):
    """One fused prefill through the block table: run the model's
    prefill into a zero view sized to the batch's token width (a ring
    leaf views its full W), then ASSIGN the written rows to the pages
    the table names — the paged replacement for the dense engine's
    masked slot merge. Returns ``(last_logits, new_pools)``."""
    L, q = bt.shape
    ps = pools[0].shape[1]
    width = batch["tokens"].shape[1]
    views = []
    for sp in specs:
        sv = sp.seq_len if sp.ring else min(sp.seq_len, -(-width // ps) * ps)
        shape = list(sp.shape)
        shape[sp.batch_ax] = L
        shape[sp.seq_ax] = sv
        views.append(jnp.zeros(shape, sp.dtype))
    cache = jax.tree.unflatten(treedef, views)
    logits, new_cache = prefill_fn(params, batch, cache)
    new_views = jax.tree.flatten(new_cache)[0]
    new_pools = tuple(
        att.scatter_page_prefill(pool, v, bt, batch_ax=sp.batch_ax,
                                 seq_ax=sp.seq_ax)
        for pool, v, sp in zip(pools, new_views, specs))
    return logits, new_pools


def prefill_table_width(specs, page_size: int, width: int) -> int:
    """Block-table width a prefill of ``width`` tokens needs: the max
    over leaves of the pages its prefill view covers."""
    return max(
        -(-(sp.seq_len if sp.ring
            else min(sp.seq_len, -(-width // page_size) * page_size))
          // page_size)
        for sp in specs)

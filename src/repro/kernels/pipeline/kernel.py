"""Pallas TPU kernel: the FULL MBioTracker pipeline fused into one kernel.

The paper's headline number is *application-level* (§4.4.2 / Table 5):
chaining kernels while the data stays resident in the SPM/VWRs is where the
energy goes away — the FIR output is consumed by the delineation, whose
window is consumed by the feature extraction, whose features feed the SVM,
and main memory is touched exactly twice (signal in, features out). Our
staged `BiosignalApp` runs those stages as separate jnp/pallas calls, so
every stage round-trips HBM. This kernel transplants the paper's staging to
the whole application, extending what `kernels/fft/kernel.py` does for one
kernel:

    one grid step = one (rb x S) window block staged into VMEM, then
      1. 11-tap FIR          — k unrolled shifted FMAs (paper §4.4.1),
      2. delineation         — the mask-algebra predicates of
                               `core.biosignal.delineate` (the paper's
                               predicated RC code), on the VMEM-resident
                               filtered block,
      3. time features       — masked interval statistics,
      4. 512-pt packed rFFT  — the Stockham stages of the FFT kernel with a
                               staged twiddle table + untangle epilogue,
                               reduced to 6 log-band powers,
      5. linear SVM          — margin + argmax class,
    and ONE HBM write of (filtered, features, margin, class).

Inter-stage tensors never leave the block: the working set is budgeted
against `VWRSpec(n_vwrs=4)` (raw + filtered + FFT planes + table/epilogue
scratch). Numerics follow `core.biosignal` op-for-op so the fused outputs
match the staged app to f32 tolerance; the delineation/median stage leans on
`sort`, which the interpret path executes directly and remains the known
gap for a fully Mosaic-compiled build (tracked in ROADMAP).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.biosignal import (band_power_features, delineate,
                                  interval_time_features)
from repro.core.fft import untangle_rfft
from repro.core.vwr import VWRSpec, resolve_block_rows
from repro.kernels.fft.kernel import twiddle_table


def _fir_stage(x, taps_ref, k: int):
    """Causal k-tap FIR on the staged block — unrolled shifted FMAs, the
    in-VMEM mirror of `core.fir.fir_direct`."""
    rb, S = x.shape
    xp = jnp.pad(x, ((0, 0), (k - 1, 0)))
    y = jnp.zeros_like(x)
    for i in range(k):                   # unrolled taps == circular shifts
        y = y + taps_ref[0, i] * xp[:, k - 1 - i: k - 1 - i + S]
    return y


def untangle_table(fft_size: int) -> np.ndarray:
    """(2, m) packed untangle factors e^{-2*pi*i*k/N} for the real-FFT
    epilogue — staged into VMEM alongside the twiddles (the paper keeps
    both in the SPM)."""
    m = fft_size // 2
    ang = -2.0 * np.pi * np.arange(m) / fft_size
    return np.stack([np.cos(ang), np.sin(ang)]).astype(np.float32)


def _rfft_band_powers(seg, wr_ref, wi_ref, u_ref, *, fft_size: int):
    """Packed real FFT (N real -> N/2 complex, Stockham stages, untangle)
    reduced to the 6 log-band powers of `core.biosignal.extract_features`.

    The butterfly stages are the FFT kernel's body verbatim, reading the
    staged (stages, m/2) twiddle table and the (2, m) untangle table.
    """
    rb = seg.shape[0]
    seg = seg - jnp.mean(seg, axis=-1, keepdims=True)
    zr, zi = seg[:, 0::2], seg[:, 1::2]            # pack: z = even + i*odd
    m = fft_size // 2
    stages = int(np.log2(m))
    g, n = 1, m
    re = zr.reshape(rb, 1, m)
    im = zi.reshape(rb, 1, m)
    for s in range(stages):
        ar, ai = re[..., : n // 2], im[..., : n // 2]
        br, bi = re[..., n // 2:], im[..., n // 2:]
        wr = wr_ref[s, : n // 2].reshape(1, 1, n // 2)
        wi = wi_ref[s, : n // 2].reshape(1, 1, n // 2)
        t0r, t0i = ar + br, ai + bi
        dr, di = ar - br, ai - bi
        t1r = dr * wr - di * wi
        t1i = dr * wi + di * wr
        # words-interleaving regroup (self-sorting Stockham)
        re = jnp.concatenate([t0r[:, None], t1r[:, None]], axis=1).reshape(
            rb, 2 * g, n // 2)
        im = jnp.concatenate([t0i[:, None], t1i[:, None]], axis=1).reshape(
            rb, 2 * g, n // 2)
        g, n = 2 * g, n // 2
    Zr = re.reshape(rb, m)
    Zi = im.reshape(rb, m)
    Xr, Xi = untangle_rfft(Zr, Zi, u_ref[0, :], u_ref[1, :])
    power = jnp.square(Xr) + jnp.square(Xi)        # (rb, fft/2+1)
    return band_power_features(power, fft_size)


def pipeline_kernel(x_ref, taps_ref, wr_ref, wi_ref, u_ref, w_ref, b_ref,
                    filt_ref, feat_ref, marg_ref, cls_ref, *,
                    n_taps: int, fft_size: int):
    x = x_ref[...].astype(jnp.float32)             # (rb, S) staged once
    # --- stage 1: preprocessing (11-tap FIR) ---
    filt = _fir_stage(x, taps_ref, n_taps)
    # --- stage 2: delineation (predicated mask algebra, never leaves VMEM)
    is_max, is_min = delineate(filt)
    # --- stage 3a: time features (masked interval statistics) ---
    f_time = interval_time_features(is_max, is_min)
    # --- stage 3b: frequency features (packed rFFT band powers) ---
    f_freq = _rfft_band_powers(filt[:, :fft_size], wr_ref, wi_ref, u_ref,
                               fft_size=fft_size)
    feats = jnp.stack(f_time + f_freq, axis=-1)    # (rb, 12)
    # --- stage 4: linear SVM margin + class ---
    margin = jnp.dot(feats, w_ref[...], preferred_element_type=jnp.float32
                     ) + b_ref[0]
    cls = jnp.argmax(margin, axis=-1).astype(jnp.int32)
    # --- the ONE HBM write ---
    filt_ref[...] = filt.astype(filt_ref.dtype)
    feat_ref[...] = feats
    marg_ref[...] = margin
    cls_ref[...] = cls[:, None]


@functools.partial(jax.jit,
                   static_argnames=("fft_size", "interpret", "block_rows"))
def pipeline_pallas(signal, taps, w, b, *, fft_size: int = 512,
                    interpret: bool = True, block_rows: int | None = None):
    """Fused MBioTracker pipeline. signal: (R, S) windows, S >= fft_size.

    Returns the same dict as the staged `BiosignalApp.__call__`:
    {"filtered": (R,S), "features": (R,F), "margin": (R,C), "class": (R,)}.
    Exactly ONE `pallas_call` runs per window batch.
    """
    R, S = signal.shape
    k = int(taps.shape[0])
    F, C = w.shape
    assert S >= fft_size, (S, fft_size)
    m = fft_size // 2
    stages = int(np.log2(m))
    assert 1 << stages == m, f"fft_size={fft_size} not a power of 2"
    wr, wi = twiddle_table(m)
    # raw + filtered + two FFT planes ~= 4 live VWR blocks
    rb = resolve_block_rows(R, S * 4, spec=VWRSpec(n_vwrs=4),
                            override=block_rows)
    taps2 = jnp.asarray(taps, jnp.float32).reshape(1, k)
    b2 = jnp.asarray(b, jnp.float32).reshape(1, C)
    filt, feats, margin, cls = pl.pallas_call(
        functools.partial(pipeline_kernel, n_taps=k, fft_size=fft_size),
        out_shape=(jax.ShapeDtypeStruct((R, S), signal.dtype),
                   jax.ShapeDtypeStruct((R, F), jnp.float32),
                   jax.ShapeDtypeStruct((R, C), jnp.float32),
                   jax.ShapeDtypeStruct((R, 1), jnp.int32)),
        in_specs=[
            pl.BlockSpec((rb, S), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((1, k), lambda i: (0, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((stages, m // 2), lambda i: (0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((stages, m // 2), lambda i: (0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((2, m), lambda i: (0, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((F, C), lambda i: (0, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((1, C), lambda i: (0, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=(
            pl.BlockSpec((rb, S), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((rb, F), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((rb, C), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((rb, 1), lambda i: (i, 0), memory_space=pltpu.VMEM),
        ),
        grid=(R // rb,),
        interpret=interpret,
    )(signal, taps2, jnp.asarray(wr), jnp.asarray(wi),
      jnp.asarray(untangle_table(fft_size)), jnp.asarray(w, jnp.float32), b2)
    return {"filtered": filt, "features": feats, "margin": margin,
            "class": cls[:, 0]}

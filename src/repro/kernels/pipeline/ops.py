"""Public API for the fused stage-graph pipeline kernels.

Two entry points share the in-VMEM stage chain:

* ``biosignal_pipeline`` — pre-framed (R, S) window batches (the PR-2
  path, now with an ``outputs`` selection);
* ``biosignal_pipeline_stream`` — the RAW 1-D signal: overlapping
  (window, hop) frames are built inside the kernel from a once-staged
  signal chunk, so HBM traffic is ~n_samples instead of n_frames*window
  and the host never gathers frames.

The ``graph_pipeline*`` trio is the GENERIC face of the same machinery:
any registered stage graph (`graph.py:register_graph_factory` —
``"biosignal"``, ``"asr"``, or one you author per
`docs/STAGE_GRAPHS.md`) resolved by name, same framed/stream/ring
entries, autotune keys carrying the graph name so winners never leak
across graphs.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.pipeline.graph import (default_app, get_graph_factory,
                                          graph_pallas, graph_ring_pallas,
                                          graph_stream_pallas)
from repro.kernels.pipeline.kernel import (OUTPUTS, canonical_outputs,
                                           pipeline_pallas,
                                           pipeline_ring_pallas,
                                           pipeline_stream_pallas,
                                           ring_chunk_samples,
                                           stream_frame_count)
from repro.kernels.pipeline.shard import (column_shares, pipeline_sharded,
                                          pipeline_stream_sharded)

__all__ = ["OUTPUTS", "canonical_outputs", "biosignal_pipeline",
           "biosignal_pipeline_stream", "biosignal_pipeline_ring",
           "app_pipeline", "app_pipeline_stream", "app_pipeline_ring",
           "graph_pipeline", "graph_pipeline_stream", "graph_pipeline_ring",
           "ring_chunk_samples", "default_app"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def biosignal_pipeline(signal, taps, w, b, *, fft_size: int = 512,
                       block_rows: int | None = None,
                       autotune: bool = False, outputs=None,
                       n_columns: int = 1, mesh=None):
    """Run the full MBioTracker pipeline on (R, S) windows in ONE fused
    Pallas call. Returns the staged app's output dict restricted to
    ``outputs`` (default: all four keys).

    ``block_rows`` pins the per-grid-step row-block; ``autotune=True``
    instead picks it from measured candidates (cached per shape) — the
    measured replacement for the static VWRSpec budget formula.
    ``n_columns > 1`` deals row-blocks across column replicas
    (`shard_map` over ``mesh``'s `data` axis when available, serial
    columns otherwise); the autotune cache key carries the column count
    so winners are per-(shape, D).
    """
    outputs = canonical_outputs(outputs)
    interpret = _interpret()
    run_cols = functools.partial(pipeline_sharded, n_columns=n_columns,
                                 mesh=mesh) if n_columns > 1 else \
        pipeline_pallas
    if autotune and block_rows is None:
        from repro.core.autotune import tuned_block_rows

        R, S = signal.shape
        extras = (S, fft_size, outputs, str(signal.dtype)) + (
            (n_columns,) if n_columns > 1 else ())
        block_rows = tuned_block_rows(
            "biosignal_pipeline", -(-R // n_columns), extras,
            lambda rb: run_cols(signal, taps, w, b, fft_size=fft_size,
                                interpret=interpret, block_rows=rb,
                                outputs=outputs))
    return run_cols(signal, taps, w, b, fft_size=fft_size,
                    interpret=interpret, block_rows=block_rows,
                    outputs=outputs)


def biosignal_pipeline_stream(signal, taps, w, b, *, window: int, hop: int,
                              fft_size: int = 512,
                              block_frames: int | None = None,
                              autotune: bool = False, outputs=None,
                              n_columns: int = 1, mesh=None,
                              column_weights=None):
    """Run the pipeline over a RAW 1-D signal with in-kernel (window, hop)
    framing — the single-residency streaming path. Output equals
    ``biosignal_pipeline`` on host-framed windows, to the last bit.

    ``block_frames`` pins the frames-per-grid-step; ``autotune=True``
    measures candidates, cached under the (window, hop, outputs, D) shape
    key. ``n_columns > 1`` deals hop-aligned signal chunks (+ window-hop
    halo) across column replicas via `shard_map` over ``mesh``'s `data`
    axis (serial columns when no mesh fits) — outputs stay equal to the
    single-device call. ``column_weights`` makes that deal load-aware
    (non-uniform `column_shares`, e.g. measured per-column rates from
    `serve.stream.StreamTelemetry`); the autotune key then carries the
    quantized share signature so winners don't leak across deal shapes.
    """
    outputs = canonical_outputs(outputs)
    interpret = _interpret()
    assert column_weights is None or len(column_weights) == n_columns, \
        (column_weights, n_columns)
    if n_columns == 1:
        # a single weight is the degenerate identity deal: normalize it
        # away so it neither reaches the kernel nor splits the autotune
        # key of the identical computation
        column_weights = None
    run_cols = functools.partial(pipeline_stream_sharded,
                                 n_columns=n_columns, mesh=mesh,
                                 weights=column_weights) \
        if n_columns > 1 else pipeline_stream_pallas
    if autotune and block_frames is None:
        from repro.core.autotune import tuned_stream_block_frames

        n = stream_frame_count(signal.shape[0], window, hop)
        if n > 1:
            shares = column_shares(n, n_columns, column_weights) \
                if column_weights is not None else None
            block_frames = tuned_stream_block_frames(
                "biosignal_pipeline_stream", n, window, hop, outputs,
                str(signal.dtype),
                lambda rb: run_cols(
                    signal, taps, w, b, window=window, hop=hop,
                    fft_size=fft_size, interpret=interpret, block_frames=rb,
                    outputs=outputs), n_columns=n_columns, shares=shares)
    return run_cols(signal, taps, w, b, window=window, hop=hop,
                    fft_size=fft_size, interpret=interpret,
                    block_frames=block_frames, outputs=outputs)


def biosignal_pipeline_ring(ring, taps, w, b, *, window: int, hop: int,
                            fft_size: int = 512,
                            block_frames: int | None = None,
                            outputs=None):
    """Run the pipeline over a `(ring_depth, span)` RING of raw chunks in
    one fused `pallas_call` — the kernel entry the device-resident loop
    (`serve/resident.py`) dispatches per sweep. Each ring slot frames
    in-kernel exactly like `biosignal_pipeline_stream` on that slot's
    chunk; slot r of the result is bit-identical to the single-chunk call
    on `ring[r]`. See `docs/ARCHITECTURE.md` (serving control loop)."""
    outputs = canonical_outputs(outputs)
    return pipeline_ring_pallas(ring, taps, w, b, window=window, hop=hop,
                                fft_size=fft_size, interpret=_interpret(),
                                block_frames=block_frames, outputs=outputs)


def graph_pipeline(name: str, app, frames, *,
                   block_rows: int | None = None, autotune: bool = False,
                   outputs=None):
    """Run a REGISTERED stage graph on pre-framed (R, S) windows in ONE
    fused Pallas call. ``name`` resolves via
    `graph.py:get_graph_factory`; ``app`` binds the graph's operand
    tables (``None`` uses the graph's registered default app). Returns
    the graph's output dict restricted to ``outputs``."""
    factory = get_graph_factory(name)
    graph, operands = factory(app if app is not None
                              else default_app(name))
    interpret = _interpret()
    if autotune and block_rows is None:
        from repro.core.autotune import tuned_block_rows

        R, S = frames.shape
        block_rows = tuned_block_rows(
            f"{graph.name}_pipeline", R,
            (S, graph.params, outputs, str(frames.dtype)),
            lambda rb: graph_pallas(frames, operands, graph=graph,
                                    interpret=interpret, block_rows=rb,
                                    outputs=outputs))
    return graph_pallas(frames, operands, graph=graph, interpret=interpret,
                        block_rows=block_rows, outputs=outputs)


def graph_pipeline_stream(name: str, app, signal, *, window: int, hop: int,
                          block_frames: int | None = None,
                          autotune: bool = False, outputs=None):
    """Run a registered stage graph over a RAW 1-D signal with in-kernel
    (window, hop) framing — `graph.py:graph_stream_pallas` under an
    autotuned frame-block. The cache key is
    ``f"{name}_pipeline_stream"``, so the biosignal graph keeps its
    historical ``"biosignal_pipeline_stream"`` winners and other graphs
    tune independently."""
    factory = get_graph_factory(name)
    graph, operands = factory(app if app is not None
                              else default_app(name))
    interpret = _interpret()
    if autotune and block_frames is None:
        from repro.core.autotune import tuned_stream_block_frames

        n = stream_frame_count(signal.shape[0], window, hop)
        if n > 1:
            block_frames = tuned_stream_block_frames(
                f"{graph.name}_pipeline_stream", n, window, hop, outputs,
                str(signal.dtype),
                lambda rb: graph_stream_pallas(
                    signal, operands, graph=graph, window=window, hop=hop,
                    interpret=interpret, block_frames=rb, outputs=outputs))
    return graph_stream_pallas(signal, operands, graph=graph, window=window,
                               hop=hop, interpret=interpret,
                               block_frames=block_frames, outputs=outputs)


def graph_pipeline_ring(name: str, app, ring, *, window: int, hop: int,
                        block_frames: int | None = None, outputs=None):
    """Run a registered stage graph over a `(ring_depth, span)` ring of
    raw chunks in one fused call — the graph-generic
    `biosignal_pipeline_ring`, dispatched by the device-resident loop
    (`serve/resident.py`) for any graph."""
    factory = get_graph_factory(name)
    graph, operands = factory(app if app is not None
                              else default_app(name))
    return graph_ring_pallas(ring, operands, graph=graph, window=window,
                             hop=hop, interpret=_interpret(),
                             block_frames=block_frames, outputs=outputs)


def app_pipeline(app, signal, *, block_rows: int | None = None,
                 autotune: bool = False, outputs=None, n_columns: int = 1,
                 mesh=None):
    """Fused execution of a `core.biosignal.BiosignalApp` instance on
    pre-framed windows."""
    return biosignal_pipeline(signal, app.fir_taps, app.svm_w, app.svm_b,
                              fft_size=app.fft_size, block_rows=block_rows,
                              autotune=autotune, outputs=outputs,
                              n_columns=n_columns, mesh=mesh)


def app_pipeline_stream(app, signal, *, window: int, hop: int,
                        block_frames: int | None = None,
                        autotune: bool = False, outputs=None,
                        n_columns: int = 1, mesh=None,
                        column_weights=None):
    """Fused raw-signal streaming execution of a `BiosignalApp`."""
    return biosignal_pipeline_stream(signal, app.fir_taps, app.svm_w,
                                     app.svm_b, window=window, hop=hop,
                                     fft_size=app.fft_size,
                                     block_frames=block_frames,
                                     autotune=autotune, outputs=outputs,
                                     n_columns=n_columns, mesh=mesh,
                                     column_weights=column_weights)


def app_pipeline_ring(app, ring, *, window: int, hop: int,
                      block_frames: int | None = None, outputs=None):
    """Fused ring-of-chunks execution of a `BiosignalApp` (one
    `pallas_call` per ring sweep — the device-resident loop's dispatch)."""
    return biosignal_pipeline_ring(ring, app.fir_taps, app.svm_w, app.svm_b,
                                   window=window, hop=hop,
                                   fft_size=app.fft_size,
                                   block_frames=block_frames,
                                   outputs=outputs)

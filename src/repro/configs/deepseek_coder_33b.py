"""deepseek-coder-33b [arXiv:2401.14196; hf] — llama-arch dense GQA."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    head_dim=128,
    rope_theta=100000.0,
    source="arXiv:2401.14196; hf:deepseek-ai/deepseek-coder-33b-base",
))

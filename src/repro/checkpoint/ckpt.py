"""Sharded checkpointing with elastic reshard-on-restore.

Layout: <dir>/step_<n>/
    meta.json                     tree structure, shapes, dtypes
    <flat.key>.npy                one file per leaf (full array)

Design points for 1000+ nodes (documented integration surface):
  * leaves are addressed by flattened tree path — restore works across code
    refactors as long as names survive;
  * restore takes target shardings and device_puts each leaf — the mesh at
    restore time may differ from the mesh at save time (elastic resize);
  * `async_save` snapshots to host RAM synchronously (cheap: device->host
    copy) and writes to disk on a worker thread — the train loop only
    blocks for the snapshot, as in production async checkpointers;
  * on a real multi-host pod each host writes only its addressable shards
    (the per-shard variant of `_save_leaf`); the single-process dry-run
    environment holds every shard, so full-array files are written.
"""
from __future__ import annotations

import json
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(flat: dict, template):
    def rec(node, prefix=""):
        if isinstance(node, dict):
            return {k: rec(v, f"{prefix}{k}/") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [rec(v, f"{prefix}{i}/") for i, v in enumerate(node)]
            return type(node)(t)
        return flat[prefix[:-1]]

    return rec(template)


def save(state, step: int, ckpt_dir: str, *, async_write: bool = False):
    """Returns the written directory (or the pending thread if async)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    tmp = d.with_suffix(".tmp")
    flat = _flatten(state)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def write():
        tmp.mkdir(parents=True, exist_ok=True)
        meta = {}
        for k, v in host.items():
            fn = k.replace("/", ".") + ".npy"
            np.save(tmp / fn, v)
            meta[k] = {"file": fn, "shape": list(v.shape),
                       "dtype": str(v.dtype)}
        (tmp / "meta.json").write_text(json.dumps(
            {"step": step, "leaves": meta}))
        tmp.rename(d)                  # atomic publish

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return d, t
    write()
    return d, None


def latest_step(ckpt_dir: str) -> int | None:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in d.glob("step_*")
                   if (p / "meta.json").exists())
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, template, shardings=None):
    """Load into the structure of `template`; device_put with `shardings`
    (a matching pytree of NamedSharding) => elastic reshard-on-restore."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    meta = json.loads((d / "meta.json").read_text())
    flat_t = _flatten(template)
    flat_s = _flatten(shardings) if shardings is not None else None
    flat = {}
    for k in flat_t:
        info = meta["leaves"][k]
        arr = np.load(d / info["file"])
        if flat_s is not None:
            flat[k] = jax.device_put(arr, flat_s[k])
        else:
            flat[k] = jax.numpy.asarray(arr)
    return _unflatten_into(flat, template)

import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective statistics.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices stand in for 2 pods x 256 chips.
MUST be run as its own process (the XLA_FLAGS line above has to execute
before any other jax import in the process).

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax.numpy as jnp

from repro.analysis.hlo_cost import analyze
from repro.configs import (SHAPES, applicable_shapes, get_config, input_specs,
                           ASSIGNED)
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models import layers as L
from repro.sharding.rules import Strategy
from repro.train import optim
from repro.train.step import make_train_step
from repro.serve.step import make_serve_step

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce-start|all-gather-start|reduce-scatter|all-to-all|"
    r"collective-permute-start|all-reduce|all-gather|collective-permute)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "f8e4m3fn": 1,
          "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        b = _shape_bytes(shape_str)
        d = out.setdefault(op, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += b
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    return out


def _opt_config_for(cfg):
    # the 400B MoE config needs compact moments to fit 16 GiB/chip
    if cfg.name.startswith("llama4"):
        return optim.OptConfig(m_dtype=jnp.bfloat16, v_dtype="qint8")
    return optim.OptConfig()


def lower_cell(arch: str, shape_name: str, mesh, strategy: str = None,
               overrides: dict = None):
    """Returns (lowered, meta) for one (arch x shape) cell."""
    import dataclasses

    from repro.sharding.rules import Strategy

    cfg = get_config(arch)
    for key, val in (overrides or {}).items():  # e.g. {"ssm.impl": "matmul"}
        if key.startswith("ssm."):
            cfg = dataclasses.replace(
                cfg, ssm=dataclasses.replace(cfg.ssm, **{key[4:]: val}))
        elif key.startswith("moe."):
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, **{key[4:]: val}))
        else:
            cfg = dataclasses.replace(cfg, **{key: val})
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    batch = input_specs(cfg, shape)

    if shape.kind == "train":
        st = Strategy(strategy or "train")
        bundle = make_train_step(model, _opt_config_for(cfg), mesh, batch,
                                 strategy=st)
        lowered = bundle.step_fn.lower(bundle.abstract_state, batch)
    else:
        st = Strategy(strategy or "serve")
        bundle = make_serve_step(model, mesh, batch,
                                 batch_size=shape.global_batch,
                                 max_len=shape.seq_len, strategy=st)
        if shape.kind == "prefill":
            lowered = bundle.prefill_fn.lower(
                bundle.abstract_params, batch, bundle.abstract_cache)
        else:
            lowered = bundle.decode_fn.lower(
                bundle.abstract_params, batch, bundle.abstract_cache)
    n_params = L.param_count(model.schema)
    return lowered, {"arch": arch, "shape": shape_name,
                     "kind": shape.kind, "n_params": n_params}


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             strategy: str = None, overrides: dict = None, tag: str = ""):
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "strategy": strategy or "default", "overrides": overrides or {},
           "devices": int(mesh.devices.size)}
    try:
        with mesh:
            lowered, meta = lower_cell(arch, shape_name, mesh, strategy,
                                       overrides)
            rec.update(meta)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)
            rec["lower_s"] = round(t1 - t0, 1)

            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):   # newer jax: one dict per
                ca = ca[0] if ca else {}        # program; take the entry
            rec["flops"] = float(ca.get("flops", -1))
            rec["bytes_accessed"] = float(ca.get("bytes accessed", -1))
            rec["transcendentals"] = float(ca.get("transcendentals", -1))

            try:
                ma = compiled.memory_analysis()
                if ma is not None:
                    rec["memory"] = {
                        k: int(getattr(ma, k))
                        for k in ("argument_size_in_bytes",
                                  "output_size_in_bytes",
                                  "temp_size_in_bytes",
                                  "generated_code_size_in_bytes")
                        if hasattr(ma, k)}
            except Exception as e:  # CPU backend may not implement it
                rec["memory_error"] = str(e)

            hlo = compiled.as_text()
            rec["collectives_raw"] = collective_stats(hlo)
            # trip-count-aware per-device cost model (see analysis/hlo_cost)
            pod_size = 256 if mesh_kind == "multi" else 0
            rec["hlo_cost"] = analyze(hlo, pod_size=pod_size)
            rec["hlo_ops"] = len(re.findall(r"\n +\S+ = ", hlo))
            rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)

    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = (f"__{strategy}" if strategy else "") + (f"__{tag}" if tag else "")
    fn = out_dir / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
    fn.write_text(json.dumps(rec, indent=1))
    status = rec["status"]
    extra = "" if status == "ok" else f"  !! {rec.get('error', '')[:160]}"
    print(f"[dryrun] {arch:28s} {shape_name:12s} {mesh_kind:6s} {status}"
          f"  ({rec['total_s']}s){extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--strategy", default=None,
                    help="override sharding strategy (e.g. fsdp)")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (e.g. ssm.impl=matmul)")
    ap.add_argument("--tag", default="", help="suffix for the output file")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v
    out_dir = Path(args.out)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in ASSIGNED:
            for shape in applicable_shapes(get_config(arch)):
                for mk in meshes:
                    cells.append((arch, shape, mk))
    else:
        assert args.arch and args.shape
        for mk in meshes:
            cells.append((args.arch, args.shape, mk))

    n_fail = 0
    for arch, shape, mk in cells:
        suffix = f"__{args.strategy}" if args.strategy else ""
        fn = out_dir / f"{arch}__{shape}__{mk}{suffix}.json"
        if args.skip_existing and fn.exists():
            rec = json.loads(fn.read_text())
            if rec.get("status") == "ok":
                print(f"[dryrun] {arch:28s} {shape:12s} {mk:6s} cached-ok",
                      flush=True)
                continue
        rec = run_cell(arch, shape, mk, out_dir, args.strategy, overrides,
                       args.tag)
        n_fail += rec["status"] != "ok"
    print(f"[dryrun] done, {n_fail} failures", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

"""Training loop: deterministic data replay + periodic (async) checkpoints
+ straggler/heartbeat bookkeeping + optional compressed-DP hooks."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.runtime.fault import StragglerDetector


@dataclasses.dataclass
class LoopConfig:
    n_steps: int = 100
    ckpt_every: int = 0               # 0 = disabled
    ckpt_dir: str = "checkpoints"
    log_every: int = 10
    async_ckpt: bool = True


def train(model, bundle, data_cfg: DataConfig, loop_cfg: LoopConfig,
          state=None, *, log: Optional[Callable] = print):
    """bundle: StepBundle from train/step.py. Resumes from the latest
    checkpoint if one exists. Returns (state, history)."""
    loader = ShardedLoader(data_cfg)
    start = 0
    if loop_cfg.ckpt_every:
        last = ckpt_lib.latest_step(loop_cfg.ckpt_dir)
        if last is not None:
            state = ckpt_lib.restore(loop_cfg.ckpt_dir, last,
                                     bundle.abstract_state,
                                     bundle.state_shardings)
            start = last
            if log:
                log(f"[train] resumed from step {last}")
    assert state is not None, "no initial state and no checkpoint"

    det = StragglerDetector()
    history = []
    pending = None
    for step in range(start, loop_cfg.n_steps):
        batch = loader.batch(step)
        t0 = time.perf_counter()
        state, metrics = bundle.step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        det.record(0, dt)
        if loop_cfg.log_every and (step + 1) % loop_cfg.log_every == 0:
            m = {k: float(np.asarray(v)) for k, v in metrics.items()}
            history.append({"step": step + 1, "time_s": dt, **m})
            if log:
                log(f"[train] step {step + 1} loss={m['loss']:.4f} "
                    f"lr={m['lr']:.2e} gnorm={m['grad_norm']:.2f} "
                    f"({dt * 1e3:.0f} ms)")
        if loop_cfg.ckpt_every and (step + 1) % loop_cfg.ckpt_every == 0:
            if pending is not None:
                pending.join()
            _, pending = ckpt_lib.save(state, step + 1, loop_cfg.ckpt_dir,
                                       async_write=loop_cfg.async_ckpt)
    if pending is not None:
        pending.join()
    return state, history

"""Table 5 — MBioTracker biosignal application (paper §5.2).

Per-step cycles/energy from the simulator vs the paper's CPU / CPU+FFT-ACCEL
/ CPU+VWR2A columns. The CPU and accelerator columns are the paper's
measurements; `savings` compares our simulated VWR2A against them.

Also times the fused single-`pallas_call` application kernel against the
staged per-stage execution (the software analogue of the paper's
whole-application SPM residency vs kernel-at-a-time offload); the CI bench
smoke gates on fused <= staged via ``run.py --check-fused``.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.table2_fft import F_HZ

PAPER_CPU = {"preprocessing": (49760, 0.74), "delineation": (46268, 0.74),
             "feat_extraction": (70639, 1.1), "total": (166667, 2.6)}
PAPER_VWR2A = {"preprocessing": (3763, 0.26), "delineation": (2723, 0.13),
               "feat_extraction": (8627, 0.47), "total": (15113, 0.86)}


def _paired_times(fns: list, reps: int = 15) -> list[list[float]]:
    """Paired per-rep wall times in us: the candidates are timed
    ALTERNATELY inside one loop so machine noise hits all of them equally
    (an unpaired comparison at the ~3%-level is a coin flip). The full
    rep lists feed the pinned-shape regression gate, whose tolerance is
    the run's own rep spread."""
    import jax

    for fn in fns:
        jax.block_until_ready(fn())          # compile + warm
    times = [[] for _ in fns]
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            times[i].append((time.perf_counter() - t0) * 1e6)
    return times


def _paired_best(fns: list, reps: int = 15) -> list[float]:
    return [min(ts) for ts in _paired_times(fns, reps)]


def _pipeline_rows():
    """Fused application kernel vs the staged executions (paper Table 5's
    execution models: whole-app residency vs kernel-at-a-time offload)."""
    from repro.core.biosignal import make_app, synthetic_respiration
    from repro.kernels.pipeline.ops import app_pipeline
    from repro.kernels.pipeline.ref import staged_kernel_fns, staged_stage_fns

    app = make_app()
    sig, _ = synthetic_respiration(32, 2048, seed=0)
    from repro.core import autotune

    staged = staged_kernel_fns(app.fir_taps, app.svm_w, app.svm_b,
                               fft_size=app.fft_size)
    fir_fn, feat_fn, svm_fn = staged_stage_fns(
        app.fir_taps, app.svm_w, app.svm_b, fft_size=app.fft_size)
    t_fused, t_staged, t_jnp = _paired_times([
        lambda: app_pipeline(app, sig),
        lambda: staged(sig),
        lambda: svm_fn(feat_fn(fir_fn(sig))),
    ])
    us_fused, us_staged, us_jnp = min(t_fused), min(t_staged), min(t_jnp)
    autotune.record_pinned("table5/pipeline_fused", t_fused,
                           baseline_us=t_staged)
    return [
        ("table5/pipeline_staged", us_staged,
         "kernel-at-a-time: 4 launches/batch (FIR kernel; delineation; "
         "rFFT kernel; SVM) with per-stage HBM round trips"),
        ("table5/pipeline_staged_jnp", us_jnp,
         "3 jnp-only jit calls/batch (no per-kernel staging); info only"),
        ("table5/pipeline_fused", us_fused,
         f"ONE pallas_call per batch;speedup_vs_staged="
         f"{us_staged / us_fused:.2f}x"),
    ]


def _stream_rows():
    """Raw-signal single-residency streaming vs host-framed feeds at the
    default overlap (hop = window/4, every sample duplicated 4x by host
    framing). Candidates are timed PAIRED (alternating min-of-reps); the CI
    bench smoke gates on stream-fused >= 1.25x framed-fused via
    ``run.py --check-stream``."""
    from repro.core.biosignal import make_app, synthetic_respiration
    from repro.kernels.pipeline.ops import (app_pipeline,
                                            app_pipeline_stream)
    from repro.kernels.pipeline.ref import staged_kernel_fns
    from repro.serve.stream import frame_signal

    app = make_app()
    window, hop, n_frames = 2048, 512, 32
    sig, _ = synthetic_respiration(1, (n_frames - 1) * hop + window, seed=1)
    raw = sig[0]
    cls_outputs = ("features", "margin", "class")   # elide filtered write
    staged = staged_kernel_fns(app.fir_taps, app.svm_w, app.svm_b,
                               fft_size=app.fft_size)
    # populate the autotune cache (these warmup calls are what lands in
    # BENCH_autotune.json), but GATE on pinned whole-batch blocks: the
    # near-tied candidates make autotune's pick a coin flip under CI load,
    # and a flapping gate is worse than a fixed one
    app_pipeline_stream(app, raw, window=window, hop=hop,
                        outputs=cls_outputs, autotune=True)
    app_pipeline(app, frame_signal(raw, window, hop), autotune=True)
    t_stream, t_framed, t_staged = _paired_times([
        lambda: app_pipeline_stream(app, raw, window=window, hop=hop,
                                    outputs=cls_outputs,
                                    block_frames=n_frames),
        lambda: app_pipeline(app, frame_signal(raw, window, hop),
                             block_rows=n_frames),
        lambda: staged(frame_signal(raw, window, hop)),
    ], reps=25)
    us_stream, us_framed, us_staged = (min(t_stream), min(t_framed),
                                       min(t_staged))
    from repro.core import autotune

    autotune.record_pinned("table5/stream_fused", t_stream,
                           baseline_us=t_framed)
    return [
        ("table5/stream_fused", us_stream,
         f"raw {raw.shape[0]}-sample feed, frames built in-kernel "
         f"(window={window},hop={hop}), outputs=features+margin+class;"
         f"speedup_vs_framed={us_framed / us_stream:.2f}x"),
        ("table5/stream_framed_fused", us_framed,
         f"host frame gather ({window // hop}x HBM duplication) + fused "
         f"kernel, all outputs"),
        ("table5/stream_framed_staged", us_staged,
         "host frame gather + kernel-at-a-time staged execution"),
    ]


def _column_rows():
    """Column-scaling sweep for the STREAMING Pallas path — the mirror of
    `table2_fft._column_sweep` (which sweeps archsim's n_columns): a fixed
    64-frame raw feed dealt across D column replicas.

    The headline metric is the measured PER-COLUMN latency (one column's
    ~n/D-frame chunk through the fused kernel) — on a real D-device
    machine that IS the dispatch wall clock, and it is what the
    ``--check-columns`` monotonicity gate checks; host-fake devices
    sharing a 2-core CPU would make the aggregate wall a core-count
    artifact. When the process does have >= D devices the true shard_map
    wall is measured too and recorded in `derived` alongside.
    """
    import jax

    from repro.core.biosignal import make_app, synthetic_respiration
    from repro.kernels.pipeline.ops import app_pipeline_stream
    from repro.kernels.pipeline.shard import column_chunks
    from repro.serve.stream import column_mesh

    app = make_app()
    window, hop, n_frames = 2048, 512, 64
    sig, _ = synthetic_respiration(1, (n_frames - 1) * hop + window, seed=2)
    raw = sig[0]
    cls_outputs = ("features", "margin", "class")
    sweep = (1, 2, 4, 8)
    # one column's chunk per D (identical per-column shapes, frames n/D)
    col0 = {d: column_chunks(raw, window, hop, d)[0][0] for d in sweep}
    fns = [
        # block pinned to the D=8 share so every D runs the same kernel
        # variant and the sweep isolates the work-per-column scaling
        (lambda d: lambda: app_pipeline_stream(
            app, col0[d], window=window, hop=hop, outputs=cls_outputs,
            block_frames=n_frames // max(sweep)))(d)
        for d in sweep
    ]
    times = _paired_times(fns, reps=10)
    rows, t1 = [], min(times[0])
    for d, ts in zip(sweep, times):
        t_col = min(ts)
        extra = ""
        mesh = column_mesh(d)
        if d > 1 and mesh is not None:
            fn = lambda: app_pipeline_stream(  # noqa: E731
                app, raw, window=window, hop=hop, outputs=cls_outputs,
                block_frames=n_frames // max(sweep), n_columns=d, mesh=mesh)
            jax.block_until_ready(fn())
            wall = min(_paired_times([fn], reps=5)[0])
            extra = f";shard_map_wall_us={wall:.1f}"
        rows.append((
            f"table5/stream_ncols{d}", t_col,
            f"per-column latency, {n_frames // d} of {n_frames} frames "
            f"(window={window},hop={hop});scaling={t1 / t_col:.2f}x;"
            f"model_windows_per_s={n_frames / t_col * 1e6:.0f}{extra}"))
    return rows


def _depth_rows():
    """Streaming-runtime pipelining depth: depth=1 (the classic double
    buffer — consume batch k while k+1 is in flight) vs depth=2 (two
    batches in flight). Measured within noise on the CPU interpret path
    (±4%, winner flips across trials), so `StreamConfig.depth` defaults
    to the simpler 1; the rows keep the comparison honest across commits
    and will show if a real accelerator target changes the answer."""
    from repro.core.biosignal import make_app, synthetic_respiration
    from repro.serve.stream import BiosignalStream, StreamConfig

    app = make_app()
    window, hop = 2048, 512
    sig, _ = synthetic_respiration(1, 512 * 120 + window, seed=4)
    raw = sig[0]
    streams = {d: BiosignalStream(app, StreamConfig(
        window=window, hop=hop, batch_windows=8, depth=d,
        outputs=("features", "margin", "class"))) for d in (1, 2)}
    t1, t2 = _paired_times([lambda: streams[1].process(raw),
                            lambda: streams[2].process(raw)], reps=7)
    us1, us2 = min(t1), min(t2)
    win = "depth2" if us2 <= us1 else "depth1"
    return [
        ("table5/stream_depth1", us1,
         "runtime end-to-end, 1 batch in flight (classic double buffer)"),
        ("table5/stream_depth2", us2,
         f"runtime end-to-end, 2 batches in flight;speedup_vs_depth1="
         f"{us1 / us2:.2f}x;winner={win} (measured within noise on CPU; "
         f"StreamConfig.depth stays 1)"),
    ]


def run():
    from repro.archsim.energy import vwr2a_energy_uj
    from repro.archsim.programs.app import run_app
    from repro.core.fir import lowpass_taps

    rng = np.random.default_rng(0)
    t = np.arange(1024) / 64.0
    sig = 0.4 * np.sin(2 * np.pi * 0.3 * t) + 0.05 * rng.standard_normal(1024)
    out = run_app(sig, lowpass_taps(11), rng.normal(size=(12, 2)) * 0.3,
                  np.zeros(2))
    rows = []
    tot_c, tot_e = 0, 0.0
    steps = ("preprocessing", "delineation", "feat_extraction", "svm")
    for step in steps:
        counters, cycles = out[step]
        e = vwr2a_energy_uj(counters)
        key = step if step != "svm" else "feat_extraction"
        tot_c += cycles
        tot_e += e
        if step == "svm":
            rows.append((f"table5/svm", cycles / F_HZ * 1e6,
                         f"sim_cycles={cycles};sim_uJ={e:.4f}"))
            continue
        cpu_c, cpu_e = PAPER_CPU[step]
        v_c, v_e = PAPER_VWR2A[step]
        rows.append((f"table5/{step}", cycles / F_HZ * 1e6,
                     f"sim_cycles={cycles};paper_vwr2a={v_c};"
                     f"cycle_savings_vs_cpu={100 * (1 - cycles / cpu_c):.1f}%"
                     f"(paper {100 * (1 - v_c / cpu_c):.1f}%);"
                     f"sim_uJ={e:.3f};"
                     f"energy_savings_vs_cpu={100 * (1 - e / cpu_e):.1f}%"))
    cpu_c, cpu_e = PAPER_CPU["total"]
    v_c, v_e = PAPER_VWR2A["total"]
    rows.append(("table5/total", tot_c / F_HZ * 1e6,
                 f"sim_cycles={tot_c};paper_vwr2a={v_c};"
                 f"cycle_savings_vs_cpu={100 * (1 - tot_c / cpu_c):.1f}%"
                 f"(paper 90.9%);sim_uJ={tot_e:.3f};"
                 f"energy_savings_vs_cpu={100 * (1 - tot_e / cpu_e):.1f}%"
                 f"(paper 66.3%)"))
    rows += _pipeline_rows()
    rows += _stream_rows()
    rows += _column_rows()
    rows += _depth_rows()
    return rows

"""End-to-end MBioTracker biosignal application (paper §4.4.2) — the
paper's own workload served by the STREAMING runtime: the RAW continuous
respiration signal is fed to the fused single-`pallas_call` pipeline in
contiguous chunks and the overlapping windows are built IN-KERNEL (the
VWR/SPM single-residency analogue — no host gather, ~1x HBM traffic),
with the filtered-window HBM write elided for classification-only
output, cross-checked against the host-framed staged app and the
cycle-accurate archsim, with a tiny SVM fit.

Run:  PYTHONPATH=src python examples/biosignal_app.py
"""
import time

import jax
import numpy as np

from repro.core.biosignal import (extract_features, make_app,
                                  svm_fit_least_squares, svm_predict,
                                  synthetic_respiration)
from repro.core.fir import fir_direct, lowpass_taps
from repro.serve.stream import BiosignalStream, StreamConfig, frame_signal

print("== generate a continuous synthetic respiration stream ==")
long_sig, _ = synthetic_respiration(1, 2048 * 40, seed=3)
long_sig = long_sig[0]

print("== stream the RAW signal through the fused pipeline kernel ==")
app = make_app()
cfg = StreamConfig(window=2048, hop=512, batch_windows=16, autotune=True,
                   outputs=("features", "margin", "class"))
stream = BiosignalStream(app, cfg)
# warm pass over a short prefix: autotune search + jit compile happen here,
# so the timed loop below measures the steady-state streaming rate
stream.process(long_sig[: 2048 * 16])
t0 = time.perf_counter()
out = stream.process(long_sig)
dt = time.perf_counter() - t0
n = out["class"].shape[0]
print(f"{long_sig.shape[0]} raw samples -> {n} overlapping windows, "
      f"{n / dt:.0f} windows/s (frames built in-kernel, one pallas_call "
      f"per {cfg.batch_windows}-window batch, double-buffered, no "
      f"filtered-window HBM write)")

print("== vs the host-framed fallback feed (gather, 4x HBM bytes) ==")
host = BiosignalStream(app, StreamConfig(
    window=2048, hop=512, batch_windows=16, autotune=True, framing="host"))
host.process(long_sig[: 2048 * 16])
t0 = time.perf_counter()
host_out = host.process(long_sig)
dt_host = time.perf_counter() - t0
print(f"host-framed: {n / dt_host:.0f} windows/s -> raw-chunk feed is "
      f"{dt_host / dt:.2f}x faster")

print("== multi-column deal: shard the dispatch across column replicas ==")
# the VWR2A column-replication analogue: hop-aligned raw chunks (+ the
# window-hop overlap halo) are dealt across 4 columns — shard_map over a
# data-axis mesh when this process has >= 4 devices (run under
# XLA_FLAGS=--xla_force_host_platform_device_count=8 to try it on a
# laptop), bit-identical serial column execution otherwise
col_cfg = StreamConfig(window=2048, hop=512, batch_windows=4, n_columns=4,
                       outputs=("features", "margin", "class"))
col_stream = BiosignalStream(app, col_cfg)
col_out = col_stream.process(long_sig)
col_err = float(abs(np.asarray(col_out["margin"]) -
                    np.asarray(out["margin"])).max())
assert col_err < 1e-4, col_err
col_mode = ("shard_map mesh" if col_stream.mesh is not None
            else "serial fallback, <4 devices")
print(f"n_columns=4 ({col_mode}): margin max|delta| = {col_err:.1e}")

print("== raw-stream == host-framed staged cross-check ==")
frames = frame_signal(long_sig, cfg.window, cfg.hop)
ref = app(frames)
err = float(abs(np.asarray(ref["margin"]) - np.asarray(out["margin"])).max())
assert err < 1e-3, err
assert sorted(out) == ["class", "features", "margin"], sorted(out)
print(f"margin max |stream - staged| = {err:.2e}")

print("== generate 64 labelled windows, preprocess + features (jit) ==")
sig, labels = synthetic_respiration(64, 2048, seed=3)
taps = lowpass_taps(11)
pipeline = jax.jit(lambda s: extract_features(fir_direct(s, taps)))
feats = pipeline(sig)
print("features:", feats.shape)

print("== fit the linear SVM head on half, evaluate on the rest ==")
w, b = svm_fit_least_squares(feats[:32], labels[:32])
_, pred = svm_predict(feats[32:], w, b)
acc = float((pred == labels[32:]).mean())
print(f"holdout accuracy: {acc:.2f} (chance 0.5)")

print("== archsim cross-check: same pipeline, cycle/energy costs ==")
from repro.archsim.energy import vwr2a_energy_uj
from repro.archsim.programs.app import run_app

out = run_app(np.asarray(sig[0]) * 0.5, taps, np.asarray(w), np.asarray(b))
total_cycles = sum(out[k][1] for k in
                   ("preprocessing", "delineation", "feat_extraction", "svm"))
total_uj = sum(vwr2a_energy_uj(out[k][0]) for k in
               ("preprocessing", "delineation", "feat_extraction", "svm"))
print(f"VWR2A: {total_cycles} cycles, {total_uj:.3f} uJ per window")
print(f"paper CPU app: 166667 cycles, 2.6 uJ  ->  "
      f"savings {100 * (1 - total_cycles / 166667):.1f}% cycles, "
      f"{100 * (1 - total_uj / 2.6):.1f}% energy (paper: 90.9% / 66.3%)")
print("biosignal app OK")

"""Deterministic sharded data pipeline.

Fault-tolerance by construction: batches are a PURE FUNCTION of
(seed, step, host_id) — a restarted or rescheduled worker regenerates its
exact shard without coordination; elastic re-sharding only changes
(host_id, num_hosts) and the indexing stays disjoint and exhaustive.

Sources: `synthetic` (hash-mixed token stream with local n-gram structure so
loss can actually decrease) or `memmap` (binary uint16/uint32 token file).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Optional

import numpy as np


def _mix(x: np.ndarray) -> np.ndarray:
    """splitmix64 — cheap stateless hash."""
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = x
    z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) \
        & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) \
        & np.uint64(0xFFFFFFFFFFFFFFFF)
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"          # synthetic | memmap
    path: Optional[str] = None
    structure: int = 97                # synthetic: n-gram period (learnable)


class ShardedLoader:
    """Yields this host's shard of the global batch for any step."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts
        self._mm = None
        if cfg.source == "memmap":
            assert cfg.path and Path(cfg.path).exists(), cfg.path
            self._mm = np.memmap(cfg.path, dtype=np.uint16, mode="r")

    def _synthetic_row(self, row_key: np.ndarray) -> np.ndarray:
        c = self.cfg
        pos = np.arange(c.seq_len + 1, dtype=np.uint64)
        h = _mix(row_key[None] ^ _mix(pos // np.uint64(c.structure)))
        # token depends on its block hash + position-in-block => learnable
        tok = (h + pos % np.uint64(c.structure)) % np.uint64(c.vocab_size)
        return tok.astype(np.int32)

    def batch(self, step: int) -> dict:
        c = self.cfg
        rows = np.arange(self.local_batch, dtype=np.uint64)
        gidx = (np.uint64(step) * np.uint64(c.global_batch)
                + np.uint64(self.host_id) * np.uint64(self.local_batch) + rows)
        if self._mm is not None:
            n = self._mm.shape[0] - (c.seq_len + 1)
            starts = (_mix(gidx ^ np.uint64(c.seed)) % np.uint64(n)).astype(
                np.int64)
            toks = np.stack([self._mm[s: s + c.seq_len + 1] for s in starts]
                            ).astype(np.int32)
        else:
            keys = _mix(gidx ^ _mix(np.full_like(gidx, c.seed)))
            toks = np.stack([self._synthetic_row(k) for k in keys])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1

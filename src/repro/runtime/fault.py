"""Fault-tolerance runtime logic: heartbeats, straggler detection, elastic
re-meshing, and a supervised step-retry loop.

Everything here is pure decision logic + a supervisor wrapper, unit-tested
at small scale; the cluster hooks (GCS heartbeat bus, pod manager API) are
the documented integration surface. The policies are the ones that matter
at 1000+ nodes:

  * heartbeat timeout => worker declared dead, elastic plan recomputed;
  * straggler = worker whose step time exceeds `straggler_factor` x the
    rolling median — persistent stragglers are evicted BEFORE they fail
    (tail-latency mitigation);
  * elastic plan keeps the model (TP) axis intact — it must match the
    sharded layer dims — and shrinks/grows the data axis to the largest
    power of two that the healthy-worker count supports;
  * recovery = restore-latest-checkpoint on the new mesh (the elastic
    reshard path of checkpoint/ckpt.py) + deterministic data replay
    (data/pipeline.py makes batches a pure function of step).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


@dataclasses.dataclass
class HeartbeatMonitor:
    timeout_s: float = 30.0
    _last: dict = dataclasses.field(default_factory=dict)

    def beat(self, worker: int, t: Optional[float] = None):
        self._last[worker] = time.monotonic() if t is None else t

    def dead(self, now: Optional[float] = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return sorted(w for w, t in self._last.items()
                      if now - t > self.timeout_s)

    def alive(self, now: Optional[float] = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return sorted(w for w, t in self._last.items()
                      if now - t <= self.timeout_s)


@dataclasses.dataclass
class StragglerDetector:
    window: int = 20
    straggler_factor: float = 2.0
    evict_after: int = 3
    _times: dict = dataclasses.field(default_factory=dict)
    _strikes: dict = dataclasses.field(default_factory=dict)

    def record(self, worker: int, step_time_s: float):
        self._times.setdefault(worker, []).append(step_time_s)
        self._times[worker] = self._times[worker][-self.window:]

    def _median_of_medians(self) -> float:
        meds = sorted(sorted(v)[len(v) // 2] for v in self._times.values()
                      if v)
        return meds[len(meds) // 2] if meds else 0.0

    def stragglers(self) -> list[int]:
        med = self._median_of_medians()
        if med <= 0:
            return []
        out = []
        for w, v in self._times.items():
            if v and sorted(v)[len(v) // 2] > self.straggler_factor * med:
                self._strikes[w] = self._strikes.get(w, 0) + 1
                if self._strikes[w] >= self.evict_after:
                    out.append(w)
            else:
                self._strikes[w] = 0
        return sorted(out)


def elastic_plan(n_healthy_chips: int, *, model_axis: int = 16,
                 pods_of: int = 256) -> dict:
    """Largest (pod, data, model) mesh the healthy chips support.

    TP ('model') stays fixed (weight shards match it); DP shrinks to the
    largest power of two; full pods are preferred (ICI locality).
    """
    assert n_healthy_chips >= model_axis
    pods = max(1, n_healthy_chips // pods_of)
    per_pod = min(n_healthy_chips // pods, pods_of)
    data = 1
    while data * 2 * model_axis <= per_pod:
        data *= 2
    return {"pod": pods, "data": data, "model": model_axis,
            "chips": pods * data * model_axis,
            "spare": n_healthy_chips - pods * data * model_axis}


@dataclasses.dataclass
class Supervisor:
    """Wraps a step function with retry + checkpoint-restore recovery."""
    save_fn: Callable        # (state, step) -> None
    restore_fn: Callable     # (step) -> state
    ckpt_every: int = 100
    max_retries: int = 3

    def run(self, state, step_fn, batches, n_steps: int, *, start_step: int = 0,
            inject_failure: Optional[Callable] = None):
        """Deterministic replay: on failure, restore the last checkpoint and
        re-run from its step. `inject_failure(step)` raising simulates a
        node loss (tests)."""
        step = start_step
        last_ckpt = start_step
        retries = 0
        metrics = None
        while step < n_steps:
            try:
                if inject_failure is not None:
                    inject_failure(step)
                state, metrics = step_fn(state, batches(step))
                step += 1
                if step % self.ckpt_every == 0:
                    self.save_fn(state, step)
                    last_ckpt = step
                    retries = 0
            except RuntimeError:
                retries += 1
                if retries > self.max_retries:
                    raise
                state = self.restore_fn(last_ckpt)
                step = last_ckpt
        return state, step, metrics

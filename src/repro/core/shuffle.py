"""The VWR2A shuffle unit (paper §3.3.1) as pure-jnp primitives.

The hardware takes VWRs A and B (128 words each), applies a hardcoded
permutation to their concatenation, and writes one VWR's worth (or selects
the upper/lower half of a 2N result) into VWR C. Four operations:

  * words interleaving        [a0,b0,a1,b1,...]            (2N -> half)
  * even / odd index pruning  keep odd / even indices of A and B  (N out)
  * bit-reversal              concat permuted by bit-reversed index (2N -> half)
  * circular shift            concat rotated up by `amount` words  (2N -> half)

All primitives operate on the LAST axis and are batched over leading axes.
These are the semantic oracles for kernels/shuffle (Pallas) and the dataflow
building blocks of core/fft.py. The TPU generalization (DESIGN.md §2): the
shift amount is a static parameter (default 32 = the paper's hardcoded value).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

HALF_LOWER = "lower"
HALF_UPPER = "upper"


def _take_half(x2n, half: str):
    n = x2n.shape[-1] // 2
    if half == HALF_LOWER:
        return x2n[..., :n]
    if half == HALF_UPPER:
        return x2n[..., n:]
    if half == "both":
        return x2n
    raise ValueError(half)


def interleave(a, b, half: str = "both"):
    """[a0,b0,a1,b1,...] — the paper's 'words interleaving'."""
    assert a.shape == b.shape
    out = jnp.stack([a, b], axis=-1).reshape(*a.shape[:-1], -1)
    return _take_half(out, half)


def prune(a, b, *, drop: str = "even"):
    """Drop even- or odd-indexed words of A and B; concat the survivors.

    drop='even' keeps odd indices (a1,a3,...,b1,b3,...); output is N words.
    """
    start = 1 if drop == "even" else 0
    return jnp.concatenate([a[..., start::2], b[..., start::2]], axis=-1)


def bit_reverse_indices(n: int) -> np.ndarray:
    bits = int(np.log2(n))
    assert 1 << bits == n, f"{n} not a power of two"
    idx = np.arange(n)
    rev = np.zeros(n, np.int64)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


def bit_reverse(a, b, half: str = "both"):
    """Bit-reversal permutation of concat(A, B)."""
    x = jnp.concatenate([a, b], axis=-1)
    rev = jnp.asarray(bit_reverse_indices(x.shape[-1]))
    return _take_half(x[..., rev], half)


def circular_shift(a, b, amount: int = 32, half: str = "both"):
    """Rotate concat(A,B) up by `amount` words (paper hardcodes 32: the upper
    32 words move to the lower 32). Generalized to any static amount."""
    x = jnp.concatenate([a, b], axis=-1)
    return _take_half(jnp.roll(x, amount, axis=-1), half)


def deinterleave(x):
    """Inverse of interleave: (..., 2N) -> even stream, odd stream."""
    return x[..., 0::2], x[..., 1::2]

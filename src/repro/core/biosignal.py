"""The MBioTracker biosignal application (paper §4.4.2) on the VWR2A core
library: preprocessing -> delineation -> feature extraction -> SVM.

Pipeline (paper §4.4.2, cognitive-workload estimation from respiration):
  1. *Preprocessing*: 11-tap FIR low-pass over the raw signal.
  2. *Delineation*: detect maxima/minima of the filtered signal to extract
     inspiration/expiration times (the control-intensive step the paper
     highlights — here vectorized into mask algebra, the JAX-native
     equivalent of VWR2A's predicated RC code).
  3. *Feature extraction*: time features (mean, median, RMS of the
     inspiration/expiration intervals) + frequency features from a
     512-point real-valued FFT of the filtered window (band powers).
  4. *Prediction*: linear SVM.

Everything is jit-able; the windowed app is a pure function of the signal.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.fft import rfft_packed
from repro.core.fir import fir_direct, lowpass_taps


# ---------------------------------------------------------------------------
# Delineation
# ---------------------------------------------------------------------------

def delineate(x, *, min_prominence: float = 0.3):
    """Detect local maxima/minima: strict neighbour extremum + amplitude
    gate (x must rise above mean + prominence*(max-mean), resp. below).

    Returns (is_max, is_min): boolean masks over the window. This is the
    paper's 'lots of if conditions' step, recast as vector predicates.
    """
    prev = jnp.roll(x, 1, axis=-1)
    nxt = jnp.roll(x, -1, axis=-1)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    hi = jnp.max(x, axis=-1, keepdims=True)
    lo = jnp.min(x, axis=-1, keepdims=True)
    is_max = (x > prev) & (x >= nxt) & (x > mu + min_prominence * (hi - mu))
    is_min = (x < prev) & (x <= nxt) & (x < mu - min_prominence * (mu - lo))
    # edges are never extrema
    edge = jnp.zeros_like(is_max).at[..., 0].set(True).at[..., -1].set(True)
    return is_max & ~edge, is_min & ~edge


def _masked_intervals(mask):
    """Mean/median/RMS of gaps between consecutive True positions (masked
    statistics, fixed shapes — jit-friendly)."""
    S = mask.shape[-1]
    pos = jnp.arange(S)
    idx = jnp.where(mask, pos, S + 1)
    sidx = jnp.sort(idx, axis=-1)
    gaps = jnp.diff(sidx, axis=-1)
    valid = (sidx[..., 1:] <= S) & (sidx[..., :-1] <= S)
    n = jnp.maximum(jnp.sum(valid, axis=-1), 1)
    g = jnp.where(valid, gaps, 0.0).astype(jnp.float32)
    mean = jnp.sum(g, axis=-1) / n
    rms = jnp.sqrt(jnp.sum(jnp.square(g), axis=-1) / n)
    # masked median: middle of the valid prefix of the sorted gap list
    gs = jnp.sort(jnp.where(valid, gaps, jnp.iinfo(jnp.int32).max), axis=-1)
    med = jnp.take_along_axis(gs, ((n - 1) // 2)[..., None], axis=-1)[..., 0]
    med = jnp.where(jnp.sum(valid, axis=-1) > 0, med, 0).astype(jnp.float32)
    return mean, med, rms


# ---------------------------------------------------------------------------
# Features + SVM
# ---------------------------------------------------------------------------

def interval_time_features(is_max, is_min) -> list:
    """The 6 time features: mean/median/RMS of the inspiration and
    expiration interval lengths (single source — also run inside the fused
    pipeline kernel)."""
    f_time = []
    for mask in (is_max, is_min):
        mean, med, rms = _masked_intervals(mask)
        f_time += [mean, med, rms]
    return f_time


def band_power_features(power, fft_size: int) -> list:
    """The 6 log-band powers over a (B, fft/2+1) power spectrum (single
    source — also run inside the fused pipeline kernel)."""
    nb = fft_size // 2 + 1
    bands = np.linspace(1, nb, 7, dtype=int)         # 6 log-ish bands
    return [jnp.log1p(jnp.sum(power[..., a:b], axis=-1))
            for a, b in zip(bands[:-1], bands[1:])]


def extract_features(filtered, fft_size: int = 512):
    """(B, S) filtered window -> (B, F) feature matrix (F = 12)."""
    is_max, is_min = delineate(filtered)
    f_time = interval_time_features(is_max, is_min)
    seg = filtered[..., :fft_size]
    seg = seg - jnp.mean(seg, axis=-1, keepdims=True)
    Xr, Xi = rfft_packed(seg)
    power = jnp.square(Xr) + jnp.square(Xi)          # (B, fft/2+1)
    return jnp.stack(f_time + band_power_features(power, fft_size), axis=-1)


def svm_predict(features, w, b):
    """Linear SVM margin + class. w: (F, C), b: (C,)."""
    margin = features @ w + b
    return margin, jnp.argmax(margin, axis=-1)


def svm_fit_least_squares(features, labels, n_classes: int = 2,
                          ridge: float = 1e-3):
    """Tiny ridge-regression 'SVM' fit (tests/examples; the paper runs a
    pre-trained SVM — the prediction path is what executes on VWR2A)."""
    F = features.shape[-1]
    y = jax.nn.one_hot(labels, n_classes) * 2 - 1
    A = features.T @ features + ridge * jnp.eye(F)
    w = jnp.linalg.solve(A, features.T @ y)
    b = jnp.mean(y - features @ w, axis=0)
    return w, b


# ---------------------------------------------------------------------------
# Full application
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BiosignalApp:
    fir_taps: np.ndarray
    svm_w: jnp.ndarray
    svm_b: jnp.ndarray
    fft_size: int = 512

    def __call__(self, signal):
        filtered = fir_direct(signal, jnp.asarray(self.fir_taps))
        feats = extract_features(filtered, self.fft_size)
        margin, cls = svm_predict(feats, self.svm_w, self.svm_b)
        return {"filtered": filtered, "features": feats,
                "margin": margin, "class": cls}


def make_app(cfg=None, seed: int = 0) -> BiosignalApp:
    from repro.configs.vwr2a_biosignal import CONFIG as BIO

    cfg = cfg or BIO
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(12, cfg.svm_classes)).astype(np.float32))
    b = jnp.zeros((cfg.svm_classes,), jnp.float32)
    return BiosignalApp(fir_taps=lowpass_taps(cfg.fir_taps),
                        svm_w=w, svm_b=b, fft_size=cfg.fft_size)


def synthetic_respiration(batch: int, samples: int, *, rate_hz: float = 0.3,
                          fs: float = 64.0, noise: float = 0.15, seed: int = 0):
    """Synthetic respiration-like signal: slow sinusoid + drift + noise."""
    rng = np.random.default_rng(seed)
    t = np.arange(samples) / fs
    rates = rate_hz * (1 + 0.3 * rng.standard_normal((batch, 1)))
    phase = rng.uniform(0, 2 * np.pi, (batch, 1))
    sig = np.sin(2 * np.pi * rates * t[None, :] + phase)
    sig += 0.2 * np.sin(2 * np.pi * 1.1 * t[None, :])     # cardiac bleed
    sig += noise * rng.standard_normal((batch, samples))
    return jnp.asarray(sig.astype(np.float32)), jnp.asarray(
        (rates[:, 0] > rate_hz).astype(np.int32))

"""Cycle-accurate VWR2A simulator + Table-3-calibrated energy model.

machine.py — N columns x (4 RCs + LSU + MXCU + LCU), 3x128-word VWRs,
32 KiB SPM, SRF, shuffle unit, q16.15 datapath (paper Fig. 1 is the
2-column default). vector.py — the NumPy-vectorized interpreter
(bit-exact vs the scalar reference path, incl. activity counters).
programs/ — generated kernel mappings (FFT §3.4, FIR §4.4.1,
MBioTracker app §4.4.2), parameterized over the column count.
"""
from repro.archsim import energy, isa, machine, vector  # noqa: F401

"""Cycle-accurate functional simulator of one VWR2A column (paper §3).

Geometry (paper):
  * SPM: 32 KiB, wide port = 4096 bit => 64 lines x 128 32-bit words
  * VWRs: A, B, C — 128 words each, single-ported, 1-cycle wide fill
  * 4 RCs x (32-bit ALU + 2-entry regfile); RC r owns VWR slice
    [32r, 32(r+1)); all RCs share the MXCU word index k (paper §3.3.2)
  * SRF: 8 x 32-bit
  * fixed-point 16.15 single-cycle multiply (FXMUL)
  * shuffle unit: C <- op(A, B) (paper §3.3.1)

The machine executes real arithmetic (int32 wraparound / q16.15) so kernel
programs produce checkable numerics; every cycle increments activity
counters consumed by the Table-3-calibrated energy model (energy.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.archsim.isa import SlotWord

VWR_WORDS = 128
SPM_LINES = 64                  # 64 x 128 words x 4 B = 32 KiB
RC_SLICE = VWR_WORDS // 4       # 32 words per RC
Q15 = 15

_I32_MASK = np.int64(0xFFFFFFFF)


def _wrap32(x) -> np.int64:
    x = np.int64(x) & _I32_MASK
    return np.int64(x - (np.int64(1) << 32)) if x >= (np.int64(1) << 31) else np.int64(x)


def to_q15(x: float) -> int:
    return int(np.clip(round(x * (1 << Q15)), -(1 << 31), (1 << 31) - 1))


def to_q15_arr(x) -> np.ndarray:
    """Vectorized to_q15: bit-identical (incl. round-half-to-even)."""
    q = np.round(np.asarray(x, np.float64) * (1 << Q15))
    return np.clip(q, -(1 << 31), (1 << 31) - 1).astype(np.int64)


def split_work(total: int, n_parts: int) -> list:
    """Deal `total` units over `n_parts` columns, remainder to the first
    columns — per-column host-side cycle charges must conserve the total
    for ANY column count (the energy model integrates activity)."""
    base, rem = divmod(total, n_parts)
    return [base + (i < rem) for i in range(n_parts)]


def from_q15(x) -> float:
    return float(np.int64(x)) / (1 << Q15)


@dataclasses.dataclass
class Counters:
    cycles: int = 0
    rc_ops: int = 0
    rc_mults: int = 0
    vwr_reads: int = 0
    vwr_writes: int = 0
    spm_line_reads: int = 0
    spm_line_writes: int = 0
    srf_accesses: int = 0
    shuffles: int = 0
    dma_words: int = 0

    def merged(self, o: "Counters") -> "Counters":
        return Counters(**{f.name: getattr(self, f.name) + getattr(o, f.name)
                           for f in dataclasses.fields(Counters)})


class Column:
    """One VWR2A column: shared PC, 4 RCs, LSU, MXCU, LCU, 3 VWRs."""

    def __init__(self, spm: np.ndarray, srf: np.ndarray):
        self.spm = spm                        # (SPM_LINES, VWR_WORDS) int64
        self.srf = srf                        # (8,) int64 (shared)
        self.vwr = {n: np.zeros(VWR_WORDS, np.int64) for n in "ABC"}
        self.rc_regs = np.zeros((4, 2), np.int64)
        self.rc_last = np.zeros(4, np.int64)  # previous-cycle results
        self.lcu_regs = np.zeros(4, np.int64)
        self.k = 0                            # MXCU word index within slice
        self.pc = 0
        self.counters = Counters()
        self.halted = False

    # ---- operand resolution ----
    def _read(self, rc_idx: int, src, new_last) -> np.int64:
        kind = src[0]
        if kind == "zero":
            return np.int64(0)
        if kind == "imm":
            return np.int64(src[1])
        if kind == "reg":
            return self.rc_regs[rc_idx, src[1]]
        if kind == "srf":
            self.counters.srf_accesses += 1
            return self.srf[src[1]]
        if kind == "rc":
            return self.rc_last[(rc_idx + src[1]) % 4]
        if kind == "vwr":
            # ("vwr", name[, offset]): word (rc*32 + k + offset) of the VWR.
            # Non-zero offsets may cross RC slices — the paper's mux network
            # with SRF-held "masking values for the VWRs index computation"
            # (§3.2); modeling note in DESIGN.md.
            off = src[2] if len(src) > 2 else 0
            self.counters.vwr_reads += 1
            return self.vwr[src[1]][(rc_idx * RC_SLICE + self.k + off)
                                    % VWR_WORDS]
        if kind == "win":
            # ("win", offset): virtual 256-word window concat(B, A) indexed
            # at 128 + rc*32 + k + offset — boundary words for FIR/conv
            self.counters.vwr_reads += 1
            g = VWR_WORDS + rc_idx * RC_SLICE + self.k + src[1]
            cat = self.vwr["B"] if g < VWR_WORDS else self.vwr["A"]
            return cat[g % VWR_WORDS]
        raise ValueError(src)

    def _alu(self, op: str, a: np.int64, b: np.int64) -> np.int64:
        if op in ("NOP", "MOV"):
            return a
        if op == "ADD":
            return _wrap32(a + b)
        if op == "SUB":
            return _wrap32(a - b)
        if op == "MUL":
            return _wrap32(a * b)
        if op == "FXMUL":      # q16.15: drop 15 LSBs, keep next 32 (paper §3.1)
            return _wrap32((np.int64(a) * np.int64(b)) >> Q15)
        if op == "SLL":
            return _wrap32(a << (b & 31))
        if op == "SRL":
            return _wrap32((np.int64(a) & _I32_MASK) >> (b & 31))
        if op == "SRA":
            return _wrap32(np.int64(a) >> (b & 31))
        if op == "AND":
            return np.int64(a) & np.int64(b)
        if op == "OR":
            return np.int64(a) | np.int64(b)
        if op == "XOR":
            return np.int64(a) ^ np.int64(b)
        if op == "MAX":
            return np.int64(max(a, b))
        if op == "MIN":
            return np.int64(min(a, b))
        raise ValueError(op)

    # ---- per-cycle slot execution ----
    def step(self, word: SlotWord):
        c = self.counters
        c.cycles += 1

        # MXCU first (paper: k addresses this cycle's VWR accesses)
        mx = word.mxcu
        if mx.op == "SETK":
            self.k = mx.k
        elif mx.op == "INCK":
            self.k = (self.k + 1) % RC_SLICE
        elif mx.op == "ADDK":
            self.k = (self.k + mx.k) % RC_SLICE

        # RCs
        new_last = self.rc_last.copy()
        for i, rc in enumerate(word.rcs):
            if rc.op == "NOP":
                continue
            a = self._read(i, rc.a, new_last)
            b = self._read(i, rc.b, new_last)
            r = self._alu(rc.op, a, b)
            c.rc_ops += 1
            if rc.op in ("MUL", "FXMUL"):
                c.rc_mults += 1
            new_last[i] = r
            if rc.dest is not None:
                d = rc.dest
                if d[0] == "reg":
                    self.rc_regs[i, d[1]] = r
                elif d[0] == "vwr":
                    off = d[2] if len(d) > 2 else 0
                    self.vwr[d[1]][(i * RC_SLICE + self.k + off)
                                   % VWR_WORDS] = r
                    c.vwr_writes += 1
                elif d[0] == "srf":
                    self.srf[d[1]] = r
                    c.srf_accesses += 1
        self.rc_last = new_last

        # LSU
        ls = word.lsu
        if ls.op != "NOP":
            if ls.op in ("LOAD", "STORE"):
                addr = int(self.srf[ls.addr[1]] if ls.addr[0] == "srf"
                           else ls.addr[1]) % SPM_LINES
                if ls.op == "LOAD":
                    self.vwr[ls.vwr][:] = self.spm[addr]
                    c.spm_line_reads += 1
                    c.vwr_writes += VWR_WORDS // VWR_WORDS  # 1 wide fill
                else:
                    self.spm[addr] = self.vwr[ls.vwr]
                    c.spm_line_writes += 1
                    c.vwr_reads += 1
            elif ls.op == "SHUFFLE":
                a, b = self.vwr["A"], self.vwr["B"]
                cat = np.concatenate([a, b])
                op = ls.shuffle_op
                if op == "interleave":
                    out = np.stack([a, b], axis=1).reshape(-1)
                elif op == "prune_even":
                    out = np.concatenate([a[1::2], b[1::2], a[1::2], b[1::2]])
                elif op == "prune_odd":
                    out = np.concatenate([a[0::2], b[0::2], a[0::2], b[0::2]])
                elif op == "bit_reverse":
                    n = cat.shape[0]
                    bits = int(np.log2(n))
                    idx = np.arange(n)
                    rev = np.zeros(n, np.int64)
                    for bb in range(bits):
                        rev |= ((idx >> bb) & 1) << (bits - 1 - bb)
                    out = cat[rev]
                elif op == "circular_shift":
                    out = np.roll(cat, 32)
                else:
                    raise ValueError(op)
                half = out[:VWR_WORDS] if ls.half == "lower" else out[VWR_WORDS:]
                self.vwr["C"][:] = half
                c.shuffles += 1
                c.vwr_reads += 2
                c.vwr_writes += 1
            elif ls.op == "LOAD_SRF":
                addr = int(ls.addr[1]) % (SPM_LINES * VWR_WORDS)
                self.srf[ls.vwr if isinstance(ls.vwr, int) else 0] = \
                    self.spm[addr // VWR_WORDS, addr % VWR_WORDS]
                c.srf_accesses += 1

        # LCU last (controls next PC)
        lc = word.lcu
        next_pc = self.pc + 1
        if lc.op == "SETI":
            self.lcu_regs[lc.reg] = lc.val
        elif lc.op == "ADDI":
            self.lcu_regs[lc.reg] = _wrap32(self.lcu_regs[lc.reg] + lc.val)
        elif lc.op == "BLT":
            if self.lcu_regs[lc.reg] < lc.val:
                next_pc = lc.target
        elif lc.op == "BGE":
            if self.lcu_regs[lc.reg] >= lc.val:
                next_pc = lc.target
        elif lc.op == "JUMP":
            next_pc = lc.target
        elif lc.op == "EXIT":
            self.halted = True
        self.pc = next_pc


class VWR2A:
    """N columns + shared SPM/SRF + DMA counter.  The paper's Fig. 1
    instance is ``n_columns=2`` (the default); the machine is
    parameterized the way Ara scales vector lanes / STRELA scales CGRA
    columns, so kernel mappings can sweep column counts.

    ``engine`` selects the interpreter: ``"vector"`` (default) runs
    straight-line k-sweep programs as NumPy array ops over all 4 RCs x
    sweep instances at once (bit-exact counters and numerics, see
    vector.py); ``"scalar"`` forces the word-at-a-time reference path.
    """

    def __init__(self, n_columns: int = 2, engine: str = "vector"):
        assert n_columns >= 1
        assert engine in ("vector", "scalar"), engine
        self.spm = np.zeros((SPM_LINES, VWR_WORDS), np.int64)
        self.srf = np.zeros(8, np.int64)
        self.cols = [Column(self.spm, self.srf) for _ in range(n_columns)]
        self.engine = engine

    @property
    def n_columns(self) -> int:
        return len(self.cols)

    def dma_in(self, line: int, words: np.ndarray):
        """System memory -> SPM (word-granular DMA, counted per word)."""
        n = words.shape[0]
        self.spm.reshape(-1)[line * VWR_WORDS: line * VWR_WORDS + n] = words
        self.cols[0].counters.dma_words += n

    def dma_out(self, line: int, n: int) -> np.ndarray:
        self.cols[0].counters.dma_words += n
        return self.spm.reshape(-1)[line * VWR_WORDS: line * VWR_WORDS + n].copy()

    def run(self, programs, max_cycles: int = 1_000_000,
            engine: str | None = None):
        """programs: list of per-column instruction lists (SlotWords).
        Shorter lists are padded with empty programs."""
        programs = list(programs)
        assert len(programs) <= len(self.cols), "more programs than columns"
        programs += [[] for _ in range(len(self.cols) - len(programs))]

        engine = engine or self.engine
        active = [(c, p) for c, p in zip(self.cols, programs) if p]
        # The vectorized path reorders execution within one column; with
        # two or more concurrently-active columns the scalar lockstep
        # interleaving over shared SPM/SRF must be preserved exactly, so
        # only single-active-column runs (the shape every generated
        # kernel pass uses) take the fast path.
        if engine == "vector" and len(active) == 1:
            from repro.archsim import vector

            col, prog = active[0]
            if len(prog) <= max_cycles:
                items = vector.compile_program(prog)
                if items is not None:
                    for c, p in zip(self.cols, programs):
                        c.pc = 0
                        c.halted = not p
                    vector.run_compiled(col, prog, items)
                    return self.counters()

        for col, prog in zip(self.cols, programs):
            col.pc = 0
            col.halted = not prog
        cycles = 0
        while cycles < max_cycles:
            live = False
            for col, prog in zip(self.cols, programs):
                if col.halted:
                    continue
                if col.pc >= len(prog):
                    col.halted = True
                    continue
                col.step(prog[col.pc])
                live = live or not col.halted
            cycles += 1
            if not live:
                break
        return self.counters()

    def counters(self) -> Counters:
        out = Counters()
        for col in self.cols:
            out = out.merged(col.counters)
        return out

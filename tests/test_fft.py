"""FFT core + Pallas kernel: numpy oracle sweeps + spectral properties."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import fft as F
from repro.kernels.fft.ops import fft as kfft, rfft as krfft


@pytest.mark.parametrize("n", [8, 64, 256, 1024])
@pytest.mark.parametrize("variant", ["stockham", "bitrev"])
def test_core_fft_vs_numpy(n, variant, rng):
    x = (rng.normal(size=(3, n)) + 1j * rng.normal(size=(3, n))).astype(
        np.complex64)
    fn = F.fft if variant == "stockham" else F.fft_bitrev
    rr, ri = fn(jnp.asarray(x.real), jnp.asarray(x.imag))
    ref = np.fft.fft(x)
    err = np.abs((np.asarray(rr) + 1j * np.asarray(ri)) - ref).max()
    assert err / np.abs(ref).max() < 1e-4


@pytest.mark.parametrize("n", [64, 512])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_fft_shapes_dtypes(n, dtype, rng):
    x = (rng.normal(size=(8, n)) + 1j * rng.normal(size=(8, n)))
    re = jnp.asarray(x.real).astype(dtype)
    im = jnp.asarray(x.imag).astype(dtype)
    rr, ri = kfft(re, im)
    assert rr.shape == (8, n) and rr.dtype == dtype
    ref = np.fft.fft(np.asarray(re, np.float32)
                     + 1j * np.asarray(im, np.float32))
    tol = 1e-4 if dtype == jnp.float32 else 0.05
    err = np.abs((np.asarray(rr, np.float64) + 1j * np.asarray(ri, np.float64))
                 - ref).max() / np.abs(ref).max()
    assert err < tol, err


def test_kernel_ifft_roundtrip(rng):
    x = rng.normal(size=(4, 256)).astype(np.float32)
    rr, ri = kfft(jnp.asarray(x), jnp.zeros_like(jnp.asarray(x)))
    br, bi = kfft(rr, ri, inverse=True)
    np.testing.assert_allclose(np.asarray(br), x, atol=2e-5)
    np.testing.assert_allclose(np.asarray(bi), 0, atol=2e-5)


@pytest.mark.parametrize("n", [64, 512, 2048])
def test_rfft_packed(n, rng):
    x = rng.normal(size=(2, n)).astype(np.float32)
    for impl in (F.rfft_packed, krfft):
        Rr, Ri = impl(jnp.asarray(x))
        ref = np.fft.rfft(x)
        err = np.abs((np.asarray(Rr) + 1j * np.asarray(Ri)) - ref).max()
        assert err / np.abs(ref).max() < 1e-4


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 9), st.integers(0, 2 ** 31 - 1))
def test_fft_linearity(logn, seed):
    n = 1 << logn
    r = np.random.default_rng(seed)
    a = r.normal(size=n).astype(np.float32)
    b = r.normal(size=n).astype(np.float32)
    fa = F.fft(jnp.asarray(a))
    fb = F.fft(jnp.asarray(b))
    fab = F.fft(jnp.asarray(2 * a + 3 * b))
    np.testing.assert_allclose(np.asarray(fab[0]),
                               2 * np.asarray(fa[0]) + 3 * np.asarray(fb[0]),
                               atol=1e-3 * max(1, np.abs(fab[0]).max()))


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 9), st.integers(0, 2 ** 31 - 1))
def test_fft_parseval(logn, seed):
    n = 1 << logn
    r = np.random.default_rng(seed)
    x = r.normal(size=n).astype(np.float32)
    rr, ri = F.fft(jnp.asarray(x))
    e_time = float(np.sum(x ** 2))
    e_freq = float(np.sum(np.asarray(rr) ** 2 + np.asarray(ri) ** 2)) / n
    assert abs(e_time - e_freq) < 1e-2 * max(1.0, e_time)


def test_fft_impulse():
    x = np.zeros(128, np.float32)
    x[0] = 1.0
    rr, ri = F.fft(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(rr), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ri), 0.0, atol=1e-5)

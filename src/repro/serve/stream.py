"""Streaming window runtime: continuous biosignal traffic through the fused
pipeline kernel.

The paper's deployment model (§4.4.2) is a sensor feeding windows to the
accelerator forever; ours is the serving analogue. The default feed is
ZERO-COPY: the runtime hands the kernel contiguous RAW signal chunks and the
kernel builds the overlapping (window, hop) frames in VMEM itself
(`kernels/pipeline.pipeline_stream_pallas`) — no host gather, no duplicated
overlap bytes in HBM, no materialized zero-padding frames for the tail
batch. The pre-framed path (`framing="host"`) is kept as the fallback and
cross-check reference. Dispatch is pipelined: while batch k's outputs are
being consumed on the host, up to `depth` later batches are already in
flight (JAX async dispatch is the host-side ping-pong buffer, mirroring the
SPM's double-buffered line fills; depth=2 measured WITHIN NOISE of the
depth=1 double buffer on the CPU interpret path — ±4% across trials, see
table5/stream_depth* rows — so the default stays 1 and the knob is there
for real accelerators with wider dispatch gaps). An ``outputs``
selection drops unrequested HBM writes — classification-only traffic never
writes filtered windows — and the kernel row-block can be autotuned from
measured candidates (`core/autotune.py`) instead of the static VWRSpec
formula.

MULTI-COLUMN: ``n_columns > 1`` is the VWR2A column-replication analogue
for this path (archsim deals passes round-robin across columns; we deal
hop-aligned raw chunks across devices). Each dispatch covers
``batch_windows`` frames PER COLUMN, `shard_map`ped over the `data` axis of
a local mesh when the process has >= n_columns devices (on a laptop/CI box:
run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``), and
falls back to bit-identical serial column execution otherwise. Independent
streams can instead be pinned to distinct columns via ``device=`` — that is
what `serve.engine.ColumnScheduler` hands out.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.biosignal import BiosignalApp, make_app
from repro.kernels.pipeline.kernel import empty_outputs
from repro.kernels.pipeline.ops import (OUTPUTS, app_pipeline,
                                        app_pipeline_stream,
                                        canonical_outputs,
                                        stream_frame_count)


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    window: int = 2048          # samples per frame (the processing window)
    hop: int = 512              # frame stride; < window => overlapping frames
    batch_windows: int = 8      # frames per fused-kernel dispatch PER COLUMN
    autotune: bool = False      # measure the kernel row-block (cached)
    block_rows: int | None = None   # pin the row-block explicitly
    outputs: tuple = OUTPUTS    # which app outputs to compute/write
    framing: str = "kernel"     # "kernel": raw chunks, frames built in VMEM
    #                             "host": gather-framed fallback/reference
    n_columns: int = 1          # column replicas a dispatch is dealt across
    depth: int = 1              # max in-flight batches (1 = classic double
    #                             buffer, the measured CPU winner; 2+ for
    #                             accelerators with wider dispatch gaps)


# single source of the framing arithmetic (shared with the kernel, whose
# trim logic depends on the same count)
frame_count = stream_frame_count


def frame_signal(signal, window: int, hop: int):
    """(S,) continuous signal -> (n_frames, window) overlapping frames.

    Host-side gather: every sample is duplicated ~window/hop times. Kept
    for the `framing="host"` fallback and as the reference the raw-chunk
    kernel path is tested against."""
    sig = jnp.asarray(signal)
    assert sig.ndim == 1, sig.shape
    n = frame_count(sig.shape[0], window, hop)
    if n == 0:
        return jnp.zeros((0, window), sig.dtype)
    idx = np.arange(n)[:, None] * hop + np.arange(window)[None, :]
    return sig[jnp.asarray(idx)]


def column_mesh(n_columns: int):
    """A `data`-axis mesh over the first n_columns local devices, or None
    when the process doesn't have that many (the sharded entry then runs
    its bit-identical serial-column fallback)."""
    if n_columns <= 1 or len(jax.devices()) < n_columns:
        return None
    from repro.launch.mesh import make_local_mesh

    return make_local_mesh(data=n_columns)


class BiosignalStream:
    """Drives a continuous signal through the fused pipeline kernel in
    pipelined window batches (up to `cfg.depth` in flight).

    >>> stream = BiosignalStream(make_app(), StreamConfig(hop=256))
    >>> out = stream.process(signal)          # dict over all frames

    ``device`` pins every dispatch of THIS stream to one device (column) —
    how the serving layer places independent streams on distinct columns —
    and is mutually exclusive with ``cfg.n_columns > 1`` (which spreads
    each dispatch of one stream across all columns).
    """

    def __init__(self, app: BiosignalApp | None = None,
                 cfg: StreamConfig | None = None, *, device=None):
        self.app = app or make_app()
        cfg = cfg or StreamConfig()
        self.cfg = dataclasses.replace(
            cfg, outputs=canonical_outputs(cfg.outputs))
        assert self.cfg.window >= self.app.fft_size, (
            self.cfg.window, self.app.fft_size)
        assert 0 < self.cfg.hop <= self.cfg.window
        assert self.cfg.batch_windows > 0
        assert self.cfg.framing in ("kernel", "host"), self.cfg.framing
        assert self.cfg.n_columns >= 1
        assert self.cfg.depth >= 1
        assert device is None or self.cfg.n_columns == 1, \
            "pin a stream to one column OR shard it across columns, not both"
        self.device = device
        self.mesh = column_mesh(self.cfg.n_columns)

    @property
    def dispatch_windows(self) -> int:
        """Frames per dispatch across all columns."""
        return self.cfg.batch_windows * self.cfg.n_columns

    @property
    def chunk_samples(self) -> int:
        """Raw samples per kernel-framed dispatch: one batch's span."""
        cfg = self.cfg
        return (self.dispatch_windows - 1) * cfg.hop + cfg.window

    def _place(self, x):
        return x if self.device is None else jax.device_put(x, self.device)

    def _dispatch_chunk(self, chunk):
        """Raw-chunk dispatch: the kernel does the framing in VMEM."""
        cfg = self.cfg
        return app_pipeline_stream(self.app, self._place(chunk),
                                   window=cfg.window, hop=cfg.hop,
                                   block_frames=cfg.block_rows,
                                   autotune=cfg.autotune,
                                   outputs=cfg.outputs,
                                   n_columns=cfg.n_columns, mesh=self.mesh)

    def _dispatch_frames(self, frames):
        """Pre-framed dispatch (fallback/reference path)."""
        return app_pipeline(self.app, self._place(frames),
                            block_rows=self.cfg.block_rows,
                            autotune=self.cfg.autotune,
                            outputs=self.cfg.outputs,
                            n_columns=self.cfg.n_columns, mesh=self.mesh)

    def _batches(self, signal) -> Iterator[tuple]:
        """(in-flight output dict, n valid frames) per window batch."""
        cfg = self.cfg
        sig = jnp.asarray(signal)
        n = frame_count(sig.shape[0], cfg.window, cfg.hop)
        bw = self.dispatch_windows
        if cfg.framing == "host":
            frames = frame_signal(sig, cfg.window, cfg.hop)
            for start in range(0, n, bw):
                batch = frames[start: start + bw]
                valid = batch.shape[0]
                if valid < bw:      # pad the tail batch to the fixed shape
                    batch = jnp.concatenate(
                        [batch, jnp.zeros((bw - valid, cfg.window),
                                          batch.dtype)], axis=0)
                yield self._dispatch_frames(batch), valid
            return
        # raw-chunk feed: batch k's frames live in one contiguous slice of
        # the signal — no gather, and the tail batch (frames % (bw*D) != 0)
        # pads with at most chunk_samples raw zeros instead of bw-valid
        # whole zero frames; the sharded entry trims the pad columns
        span = self.chunk_samples
        for start in range(0, n, bw):
            s0 = start * cfg.hop
            chunk = sig[s0: s0 + span]
            if chunk.shape[0] < span:
                chunk = jnp.concatenate(
                    [chunk, jnp.zeros((span - chunk.shape[0],), sig.dtype)])
            yield self._dispatch_chunk(chunk), min(bw, n - start)

    def stream(self, signal) -> Iterator[dict]:
        """Yields one output dict per window batch (trimmed to the real
        frames). Up to `cfg.depth` later batches are dispatched before
        batch k is yielded, so the consumer always overlaps with
        `depth` in-flight batches (depth=1 is the classic double buffer:
        consume k while k+1 runs)."""
        inflight: deque[tuple[dict, int]] = deque()
        for nxt in self._batches(signal):       # async: in flight now
            inflight.append(nxt)
            if len(inflight) > self.cfg.depth:
                yield self._collect(*inflight.popleft())
        while inflight:
            yield self._collect(*inflight.popleft())

    @staticmethod
    def _collect(out: dict, valid: int) -> dict:
        out = jax.block_until_ready(out)
        return {k: v[:valid] for k, v in out.items()}

    def _empty(self, dtype) -> dict:
        """Zero-frame result: same keys/shapes/dtypes as the kernel path."""
        w = self.app.svm_w.shape
        return empty_outputs(self.cfg.window, w[0], w[1], dtype,
                             self.cfg.outputs)

    def process(self, signal) -> dict:
        """One-call convenience: all framed outputs concatenated, equal to
        running the app on `frame_signal(signal, window, hop)` at once."""
        chunks = list(self.stream(signal))
        if not chunks:
            return self._empty(jnp.asarray(signal).dtype)
        return {k: jnp.concatenate([c[k] for c in chunks], axis=0)
                for k in chunks[0]}

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — tests and benches must see the real
# (single-CPU) device set; only launch/dryrun.py forces 512 host devices.


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)

"""Per-request sampling-stream determinism — the invariant replay rests on.

`serve/engine.py` derives each sampled token from
``fold_in(fold_in(PRNGKey(seed), rid), step_within_request)`` (see
`_sample_per_request`), so a request's token sequence is a pure function
of (seed, rid, prompt, model) — NOT of which slot it ran on, who its
co-tenants were, or how the global step counter advanced. These property
sweeps pin exactly that: identical output across slot placements,
co-tenant mixes, submission orders, and slot counts, for greedy AND
temperature sampling. `tests/test_engine_fault.py` then leans on it to
demand bit-identical recovery under chaos.
"""
import dataclasses

import jax
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model, init_model_params
from repro.serve.engine import Engine, Request


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(reduced(get_config("qwen1.5-0.5b")),
                              vocab_size=64)
    model = build_model(cfg)
    params = init_model_params(model, seed=3)
    compiled = Engine.compile_model(model)
    return model, params, compiled


PROMPTS = {0: [3, 1, 4, 1], 1: [5, 9, 2], 2: [6, 5], 3: [8, 9, 7, 9, 3],
           4: [2, 3], 5: [4, 6, 2, 6]}


def _serve(setup, rids, *, slots, temperature, seed=7, max_new=5,
           order=None):
    model, params, compiled = setup
    eng = Engine(model, params, slots=slots, max_len=64,
                 temperature=temperature, seed=seed, compiled=compiled)
    for rid in (order if order is not None else rids):
        eng.submit(Request(rid, list(PROMPTS[rid]), max_new=max_new))
    done = eng.run_to_completion(max_steps=500)
    assert sorted(r.rid for r in done) == sorted(rids)
    return {r.rid: tuple(r.out) for r in done}


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_output_invariant_to_slot_count(setup, temperature):
    """Same requests on 1, 2, and 4 slots: placement changes (which slot,
    which decode batch, which prefill bucket co-tenants), tokens don't."""
    rids = [0, 1, 2, 3]
    ref = _serve(setup, rids, slots=4, temperature=temperature)
    for slots in (1, 2, 3):
        assert _serve(setup, rids, slots=slots,
                      temperature=temperature) == ref


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_output_invariant_to_cotenants(setup, temperature):
    """Request 1's tokens are identical served alone, with one co-tenant,
    and in a full house — co-tenant traffic must not perturb the stream
    (the shared-sequential-RNG failure mode this design removed)."""
    alone = _serve(setup, [1], slots=2, temperature=temperature)[1]
    pair = _serve(setup, [1, 4], slots=2, temperature=temperature)[1]
    crowd = _serve(setup, [0, 1, 2, 3, 4, 5], slots=2,
                   temperature=temperature)[1]
    assert alone == pair == crowd


def test_output_invariant_to_submission_order(setup):
    """Admission order permutes slot placement and batch composition;
    per-request keys make the outputs order-independent."""
    rids = [0, 1, 2, 3, 4, 5]
    ref = _serve(setup, rids, slots=2, temperature=0.9)
    perm = _serve(setup, rids, slots=2, temperature=0.9,
                  order=[5, 2, 0, 4, 1, 3])
    assert perm == ref


def test_seed_and_rid_separate_streams(setup):
    """Different seeds give different tokens (the sampler really samples);
    the same prompt under different rids draws from independent streams."""
    a = _serve(setup, [0, 1], slots=2, temperature=1.0, seed=7)
    b = _serve(setup, [0, 1], slots=2, temperature=1.0, seed=8)
    assert a != b
    model, params, compiled = setup
    eng = Engine(model, params, slots=2, max_len=64, temperature=1.0,
                 seed=7, compiled=compiled)
    eng.submit(Request(10, [3, 1, 4, 1], max_new=8))
    eng.submit(Request(11, [3, 1, 4, 1], max_new=8))
    done = {r.rid: tuple(r.out) for r in eng.run_to_completion()}
    assert done[10] != done[11]


def test_per_request_stream_is_key_exact(setup):
    """The engine's sampled tokens match a hand-rolled fold_in chain over
    the same logits — pins the key derivation itself, not just
    consistency between two engine runs."""
    model, params, compiled = setup
    temperature = 0.8
    eng = Engine(model, params, slots=1, max_len=64,
                 temperature=temperature, seed=7, compiled=compiled)
    rid, prompt = 42, [3, 1, 4, 1, 5]
    eng.submit(Request(rid, list(prompt), max_new=4))
    out = eng.run_to_completion()[0].out

    import numpy as np

    from repro.models.api import init_cache
    prefill, decode = compiled
    cache = init_cache(model, 1, 64)
    toks = np.zeros((1, len(prompt)), np.int32)
    toks[0] = prompt
    _, cache = prefill(params, {"tokens": jax.numpy.asarray(toks)}, cache)
    base = jax.random.PRNGKey(7)
    seq = list(prompt)
    expect = []
    for step in range(4):
        # the engine re-feeds the sequence's LAST token at cache position
        # len(seq)-1, then samples on the request's own key stream
        batch = {"tokens": np.array([[seq[-1]]], np.int32),
                 "cache_len": np.array([len(seq) - 1], np.int32)}
        logits, cache = decode(params, batch, cache)
        k = jax.random.fold_in(jax.random.fold_in(base, rid), step)
        tok = int(jax.random.categorical(k, logits[0, 0, :] / temperature))
        expect.append(tok)
        seq.append(tok)
    assert list(out) == expect


@pytest.mark.parametrize("temperature", [0.0, 0.8])
@pytest.mark.parametrize("page_size,slots", [(4, 2), (16, 3)])
def test_paged_engine_joins_the_invariant(setup, temperature, page_size,
                                          slots):
    """The paged engine is a fourth placement axis: page size and lane
    count change which page backs which token, never the tokens. Same
    per-request streams, same outputs as the dense sweep's reference."""
    from repro.serve.engine import PagedEngine
    model, params, compiled = setup
    rids = [0, 1, 2, 3]
    ref = _serve(setup, rids, slots=4, temperature=temperature)
    eng = PagedEngine(model, params, slots=slots, max_len=64,
                      temperature=temperature, seed=7, compiled=compiled,
                      page_size=page_size)
    for rid in rids:
        eng.add_request(Request(rid, list(PROMPTS[rid]), max_new=5))
    done = eng.run_to_completion(max_steps=500)
    assert {r.rid: tuple(r.out) for r in done} == ref

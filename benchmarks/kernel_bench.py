"""Pallas-kernel micro-benchmarks: wall time of each kernel (interpret mode
on CPU — structural check; real perf is the TPU target) vs its jnp oracle,
plus the blockwise-attention path vs the O(S^2) reference."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, reps: int = 5) -> float:
    jax.block_until_ready(fn(*args))     # one warmup/compile call
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)

    from repro.kernels.shuffle.ops import shuffle, shuffle_ref
    a = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
    us_k = _time(lambda: shuffle(a, b, "interleave"))
    us_r = _time(lambda: shuffle_ref(a, b, "interleave"))
    rows.append(("kernel/shuffle_interleave_256x128", us_k,
                 f"oracle_us={us_r:.0f}"))

    from repro.kernels.fft.ops import fft as kfft
    from repro.core.fft import fft as cfft
    re = jnp.asarray(rng.normal(size=(32, 512)).astype(np.float32))
    im = jnp.asarray(rng.normal(size=(32, 512)).astype(np.float32))
    us_k = _time(lambda: kfft(re, im))
    us_r = _time(lambda: cfft(re, im))
    rows.append(("kernel/fft_32x512", us_k, f"oracle_us={us_r:.0f}"))

    from repro.kernels.fir.ops import fir as kfir
    from repro.core.fir import fir_direct, lowpass_taps
    x = jnp.asarray(rng.normal(size=(16, 4096)).astype(np.float32))
    taps = jnp.asarray(lowpass_taps(11))
    us_k = _time(lambda: kfir(x, taps))
    us_r = _time(lambda: fir_direct(x, taps))
    rows.append(("kernel/fir_16x4096_11tap", us_k, f"oracle_us={us_r:.0f}"))

    from repro.kernels.rope.ops import rope as krope
    from repro.kernels.rope.ref import rope_ref
    xr = jnp.asarray(rng.normal(size=(2048, 128)).astype(np.float32))
    pos = jnp.asarray(np.arange(2048) % 512, dtype=jnp.int32)
    us_k = _time(lambda: krope(xr, pos))
    us_r = _time(lambda: rope_ref(xr, pos))
    rows.append(("kernel/rope_2048x128", us_k, f"oracle_us={us_r:.0f}"))

    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_ref
    qf = jnp.asarray(rng.normal(size=(2, 512, 8, 64)).astype(np.float32))
    kf = jnp.asarray(rng.normal(size=(2, 512, 4, 64)).astype(np.float32))
    vf = jnp.asarray(rng.normal(size=(2, 512, 4, 64)).astype(np.float32))
    us_k = _time(lambda: flash_attention(qf, kf, vf, q_chunk=128,
                                         kv_chunk=128))
    us_r = _time(lambda: flash_ref(qf, kf, vf))
    rows.append(("kernel/flash_attn_B2_S512", us_k, f"oracle_us={us_r:.0f}"))

    from benchmarks.table5_app import _paired_best
    from repro.core.biosignal import make_app, synthetic_respiration
    from repro.kernels.pipeline.ops import app_pipeline
    from repro.kernels.pipeline.ref import staged_kernel_fns
    app = make_app()
    sig, _ = synthetic_respiration(32, 2048, seed=0)
    staged = staged_kernel_fns(app.fir_taps, app.svm_w, app.svm_b,
                               fft_size=app.fft_size)
    us_k, us_r = _paired_best([lambda: app_pipeline(app, sig),
                               lambda: staged(sig)], reps=5)
    rows.append(("kernel/pipeline_fused_32x2048", us_k,
                 f"staged_us={us_r:.0f};speedup={us_r / us_k:.2f}x"))

    from repro.models.attention import blockwise_attention, reference_attention
    q = jnp.asarray(rng.normal(size=(2, 512, 8, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 512, 4, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 512, 4, 64)).astype(np.float32))
    f_blk = jax.jit(lambda q, k, v: blockwise_attention(
        q, k, v, causal=True, q_chunk=128, kv_chunk=128))
    f_ref = jax.jit(lambda q, k, v: reference_attention(q, k, v, causal=True))
    us_k = _time(lambda: f_blk(q, k, v))
    us_r = _time(lambda: f_ref(q, k, v))
    rows.append(("model/blockwise_attn_B2_S512", us_k, f"oracle_us={us_r:.0f}"))
    return rows

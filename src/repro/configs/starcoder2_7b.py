"""starcoder2-7b [arXiv:2402.19173; hf] — GQA, RoPE, non-gated GELU MLP,
layernorm, biased projections (HF config: use_bias=true, mlp 4x)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    head_dim=128,
    rope_theta=1000000.0,
    qkv_bias=True,
    proj_bias=True,
    norm_type="layernorm",
    mlp_gated=False,
    act="gelu",
    source="arXiv:2402.19173; hf:bigcode/starcoder2-7b",
))

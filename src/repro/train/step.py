"""Train-step factory: builds the pjit-ready step function plus the full
sharding trees (params / optimizer state / batch) for a given mesh.

State layout: {"params": ..., "opt": {"m","v","count"}, "step": i32[]}
Params and both moments are sharded identically (FSDP x TP = ZeRO-3); the
qint8 second moment falls back to a shard-dim0-over-data heuristic since its
storage tree has a different rank than the parameter it tracks.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.models import layers as L
from repro.sharding import ctx as shard_ctx
from repro.sharding.rules import Strategy, sharding_tree, replicated
from repro.train import optim


@dataclasses.dataclass
class StepBundle:
    step_fn: Any               # (state, batch) -> (state, metrics)
    abstract_state: Any
    state_shardings: Any
    batch_shardings: Any
    mesh: Any


def _dp_degree(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("data", 1) * sizes.get("pod", 1)


def batch_shardings_for(batch_tree, mesh, strategy):
    from repro.sharding.rules import spec_for

    def one(sds):
        if sds.ndim == 0:
            return replicated(mesh)
        axes = ("batch",) + (None,) * (sds.ndim - 1)
        return NamedSharding(mesh, spec_for(axes, sds.shape, mesh, strategy))

    return jax.tree.map(one, batch_tree)


def _heuristic_sharding(mesh, strategy):
    """dim0-over-data fallback for state tensors with no logical axes."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    d = sizes.get("data", 1)

    def one(sds):
        if sds.ndim >= 1 and sds.shape[0] % d == 0 and sds.shape[0] >= d:
            return NamedSharding(mesh, PartitionSpec("data",
                                                     *(None,) * (sds.ndim - 1)))
        return replicated(mesh)

    return one


def opt_state_shardings(abs_opt, param_shardings, mesh, strategy, opt_cfg):
    m_sh = jax.tree.map(lambda _, s: s, abs_opt["m"], param_shardings)
    if opt_cfg.v_dtype == "qint8":
        v_sh = jax.tree.map(_heuristic_sharding(mesh, strategy), abs_opt["v"])
    else:
        v_sh = jax.tree.map(lambda _, s: s, abs_opt["v"], param_shardings)
    return {"m": m_sh, "v": v_sh, "count": replicated(mesh)}


def make_train_step(model, opt_cfg: optim.OptConfig, mesh,
                    batch_tree: dict, strategy: Strategy | None = None):
    cfg = model.cfg
    strategy = strategy or Strategy("train")

    ax = L.axes_tree(model.schema)
    abs_params = L.abstract_params(model.schema, cfg.param_dtype)
    param_sh = sharding_tree(ax, abs_params, mesh, strategy)
    abs_opt = optim.abstract_opt_state(abs_params, opt_cfg)
    opt_sh = opt_state_shardings(abs_opt, param_sh, mesh, strategy, opt_cfg)

    abstract_state = {
        "params": abs_params,
        "opt": abs_opt,
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    state_sh = {"params": param_sh, "opt": opt_sh, "step": replicated(mesh)}
    batch_sh = batch_shardings_for(batch_tree, mesh, strategy)

    def train_step(state, batch):
        shard_ctx.install(mesh, strategy.name)  # constraints at trace
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state["params"], batch)
        new_params, new_opt, stats = optim.adamw_update(
            grads, state["opt"], state["params"], opt_cfg)
        metrics = {**metrics, **stats, "step": state["step"] + 1}
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    step_fn = jax.jit(
        train_step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )
    return StepBundle(step_fn=step_fn, abstract_state=abstract_state,
                      state_shardings=state_sh, batch_shardings=batch_sh,
                      mesh=mesh)


def init_state(model, opt_cfg: optim.OptConfig, seed: int = 0):
    params = L.init_params(jax.random.PRNGKey(seed), model.schema,
                           model.cfg.param_dtype)
    return {"params": params, "opt": optim.init_opt_state(params, opt_cfg),
            "step": jnp.zeros((), jnp.int32)}

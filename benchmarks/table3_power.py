"""Table 3 + Fig 2 — power breakdown and FFT energy comparison (§5.1.1).

The energy model is calibrated so the simulated 512-pt real FFT reproduces
Table 3's component shares; this benchmark VERIFIES the calibration closes
(shares match) and derives the Fig-2-style energy ratio VWR2A / FFT-ACCEL
for each size.
"""
from __future__ import annotations

import numpy as np

from benchmarks.table2_fft import F_HZ

PAPER_SHARES = {"dma": 0.02, "memories": 0.64, "control": 0.02,
                "datapath": 0.32}
PAPER_TOTAL_MW = 5.41
ACCEL_MW = 0.983


def run():
    from repro.archsim.energy import default_model, vwr2a_energy_uj
    from repro.archsim.programs.fft import run_rfft

    rows = []
    rng = np.random.default_rng(0)
    _, counters, cycles = run_rfft(512, rng.normal(size=512) * 0.3)
    e = default_model().energy_pj(counters)
    t_s = cycles / F_HZ
    total_mw = e["total"] * 1e-12 / t_s * 1e3
    for comp in ("dma", "memories", "control", "datapath"):
        share = e[comp] / e["total"]
        rows.append((f"table3/share_{comp}", t_s * 1e6,
                     f"sim_share={share:.3f};paper_share={PAPER_SHARES[comp]:.2f}"))
    rows.append(("table3/total_power_mw", t_s * 1e6,
                 f"sim_mw={total_mw:.2f};paper_mw={PAPER_TOTAL_MW}"))

    # Fig 2: energy ratio vs the fixed-function FFT accelerator
    accel_cycles = {512: 3523, 1024: 8007, 2048: 16490}   # real-valued FFTs
    for n, acc_cyc in accel_cycles.items():
        x = rng.normal(size=n) * 0.3
        _, c, cyc = run_rfft(n, x)
        e_vwr2a = vwr2a_energy_uj(c)
        e_accel = ACCEL_MW * 1e-3 * (acc_cyc / F_HZ) * 1e6
        rows.append((f"fig2/rfft_{n}_energy", cyc / F_HZ * 1e6,
                     f"vwr2a_uJ={e_vwr2a:.3f};accel_uJ={e_accel:.3f};"
                     f"ratio={e_vwr2a / e_accel:.1f}"))
    return rows

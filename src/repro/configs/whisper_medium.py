"""whisper-medium [arXiv:2212.04356] — encoder-decoder audio backbone.
24 encoder + 24 decoder layers; the conv frontend is a STUB: input_specs()
provides precomputed frame embeddings (B, S, d_model)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,          # decoder depth
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    rope_style="none",      # whisper uses absolute positions (sinusoidal here)
    norm_type="layernorm",
    mlp_gated=False,
    act="gelu",
    proj_bias=True,
    source="arXiv:2212.04356; hf:openai/whisper-medium",
))

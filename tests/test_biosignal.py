"""MBioTracker application: delineation properties, feature sanity, SVM
end-to-end accuracy on synthetic respiration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.biosignal import (delineate, extract_features, make_app,
                                  svm_fit_least_squares, svm_predict,
                                  synthetic_respiration)
from repro.core.fir import fir_direct, lowpass_taps


def test_delineate_finds_sine_peaks():
    t = np.arange(512) / 64.0
    x = jnp.asarray(np.sin(2 * np.pi * 0.5 * t).astype(np.float32))[None]
    is_max, is_min = delineate(x)
    # 0.5 Hz over 8 s => ~4 maxima and ~4 minima
    assert 3 <= int(is_max.sum()) <= 5
    assert 3 <= int(is_min.sum()) <= 5
    # maxima are where the signal is high
    assert float(x[is_max].min()) > 0.8


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_delineate_max_min_disjoint(seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(2, 256)).astype(np.float32))
    is_max, is_min = delineate(x)
    assert not bool((is_max & is_min).any())
    assert not bool(is_max[..., 0].any()) and not bool(is_max[..., -1].any())


def test_features_finite_and_fixed_width():
    sig, _ = synthetic_respiration(8, 1024)
    filtered = fir_direct(sig, jnp.asarray(lowpass_taps(11)))
    f = extract_features(filtered)
    assert f.shape == (8, 12)
    assert bool(jnp.isfinite(f).all())


@pytest.mark.slow
def test_svm_learns_rate_classes():
    sig, labels = synthetic_respiration(96, 2048, seed=5)
    filtered = fir_direct(sig, jnp.asarray(lowpass_taps(11)))
    feats = extract_features(filtered)
    w, b = svm_fit_least_squares(feats[:64], labels[:64])
    _, pred = svm_predict(feats[64:], w, b)
    acc = float((pred == labels[64:]).mean())
    assert acc >= 0.7, acc


def test_full_app_jit():
    app = make_app()
    sig, _ = synthetic_respiration(4, 2048)
    out = jax.jit(app.__call__)(sig)
    assert out["class"].shape == (4,)
    assert bool(jnp.isfinite(out["margin"]).all())


def test_delineate_refractory_spacing():
    """The refractory gate: consecutive extrema sit > min_distance apart,
    so noise ripple near a breath peak yields ONE extremum — this spacing
    is also what keeps the interval median on its fixed-size network."""
    sig, _ = synthetic_respiration(8, 2048, seed=1)
    filtered = fir_direct(sig, jnp.asarray(lowpass_taps(11)))
    for mask in delineate(filtered):
        for row in np.asarray(mask):
            pos = np.flatnonzero(row)
            if len(pos) > 1:
                assert np.diff(pos).min() > 15, np.diff(pos).min()


def test_network_sort_matches_np_sort():
    """Batcher odd-even merge network == np.sort for every power of two,
    both the table-driven and the arithmetic (in-kernel fallback) forms."""
    from repro.core.biosignal import _network_sort_arith, network_sort

    rng = np.random.default_rng(0)
    for n in (1, 2, 4, 16, 128, 512):
        x = rng.integers(-1000, 1000, size=(5, n)).astype(np.int32)
        want = np.sort(x, axis=-1)
        got = np.asarray(jax.jit(network_sort)(jnp.asarray(x)))
        np.testing.assert_array_equal(got, want)
        got2 = np.asarray(jax.jit(_network_sort_arith)(jnp.asarray(x)))
        np.testing.assert_array_equal(got2, want)


def test_masked_intervals_matches_sort_reference():
    """Ref-equivalence of the sorting-network masked-median against the
    seed's sort/take_along_axis path, across densities that exercise BOTH
    the fixed-size fast path and the full-length fallback (plus empty,
    single-extremum, and all-True masks)."""
    from repro.core.biosignal import _masked_intervals, _masked_intervals_sort

    rng = np.random.default_rng(7)
    cases = []
    for S in (7, 64, 300, 2048):
        dense = rng.random((4, S)) < 0.4          # collisions -> fallback
        sparse = np.zeros((4, S), bool)           # fits the 128-slot buffer
        pos = np.unique(rng.integers(0, S, size=max(S // 64, 1)))
        sparse[:, pos] = True
        corner = np.zeros((3, S), bool)
        corner[1, S // 2] = True                  # single extremum: no gaps
        corner[2] = True                          # pathological all-True
        cases += [dense, sparse, corner]
    for m in cases:
        got = [np.asarray(v) for v in _masked_intervals(jnp.asarray(m))]
        want = [np.asarray(v) for v in _masked_intervals_sort(jnp.asarray(m))]
        for g, w, name in zip(got, want, ("mean", "median", "rms")):
            np.testing.assert_array_equal(g, w, err_msg=name)


def test_masked_intervals_sparse2_matches_sort_reference():
    """The sparse2=True pre-fold — the path `interval_time_features`
    actually runs — must match the seed sort reference both when the
    caller's no-adjacent-Trues promise holds AND when it is violated
    (adjacent Trues trip the guard onto the exact full-length network)."""
    from repro.core.biosignal import _masked_intervals, _masked_intervals_sort

    rng = np.random.default_rng(11)
    for S in (64, 512, 2048):
        honest = np.zeros((4, S), bool)      # >=2-apart, promise holds
        pos = np.sort(rng.choice(S // 2, size=S // 40 + 1,
                                 replace=False)) * 2
        honest[:, pos] = True
        broken = honest.copy()               # adjacent pair: promise broken
        broken[:, S // 2] = broken[:, S // 2 + 1] = True
        dense = rng.random((4, S)) < 0.5     # many adjacent pairs
        for m in (honest, broken, dense):
            got = [np.asarray(v) for v in
                   _masked_intervals(jnp.asarray(m), sparse2=True)]
            want = [np.asarray(v) for v in
                    _masked_intervals_sort(jnp.asarray(m))]
            for g, w, name in zip(got, want, ("mean", "median", "rms")):
                np.testing.assert_array_equal(g, w, err_msg=(S, name))


def test_interval_features_no_sort_primitives():
    """Acceptance: the delineation/median stage must not lower to XLA
    `sort` or gather (`take_along_axis`) — the Mosaic-compile gap."""
    from repro.core.biosignal import interval_time_features

    def run(mask):
        return tuple(interval_time_features(mask, jnp.roll(mask, 5, -1)))

    m = jnp.asarray(np.random.default_rng(0).random((4, 2048)) < 0.01)
    hlo = jax.jit(run).lower(m).as_text()
    assert " sort(" not in hlo and " gather(" not in hlo, (
        "sort/gather leaked into the interval feature stage")

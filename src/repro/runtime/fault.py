"""Fault-tolerance runtime logic: heartbeats, straggler detection, elastic
re-meshing, and a supervised retry loop with capped exponential backoff.

Everything here is pure decision logic + a supervisor wrapper, unit-tested
at small scale (`tests/test_fault.py`); the policies are the ones that
matter at 1000+ nodes:

  * heartbeat timeout => worker declared dead, elastic plan recomputed;
  * straggler = worker whose step time exceeds `straggler_factor` x the
    rolling median — persistent stragglers are evicted BEFORE they fail
    (tail-latency mitigation);
  * elastic plan keeps the model (TP) axis intact — it must match the
    sharded layer dims — and shrinks/grows the data axis to the largest
    power of two that the healthy-worker count supports; too few healthy
    workers raises the typed `InsufficientHealthyWorkers` (never a bare
    `assert`, which vanishes under ``python -O``);
  * recovery = restore-latest-checkpoint on the new mesh + deterministic
    replay (batches are a pure function of step).

This module is ALSO the live serving runtime's decision layer
(`serve/fault.py` + `serve/engine.py:ColumnScheduler.supervise`): the
streaming telemetry's retire feed doubles as the heartbeat source, the
per-column batch times feed `StragglerDetector`, and `Supervisor.call`
is the capped-backoff retry the dispatch path wraps transient failures
in. The fault taxonomy the serving layer injects/handles is defined in
`serve/errors.py` — a dependency-free leaf module rooted at
`ServeError`, so importing it here creates no layering cycle — and
re-exported from this module for the decision layer's consumers:

  * `TransientDispatchError` — retryable (a flaky dispatch; the column
    survives). `Supervisor`'s default `retry_on` covers it.
  * `ColumnDeadError` — fatal for the column (it will never answer
    again); deliberately NOT a `RuntimeError` so no retry loop can
    swallow it. The serving layer drains + requeues instead.

The LM engine's supervision layer
(`serve/engine_fault.py:FaultTolerantEngine`) reuses this taxonomy
unchanged with an engine SLOT as the supervised unit: token retires
beat `HeartbeatMonitor`, per-slot dispatch walls feed
`StragglerDetector`, `Supervisor.call` absorbs transient dispatch
faults in place, and the last healthy slot dying raises the same typed
`InsufficientHealthyWorkers` — one decision layer, three consumers
(training elasticity, column streams, LM slots).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

# the typed taxonomy moved under serve/errors.py (ServeError root) in
# the serving-API normalization; these names stay importable from here
from repro.serve.errors import (ColumnDeadError,  # noqa: F401 (re-export)
                                InsufficientHealthyWorkers,
                                TransientDispatchError)

__all__ = ["InsufficientHealthyWorkers", "TransientDispatchError",
           "ColumnDeadError", "HeartbeatMonitor", "StragglerDetector",
           "elastic_plan", "Supervisor"]


@dataclasses.dataclass
class HeartbeatMonitor:
    timeout_s: float = 30.0
    _last: dict = dataclasses.field(default_factory=dict)

    def beat(self, worker: int, t: Optional[float] = None):
        self._last[worker] = time.monotonic() if t is None else t

    def forget(self, worker: int) -> None:
        """Drop a worker from monitoring (it was drained/released);
        a forgotten worker is neither dead nor alive."""
        self._last.pop(worker, None)

    def dead(self, now: Optional[float] = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return sorted(w for w, t in self._last.items()
                      if now - t > self.timeout_s)

    def alive(self, now: Optional[float] = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return sorted(w for w, t in self._last.items()
                      if now - t <= self.timeout_s)


@dataclasses.dataclass
class StragglerDetector:
    window: int = 20
    straggler_factor: float = 2.0
    evict_after: int = 3
    _times: dict = dataclasses.field(default_factory=dict)
    _strikes: dict = dataclasses.field(default_factory=dict)

    def record(self, worker: int, step_time_s: float):
        self._times.setdefault(worker, []).append(step_time_s)
        self._times[worker] = self._times[worker][-self.window:]

    def forget(self, worker: int) -> None:
        """Drop a worker's samples + strikes (evicted/drained workers
        must not keep skewing the fleet median)."""
        self._times.pop(worker, None)
        self._strikes.pop(worker, None)

    def _median_of_medians(self) -> float:
        meds = sorted(sorted(v)[len(v) // 2] for v in self._times.values()
                      if v)
        return meds[len(meds) // 2] if meds else 0.0

    def stragglers(self) -> list[int]:
        med = self._median_of_medians()
        if med <= 0:
            return []
        out = []
        for w, v in self._times.items():
            if v and sorted(v)[len(v) // 2] > self.straggler_factor * med:
                self._strikes[w] = self._strikes.get(w, 0) + 1
                if self._strikes[w] >= self.evict_after:
                    out.append(w)
            else:
                self._strikes[w] = 0
        return sorted(out)


def elastic_plan(n_healthy_chips: int, *, model_axis: int = 16,
                 pods_of: int = 256) -> dict:
    """Largest (pod, data, model) mesh the healthy chips support.

    TP ('model') stays fixed (weight shards match it); DP shrinks to the
    largest power of two; full pods are preferred (ICI locality). Raises
    the typed `InsufficientHealthyWorkers` when the healthy count cannot
    cover even one model shard — a real error callers handle (shrink the
    model axis, wait for capacity), not an `assert` that disappears
    under ``python -O``.
    """
    if n_healthy_chips < model_axis:
        raise InsufficientHealthyWorkers(
            f"{n_healthy_chips} healthy chips cannot cover the fixed "
            f"model axis of {model_axis}")
    pods = max(1, n_healthy_chips // pods_of)
    per_pod = min(n_healthy_chips // pods, pods_of)
    data = 1
    while data * 2 * model_axis <= per_pod:
        data *= 2
    return {"pod": pods, "data": data, "model": model_axis,
            "chips": pods * data * model_axis,
            "spare": n_healthy_chips - pods * data * model_axis}


@dataclasses.dataclass
class Supervisor:
    """Wraps work in retry + recovery policies.

    Two entry points share the same (max_retries, retry_on, backoff)
    policy knobs:

    * `run` — the training-loop form: step/checkpoint/restore with
      deterministic replay. ``retries`` counts CONSECUTIVE failures and
      resets whenever the run makes NEW progress (advances past its
      prior high-water step) — transient failures spread across a long
      run must not exhaust the budget when there is progress in between,
      while a persistent fault at one step still exhausts it (a reset on
      every replayed step would retry forever).
    * `call` — the serving-dispatch form: retry one callable on
      ``retry_on`` with capped exponential backoff
      (``backoff_base_s * backoff_factor**attempt``, clamped to
      ``backoff_cap_s``; base 0 disables sleeping). The streaming
      dispatch path wraps transient faults in this
      (`serve/stream.py:BiosignalStream`).

    ``retry_on`` is the configurable exception tuple: only those types
    are retried, everything else propagates. The default covers
    `RuntimeError` (and therefore `TransientDispatchError`);
    `ColumnDeadError` is not a `RuntimeError` precisely so the default
    never swallows a death. ``sleep`` is injectable for tests.
    """
    save_fn: Optional[Callable] = None     # (state, step) -> None
    restore_fn: Optional[Callable] = None  # (step) -> state
    ckpt_every: int = 100
    max_retries: int = 3
    retry_on: tuple = (RuntimeError,)
    backoff_base_s: float = 0.0
    backoff_factor: float = 2.0
    backoff_cap_s: float = 30.0
    sleep: Callable = time.sleep

    def backoff_s(self, attempt: int) -> float:
        """Sleep before retry ``attempt`` (0-based): capped exponential."""
        return min(self.backoff_base_s * self.backoff_factor ** attempt,
                   self.backoff_cap_s)

    def call(self, fn: Callable, *args, **kwargs):
        """Run ``fn`` with up to ``max_retries`` retries on ``retry_on``
        failures, sleeping `backoff_s(attempt)` between attempts. The
        last failure re-raises."""
        for attempt in range(self.max_retries + 1):
            try:
                return fn(*args, **kwargs)
            except self.retry_on:
                if attempt >= self.max_retries:
                    raise
                delay = self.backoff_s(attempt)
                if delay > 0:
                    self.sleep(delay)

    def run(self, state, step_fn, batches, n_steps: int, *, start_step: int = 0,
            inject_failure: Optional[Callable] = None):
        """Deterministic replay: on failure, restore the last checkpoint and
        re-run from its step. `inject_failure(step)` raising simulates a
        node loss (tests). Consecutive-failure budget: ``retries`` resets
        whenever the run advances past its previous high-water step —
        not just on checkpoint boundaries — so a long run survives any
        number of transient failures as long as each recovery makes NEW
        progress. Replayed steps below the high-water mark do not reset
        the counter: a persistent fault at one step must exhaust the
        budget, not loop forever on restore/replay/reset."""
        assert self.save_fn is not None and self.restore_fn is not None, \
            "Supervisor.run needs save_fn/restore_fn (call() does not)"
        step = start_step
        last_ckpt = start_step
        high_water = start_step
        retries = 0
        metrics = None
        while step < n_steps:
            try:
                if inject_failure is not None:
                    inject_failure(step)
                state, metrics = step_fn(state, batches(step))
                step += 1
                if step > high_water:
                    high_water = step
                    retries = 0
                if step % self.ckpt_every == 0:
                    self.save_fn(state, step)
                    last_ckpt = step
            except self.retry_on:
                retries += 1
                if retries > self.max_retries:
                    raise
                state = self.restore_fn(last_ckpt)
                step = last_ckpt
        return state, step, metrics

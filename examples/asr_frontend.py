"""Streaming ASR front-end on the stage-graph substrate — the repo's
second fused workload, end to end: a raw 16 kHz waveform is featurized
by the registered ``"asr"`` stage graph (pre-emphasis FIR -> Hann ->
packed-rFFT power -> slaney log-mel, ONE `pallas_call` with in-kernel
(window, hop) framing), cross-checked against the independent numpy
oracle and the 4-launch staged baseline, served by the SAME streaming
runtime as the biosignal class via `StreamConfig(graph="asr")`, and
finally submitted as the third traffic class
(`serve.frontend.AsrTranscribe`) — fused features + a reduced
whisper-medium enc-dec decode under one ticket.

Run:  PYTHONPATH=src python examples/asr_frontend.py
"""
import dataclasses
import time

import numpy as np

from repro.kernels.pipeline.asr import (asr_reference, asr_staged,
                                        make_asr_frontend)
from repro.kernels.pipeline.ops import graph_pipeline_stream
from repro.serve.stream import BiosignalStream, StreamConfig

print("== synthesize a 16 kHz utterance (chirp + noise stand-in) ==")
SR, WINDOW, HOP = 16000, 512, 160            # whisper-style 32 ms / 10 ms
rng = np.random.default_rng(0)
t = np.arange(SR * 4) / SR                   # 4 seconds
audio = (np.sin(2 * np.pi * (180 + 60 * t) * t)
         + 0.1 * rng.standard_normal(t.shape[0])).astype(np.float32)

print("== fused stage-graph featurize: ONE pallas_call, in-kernel framing ==")
app = make_asr_frontend()                    # 512-pt FFT, 64 slaney mels
out = graph_pipeline_stream("asr", app, audio, window=WINDOW, hop=HOP,
                            outputs=("logmel",))
print(f"{audio.shape[0]} samples -> log-mel {out['logmel'].shape} "
      f"(filtered-frame HBM write elided)")

print("== vs the independent numpy oracle (np.fft, float64 twiddles) ==")
ref = asr_reference(app, audio, window=WINDOW, hop=HOP)
err = float(np.abs(np.asarray(out["logmel"]) - ref["logmel"]).max())
scale = max(1.0, float(np.abs(ref["logmel"]).max()))
assert err / scale < 1e-5, err
print(f"log-mel max |fused - oracle| = {err:.2e} (scale-relative f32 tol)")

print("== vs the 4-launch staged baseline (the --check-asr pairing) ==")
t0 = time.perf_counter()
staged = asr_staged(app, audio, window=WINDOW, hop=HOP)
staged["logmel"].block_until_ready()
dt_staged = time.perf_counter() - t0
t0 = time.perf_counter()
fused = graph_pipeline_stream("asr", app, audio, window=WINDOW, hop=HOP,
                              outputs=("logmel",))
fused["logmel"].block_until_ready()
dt_fused = time.perf_counter() - t0
print(f"staged {dt_staged * 1e3:.1f} ms vs fused {dt_fused * 1e3:.1f} ms "
      f"-> {dt_staged / dt_fused:.1f}x (4 dispatches + host-framing HBM "
      f"blow-up vs one call; CI gates >= 1.2x)")

print("== served by the SAME streaming runtime as the biosignal class ==")
cfg = StreamConfig(window=WINDOW, hop=HOP, batch_windows=32, graph="asr",
                   outputs=("logmel",))
stream = BiosignalStream(app, cfg)
served = stream.process(audio)
assert np.array_equal(np.asarray(served["logmel"]),
                      np.asarray(out["logmel"]))
print(f"StreamConfig(graph='asr'): {served['logmel'].shape[0]} frames, "
      f"bit-identical to the one-call kernel (hop-aligned batches)")

print("== the third traffic class: AsrTranscribe through ServeFrontend ==")
from repro.configs import get_config, reduced
from repro.models import build_model, init_model_params
from repro.serve.engine import Engine
from repro.serve.frontend import AsrTranscribe, ServeFrontend

cfg_lm = dataclasses.replace(reduced(get_config("whisper-medium")),
                             vocab_size=64)
model = build_model(cfg_lm)
engine = Engine(model, init_model_params(model, seed=3), slots=2,
                max_len=64, temperature=0.0, seed=7,
                compiled=Engine.compile_model(model))
front = ServeFrontend(engine=engine)
ticket = front.submit(AsrTranscribe(0, audio[: SR // 2], max_new=8))
front.run()
res = ticket.result()
print(f"ticket done: features {res.features.shape}, "
      f"decoded ids {res.tokens} (reduced whisper-medium enc-dec)")
print("asr frontend OK")

"""VWR2A core library: the paper's contribution as composable JAX modules.

  vwr       — VWR staging discipline (asymmetric wide-register interface ->
              BlockSpec/VMEM block planning)
  shuffle   — the 4 shuffle-unit primitives (interleave, prune, bit-reversal,
              circular shift)
  fft       — radix-2 FFT on the shuffle dataflow (+ real-FFT packing)
  fir       — FIR filtering on the VWR dataflow
  biosignal — the MBioTracker application (preprocess/delineate/features/SVM)
"""
from repro.core import biosignal, fft, fir, shuffle, vwr  # noqa: F401

"""Multi-column sharding for the fused biosignal pipeline.

VWR2A scales throughput by replicating columns: the CGRA deals passes
round-robin across identical column slices that share the scratchpad
crossbar, and archsim's `VWR2A(n_columns=...)` models exactly that
(conserved activity, ~1/D cycles). This module is the Pallas-path
analogue: a `data`-axis `shard_map` around `pipeline_pallas` /
`pipeline_stream_pallas` that deals frame-blocks across devices the way
the simulator deals passes across columns.

The raw-signal split happens on HOP boundaries: column d owns the
contiguous run of frames [d*n_d, (d+1)*n_d) (n_d = ceil(n_frames / D) —
the same conserved-work deal as archsim's round-robin, collapsed to one
run per column so the inter-column halo stays minimal), and its chunk is

    signal[d*n_d*hop : d*n_d*hop + n_d*hop + (window - hop)]

i.e. each column stages ~n_samples/D body samples plus ONE `window-hop`
overlap halo replicated from its right neighbour — the inter-device
mirror of the in-kernel overlap sharing (PR 3), which keeps per-device
HBM traffic at ~n_samples/D instead of n_frames*window/D.

Every column runs the SAME single-device kernel on its chunk, so sharded
outputs are bit-identical to the unsharded call (each frame's pipeline
reads only its own window: the chunk FIR's frame-local transient patch
makes frames independent of how chunks are cut). When no mesh is
available (or D exceeds the device count) the identical per-column body
runs serially on one device — the fallback tests rely on for
device-count-independent equivalence properties.

LOAD-AWARE DEAL: ``weights`` generalizes the equal split to non-uniform
hop-aligned shares — column d owns a contiguous run of frames whose count
is proportional to its weight (largest-remainder apportionment, so shares
sum to exactly n_frames and every chunk still starts on a hop boundary;
the halo logic is unchanged). ``weights=None`` is the equal-deal fast
path, bit-for-bit the PR-4 behaviour. The serving layer feeds measured
per-column throughput (`serve.stream.StreamTelemetry` EWMAs) in as the
weight vector so an externally loaded column — e.g. one shared with the
LM engine — is dealt a proportionally smaller share: the software
analogue of work-stealing between VWR2A columns.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels.pipeline.kernel import (OUTPUTS, canonical_outputs,
                                           empty_outputs, pipeline_pallas,
                                           pipeline_stream_pallas,
                                           stream_frame_count)

__all__ = ["Deal", "column_frames", "column_shares", "column_chunks",
           "requeue_ranges", "pipeline_sharded", "pipeline_stream_sharded",
           "data_mesh_size"]


def data_mesh_size(mesh) -> int:
    """Size of the mesh's `data` axis (the column-replication axis)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)


def _check_mesh(mesh, n_columns: int) -> None:
    """`mesh=None` means the serial fallback by design, but a PROVIDED
    mesh whose data axis doesn't match n_columns is a misconfiguration —
    silently running serial would hand back single-device throughput with
    zero diagnostics."""
    assert mesh is None or data_mesh_size(mesh) == n_columns, (
        f"mesh data axis {data_mesh_size(mesh)} != n_columns {n_columns}; "
        f"build the mesh with make_local_mesh(data=n_columns) or pass "
        f"mesh=None for the serial fallback")


def column_frames(n_frames: int, n_columns: int) -> int:
    """Frames per column: the conserved-work equal deal. Every column
    processes the same padded count (shard_map shards must agree on
    shape); the `n_columns*column_frames - n_frames` pad frames are
    trimmed after."""
    assert n_columns >= 1, n_columns
    return -(-max(n_frames, 1) // n_columns)


def column_shares(n_frames: int, n_columns: int,
                  weights=None) -> tuple[int, ...]:
    """Per-column frame counts for the deal.

    ``weights=None``: the equal deal — every column the same padded
    `column_frames` count (sum may exceed n_frames; the pad is trimmed).
    With ``weights`` (n_columns non-negative finites, sum > 0): column d's
    share is proportional to weights[d], quantized by largest-remainder
    apportionment so the shares sum to EXACTLY n_frames — contiguous
    frame runs cover every frame once with no overlap, and since frames
    start on hop multiples every chunk boundary stays hop-aligned. A
    zero-weight (cold/reserved) column gets zero frames.
    """
    assert n_columns >= 1, n_columns
    if weights is None:
        return (column_frames(n_frames, n_columns),) * n_columns
    w = [float(x) for x in weights]
    assert len(w) == n_columns, (len(w), n_columns)
    assert all(x >= 0.0 and x == x and x != float("inf") for x in w), w
    total = sum(w)
    assert total > 0.0, "weights must not all be zero"
    ideal = [n_frames * x / total for x in w]
    base = [int(i) for i in ideal]
    # hand the leftover frames to the largest fractional remainders
    # (ties -> lower column index, so the deal is deterministic)
    order = sorted(range(n_columns), key=lambda d: (base[d] - ideal[d], d))
    for d in order[: n_frames - sum(base)]:
        base[d] += 1
    assert sum(base) == n_frames, (base, n_frames)
    return tuple(base)


def requeue_ranges(ranges, n_columns: int,
                   weights=None) -> list[list[tuple[int, int]]]:
    """Deal a dead column's unretired frame ranges across columns.

    ``ranges`` is an ordered list of ``(start, count)`` frame runs (frame
    indices, so every boundary is hop-aligned by construction — frame i
    starts at sample ``i*hop``). The total frame count is apportioned by
    the SAME largest-remainder arithmetic as the initial deal
    (`column_shares`, so a zero-weight — dead — column receives nothing),
    then the runs are walked in order and split at share boundaries:
    column d's portion is a list of ``(start, count)`` runs covering
    exactly its share.

    Properties the chaos tests pin: concatenating every column's runs in
    column order reproduces the input frame set exactly (full coverage,
    no overlap, order preserved), every run is non-empty, and per-column
    counts equal `column_shares` of the total. Contiguous runs landing on
    the same column COALESCE into one (the input runs are dispatch-sized
    fragments of one contiguous share; re-fragmenting them across a
    share boundary would make a survivor pay two dispatch overheads for
    adjacent frames). This is the requeue step of the fault-tolerant
    serving loop (`serve/fault.py`): the degraded deal is just the
    healthy deal with dead columns' weights zeroed.
    """
    ranges = [(int(s), int(c)) for s, c in ranges if c > 0]
    total = sum(c for _, c in ranges)
    if total == 0:
        return [[] for _ in range(n_columns)]
    # weights=None means the equal deal; column_shares' None path pads to
    # a uniform per-column count (shard_map shape agreement), but requeue
    # needs shares summing to EXACTLY the frame total — use explicit
    # equal weights to get the largest-remainder exact-sum path
    shares = column_shares(total, n_columns,
                           weights if weights is not None
                           else (1.0,) * n_columns)
    out: list[list[tuple[int, int]]] = [[] for _ in range(n_columns)]
    it = iter(ranges)
    cur_start, cur_count = 0, 0
    for d, share in enumerate(shares):
        need = share
        while need > 0:
            if cur_count == 0:
                cur_start, cur_count = next(it)
            take = min(need, cur_count)
            if out[d] and out[d][-1][0] + out[d][-1][1] == cur_start:
                out[d][-1] = (out[d][-1][0], out[d][-1][1] + take)
            else:
                out[d].append((cur_start, take))
            cur_start += take
            cur_count -= take
            need -= take
    return out


@dataclasses.dataclass(frozen=True)
class Deal:
    """The result of one column deal (`column_chunks`), named.

    ``chunks`` is the `(D, L)` staged-signal array (None when the signal
    frames to nothing), ``n_frames`` the global frame count, ``shares``
    the per-column frame counts (`column_shares`). Iterates like the
    legacy ``(chunks, n_frames, shares)`` 3-tuple, so both
    ``deal.shares`` and ``chunks, n, shares = column_chunks(...)``
    read correctly at call sites."""
    chunks: object
    n_frames: int
    shares: tuple[int, ...]

    def __iter__(self):
        return iter((self.chunks, self.n_frames, self.shares))


def column_chunks(signal, window: int, hop: int, n_columns: int,
                  weights=None) -> Deal:
    """Split a raw 1-D signal into per-column chunks on hop boundaries.

    Returns a `Deal`. ``Deal.chunks`` is `(D, L)` with
    `L = max(shares)*hop + window - hop`: row d starts at the first
    sample of its first owned frame (`offset_d*hop`, hop-aligned by
    construction) and carries its `window-hop` right-halo (replicated
    from the neighbour's first samples), zero-padded past the signal end
    — so row d's first ``shares[d]`` framed windows are exactly the ones
    frame-global indices [offset_d, offset_d + shares[d]) would produce.

    With the equal deal (``weights=None``) every share is the same padded
    `column_frames` count and rows frame to exactly that count — the PR-4
    behaviour. With ``weights`` the shares are the non-uniform
    `column_shares` deal (summing to n_frames exactly); rows are padded
    to the widest share's length so shard_map shards agree on shape, and
    a row's frames past its own share are discard-on-trim duplicates of
    its neighbour's frames. `n_frames == 0` yields
    ``Deal(None, 0, (0,)*D)``.
    """
    sig = jnp.asarray(signal)
    assert sig.ndim == 1, sig.shape
    n = stream_frame_count(sig.shape[0], window, hop)
    if n == 0:
        return Deal(None, 0, (0,) * n_columns)
    shares = column_shares(n, n_columns, weights)
    L = max(shares) * hop + (window - hop)
    offsets = [sum(shares[:d]) for d in range(n_columns)]
    total = max(off * hop + L for off in offsets)
    if total > sig.shape[0]:
        sig = jnp.concatenate(
            [sig, jnp.zeros((total - sig.shape[0],), sig.dtype)])
    chunks = jnp.stack([sig[off * hop: off * hop + L] for off in offsets])
    return Deal(chunks, n, shares)


def _trim(out: dict, n: int) -> dict:
    return {k: v[:n] for k, v in out.items()}


def _stream_body(chunk, taps, w, b, *, window, hop, fft_size, interpret,
                 block_frames, outputs):
    """One column's work: the unsharded single-device kernel on a (1, L)
    chunk row. Shared verbatim by the shard_map shard and the serial
    fallback, which is what makes the two paths bit-identical."""
    return pipeline_stream_pallas(
        chunk[0], taps, w, b, window=window, hop=hop, fft_size=fft_size,
        interpret=interpret, block_frames=block_frames, outputs=outputs)


@functools.lru_cache(maxsize=64)
def _stream_shard_fn(mesh, window, hop, fft_size, interpret, block_frames,
                     outputs):
    """Memoized jit(shard_map(...)) per (mesh, static config): an eager
    shard_map re-traces every dispatch, which would swamp the per-batch
    runtime; Mesh hashes by value, so every stream with the same column
    layout shares one compiled executable."""
    body = functools.partial(_stream_body, window=window, hop=hop,
                             fft_size=fft_size, interpret=interpret,
                             block_frames=block_frames, outputs=outputs)
    return jax.jit(shard_map(
        body, mesh=mesh, in_specs=(P("data"), P(), P(), P()),
        out_specs=P("data"),
        check_rep=False))         # pallas_call has no replication rule


def pipeline_stream_sharded(signal, taps, w, b, *, window: int, hop: int,
                            n_columns: int, mesh=None, fft_size: int = 512,
                            interpret: bool = True,
                            block_frames: int | None = None,
                            outputs: tuple = OUTPUTS, weights=None):
    """`pipeline_stream_pallas` dealt across `n_columns` column replicas.

    With `mesh` (a mesh whose `data` axis has >= n_columns devices... in
    fact exactly n_columns — build it with
    `launch.mesh.make_local_mesh(data=n_columns)`), the per-column chunks
    are `shard_map`ped over the `data` axis: each device stages only its
    ~n_samples/D chunk + halo and runs the fused kernel on it. Without a
    mesh the same per-column body runs serially — identical outputs, so
    every equivalence property is testable on a single device.

    ``weights`` switches the equal deal to the non-uniform
    `column_shares` deal (load-aware: a slow column gets a small share).
    On the serial fallback each column runs EXACTLY its own share — the
    per-column wall times really are proportional to the deal, which is
    what the `table5/stream_hetero` bench measures. Under shard_map the
    shards stay shape-uniform (padded to the widest share; the pad frames
    are discarded on trim), so a smaller share still cuts the loaded
    column's staged bytes and valid output rows. Outputs are bit-identical
    to the single-device kernel for ANY valid weight vector.

    Invariants: every chunk boundary is HOP-ALIGNED (frames start on hop
    multiples, so the deal never splits a frame) and the chunk FIR's
    frame-local transient patch makes each frame independent of where
    the signal was cut — the two facts that make the deal numerically
    invisible. See `docs/ARCHITECTURE.md` (column replication) for the
    paper mapping and `docs/BENCHMARKS.md` for the `--check-columns` /
    `--check-hetero` gates this entry backs.
    """
    outputs = canonical_outputs(outputs)
    _check_mesh(mesh, n_columns)
    F, C = w.shape
    deal = column_chunks(signal, window, hop, n_columns, weights)
    chunks, n, shares = deal.chunks, deal.n_frames, deal.shares
    if n == 0:
        return empty_outputs(window, F, C, jnp.asarray(signal).dtype,
                             outputs)
    body = functools.partial(_stream_body, window=window, hop=hop,
                             fft_size=fft_size, interpret=interpret,
                             block_frames=block_frames, outputs=outputs)
    if n_columns == 1:
        return _trim(body(chunks, taps, w, b), n)
    if mesh is not None:
        sharded = _stream_shard_fn(mesh, window, hop, fft_size, interpret,
                                   block_frames, outputs)
        out = sharded(chunks, taps, w, b)
        if weights is None:
            return _trim(out, n)
        # non-uniform deal: every shard framed max(shares) rows; keep each
        # column's own share and drop its pad rows
        n_max = max(shares)
        keep = [slice(d * n_max, d * n_max + s)
                for d, s in enumerate(shares) if s]
        return {k: jnp.concatenate([v[sl] for sl in keep])
                for k, v in out.items()}
    # serial-column fallback: same deal, one device. Non-uniform shares
    # run each column on exactly its own share's samples (chunk rows are
    # padded to the widest share; the slice undoes the pad) so serial
    # per-column timing reflects the deal.
    if weights is None:
        outs = [body(chunks[d: d + 1], taps, w, b)
                for d in range(n_columns)]
    else:
        outs = [body(chunks[d: d + 1, : s * hop + (window - hop)],
                     taps, w, b)
                for d, s in enumerate(shares) if s]
    return _trim({k: jnp.concatenate([o[k] for o in outs]) for k in outs[0]},
                 n)


def _framed_body(rows, taps, w, b, *, fft_size, interpret, block_rows,
                 outputs):
    return pipeline_pallas(rows, taps, w, b, fft_size=fft_size,
                           interpret=interpret, block_rows=block_rows,
                           outputs=outputs)


@functools.lru_cache(maxsize=64)
def _framed_shard_fn(mesh, fft_size, interpret, block_rows, outputs):
    body = functools.partial(_framed_body, fft_size=fft_size,
                             interpret=interpret, block_rows=block_rows,
                             outputs=outputs)
    return jax.jit(shard_map(
        body, mesh=mesh, in_specs=(P("data"), P(), P(), P()),
        out_specs=P("data"),
        check_rep=False))         # pallas_call has no replication rule


def pipeline_sharded(frames, taps, w, b, *, n_columns: int, mesh=None,
                     fft_size: int = 512, interpret: bool = True,
                     block_rows: int | None = None,
                     outputs: tuple = OUTPUTS):
    """`pipeline_pallas` on pre-framed (R, S) windows, rows dealt across
    columns: row-block d of ceil(R/D) windows goes to column d (pad rows
    are trimmed after). The framed counterpart of
    `pipeline_stream_sharded` — no halo needed, frames carry their own
    overlap."""
    outputs = canonical_outputs(outputs)
    _check_mesh(mesh, n_columns)
    R, S = frames.shape
    F, C = w.shape
    if R == 0:
        return empty_outputs(S, F, C, frames.dtype, outputs)
    body = functools.partial(_framed_body, fft_size=fft_size,
                             interpret=interpret, block_rows=block_rows,
                             outputs=outputs)
    if n_columns == 1:
        return body(frames, taps, w, b)
    r_d = column_frames(R, n_columns)
    if n_columns * r_d > R:
        frames = jnp.concatenate(
            [frames, jnp.zeros((n_columns * r_d - R, S), frames.dtype)])
    if mesh is not None:
        sharded = _framed_shard_fn(mesh, fft_size, interpret, block_rows,
                                   outputs)
        return _trim(sharded(frames, taps, w, b), R)
    outs = [body(frames[d * r_d: (d + 1) * r_d], taps, w, b)
            for d in range(n_columns)]
    return _trim({k: jnp.concatenate([o[k] for o in outs]) for k in outs[0]},
                 R)

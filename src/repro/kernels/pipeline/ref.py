"""Staged references for the fused pipeline kernel.

Two baselines, matching the paper's Table 5 columns:

* ``staged_kernel_fns`` — kernel-at-a-time offload: each stage is its own
  kernel launch with an HBM round trip between stages (Pallas FIR kernel,
  jnp delineation/time features, Pallas packed-rFFT kernel, jnp SVM). This
  is the paper's CPU+FFT-ACCEL execution model and the baseline the CI
  ``--check-fused`` gate compares the fused kernel against.
* ``staged_stage_fns`` — the same pipeline as three separately-jitted jnp
  calls (the seed `BiosignalApp` decomposition); informational.

For numerical tests the oracle is `core.biosignal.BiosignalApp` itself.

The ASR front-end has the same pair of baselines in its own module:
`asr.py:asr_staged` is this file's kernel-at-a-time sibling (host frame
gather + FIR kernel + jnp Hann + rFFT kernel + jnp mel/log — the
``--check-asr`` gate's baseline), and `asr.py:asr_reference` is its
numpy oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.biosignal import (band_power_features, delineate,
                                  extract_features, interval_time_features,
                                  svm_predict)
from repro.core.fir import fir_direct


def staged_stage_fns(taps, w, b, *, fft_size: int = 512):
    """The pipeline as its three separately-jitted jnp stages (FIR,
    features, SVM). Each call materializes its output — the HBM round
    trip."""
    taps = jnp.asarray(taps)
    fir_fn = jax.jit(lambda s: fir_direct(s, taps))
    feat_fn = jax.jit(functools.partial(extract_features, fft_size=fft_size))
    svm_fn = jax.jit(lambda f: svm_predict(f, w, b))
    return fir_fn, feat_fn, svm_fn


def staged_kernel_fns(taps, w, b, *, fft_size: int = 512):
    """Kernel-at-a-time execution: one launch per stage, every inter-stage
    tensor round-tripping HBM. Returns a single callable running the chain.
    """
    from repro.kernels.fir.ops import fir as kfir
    from repro.kernels.fft.ops import rfft as krfft

    taps = jnp.asarray(taps)

    @jax.jit
    def time_feats(filtered):
        is_max, is_min = delineate(filtered)
        seg = filtered[..., :fft_size]
        return (interval_time_features(is_max, is_min),
                seg - jnp.mean(seg, axis=-1, keepdims=True))

    @jax.jit
    def finish(f_time, Xr, Xi):
        power = jnp.square(Xr) + jnp.square(Xi)
        feats = jnp.stack(list(f_time) + band_power_features(power, fft_size),
                          axis=-1)
        margin, cls = svm_predict(feats, w, b)
        return feats, margin, cls

    def run(signal):
        filtered = kfir(signal, taps)        # launch 1: FIR kernel
        f_time, seg = time_feats(filtered)   # launch 2: delineation/time
        Xr, Xi = krfft(seg)                  # launch 3: packed-rFFT kernel
        feats, margin, cls = finish(f_time, Xr, Xi)   # launch 4: bands+SVM
        return {"filtered": filtered, "features": feats,
                "margin": margin, "class": cls}

    return run


def pipeline_staged(signal, taps, w, b, *, fft_size: int = 512):
    """Dict-identical kernel-at-a-time staged execution."""
    return staged_kernel_fns(taps, w, b, fft_size=fft_size)(signal)

"""Sharding rules + HLO cost analyzer unit tests (no 512-device mesh —
the production meshes are exercised by launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.analysis.hlo_cost import analyze, replica_groups, type_bytes
from repro.sharding.rules import Strategy, spec_for


class FakeMesh:
    axis_names = ("pod", "data", "model")

    class devices:
        shape = (2, 16, 16)


class FakeMesh2D:
    axis_names = ("data", "model")

    class devices:
        shape = (16, 16)


def test_spec_for_train_weights():
    st = Strategy("train")
    m = FakeMesh2D()
    # mlp weight: embed->data (FSDP), mlp->model (TP)
    assert spec_for(("embed", "mlp"), (4096, 14336), m, st) == \
        P("data", "model")
    # head-count not divisible and not padded here: heads dim replicated
    assert spec_for(("embed", "heads", "head_dim"), (4096, 56, 128), m, st) \
        == P("data", None, None)
    # padded head count shards
    assert spec_for(("embed", "heads", "head_dim"), (4096, 64, 128), m, st) \
        == P("data", "model", None)
    # whisper vocab 51865 does not divide 16 -> falls to embed/data
    assert spec_for(("vocab", "embed"), (51865, 1024), m, st) == \
        P(None, "data")


def test_spec_for_serve_cache():
    st = Strategy("serve")
    m = FakeMesh2D()
    # kv divisible: heads take model, batch takes data
    assert spec_for(("batch", "seq", "kv_heads", "head_dim"),
                    (128, 32768, 16, 64), m, st) == \
        P("data", None, "model", None)
    # kv = 8 < 16: sequence-sharded cache (flash-decoding layout)
    assert spec_for(("batch", "seq", "kv_heads", "head_dim"),
                    (128, 32768, 8, 128), m, st) == \
        P("data", "model", None, None)
    # long-context batch=1: seq grabs model, data idle for batch
    assert spec_for(("batch", "seq", "kv_heads", "head_dim"),
                    (1, 524288, 8, 120), m, st) == \
        P(None, "model", None, None)
    # serve weights: replicated over data (no FSDP gather at decode)
    assert spec_for(("embed", "mlp"), (4096, 14336), m, st) == \
        P(None, "model")


def test_spec_for_multipod_batch():
    st = Strategy("train")
    assert spec_for(("batch", None), (256, 4096), FakeMesh(), st) == \
        P(("pod", "data"), None)


# ---------------- HLO cost analyzer ----------------

_HLO = """
HloModule test

%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,128] get-tuple-element(%p), index=1
  %w = f32[128,128] constant({...})
  %dot.1 = f32[8,128] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,128] all-reduce(%dot.1), replica_groups=[2,4]<=[4,2]T(1,0), to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,128]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,128])) -> pred[] {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(24)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,128]) -> f32[8,128] {
  %a = f32[8,128] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,128]) tuple(%z, %a)
  %w = (s32[], f32[8,128]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"24"}}
  ROOT %out = f32[8,128] get-tuple-element(%w), index=1
}
"""


def test_hlo_cost_multiplies_while_bodies():
    r = analyze(_HLO)
    # one dot = 2*8*128*128 flops, x24 trips
    assert r["flops"] == 24 * 2 * 8 * 128 * 128
    assert r["collectives"]["all-reduce"]["count"] == 24
    assert r["collectives"]["all-reduce"]["bytes"] == 24 * 8 * 128 * 4
    assert r["collectives"]["all-reduce"]["group_size"] == 4


def test_replica_group_reconstruction():
    g = replica_groups('replica_groups=[2,4]<=[4,2]T(1,0)')
    assert g.shape == (2, 4)
    ids = np.arange(8).reshape(4, 2).transpose(1, 0).reshape(2, 4)
    np.testing.assert_array_equal(g, ids)
    g2 = replica_groups('replica_groups={{0,2},{1,3}}')
    np.testing.assert_array_equal(g2, [[0, 2], [1, 3]])


def test_type_bytes():
    assert type_bytes("f32[8,128]") == 8 * 128 * 4
    assert type_bytes("(bf16[2,2]{1,0}, s8[16])") == 8 + 16
    assert type_bytes("pred[]") == 1


def test_analyzer_on_real_compiled_module(rng):
    """Compile a scanned matmul on CPU; analyzer flops must scale with the
    trip count while XLA's builtin count stays flat."""
    w = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))

    def f(x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    comp = jax.jit(f).lower(jnp.ones((8, 64))).compile()
    r = analyze(comp.as_text())
    expected = 10 * 2 * 8 * 64 * 64
    assert 0.9 * expected <= r["flops"] <= 1.2 * expected, r["flops"]


def test_spec_for_fsdp_strategy():
    """Pure-FSDP layout: batch over every axis, weights fully sharded."""
    st = Strategy("fsdp")
    m = FakeMesh2D()
    assert spec_for(("batch", None), (256, 4096), m, st) == \
        P(("data", "model"), None)
    # batch that can't span 256 falls back to data only
    assert spec_for(("batch", None), (32, 4096), m, st) == P("data", None)
    assert spec_for(("embed", "mlp"), (4096, 14336), m, st) == \
        P("data", "model")


def test_activation_specs_strategies():
    import jax
    from repro.sharding.ctx import make_activation_specs

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tp = make_activation_specs(mesh, "train")
    assert tp["btd"].spec == P("data", None, None)
    assert tp["btv"].spec == P("data", None, "model")
    fs = make_activation_specs(mesh, "fsdp")
    assert fs["btd"].spec == P(("data", "model"), None, None)
    assert fs["btv"].spec == P(("data", "model"), None, None)

"""Activity-based energy model calibrated to the paper's Table 3.

Table 3 gives the VWR2A power breakdown at 80 MHz while executing a
512-point real-valued FFT: DMA 0.0947 mW (2%), Memories 3.49 mW (64%, of
which SPM 46% / VWRs 54%), Control 0.100 mW (2%), Datapath 1.72 mW (32%),
total 5.41 mW. We calibrate per-event energies so that OUR simulated
512-pt rFFT activity reproduces exactly that breakdown; Tables 4/5 energies
are then predictions from activity counts. CPU energy uses the paper's own
Table 4 rate (0.37 uJ / 24747 cycles ~ 15 pJ/cycle).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.archsim.machine import Counters

F_HZ = 80e6
# Table 3 (VWR2A column), in mW
P_DMA = 9.47e-2
P_MEM = 3.49
P_SPM = P_MEM * 0.46
P_VWR = P_MEM * 0.54
P_CTRL = 1.00e-1
P_DP = 1.72
P_TOTAL = 5.41

# paper Table 4: CPU (Cortex-M4 + CMSIS q15): 0.37 uJ / 24747 cycles
CPU_PJ_PER_CYCLE = 0.37e-6 / 24747 * 1e12       # ~14.95 pJ/cycle
# paper Table 2+Fig 2 context: FFT ACCEL ~0.983 mW at 80 MHz
FFT_ACCEL_PJ_PER_CYCLE = 0.983e-3 / F_HZ * 1e12  # ~12.3 pJ/cycle


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    pj_spm_line: float
    pj_vwr_access: float
    pj_rc_op: float
    pj_ctrl_cycle: float
    pj_dma_word: float

    def energy_pj(self, c: Counters) -> dict:
        spm = (c.spm_line_reads + c.spm_line_writes) * self.pj_spm_line
        vwr = (c.vwr_reads + c.vwr_writes) * self.pj_vwr_access
        dp = c.rc_ops * self.pj_rc_op
        ctrl = c.cycles * self.pj_ctrl_cycle
        dma = c.dma_words * self.pj_dma_word
        total = spm + vwr + dp + ctrl + dma
        return {"spm": spm, "vwr": vwr, "datapath": dp, "control": ctrl,
                "dma": dma, "memories": spm + vwr, "total": total}


def calibrate(counters: Counters, wall_cycles: int) -> EnergyModel:
    """Fit per-event energies so this activity profile reproduces the
    Table 3 powers at 80 MHz."""
    t_s = wall_cycles / F_HZ
    mw_to_pj = lambda p_mw: p_mw * 1e-3 * t_s * 1e12  # component energy in pJ
    spm_ev = max(1, counters.spm_line_reads + counters.spm_line_writes)
    vwr_ev = max(1, counters.vwr_reads + counters.vwr_writes)
    rc_ev = max(1, counters.rc_ops)
    dma_ev = max(1, counters.dma_words)
    return EnergyModel(
        pj_spm_line=mw_to_pj(P_SPM) / spm_ev,
        pj_vwr_access=mw_to_pj(P_VWR) / vwr_ev,
        pj_rc_op=mw_to_pj(P_DP) / rc_ev,
        pj_ctrl_cycle=mw_to_pj(P_CTRL) / max(1, counters.cycles),
        pj_dma_word=mw_to_pj(P_DMA) / dma_ev,
    )


_DEFAULT: EnergyModel | None = None


def default_model() -> EnergyModel:
    """Calibrated on the simulated 512-pt real FFT (lazy singleton)."""
    global _DEFAULT
    if _DEFAULT is None:
        from repro.archsim.programs.fft import run_rfft

        rng = np.random.default_rng(0)
        _, counters, cycles = run_rfft(512, rng.normal(size=512) * 0.3)
        _DEFAULT = calibrate(counters, cycles)
    return _DEFAULT


def cpu_energy_uj(cycles: int) -> float:
    return cycles * CPU_PJ_PER_CYCLE * 1e-6


def vwr2a_energy_uj(c: Counters) -> float:
    return default_model().energy_pj(c)["total"] * 1e-6

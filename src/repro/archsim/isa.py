"""VWR2A slot ISA (paper §3.1-3.3, Table 1).

One configuration word per cycle per slot; bits == control signals (no
decode stage). We model each slot's instruction as a small dataclass; a
column executes one instruction per slot per cycle under a shared PC.

Slots per column: LCU (loops/branches), LSU (SPM<->VWR/SRF + shuffle unit),
MXCU (VWR word index k + masks), RC0..RC3 (32-bit ALU, 2-entry regfile).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---- operand sources / destinations for RC ops -----------------------------
# ("vwr", name[, off]) word (rc*32 + k + off) of the VWR (MXCU-controlled k;
#                      non-zero off models the paper's mux-network offset
#                      indexing via SRF masking values, §3.2)
# ("win", off)         virtual 256-word [B;A] window at 128 + rc*32 + k + off
#                      (boundary words for FIR/conv, §3.3.1)
# ("srf", i)           scalar register file entry i
# ("reg", 0|1)         RC-local register
# ("imm", value)       immediate
# ("rc", delta)        previous-cycle result of neighbour RC (delta = +-1)
# ("zero",)            constant 0

RC_OPS = ("NOP", "ADD", "SUB", "MUL", "FXMUL", "SLL", "SRL", "SRA",
          "AND", "OR", "XOR", "MAX", "MIN", "MOV")


@dataclasses.dataclass(frozen=True)
class RCInstr:
    op: str = "NOP"
    a: Tuple = ("zero",)
    b: Tuple = ("zero",)
    dest: Optional[Tuple] = None          # ("reg",i) | ("vwr",name) | ("srf",i)

    def __post_init__(self):
        assert self.op in RC_OPS, self.op


@dataclasses.dataclass(frozen=True)
class LSUInstr:
    op: str = "NOP"     # NOP | LOAD | STORE | LOAD_SRF | STORE_SRF | SHUFFLE
    vwr: str = "A"      # target VWR (LOAD/STORE) or shuffle half selector
    addr: Tuple = ("imm", 0)   # SPM line address source: ("imm",v)|("srf",i)
    shuffle_op: str = ""       # interleave|prune_even|prune_odd|bit_reverse|circular_shift
    half: str = "lower"


@dataclasses.dataclass(frozen=True)
class MXCUInstr:
    op: str = "NOP"     # NOP | SETK | INCK | ADDK
    k: int = 0          # immediate for SETK/ADDK


@dataclasses.dataclass(frozen=True)
class LCUInstr:
    op: str = "NOP"     # NOP | SETI | ADDI | BLT | BGE | JUMP | EXIT
    reg: int = 0        # LCU register index (4 regs)
    val: int = 0        # immediate / compare bound
    target: int = 0     # branch target PC


@dataclasses.dataclass(frozen=True)
class SlotWord:
    """One VLIW-style configuration word: all slots for one PC."""
    lcu: LCUInstr = LCUInstr()
    lsu: LSUInstr = LSUInstr()
    mxcu: MXCUInstr = MXCUInstr()
    rcs: Tuple[RCInstr, RCInstr, RCInstr, RCInstr] = (
        RCInstr(), RCInstr(), RCInstr(), RCInstr())


NOP_WORD = SlotWord()
NOP_RC = RCInstr()


# ---- k-sweep macro ---------------------------------------------------------
# Generated kernel programs are dominated by "k-sweeps": the same per-RC
# instruction sequence replayed at a series of MXCU word indices k (a SETK
# configuration word followed by mxcu-NOP body words).  sweep_words() is the
# one builder all program generators share.  It memoizes the SlotWords per
# (instruction sequence, k, lane mask): the body words of a sweep do not
# depend on k at all, so every k (and every later pass/block reusing the
# pattern) gets the *same* word objects back.  That identity-sharing is what
# lets the vectorized engine (vector.py) recognize and cache repeated
# packets instead of re-analyzing tens of thousands of fresh dataclasses.

_SWEEP_CACHE: dict = {}
_ALL_LANES = (True, True, True, True)


def sweep_words(k: int, instrs, active=_ALL_LANES) -> list:
    """One sweep instance: SETK k, then `instrs` issued per cycle on the
    lanes enabled in `active` (inactive RCs issue NOPs; their cycles are
    still charged).  `instrs` must be a hashable tuple of RCInstr."""
    instrs = tuple(instrs)
    active = tuple(active)
    key = (instrs, active)
    body = _SWEEP_CACHE.get(key)
    if body is None:
        rcs_rows = [tuple(ins if active[r] else NOP_RC for r in range(4))
                    for ins in instrs]
        body = [SlotWord(rcs=rcs) for rcs in rcs_rows[1:]]
        _SWEEP_CACHE[key] = body
        _SWEEP_CACHE[key + ("heads",)] = {}
    heads = _SWEEP_CACHE[key + ("heads",)]
    head = heads.get(k)
    if head is None:
        rcs0 = tuple(instrs[0] if active[r] else NOP_RC for r in range(4))
        head = SlotWord(mxcu=MXCUInstr("SETK", k), rcs=rcs0)
        heads[k] = head
    return [head] + body

"""Public jit'd API for the flash-attention kernel."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    q_chunk: int = 256, kv_chunk: int = 256):
    """Flash attention with GQA and sliding-window support.
    q: (B,Sq,H,dh); k,v: (B,Skv,KV,dh)."""
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  q_chunk=q_chunk, kv_chunk=kv_chunk,
                                  interpret=_interpret())

"""Device-resident streaming loop: the on-device `lax.scan` steady state
must be BIT-identical to the host-driven per-batch reference for every
(n_frames, ring_depth) — dividing or not — and its drained telemetry
counters must match the per-batch retire accounting exactly, including
the zero-frame and tail-pad cases. Also covers the ring kernel's
slot-equivalence, the retire-count rebalance trigger, and the ring-depth
autotune path."""
import numpy as np
import pytest

from repro.core import autotune
from repro.core.biosignal import make_app, synthetic_respiration
from repro.kernels.pipeline.ops import (app_pipeline_ring,
                                        app_pipeline_stream)
from repro.serve.engine import ColumnScheduler
from repro.serve.resident import (ResidentConfig, ResidentStream,
                                  ring_chunk_samples)
from repro.serve.stream import (BiosignalStream, StreamConfig,
                                StreamTelemetry, frame_count)


class VirtualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt
        return self.t


def _signal(n_samples, seed=0):
    sig, _ = synthetic_respiration(1, n_samples, seed=seed)
    return sig[0]


def _assert_identical(out, ref):
    assert sorted(out) == sorted(ref)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(ref[k]), err_msg=k)


# ------------------------------------------------------------ ring kernel

@pytest.mark.parametrize("window,hop,bw,depth", [
    (512, 128, 4, 3),       # deep overlap, odd ring depth
    (512, 512, 2, 2),       # hop == window: no tail specs at all
    (1024, 320, 3, 4),      # hop does not divide window
])
def test_ring_kernel_matches_per_chunk(window, hop, bw, depth):
    """One (slot, block)-grid ring dispatch == `depth` independent
    per-chunk dispatches, to the last bit (the kernel body is shared)."""
    app = make_app()
    span = ring_chunk_samples(window, hop, bw)
    stride = bw * hop
    sig = _signal((depth - 1) * stride + span, seed=window + depth)
    ring = np.stack([np.asarray(sig[r * stride: r * stride + span])
                     for r in range(depth)])
    out = app_pipeline_ring(app, ring, window=window, hop=hop)
    for r in range(depth):
        ref = app_pipeline_stream(app, ring[r], window=window, hop=hop)
        for k in ref:
            assert out[k].shape == (depth,) + ref[k].shape, (k, out[k].shape)
            np.testing.assert_array_equal(
                np.asarray(out[k][r]),
                np.asarray(ref[k]), err_msg=f"slot {r} key {k}")


# ---------------------------------------------------- resident == host

CASES = [
    # (window, hop, batch_windows, ring_depth, n_samples) — the sweep
    # crosses dividing and non-dividing n_batches/ring_depth, hop==window,
    # non-dividing hop, and the rd > n_batches degenerate
    (512, 128, 4, 2, 128 * 32 + 512),        # n_batches divides ring depth
    (512, 128, 4, 3, 128 * 29 + 77),         # ragged tail, non-dividing rd
    (512, 512, 3, 2, 512 * 5 + 11),          # hop == window, odd frames
    (1024, 320, 2, 4, 320 * 9 + 1024 + 5),   # hop does not divide window
    (2048, 512, 8, 4, 512 * 40 + 2048),      # the paper-default shape
    (512, 256, 4, 8, 256 * 3 + 512),         # ring deeper than the signal
]


@pytest.mark.parametrize("window,hop,bw,rd,n_samples", CASES)
def test_resident_matches_host(window, hop, bw, rd, n_samples):
    app = make_app()
    sig = _signal(n_samples, seed=hop + rd)
    cfg = StreamConfig(window=window, hop=hop, batch_windows=bw)
    ref = BiosignalStream(app, cfg).process(sig)
    out = ResidentStream(app, cfg, ResidentConfig(ring_depth=rd)).process(sig)
    n = frame_count(n_samples, window, hop)
    assert out["class"].shape == (n,)
    _assert_identical(out, ref)


def test_resident_zero_frames():
    """A signal shorter than one window: same degenerate contract as the
    host path — canonical empty dict, no retires, no drains."""
    app = make_app()
    cfg = StreamConfig(window=512, hop=256, batch_windows=4)
    tel = StreamTelemetry(clock=VirtualClock())
    rs = ResidentStream(app, cfg, telemetry=tel, stream_id="cold")
    out = rs.process(np.zeros(100, np.float32))
    ref = BiosignalStream(app, cfg).process(np.zeros(100, np.float32))
    _assert_identical(out, ref)
    assert all(v.shape[0] == 0 for v in out.values())
    assert rs.last_drains == []
    assert tel.column_stats(1)[0].windows == 0


def test_process_resident_entry_point():
    """`BiosignalStream.process_resident` == `process`, and the lazy
    `ResidentStream` sibling is cached across calls."""
    app = make_app()
    sig = _signal(128 * 40 + 512, seed=3)
    bs = BiosignalStream(app, StreamConfig(window=512, hop=128,
                                           batch_windows=4))
    rcfg = ResidentConfig(ring_depth=2)
    _assert_identical(bs.process_resident(sig, rcfg), bs.process(sig))
    first = bs._resident
    bs.process_resident(sig, rcfg)
    assert bs._resident is first            # same rcfg -> cached sibling
    bs.process_resident(sig, ResidentConfig(ring_depth=4))
    assert bs._resident is not first        # new rcfg -> rebuilt


# ------------------------------------------------------- drain accounting

@pytest.mark.parametrize("drain_interval", [1, 2, 3, 7])
@pytest.mark.parametrize("window,hop,bw,rd,n_samples", [
    (512, 128, 4, 2, 128 * 32 + 512),
    (512, 256, 3, 3, 256 * 20 + 99),        # ragged tail batch
    (512, 512, 2, 2, 512 * 5),              # exact cover, no pad
])
def test_drain_totals_match_host_accounting(drain_interval, window, hop,
                                            bw, rd, n_samples):
    """Counters drained every k sweeps must sum to EXACTLY what the
    per-batch host path reports retire-by-retire: same total windows,
    tail-pad frames never counted, final drain always lands."""
    app = make_app()
    sig = _signal(n_samples, seed=drain_interval)
    cfg = StreamConfig(window=window, hop=hop, batch_windows=bw)
    n = frame_count(n_samples, window, hop)

    host_tel = StreamTelemetry(clock=VirtualClock())
    host = BiosignalStream(app, cfg, telemetry=host_tel, stream_id="h")
    host.process(sig)

    res_tel = StreamTelemetry(clock=VirtualClock())
    drains = []
    res_tel.add_retire_listener(lambda sid, nw: drains.append(nw))
    rs = ResidentStream(app, cfg,
                        ResidentConfig(ring_depth=rd,
                                       drain_interval=drain_interval),
                        telemetry=res_tel, stream_id="r")
    rs.process(sig)

    assert sum(drains) == n
    assert res_tel.column_stats(1)[0].windows == \
        host_tel.column_stats(1)[0].windows == n
    # cumulative snapshots: monotone, end at the full frame count
    assert rs.last_drains == sorted(rs.last_drains)
    assert rs.last_drains[-1] == n
    # drain COUNT: one per full interval plus the forced final drain
    n_batches = -(-n // bw)
    n_sweeps = -(-n_batches // rd)
    expect = max(1, n_sweeps // drain_interval +
                 (1 if n_sweeps % drain_interval else 0))
    assert len(drains) == expect


# --------------------------------------------------- retire-count trigger

def test_retire_trigger_feeds_on_drains():
    """The scheduler's retire-count trigger consumes resident-mode drains
    exactly like per-batch retires — no host poller anywhere."""
    clock = VirtualClock()
    tel = StreamTelemetry(clock=clock)
    sched = ColumnScheduler(devices=[None], telemetry=tel,
                            rebalance_every=10 ** 9)
    device = sched.admit("res-stream")
    assert device is None                   # the placeholder column
    app = make_app()
    cfg = StreamConfig(window=512, hop=256, batch_windows=4)
    sig = _signal(256 * 24 + 512, seed=5)
    rs = ResidentStream(app, cfg, ResidentConfig(ring_depth=2,
                                                 drain_interval=2),
                        telemetry=tel, stream_id="res-stream")
    rs.process(sig)
    n = frame_count(sig.shape[0], 512, 256)
    assert sched._retired_since_rebalance == n


def test_retire_trigger_rebalances_and_queues_moves():
    clock = VirtualClock()
    tel = StreamTelemetry(clock=clock)
    sched = ColumnScheduler(devices=["d0", "d1"], telemetry=tel,
                            rebalance_every=60)
    for sid in ("s1", "s2", "s3"):
        sched.admit(sid)                    # round-robin: s1,s3 -> col0
    assert sched.column_of("s3") == 0
    # warm the rates: s1 and s3 are heavy (10 windows per tick), s2 light
    for _ in range(6):
        clock.advance(1.0)
        tel.record_retire("s1", 10)
        tel.record_retire("s3", 10)
        tel.record_retire("s2", 1)
    # the trigger fired mid-loop (>= 60 windows retired) and queued the
    # work-stealing move off the overloaded column 0
    moves = sched.pop_moves()
    assert moves, "retire-count trigger never rebalanced"
    assert set(moves.values()) <= {"d0", "d1"}
    assert sched.pop_moves() == {}          # drained
    # a foreign stream sharing the telemetry never counts
    before = sched._retired_since_rebalance
    tel.record_retire("not-mine", 500)
    assert sched._retired_since_rebalance == before


# ------------------------------------------------------- ring-depth tuning

def test_candidate_ring_depths():
    assert autotune.candidate_ring_depths(1) == [1]
    for n in (2, 3, 5, 16, 40):
        cands = autotune.candidate_ring_depths(n)
        assert cands and cands == sorted(cands, reverse=True)
        assert all(d & (d - 1) == 0 and d <= n for d in cands)
        assert len(cands) <= 4
        # depth 1 survives the top-4 cut whenever there's room for it
        assert 1 in cands or len(cands) == 4


def test_resident_autotune_matches_host():
    """The measured ring depth is a pure perf knob: whatever wins, the
    outputs stay bit-identical and the winner is cached per shape."""
    autotune.clear_cache()
    try:
        app = make_app()
        cfg = StreamConfig(window=512, hop=256, batch_windows=2)
        sig = _signal(256 * 15 + 512, seed=11)
        ref = BiosignalStream(app, cfg).process(sig)
        rs = ResidentStream(app, cfg, ResidentConfig(autotune=True))
        _assert_identical(rs.process(sig), ref)
        cache = autotune.cache_snapshot()
        assert any(k[0] == "resident_ring" for k in cache)
        rs.process(sig)                     # second call: cache hit
        assert autotune.cache_snapshot() == cache
    finally:
        autotune.clear_cache()

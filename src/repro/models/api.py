"""Public model API: build_model(cfg) -> Model with schema/forward/decode.

All entry points are pure functions of (params, batch) suitable for jit /
pjit; abstract variants (eval_shape-compatible) are used by the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import attention as att  # noqa: F401 (re-export)
from repro.models import layers as L
from repro.models import transformer as tfm
from repro.models.layers import P
from repro.sharding.ctx import constrain


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    schema: dict
    plan: list
    forward: Callable      # (params, batch) -> (logits, aux)
    prefill: Callable      # (params, batch, cache) -> (logits, cache)
    decode: Callable       # (params, batch, cache) -> (logits, cache)
    cache_schema: Callable  # (batch_size, max_len) -> schema tree
    loss: Callable         # (params, batch) -> (scalar, metrics)


def _embed_tokens(params, batch, cfg, *, mode):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens).astype(cfg.compute_dtype)
    if cfg.vlm_patches and mode != "decode" and "patch_emb" in batch:
        Pn = cfg.vlm_patches
        x = x.at[:, :Pn, :].set(batch["patch_emb"].astype(x.dtype))
    if cfg.is_encdec:  # whisper decoder: absolute sinusoidal positions
        S = tokens.shape[1]
        if mode == "decode":
            # position of the new token = cache_len (scalar or per-slot)
            B = tokens.shape[0]
            cl = jnp.broadcast_to(jnp.atleast_1d(batch["cache_len"]), (B,))
            pos_tab = L.sinusoidal_positions(8192, cfg.d_model, x.dtype)
            x = x + pos_tab[cl][:, None, :]
        else:
            x = x + L.sinusoidal_positions(S, cfg.d_model, x.dtype)[None]
    if "embed_norm" in params:
        x = L.apply_norm(params["embed_norm"], x, kind="layernorm",
                         eps=cfg.norm_eps)
    return constrain(x, "btd")


def _positions(batch, cfg, *, mode):
    tokens = batch["tokens"]
    B, S = tokens.shape
    if cfg.rope_style == "mrope":
        return batch["positions"]
    if mode == "decode":
        cl = jnp.broadcast_to(jnp.atleast_1d(batch["cache_len"]), (B,))
        return cl[:, None]
    return jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))


def _final_logits(params, x, cfg):
    x = L.apply_norm(params["final_norm"], x, kind=cfg.norm_type,
                     eps=cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = L.linear_head(params["head"], x)
    return constrain(logits, "btv")


def _encode(params, batch, cfg, enc_plan):
    frames = batch["frames"].astype(cfg.compute_dtype)
    S = frames.shape[1]
    x = frames + L.sinusoidal_positions(S, cfg.d_model, frames.dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], frames.shape[:2])
    ctx = tfm.Ctx(cfg=cfg, mode="train", positions=pos, causal=False)
    x, _, _ = tfm.apply_stack(params["encoder"], x, enc_plan, ctx)
    return L.apply_norm(params["enc_norm"], x, kind=cfg.norm_type,
                        eps=cfg.norm_eps)


def build_model(cfg) -> Model:
    plan = tfm.stack_plan(cfg)
    enc_plan = tfm.encoder_plan(cfg) if cfg.is_encdec else None

    schema: dict = {
        "embed": L.embed_schema(cfg.vocab_size, cfg.d_model),
        "stack": tfm.stack_schema(cfg, plan),
        "final_norm": L.norm_schema(cfg.d_model, cfg.norm_type),
    }
    if not cfg.tie_embeddings:
        schema["head"] = L.linear_head_schema(cfg.d_model, cfg.vocab_size)
    if cfg.shared_attn_every:
        schema["shared_attn"] = tfm.shared_attn_schema(cfg)
    if cfg.is_encdec:
        schema["encoder"] = tfm.stack_schema(cfg, enc_plan)
        schema["enc_norm"] = L.norm_schema(cfg.d_model, cfg.norm_type)
    if cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
        schema["embed_norm"] = L.norm_schema(cfg.d_model, "layernorm")

    def _run(params, batch, cache, mode):
        x = _embed_tokens(params, batch, cfg, mode=mode)
        pos = _positions(batch, cfg, mode=mode)
        enc_out = None
        if cfg.is_encdec and mode != "decode":
            enc_out = _encode(params, batch, cfg, enc_plan)
        elif cfg.is_encdec and "enc_out" in batch:   # optional override
            enc_out = batch["enc_out"].astype(cfg.compute_dtype)
        cache_len = batch.get("cache_len") if mode == "decode" else None
        ctx = tfm.Ctx(cfg=cfg, mode=mode, positions=pos, cache_len=cache_len,
                      causal=True, enc_out=enc_out,
                      shared=params.get("shared_attn"))
        x, new_cache, aux = tfm.apply_stack(params["stack"], x, plan, ctx,
                                            cache=cache)
        logits = _final_logits(params, x, cfg)
        return logits, new_cache, aux

    def forward(params, batch):
        logits, _, aux = _run(params, batch, None, "train")
        return logits, aux

    def prefill(params, batch, cache):
        logits, new_cache, _ = _run(params, batch, cache, "prefill")
        return logits[:, -1:, :], new_cache

    def decode(params, batch, cache):
        logits, new_cache, _ = _run(params, batch, cache, "decode")
        return logits, new_cache

    def cache_schema_fn(batch_size: int, max_len: int):
        return tfm.cache_schema(cfg, plan, batch_size, max_len)

    def loss(params, batch):
        logits, aux = forward(params, batch)
        ce = L.cross_entropy_loss(logits, batch["labels"])
        total = ce + aux
        return total, {"loss": total, "ce": ce, "aux": aux}

    return Model(cfg=cfg, schema=schema, plan=plan, forward=forward,
                 prefill=prefill, decode=decode,
                 cache_schema=cache_schema_fn, loss=loss)


# ---------------------------------------------------------------------------
# Convenience
# ---------------------------------------------------------------------------

def init_model_params(model: Model, seed: int = 0):
    return L.init_params(jax.random.PRNGKey(seed), model.schema,
                         model.cfg.param_dtype)


def init_cache(model: Model, batch_size: int, max_len: int):
    schema = model.cache_schema(batch_size, max_len)
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, p.dtype or jnp.float32),
        schema, is_leaf=lambda x: isinstance(x, P))


def abstract_cache(model: Model, batch_size: int, max_len: int):
    schema = model.cache_schema(batch_size, max_len)
    return L.abstract_params(schema, jnp.float32)

"""Doc-sync: every `file.py` / `file.py:symbol` reference in docs/ and
README.md must resolve against the tree — the same check the CI lint job
runs via `tools/check_docs.py`. Plus negative coverage so the checker
itself can't silently rot into a yes-machine."""
import importlib.util
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_docs", ROOT / "tools" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_repo_docs_are_in_sync(capsys):
    mod = _load_checker()
    assert mod.main([]) == 0, capsys.readouterr().err


def test_checker_flags_broken_references(tmp_path, monkeypatch):
    mod = _load_checker()
    doc = tmp_path / "bad.md"
    doc.write_text(
        "See `serve/stream.py:BiosignalStream` (real) but also\n"
        "`serve/no_such_module.py` and `serve/stream.py:NoSuchClass`\n"
        "and a [dead link](missing_page.md).\n")
    errors = mod.check_file(doc.resolve())
    msgs = "\n".join(errors)
    assert len(errors) == 3, msgs
    assert "no_such_module.py" in msgs
    assert "NoSuchClass" in msgs
    assert "missing_page.md" in msgs


def test_checker_symbol_resolution():
    mod = _load_checker()
    src = ("CONST = 3\n"
           "class Foo:\n"
           "    bar: int = 1\n"
           "    def baz(self):\n"
           "        pass\n")
    assert mod.symbol_defined(src, "CONST")
    assert mod.symbol_defined(src, "Foo")
    assert mod.symbol_defined(src, "Foo.baz")
    assert mod.symbol_defined(src, "Foo.bar")
    assert not mod.symbol_defined(src, "Foo.qux")
    assert not mod.symbol_defined(src, "missing")


def test_checker_cli_exit_codes():
    mod = _load_checker()
    assert mod.main(["README.md"]) == 0
    assert mod.main(["docs"]) == 0
    assert mod.main(["no/such/dir"]) == 2

"""The MBioTracker biosignal application (paper §4.4.2) on the VWR2A core
library: preprocessing -> delineation -> feature extraction -> SVM.

Pipeline (paper §4.4.2, cognitive-workload estimation from respiration):
  1. *Preprocessing*: 11-tap FIR low-pass over the raw signal.
  2. *Delineation*: detect maxima/minima of the filtered signal to extract
     inspiration/expiration times (the control-intensive step the paper
     highlights — here vectorized into mask algebra, the JAX-native
     equivalent of VWR2A's predicated RC code).
  3. *Feature extraction*: time features (mean, median, RMS of the
     inspiration/expiration intervals) + frequency features from a
     512-point real-valued FFT of the filtered window (band powers).
  4. *Prediction*: linear SVM.

Everything is jit-able; the windowed app is a pure function of the signal.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.fft import rfft_packed
from repro.core.fir import fir_direct, lowpass_taps


# ---------------------------------------------------------------------------
# Delineation
# ---------------------------------------------------------------------------

def _dilate(x, reduce, d: int):
    """Running reduce (max/min) over [t - d, t + d] in log-steps of
    edge-padded shifts — the vectorized morphological dilation that backs
    the delineation refractory window. Shift+select only (Mosaic-safe)."""
    steps, span, s = [], 0, 1
    while span < d:
        steps.append(min(s, d - span))
        span += steps[-1]
        s *= 2
    fwd = bwd = x
    for s in steps:
        fwd = reduce(fwd, jnp.concatenate(
            [fwd[..., s:], fwd[..., -1:].repeat(s, axis=-1)], axis=-1))
        bwd = reduce(bwd, jnp.concatenate(
            [bwd[..., :1].repeat(s, axis=-1), bwd[..., :-s]], axis=-1))
    return reduce(fwd, bwd)


def delineate(x, *, min_prominence: float = 0.3, min_distance: int = 15):
    """Detect local maxima/minima: strict neighbour extremum + amplitude
    gate (x must rise above mean + prominence*(max-mean), resp. below) +
    a +-`min_distance`-sample refractory window (the extremum must
    dominate its neighbourhood — breaths are seconds apart at fs=64 Hz,
    so sensor ripple a few samples wide is not a breath).

    Returns (is_max, is_min): boolean masks over the window. This is the
    paper's 'lots of if conditions' step, recast as vector predicates. The
    refractory gate also bounds the interval density — consecutive
    extrema sit >= min_distance + 1 apart (ties excepted), which keeps the
    interval-median's fixed-size `INTERVAL_SLOTS` sorting network on its
    fast path for windows up to INTERVAL_SLOTS*(min_distance+1) samples.
    """
    prev = jnp.roll(x, 1, axis=-1)
    nxt = jnp.roll(x, -1, axis=-1)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    hi = jnp.max(x, axis=-1, keepdims=True)
    lo = jnp.min(x, axis=-1, keepdims=True)
    is_max = (x > prev) & (x >= nxt) & (x > mu + min_prominence * (hi - mu))
    is_min = (x < prev) & (x <= nxt) & (x < mu - min_prominence * (mu - lo))
    if min_distance > 0:
        is_max &= x >= _dilate(x, jnp.maximum, min_distance)
        is_min &= x <= _dilate(x, jnp.minimum, min_distance)
    # edges are never extrema
    edge = jnp.zeros_like(is_max).at[..., 0].set(True).at[..., -1].set(True)
    return is_max & ~edge, is_min & ~edge


def _masked_intervals_sort(mask):
    """Seed reference: mean/median/RMS of gaps between consecutive True
    positions via compaction `sort` + `take_along_axis`. Kept ONLY as the
    equivalence oracle for `_masked_intervals` — `sort`/`take_along_axis`
    are the known Mosaic-compile gap, so nothing on the kernel path may
    call this."""
    S = mask.shape[-1]
    pos = jnp.arange(S)
    idx = jnp.where(mask, pos, S + 1)
    sidx = jnp.sort(idx, axis=-1)
    gaps = jnp.diff(sidx, axis=-1)
    valid = (sidx[..., 1:] <= S) & (sidx[..., :-1] <= S)
    n = jnp.maximum(jnp.sum(valid, axis=-1), 1)
    g = jnp.where(valid, gaps, 0.0).astype(jnp.float32)
    mean = jnp.sum(g, axis=-1) / n
    rms = jnp.sqrt(jnp.sum(jnp.square(g), axis=-1) / n)
    # masked median: middle of the valid prefix of the sorted gap list
    gs = jnp.sort(jnp.where(valid, gaps, jnp.iinfo(jnp.int32).max), axis=-1)
    med = jnp.take_along_axis(gs, ((n - 1) // 2)[..., None], axis=-1)[..., 0]
    med = jnp.where(jnp.sum(valid, axis=-1) > 0, med, 0).astype(jnp.float32)
    return mean, med, rms


@functools.lru_cache(maxsize=None)
def oddeven_tables(n: int) -> tuple:
    """Stage tables of Batcher's odd-even merge sort for a power-of-two
    length `n`: (lo, hi, ks) numpy arrays of shape (n_stages, n) x2 and
    (n_stages, 1). Stage s compare-exchanges the disjoint pairs
    (t, t + ks[s]): a slot with lo[s, t] keeps min(x[t], x[t+k]), a slot
    with hi[s, t] keeps max(x[t], x[t-k]). Classic Batcher pairing — t in
    the upper-k half of its 2k-group (offset by k%p), both endpoints in
    the same 2p-block.

    The tables are STAGED OPERANDS of the fused kernel (like the FFT
    twiddle tables — the paper keeps such tables in the SPM): Pallas
    kernels cannot capture array constants, and recomputing the masks
    every `fori_loop` iteration doubles the per-stage op count."""
    assert n >= 1 and n & (n - 1) == 0, n
    t = np.arange(n)
    los, his, ks = [], [], []
    p = 1
    while p < n:
        k = p
        while k >= 1:
            lo = (((t - (k % p)) % (2 * k)) < k) & (t + k < n) & \
                ((t // (2 * p)) == ((t + k) // (2 * p)))
            los.append(lo)
            his.append(np.roll(lo, k))   # lo slots >= n-k are False: no wrap
            ks.append(k)
            k //= 2
        p *= 2
    if not los:                          # n == 1: the empty network
        return (np.zeros((0, n), bool), np.zeros((0, n), bool),
                np.zeros((0, 1), np.int32))
    return (np.stack(los), np.stack(his),
            np.asarray(ks, np.int32).reshape(-1, 1))


def network_sort(x, tables=None):
    """Ascending sort along the last (power-of-two) axis via Batcher's
    odd-even merge network: O(log^2 n) vectorized stages of shift +
    select, driven by the `oddeven_tables` stage masks. No `sort`,
    `take_along_axis`, or gather — shifts, compares and selects only,
    closing the fused kernel's Mosaic-compile gap. `tables` lets a Pallas
    caller pass the masks as staged kernel operands."""
    n = x.shape[-1]
    assert n & (n - 1) == 0, f"network_sort needs a power-of-two length: {n}"
    lo_t, hi_t, k_t = tables if tables is not None else tuple(
        jnp.asarray(a) for a in oddeven_tables(n))
    n_stages = lo_t.shape[0]
    if n_stages == 0:                # n == 1: the empty network
        return x

    def stage(s, y):
        k = k_t[s, 0]
        lo = jax.lax.dynamic_slice_in_dim(lo_t, s, 1, 0)[0]
        hi = jax.lax.dynamic_slice_in_dim(hi_t, s, 1, 0)[0]
        z = jnp.concatenate([y, y], axis=-1)          # one buffer, two views
        fwd = jax.lax.dynamic_slice_in_dim(z, k, n, z.ndim - 1)
        bwd = jax.lax.dynamic_slice_in_dim(z, n - k, n, z.ndim - 1)
        return jnp.where(lo, jnp.minimum(y, fwd),
                         jnp.where(hi, jnp.maximum(y, bwd), y))

    # NOTE: keep the loop rolled — XLA CPU pessimizes any unrolling of this
    # body (unroll=4 measured 3x slower, full unroll 60x slower)
    return jax.lax.fori_loop(0, n_stages, stage, x)


def _network_sort_arith(x):
    """`network_sort` with the stage masks recomputed from iota arithmetic
    each iteration instead of read from tables. Slower (≈2x), but capture-
    free: this is the exact-fallback path inside Pallas kernels, where the
    fixed-size stage tables are sized for `INTERVAL_SLOTS` and a full-
    length sort has no table operand to read."""
    n = x.shape[-1]
    assert n & (n - 1) == 0, n
    t = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)

    def stage(x, a, j):
        k = jnp.left_shift(1, a - j)
        kmodp = jnp.where(j == 0, 0, k)      # k % p: k == p exactly at j == 0
        lo = (((t - kmodp) & (2 * k - 1)) < k) & (t + k < n) & \
            ((t >> (a + 1)) == ((t + k) >> (a + 1)))
        hi = jnp.roll(lo, k)
        fwd = jnp.roll(x, -k, axis=-1)
        bwd = jnp.roll(x, k, axis=-1)
        return jnp.where(lo, jnp.minimum(x, fwd),
                         jnp.where(hi, jnp.maximum(x, bwd), x))

    def outer(a, y):                  # p = 2^a; inner: k = p, p/2, ..., 1
        return jax.lax.fori_loop(
            0, a + 1, lambda j, z: stage(z, a, j), y)

    return jax.lax.fori_loop(0, max(n.bit_length() - 1, 0), outer, x)


def _interval_gaps(mask):
    """Gaps between consecutive True positions as mask algebra: a running
    cummax of the last-seen True index replaces the seed's compaction sort.
    Returns (gaps, valid) full-window arrays — position i carries the gap
    to its predecessor extremum iff valid[i]."""
    S = mask.shape[-1]
    pos = jnp.arange(S, dtype=jnp.int32)
    prev = jax.lax.cummax(jnp.where(mask, pos, -1), axis=mask.ndim - 1)
    prev_excl = jnp.concatenate(
        [jnp.full(mask.shape[:-1] + (1,), -1, prev.dtype), prev[..., :-1]],
        axis=-1)
    valid = mask & (prev_excl >= 0)
    gaps = jnp.where(valid, pos - prev_excl, 0)
    return gaps, valid


def _masked_intervals(mask, *, sparse2: bool = False, sort_tables=None):
    """Mean/median/RMS of gaps between consecutive True positions (masked
    statistics, fixed shapes — jit-friendly).

    Mosaic-compilable formulation: gap extraction is cummax mask algebra
    (`_interval_gaps`), the median is `network_sort` + a one-hot k-th-order
    pick. Matches `_masked_intervals_sort` exactly — gap values are small
    integers, so the f32 reductions are order-independent.

    ``sparse2`` promises no two ADJACENT positions are both True (always
    the case for `delineate` extrema: a strict rise cannot follow itself),
    letting the median pre-fold even/odd slots so the network runs at half
    the window length."""
    S = mask.shape[-1]
    gaps, valid = _interval_gaps(mask)
    nv = jnp.sum(valid, axis=-1)
    n = jnp.maximum(nv, 1)
    g = jnp.where(valid, gaps, 0).astype(jnp.float32)
    mean = jnp.sum(g, axis=-1) / n
    rms = jnp.sqrt(jnp.sum(jnp.square(g), axis=-1) / n)
    # gaps are in [0, S] — sort in the narrowest int the window allows to
    # halve the bytes the network moves
    sdt = jnp.int16 if S <= 2 ** 14 else jnp.int32
    big = jnp.iinfo(sdt).max
    vals = jnp.where(valid, gaps, big).astype(sdt)
    k = ((n - 1) // 2)[..., None].astype(jnp.int32)

    def kth_smallest(svals):
        sel = jax.lax.broadcasted_iota(jnp.int32, svals.shape,
                                       svals.ndim - 1)
        return jnp.sum(jnp.where(sel == k, svals.astype(jnp.int32), 0),
                       axis=-1)

    def pad_pow2(v, to=0):
        L = v.shape[-1]
        N = max(1 << max(L - 1, 0).bit_length(), to)
        if N == L:
            return v
        return jnp.concatenate(
            [v, jnp.full(mask.shape[:-1] + (N - L,), big, sdt)], axis=-1)

    collide = None                     # lossy-fold guard (traced bool)
    folded = vals
    if sparse2 and S % 2 == 0:
        # each even/odd slot pair SHOULD hold at most one valid gap
        # (guaranteed for delineate extrema, which are never adjacent) —
        # fold to S/2, but GUARD it: sparse2 is a caller promise, not a
        # property of the mask argument
        ev, od = vals[..., 0::2], vals[..., 1::2]
        folded = jnp.minimum(ev, od)
        collide = jnp.any((ev < big) & (od < big))
    folded = pad_pow2(folded, INTERVAL_SLOTS)
    K = INTERVAL_SLOTS
    if folded.shape[-1] > K:
        # compact into the fixed K-slot buffer: fold segments of N/K
        # slots by min. Exact whenever every segment holds at most one
        # interval (sentinels are +inf) — true for any physiological
        # signal, where extrema sit far apart. A colliding segment
        # anywhere joins the guard below.
        N = folded.shape[-1]
        seg = jnp.sum((folded < big).reshape(mask.shape[:-1] + (K, N // K)),
                      axis=-1)
        seg_collide = jnp.any(seg > 1)
        collide = seg_collide if collide is None else collide | seg_collide
        y = folded
        while y.shape[-1] > K:
            y = jnp.minimum(y[..., 0::2], y[..., 1::2])
        folded = y

    def fast(_):
        return kth_smallest(network_sort(folded, tables=sort_tables))

    if collide is None:
        # no lossy fold happened: the fixed-size network is always exact
        med = fast(None)
    else:
        # any collision routes the whole batch to a full-length network
        # over the UNFOLDED gaps (rare, slower, always exact)
        full = pad_pow2(vals)

        def slow(_):
            return kth_smallest(_network_sort_arith(full))

        med = jax.lax.cond(collide, slow, fast, None)
    med = jnp.where(nv > 0, med, 0).astype(jnp.float32)
    return mean, med, rms


# ---------------------------------------------------------------------------
# Features + SVM
# ---------------------------------------------------------------------------

# The FIXED size of the interval median's sorting network: one VWR worth of
# interval candidates (128 32-bit words, paper §3.1). Windows whose folded
# gap array is longer are compacted into this buffer by segment folding
# (exact whenever no segment holds two intervals — guarded, with a full-
# length network fallback), so the kernel's hot sort always runs at 128
# slots regardless of the window length.
INTERVAL_SLOTS = 128


def interval_time_features(is_max, is_min, sort_tables=None) -> list:
    """The 6 time features: mean/median/RMS of the inspiration and
    expiration interval lengths (single source — also run inside the fused
    pipeline kernel). Both masks ride ONE sorting-network pass (stacked
    along the batch axis), and extrema are never adjacent, so the median
    network runs at half the window length (`sparse2`). ``sort_tables``
    forwards staged `oddeven_tables` operands from a Pallas caller."""
    if is_max.ndim >= 2:
        both = jnp.concatenate([is_max, is_min], axis=0)
        mean, med, rms = _masked_intervals(both, sparse2=True,
                                           sort_tables=sort_tables)
        R = is_max.shape[0]
        return [mean[:R], med[:R], rms[:R], mean[R:], med[R:], rms[R:]]
    f_time = []
    for mask in (is_max, is_min):
        mean, med, rms = _masked_intervals(mask, sparse2=True,
                                           sort_tables=sort_tables)
        f_time += [mean, med, rms]
    return f_time


def band_power_features(power, fft_size: int) -> list:
    """The 6 log-band powers over a (B, fft/2+1) power spectrum (single
    source — also run inside the fused pipeline kernel)."""
    nb = fft_size // 2 + 1
    bands = np.linspace(1, nb, 7, dtype=int)         # 6 log-ish bands
    return [jnp.log1p(jnp.sum(power[..., a:b], axis=-1))
            for a, b in zip(bands[:-1], bands[1:])]


def extract_features(filtered, fft_size: int = 512):
    """(B, S) filtered window -> (B, F) feature matrix (F = 12)."""
    is_max, is_min = delineate(filtered)
    f_time = interval_time_features(is_max, is_min)
    seg = filtered[..., :fft_size]
    seg = seg - jnp.mean(seg, axis=-1, keepdims=True)
    Xr, Xi = rfft_packed(seg)
    power = jnp.square(Xr) + jnp.square(Xi)          # (B, fft/2+1)
    return jnp.stack(f_time + band_power_features(power, fft_size), axis=-1)


def svm_predict(features, w, b):
    """Linear SVM margin + class. w: (F, C), b: (C,)."""
    margin = features @ w + b
    return margin, jnp.argmax(margin, axis=-1)


def svm_fit_least_squares(features, labels, n_classes: int = 2,
                          ridge: float = 1e-3):
    """Tiny ridge-regression 'SVM' fit (tests/examples; the paper runs a
    pre-trained SVM — the prediction path is what executes on VWR2A)."""
    F = features.shape[-1]
    y = jax.nn.one_hot(labels, n_classes) * 2 - 1
    A = features.T @ features + ridge * jnp.eye(F)
    w = jnp.linalg.solve(A, features.T @ y)
    b = jnp.mean(y - features @ w, axis=0)
    return w, b


# ---------------------------------------------------------------------------
# Full application
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BiosignalApp:
    fir_taps: np.ndarray
    svm_w: jnp.ndarray
    svm_b: jnp.ndarray
    fft_size: int = 512

    def __call__(self, signal):
        filtered = fir_direct(signal, jnp.asarray(self.fir_taps))
        feats = extract_features(filtered, self.fft_size)
        margin, cls = svm_predict(feats, self.svm_w, self.svm_b)
        return {"filtered": filtered, "features": feats,
                "margin": margin, "class": cls}


def make_app(cfg=None, seed: int = 0) -> BiosignalApp:
    from repro.configs.vwr2a_biosignal import CONFIG as BIO

    cfg = cfg or BIO
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(12, cfg.svm_classes)).astype(np.float32))
    b = jnp.zeros((cfg.svm_classes,), jnp.float32)
    return BiosignalApp(fir_taps=lowpass_taps(cfg.fir_taps),
                        svm_w=w, svm_b=b, fft_size=cfg.fft_size)


def synthetic_respiration(batch: int, samples: int, *, rate_hz: float = 0.3,
                          fs: float = 64.0, noise: float = 0.15, seed: int = 0):
    """Synthetic respiration-like signal: slow sinusoid + drift + noise."""
    rng = np.random.default_rng(seed)
    t = np.arange(samples) / fs
    rates = rate_hz * (1 + 0.3 * rng.standard_normal((batch, 1)))
    phase = rng.uniform(0, 2 * np.pi, (batch, 1))
    sig = np.sin(2 * np.pi * rates * t[None, :] + phase)
    sig += 0.2 * np.sin(2 * np.pi * 1.1 * t[None, :])     # cardiac bleed
    sig += noise * rng.standard_normal((batch, samples))
    return jnp.asarray(sig.astype(np.float32)), jnp.asarray(
        (rates[:, 0] > rate_hz).astype(np.int32))

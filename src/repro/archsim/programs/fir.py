"""11-tap FIR mapped onto the VWR2A simulator (paper §4.4.1).

The input blocks are independent, so they are dealt round-robin to however
many columns the machine has (paper mapping: both columns on different
slices of the input; ``n_columns`` generalizes it).  Each 128-word block
pass stages the current block in VWR A and the previous block in VWR B;
the (k-1)-word boundary reads use the virtual [B;A] window (the
circular-shift boundary delivery of §3.3.1).  Taps are q16.15 immediates
in the configuration words.  21 RC-cycles per output word (1 FXMUL + 10
FXMUL/ADD pairs), MXCU INCK and LCU looping ride in parallel slots.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.archsim.isa import LSUInstr, RCInstr, SlotWord, sweep_words
from repro.archsim.machine import RC_SLICE, VWR_WORDS, VWR2A, to_q15_arr


@functools.lru_cache(maxsize=64)
def _mac_instrs(taps_q15: tuple):
    """The per-output 21-cycle MAC sequence (k-independent)."""
    k_taps = len(taps_q15)
    seq = [RCInstr("FXMUL", ("win", 0), ("imm", taps_q15[0]), ("reg", 0))]
    for i in range(1, k_taps):
        seq.append(RCInstr("FXMUL", ("win", -i), ("imm", taps_q15[i]), None))
        dest = ("vwr", "C", 0) if i == k_taps - 1 else ("reg", 0)
        seq.append(RCInstr("ADD", ("reg", 0), ("rc", 0), dest))
    return tuple(seq)


def gen_fir_block(x_line: int, prev_line: int, out_line: int,
                  taps_q15: tuple):
    """One 128-output FIR pass: LOAD A/B, 32 x 21-cycle MACs, STORE C."""
    instrs = _mac_instrs(tuple(taps_q15))
    words = [
        SlotWord(lsu=LSUInstr("LOAD", "A", ("imm", x_line))),
        SlotWord(lsu=LSUInstr("LOAD", "B", ("imm", prev_line))),
    ]
    for k in range(RC_SLICE):
        words += sweep_words(k, instrs)
    words.append(SlotWord(lsu=LSUInstr("STORE", "C", ("imm", out_line))))
    return words


def run_fir(x: np.ndarray, taps: np.ndarray, *,
            machine: VWR2A | None = None, charge_dma: bool = True,
            n_columns: int | None = None):
    """Simulate the FIR over a real-valued signal (len multiple of 128).
    Returns (y, counters, wall_cycles)."""
    m = machine or VWR2A(n_columns or 2)
    nc = m.n_columns
    n = x.shape[0]
    assert n % VWR_WORDS == 0
    n_lines = n // VWR_WORDS
    out_base = 24                          # output region in the SPM
    assert out_base + n_lines <= 48

    xq = to_q15_arr(x)
    if charge_dma:
        for ln in range(n_lines):
            m.dma_in(ln, xq[ln * VWR_WORDS: (ln + 1) * VWR_WORDS])
    else:
        m.spm[:n_lines] = xq.reshape(n_lines, VWR_WORDS)
    # zero line for the first block's boundary
    ZERO_LINE = 63
    m.spm[ZERO_LINE] = 0

    tq = tuple(int(v) for v in to_q15_arr(np.asarray(taps, np.float64)))
    for ln in range(n_lines):              # columns take alternating blocks
        prev = ZERO_LINE if ln == 0 else ln - 1
        prog = gen_fir_block(ln, prev, out_base + ln, tq)
        progs = [[] for _ in range(nc)]
        progs[ln % nc] = prog
        m.run(progs)

    yq = m.spm[out_base: out_base + n_lines].reshape(-1).copy()
    if charge_dma:
        m.dma_out(out_base, n)
    y = yq.astype(np.float64) / (1 << 15)
    cycles = max(c.counters.cycles for c in m.cols)
    return y, m.counters(), cycles

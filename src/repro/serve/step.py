"""Serving steps: batched prefill and single-token decode with a sharded KV
cache (or SSM state), pjit-ready with full sharding trees.

Serving layout (see sharding/rules.py): weights TP-sharded over `model` and
replicated over `data`; requests sharded over (pod, data); KV cache sharded
over kv-heads when they divide the TP degree, otherwise over the *sequence*
axis — the flash-decoding layout: each model-rank attends to its slice of
the context and XLA's SPMD partitioner inserts the small (m, l) softmax-
combine all-reduces instead of an all-gather of the cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import abstract_cache, layers as L
from repro.sharding import ctx as shard_ctx
from repro.sharding.rules import Strategy, sharding_tree
from repro.train.step import batch_shardings_for


@dataclasses.dataclass
class ServeBundle:
    prefill_fn: Any
    decode_fn: Any
    abstract_params: Any
    abstract_cache: Any
    param_shardings: Any
    cache_shardings: Any
    mesh: Any


def make_serve_step(model, mesh, batch_tree: dict, *, batch_size: int,
                    max_len: int, strategy: Strategy | None = None):
    cfg = model.cfg
    strategy = strategy or Strategy("serve")

    ax = L.axes_tree(model.schema)
    # serve with bf16 weights (deployment-realistic; params are cast on load)
    abs_params = L.abstract_params(model.schema, cfg.compute_dtype)
    param_sh = sharding_tree(ax, abs_params, mesh, strategy)

    cache_schema = model.cache_schema(batch_size, max_len)
    cache_ax = L.axes_tree(cache_schema)
    abs_cache = L.abstract_params(cache_schema, jnp.float32)
    # honour per-leaf dtypes in the cache schema
    abs_cache = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype or jnp.float32),
        cache_schema, is_leaf=lambda x: hasattr(x, "axes"))
    cache_sh = sharding_tree(cache_ax, abs_cache, mesh, strategy)
    batch_sh = batch_shardings_for(batch_tree, mesh, strategy)

    def _prefill(params, batch, cache):
        shard_ctx.install(mesh, strategy.name)
        return model.prefill(params, batch, cache)

    def _decode(params, batch, cache):
        shard_ctx.install(mesh, strategy.name)
        return model.decode(params, batch, cache)

    prefill_fn = jax.jit(
        _prefill,
        in_shardings=(param_sh, batch_sh, cache_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(2,),
    )
    decode_fn = jax.jit(
        _decode,
        in_shardings=(param_sh, batch_sh, cache_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(2,),
    )
    return ServeBundle(prefill_fn=prefill_fn, decode_fn=decode_fn,
                       abstract_params=abs_params, abstract_cache=abs_cache,
                       param_shardings=param_sh, cache_shardings=cache_sh,
                       mesh=mesh)

"""Flash-attention Pallas kernel: shape/dtype/GQA/window sweeps vs oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_ref


@pytest.mark.parametrize("shapes", [
    (2, 256, 8, 4, 64),    # GQA group 2
    (1, 128, 4, 4, 32),    # MHA
    (2, 256, 8, 2, 64),    # GQA group 4
    (1, 512, 4, 1, 128),   # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_reference(shapes, causal, rng):
    B, S, H, KV, dh = shapes
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, KV, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, KV, dh)).astype(np.float32))
    got = flash_attention(q, k, v, causal=causal, q_chunk=64, kv_chunk=64)
    want = flash_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("window", [32, 96])
def test_flash_sliding_window(window, rng):
    q = jnp.asarray(rng.normal(size=(2, 256, 4, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 256, 2, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 256, 2, 32)).astype(np.float32))
    got = flash_attention(q, k, v, causal=True, window=window,
                          q_chunk=64, kv_chunk=64)
    want = flash_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


def test_flash_bf16(rng):
    q = jnp.asarray(rng.normal(size=(1, 128, 4, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 64))).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, q_chunk=64, kv_chunk=64)
    assert got.dtype == jnp.bfloat16
    want = flash_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                     v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=0.03, rtol=0.03)


def test_flash_cross_chunk_sizes(rng):
    q = jnp.asarray(rng.normal(size=(1, 256, 2, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 32)).astype(np.float32))
    ref = flash_ref(q, k, v)
    for qc, kc in [(32, 128), (128, 32), (256, 256)]:
        got = flash_attention(q, k, v, q_chunk=qc, kv_chunk=kc)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=3e-5, rtol=3e-5)

"""Oracle for the flash-attention kernel: the O(S^2) reference from
models/attention.py (itself cross-checked against blockwise_attention)."""
from __future__ import annotations

from repro.models.attention import reference_attention  # noqa: F401


def flash_ref(q, k, v, *, causal=True, window=None):
    """q: (B,Sq,H,dh); k,v: (B,Skv,KV,dh)."""
    return reference_attention(q, k, v, causal=causal, window=window)

"""Pallas TPU kernel: flash attention (online-softmax, VMEM-resident tiles).

The §Roofline prefill tables carry a documented caveat: the pure-JAX
blockwise attention round-trips f32 score chunks through HBM. This kernel is
the VWR-discipline answer — the (qc x kc) score tile, the running softmax
statistics and the output accumulator never leave VMEM:

  grid = (batch x heads, q-chunks, kv-chunks)    [kv innermost]
  scratch (VMEM): m (qc,1), l (qc,1), acc (qc, dh) — persist across the kv
  grid dimension (the standard TPU flash pattern); the kv loop initializes
  at j==0 and publishes at j==last.

GQA is handled in the BlockSpec index maps (kv head = h // group); causal
chunks above the diagonal are skipped with @pl.when (no wasted tiles).
f32 accumulation regardless of I/O dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, window, qc: int, kc: int, nk: int, scale: float):
    i = pl.program_id(1)          # q chunk
    j = pl.program_id(2)          # kv chunk

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = i * qc
    k_lo = j * kc

    def _step():
        q = q_ref[0].astype(jnp.float32) * scale        # (qc, dh)
        k = k_ref[0].astype(jnp.float32)                # (kc, dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (qc, kc)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
        mask = jnp.ones((qc, kc), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, -1e30)
        m_prev = m_ref[...]                              # (qc, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                           # (qc, kc)
        corr = jnp.exp(m_prev - m_new)                   # (qc, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    if causal or window is not None:  # skip off-band tiles entirely
        live = jnp.bool_(True)
        if causal:
            live &= k_lo <= q_lo + qc - 1
        if window is not None:
            live &= k_lo + kc - 1 >= q_lo - (window - 1)
        pl.when(live)(_step)
    else:
        _step()

    @pl.when(j == nk - 1)
    def _publish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_chunk",
                                             "kv_chunk", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window=None,
                           q_chunk: int = 256, kv_chunk: int = 256,
                           interpret: bool = True):
    """q: (B,Sq,H,dh); k,v: (B,Skv,KV,dh), H % KV == 0 -> (B,Sq,H,dh)."""
    B, Sq, H, dh = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Skv)
    assert Sq % qc == 0 and Skv % kc == 0, (Sq, qc, Skv, kc)
    nq, nk = Sq // qc, Skv // kc
    scale = float(1.0 / np.sqrt(dh))

    # (B,S,H,dh) -> (B*H, S, dh) with heads-major flattening
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, dh)

    kern = functools.partial(_kernel, causal=causal, window=window,
                             qc=qc, kc=kc, nk=nk, scale=scale)
    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, dh), q.dtype),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qc, dh), lambda bh, i, j: (bh, i, 0),
                         memory_space=pltpu.VMEM),
            # GQA: flat kv row = (bh // H) * KV + (bh % H) // G
            pl.BlockSpec((1, kc, dh),
                         lambda bh, i, j, H=H, KV=KV, G=G:
                         ((bh // H) * KV + (bh % H) // G, j, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, kc, dh),
                         lambda bh, i, j, H=H, KV=KV, G=G:
                         ((bh // H) * KV + (bh % H) // G, j, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, qc, dh), lambda bh, i, j: (bh, i, 0),
                               memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((qc, 1), jnp.float32),
            pltpu.VMEM((qc, 1), jnp.float32),
            pltpu.VMEM((qc, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, dh).transpose(0, 2, 1, 3)

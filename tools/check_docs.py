"""Doc-sync check: code references in the docs must resolve at HEAD.

Docs rot silently: a refactor renames `ColumnScheduler.rebalance` or moves
`serve/stream.py` and every prose reference to it keeps reading fine while
pointing at nothing. This checker makes the references load-bearing — the
lint job (and `tests/test_docs.py`) fails when any of them breaks.

What counts as a reference (extracted from backticked spans and markdown
link targets in `docs/*.md` and `README.md`):

* repo file paths with a checked suffix (`.py`, `.md`, `.yml`, `.toml`) —
  resolved against the repo root and, for source paths written without
  the `src/` prefix (e.g. `serve/stream.py`), against `src/repro/`;
  directory references ending in `/` are checked as directories;
* `path.py:symbol` anchors (e.g. `serve/resident.py:ResidentStream` or
  `serve/stream.py:StreamTelemetry.record_retire`) — the file must exist
  AND define the symbol: a `class`/`def` of that name at any nesting, or
  a `name = ...` / `name: ...` binding; dotted `Cls.member` requires the
  class and the member definition.

Artifact names (`BENCH_*.json`), URLs, and glob patterns are ignored.

Usage: ``python tools/check_docs.py [files-or-dirs...]`` (default:
``docs`` and ``README.md``). Exits 1 with one line per broken reference.

On a default (argument-less) run the docs in `REQUIRED` must be among
the checked set — the authoring guide `docs/STAGE_GRAPHS.md` in
particular is load-bearing for the stage-graph layer, so deleting or
renaming it fails the check instead of silently shrinking coverage.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
CHECKED_SUFFIXES = (".py", ".md", ".yml", ".toml")

# docs that MUST exist and be checked on a default run (see module
# docstring) — extend this when a new doc becomes load-bearing
REQUIRED = ("README.md", "docs/ARCHITECTURE.md", "docs/BENCHMARKS.md",
            "docs/STAGE_GRAPHS.md")

# a repo-looking path, optionally with a :symbol anchor (only for .py)
_PATH_RE = re.compile(
    r"(?P<path>[A-Za-z0-9_][A-Za-z0-9_./-]*"
    r"(?:\.(?:py|md|yml|toml)|/))"
    r"(?::(?P<sym>[A-Za-z_][A-Za-z0-9_.]*))?$")
_BACKTICK_RE = re.compile(r"`([^`\n]+)`")
_LINK_RE = re.compile(r"\]\(([^)#\s]+)\)")


def resolve(path: str, doc: Path) -> Path | None:
    """Resolve a doc reference to a real file/dir, trying (in order) the
    repo root, the `src/repro/` source prefix, and the doc's own
    directory (relative markdown links)."""
    for base in (ROOT, ROOT / "src", ROOT / "src" / "repro", doc.parent):
        p = base / path
        if p.exists():
            return p
    return None


def symbol_defined(src: str, sym: str) -> bool:
    """True when `sym` is defined in the module text: a class/def at any
    nesting, or a `name = ...` / `name: ...` binding (module constants,
    dataclass fields). Dotted `Cls.member` needs the class AND a member
    definition."""
    def has(name: str) -> bool:
        n = re.escape(name)
        return re.search(
            rf"(?m)^\s*(?:(?:class|def)\s+{n}\b|{n}\s*[:=])",
            src) is not None

    parts = sym.split(".")
    return all(has(p) for p in parts)


def check_file(doc: Path) -> list[str]:
    text = doc.read_text()
    refs: set[tuple[str, str | None]] = set()
    for span in _BACKTICK_RE.findall(text):
        span = span.strip()
        if "*" in span or "://" in span or " " in span:
            continue
        m = _PATH_RE.match(span)
        if m:
            refs.add((m.group("path"), m.group("sym")))
    for target in _LINK_RE.findall(text):
        if "://" in target or "*" in target:
            continue
        if target.endswith(CHECKED_SUFFIXES) or target.endswith("/"):
            refs.add((target, None))
    errors = []
    rel = doc.relative_to(ROOT) if doc.is_relative_to(ROOT) else doc
    for path, sym in sorted(refs, key=lambda r: (r[0], r[1] or "")):
        resolved = resolve(path, doc)
        if resolved is None:
            errors.append(f"{rel}: broken file reference `{path}`")
            continue
        if sym is not None:
            if not resolved.suffix == ".py":
                errors.append(f"{rel}: symbol anchor on non-Python file "
                              f"`{path}:{sym}`")
            elif not symbol_defined(resolved.read_text(), sym):
                errors.append(f"{rel}: `{path}` does not define `{sym}`")
    return errors


def main(argv: list[str]) -> int:
    targets = argv or ["docs", "README.md"]
    docs: list[Path] = []
    for t in targets:
        p = (ROOT / t) if not Path(t).is_absolute() else Path(t)
        if p.is_dir():
            docs += sorted(p.glob("*.md"))
        elif p.exists():
            docs.append(p)
        else:
            print(f"check_docs: no such file or directory: {t}",
                  file=sys.stderr)
            return 2
    errors = []
    if not argv:
        rels = {str(d.relative_to(ROOT)) for d in docs
                if d.is_relative_to(ROOT)}
        errors += [f"required doc missing from tree: {r}"
                   for r in REQUIRED if r not in rels]
    for doc in docs:
        errors += check_file(doc)
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if errors:
        print(f"check_docs: {len(errors)} broken reference(s) across "
              f"{len(docs)} file(s)", file=sys.stderr)
        return 1
    print(f"check_docs: ok ({len(docs)} doc file(s), all code references "
          f"resolve)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))

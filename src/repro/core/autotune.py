"""Measured block-size autotuning for the Pallas kernels.

`VWRSpec.max_block_bytes` picks the row-block `rb` with a static formula
(largest block whose n_vwrs live copies fit the VMEM budget). That is the
paper's *design-time* reasoning about the 4096-bit VWR width; at *run* time
the right refill width depends on the actual kernel and shape. This module
replaces the formula with measurement: time a handful of candidate `rb`
values on the real arrays, keep the fastest, and cache the winner per
(kernel, shape) key so the search cost is paid once per process.

Shared by the fft / fir / fused-pipeline kernels (their `ops` wrappers grow
an ``autotune=True`` knob) and the streaming window runtime.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax

from repro.core.vwr import SUBLANES

# (kernel-name, shape...) -> winning block_rows
_CACHE: dict[tuple, int] = {}
# pinned-shape perf records: name -> {"us", "spread", ...} — the paired
# rep measurements CI's regression gate compares across commits
_PINNED: dict[str, dict] = {}


def clear_cache() -> None:
    _CACHE.clear()
    _PINNED.clear()


def cache_snapshot() -> dict:
    return dict(_CACHE)


def record_pinned(name: str, times_us: list, *,
                  baseline_us: list | None = None) -> dict:
    """Record a pinned benchmark shape's paired-rep timings for the
    cross-commit gate (`benchmarks/diff_autotune.py --gate`).

    ``times_us`` are the per-rep wall times of the pinned configuration;
    ``baseline_us`` (optional) a PAIRED sibling timed alternately in the
    same rep loop. The gate compares the runner-normalized ratio
    baseline/us when a baseline exists (cross-runner absolute times are
    not comparable; a same-run paired ratio is), with the tolerance taken
    from the run's own rep spread. Spread is (median - min)/min — robust
    to the occasional 5-10x GC/neighbour outlier rep that would otherwise
    blow the gate tolerance wide open.
    """
    def _spread(ts):
        ts = sorted(ts)
        return (ts[len(ts) // 2] - ts[0]) / max(ts[0], 1e-9)

    best = min(times_us)
    rec = {"us": best, "spread": _spread(times_us), "reps": len(times_us)}
    if baseline_us is not None:
        rec["ratio"] = min(baseline_us) / max(best, 1e-9)
        rec["spread"] = max(rec["spread"], _spread(baseline_us))
    _PINNED[name] = rec
    return rec


def _freeze(x):
    """JSON round-trip: lists (de)serialize to tuples, recursively — cache
    keys are nested tuples like (name, rows, window, hop, outputs, dtype)."""
    return tuple(_freeze(v) for v in x) if isinstance(x, (list, tuple)) else x


def save_cache(path: str) -> int:
    """Persist the winners as a JSON artifact (next to the BENCH_*.json
    perf records) so later processes warm-start instead of re-measuring
    and CI can diff winners across commits. Pinned-shape perf records
    (`record_pinned`) ride along for the regression gate. Returns the
    winner entry count."""
    entries = [{"key": list(k), "block_rows": v}
               for k, v in sorted(_CACHE.items(), key=lambda kv: str(kv[0]))]
    with open(path, "w") as f:
        json.dump({"autotune_winners": entries, "pinned": dict(_PINNED)},
                  f, indent=1, default=list)
    return len(entries)


def load_cache(path: str) -> int:
    """Warm-start the in-process cache from a `save_cache` artifact.
    Missing file is not an error (first run of a fresh checkout). Pinned
    perf records are deliberately NOT loaded — they must be re-measured
    every run, or the cross-commit gate would compare an artifact against
    a copy of itself. Returns the number of loaded winner entries."""
    if not os.path.exists(path):
        return 0
    with open(path) as f:
        data = json.load(f)
    entries = data.get("autotune_winners", [])
    for e in entries:
        _CACHE[_freeze(e["key"])] = int(e["block_rows"])
    return len(entries)


def candidate_block_rows(rows: int, *, max_candidates: int = 4) -> list[int]:
    """Candidate row-blocks for an R-row operand: divisors of R (so the grid
    tiles exactly), preferring sublane multiples, largest first. The
    whole-batch block (rows itself) is always the first candidate — it is
    the largest divisor, sublane-aligned whenever any divisor is."""
    divs = [d for d in range(1, rows + 1) if rows % d == 0]
    aligned = [d for d in divs if d % SUBLANES == 0]
    pool = sorted(aligned or divs, reverse=True)
    return pool[:max_candidates]


def _measure(fn: Callable[[], object], reps: int) -> float:
    jax.block_until_ready(fn())                 # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def autotune_block_rows(key: tuple, candidates: list[int],
                        build: Callable[[int], Callable[[], object]],
                        *, reps: int = 3) -> int:
    """Pick the fastest `block_rows` among `candidates`.

    ``build(rb)`` returns a zero-arg callable running the kernel with that
    block size; each candidate is compiled once and timed best-of-`reps`.
    The winner is cached under ``key`` for the life of the process.
    """
    if key in _CACHE:
        return _CACHE[key]
    if len(candidates) == 1:
        _CACHE[key] = candidates[0]
        return candidates[0]
    timed = [(_measure(build(rb), reps), rb) for rb in candidates]
    best = min(timed)[1]
    _CACHE[key] = best
    return best


def tuned_block_rows(name: str, rows: int, extras: tuple,
                     run: Callable[[int], object]) -> int:
    """One-call wiring for the kernel `ops` wrappers: build the per-shape
    cache key, enumerate candidates, measure, cache. ``run(rb)`` executes
    the kernel with that block size."""
    key = _freeze((name, rows) + tuple(extras))
    return autotune_block_rows(key, candidate_block_rows(rows),
                               lambda rb: lambda: run(rb))


def candidate_stream_block_frames(n_frames: int, window: int, hop: int,
                                  *, max_candidates: int = 4) -> list[int]:
    """Candidate frame-blocks for the raw-signal streaming kernel. The
    grid pads the frame count, so candidates need not divide it — but the
    body chunk (block_frames*hop samples) must cover the window-hop
    overlap spill, which floors every candidate."""
    floor = 1 if window <= hop else -(-(window - hop) // hop)
    pool = {c for c in (1, 2, 4, 8, 16, SUBLANES * 4)
            if floor <= c <= max(n_frames, floor)}
    pool |= {floor, min(max(n_frames, floor), max(8, floor))}
    return sorted(pool, reverse=True)[:max_candidates]


def tuned_stream_block_frames(name: str, n_frames: int, window: int,
                              hop: int, outputs: tuple, dtype: str,
                              run: Callable[[int], object],
                              n_columns: int = 1,
                              shares: tuple | None = None) -> int:
    """`tuned_block_rows` for the raw-signal streaming kernel: the cache
    key carries the full (window, hop, outputs) shape — the same window
    batch tuned for classification-only traffic (no `filtered` write) may
    legitimately pick a different block than the all-outputs variant —
    plus the column count when sharded (`n_columns > 1`): each column
    stages only ~n_frames/D frames, so the right block is per-(shape, D).
    A non-uniform deal additionally carries its quantized share signature
    (``shares``, the `column_shares` frame counts): a winner measured on
    a (9, 19, 18, 18) deal must not leak onto the (16,)*4 equal deal.
    Candidates are enumerated over the WIDEST per-column share — the
    column that bounds the dispatch wall."""
    sig = () if shares is None else ("w",) + tuple(shares)
    key = _freeze((name, n_frames, window, hop, outputs, dtype)
                  + ((n_columns,) if n_columns > 1 else ()) + sig)
    per_col = max(shares) if shares is not None else -(-n_frames // n_columns)
    return autotune_block_rows(
        key, candidate_stream_block_frames(max(per_col, 1), window, hop),
        lambda rb: lambda: run(rb))


def candidate_ring_depths(n_batches: int, *,
                          max_candidates: int = 4) -> list[int]:
    """Candidate ring depths (chunks per on-device sweep) for the
    device-resident loop: powers of two up to the batch count — a deeper
    ring amortizes more sweep overhead but compiles a wider dispatch and
    pads more tail batches."""
    pool = {d for d in (1, 2, 4, 8, 16) if d <= max(n_batches, 1)}
    pool.add(1)
    return sorted(pool, reverse=True)[:max_candidates]


def tuned_ring_depth(name: str, window: int, hop: int, batch_windows: int,
                     outputs: tuple, dtype: str, drain_interval: int,
                     n_batches: int, run: Callable[[int], object]) -> int:
    """Measured ring depth for `serve.resident.ResidentStream`. The cache
    key carries the full dispatch shape (window, hop, batch_windows,
    outputs, dtype), the DRAIN INTERVAL (draining every sweep makes
    shallow rings pay a counter readback more often, so the winner is
    per-interval), and the batch count (a 4-batch signal cannot justify a
    16-deep ring). ``run(rd)`` executes one full resident loop at that
    ring depth."""
    key = _freeze((name, window, hop, batch_windows, outputs, dtype,
                   drain_interval, n_batches))
    return autotune_block_rows(key, candidate_ring_depths(n_batches),
                               lambda rd: lambda: run(rd))

"""Public jit'd API for the FIR kernel."""
from __future__ import annotations

import jax

from repro.kernels.fir.kernel import fir_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fir(x, taps, *, seq_block: int = 2048):
    """Causal FIR along the last axis. x: (R, S) or (S,)."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    y = fir_pallas(x, taps, seq_block=seq_block, interpret=_interpret())
    return y[0] if squeeze else y

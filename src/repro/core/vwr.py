"""VWR staging discipline: the paper's asymmetric register interface mapped
to the TPU memory hierarchy (DESIGN.md §2).

VWR2A fills a 4096-bit register from the SPM in ONE wide transaction and
lets the datapath consume it word-by-word. On TPU the analogue is a
BlockSpec-described VMEM block fetched by one (double-buffered) DMA per grid
step, consumed by VREG-level compute. This module sizes those blocks:

  * a "VWR line" = one (sublane x lane) = (8, 128) f32 tile = 4 KiB — the
    TPU's natural wide word;
  * a kernel's working set is budgeted as N_VWRS (default 3: A, B operands +
    C result) wide registers, scaled to a VMEM budget instead of 3 x 512 B.

``plan_blocks`` returns the largest hardware-aligned block shape such that
n_vwrs live blocks (+ double buffering) fit the VMEM budget — the same
trade-off the paper describes for choosing the 4096-bit VWR width
("large enough to minimize refill frequency, small enough to bound leakage"
becomes "large enough to amortize DMA latency, small enough to fit VMEM").
"""
from __future__ import annotations

import dataclasses

SUBLANES = 8
LANES = 128
VMEM_BYTES = 16 * 2 ** 20          # v5e VMEM per core (16 MiB)


@dataclasses.dataclass(frozen=True)
class VWRSpec:
    n_vwrs: int = 3                 # paper: A, B, C
    vmem_budget: int = VMEM_BYTES // 2   # leave half for the compiler
    double_buffer: bool = True      # Pallas pipelines HBM->VMEM fetches

    def line_bytes(self, elem_bytes: int) -> int:
        return SUBLANES * LANES * elem_bytes

    def max_block_bytes(self, elem_bytes: int) -> int:
        slots = self.n_vwrs * (2 if self.double_buffer else 1)
        return self.vmem_budget // slots

    def block_rows(self, row_bytes: int, elem_bytes: int) -> int:
        """How many rows of `row_bytes` fit one staged block (>=1)."""
        per = self.max_block_bytes(elem_bytes)
        rows = max(1, per // max(row_bytes, 1))
        # align down to a sublane multiple when possible
        return max(1, (rows // SUBLANES) * SUBLANES) if rows >= SUBLANES else rows


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def resolve_block_rows(rows: int, row_bytes: int, *, elem_bytes: int = 4,
                       spec: VWRSpec | None = None,
                       override: int | None = None) -> int:
    """The row-block every Pallas kernel stages per grid step: `override`
    (e.g. an autotuned winner) when given, else the largest block of
    `row_bytes` rows fitting the VWRSpec budget — always decremented to a
    divisor of `rows` so the grid tiles exactly."""
    if override:
        rb = min(rows, override)
    else:
        spec = spec or VWRSpec()
        rb = max(1, min(rows, spec.max_block_bytes(elem_bytes) //
                        max(1, row_bytes)))
    while rows % rb:
        rb -= 1
    return rb


def plan_blocks(shape: tuple, elem_bytes: int,
                spec: VWRSpec | None = None) -> tuple:
    """Choose a hardware-aligned VMEM block shape for an (R, C) operand.

    The last dim is padded conceptually to LANES, the second-to-last to
    SUBLANES; leading dims are tiled to 1. Returns the block shape.
    """
    spec = spec or VWRSpec()
    if len(shape) == 1:
        cols = min(round_up(shape[0], LANES),
                   spec.max_block_bytes(elem_bytes) // elem_bytes)
        return (max(LANES, cols),)
    *lead, r, c = shape
    c_block = min(round_up(c, LANES), 4096)
    row_bytes = c_block * elem_bytes
    r_block = min(round_up(r, SUBLANES),
                  spec.block_rows(row_bytes, elem_bytes))
    return tuple([1] * len(lead) + [r_block, c_block])


def vwr_words(bits: int = 4096, word_bits: int = 32) -> int:
    """The paper's VWR geometry: 4096-bit register = 128 32-bit words."""
    return bits // word_bits

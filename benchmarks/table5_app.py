"""Table 5 — MBioTracker biosignal application (paper §5.2).

Per-step cycles/energy from the simulator vs the paper's CPU / CPU+FFT-ACCEL
/ CPU+VWR2A columns. The CPU and accelerator columns are the paper's
measurements; `savings` compares our simulated VWR2A against them.

Also times the fused single-`pallas_call` application kernel against the
staged per-stage execution (the software analogue of the paper's
whole-application SPM residency vs kernel-at-a-time offload); the CI bench
smoke gates on fused <= staged via ``run.py --check-fused``.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.table2_fft import F_HZ

PAPER_CPU = {"preprocessing": (49760, 0.74), "delineation": (46268, 0.74),
             "feat_extraction": (70639, 1.1), "total": (166667, 2.6)}
PAPER_VWR2A = {"preprocessing": (3763, 0.26), "delineation": (2723, 0.13),
               "feat_extraction": (8627, 0.47), "total": (15113, 0.86)}


def _paired_times(fns: list, reps: int = 15) -> list[list[float]]:
    """Paired per-rep wall times in us: the candidates are timed
    ALTERNATELY inside one loop so machine noise hits all of them equally
    (an unpaired comparison at the ~3%-level is a coin flip). The full
    rep lists feed the pinned-shape regression gate, whose tolerance is
    the run's own rep spread."""
    import jax

    for fn in fns:
        jax.block_until_ready(fn())          # compile + warm
    times = [[] for _ in fns]
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            times[i].append((time.perf_counter() - t0) * 1e6)
    return times


def _paired_best(fns: list, reps: int = 15) -> list[float]:
    return [min(ts) for ts in _paired_times(fns, reps)]


def _pipeline_rows():
    """Fused application kernel vs the staged executions (paper Table 5's
    execution models: whole-app residency vs kernel-at-a-time offload)."""
    from repro.core.biosignal import make_app, synthetic_respiration
    from repro.kernels.pipeline.ops import app_pipeline
    from repro.kernels.pipeline.ref import staged_kernel_fns, staged_stage_fns

    app = make_app()
    sig, _ = synthetic_respiration(32, 2048, seed=0)
    from repro.core import autotune

    staged = staged_kernel_fns(app.fir_taps, app.svm_w, app.svm_b,
                               fft_size=app.fft_size)
    fir_fn, feat_fn, svm_fn = staged_stage_fns(
        app.fir_taps, app.svm_w, app.svm_b, fft_size=app.fft_size)
    t_fused, t_staged, t_jnp = _paired_times([
        lambda: app_pipeline(app, sig),
        lambda: staged(sig),
        lambda: svm_fn(feat_fn(fir_fn(sig))),
    ])
    us_fused, us_staged, us_jnp = min(t_fused), min(t_staged), min(t_jnp)
    autotune.record_pinned("table5/pipeline_fused", t_fused,
                           baseline_us=t_staged)
    return [
        ("table5/pipeline_staged", us_staged,
         "kernel-at-a-time: 4 launches/batch (FIR kernel; delineation; "
         "rFFT kernel; SVM) with per-stage HBM round trips"),
        ("table5/pipeline_staged_jnp", us_jnp,
         "3 jnp-only jit calls/batch (no per-kernel staging); info only"),
        ("table5/pipeline_fused", us_fused,
         f"ONE pallas_call per batch;speedup_vs_staged="
         f"{us_staged / us_fused:.2f}x"),
    ]


def _stream_rows():
    """Raw-signal single-residency streaming vs host-framed feeds at the
    default overlap (hop = window/4, every sample duplicated 4x by host
    framing). Candidates are timed PAIRED (alternating min-of-reps); the CI
    bench smoke gates on stream-fused >= 1.25x framed-fused via
    ``run.py --check-stream``."""
    from repro.core.biosignal import make_app, synthetic_respiration
    from repro.kernels.pipeline.ops import (app_pipeline,
                                            app_pipeline_stream)
    from repro.kernels.pipeline.ref import staged_kernel_fns
    from repro.serve.stream import frame_signal

    app = make_app()
    window, hop, n_frames = 2048, 512, 32
    sig, _ = synthetic_respiration(1, (n_frames - 1) * hop + window, seed=1)
    raw = sig[0]
    cls_outputs = ("features", "margin", "class")   # elide filtered write
    staged = staged_kernel_fns(app.fir_taps, app.svm_w, app.svm_b,
                               fft_size=app.fft_size)
    # populate the autotune cache (these warmup calls are what lands in
    # BENCH_autotune.json), but GATE on pinned whole-batch blocks: the
    # near-tied candidates make autotune's pick a coin flip under CI load,
    # and a flapping gate is worse than a fixed one
    app_pipeline_stream(app, raw, window=window, hop=hop,
                        outputs=cls_outputs, autotune=True)
    app_pipeline(app, frame_signal(raw, window, hop), autotune=True)
    t_stream, t_framed, t_staged = _paired_times([
        lambda: app_pipeline_stream(app, raw, window=window, hop=hop,
                                    outputs=cls_outputs,
                                    block_frames=n_frames),
        lambda: app_pipeline(app, frame_signal(raw, window, hop),
                             block_rows=n_frames),
        lambda: staged(frame_signal(raw, window, hop)),
    ], reps=25)
    us_stream, us_framed, us_staged = (min(t_stream), min(t_framed),
                                       min(t_staged))
    from repro.core import autotune

    autotune.record_pinned("table5/stream_fused", t_stream,
                           baseline_us=t_framed)
    return [
        ("table5/stream_fused", us_stream,
         f"raw {raw.shape[0]}-sample feed, frames built in-kernel "
         f"(window={window},hop={hop}), outputs=features+margin+class;"
         f"speedup_vs_framed={us_framed / us_stream:.2f}x"),
        ("table5/stream_framed_fused", us_framed,
         f"host frame gather ({window // hop}x HBM duplication) + fused "
         f"kernel, all outputs"),
        ("table5/stream_framed_staged", us_staged,
         "host frame gather + kernel-at-a-time staged execution"),
    ]


def _asr_rows():
    """Streaming ASR feature front-end — the SECOND workload on the
    stage-graph substrate: the fused ``"asr"`` graph (ONE `pallas_call`,
    in-kernel (window, hop) framing, pre-emphasis FIR -> Hann -> packed
    rFFT power -> log-mel matmul) vs the staged 4-launch reference
    (`kernels/pipeline/asr.py:asr_staged`: host frame gather, FIR
    kernel, jnp Hann, rFFT kernel, jnp mel/log — per-stage HBM round
    trips). Numerically equal to f32 tolerance (`tests/test_asr.py`);
    timed paired; the CI bench smoke gates fused >= 1.2x staged via
    ``run.py --check-asr``."""
    from repro.kernels.pipeline.asr import asr_staged, make_asr_frontend
    from repro.kernels.pipeline.ops import graph_pipeline_stream

    app = make_asr_frontend()
    window, hop, n_frames = 512, 160, 64
    rng = np.random.default_rng(9)
    raw = rng.standard_normal(
        (n_frames - 1) * hop + window).astype(np.float32)
    t_fused, t_staged = _paired_times([
        lambda: graph_pipeline_stream("asr", app, raw, window=window,
                                      hop=hop, outputs=("logmel",),
                                      block_frames=n_frames),
        lambda: asr_staged(app, raw, window=window, hop=hop),
    ], reps=25)
    us_fused, us_staged = min(t_fused), min(t_staged)
    from repro.core import autotune

    autotune.record_pinned("table5/asr_fused", t_fused,
                           baseline_us=t_staged)
    return [
        ("table5/asr_staged", us_staged,
         f"4 launches/utterance (host frame gather; FIR kernel; Hann; "
         f"rFFT kernel; mel/log) with per-stage HBM round trips "
         f"(window={window},hop={hop},{n_frames} frames)"),
        ("table5/asr_fused", us_fused,
         f"ONE pallas_call/utterance, 'asr' stage graph with in-kernel "
         f"framing, outputs=logmel;"
         f"speedup_vs_staged={us_staged / us_fused:.2f}x"),
    ]


def _column_rows():
    """Column-scaling sweep for the STREAMING Pallas path — the mirror of
    `table2_fft._column_sweep` (which sweeps archsim's n_columns): a fixed
    64-frame raw feed dealt across D column replicas.

    The headline metric is the measured PER-COLUMN latency (one column's
    ~n/D-frame chunk through the fused kernel) — on a real D-device
    machine that IS the dispatch wall clock, and it is what the
    ``--check-columns`` monotonicity gate checks; host-fake devices
    sharing a 2-core CPU would make the aggregate wall a core-count
    artifact. When the process does have >= D devices the true shard_map
    wall is measured too and recorded in `derived` alongside.
    """
    import jax

    from repro.core.biosignal import make_app, synthetic_respiration
    from repro.kernels.pipeline.ops import app_pipeline_stream
    from repro.kernels.pipeline.shard import column_chunks
    from repro.serve.stream import column_mesh

    app = make_app()
    window, hop, n_frames = 2048, 512, 64
    sig, _ = synthetic_respiration(1, (n_frames - 1) * hop + window, seed=2)
    raw = sig[0]
    cls_outputs = ("features", "margin", "class")
    sweep = (1, 2, 4, 8)
    # one column's chunk per D (identical per-column shapes, frames n/D)
    col0 = {d: column_chunks(raw, window, hop, d).chunks[0] for d in sweep}
    fns = [
        # block pinned to the D=8 share so every D runs the same kernel
        # variant and the sweep isolates the work-per-column scaling
        (lambda d: lambda: app_pipeline_stream(
            app, col0[d], window=window, hop=hop, outputs=cls_outputs,
            block_frames=n_frames // max(sweep)))(d)
        for d in sweep
    ]
    times = _paired_times(fns, reps=10)
    rows, t1 = [], min(times[0])
    for d, ts in zip(sweep, times):
        t_col = min(ts)
        extra = ""
        mesh = column_mesh(d)
        if d > 1 and mesh is not None:
            fn = lambda: app_pipeline_stream(  # noqa: E731
                app, raw, window=window, hop=hop, outputs=cls_outputs,
                block_frames=n_frames // max(sweep), n_columns=d, mesh=mesh)
            jax.block_until_ready(fn())
            wall = min(_paired_times([fn], reps=5)[0])
            extra = f";shard_map_wall_us={wall:.1f}"
        rows.append((
            f"table5/stream_ncols{d}", t_col,
            f"per-column latency, {n_frames // d} of {n_frames} frames "
            f"(window={window},hop={hop});scaling={t1 / t_col:.2f}x;"
            f"model_windows_per_s={n_frames / t_col * 1e6:.0f}{extra}"))
    return rows


def _hetero_rows():
    """Heterogeneous-load column deal: static equal split vs the
    telemetry-driven dynamic deal when ONE of D=4 columns carries a 2x
    background load (a second tenant's 16-frame dispatch riding on column
    0 — the Versa-style column-shared-with-an-LM-engine scenario).

    Columns are timed serially (the serial-fallback path, measurable on
    one device, same convention as `_column_rows`); the modelled dispatch
    wall is max over columns of (column share time + its background
    time), which on a real D-device machine IS the wall clock. The
    dynamic deal replays measured per-column times through
    `StreamTelemetry` (injected clock), takes `ColumnScheduler.
    deal_weights(band=0.3)` — measured windows/s per column, deadband-
    clustered so jitter between the identical light columns cannot skew
    the deal — and re-deals via `column_chunks(weights=...)`; one
    refinement round (the periodic rebalance in miniature) converges the
    deal against the loaded column's ADDITIVE background cost. Both
    deals' columns are then timed alternately in ONE paired rep loop.
    Measured on CPU interpret: deterministic deal (7, 19, 19, 19) and
    1.27-1.39x over the static wall across trials; CI gates dynamic >=
    1.15x static throughput via ``run.py --check-hetero``.
    """
    import jax

    from repro.core.biosignal import make_app, synthetic_respiration
    from repro.kernels.pipeline.ops import app_pipeline_stream
    from repro.kernels.pipeline.shard import column_chunks
    from repro.serve.engine import ColumnScheduler
    from repro.serve.stream import StreamTelemetry

    app = make_app()
    # hop = window/2 keeps the kernel's frame-block floor at 1, and
    # block_frames=1 makes a column's cost LINEAR in its share — a deal
    # quantized to an 8-frame grid block would round a 9-frame share back
    # up to 16 frames of compute and erase the re-deal's win
    window, hop, n_frames, D = 2048, 1024, 64, 4
    cls_outputs = ("features", "margin", "class")
    block = 1                     # pinned: every share runs the same block
    sig, _ = synthetic_respiration(1, (n_frames - 1) * hop + window, seed=6)
    raw = sig[0]
    bg_sig, _ = synthetic_respiration(
        1, (n_frames // D - 1) * hop + window, seed=7)
    bg = bg_sig[0]                # the tenant's own 16-frame dispatch

    def col_fn(chunk):
        return lambda: app_pipeline_stream(
            app, chunk, window=window, hop=hop, outputs=cls_outputs,
            block_frames=block)

    def col_slices(shares, chunks):
        return [chunks[d][: s * hop + (window - hop)] if s else None
                for d, s in enumerate(shares)]

    def walls(per_col_times, bg_times):
        """Per-rep modelled dispatch wall: max over columns, background
        load added onto column 0. Used for the pinned record's rep
        spread; the headline wall takes each column's best-of-reps first
        (`wall_best`) — on a real D-device machine the columns run
        independently, so one host-jitter rep on one column must not
        inflate the modelled wall."""
        return [max(ts[i] + (bg_times[i] if d == 0 else 0.0)
                    for d, ts in enumerate(per_col_times))
                for i in range(len(bg_times))]

    def wall_best(per_col_times, bg_times):
        return max(min(ts) + (min(bg_times) if d == 0 else 0.0)
                   for d, ts in enumerate(per_col_times))

    deal_s = column_chunks(raw, window, hop, D)
    shares_s = deal_s.shares
    cols_s = col_slices(shares_s, deal_s.chunks)

    # CALIBRATION round: measure the static deal's per-column busy times
    # and replay them through the telemetry (virtual clock: retires of
    # share windows spaced by the MEDIAN-of-reps busy time — on a noisy
    # runner the median is the tightest per-column estimator: min still
    # jitters ~15% between identical columns, median ~8%), then ask the
    # scheduler for the deal weights with a 30% deadband (`band`) so
    # residual jitter between the three identical light columns cannot
    # deal them unequal shares (the 2x-loaded column sits ~100% away —
    # far outside the band)
    cal = _paired_times([col_fn(bg)] + [col_fn(c) for c in cols_s],
                        reps=13)
    bg_cal, col_cal = cal[0], cal[1:]

    def _median(ts):
        return sorted(ts)[len(ts) // 2]

    now = [0.0]
    tel = StreamTelemetry(alpha=0.5, clock=lambda: now[0])
    vt = [0.0] * D
    busy = [_median(ts) + (_median(bg_cal) if d == 0 else 0.0)
            for d, ts in enumerate(col_cal)]
    for d in range(D):
        tel.attach(f"col{d}", d)
    for _ in range(3):
        for d in range(D):
            vt[d] += busy[d] * 1e-6
            now[0] = vt[d]
            tel.record_retire(f"col{d}", shares_s[d])
    sched = ColumnScheduler([jax.devices()[0]] * D, telemetry=tel)

    def redeal():
        weights = sched.deal_weights(band=0.3)
        deal_w = column_chunks(raw, window, hop, D, weights)
        return weights, deal_w.shares, col_slices(deal_w.shares,
                                                  deal_w.chunks)

    weights, shares_d, cols_d = redeal()
    # one REFINEMENT round — the periodic rebalance in miniature: measure
    # the first re-deal, feed the new retires into the same telemetry,
    # deal again. A single rate-proportional step under-shifts when the
    # background load is additive (the loaded column's cost is fixed +
    # share, not proportional); the closed loop converges on it.
    ref = _paired_times([col_fn(bg)] +
                        [col_fn(c) for c in cols_d if c is not None],
                        reps=9)
    ref_cols = iter(ref[1:])
    busy = [(next(ref_cols) if s else None) for s in shares_d]
    for _ in range(3):
        for d in range(D):
            if shares_d[d] == 0:
                continue
            vt[d] += (_median(busy[d]) +
                      (_median(ref[0]) if d == 0 else 0.0)) * 1e-6
            now[0] = vt[d]
            tel.record_retire(f"col{d}", shares_d[d])
    weights, shares_d, cols_d = redeal()
    cols_d = [c for c in cols_d if c is not None]

    # FINAL round: BOTH deals' columns timed alternately in ONE paired
    # rep loop (machine drift between two separate rounds was measurable
    # as a coin-flip headline; within-loop pairing hits both deals
    # equally), walls computed per rep from the same loop
    fns = [col_fn(bg)] + [col_fn(c) for c in cols_s] + \
        [col_fn(c) for c in cols_d]
    times = _paired_times(fns, reps=12)
    bg_t = times[0]
    per_col_s = times[1: 1 + D]
    dyn_iter = iter(times[1 + D:])
    per_col_d = [next(dyn_iter) if s else [0.0] * len(bg_t)
                 for s in shares_d]
    wall_s = walls(per_col_s, bg_t)
    wall_d = walls(per_col_d, bg_t)
    us_s = wall_best(per_col_s, bg_t)
    us_d = wall_best(per_col_d, bg_t)
    from repro.core import autotune

    autotune.record_pinned("table5/stream_hetero", wall_d,
                           baseline_us=wall_s)
    rates = ";".join(f"{w:.1f}" for w in weights)
    return [
        ("table5/stream_hetero_static", us_s,
         f"modelled dispatch wall, equal deal {tuple(shares_s)} with a "
         f"{n_frames // D}-frame background tenant on column 0;"
         f"windows_per_s={n_frames / us_s * 1e6:.0f}"),
        ("table5/stream_hetero_dynamic", us_d,
         f"telemetry-driven deal {tuple(shares_d)} (measured col rates "
         f"w/s: {rates});windows_per_s={n_frames / us_d * 1e6:.0f};"
         f"speedup_vs_static={us_s / us_d:.2f}x"),
    ]


def _resident_rows():
    """Device-resident steady-state loop vs the host-driven per-batch
    dispatch loop: the SAME signal, config, and fused kernel — the only
    difference is where the loop runs. The per-batch path pays one
    Python-loop round trip (dispatch + retire + telemetry) per
    `batch_windows` frames; the resident path runs the whole steady state
    as ONE compiled `lax.scan` over ring sweeps
    (`serve/resident.py:ResidentStream`), bit-identical outputs. Timed
    paired; the CI bench smoke gates resident >= per-batch dispatch
    throughput via ``run.py --check-resident``."""
    from repro.core.biosignal import make_app, synthetic_respiration
    from repro.serve.resident import ResidentConfig, ResidentStream
    from repro.serve.stream import BiosignalStream, StreamConfig

    app = make_app()
    window, hop, bw, ring = 2048, 512, 8, 4
    cfg = StreamConfig(window=window, hop=hop, batch_windows=bw,
                       outputs=("features", "margin", "class"))
    sig, _ = synthetic_respiration(1, 512 * 120 + window, seed=5)
    raw = sig[0]
    n = (raw.shape[0] - window) // hop + 1
    n_batches = -(-n // bw)
    n_sweeps = -(-n_batches // ring)
    host = BiosignalStream(app, cfg)
    res = ResidentStream(app, cfg, ResidentConfig(ring_depth=ring))
    t_res, t_host = _paired_times([lambda: res.process(raw),
                                   lambda: host.process(raw)], reps=11)
    us_res, us_host = min(t_res), min(t_host)
    from repro.core import autotune

    autotune.record_pinned("table5/stream_resident", t_res,
                           baseline_us=t_host)
    return [
        ("table5/stream_perbatch", us_host,
         f"host-driven dispatch loop, {n_batches} round trips of "
         f"{bw} frames (window={window},hop={hop})"),
        ("table5/stream_resident", us_res,
         f"device-resident lax.scan loop, ring_depth={ring} "
         f"({n_sweeps} sweeps, 1 host dispatch);"
         f"windows_per_s={n / us_res * 1e6:.0f};"
         f"speedup_vs_perbatch={us_host / us_res:.2f}x"),
    ]


def _depth_rows():
    """Streaming-runtime pipelining depth: depth=1 (the classic double
    buffer — consume batch k while k+1 is in flight) vs depth=2 (two
    batches in flight). Measured within noise on the CPU interpret path
    (±4%, winner flips across trials), so `StreamConfig.depth` defaults
    to the simpler 1; the rows keep the comparison honest across commits
    and will show if a real accelerator target changes the answer."""
    from repro.core.biosignal import make_app, synthetic_respiration
    from repro.serve.stream import BiosignalStream, StreamConfig

    app = make_app()
    window, hop = 2048, 512
    sig, _ = synthetic_respiration(1, 512 * 120 + window, seed=4)
    raw = sig[0]
    streams = {d: BiosignalStream(app, StreamConfig(
        window=window, hop=hop, batch_windows=8, depth=d,
        outputs=("features", "margin", "class"))) for d in (1, 2)}
    t1, t2 = _paired_times([lambda: streams[1].process(raw),
                            lambda: streams[2].process(raw)], reps=7)
    us1, us2 = min(t1), min(t2)
    win = "depth2" if us2 <= us1 else "depth1"
    return [
        ("table5/stream_depth1", us1,
         "runtime end-to-end, 1 batch in flight (classic double buffer)"),
        ("table5/stream_depth2", us2,
         f"runtime end-to-end, 2 batches in flight;speedup_vs_depth1="
         f"{us1 / us2:.2f}x;winner={win} (measured within noise on CPU; "
         f"StreamConfig.depth stays 1)"),
    ]


def _fault_rows():
    """Fault-tolerant serving: the cost of losing one of D=4 columns
    mid-run. Both runs go through `serve/fault.py:
    FaultTolerantColumnRunner`; the fault run kills column 0 at its
    second dispatch (`FaultInjector`), after which its unretired
    hop-aligned frame ranges requeue across the three survivors under
    the degraded deal (dead column zeroed). The modelled dispatch wall
    is max over per-column busy time — same convention as
    `_hetero_rows`: on a real D-device machine the columns run
    independently, so that max IS the wall clock. Outputs must be
    BIT-IDENTICAL to the fault-free run (the chaos invariant,
    `tests/test_chaos.py`); the CI bench smoke gates recovered wall <=
    1.5x fault-free AND bit-identity via ``run.py --check-fault``."""
    import jax
    import jax.numpy as jnp

    from repro.core.biosignal import make_app, synthetic_respiration
    from repro.serve.fault import FaultInjector, FaultTolerantColumnRunner
    from repro.serve.stream import StreamConfig

    app = make_app()
    # 64 frames over D=4: 16 per column, 4 dispatches of bw=4. Killing
    # column 0 at its 2nd dispatch loses 12 unretired frames -> 4 extra
    # frames (ONE extra dispatch, requeued runs coalesce) per survivor:
    # modelled recovery ratio ~5/4 even if dispatch cost were flat per
    # call, comfortably inside the 1.5 gate
    window, hop, bw, D, n_frames = 2048, 1024, 4, 4, 64
    cfg = StreamConfig(window=window, hop=hop, batch_windows=bw,
                       outputs=("features", "margin", "class"))
    sig, _ = synthetic_respiration(1, (n_frames - 1) * hop + window, seed=8)
    raw = sig[0]

    def run_once(injector):
        if injector is not None:
            injector.reset()
        r = FaultTolerantColumnRunner(app, cfg, n_columns=D,
                                      injector=injector)
        out = r.process(raw)
        jax.block_until_ready(out)
        return max(r.column_busy) * 1e6, out

    kill = FaultInjector(kill={0: 1})
    run_once(None)                   # compile + warm
    run_once(kill)
    walls_ok, walls_f = [], []
    out_ok = out_f = None
    for _ in range(7):               # paired: alternate inside one loop
        w, out_ok = run_once(None)
        walls_ok.append(w)
        w, out_f = run_once(kill)
        walls_f.append(w)
    identical = set(out_ok) == set(out_f) and all(
        bool((jnp.asarray(out_ok[k]) == jnp.asarray(out_f[k])).all())
        for k in out_ok)
    us_ok, us_f = min(walls_ok), min(walls_f)
    from repro.core import autotune

    autotune.record_pinned("table5/stream_fault_recovered", walls_f,
                           baseline_us=walls_ok)
    return [
        ("table5/stream_faultfree", us_ok,
         f"modelled dispatch wall, D={D} healthy columns, equal deal of "
         f"{n_frames} frames (window={window},hop={hop},bw={bw})"),
        ("table5/stream_fault_recovered", us_f,
         f"column 0 killed at its 2nd dispatch, unretired frames "
         f"requeued over {D - 1} survivors;bit_identical={identical};"
         f"recovery_ratio={us_f / us_ok:.2f}x"),
    ]


def _engine_fault_rows():
    """Fault-tolerant LM serving: the cost of losing one of four engine
    slots mid-decode. Both runs go through
    `serve/engine_fault.py:FaultTolerantEngine`; the fault run kills
    slot 0 at its 5th dispatch (`FaultInjector` seq 4 — prefill is seq 0,
    so mid-decode), after which the slot is poisoned, its request
    requeues to the queue front, and the survivors replay it from the
    re-prefilled prompt + generated prefix. Wall time is the real
    run_to_completion wall (one batched decode dispatch per step — the
    degraded engine pays more steps on fewer slots). Tokens must be
    BIT-IDENTICAL to the fault-free run for every request (the per-
    request-key chaos invariant, `tests/test_engine_fault.py`); the CI
    bench smoke gates recovered wall <= 1.5x fault-free AND bit-identity
    via ``run.py --check-engine-fault``."""
    import dataclasses as dc

    from repro.configs import get_config, reduced
    from repro.core import autotune
    from repro.models import build_model, init_model_params
    from repro.serve.engine import Engine, Request
    from repro.serve.engine_fault import FaultInjector, FaultTolerantEngine

    cfg = dc.replace(reduced(get_config("qwen1.5-0.5b")), vocab_size=64)
    model = build_model(cfg)
    params = init_model_params(model, seed=3)
    compiled = Engine.compile_model(model)
    # 14 requests over 4 slots, 12 new tokens each, equal-length prompts
    # (one prefill bucket): fault-free serves 14x12 = 168 tokens in ~48
    # batched decode steps (3.5 waves). Killing slot 0 at seq 4 (its 4th
    # dispatch, mid-decode) poisons it, so the remaining tokens drain
    # over 3 slots in ~60 steps — a 1.25x step ratio whose tail slack
    # absorbs the replay prefill and the staggered wave admissions
    # inside the 1.5 gate.
    slots, max_new, n_req = 4, 12, 14
    prompts = {rid: [1 + rid % 8, (rid % 5) + 1] for rid in range(n_req)}

    def run_once(injector):
        if injector is not None:
            injector.reset()
        eng = FaultTolerantEngine(model, params, slots=slots, max_len=64,
                                  temperature=0.8, seed=7,
                                  compiled=compiled, injector=injector)
        for rid, p in prompts.items():
            eng.add_request(Request(rid, list(p), max_new=max_new))
        t0 = time.perf_counter()
        done = eng.run_to_completion(max_steps=500)
        wall = (time.perf_counter() - t0) * 1e6
        return wall, {r.rid: tuple(r.out) for r in done}

    kill = FaultInjector(kill={0: 4})
    run_once(None)                   # compile + warm (incl. decode trace)
    run_once(kill)                   # warm the replay-prefill trace too
    walls_ok, walls_f = [], []
    out_ok = out_f = None
    for _ in range(7):               # paired: alternate inside one loop
        w, out_ok = run_once(None)
        walls_ok.append(w)
        w, out_f = run_once(kill)
        walls_f.append(w)
    identical = out_ok == out_f
    us_ok, us_f = min(walls_ok), min(walls_f)
    autotune.record_pinned("table5/engine_fault_recovered", walls_f,
                           baseline_us=walls_ok)
    return [
        ("table5/engine_faultfree", us_ok,
         f"LM engine wall, {slots} healthy slots, {n_req} requests x "
         f"{max_new} tokens, temperature-sampled per-request streams"),
        ("table5/engine_fault_recovered", us_f,
         f"slot 0 killed mid-decode (seq 4), request replayed on "
         f"{slots - 1} survivors;bit_identical={identical};"
         f"recovery_ratio={us_f / us_ok:.2f}x"),
    ]


def _engine_paged_rows():
    """Paged KV cache vs dense slots at OVERSUBSCRIBED admission.

    Both engines serve 14 short requests (2-token prompts, 12 new tokens)
    with 4 decode lanes and max_len=256. The dense engine admits at most
    4 at a time and every decode step attends over the full 256-slot
    cache rows. The paged engine (`serve/engine.py:PagedEngine`,
    page_size=16) admits ALL 14 up front — admission is bounded by free
    pages, and a 14-token worst case fits ONE page — so
    ``peak_admitted`` hits 14 > 4 lanes, and each decode step gathers a
    16-wide page view instead of 256 dense columns: the compute saving
    that pays for the block-table indirection. Tokens must be
    BIT-IDENTICAL (temperature-sampled per-request streams — the
    `tests/test_paged.py` invariant); the CI bench smoke gates paged
    wall <= dense wall AND bit-identity via ``run.py --check-paged``."""
    import dataclasses as dc

    from repro.configs import get_config, reduced
    from repro.core import autotune
    from repro.models import build_model, init_model_params
    from repro.serve.engine import Engine, PagedEngine, Request

    cfg = dc.replace(reduced(get_config("qwen1.5-0.5b")), vocab_size=64)
    model = build_model(cfg)
    params = init_model_params(model, seed=3)
    compiled = Engine.compile_model(model)
    slots, max_len, max_new, n_req = 4, 256, 12, 14
    prompts = {rid: [1 + rid % 8, (rid % 5) + 1] for rid in range(n_req)}

    peak = [0]

    def run_once(paged: bool):
        cls = PagedEngine if paged else Engine
        kw = {"page_size": 16} if paged else {}
        eng = cls(model, params, slots=slots, max_len=max_len,
                  temperature=0.8, seed=7, compiled=compiled, **kw)
        for rid, p in prompts.items():
            eng.add_request(Request(rid, list(p), max_new=max_new))
        t0 = time.perf_counter()
        done = eng.run_to_completion(max_steps=500)
        wall = (time.perf_counter() - t0) * 1e6
        if paged:
            peak[0] = eng.peak_admitted
        return wall, {r.rid: tuple(r.out) for r in done}

    run_once(False)                  # compile + warm both paths
    run_once(True)
    walls_d, walls_p = [], []
    out_d = out_p = None
    for _ in range(7):               # paired: alternate inside one loop
        w, out_d = run_once(False)
        walls_d.append(w)
        w, out_p = run_once(True)
        walls_p.append(w)
    identical = out_d == out_p
    us_d, us_p = min(walls_d), min(walls_p)
    autotune.record_pinned("table5/engine_paged", walls_p,
                           baseline_us=walls_d)
    return [
        ("table5/engine_dense", us_d,
         f"dense-slot LM engine wall, {slots} slots x max_len={max_len}, "
         f"{n_req} requests x {max_new} tokens (admission bound: slots)"),
        ("table5/engine_paged", us_p,
         f"paged KV (page_size=16), admission bound: free pages — "
         f"peak_admitted={peak[0]} on {slots} lanes;"
         f"bit_identical={identical};paged_speedup={us_d / us_p:.2f}x"),
    ]


def run():
    from repro.archsim.energy import vwr2a_energy_uj
    from repro.archsim.programs.app import run_app
    from repro.core.fir import lowpass_taps

    rng = np.random.default_rng(0)
    t = np.arange(1024) / 64.0
    sig = 0.4 * np.sin(2 * np.pi * 0.3 * t) + 0.05 * rng.standard_normal(1024)
    out = run_app(sig, lowpass_taps(11), rng.normal(size=(12, 2)) * 0.3,
                  np.zeros(2))
    rows = []
    tot_c, tot_e = 0, 0.0
    steps = ("preprocessing", "delineation", "feat_extraction", "svm")
    for step in steps:
        counters, cycles = out[step]
        e = vwr2a_energy_uj(counters)
        key = step if step != "svm" else "feat_extraction"
        tot_c += cycles
        tot_e += e
        if step == "svm":
            rows.append((f"table5/svm", cycles / F_HZ * 1e6,
                         f"sim_cycles={cycles};sim_uJ={e:.4f}"))
            continue
        cpu_c, cpu_e = PAPER_CPU[step]
        v_c, v_e = PAPER_VWR2A[step]
        rows.append((f"table5/{step}", cycles / F_HZ * 1e6,
                     f"sim_cycles={cycles};paper_vwr2a={v_c};"
                     f"cycle_savings_vs_cpu={100 * (1 - cycles / cpu_c):.1f}%"
                     f"(paper {100 * (1 - v_c / cpu_c):.1f}%);"
                     f"sim_uJ={e:.3f};"
                     f"energy_savings_vs_cpu={100 * (1 - e / cpu_e):.1f}%"))
    cpu_c, cpu_e = PAPER_CPU["total"]
    v_c, v_e = PAPER_VWR2A["total"]
    rows.append(("table5/total", tot_c / F_HZ * 1e6,
                 f"sim_cycles={tot_c};paper_vwr2a={v_c};"
                 f"cycle_savings_vs_cpu={100 * (1 - tot_c / cpu_c):.1f}%"
                 f"(paper 90.9%);sim_uJ={tot_e:.3f};"
                 f"energy_savings_vs_cpu={100 * (1 - tot_e / cpu_e):.1f}%"
                 f"(paper 66.3%)"))
    rows += _pipeline_rows()
    rows += _stream_rows()
    rows += _asr_rows()
    rows += _column_rows()
    rows += _hetero_rows()
    rows += _resident_rows()
    rows += _depth_rows()
    rows += _fault_rows()
    rows += _engine_fault_rows()
    rows += _engine_paged_rows()
    return rows

"""Public jit'd API for the FFT kernel + real-FFT packing wrapper."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fft.kernel import fft_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fft(re, im=None, *, inverse: bool = False):
    """Batched complex FFT (R, N) via the Pallas kernel."""
    if im is None:
        im = jnp.zeros_like(re)
    return fft_pallas(re, im, inverse=inverse, interpret=_interpret())


def rfft(x):
    """Real FFT via the paper's N-real -> N/2-complex packing; untangle on
    the host side of the kernel (cheap O(N) epilogue)."""
    n = x.shape[-1]
    zr, zi = x[..., 0::2], x[..., 1::2]
    Zr, Zi = fft(zr, zi)
    m = n // 2
    idx = (-jnp.arange(m)) % m
    Zcr, Zci = Zr[..., idx], -Zi[..., idx]
    ang = -2.0 * np.pi * np.arange(m) / n
    wr = jnp.asarray(np.cos(ang), Zr.dtype)
    wi = jnp.asarray(np.sin(ang), Zr.dtype)
    er, ei = (Zr + Zcr) * 0.5, (Zi + Zci) * 0.5
    or_, oi = (Zr - Zcr) * 0.5, (Zi - Zci) * 0.5
    pr = wr * or_ - wi * oi
    pi = wr * oi + wi * or_
    Xr = er + pi
    Xi = ei - pr
    nyq = (Zr[..., :1] - Zi[..., :1])
    return (jnp.concatenate([Xr, nyq], axis=-1),
            jnp.concatenate([Xi, jnp.zeros_like(nyq)], axis=-1))

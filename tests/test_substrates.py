"""Optimizer, data pipeline, checkpointing, compression, fault logic."""
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.runtime.fault import (HeartbeatMonitor, StragglerDetector,
                                 Supervisor, elastic_plan)
from repro.train import optim
from repro.train.compress import (EFCompressor, dequantize_block_int8,
                                  quantize_block_int8)


# ---------------- optimizer ----------------

@pytest.mark.parametrize("v_dtype", [jnp.float32, "qint8"])
@pytest.mark.parametrize("m_dtype", [jnp.float32, jnp.bfloat16])
def test_adamw_converges_quadratic(v_dtype, m_dtype):
    oc = optim.OptConfig(lr=0.1, warmup_steps=1, total_steps=200,
                         weight_decay=0.0, m_dtype=m_dtype, v_dtype=v_dtype)
    target = jnp.asarray(np.linspace(-2, 2, 64, dtype=np.float32)).reshape(8, 8)
    params = {"w": jnp.zeros((8, 8))}
    state = optim.init_opt_state(params, oc)
    for _ in range(150):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = optim.adamw_update(grads, state, params, oc)
    err = float(jnp.abs(params["w"] - target).max())
    assert err < 0.05, err


def test_schedule_shape():
    oc = optim.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                         min_lr_ratio=0.1)
    s = [float(optim.schedule(jnp.asarray(t), oc)) for t in range(101)]
    assert s[0] < 0.2 and abs(s[10] - 1.0) < 1e-5
    assert s[100] == pytest.approx(0.1, rel=1e-3)
    assert all(a >= b - 1e-9 for a, b in zip(s[10:], s[11:]))  # monotone


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, gn = optim.clip_by_global_norm(g, 1.0)
    assert float(gn) > 100
    assert float(optim.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


# ---------------- data pipeline ----------------

def test_data_determinism_and_sharding():
    dc = DataConfig(vocab_size=97, seq_len=16, global_batch=8)
    full = ShardedLoader(dc, 0, 1).batch(3)
    shards = [ShardedLoader(dc, h, 4).batch(3) for h in range(4)]
    merged = np.concatenate([s["tokens"] for s in shards])
    np.testing.assert_array_equal(merged, full["tokens"])
    again = ShardedLoader(dc, 0, 1).batch(3)
    np.testing.assert_array_equal(again["tokens"], full["tokens"])
    assert full["tokens"].max() < 97 and full["tokens"].min() >= 0
    # labels are next tokens
    np.testing.assert_array_equal(full["labels"][:, :-1],
                                  full["tokens"][:, 1:])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.integers(0, 1000))
def test_data_steps_differ(s1, s2):
    dc = DataConfig(vocab_size=1000, seq_len=32, global_batch=2)
    l = ShardedLoader(dc)
    if s1 != s2:
        assert not np.array_equal(l.batch(s1)["tokens"],
                                  l.batch(s2)["tokens"])


# ---------------- checkpoint ----------------

def test_checkpoint_roundtrip_and_resume():
    d = tempfile.mkdtemp()
    try:
        state = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
                 "opt": {"m": [jnp.ones(3), jnp.zeros(2)]},
                 "step": jnp.asarray(7)}
        ckpt.save(state, 7, d)
        ckpt.save(state, 9, d)
        assert ckpt.latest_step(d) == 9
        out = ckpt.restore(d, 9, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        shutil.rmtree(d)


def test_checkpoint_async_and_reshard():
    d = tempfile.mkdtemp()
    try:
        state = {"w": jnp.arange(64.0).reshape(8, 8)}
        _, t = ckpt.save(state, 1, d, async_write=True)
        t.join()
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        sh = {"w": jax.NamedSharding(mesh, jax.sharding.PartitionSpec(
            "data", None))}
        out = ckpt.restore(d, 1, state, sh)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(state["w"]))
        assert out["w"].sharding.spec == sh["w"].spec
    finally:
        shutil.rmtree(d)


# ---------------- compression ----------------

@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 400))
def test_int8_quant_error_bound(seed, n):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=n).astype(np.float32)) * 10
    q, s = quantize_block_int8(x, block=64)
    deq = dequantize_block_int8(q, s, x.shape)
    blockmax = np.abs(np.asarray(x)).max()
    assert float(jnp.abs(deq - x).max()) <= blockmax / 127.0 + 1e-6


def test_error_feedback_reduces_bias(rng):
    grads = {"w": jnp.asarray(rng.normal(size=(256,)).astype(np.float32))}
    c = EFCompressor(block=64)
    res = c.init(grads)
    acc_plain = np.zeros(256)
    acc_ef = np.zeros(256)
    for _ in range(50):
        comp, res = c.compress(grads, res)
        acc_ef += np.asarray(c.decompress(comp, grads)["w"])
        q, s = quantize_block_int8(grads["w"], 64)
        acc_plain += np.asarray(dequantize_block_int8(q, s, (256,)))
    true = np.asarray(grads["w"]) * 50
    assert np.abs(acc_ef - true).max() <= np.abs(acc_plain - true).max() + 1e-4
    assert np.abs(acc_ef - true).max() < 0.2


# ---------------- fault tolerance ----------------

def test_heartbeat_and_stragglers():
    hb = HeartbeatMonitor(timeout_s=10)
    hb.beat(0, t=100.0)
    hb.beat(1, t=105.0)
    assert hb.dead(now=112.0) == [0]
    assert hb.alive(now=112.0) == [1]

    det = StragglerDetector(straggler_factor=2.0, evict_after=2)
    for step in range(10):
        for w in range(4):
            det.record(w, 1.0 if w != 3 else 5.0)
        det.stragglers()
    assert det.stragglers() == [3]


@settings(max_examples=25, deadline=None)
@given(st.integers(16, 4096))
def test_elastic_plan_invariants(chips):
    plan = elastic_plan(chips, model_axis=16, pods_of=256)
    assert plan["chips"] <= chips
    assert plan["model"] == 16
    assert plan["data"] & (plan["data"] - 1) == 0      # power of two
    assert plan["chips"] == plan["pod"] * plan["data"] * plan["model"]


def test_supervisor_recovers_from_failures():
    store = {}

    def save_fn(state, step):
        store[step] = float(state)

    def restore_fn(step):
        return jnp.asarray(store.get(step, 0.0))

    failures = {7, 15}

    def inject(step):
        if step in failures:
            failures.discard(step)
            raise RuntimeError("node lost")

    def step_fn(state, batch):
        return state + batch, {"loss": state}

    sup = Supervisor(save_fn=save_fn, restore_fn=restore_fn, ckpt_every=5)
    save_fn(jnp.asarray(0.0), 0)
    state, step, _ = sup.run(jnp.asarray(0.0), step_fn,
                             lambda s: jnp.asarray(1.0), 20,
                             inject_failure=inject)
    assert step == 20
    assert float(state) == 20.0      # deterministic replay => exact result


def test_psum_compressed_shard_map(rng):
    """Compressed all-reduce building block under shard_map (1 device)."""
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.train.compress import psum_compressed

    mesh = jax.make_mesh((1,), ("pod",))
    x = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    f = shard_map(lambda v: psum_compressed(v, "pod"), mesh=mesh,
                  in_specs=P(), out_specs=P(), check_rep=False)
    with mesh:
        y = f(x)
    # single member: psum is identity up to int8 quantization error
    assert float(jnp.abs(y - x).max()) <= float(jnp.abs(x).max()) / 127 + 1e-6

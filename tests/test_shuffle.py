"""Shuffle-unit kernel: sweeps vs oracle + algebraic properties."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.shuffle import (bit_reverse, circular_shift, deinterleave,
                                interleave, prune)
from repro.kernels.shuffle.ops import shuffle, shuffle_ref

OPS = ["interleave", "prune_even", "prune_odd", "bit_reverse",
       "circular_shift"]


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("shape", [(8, 128), (16, 64), (1, 256), (64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_kernel_matches_oracle(op, shape, dtype, rng):
    a = jnp.asarray(rng.integers(-100, 100, shape)).astype(dtype)
    b = jnp.asarray(rng.integers(-100, 100, shape)).astype(dtype)
    halves = ["both"] if op.startswith("prune") else ["lower", "upper", "both"]
    for half in halves:
        got = shuffle(a, b, op, half=half)
        want = shuffle_ref(a, b, op, half=half)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(0, 2 ** 31 - 1))
def test_interleave_deinterleave_roundtrip(logn, seed):
    n = 1 << logn
    r = np.random.default_rng(seed)
    a = jnp.asarray(r.normal(size=(3, n)).astype(np.float32))
    b = jnp.asarray(r.normal(size=(3, n)).astype(np.float32))
    ev, od = deinterleave(interleave(a, b))
    np.testing.assert_array_equal(np.asarray(ev), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(od), np.asarray(b))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(0, 2 ** 31 - 1))
def test_bit_reverse_involution(logn, seed):
    n = 1 << logn
    r = np.random.default_rng(seed)
    a = jnp.asarray(r.normal(size=(n,)).astype(np.float32))
    b = jnp.asarray(r.normal(size=(n,)).astype(np.float32))
    once = bit_reverse(a, b)
    twice = bit_reverse(once[..., :n], once[..., n:])
    np.testing.assert_array_equal(np.asarray(twice[..., :n]), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(twice[..., n:]), np.asarray(b))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(0, 63), st.integers(0, 63))
def test_circular_shift_composes(logn, s1, s2):
    n = 1 << logn
    a = jnp.arange(n, dtype=jnp.float32)
    b = a + 1000
    one = circular_shift(a, b, amount=(s1 + s2) % (2 * n))
    two_a = circular_shift(a, b, amount=s1 % (2 * n))
    two = circular_shift(two_a[..., :n], two_a[..., n:],
                         amount=s2 % (2 * n))
    np.testing.assert_array_equal(np.asarray(one), np.asarray(two))


def test_prune_keeps_survivors(rng):
    a = jnp.arange(16.0)
    b = jnp.arange(16.0) + 100
    out = prune(a, b, drop="even")
    np.testing.assert_array_equal(np.asarray(out[:8]), np.asarray(a[1::2]))
    np.testing.assert_array_equal(np.asarray(out[8:]), np.asarray(b[1::2]))

"""Public jit'd API for the FIR kernel."""
from __future__ import annotations

import jax

from repro.kernels.fir.kernel import fir_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fir(x, taps, *, seq_block: int = 2048,
        block_rows: int | None = None, autotune: bool = False):
    """Causal FIR along the last axis. x: (R, S) or (S,).

    ``autotune=True`` picks the row-block from measured candidates (cached
    per shape) instead of the static VWRSpec budget."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    interp = _interpret()
    if autotune and block_rows is None:
        from repro.core.autotune import tuned_block_rows

        R, S = x.shape
        block_rows = tuned_block_rows(
            "fir", R, (S, seq_block, str(x.dtype), int(taps.shape[0])),
            lambda rb: fir_pallas(x, taps, seq_block=seq_block,
                                  interpret=interp, block_rows=rb))
    y = fir_pallas(x, taps, seq_block=seq_block, interpret=interp,
                   block_rows=block_rows)
    return y[0] if squeeze else y

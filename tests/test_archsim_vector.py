"""Vectorized-engine regression: the NumPy k-sweep interpreter must match
the seed scalar interpreter BIT-EXACTLY (numerics, memory state, and every
activity counter) on all generated kernel programs, and the parameterized
machine must scale wall cycles down monotonically with column count."""
import dataclasses

import numpy as np
import pytest

from repro.archsim.isa import LCUInstr, LSUInstr, MXCUInstr, RCInstr, SlotWord
from repro.archsim.machine import RC_SLICE, VWR2A
from repro.archsim.programs.app import run_delineate
from repro.archsim.programs.fft import run_fft, run_rfft
from repro.archsim.programs.fir import run_fir
from repro.core.fir import fir_reference, lowpass_taps


def assert_machines_identical(ma: VWR2A, mb: VWR2A):
    np.testing.assert_array_equal(ma.spm, mb.spm)
    np.testing.assert_array_equal(ma.srf, mb.srf)
    for ca, cb in zip(ma.cols, mb.cols):
        assert dataclasses.asdict(ca.counters) == dataclasses.asdict(
            cb.counters)
        for n in "ABC":
            np.testing.assert_array_equal(ca.vwr[n], cb.vwr[n])
        np.testing.assert_array_equal(ca.rc_regs, cb.rc_regs)
        np.testing.assert_array_equal(ca.rc_last, cb.rc_last)
        assert ca.k == cb.k


def both_engines():
    return VWR2A(engine="scalar"), VWR2A(engine="vector")


@pytest.mark.parametrize("n", [64, 256])
def test_fft_engine_equivalence(n, rng):
    x = (rng.normal(size=n) + 1j * rng.normal(size=n)) * 0.3
    ms, mv = both_engines()
    Xs, cs, cys = run_fft(n, x, machine=ms)
    Xv, cv, cyv = run_fft(n, x, machine=mv)
    np.testing.assert_array_equal(Xs, Xv)
    assert cs == cv and cys == cyv
    assert_machines_identical(ms, mv)


def test_rfft_engine_equivalence(rng):
    x = rng.normal(size=512) * 0.3
    ms, mv = both_engines()
    Xs, cs, cys = run_rfft(512, x, machine=ms)
    Xv, cv, cyv = run_rfft(512, x, machine=mv)
    np.testing.assert_array_equal(Xs, Xv)
    assert cs == cv and cys == cyv
    assert_machines_identical(ms, mv)


def test_fir_engine_equivalence(rng):
    x = np.sin(np.arange(512) * 0.1) * 0.5
    taps = lowpass_taps(11)
    ms, mv = both_engines()
    ys, cs, cys = run_fir(x, taps, machine=ms)
    yv, cv, cyv = run_fir(x, taps, machine=mv)
    np.testing.assert_array_equal(ys, yv)
    assert cs == cv and cys == cyv
    assert_machines_identical(ms, mv)


def test_delineate_engine_equivalence(rng):
    x = rng.normal(size=256) * 0.2
    ms, mv = both_engines()
    mx_s, mn_s, cs, cys = run_delineate(x, machine=ms)
    mx_v, mn_v, cv, cyv = run_delineate(x, machine=mv)
    np.testing.assert_array_equal(mx_s, mx_v)
    np.testing.assert_array_equal(mn_s, mn_v)
    assert cs == cv and cys == cyv
    assert_machines_identical(ms, mv)


def test_raw_sweep_program_equivalence():
    """Hand-built k-sweep (the shape compile_program vectorizes) matches."""
    progs = []
    for m in both_engines():
        a = np.arange(128, dtype=np.int64) - 64
        b = np.arange(128, dtype=np.int64) * 3
        m.spm[0], m.spm[1] = a, b
        prog = [SlotWord(lsu=LSUInstr("LOAD", "A", ("imm", 0))),
                SlotWord(lsu=LSUInstr("LOAD", "B", ("imm", 1)))]
        ins0 = RCInstr("SUB", ("vwr", "A"), ("vwr", "B"), ("reg", 0))
        ins1 = RCInstr("MUL", ("reg", 0), ("rc", 0), ("vwr", "C"))
        for k in range(RC_SLICE):
            prog.append(SlotWord(mxcu=MXCUInstr("SETK", k),
                                 rcs=(ins0, ins0, ins0, ins0)))
            prog.append(SlotWord(rcs=(ins1, ins1, ins1, ins1)))
        prog.append(SlotWord(lsu=LSUInstr("STORE", "C", ("imm", 2))))
        m.run([prog])
        progs.append(m)
    ms, mv = progs
    assert_machines_identical(ms, mv)
    np.testing.assert_array_equal(
        ms.spm[2], (np.arange(128) - 64 - np.arange(128) * 3) ** 2)


def test_branchy_program_falls_back_to_scalar():
    """LCU control flow must run on the scalar path with identical state."""
    results = []
    for m in both_engines():
        body = SlotWord(lcu=LCUInstr("ADDI", reg=0, val=1),
                        rcs=(RCInstr("ADD", ("reg", 0), ("imm", 3),
                                     ("reg", 0)),
                             RCInstr(), RCInstr(), RCInstr()))
        prog = [SlotWord(lcu=LCUInstr("SETI", reg=0, val=0)),
                body,
                SlotWord(lcu=LCUInstr("BLT", reg=0, val=7, target=1)),
                SlotWord(lcu=LCUInstr("EXIT"))]
        m.run([prog])
        results.append(m)
    assert_machines_identical(*results)
    assert int(results[0].cols[0].rc_regs[0, 0]) == 21


@pytest.mark.parametrize("n_columns", [1, 2, 4])
def test_fft_multicolumn_numerics(n_columns, rng):
    x = (rng.normal(size=256) + 1j * rng.normal(size=256)) * 0.3
    X, _, cycles = run_fft(256, x, n_columns=n_columns)
    ref = np.fft.fft(x)
    assert np.abs(X - ref).max() / np.abs(ref).max() < 0.01
    assert cycles > 0


def test_fft_multicolumn_cycle_scaling(rng):
    x = (rng.normal(size=256) + 1j * rng.normal(size=256)) * 0.3
    cycles = [run_fft(256, x, n_columns=nc)[2] for nc in (1, 2, 4)]
    assert cycles[0] > cycles[1] > cycles[2]
    # total activity (energy proxy) is conserved, only spread over columns
    ops = [run_fft(256, x, n_columns=nc)[1].rc_ops for nc in (1, 2, 4)]
    assert ops[0] == ops[1] == ops[2]


@pytest.mark.parametrize("n_columns", [1, 2, 4])
def test_fir_multicolumn_numerics(n_columns, rng):
    taps = lowpass_taps(11)
    x = np.sin(np.arange(512) * 0.1) * 0.5
    y, counters, cycles = run_fir(x, taps, n_columns=n_columns)
    ref = fir_reference(x[None, :], taps)[0]
    assert np.abs(y - ref).max() < 1e-3
    assert counters.dma_words == 1024


def test_unprovable_dest_falls_back_to_scalar():
    """A sweep with an RC dest outside the proven subset (("win", ...))
    must run on the scalar path, not crash the vector engine."""
    results = []
    for m in both_engines():
        m.spm[0] = np.arange(128)
        prog = [SlotWord(lsu=LSUInstr("LOAD", "A", ("imm", 0)))]
        ins = RCInstr("ADD", ("vwr", "A"), ("imm", 1), ("win", 0))
        for k in range(RC_SLICE):
            prog.append(SlotWord(mxcu=MXCUInstr("SETK", k),
                                 rcs=(ins, ins, ins, ins)))
        m.run([prog])
        results.append(m)
    assert_machines_identical(*results)


@pytest.mark.parametrize("n_columns", [1, 2, 3, 4, 5])
def test_rfft_activity_conserved_any_width(n_columns, rng):
    """Host-side cycle charges must conserve total activity for ANY
    column count — the energy model integrates these counters."""
    x = rng.normal(size=512) * 0.3
    _, ref, _ = run_rfft(512, x, n_columns=2)
    _, c, _ = run_rfft(512, x, n_columns=n_columns)
    for f in ("rc_ops", "rc_mults", "vwr_reads", "vwr_writes",
              "spm_line_reads", "spm_line_writes"):
        assert getattr(c, f) == getattr(ref, f), f


def test_fir_multicolumn_cycle_scaling():
    taps = lowpass_taps(11)
    x = np.sin(np.arange(512) * 0.1) * 0.5
    cycles = [run_fir(x, taps, n_columns=nc)[2] for nc in (1, 2, 4)]
    assert cycles[0] > cycles[1] > cycles[2]
    assert cycles[0] >= 2 * cycles[1]          # blocks split evenly

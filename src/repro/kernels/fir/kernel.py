"""Pallas TPU kernel: k-tap causal FIR / depthwise conv (paper §4.4.1).

Grid walks (row-block, seq-block) tiles. Each seq block is staged together
with a (k-1)-word halo — the trailing words of the previous block, prepared
by the host-side wrapper exactly like VWR2A's LSU uses the *circular shift*
shuffle to deliver slice-boundary words (paper §3.3.1). Taps unroll to k
shifted FMAs on the VPU; accumulation is f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.vwr import resolve_block_rows


def fir_kernel(x_ref, halo_ref, taps_ref, o_ref, *, k: int):
    x = x_ref[...]                       # (rb, sb)
    halo = halo_ref[:, 0, :]             # (rb, k-1)
    xp = jnp.concatenate([halo, x], axis=-1)     # (rb, sb + k - 1)
    acc = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):                   # unrolled taps == circular shifts
        acc += taps_ref[0, i] * xp[:, k - 1 - i: k - 1 - i + x.shape[-1]
                                   ].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "seq_block", "block_rows"))
def fir_pallas(x, taps, *, seq_block: int = 2048, interpret: bool = True,
               block_rows: int | None = None):
    """x: (R, S); taps: (k,). Causal FIR along the last axis.

    ``block_rows`` overrides the static VWRSpec row budget (core/autotune.py
    feeds a measured winner through here)."""
    R, S = x.shape
    k = int(taps.shape[0])
    sb = min(seq_block, S)
    while S % sb:
        sb -= 1
    assert sb >= k, (sb, k)
    nb = S // sb
    # halo[j] = last (k-1) words of block j-1 (zeros for j=0) — the LSU-
    # prepared boundary words
    ends = jnp.arange(nb) * sb - (k - 1)
    gather_idx = ends[:, None] + jnp.arange(k - 1)[None, :]     # (nb, k-1)
    halo = jnp.where(gather_idx[None, :, :] >= 0,
                     x[:, jnp.maximum(gather_idx, 0)], 0).astype(x.dtype)
    rb = resolve_block_rows(R, sb * x.dtype.itemsize,
                            elem_bytes=x.dtype.itemsize, override=block_rows)
    taps2 = taps.reshape(1, k).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(fir_kernel, k=k),
        out_shape=jax.ShapeDtypeStruct((R, S), x.dtype),
        in_specs=[
            pl.BlockSpec((rb, sb), lambda i, j: (i, j),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((rb, 1, k - 1), lambda i, j: (i, j, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, k), lambda i, j: (0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((rb, sb), lambda i, j: (i, j),
                               memory_space=pltpu.VMEM),
        grid=(R // rb, nb),
        interpret=interpret,
    )(x, halo, taps2)

"""Serving CLI: batched decode with the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model, init_model_params
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = init_model_params(model, args.seed)
    eng = Engine(model, params, slots=args.slots, max_len=args.max_len,
                 temperature=args.temperature, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        plen = int(rng.integers(2, 8))
        prompt = rng.integers(1, cfg.vocab_size, size=plen).tolist()
        eng.submit(Request(rid, prompt, max_new=args.max_new))
    done = eng.run_to_completion()
    dt = time.perf_counter() - t0
    tok = sum(len(r.out) for r in done)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt={r.prompt} -> {r.out}")
    print(f"[serve] {len(done)} requests, {tok} tokens, "
          f"{tok / dt:.1f} tok/s (CPU interpret)")


if __name__ == "__main__":
    main()

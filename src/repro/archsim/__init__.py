"""Cycle-accurate VWR2A simulator + Table-3-calibrated energy model.

machine.py — 2 columns x (4 RCs + LSU + MXCU + LCU), 3x128-word VWRs,
32 KiB SPM, SRF, shuffle unit, q16.15 datapath. programs/ — generated
kernel mappings (FFT §3.4, FIR §4.4.1, MBioTracker app §4.4.2).
"""
from repro.archsim import energy, isa, machine  # noqa: F401

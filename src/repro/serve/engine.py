"""Batched serving engine: continuous-batching decode over fixed slots.

Requests occupy slots of a fixed-capacity batch; each engine step decodes
one token for every live slot (one jit'd decode_fn call — padding slots
ride along). Prefill fills a slot's cache region. Greedy or temperature
sampling. The same engine drives the serve_lm example and the serving
integration tests.

`ColumnScheduler` is the admission policy for the OTHER traffic class the
repo serves — continuous biosignal streams: independent streams are placed
on distinct column replicas (devices), the multi-tenant complement of
sharding one stream across all columns (`StreamConfig.n_columns`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_cache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, model, params, *, slots: int = 4, max_len: int = 256,
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.cache = init_cache(model, slots, max_len)
        self.live: list[Optional[Request]] = [None] * slots
        self.lens = np.zeros(slots, np.int32)
        self.queue: list[Request] = []
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(model.decode)
        self._prefill = jax.jit(model.prefill)

    def submit(self, req: Request):
        self.queue.append(req)

    def _length_bucket(self, n: int) -> int:
        """Pad prompt lengths up to the next power of two so bursty mixed-
        length traffic funnels into a handful of prefill trace shapes —
        capped at max_len: the cache has no rows past it, and a valid
        prompt of length <= max_len must not be padded beyond it."""
        return min(1 << max(n - 1, 0).bit_length(), self.max_len)

    def _admit(self):
        # claim every free slot first, then admit them in as few prefill
        # dispatches as possible (one per prompt-length bucket) — under
        # bursty load the seed's request-at-a-time admission paid one
        # dispatch per request
        admitted = []
        for s in range(self.slots):
            if self.live[s] is None and self.queue:
                req = self.queue.pop(0)
                self.live[s] = req
                admitted.append((s, req))
        if not admitted:
            return
        if getattr(self.model.cfg, "is_encdec", False):
            # enc-dec decoders have no engine-supplied encoder frames:
            # prefill mode would run _encode, so keep the token-at-a-time
            # decode-mode admission for them
            for s, req in admitted:
                for t, tok in enumerate(req.prompt):
                    batch = {"tokens": jnp.full((self.slots, 1), tok,
                                                jnp.int32),
                             "cache_len": jnp.asarray(t, jnp.int32)}
                    _, cache = self._decode(self.params, batch, self.cache)
                    self.cache = self._merge_slots(cache, [s])
                self.lens[s] = len(req.prompt)
            return
        # Right-padding a prompt is safe for LINEAR causal-attention
        # caches (pad positions only write K/V beyond the prompt, which
        # decode masks via cache_len and overwrites before it becomes
        # visible), but NOT for recurrent state (every consumed token
        # mutates it) nor for sliding-window RING caches (the kept k[-W:]
        # tail and the slot rotation are computed from the padded length,
        # so pad keys evict real prompt keys) — those bucket by exact
        # length instead.
        cfg = self.model.cfg
        pad_ok = (getattr(cfg, "ssm", None) is None and
                  getattr(cfg, "sliding_window", None) is None)
        buckets: dict[int, list] = {}
        for s, req in admitted:
            n = len(req.prompt)
            buckets.setdefault(self._length_bucket(n) if pad_ok else n,
                               []).append((s, req))
        for width, group in sorted(buckets.items()):
            # one padded prefill for the whole bucket: every admitted
            # slot's prompt K/V written in a single dispatch; the cache
            # merge keeps only the group's rows (identical semantics to
            # per-request admission, len(group)x fewer dispatches)
            tokens = np.zeros((self.slots, width), np.int32)
            for s, req in group:
                tokens[s, : len(req.prompt)] = req.prompt
            _, cache = self._prefill(self.params,
                                     {"tokens": jnp.asarray(tokens)},
                                     self.cache)
            self.cache = self._merge_slots(cache, [s for s, _ in group])
            for s, req in group:
                self.lens[s] = len(req.prompt)

    def _merge_slots(self, new_cache, slots: list):
        # admission updates every slot's cache row; keep only the admitted
        # `slots` rows from the new cache
        idx = np.asarray(slots)

        def merge(old, new):
            if old.ndim >= 1 and old.shape[0] == self.slots:
                return old.at[idx].set(new[idx])
            # stacked-layer leading dim: slot axis is axis 1
            if old.ndim >= 2 and old.shape[1] == self.slots:
                return old.at[:, idx].set(new[:, idx])
            return new
        return jax.tree.map(merge, self.cache, new_cache)

    def step(self):
        """One decode step for all live slots; returns finished requests."""
        self._admit()
        live_mask = np.array([r is not None for r in self.live])
        if not live_mask.any():
            return []
        last_tokens = np.zeros((self.slots, 1), np.int32)
        for s, r in enumerate(self.live):
            if r is not None:
                seq = r.prompt + r.out
                last_tokens[s, 0] = seq[-1]
        # per-slot positions (continuous batching): slot s's last token sits
        # at index lens[s]-1; dead slots park at 0 (overwritten on admit)
        cl = np.maximum(self.lens - 1, 0).astype(np.int32)
        batch = {"tokens": jnp.asarray(last_tokens),
                 "cache_len": jnp.asarray(cl)}
        logits, self.cache = self._decode(self.params, batch, self.cache)
        # one batched sample over ALL slots (dead slots ride along and are
        # ignored below) — a single key split + categorical/argmax instead
        # of a per-slot Python loop
        if self.temperature > 0:
            self.key, sub = jax.random.split(self.key)
            sampled = np.asarray(jax.random.categorical(
                sub, logits[:, 0, :] / self.temperature, axis=-1))
        else:
            sampled = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        finished = []
        for s, r in enumerate(self.live):
            if r is None:
                continue
            tok = int(sampled[s])
            r.out.append(tok)
            self.lens[s] += 1
            if len(r.out) >= r.max_new or self.lens[s] >= self.max_len - 1:
                r.done = True
                finished.append(r)
                self.live[s] = None
                self.lens[s] = 0
        return finished

    def run_to_completion(self, max_steps: int = 10_000):
        done = []
        for _ in range(max_steps):
            done += self.step()
            if not self.queue and all(r is None for r in self.live):
                break
        return done


class ColumnScheduler:
    """Admission placement of independent biosignal streams onto column
    replicas (devices).

    Two ways to use D columns: one heavy stream `shard_map`s each dispatch
    across all of them (`StreamConfig.n_columns=D`), or D independent
    streams each stay resident on ONE column — no cross-device halo, and
    per-column autotune winners stay valid because every column sees the
    single-column shape. This scheduler implements the second: `admit`
    pins a new stream to the least-loaded column (ties broken by column
    index, so an idle machine fills round-robin — the archsim pass deal),
    `release` frees it on stream close.

    >>> sched = ColumnScheduler()
    >>> stream = BiosignalStream(app, cfg, device=sched.admit("sensor-7"))
    """

    def __init__(self, devices=None):
        self.devices = list(devices) if devices is not None \
            else list(jax.devices())
        assert self.devices, "no devices to schedule columns on"
        self._load = [0] * len(self.devices)
        self._placement: dict = {}

    @property
    def n_columns(self) -> int:
        return len(self.devices)

    def column_of(self, stream_id) -> int:
        return self._placement[stream_id]

    def loads(self) -> list:
        """Live-stream count per column (admission balance introspection)."""
        return list(self._load)

    def admit(self, stream_id):
        """Place a new stream; returns the device to pin it to
        (`BiosignalStream(..., device=...)`)."""
        assert stream_id not in self._placement, \
            f"stream {stream_id!r} already placed"
        col = min(range(len(self.devices)), key=lambda i: (self._load[i], i))
        self._load[col] += 1
        self._placement[stream_id] = col
        return self.devices[col]

    def release(self, stream_id) -> None:
        self._load[self._placement.pop(stream_id)] -= 1

    def open_stream(self, app=None, cfg=None, *, stream_id):
        """Admit + construct in one call: a `BiosignalStream` whose every
        dispatch is committed to the assigned column."""
        from repro.serve.stream import BiosignalStream

        return BiosignalStream(app, cfg, device=self.admit(stream_id))

"""Int8 error-feedback gradient compression for the cross-pod (DCN) hop.

At 2+ pods the gradient all-reduce crosses data-center network links that
are ~25x slower than ICI; compressing the pod-level reduction 4x (f32->int8
with per-block scales) moves the §Roofline collective term down by the same
factor on that hop. Error feedback keeps the quantization noise unbiased
over time (the residual is added back before the next quantization), which
is the standard convergence-preserving trick.

`psum_compressed` is the shard_map building block; `EFCompressor` carries
the residual state in the train loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_block_int8(x, block: int = 256):
    """x: any shape -> (q int8, scale f32 per block of the flat last dim)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    fp = jnp.pad(flat, (0, pad))
    fb = fp.reshape(-1, block)
    scale = jnp.max(jnp.abs(fb), axis=1, keepdims=True) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(fb / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_block_int8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


class EFCompressor:
    """Error-feedback int8 compressor for a gradient pytree."""

    def __init__(self, block: int = 256):
        self.block = block

    def init(self, grads):
        return jax.tree.map(jnp.zeros_like, grads)

    def compress(self, grads, residual):
        """-> (quantized tree [(q, scale, shape)], new residual)."""
        def one(g, r):
            g = g.astype(jnp.float32) + r.astype(jnp.float32)
            q, s = quantize_block_int8(g, self.block)
            deq = dequantize_block_int8(q, s, g.shape)
            return (q, s), (g - deq)

        flat_g, td = jax.tree.flatten(grads)
        flat_r = td.flatten_up_to(residual)
        pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
        comp = td.unflatten([p[0] for p in pairs])
        new_res = td.unflatten([p[1] for p in pairs])
        return comp, new_res

    def decompress(self, comp, like):
        flat_c, td = jax.tree.flatten(comp, is_leaf=lambda x: isinstance(
            x, tuple) and len(x) == 2 and hasattr(x[0], "dtype"))
        flat_l = td.flatten_up_to(like)
        return td.unflatten([
            dequantize_block_int8(q, s, l.shape).astype(l.dtype)
            for (q, s), l in zip(flat_c, flat_l)])


def psum_compressed(x, axis_name: str, *, block: int = 256):
    """shard_map collective: int8-quantize, all-reduce the int32 partial
    sums + f32 scales, dequantize. Wire bytes on the `axis_name` hop drop
    ~4x vs f32 (q int8 + 1/block scales)."""
    q, s = quantize_block_int8(x, block)
    # reduce dequantized per-block contributions: sum_i q_i * s_i
    part = q.astype(jnp.float32) * s
    tot = jax.lax.psum(part, axis_name)     # models the compressed exchange
    n = 1
    for d in x.shape:
        n *= d
    return tot.reshape(-1)[:n].reshape(x.shape)

"""Paged KV cache (`serve/paged.py` + `serve/engine.py:PagedEngine`).

THE INVARIANT under test: paging is INVISIBLE in the tokens. For any
(page size, request mix, eviction/defrag schedule), `PagedEngine`'s
outputs are **bit-identical** to the dense `Engine`'s, greedy AND
temperature-sampled — masked positions (scratch garbage included)
contribute exactly zero to the attention softmax, so gathering a
short page-quantized view changes nothing downstream. On top of that
ride the pool-accounting properties (lowest-first alloc, scratch page
never allocated, every page freed by the end) and the headline
capability the redesign buys: ADMISSION BOUNDED BY FREE PAGES, i.e.
more concurrent requests in flight than the engine has decode lanes.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model, init_model_params
from repro.serve.engine import Engine, PagedEngine, Request
from repro.serve.errors import InsufficientPages, PagedCacheUnsupported
from repro.serve.paged import SCRATCH_PAGE, PagePool, PageTable

MAX_LEN, MAX_NEW = 64, 6
PROMPTS = {0: [3, 1, 4, 1], 1: [5, 9, 2], 2: [6, 5], 3: [8, 9, 7, 9, 3],
           4: [2, 3, 8], 5: [4, 6, 2, 6]}


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(reduced(get_config("qwen1.5-0.5b")),
                              vocab_size=64)
    model = build_model(cfg)
    params = init_model_params(model, seed=3)
    compiled = Engine.compile_model(model)
    return model, params, compiled


@pytest.fixture(scope="module")
def reference(setup):
    cache = {}

    def get(temperature: float):
        if temperature not in cache:
            cache[temperature] = _serve(setup, Engine, temperature)[0]
        return cache[temperature]

    return get


def _engine(setup, cls, temperature, *, slots=2, **kw):
    model, params, compiled = setup
    return cls(model, params, slots=slots, max_len=MAX_LEN,
               temperature=temperature, seed=7, compiled=compiled, **kw)


def _serve(setup, cls, temperature, *, slots=2, rids=tuple(PROMPTS), **kw):
    eng = _engine(setup, cls, temperature, slots=slots, **kw)
    for rid in rids:
        eng.add_request(Request(rid, list(PROMPTS[rid]), max_new=MAX_NEW))
    done = eng.run_to_completion(max_steps=500)
    assert sorted(r.rid for r in done) == sorted(rids)
    return {r.rid: tuple(r.out) for r in done}, eng


# --------------------------------------------------- pool/table accounting

def test_pool_alloc_lowest_first_and_scratch_reserved(setup):
    model = setup[0]
    pool = PagePool(model, page_size=8, n_pages=9, max_len=MAX_LEN)
    assert pool.capacity == 8 and pool.n_free == 8
    a = pool.alloc(3)
    assert a == (1, 2, 3)                      # lowest ids first
    assert SCRATCH_PAGE not in a
    b = pool.alloc(2)
    assert b == (4, 5)
    pool.free((2, 3))
    assert pool.n_free == 5
    # freed ids are reissued before untouched higher ones
    assert pool.alloc(2) == (2, 3)


def test_pool_insufficient_pages_typed(setup):
    pool = PagePool(setup[0], page_size=8, n_pages=5, max_len=MAX_LEN)
    pool.alloc(3)
    with pytest.raises(InsufficientPages) as ei:
        pool.alloc(2)
    assert ei.value.need == 2 and ei.value.free == 1
    assert ei.value.capacity == 4


def test_pages_for_is_page_quantized(setup):
    pool = PagePool(setup[0], page_size=8, n_pages=9, max_len=MAX_LEN)
    assert pool.pages_for(1) == 1
    assert pool.pages_for(8) == 1
    assert pool.pages_for(9) == 2
    # footprint saturates at max_len
    assert pool.pages_for(10_000) == MAX_LEN // 8


def test_block_table_scratch_padding_and_truncation(setup):
    pool = PagePool(setup[0], page_size=8, n_pages=9, max_len=MAX_LEN)
    table = PageTable(pool)
    table.assign("a", 3)
    table.assign("b", 1)
    bt = table.block_table(["a", None, "b"])
    assert bt.shape == (3, 3)                  # width = widest holder
    assert tuple(bt[0]) == table.pages("a")
    assert (bt[1] == SCRATCH_PAGE).all()       # empty lane: all scratch
    assert bt[2][0] == table.pages("b")[0]
    assert (bt[2][1:] == SCRATCH_PAGE).all()
    # a narrower explicit width truncates instead of raising (prefill
    # tables only address the pages the prompt touches)
    assert table.block_table(["a"], width=2).shape == (1, 2)
    table.release("a")
    assert not table.holds("a") and pool.n_free == 7


def test_defrag_compacts_and_moves_rows(setup):
    pool = PagePool(setup[0], page_size=8, n_pages=12, max_len=MAX_LEN)
    table = PageTable(pool)
    table.assign("a", 2)                       # pages (1, 2)
    table.assign("b", 2)                       # pages (3, 4)
    table.assign("c", 1)                       # page  (5,)
    # stamp a recognizable value into b's first page on every leaf
    marked = table.pages("b")[0]
    pool.leaves = [leaf.at[marked].set(7.0) for leaf in pool.leaves]
    table.release("a")                         # holes at 1, 2
    moves = table.defrag()
    # the held set compacts onto the lowest ids; 5 held pages -> 1..5
    assert set(moves.keys()) <= {3, 4, 5}
    held = table.pages("b") + table.pages("c")
    assert sorted(held) == [1, 2, 3]
    new_home = moves[marked]
    for leaf in pool.leaves:
        assert (np.asarray(leaf[new_home]) == 7.0).all()
    # page ids freed by the compaction are allocatable again
    assert pool.n_free == pool.capacity - 3


# ------------------------------------------------------ dense equivalence

@pytest.mark.parametrize("temperature", [0.0, 0.8])
@pytest.mark.parametrize("page_size", [4, 16])
def test_paged_matches_dense_bit_identical(setup, reference, temperature,
                                           page_size):
    """The tentpole property: same tokens, any page size, greedy and
    temperature-sampled — paging is invisible in the output."""
    out, eng = _serve(setup, PagedEngine, temperature,
                      page_size=page_size)
    assert out == reference(temperature)
    assert eng.pool.n_free == eng.pool.capacity   # every page freed


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_oversubscription_beyond_slots(setup, reference, temperature):
    """The capability the redesign buys: with short requests, MORE
    concurrent admissions than decode lanes (`peak_admitted > slots`),
    bounded by free pages — and still bit-identical to dense."""
    out, eng = _serve(setup, PagedEngine, temperature, slots=2,
                      page_size=8)
    assert out == reference(temperature)
    assert eng.peak_admitted > eng.slots
    assert eng.pool.n_free == eng.pool.capacity


def test_submission_order_invariance_paged(setup):
    """Per-request sampling streams survive the paged path: admission
    order permutes page assignment and lane placement, tokens don't."""
    eng = _engine(setup, PagedEngine, 0.9, page_size=8)
    for rid in [5, 2, 0, 4, 1, 3]:
        eng.add_request(Request(rid, list(PROMPTS[rid]), max_new=MAX_NEW))
    perm = {r.rid: tuple(r.out)
            for r in eng.run_to_completion(max_steps=500)}
    ref = _serve(setup, PagedEngine, 0.9, page_size=8)[0]
    assert perm == ref


def test_defrag_mid_decode_bit_identical(setup, reference):
    """Compacting pages between engine steps — after some requests have
    finished and left holes — must not change a single token."""
    eng = _engine(setup, PagedEngine, 0.8, page_size=4)
    for rid in PROMPTS:
        eng.add_request(Request(rid, list(PROMPTS[rid]), max_new=MAX_NEW))
    done = []
    steps = 0
    while eng._work_pending():
        done += eng.step()
        steps += 1
        if done:                     # holes exist: compact every step
            eng.defrag()
        assert steps < 500
    out = {r.rid: tuple(r.out) for r in done}
    assert out == reference(0.8)
    assert eng.pool.n_free == eng.pool.capacity


def test_request_larger_than_pool_typed(setup):
    model, params, compiled = setup
    eng = PagedEngine(model, params, slots=2, max_len=MAX_LEN,
                      compiled=compiled, page_size=8, n_pages=3)
    with pytest.raises(InsufficientPages):
        eng.add_request(Request(0, list(range(2, 30)), max_new=MAX_NEW))
    assert not eng.queue                # rejected, not half-admitted


# ----------------------------------------------- other cache geometries

def test_ring_sliding_window_paged_matches_dense():
    """The ring (sliding-window) cache leaf pages too: its view is
    always exactly W wide so the ring-decode path still triggers."""
    cfg = dataclasses.replace(reduced(get_config("h2o-danube-3-4b")),
                              vocab_size=64)
    model = build_model(cfg)
    params = init_model_params(model, seed=3)
    compiled = Engine.compile_model(model)
    args = dict(slots=2, max_len=MAX_LEN, temperature=0.8, seed=7,
                compiled=compiled)
    outs = []
    for cls, kw in ((Engine, {}), (PagedEngine, {"page_size": 8})):
        eng = cls(model, params, **args, **kw)
        for rid in (0, 1, 2, 3):
            eng.add_request(Request(rid, list(PROMPTS[rid]),
                                    max_new=MAX_NEW))
        outs.append({r.rid: tuple(r.out)
                     for r in eng.run_to_completion(max_steps=500)})
    assert outs[0] == outs[1]


def test_recurrent_state_rejected_typed():
    """A cache with no (batch, seq) leaves cannot be paged; the typed
    `PagedCacheUnsupported` fires at construction, not mid-serve."""
    cfg = dataclasses.replace(reduced(get_config("rwkv6-7b")),
                              vocab_size=64)
    model = build_model(cfg)
    params = init_model_params(model, seed=3)
    with pytest.raises(PagedCacheUnsupported):
        PagedEngine(model, params, slots=2, max_len=MAX_LEN)

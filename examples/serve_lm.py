"""Serve a small model with batched requests through the continuous-
batching engine (greedy decode over 4 slots).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model, init_model_params
from repro.serve.engine import Engine, Request

cfg = reduced(get_config("h2o-danube-3-4b"))   # exercises SWA decode
model = build_model(cfg)
params = init_model_params(model)
eng = Engine(model, params, slots=4, max_len=96)

rng = np.random.default_rng(0)
for rid in range(6):
    prompt = rng.integers(1, cfg.vocab_size, size=int(rng.integers(2, 6)))
    eng.submit(Request(rid, prompt.tolist(), max_new=12))

t0 = time.perf_counter()
done = eng.run_to_completion()
dt = time.perf_counter() - t0
for r in sorted(done, key=lambda r: r.rid):
    print(f"req {r.rid}: {r.prompt} -> {r.out}")
tok = sum(len(r.out) for r in done)
print(f"{len(done)} requests, {tok} tokens in {dt:.1f}s "
      f"({tok / dt:.1f} tok/s, CPU)")
assert len(done) == 6 and all(len(r.out) == 12 for r in done)
print("serve_lm OK")

"""zamba2-7b [arXiv:2411.15242; unverified] — hybrid: Mamba2 backbone with a
SHARED full-attention block applied periodically. 81 Mamba2 layers,
d_model 3584, ssm_state 64, shared attn 32H (MHA) + MLP d_ff 14336 every 6
layers (simplified from Zamba2's two alternating shared blocks; documented
in DESIGN.md)."""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,
    rope_theta=10000.0,
    ssm=SSMConfig(kind="mamba2", head_size=64, d_state=64, expand=2,
                  conv_kernel=4, chunk_size=64),
    shared_attn_every=6,
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-7B",
))

"""Accumulate the CI perf trajectory: BENCH_trajectory.json.

Each bench-smoke run produces a fresh BENCH_smoke.json (plus the pinned
records inside BENCH_autotune.json) — and until now that history died with
the run: artifacts are per-commit, so the trajectory across commits was
only reconstructible by hand. This tool appends ONE commit-stamped row per
run to a rolling BENCH_trajectory.json that CI persists via
`actions/cache` (restore-keys fall back to the branch's previous run, then
any run) and re-uploads as an artifact, so after two runs on main the
artifact carries >= 2 entries and the perf trajectory of every gated
headline number is a single downloadable file.

An entry is deliberately compact — {commit, branch, time, rows, pinned} —
where ``rows`` maps every bench row name to its us_per_call and ``pinned``
carries the paired-ratio records the regression gate runs on. Re-running a
commit (e.g. a re-triggered workflow) REPLACES its entry instead of
duplicating it; the file is capped at ``--max-entries`` (oldest dropped).
A missing or corrupt trajectory file starts fresh with a warning — a
broken cache restore must not fail the bench job, only re-seed history.

Usage:
    python -m benchmarks.trajectory append TRAJ.json BENCH.json \
        --commit SHA [--branch B] [--autotune BENCH_autotune.json] \
        [--max-entries N]
    python -m benchmarks.trajectory show TRAJ.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _load_trajectory(path: str) -> list[dict]:
    try:
        with open(path) as f:
            data = json.load(f)
        entries = data["entries"]
        assert isinstance(entries, list)
        return entries
    except FileNotFoundError:
        return []
    except Exception as e:  # corrupt restore: re-seed, don't fail the job
        print(f"trajectory: {path} unreadable ({type(e).__name__}: {e}) "
              f"- starting a fresh trajectory", file=sys.stderr)
        return []


def make_entry(bench: dict, *, commit: str, branch: str,
               pinned: dict | None = None,
               timestamp: float | None = None) -> dict:
    rows = {r["name"]: round(float(r["us_per_call"]), 2)
            for r in bench.get("rows", [])
            if isinstance(r.get("us_per_call"), (int, float))}
    return {"commit": commit, "branch": branch,
            "time": time.time() if timestamp is None else timestamp,
            "failed": bench.get("failed", 0), "rows": rows,
            "pinned": pinned or {}}


def append(traj_path: str, bench_path: str, *, commit: str, branch: str,
           autotune_path: str | None = None, max_entries: int = 500,
           timestamp: float | None = None) -> int:
    """Append (or replace, same commit) one entry; returns the new count."""
    with open(bench_path) as f:
        bench = json.load(f)
    pinned = {}
    if autotune_path:
        try:
            with open(autotune_path) as f:
                pinned = json.load(f).get("pinned", {})
        except Exception as e:
            print(f"trajectory: no pinned records from {autotune_path} "
                  f"({type(e).__name__})", file=sys.stderr)
    entries = _load_trajectory(traj_path)
    entries = [e for e in entries if e.get("commit") != commit]
    entries.append(make_entry(bench, commit=commit, branch=branch,
                              pinned=pinned, timestamp=timestamp))
    entries = entries[-max_entries:]
    with open(traj_path, "w") as f:
        json.dump({"entries": entries}, f, indent=1)
    return len(entries)


def show(traj_path: str) -> None:
    entries = _load_trajectory(traj_path)
    print(f"{traj_path}: {len(entries)} entries")
    for e in entries:
        pins = ", ".join(
            f"{k.split('/')[-1]}={v['ratio']:.2f}x"
            for k, v in sorted(e.get("pinned", {}).items())
            if isinstance(v, dict) and "ratio" in v)
        print(f"  {e.get('commit', '?')[:12]:12s} {e.get('branch', '?'):16s}"
              f" rows={len(e.get('rows', {})):3d}"
              f" failed={e.get('failed', 0)}  {pins}")


def main() -> None:
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="cmd", required=True)
    ap_a = sub.add_parser("append", help="append one run to the trajectory")
    ap_a.add_argument("trajectory")
    ap_a.add_argument("bench")
    ap_a.add_argument("--commit", required=True)
    ap_a.add_argument("--branch", default="")
    ap_a.add_argument("--autotune", default=None,
                      help="BENCH_autotune.json to lift pinned records from")
    ap_a.add_argument("--max-entries", type=int, default=500)
    ap_s = sub.add_parser("show", help="print the trajectory")
    ap_s.add_argument("trajectory")
    args = ap.parse_args()
    if args.cmd == "append":
        n = append(args.trajectory, args.bench, commit=args.commit,
                   branch=args.branch, autotune_path=args.autotune,
                   max_entries=args.max_entries)
        print(f"trajectory: {args.trajectory} now holds {n} entries")
    else:
        show(args.trajectory)


if __name__ == "__main__":
    main()

"""Fused application-pipeline kernel + streaming window runtime: the fused
single-`pallas_call` pipeline must match the staged `BiosignalApp` on every
output, across batch/window shapes, and the streaming runtime must equal
one-shot batch execution on overlapping frames."""
import numpy as np
import pytest

from repro.core.biosignal import make_app, synthetic_respiration
from repro.kernels.pipeline.kernel import pipeline_pallas
from repro.kernels.pipeline.ops import app_pipeline
from repro.kernels.pipeline.ref import pipeline_staged
from repro.serve.stream import (BiosignalStream, StreamConfig, frame_count,
                                frame_signal)


def _assert_matches(out, ref, tol=1e-4):
    for k in ("filtered", "features", "margin"):
        a = np.asarray(ref[k], np.float64)
        b = np.asarray(out[k], np.float64)
        scale = max(1.0, float(np.abs(a).max()))
        assert a.shape == b.shape, (k, a.shape, b.shape)
        assert float(np.abs(a - b).max()) / scale < tol, k
    np.testing.assert_array_equal(np.asarray(out["class"]),
                                  np.asarray(ref["class"]))


@pytest.mark.parametrize("batch,samples", [(4, 2048), (8, 1024), (3, 512)])
def test_fused_matches_staged_app(batch, samples):
    app = make_app()
    sig, _ = synthetic_respiration(batch, samples, seed=batch)
    _assert_matches(app_pipeline(app, sig), app(sig))


def test_fused_matches_kernel_staged():
    """Fused == the kernel-at-a-time staged reference (the bench baseline)."""
    app = make_app()
    sig, _ = synthetic_respiration(6, 1024, seed=11)
    ref = pipeline_staged(sig, app.fir_taps, app.svm_w, app.svm_b,
                          fft_size=app.fft_size)
    _assert_matches(app_pipeline(app, sig), ref)


@pytest.mark.parametrize("block_rows", [1, 2, 4])
def test_fused_interpret_multi_block_grid(block_rows):
    """Explicit row-blocking: grid > 1 must tile the batch without seams."""
    app = make_app()
    sig, _ = synthetic_respiration(8, 1024, seed=13)
    out = pipeline_pallas(sig, app.fir_taps, app.svm_w, app.svm_b,
                          fft_size=app.fft_size, interpret=True,
                          block_rows=block_rows)
    _assert_matches(out, app(sig))


def test_fused_single_pallas_call(monkeypatch):
    """The whole window batch runs in exactly ONE pallas_call."""
    import repro.kernels.pipeline.kernel as K

    calls = []
    real = K.pl.pallas_call

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(K.pl, "pallas_call", counting)
    app = make_app()
    # unique shape so the jit cache cannot satisfy the call without tracing
    sig, _ = synthetic_respiration(7, 512, seed=17)
    out = app_pipeline(app, sig)
    assert np.asarray(out["class"]).shape == (7,)
    assert len(calls) == 1, f"expected 1 pallas_call, traced {len(calls)}"


def test_streaming_matches_one_shot():
    """Windowed streaming output == one-shot batch over the same frames
    (frame count deliberately not a multiple of batch_windows)."""
    app = make_app()
    sig, _ = synthetic_respiration(1, 1024 * 5 + 333, seed=19)
    sig = sig[0]
    cfg = StreamConfig(window=1024, hop=320, batch_windows=4)
    out = BiosignalStream(app, cfg).process(sig)
    frames = frame_signal(sig, cfg.window, cfg.hop)
    assert frames.shape[0] == frame_count(sig.shape[0], cfg.window, cfg.hop)
    assert frames.shape[0] % cfg.batch_windows != 0
    _assert_matches(out, app(frames))


def test_streaming_short_signal():
    app = make_app()
    out = BiosignalStream(app, StreamConfig()).process(np.zeros(100, np.float32))
    assert all(v.shape[0] == 0 for v in out.values())


def test_frame_signal_overlap():
    x = np.arange(32, dtype=np.float32)
    f = np.asarray(frame_signal(x, window=8, hop=4))
    assert f.shape == (7, 8)
    np.testing.assert_array_equal(f[0], x[0:8])
    np.testing.assert_array_equal(f[1], x[4:12])
    np.testing.assert_array_equal(f[-1], x[24:32])


def test_autotune_matches_static_and_caches():
    from repro.core import autotune
    from repro.kernels.fft.ops import fft as kfft

    autotune.clear_cache()
    rng = np.random.default_rng(23)
    re = rng.normal(size=(8, 128)).astype(np.float32)
    im = rng.normal(size=(8, 128)).astype(np.float32)
    a = kfft(re, im)
    b = kfft(re, im, autotune=True)
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]), atol=1e-6)
    cache = autotune.cache_snapshot()
    assert len(cache) == 1
    (key, rb), = cache.items()
    assert key[0] == "fft" and rb in autotune.candidate_block_rows(8)
    # second call hits the cache (no new keys, same answer)
    kfft(re, im, autotune=True)
    assert autotune.cache_snapshot() == cache


def test_candidate_block_rows_divide_rows():
    from repro.core.autotune import candidate_block_rows

    for rows in (1, 3, 8, 22, 64, 96):
        cands = candidate_block_rows(rows)
        assert cands and all(rows % c == 0 for c in cands)
        assert rows in cands or any(c % 8 == 0 for c in cands)

"""Graph compiler: registered stages -> ONE fused `pallas_call` body.

This module is the machinery half of the stage-graph layer
(`stages.py` is the registry half; `docs/STAGE_GRAPHS.md` the authoring
guide). A `StageGraph` names a chain of registered stages, binds their
VMEM table operands, and declares the per-frame outputs; the compiler
assembles them into the SAME three fused entries the hardcoded
biosignal kernel used to own:

* `graph_pallas` — pre-framed (R, S) window batches;
* `graph_stream_pallas` — RAW 1-D signal, overlapping (window, hop)
  frames built in-kernel from a once-staged chunk (the §4.2
  single-residency overlap reuse);
* `graph_ring_pallas` — a (ring_depth, span) ring of raw chunks in one
  call, the dispatch of the device-resident loop (`serve/resident.py`).

Invariants (pinned by `tests/test_stage_graph.py` / `tests/test_asr.py`):

* **Bit-identity with the pre-refactor kernel.** The compiled body
  composes the same helpers in the same order as the frozen legacy
  bodies (`kernel.py:pipeline_kernel` /
  `kernel.py:pipeline_stream_kernel`): stage once -> FIR (`_fir_stage`)
  -> registered map stages -> one HBM write. For the biosignal graph
  the outputs are bitwise equal to the pre-refactor fused kernel across
  every (window, hop, outputs, ring_depth).
* **FIR-first / hop-alignment.** Every graph's first stage is a causal
  k-tap FIR (`stages.Stage` kind ``"fir"``). The stream/ring framing —
  body chunk + hop-sized tail specs, FIR once over the chunk, the
  frame-local zero-history head patch of the first ``n_taps - 1``
  columns — is keyed off that stage's tap count and is what makes raw
  hop-aligned chunk feeds bit-identical to host framing for ANY graph.
* **Generic elision.** A registered stage runs only when a *requested*
  output transitively depends on it (`stages_to_run`); unrequested
  outputs are never written to HBM (their out specs don't exist). This
  strictly generalizes the old ``outputs != ("filtered",)`` special
  case.

The biosignal graph is registered by `kernel.py` (name ``"biosignal"``),
the ASR front-end by `asr.py` (name ``"asr"``); `get_graph_factory`
resolves either by name for the serving layer
(`serve/stream.py:StreamConfig.graph`).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.vwr import VWRSpec, resolve_block_rows
from repro.kernels.pipeline.stages import (OperandMismatchError,
                                           StageGraphError,
                                           UnknownGraphError, get_stage,
                                           register_stage)

__all__ = ["OutputSpec", "StageGraph", "build_graph", "stages_to_run",
           "canonical_graph_outputs", "graph_empty_outputs",
           "register_graph_factory", "get_graph_factory", "default_app",
           "registered_graphs", "graph_pallas", "graph_stream_pallas",
           "graph_ring_pallas", "stream_frame_count",
           "min_stream_block_frames", "resolve_stream_block_frames",
           "ring_chunk_samples"]


# ---------------------------------------------------------------------------
# Framing arithmetic (single source; `kernel.py` re-exports these names)
# ---------------------------------------------------------------------------

def stream_frame_count(n_samples: int, window: int, hop: int) -> int:
    return 0 if n_samples < window else 1 + (n_samples - window) // hop


def min_stream_block_frames(window: int, hop: int) -> int:
    """Smallest legal frame-block: the tail chunk supplies the
    (window - hop) overlap spill, so the body chunk (block_frames * hop
    samples) must be at least that long."""
    return 1 if window <= hop else -(-(window - hop) // hop)


def resolve_stream_block_frames(n_frames: int, window: int, hop: int,
                                override: int | None = None) -> int:
    """Frames staged per grid step. Unlike the framed kernel the block
    need not divide (or even stay below) the frame count — the signal is
    zero-padded and the garbage tail frames are trimmed after the call.
    Never below `min_stream_block_frames`: the tail chunk holds only
    block_frames*hop samples, which must cover the window-hop spill."""
    rb = override or min(max(n_frames, 1), 8)
    return max(1, rb, min_stream_block_frames(window, hop))


def ring_chunk_samples(window: int, hop: int, batch_windows: int) -> int:
    """Samples per ring slot: one `batch_windows`-frame dispatch's span —
    the same arithmetic as `serve.stream.BiosignalStream.chunk_samples`."""
    return (batch_windows - 1) * hop + window


def _fir_stage(x, taps_ref, k: int):
    """Causal k-tap FIR on the staged block — unrolled shifted FMAs, the
    in-VMEM mirror of `core.fir.fir_direct`. The mandatory first stage of
    every graph; the stream framing's head patch reuses it per frame."""
    rb, S = x.shape
    xp = jnp.pad(x, ((0, 0), (k - 1, 0)))
    y = jnp.zeros_like(x)
    for i in range(k):                   # unrolled taps == circular shifts
        y = y + taps_ref[0, i] * xp[:, k - 1 - i: k - 1 - i + S]
    return y


@register_stage("fir", kind="fir", operands=("fir_taps",),
                produces=("filtered",))
def _fir_body(state, tables, params):
    """The mandatory first stage, shared by every graph (the biosignal
    lowpass and the ASR pre-emphasis are both instances). The compiled
    bodies never call this: the framing machinery inlines `_fir_stage`
    itself, because the stream/ring schedule (FIR once over the chunk,
    then the frame-local head patch) cannot be expressed as a per-frame
    map. Kept as the semantic reference of what it inlines."""
    return {"filtered": _fir_stage(state["raw"], tables["fir_taps"],
                                   int(params["n_taps"]))}


# ---------------------------------------------------------------------------
# Graph definition
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OutputSpec:
    """Shape/dtype contract of one per-frame graph output.

    ``shape`` is the TRAILING shape per frame: a tuple of ints or
    symbolic keys — ``"window"`` (the runtime frame length) or the name
    of a graph param (e.g. ``"n_mels"``). The empty tuple means a scalar
    per frame (stored as an (R, 1) HBM column, squeezed on read — the
    generalization of the biosignal ``class`` output). ``dtype`` is
    ``"float32"`` | ``"int32"`` | ``"input"`` (the signal's own dtype —
    the big elidable ``filtered`` write uses it)."""
    shape: tuple
    dtype: str = "float32"

    def __post_init__(self):
        if self.dtype not in ("float32", "int32", "input"):
            raise StageGraphError(f"OutputSpec dtype {self.dtype!r}")

    def resolve(self, window: int, params: dict) -> tuple:
        out = []
        for d in self.shape:
            if isinstance(d, str):
                d = window if d == "window" else params[d]
            out.append(int(d))
        return tuple(out)

    def np_dtype(self, input_dtype):
        return {"float32": jnp.float32, "int32": jnp.int32,
                "input": input_dtype}[self.dtype]


@dataclasses.dataclass(frozen=True)
class StageGraph:
    """A fused application: registered stages + operand binding + outputs.

    Hashable (stages hash by their frozen fields) so the whole graph is
    a STATIC argument of the jitted entries below — one compiled kernel
    per (graph, shape) like the legacy per-app entries. ``params`` must
    carry ``n_taps`` (the FIR-first framing contract) and ``fft_size``
    (the rFFT segment length, also the minimum legal window). Build via
    `build_graph`, which validates stage/operand wiring with the typed
    `stages.py` errors."""
    name: str
    stages: tuple                    # Stage objects, dataflow order
    outputs: tuple                   # ((name, OutputSpec), ...)
    operands: tuple                  # staged table names, binding order
    params: tuple                    # ((key, value), ...) static scalars

    def param(self, key: str):
        return dict(self.params)[key]

    @property
    def n_taps(self) -> int:
        return int(self.param("n_taps"))

    @property
    def fft_size(self) -> int:
        return int(self.param("fft_size"))

    @property
    def output_names(self) -> tuple:
        return tuple(n for n, _ in self.outputs)

    @property
    def output_specs(self) -> dict:
        return dict(self.outputs)


def build_graph(name: str, stage_names, outputs, operands,
                params) -> StageGraph:
    """Resolve + validate a `StageGraph` (the only constructor the
    authoring guide blesses — see `docs/STAGE_GRAPHS.md`).

    Checks, each with a typed error from `stages.py`:
    unknown stage name (`UnknownStageError`); first stage not a FIR, a
    later FIR, an output no stage produces, duplicate state keys, or a
    missing required param (`StageGraphError`); a stage operand the
    graph doesn't bind, an operand no stage reads, or a stage requiring
    state nothing earlier produced (`OperandMismatchError`)."""
    stages = tuple(get_stage(s) if isinstance(s, str) else s
                   for s in stage_names)
    outputs = tuple((n, spec) for n, spec in outputs)
    operands = tuple(operands)
    params = tuple(params)
    if not stages:
        raise StageGraphError(f"graph {name!r}: needs at least one stage")
    if stages[0].kind != "fir":
        raise StageGraphError(
            f"graph {name!r}: first stage must be kind='fir' (the framing "
            f"machinery keys its head patch off it), got "
            f"{stages[0].name!r}")
    if any(s.kind == "fir" for s in stages[1:]):
        raise StageGraphError(
            f"graph {name!r}: only the first stage may be kind='fir'")
    pdict = dict(params)
    for need in ("n_taps", "fft_size"):
        if need not in pdict:
            raise StageGraphError(f"graph {name!r}: missing param {need!r}")
    bound = set(operands)
    read: set = set()
    produced: set = set()
    for s in stages:
        missing = [o for o in s.operands if o not in bound]
        if missing:
            raise OperandMismatchError(
                f"graph {name!r}: stage {s.name!r} reads operands "
                f"{missing} the graph does not bind (bound: "
                f"{list(operands)})")
        read |= set(s.operands)
        unmet = [r for r in s.requires if r not in produced]
        if unmet:
            raise OperandMismatchError(
                f"graph {name!r}: stage {s.name!r} requires state {unmet} "
                f"no earlier stage produces")
        dup = [p for p in s.produces if p in produced]
        if dup:
            raise StageGraphError(
                f"graph {name!r}: stage {s.name!r} re-produces {dup}")
        produced |= set(s.produces)
    unread = [o for o in operands if o not in read]
    if unread:
        raise OperandMismatchError(
            f"graph {name!r}: bound operands {unread} are read by no stage")
    for n, _spec in outputs:
        if n not in produced:
            raise StageGraphError(
                f"graph {name!r}: output {n!r} is produced by no stage")
    return StageGraph(name=name, stages=stages, outputs=outputs,
                      operands=operands, params=params)


def stages_to_run(graph: StageGraph, outputs: tuple) -> tuple:
    """The MAP stages a compiled body must execute for this output
    selection: a reverse dataflow walk — a stage runs iff a requested
    output transitively depends on its products. (The FIR stage is the
    framing machinery itself and always runs.) This is the generic form
    of the legacy kernel's ``outputs != ("filtered",)`` elision."""
    needed = set(outputs)
    run = []
    for s in reversed(graph.stages[1:]):
        if needed & set(s.produces):
            run.append(s)
            needed |= set(s.requires)
    return tuple(reversed(run))


def canonical_graph_outputs(graph: StageGraph, outputs) -> tuple:
    """Validate + canonically order an output selection against the
    graph's declared outputs (`None` = all of them) — the per-graph
    generalization of `kernel.py:canonical_outputs`."""
    names = graph.output_names
    if outputs is None:
        return names
    sel = tuple(outputs)
    bad = [o for o in sel if o not in names]
    if bad:
        raise StageGraphError(
            f"graph {graph.name!r}: unknown outputs {bad}; choose from "
            f"{names}")
    if not sel:
        raise StageGraphError("outputs selection must not be empty")
    return tuple(o for o in names if o in sel)


def graph_empty_outputs(graph: StageGraph, window: int, dtype,
                        outputs=None) -> dict:
    """The zero-frame result for a graph, with the SAME keys/shapes/
    dtypes as a non-empty call — the degenerate-path single source
    (generalizes `kernel.py:empty_outputs`)."""
    outputs = canonical_graph_outputs(graph, outputs)
    params = dict(graph.params)
    specs = graph.output_specs
    return {o: jnp.zeros((0,) + specs[o].resolve(window, params),
                         specs[o].np_dtype(dtype)) for o in outputs}


# ---------------------------------------------------------------------------
# Graph factory registry (name -> factory building (graph, operands))
# ---------------------------------------------------------------------------

# name -> (factory(app) -> (StageGraph, operand arrays), default_app())
_GRAPHS: dict[str, tuple[Callable, Callable | None]] = {}


def register_graph_factory(name: str, factory: Callable, *,
                           default_app: Callable | None = None) -> None:
    """Register a named graph: ``factory(app) -> (graph, operands)``
    binds an application's weights/tables to the graph's operand list;
    ``default_app()`` (optional) builds the app the serving layer uses
    when a `StreamOpen`/`AsrTranscribe` carries none."""
    if name in _GRAPHS:
        raise StageGraphError(f"graph {name!r} is already registered")
    _GRAPHS[name] = (factory, default_app)


def get_graph_factory(name: str) -> Callable:
    """Resolve a graph name to its factory — the serving layer's graph
    handle (`serve/stream.py:StreamConfig.graph`). Lazily imports the
    in-repo graph modules so registration order never matters; raises
    the typed `UnknownGraphError` on a miss."""
    if name not in _GRAPHS:
        import repro.kernels.pipeline.asr     # noqa: F401 (registers "asr")
        import repro.kernels.pipeline.kernel  # noqa: F401 ("biosignal")
    try:
        return _GRAPHS[name][0]
    except KeyError:
        raise UnknownGraphError(
            f"unknown graph {name!r}; registered: "
            f"{sorted(_GRAPHS)}") from None


def default_app(name: str):
    """The registered default application instance for a graph name."""
    get_graph_factory(name)                  # force registration + typo check
    builder = _GRAPHS[name][1]
    if builder is None:
        raise StageGraphError(f"graph {name!r} registered no default app")
    return builder()


def registered_graphs() -> tuple:
    return tuple(sorted(_GRAPHS))


# ---------------------------------------------------------------------------
# Compiled bodies
# ---------------------------------------------------------------------------

def _write_graph_outputs(graph: StageGraph, refs: dict, state: dict) -> None:
    """The ONE HBM write per grid step — only requested refs exist.
    Scalar-per-frame outputs (shape ()) are stored as an (rb, 1) column;
    values are cast to the ref dtype only when they differ (a no-op for
    the all-f32 path, the `filtered` input-dtype cast otherwise)."""
    specs = graph.output_specs
    for o, ref in refs.items():
        v = state[o]
        if specs[o].shape == ():
            v = v[:, None]
        ref[...] = v if v.dtype == ref.dtype else v.astype(ref.dtype)


def _run_graph(graph: StageGraph, filt, tables: dict, outputs: tuple):
    """Execute the elided map-stage chain on a VMEM-resident FIR output
    block; returns the full state dict (the inter-stage tensors never
    leave the block — the paper's single-residency chaining)."""
    params = dict(graph.params)
    state = {graph.stages[0].produces[0]: filt}
    for stage in stages_to_run(graph, outputs):
        state.update(stage.body(state, tables, params))
    return state


def graph_kernel(*refs, graph: StageGraph, outputs: tuple):
    """Pre-framed graph body: one (rb, S) block staged once, the FIR-first
    stage chain, one HBM write (the generic `kernel.py:pipeline_kernel`)."""
    n_ops = len(graph.operands)
    x_ref = refs[0]
    tables = dict(zip(graph.operands, refs[1: 1 + n_ops]))
    out_refs = dict(zip(outputs, refs[1 + n_ops:]))
    x = x_ref[...].astype(jnp.float32)             # (rb, S) staged once
    filt = _fir_stage(x, tables[graph.stages[0].operands[0]], graph.n_taps)
    _write_graph_outputs(graph, out_refs,
                         _run_graph(graph, filt, tables, outputs))


def graph_stream_kernel(*refs, graph: StageGraph, window: int, hop: int,
                        block_frames: int, outputs: tuple, n_tails: int):
    """Raw-signal graph body with IN-KERNEL framing — the generic
    `kernel.py:pipeline_stream_kernel`: one body chunk + `n_tails`
    hop-sized tail views of the same signal, the graph's FIR once over
    the chunk, frames cut by static hop slices, and the first
    ``n_taps - 1`` columns patched with frame-local zero history so the
    result is bit-identical to running the graph on host-framed windows.
    Shared verbatim by the (slot, block) ring grid."""
    n_taps = graph.n_taps
    body_ref, tail_refs = refs[0], refs[1: 1 + n_tails]
    i = 1 + n_tails
    tables = dict(zip(graph.operands, refs[i: i + len(graph.operands)]))
    out_refs = dict(zip(outputs, refs[i + len(graph.operands):]))
    taps_ref = tables[graph.stages[0].operands[0]]
    chunk = jnp.concatenate(
        [r[0, :] for r in (body_ref,) + tuple(tail_refs)]
    )[: block_frames * hop + (window - hop)].astype(jnp.float32)
    # FIR once over the chunk (overlap shared in VMEM)
    filt_chunk = _fir_stage(chunk[None, :], taps_ref, n_taps)[0]
    filt = jnp.stack([filt_chunk[r * hop: r * hop + window]
                      for r in range(block_frames)])
    # frame-local FIR transient: the framed reference zero-pads each
    # frame's history, the chunk FIR used real preceding samples — patch
    # the first n_taps-1 columns (the only ones that can differ)
    head = jnp.stack([chunk[r * hop: r * hop + n_taps - 1]
                      for r in range(block_frames)])
    filt = jnp.concatenate([_fir_stage(head, taps_ref, n_taps),
                            filt[:, n_taps - 1:]], axis=1)
    _write_graph_outputs(graph, out_refs,
                         _run_graph(graph, filt, tables, outputs))


# ---------------------------------------------------------------------------
# Entries (unjitted cores + jitted wrappers)
# ---------------------------------------------------------------------------

def _operand_specs(operands) -> list:
    """Broadcast VMEM BlockSpecs for the staged tables: the same index_map
    takes ANY grid rank, so one operand list serves the 1-D framed/stream
    grids and the 2-D ring grid."""
    return [pl.BlockSpec(tuple(op.shape), lambda *_: (0, 0),
                         memory_space=pltpu.VMEM) for op in operands]


def _graph_out_shapes_specs(graph: StageGraph, R: int, rb: int, window: int,
                            dtype, outputs: tuple, index_map=None):
    """Output ShapeDtypeStructs + BlockSpecs for an R-row result written
    in rb-row blocks, resolved from the graph's `OutputSpec`s (the
    generic `kernel.py:_out_shapes_specs`)."""
    params = dict(graph.params)
    specs = graph.output_specs
    imap = index_map if index_map is not None else lambda i: (i, 0)
    out_shape, out_specs = [], []
    for o in outputs:
        trail = specs[o].resolve(window, params) or (1,)
        dt = specs[o].np_dtype(dtype)
        out_shape.append(jax.ShapeDtypeStruct((R,) + trail, dt))
        out_specs.append(pl.BlockSpec((rb,) + trail, imap,
                                      memory_space=pltpu.VMEM))
    return tuple(out_shape), tuple(out_specs)


def _graph_as_output_dict(graph: StageGraph, outs: tuple, outputs: tuple,
                          n: int) -> dict:
    specs = graph.output_specs
    return {o: v[:n, 0] if specs[o].shape == () else v[:n]
            for o, v in zip(outputs, outs)}


def graph_frames_call(frames, operands, *, graph: StageGraph,
                      interpret: bool = True,
                      block_rows: int | None = None, outputs=None):
    """Unjitted framed core (jit wrapper: `graph_pallas`; `kernel.py`'s
    legacy-signature `pipeline_pallas` routes here with the biosignal
    graph)."""
    outputs = canonical_graph_outputs(graph, outputs)
    R, S = frames.shape
    assert S >= graph.fft_size, (S, graph.fft_size)
    # raw + filtered + two FFT planes ~= 4 live VWR blocks
    rb = resolve_block_rows(R, S * 4, spec=VWRSpec(n_vwrs=4),
                            override=block_rows)
    out_shape, out_specs = _graph_out_shapes_specs(graph, R, rb, S,
                                                   frames.dtype, outputs)
    outs = pl.pallas_call(
        functools.partial(graph_kernel, graph=graph, outputs=outputs),
        out_shape=out_shape,
        in_specs=[pl.BlockSpec((rb, S), lambda i: (i, 0),
                               memory_space=pltpu.VMEM)]
        + _operand_specs(operands),
        out_specs=out_specs,
        grid=(R // rb,),
        interpret=interpret,
    )(frames, *operands)
    return _graph_as_output_dict(graph, outs, outputs, R)


def graph_stream_call(signal, operands, *, graph: StageGraph, window: int,
                      hop: int, interpret: bool = True,
                      block_frames: int | None = None, outputs=None):
    """Unjitted raw-signal streaming core (jit wrapper:
    `graph_stream_pallas`). Exactly ONE `pallas_call` per call; the
    framing/padding arithmetic is the legacy
    `kernel.py:pipeline_stream_pallas` unchanged."""
    outputs = canonical_graph_outputs(graph, outputs)
    (S,) = signal.shape
    assert window >= graph.fft_size, (window, graph.fft_size)
    assert 0 < hop <= window, (hop, window)
    n = stream_frame_count(S, window, hop)
    if n == 0:
        return graph_empty_outputs(graph, window, signal.dtype, outputs)
    rb = resolve_stream_block_frames(n, window, hop, block_frames)
    n_blocks = -(-n // rb)
    L = rb * hop                     # body chunk: one block's sample stride
    n_tails = min_stream_block_frames(window, hop) if window > hop else 0
    # hop-granular padding: every spec must tile the padded signal, so pad
    # the hop count up to a multiple of rb (zeros; garbage frames trimmed)
    total = -(-(n_blocks * rb + n_tails) // rb) * L
    sig = signal[:min(S, total)]
    if total > sig.shape[0]:
        sig = jnp.concatenate(
            [sig, jnp.zeros((total - sig.shape[0],), sig.dtype)])
    sig2 = sig.reshape(1, total)
    in_specs = [pl.BlockSpec((1, L), lambda j: (0, j),
                             memory_space=pltpu.VMEM)]
    for i in range(n_tails):         # the SAME signal, i hop-blocks ahead
        in_specs.append(pl.BlockSpec(
            (1, hop), lambda j, i=i: (0, j * rb + rb + i),
            memory_space=pltpu.VMEM))
    out_shape, out_specs = _graph_out_shapes_specs(
        graph, n_blocks * rb, rb, window, signal.dtype, outputs)
    outs = pl.pallas_call(
        functools.partial(graph_stream_kernel, graph=graph, window=window,
                          hop=hop, block_frames=rb, outputs=outputs,
                          n_tails=n_tails),
        out_shape=out_shape,
        in_specs=in_specs + _operand_specs(operands),
        out_specs=out_specs,
        grid=(n_blocks,),
        interpret=interpret,
    )(*((sig2,) * (1 + n_tails)), *operands)
    return _graph_as_output_dict(graph, outs, outputs, n)


def graph_ring_call(ring, operands, *, graph: StageGraph, window: int,
                    hop: int, interpret: bool = True,
                    block_frames: int | None = None, outputs=None):
    """Unjitted ring core (jit wrapper: `graph_ring_pallas`): a
    (ring_depth, span) ring of raw chunks through ONE `pallas_call` on a
    (slot, block) grid, the stream body/tail index_maps reused verbatim
    per slot. Slot r of the result is bit-identical to
    `graph_stream_call(ring[r], ...)` — the device-resident loop's
    dispatch contract."""
    outputs = canonical_graph_outputs(graph, outputs)
    D, span = ring.shape
    assert window >= graph.fft_size, (window, graph.fft_size)
    assert 0 < hop <= window, (hop, window)
    n = stream_frame_count(span, window, hop)      # frames per ring slot
    assert n > 0, f"ring span {span} shorter than one {window}-window"
    rb = resolve_stream_block_frames(n, window, hop, block_frames)
    n_blocks = -(-n // rb)
    L = rb * hop                     # body chunk: one block's sample stride
    n_tails = min_stream_block_frames(window, hop) if window > hop else 0
    # pad every slot row to the block tiling (same hop-granular arithmetic
    # as the single-chunk entry; the pad frames are trimmed per slot)
    total = -(-(n_blocks * rb + n_tails) // rb) * L
    if total > span:
        ring = jnp.concatenate(
            [ring, jnp.zeros((D, total - span), ring.dtype)], axis=1)
    else:
        ring = ring[:, :total]
    in_specs = [pl.BlockSpec((1, L), lambda r, j: (r, j),
                             memory_space=pltpu.VMEM)]
    for i in range(n_tails):         # the SAME slot row, i hop-blocks ahead
        in_specs.append(pl.BlockSpec(
            (1, hop), lambda r, j, i=i: (r, j * rb + rb + i),
            memory_space=pltpu.VMEM))
    out_shape, out_specs = _graph_out_shapes_specs(
        graph, D * n_blocks * rb, rb, window, ring.dtype, outputs,
        index_map=lambda r, j: (r * n_blocks + j, 0))
    outs = pl.pallas_call(
        functools.partial(graph_stream_kernel, graph=graph, window=window,
                          hop=hop, block_frames=rb, outputs=outputs,
                          n_tails=n_tails),
        out_shape=out_shape,
        in_specs=in_specs + _operand_specs(operands),
        out_specs=out_specs,
        grid=(D, n_blocks),
        interpret=interpret,
    )(*((ring,) * (1 + n_tails)), *operands)
    res = _graph_as_output_dict(graph, outs, outputs, D * n_blocks * rb)
    # per-slot trim: every slot framed n_blocks*rb rows, keep its n real
    # frames and restore the (ring_depth, n, ...) slot structure
    return {key: v.reshape((D, n_blocks * rb) + v.shape[1:])[:, :n]
            for key, v in res.items()}


graph_pallas = functools.partial(jax.jit, static_argnames=(
    "graph", "interpret", "block_rows", "outputs"))(graph_frames_call)
graph_stream_pallas = functools.partial(jax.jit, static_argnames=(
    "graph", "window", "hop", "interpret", "block_frames",
    "outputs"))(graph_stream_call)
graph_ring_pallas = functools.partial(jax.jit, static_argnames=(
    "graph", "window", "hop", "interpret", "block_frames",
    "outputs"))(graph_ring_call)

"""Architecture / shape configuration and registry.

Every assigned architecture gets one module in this package defining a
``CONFIG = ArchConfig(...)`` with the published dimensions, registered under
its id. ``input_specs(cfg, shape)`` yields ShapeDtypeStruct stand-ins for
every model input of a (arch x shape) cell — weak-type-correct, shardable,
and allocation-free, for use by the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    d_ff_shared: int = 0
    every_k_layers: int = 1       # MoE on layers where (i % every_k) == every_k-1
    first_dense: int = 0          # first N layers are dense
    capacity_factor: float = 1.25
    group_size: int = 128         # GShard dispatch group size (tokens)
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str                     # "rwkv6" | "mamba2"
    head_size: int = 64           # rwkv6 head size / mamba2 headdim
    d_state: int = 64             # mamba2 SSM state size
    expand: int = 2               # mamba2 d_inner = expand * d_model
    conv_kernel: int = 4          # mamba2 short conv
    chunk_size: int = 64          # chunked-scan block length
    lora_rank: int = 64           # rwkv6 data-dependent mix LoRA rank
    impl: str = "stable"          # wkv evaluator: stable | matmul (see
                                  # models/rwkv.py; matmul clamps log-decay)
    wkv_clamp: float = -2.0       # per-step log-decay floor (matmul impl)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 => d_model // num_heads
    # attention flavour
    rope_style: str = "neox"      # neox | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: tuple = (2, 1, 1)   # fractions (of head_dim/2) per t/h/w stream
    qkv_bias: bool = False
    proj_bias: bool = False
    sliding_window: Optional[int] = None
    # block flavour
    norm_type: str = "rmsnorm"    # rmsnorm | layernorm
    mlp_gated: bool = True
    act: str = "silu"
    tie_embeddings: bool = False
    # families
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    shared_attn_every: int = 0    # zamba2: shared attention block period
    encoder_layers: int = 0       # whisper: encoder depth (num_layers = decoder depth)
    enc_ctx: int = 1500           # enc-dec: encoder frames (whisper: 30 s)
    vlm_patches: int = 0          # qwen2-vl: patch embeddings per sample (stub frontend)
    # numerics / training
    norm_eps: float = 1e-5
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    # attention chunking (flash-style blockwise attention)
    q_chunk: int = 1024
    kv_chunk: int = 1024
    # pad query heads (per KV group) so the head axis divides the TP degree;
    # padded heads are masked out (exactly-zero output and gradients)
    tp_pad: int = 16
    remat: str = "dots"           # none | dots | full
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def attention_free(self) -> bool:
        return self.ssm is not None and self.shared_attn_every == 0

    @property
    def subquadratic(self) -> bool:
        """Can this arch serve a 500k-token context? (SSM/hybrid/SWA)"""
        return self.ssm is not None or self.sliding_window is not None

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    from repro import configs as _  # ensure registry population  # noqa: F401

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    from repro import configs as _  # noqa: F401

    return sorted(_REGISTRY)


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """The assigned shape cells that are well-defined for this arch.

    long_500k needs sub-quadratic attention: run for SSM/hybrid/SWA archs,
    skip (documented in DESIGN.md) for pure full-attention archs.
    """
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        names.append("long_500k")
    return names


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Abstract model inputs for one (arch x shape) cell.

    train  : full batch with labels.
    prefill: full batch, no labels (returns logits + cache/state).
    decode : one new token per sequence + a KV cache / SSM state of seq_len.
    """
    B = shape.global_batch
    S = shape.seq_len
    f = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    e = lambda *s: jax.ShapeDtypeStruct(s, cfg.compute_dtype)

    if shape.kind == "train":
        batch = {"tokens": f(B, S), "labels": f(B, S)}
    elif shape.kind == "prefill":
        batch = {"tokens": f(B, S)}
    else:  # decode: one token; the cache itself is created by init_cache()
        batch = {"tokens": f(B, 1), "cache_len": jax.ShapeDtypeStruct((), jnp.int32)}

    if cfg.is_encdec:
        # stub audio frontend: precomputed frame embeddings (brief
        # requirement). Whisper's encoder context is a FIXED 1500 frames
        # (30 s); the assigned seq_len applies to the decoder/LM side.
        if shape.kind in ("train", "prefill"):
            batch["frames"] = e(B, cfg.enc_ctx, cfg.d_model)
    if cfg.vlm_patches:
        # stub vision frontend: precomputed patch embeddings + 3D positions
        P = cfg.vlm_patches
        if shape.kind in ("train", "prefill"):
            batch["patch_emb"] = e(B, P, cfg.d_model)
            batch["positions"] = f(B, S, 3)
        else:
            batch["positions"] = f(B, 1, 3)
    return batch


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------

def reduced(cfg: ArchConfig) -> ArchConfig:
    """Small same-family config: a few layers, tiny widths, tiny vocab."""
    kw: dict[str, Any] = dict(
        name=cfg.name + "-smoke",
        num_layers=4 if cfg.shared_attn_every else 2,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        q_chunk=32,
        kv_chunk=32,
        tp_pad=1,
        param_dtype=jnp.float32,
        compute_dtype=jnp.float32,
    )
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=32,
            d_ff_shared=32 if cfg.moe.num_shared else 0, group_size=16,
            first_dense=min(cfg.moe.first_dense, 1),
        )
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, head_size=8, d_state=8, chunk_size=8, lora_rank=8
        )
    if cfg.shared_attn_every:
        kw["shared_attn_every"] = 2
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
        kw["enc_ctx"] = 16
    if cfg.vlm_patches:
        kw["vlm_patches"] = 4
    if cfg.sliding_window:
        kw["sliding_window"] = 48
    return dataclasses.replace(cfg, **kw)


def smoke_shape(kind: str = "train") -> ShapeSpec:
    if kind == "train":
        return ShapeSpec("smoke_train", 64, 2, "train")
    if kind == "prefill":
        return ShapeSpec("smoke_prefill", 64, 2, "prefill")
    return ShapeSpec("smoke_decode", 64, 2, "decode")

"""Unified admission front-end (`serve/frontend.py`) + error taxonomy
(`serve/errors.py`).

One `submit` verb for both traffic classes, typed `Ticket` handles,
per-class QoS weighting, backpressure-aware pumping, column
re-provisioning between the classes, and the `DeprecationWarning` shims
on the three old entry points. The taxonomy tests pin that every serving
error roots at `ServeError` AND keeps its legacy base (so existing
``except RuntimeError`` / ``except ValueError`` callers still catch),
and that the historical import locations keep working.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model, init_model_params
from repro.serve import errors as err
from repro.serve.engine import ColumnScheduler, Engine, PagedEngine, Request
from repro.serve.engine_fault import FaultTolerantEngine
from repro.serve.frontend import (AsrResult, AsrTranscribe, ServeFrontend,
                                  StreamOpen, Ticket)

PROMPTS = {0: [3, 1, 4, 1], 1: [5, 9, 2], 2: [6, 5], 3: [8, 9, 7, 9, 3]}


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(reduced(get_config("qwen1.5-0.5b")),
                              vocab_size=64)
    model = build_model(cfg)
    params = init_model_params(model, seed=3)
    compiled = Engine.compile_model(model)
    return model, params, compiled


def _engine(setup, cls=Engine, **kw):
    model, params, compiled = setup
    return cls(model, params, slots=2, max_len=64, temperature=0.0,
               seed=7, compiled=compiled, **kw)


# ------------------------------------------------------- ticket lifecycle

def test_lm_ticket_lifecycle(setup):
    front = ServeFrontend(engine=_engine(setup))
    t = front.submit(Request(0, list(PROMPTS[0]), max_new=4))
    assert isinstance(t, Ticket)
    assert (t.work_class, t.status) == ("lm", "queued")
    with pytest.raises(err.TicketNotReady):
        t.result()
    front.run()
    assert t.status == "done"
    req = t.result()
    assert req.rid == 0 and len(req.out) == 4


def test_stream_ticket_resolves_at_dispatch(setup):
    sched = ColumnScheduler(devices=["c0", "c1"])
    front = ServeFrontend(scheduler=sched)
    t = front.submit(StreamOpen(stream_id="s-1"))
    assert t.status == "queued"
    front.pump()
    assert t.status == "done"
    assert t.result().column == sched.column_of("s-1")


def test_both_classes_one_front_end(setup):
    """The headline: LM requests and stream opens through ONE verb, one
    queue, both resolving with class-appropriate results."""
    sched = ColumnScheduler(devices=["c0", "c1"])
    front = ServeFrontend(engine=_engine(setup, PagedEngine, page_size=8),
                          scheduler=sched)
    tickets = [front.submit(Request(r, list(p), max_new=4))
               for r, p in PROMPTS.items()]
    tickets += [front.submit(StreamOpen(stream_id=f"s{i}"))
                for i in range(3)]
    front.run()
    assert all(t.status == "done" for t in tickets)
    dense = {r.rid: tuple(r.out) for r in
             _serve_dense(setup, PROMPTS)}
    assert {t.result().rid: tuple(t.result().out)
            for t in tickets[:4]} == dense
    assert sorted(sched.loads()) == [1, 2]     # streams balanced


def _serve_dense(setup, prompts):
    eng = _engine(setup)
    for rid, p in prompts.items():
        eng.add_request(Request(rid, list(p), max_new=4))
    return eng.run_to_completion(max_steps=500)


def test_submit_rejects_unknown_work(setup):
    front = ServeFrontend(engine=_engine(setup))
    with pytest.raises(TypeError):
        front.submit("not a work item")
    with pytest.raises(ValueError):
        front.submit(StreamOpen(stream_id="s"))   # no scheduler wired


def test_typed_rejection_lands_on_ticket(setup):
    front = ServeFrontend(engine=_engine(setup))
    t = front.submit(Request(0, list(range(2, 80)), max_new=4))
    front.pump()
    assert t.status == "failed"
    with pytest.raises(err.PromptTooLong):
        t.result()


def test_qos_round_robin_interleaves_classes(setup):
    """A burst of one class cannot starve the other: with weights
    {lm: 1, stream: 2}, each pump cycle dispatches 1 LM per 2 streams
    while both classes wait."""
    order = []

    class SpyEngine:
        def add_request(self, req):
            order.append(("lm", req.rid))

    class SpyScheduler:
        def place_stream(self, app=None, cfg=None, *, stream_id):
            order.append(("stream", stream_id))
            return stream_id

    front = ServeFrontend(engine=SpyEngine(), scheduler=SpyScheduler(),
                          qos={"lm": 1, "stream": 2})
    for i in range(3):
        front.submit(Request(i, [1, 2], max_new=1))
    for i in range(6):
        front.submit(StreamOpen(stream_id=i))
    front.pump()
    assert order[:6] == [("lm", 0), ("stream", 0), ("stream", 1),
                         ("lm", 1), ("stream", 2), ("stream", 3)]
    assert len(order) == 9                     # everything dispatched


def test_queue_full_backpressure_retries_next_pump(setup):
    """`QueueFull` leaves the ticket QUEUED (not failed); `run`
    re-pumps as the engine frees queue space until every ticket
    resolves."""
    eng = _engine(setup, FaultTolerantEngine, max_queue=2)
    front = ServeFrontend(engine=eng)
    tickets = [front.submit(Request(r, list(p), max_new=4))
               for r, p in PROMPTS.items()]
    n = front.pump()
    assert n == 2                              # the queue bound
    statuses = [t.status for t in tickets]
    assert statuses == ["running", "running", "queued", "queued"]
    front.run()
    assert all(t.status == "done" for t in tickets)


# ------------------------------------------------------- the ASR class

@pytest.fixture(scope="module")
def asr_setup():
    """Reduced whisper-medium enc-dec engine — the ASR decode backend."""
    cfg = dataclasses.replace(reduced(get_config("whisper-medium")),
                              vocab_size=64)
    model = build_model(cfg)
    params = init_model_params(model, seed=3)
    compiled = Engine.compile_model(model)
    return model, params, compiled


def _audio(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(n).astype(np.float32)


def test_asr_ticket_lifecycle(asr_setup):
    """The third class end to end: fused featurize at dispatch, enc-dec
    decode, AsrResult pairing log-mel features with the finished
    request."""
    front = ServeFrontend(engine=_engine(asr_setup))
    t = front.submit(AsrTranscribe(7, _audio(512 * 3), max_new=4))
    assert (t.work_class, t.status) == ("asr", "queued")
    with pytest.raises(err.TicketNotReady):
        t.result()
    front.run()
    assert t.status == "done"
    res = t.result()
    assert isinstance(res, AsrResult) and res.rid == 7
    # 512*3 samples at (window=512, hop=160) -> 7 frames of 64 mels
    assert res.features.shape == (7, 64)
    assert np.isfinite(np.asarray(res.features)).all()
    assert res.tokens == res.request.out
    assert 1 <= len(res.tokens) <= 4
    assert front._features == {}               # stash drained on finish


def test_asr_requires_engine():
    front = ServeFrontend(scheduler=ColumnScheduler(devices=["c0"]))
    with pytest.raises(ValueError, match="no engine"):
        front.submit(AsrTranscribe(0, _audio(1024)))


def test_asr_default_qos_covers_three_classes(asr_setup):
    front = ServeFrontend(engine=_engine(asr_setup))
    assert front.qos == {"lm": 1, "stream": 1, "asr": 1}


def test_three_classes_one_front_end(asr_setup):
    """LM requests, stream opens, AND transcriptions through the ONE
    submit verb, each resolving with its class-typed result."""
    sched = ColumnScheduler(devices=["c0", "c1"])
    front = ServeFrontend(engine=_engine(asr_setup), scheduler=sched)
    t_lm = front.submit(Request(0, [3, 1, 4], max_new=4))
    t_st = front.submit(StreamOpen(stream_id="s-0"))
    t_asr = front.submit(AsrTranscribe(1, _audio(512 * 2, seed=2),
                                       max_new=4))
    front.run()
    assert [t.status for t in (t_lm, t_st, t_asr)] == ["done"] * 3
    assert t_lm.result().rid == 0
    assert t_st.result().column == sched.column_of("s-0")
    res = t_asr.result()
    assert isinstance(res, AsrResult)
    assert res.features.shape[1] == 64


def test_asr_backpressure_reuses_feature_stash(asr_setup):
    """`QueueFull` leaves ASR tickets queued; the features computed at
    the first dispatch attempt are stashed and reused on the retry (and
    every ticket still resolves)."""
    eng = _engine(asr_setup, FaultTolerantEngine, max_queue=1)
    front = ServeFrontend(engine=eng)
    tickets = [front.submit(AsrTranscribe(r, _audio(512 * 2, seed=r),
                                          max_new=2)) for r in range(3)]
    n = front.pump()
    assert n == 1                              # the queue bound
    assert [t.status for t in tickets] == ["running", "queued", "queued"]
    front.run()
    assert all(t.status == "done" for t in tickets)
    assert {t.result().rid for t in tickets} == {0, 1, 2}
    assert front._features == {}


# --------------------------------------------------------- re-provisioning

def test_lend_and_return_columns():
    sched = ColumnScheduler(devices=["c0", "c1", "c2"])
    for i in range(3):
        sched.admit(f"s{i}")
    front = ServeFrontend(scheduler=sched)
    devs = front.lend_columns(2)
    assert len(devs) == 2 and len(sched.healthy_columns()) == 1
    # the lent columns' streams drained onto the survivor
    survivor = sched.healthy_columns()[0]
    assert all(sched.column_of(f"s{i}") == survivor for i in range(3))
    # a failed column is NOT restorable; a withdrawn one is
    with pytest.raises(err.InsufficientHealthyWorkers):
        front.lend_columns(1)                  # quorum of one holds
    assert front.return_columns() == sorted(
        set(range(3)) - {survivor}, reverse=True)
    assert sched.healthy_columns() == [0, 1, 2]


def test_withdraw_restore_guards():
    sched = ColumnScheduler(devices=["c0", "c1"])
    sched.withdraw(1)
    with pytest.raises(ValueError):
        sched.withdraw(1)                      # already withdrawn
    with pytest.raises(ValueError):
        sched.restore(0)                       # never withdrawn
    sched.restore(1)
    assert sched.healthy_columns() == [0, 1]
    # a genuinely dead column is not restorable
    sched.mark_dead(1)
    with pytest.raises(ValueError):
        sched.restore(1)


# ------------------------------------------------------ deprecation shims

def test_engine_submit_shim_warns(setup):
    eng = _engine(setup)
    with pytest.warns(DeprecationWarning, match="Engine.submit"):
        eng.submit(Request(0, [1, 2], max_new=1))
    assert eng.queue[0].rid == 0               # still lands in the queue


def test_fault_tolerant_submit_shim_warns(setup):
    eng = _engine(setup, FaultTolerantEngine, max_queue=4)
    with pytest.warns(DeprecationWarning, match="Engine.submit"):
        eng.submit(Request(0, [1, 2], max_new=1), ttl=10.0)
    assert 0 in eng.deadlines                  # kwargs reach add_request


def test_open_stream_shim_warns():
    sched = ColumnScheduler(devices=["c0"])
    with pytest.warns(DeprecationWarning, match="open_stream"):
        sched.open_stream(stream_id="s-legacy")
    assert sched.column_of("s-legacy") == 0


# --------------------------------------------------------- error taxonomy

def test_every_serving_error_roots_at_serve_error():
    for name in err.__all__:
        cls = getattr(err, name)
        if isinstance(cls, type) and issubclass(cls, Exception):
            assert issubclass(cls, err.ServeError), name


def test_legacy_bases_preserved():
    """Old call sites catch by the legacy base; the taxonomy keeps it."""
    assert issubclass(err.PromptTooLong, ValueError)
    assert issubclass(err.PagedCacheUnsupported, TypeError)
    for cls in (err.QueueFull, err.RequestExpired, err.EngineStalled,
                err.InsufficientHealthyWorkers, err.InsufficientPages,
                err.TransientDispatchError, err.TicketNotReady):
        assert issubclass(cls, RuntimeError), cls
    # the two errors the dispatch retry loop must NOT swallow stay
    # OUTSIDE RuntimeError
    for cls in (err.ColumnDeadError, err.ColumnHungError):
        assert issubclass(cls, err.ServeError)
        assert not issubclass(cls, RuntimeError), cls


def test_historical_import_locations_still_work():
    from repro.runtime.fault import (ColumnDeadError,
                                     InsufficientHealthyWorkers,
                                     TransientDispatchError)
    from repro.serve.engine import EngineStalled, PromptTooLong
    from repro.serve.engine_fault import QueueFull, RequestExpired
    from repro.serve.fault import ColumnHungError
    assert ColumnDeadError is err.ColumnDeadError
    assert InsufficientHealthyWorkers is err.InsufficientHealthyWorkers
    assert TransientDispatchError is err.TransientDispatchError
    assert EngineStalled is err.EngineStalled
    assert PromptTooLong is err.PromptTooLong
    assert QueueFull is err.QueueFull
    assert RequestExpired is err.RequestExpired
    assert ColumnHungError is err.ColumnHungError

"""Streaming ASR feature front-end: the SECOND registered stage graph.

The paper's flexibility claim — one substrate, many kernels — needs more
than one workload to mean anything. A log-mel filterbank front-end (what
feeds every Whisper-style encoder) has exactly the biosignal pipeline's
shape: framing -> causal FIR (pre-emphasis) -> rFFT -> matmul epilogue.
So it is FOUR registered stages over the same graph machinery
(`graph.py:StageGraph`), compiled into the same single-`pallas_call`
entries with the in-kernel framing, `outputs=` elision and ring grid of
`kernel.py` completely unchanged:

    fir (pre-emphasis, taps [1, -preemph])
      -> hann  (periodic Hann on the first fft_size samples)
      -> power_spectrum (the packed rFFT of `kernel.py:_packed_rfft`,
                         |X|^2 — NO mean subtraction, unlike the
                         biosignal band-power stage)
      -> logmel (log1p(power @ mel_w), a slaney-style mel filterbank)

Invariants (pinned by `tests/test_asr.py`):

* **f32 tolerance vs the host reference.** `asr_reference` computes the
  same features with frame-local numpy (np.fft.rfft, float64 twiddles);
  the fused kernel matches it to scale-relative f32 tolerance for
  dividing and non-dividing (window, hop, n_samples), including the
  zero-frame and tail-pad cases.
* **Hop-alignment.** The graph rides `graph.py:graph_stream_call`
  framing, so feeding raw hop-aligned chunks is bit-identical to
  host-framed windows — the property the serving layer
  (`serve/stream.py`) relies on for requeue/replay.
* ``log1p`` (not ``log``) keeps the reference comparison well-posed for
  near-zero mel bins, mirroring `core.biosignal.band_power_features`.

`asr_staged` is the 4-launch baseline (host framing gather + the
standalone FIR/FFT kernels) that `benchmarks/table5_app.py` pairs
against the fused graph for the `run.py --check-asr` gate. The serving
path: `ops.py:graph_pipeline_stream` with graph ``"asr"``, and
`serve/frontend.py:AsrTranscribe` feeds the features to the
`whisper_medium` enc-dec engine as the third traffic class.
"""
from __future__ import annotations

import dataclasses
import functools

import jax.numpy as jnp
import numpy as np

from repro.kernels.pipeline.graph import (OutputSpec, build_graph,
                                          register_graph_factory,
                                          stream_frame_count)
from repro.kernels.pipeline.kernel import _packed_rfft, _table_operands
from repro.kernels.pipeline.stages import register_stage

__all__ = ["AsrFrontendApp", "make_asr_frontend", "mel_filterbank",
           "hann_window", "asr_graph", "asr_reference",
           "asr_reference_frames", "asr_staged"]


# ---------------------------------------------------------------------------
# Host-side constant tables (computed once, staged as VMEM operands)
# ---------------------------------------------------------------------------

def hann_window(n: int) -> np.ndarray:
    """Periodic Hann window (the STFT convention librosa/scipy use for
    ``sym=False``): 0.5 * (1 - cos(2*pi*k/n))."""
    return (0.5 * (1.0 - np.cos(2.0 * np.pi * np.arange(n) / n))
            ).astype(np.float32)


def _hz_to_mel(f):
    """Slaney mel scale: linear below 1 kHz, log above."""
    f = np.asarray(f, np.float64)
    mel = f / (200.0 / 3.0)
    log_step = np.log(6.4) / 27.0
    return np.where(f >= 1000.0, 15.0 + np.log(np.maximum(f, 1e-10)
                                               / 1000.0) / log_step, mel)


def _mel_to_hz(m):
    m = np.asarray(m, np.float64)
    log_step = np.log(6.4) / 27.0
    return np.where(m >= 15.0, 1000.0 * np.exp(log_step * (m - 15.0)),
                    m * (200.0 / 3.0))


def mel_filterbank(fft_size: int = 512, n_mels: int = 64,
                   sample_rate: float = 16000.0, fmin: float = 0.0,
                   fmax: float | None = None) -> np.ndarray:
    """Slaney-style triangular mel filterbank, area-normalized — the
    librosa ``filters.mel(norm="slaney")`` construction, implemented
    in-repo (no librosa dependency). Returned TRANSPOSED as
    ``(fft_size//2 + 1, n_mels)`` so the kernel's epilogue is a plain
    ``power @ mel_w`` matmul on the MXU (`asr.py:_logmel_body`)."""
    fmax = sample_rate / 2.0 if fmax is None else fmax
    n_bins = fft_size // 2 + 1
    fft_hz = np.arange(n_bins) * (sample_rate / fft_size)
    mel_pts = _mel_to_hz(np.linspace(_hz_to_mel(fmin), _hz_to_mel(fmax),
                                     n_mels + 2))
    fb = np.zeros((n_mels, n_bins))
    for i in range(n_mels):
        lo, mid, hi = mel_pts[i], mel_pts[i + 1], mel_pts[i + 2]
        up = (fft_hz - lo) / max(mid - lo, 1e-10)
        down = (hi - fft_hz) / max(hi - mid, 1e-10)
        fb[i] = np.maximum(0.0, np.minimum(up, down))
        fb[i] *= 2.0 / (hi - lo)                      # slaney area norm
    return fb.T.astype(np.float32)


# ---------------------------------------------------------------------------
# The three ASR map stages (the "fir" stage is shared — graph.py)
# ---------------------------------------------------------------------------

@register_stage("hann", operands=("hann",), requires=("filtered",),
                produces=("windowed",))
def _hann_body(state, tables, params):
    """Periodic Hann on the first fft_size samples of each pre-emphasized
    frame. Windowing only the FFT segment (not the full frame) keeps the
    stage valid for any window >= fft_size, like the biosignal band-power
    stage."""
    return {"windowed":
            state["filtered"][:, :params["fft_size"]] * tables["hann"][0]}


@register_stage("power_spectrum",
                operands=("twiddle_re", "twiddle_im", "untangle"),
                requires=("windowed",), produces=("power",))
def _power_body(state, tables, params):
    """|rFFT|^2 of the windowed segment via the shared packed-rFFT helper
    (`kernel.py:_packed_rfft`) — same Stockham stages and staged twiddle/
    untangle tables as the biosignal graph, WITHOUT its mean subtraction
    (spectral features keep the DC bin)."""
    Xr, Xi = _packed_rfft(state["windowed"], tables["twiddle_re"],
                          tables["twiddle_im"], tables["untangle"],
                          fft_size=params["fft_size"])
    return {"power": jnp.square(Xr) + jnp.square(Xi)}


@register_stage("logmel", operands=("mel_w",), requires=("power",),
                produces=("logmel",))
def _logmel_body(state, tables, params):
    """log1p(power @ mel_w): the mel matmul epilogue on the MXU. ``log1p``
    not ``log`` so silent frames (power -> 0) stay finite and the host
    comparison is well-posed at f32."""
    return {"logmel": jnp.log1p(jnp.dot(
        state["power"], tables["mel_w"][...],
        preferred_element_type=jnp.float32))}


@functools.lru_cache(maxsize=None)
def asr_graph(n_taps: int, fft_size: int, n_mels: int):
    """The ASR front-end `StageGraph`. ``filtered`` (the pre-emphasized
    frames, the big elidable write) and ``logmel`` (the (n, n_mels)
    features the encoder consumes) are its two outputs."""
    return build_graph(
        "asr",
        ("fir", "hann", "power_spectrum", "logmel"),
        (("filtered", OutputSpec(("window",), "input")),
         ("logmel", OutputSpec(("n_mels",), "float32"))),
        ("fir_taps", "hann", "twiddle_re", "twiddle_im", "untangle",
         "mel_w"),
        (("n_taps", int(n_taps)), ("fft_size", int(fft_size)),
         ("n_mels", int(n_mels))))


@dataclasses.dataclass(frozen=True)
class AsrFrontendApp:
    """Streaming ASR feature front-end parameters (the graph's "app").

    Exposes ``fir_taps`` (pre-emphasis ``[1, -preemph]``; `core.fir`
    convention ``y[t] = sum taps[i] * x[t-i]``) and ``fft_size`` so the
    serving layer's app contract (`serve/stream.py` asserts
    ``window >= app.fft_size``) holds unchanged."""
    preemph: float = 0.97
    fft_size: int = 512
    n_mels: int = 64
    sample_rate: float = 16000.0
    fmin: float = 0.0
    fmax: float | None = None

    @property
    def fir_taps(self) -> np.ndarray:
        return np.array([1.0, -self.preemph], np.float32)

    @property
    def hann(self) -> np.ndarray:
        return hann_window(self.fft_size)

    @property
    def mel_weights(self) -> np.ndarray:
        return mel_filterbank(self.fft_size, self.n_mels, self.sample_rate,
                              self.fmin, self.fmax)

    def __call__(self, frames):
        """Host reference on pre-framed windows (`asr_reference_frames`)."""
        return asr_reference_frames(self, frames)


def make_asr_frontend(**kw) -> AsrFrontendApp:
    """Default ASR front-end: 16 kHz, 512-pt FFT, 64 slaney mel bands —
    the whisper-style configuration `examples/asr_frontend.py` serves."""
    return AsrFrontendApp(**kw)


def _asr_factory(app: AsrFrontendApp):
    """Graph factory (`graph.py:register_graph_factory`): stage the app's
    tables in the graph's operand binding order. Reuses the biosignal
    twiddle/untangle staging (`kernel.py:_table_operands`) so both graphs
    share one table-construction path."""
    base, _ = _table_operands(app.fir_taps, np.zeros((1, 1), np.float32),
                              np.zeros((1,), np.float32), app.fft_size)
    taps, wr, wi, u = base[0], base[1], base[2], base[3]
    operands = (taps, jnp.asarray(app.hann).reshape(1, app.fft_size),
                wr, wi, u, jnp.asarray(app.mel_weights))
    return asr_graph(2, app.fft_size, app.n_mels), operands


register_graph_factory("asr", _asr_factory, default_app=make_asr_frontend)


# ---------------------------------------------------------------------------
# Host reference (independent numerics: numpy float64 FFT) + staged baseline
# ---------------------------------------------------------------------------

def asr_reference_frames(app: AsrFrontendApp, frames) -> dict:
    """Librosa-style host oracle on pre-framed (n, window) windows:
    frame-local pre-emphasis (zero history per frame, the `core.fir`
    convention), periodic Hann, ``np.fft.rfft`` (float64 twiddles —
    numerics independent of the kernel's packed Stockham path), slaney
    mel matmul, log1p. The fused graph matches this to scale-relative
    f32 tolerance — the `tests/test_asr.py` pin."""
    x = np.asarray(frames, np.float32)
    n, window = x.shape
    taps = app.fir_taps
    k = len(taps)
    xp = np.pad(x, ((0, 0), (k - 1, 0)))
    filt = np.zeros_like(x)
    for i in range(k):
        filt += taps[i] * xp[:, k - 1 - i: k - 1 - i + window]
    windowed = filt[:, :app.fft_size] * app.hann
    power = np.abs(np.fft.rfft(windowed, axis=-1)) ** 2
    logmel = np.log1p(power.astype(np.float32) @ app.mel_weights)
    return {"filtered": filt, "logmel": logmel.astype(np.float32)}


def host_frames(signal, window: int, hop: int) -> np.ndarray:
    """Host-side (window, hop) framing gather — the HBM-heavy layout the
    in-kernel framing exists to avoid (each sample duplicated ~window/hop
    times)."""
    sig = np.asarray(signal)
    n = stream_frame_count(sig.shape[0], window, hop)
    idx = np.arange(n)[:, None] * hop + np.arange(window)[None, :]
    return sig[idx] if n else np.zeros((0, window), sig.dtype)


def asr_reference(app: AsrFrontendApp, signal, *, window: int,
                  hop: int) -> dict:
    """Host oracle over a raw 1-D signal: frame on the host, then
    `asr_reference_frames`. Zero-frame signals return empty (0, ...)
    results matching `graph.py:graph_empty_outputs`."""
    return asr_reference_frames(app, host_frames(signal, window, hop))


def asr_staged(app: AsrFrontendApp, signal, *, window: int, hop: int):
    """The 4-launch staged baseline the fused graph is benchmarked
    against (`benchmarks/table5_app.py`, gate ``run.py --check-asr``):
    host framing gather -> standalone FIR kernel (`kernels/fir/ops.py`)
    -> jitted Hann -> standalone packed-rFFT kernel
    (`kernels/fft/ops.py`) -> jitted mel/log1p. Every arrow is an HBM
    round trip; the fused graph is ONE `pallas_call` over the raw
    signal."""
    import jax

    from repro.kernels.fft.ops import rfft
    from repro.kernels.fir.ops import fir

    frames = jnp.asarray(host_frames(signal, window, hop))
    if frames.shape[0] == 0:
        return {"filtered": jnp.zeros((0, window), frames.dtype),
                "logmel": jnp.zeros((0, app.n_mels), jnp.float32)}
    filt = fir(frames, jnp.asarray(app.fir_taps))
    hann = jnp.asarray(app.hann)
    windowed = jax.jit(lambda f, h: f[:, :app.fft_size] * h)(filt, hann)
    Xr, Xi = rfft(windowed)
    mel_w = jnp.asarray(app.mel_weights)

    @jax.jit
    def finish(xr, xi, w):
        return jnp.log1p(jnp.dot(jnp.square(xr) + jnp.square(xi), w,
                                 preferred_element_type=jnp.float32))

    return {"filtered": filt, "logmel": finish(Xr, Xi, mel_w)}

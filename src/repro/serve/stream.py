"""Streaming window runtime: continuous biosignal traffic through the fused
pipeline kernel.

The paper's deployment model (§4.4.2) is a sensor feeding windows to the
accelerator forever; ours is the serving analogue: a continuous signal is
framed into overlapping (window, hop) frames, frames are grouped into
fixed-size window batches, and each batch runs through the fused
single-`pallas_call` pipeline (`kernels/pipeline`). Dispatch is
double-buffered: while batch k's outputs are being consumed on the host,
batch k+1 is already in flight (JAX async dispatch is the host-side
ping-pong buffer, mirroring the SPM's double-buffered line fills). The
row-block of the fused kernel can be autotuned from measured candidates
(`core/autotune.py`) instead of the static VWRSpec formula.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.biosignal import BiosignalApp, make_app
from repro.kernels.pipeline.ops import app_pipeline


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    window: int = 2048          # samples per frame (the processing window)
    hop: int = 512              # frame stride; < window => overlapping frames
    batch_windows: int = 8      # frames per fused-kernel dispatch
    autotune: bool = False      # measure the kernel row-block (cached)
    block_rows: int | None = None   # pin the row-block explicitly


def frame_count(n_samples: int, window: int, hop: int) -> int:
    if n_samples < window:
        return 0
    return 1 + (n_samples - window) // hop


def frame_signal(signal, window: int, hop: int):
    """(S,) continuous signal -> (n_frames, window) overlapping frames."""
    sig = jnp.asarray(signal)
    assert sig.ndim == 1, sig.shape
    n = frame_count(sig.shape[0], window, hop)
    if n == 0:
        return jnp.zeros((0, window), sig.dtype)
    idx = np.arange(n)[:, None] * hop + np.arange(window)[None, :]
    return sig[jnp.asarray(idx)]


class BiosignalStream:
    """Drives a continuous signal through the fused pipeline kernel in
    double-buffered window batches.

    >>> stream = BiosignalStream(make_app(), StreamConfig(hop=256))
    >>> out = stream.process(signal)          # dict over all frames
    """

    def __init__(self, app: BiosignalApp | None = None,
                 cfg: StreamConfig | None = None):
        self.app = app or make_app()
        self.cfg = cfg or StreamConfig()
        assert self.cfg.window >= self.app.fft_size, (
            self.cfg.window, self.app.fft_size)
        assert 0 < self.cfg.hop <= self.cfg.window
        assert self.cfg.batch_windows > 0

    def _dispatch(self, frames):
        return app_pipeline(self.app, frames,
                            block_rows=self.cfg.block_rows,
                            autotune=self.cfg.autotune)

    def stream(self, signal) -> Iterator[dict]:
        """Yields one output dict per window batch (trimmed to the real
        frames). Batch k+1 is dispatched before batch k is yielded, so the
        consumer always overlaps with one in-flight batch."""
        cfg = self.cfg
        frames = frame_signal(signal, cfg.window, cfg.hop)
        n = frames.shape[0]
        bw = cfg.batch_windows
        inflight: tuple[dict, int] | None = None
        for start in range(0, n, bw):
            batch = frames[start: start + bw]
            valid = batch.shape[0]
            if valid < bw:      # pad the tail batch to the fixed shape
                batch = jnp.concatenate(
                    [batch, jnp.zeros((bw - valid, cfg.window),
                                      batch.dtype)], axis=0)
            nxt = (self._dispatch(batch), valid)    # async: in flight now
            if inflight is not None:
                yield self._collect(*inflight)
            inflight = nxt
        if inflight is not None:
            yield self._collect(*inflight)

    @staticmethod
    def _collect(out: dict, valid: int) -> dict:
        out = jax.block_until_ready(out)
        return {k: v[:valid] for k, v in out.items()}

    def process(self, signal) -> dict:
        """One-call convenience: all framed outputs concatenated, equal to
        running the app on `frame_signal(signal, window, hop)` at once."""
        chunks = list(self.stream(signal))
        if not chunks:
            w = self.app.svm_w.shape
            return {"filtered": jnp.zeros((0, self.cfg.window)),
                    "features": jnp.zeros((0, w[0])),
                    "margin": jnp.zeros((0, w[1])),
                    "class": jnp.zeros((0,), jnp.int32)}
        return {k: jnp.concatenate([c[k] for c in chunks], axis=0)
                for k in chunks[0]}

"""Config-driven layer-stack assembler covering all assigned families.

A stack is a list of Segments; each Segment is a repeated *pattern* of
layers, scanned with lax.scan (remat-wrapped) so the HLO stays one-pattern
sized regardless of depth. A layer is an ordered tuple of sublayer kinds:

    ("attn","mlp")          dense transformer layer
    ("attn","moe")          MoE transformer layer
    ("attn","cross","mlp")  whisper decoder layer
    ("rwkv",)               RWKV6 block
    ("mamba",)              Mamba2 block
    ("mamba","shared_attn") zamba2: mamba + the weight-SHARED attention block

Shared-attention weights live outside the scanned stacks and are closed
over, so all invocations reuse one copy (Zamba2 semantics).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as att
from repro.models import layers as L
from repro.models import mamba as mam
from repro.models import moe as moe_mod
from repro.models import rwkv as rwk
from repro.models.layers import P
from repro.sharding.ctx import constrain


@dataclasses.dataclass(frozen=True)
class Segment:
    pattern: tuple  # tuple of layer tuples
    repeats: int


# ---------------------------------------------------------------------------
# Stack plans
# ---------------------------------------------------------------------------

def stack_plan(cfg) -> list[Segment]:
    Lc = cfg.num_layers
    if cfg.ssm is not None and cfg.shared_attn_every:
        k = cfg.shared_attn_every
        pattern = (("mamba",),) * (k - 1) + (("mamba", "shared_attn"),)
        full, tail = divmod(Lc, k)
        segs = []
        if full:
            segs.append(Segment(pattern, full))
        if tail:
            segs.append(Segment((("mamba",),), tail))
        return segs
    if cfg.ssm is not None:
        kind = "rwkv" if cfg.ssm.kind == "rwkv6" else "mamba"
        return [Segment(((kind,),), Lc)]
    if cfg.moe is not None:
        m = cfg.moe
        segs = []
        rest = Lc - m.first_dense
        if m.first_dense:
            segs.append(Segment((("attn", "mlp"),), m.first_dense))
        if m.every_k_layers > 1:
            pat = (("attn", "mlp"),) * (m.every_k_layers - 1) + (("attn", "moe"),)
            segs.append(Segment(pat, rest // m.every_k_layers))
        else:
            segs.append(Segment((("attn", "moe"),), rest))
        return segs
    if cfg.is_encdec:
        return [Segment((("attn", "cross", "mlp"),), Lc)]  # decoder
    return [Segment((("attn", "mlp"),), Lc)]


def encoder_plan(cfg) -> list[Segment]:
    return [Segment((("attn", "mlp"),), cfg.encoder_layers)]


# ---------------------------------------------------------------------------
# Sublayer schemas
# ---------------------------------------------------------------------------

def _sublayer_schema(kind: str, cfg):
    if kind == "attn" or kind == "cross":
        return {"norm": L.norm_schema(cfg.d_model, cfg.norm_type),
                "attn": att.attention_schema(cfg)}
    if kind == "mlp":
        return {"norm": L.norm_schema(cfg.d_model, cfg.norm_type),
                "mlp": L.mlp_schema(cfg.d_model, cfg.d_ff, gated=cfg.mlp_gated,
                                    bias=cfg.proj_bias)}
    if kind == "moe":
        return {"norm": L.norm_schema(cfg.d_model, cfg.norm_type),
                "moe": moe_mod.moe_schema(cfg)}
    if kind == "rwkv":
        return rwk.rwkv_block_schema(cfg)
    if kind == "mamba":
        return mam.mamba_block_schema(cfg)
    if kind == "shared_attn":
        return {}  # weights are shared; provided separately
    raise ValueError(kind)


def _pattern_schema(pattern, cfg):
    s = {}
    for li, layer in enumerate(pattern):
        for kind in layer:
            sub = _sublayer_schema(kind, cfg)
            if sub:
                s[f"l{li}_{kind}"] = sub
    return s


def stack_schema(cfg, plan) -> dict:
    return {f"seg{i}": L.stack_schema(seg.repeats, _pattern_schema(seg.pattern, cfg))
            for i, seg in enumerate(plan)}


def shared_attn_schema(cfg):
    return {
        "norm1": L.norm_schema(cfg.d_model, cfg.norm_type),
        "attn": att.attention_schema(cfg),
        "norm2": L.norm_schema(cfg.d_model, cfg.norm_type),
        "mlp": L.mlp_schema(cfg.d_model, cfg.d_ff, gated=cfg.mlp_gated,
                            bias=cfg.proj_bias),
    }


# ---------------------------------------------------------------------------
# Cache schemas
# ---------------------------------------------------------------------------

def _sublayer_cache_schema(kind: str, cfg, batch: int, max_len: int):
    KV, dh = cfg.num_kv_heads, cfg.hd
    kv_axes = ("batch", "seq", "kv_heads", "head_dim")
    if kind in ("attn", "shared_attn"):
        # sliding-window archs only ever attend to the last `window` keys:
        # allocate a RING buffer (beyond-paper: 500k-token decode holds a
        # window-sized cache, 128x smaller for danube long_500k)
        slots = max_len
        if cfg.sliding_window and cfg.sliding_window < max_len:
            slots = cfg.sliding_window
        return {"k": P((batch, slots, KV, dh), kv_axes, 0.0, cfg.compute_dtype),
                "v": P((batch, slots, KV, dh), kv_axes, 0.0, cfg.compute_dtype)}
    if kind == "rwkv":
        return rwk.rwkv_state_schema(cfg, batch)
    if kind == "mamba":
        return mam.mamba_state_schema(cfg, batch)
    if kind == "cross":
        # encoder K/V cache: computed ONCE at prefill, reused every decoded
        # token (the §Roofline useful-ratio metric flagged the recompute)
        return {"ek": P((batch, cfg.enc_ctx, KV, dh), kv_axes, 0.0,
                        cfg.compute_dtype),
                "ev": P((batch, cfg.enc_ctx, KV, dh), kv_axes, 0.0,
                        cfg.compute_dtype)}
    return None  # mlp / moe: stateless


def cache_schema(cfg, plan, batch: int, max_len: int) -> dict:
    out = {}
    for i, seg in enumerate(plan):
        s = {}
        for li, layer in enumerate(seg.pattern):
            for kind in layer:
                cs = _sublayer_cache_schema(kind, cfg, batch, max_len)
                if cs:
                    s[f"l{li}_{kind}"] = cs
        out[f"seg{i}"] = L.stack_schema(seg.repeats, s)
    return out


def paged_pool_schema(cfg, plan, *, n_pages: int, page_size: int,
                      max_len: int) -> dict:
    """The PAGED view of `cache_schema`: one pool leaf per cache leaf.

    Each dense leaf's named "batch" and "seq" axes are replaced by a
    leading (pages, page) pair — pool shape ``(n_pages, page_size,
    *rest)`` with the remaining axes in their original order — so a
    per-request block table plus `models.attention.gather_page_view`
    reconstructs exactly the dense per-slot layout. Ring/SWA leaves page
    their W ring slots the same way (page j holds ring slots
    [j*page_size, (j+1)*page_size)); a leaf WITHOUT a "seq" axis
    (recurrent rwkv/mamba state — the state is the whole history) cannot
    be paged and raises ``ValueError``; the serving layer surfaces that
    as its typed `serve.errors.PagedCacheUnsupported`."""
    def pool_leaf(p: P) -> P:
        if "batch" not in p.axes or "seq" not in p.axes:
            raise ValueError(
                f"cache leaf with axes {p.axes} has no (batch, seq) pair "
                f"to page over")
        b, s = p.axes.index("batch"), p.axes.index("seq")
        rest = [i for i in range(len(p.shape)) if i not in (b, s)]
        return P((n_pages, page_size) + tuple(p.shape[i] for i in rest),
                 ("pages", "page") + tuple(p.axes[i] for i in rest),
                 0.0, p.dtype)

    return jax.tree.map(pool_leaf, cache_schema(cfg, plan, 1, max_len),
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Ctx:
    cfg: Any
    mode: str                   # train | prefill | decode
    positions: Any              # (B,S) or (B,S,3)
    cache_len: Any = None       # traced scalar (decode)
    causal: bool = True
    enc_out: Any = None         # encoder output for cross sublayers
    shared: Any = None          # shared-attn params (zamba)


def _zero_state(schema):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype or jnp.float32),
                        schema, is_leaf=lambda s: isinstance(s, P))


def _apply_sublayer(kind, params, x, cache, ctx):
    cfg = ctx.cfg
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        h = L.apply_norm(params["norm"], x, kind=cfg.norm_type, eps=cfg.norm_eps)
        kv = (cache["k"], cache["v"]) if cache else None
        out, new_kv = att.attention_block(
            params["attn"], h, cfg=cfg, positions=ctx.positions,
            causal=ctx.causal, cache=kv, cache_len=ctx.cache_len)
        new_cache = {"k": new_kv[0], "v": new_kv[1]} if new_kv else cache
        return x + out, new_cache, aux
    if kind == "cross":
        h = L.apply_norm(params["norm"], x, kind=cfg.norm_type, eps=cfg.norm_eps)
        if ctx.mode == "decode" and cache is not None:
            ek, ev = cache["ek"], cache["ev"]     # prefilled encoder K/V
        else:
            ek = jnp.einsum("bsd,dke->bske", ctx.enc_out,
                            params["attn"]["wk"].astype(ctx.enc_out.dtype))
            ev = jnp.einsum("bsd,dke->bske", ctx.enc_out,
                            params["attn"]["wv"].astype(ctx.enc_out.dtype))
            if "bk" in params["attn"]:
                ek = ek + params["attn"]["bk"].astype(ek.dtype)
                ev = ev + params["attn"]["bv"].astype(ev.dtype)
        out, _ = att.attention_block(params["attn"], h, cfg=cfg,
                                     positions=ctx.positions,
                                     cross_kv=(ek, ev))
        new_cache = cache
        if cache is not None and ctx.mode == "prefill":
            new_cache = {"ek": ek.astype(cache["ek"].dtype),
                         "ev": ev.astype(cache["ev"].dtype)}
        return x + out, new_cache, aux
    if kind == "mlp":
        h = L.apply_norm(params["norm"], x, kind=cfg.norm_type, eps=cfg.norm_eps)
        return x + L.apply_mlp(params["mlp"], h, act=cfg.act), cache, aux
    if kind == "moe":
        h = L.apply_norm(params["norm"], x, kind=cfg.norm_type, eps=cfg.norm_eps)
        out, aux = moe_mod.moe_layer(params["moe"], h, cfg)
        return x + out, cache, aux
    if kind == "rwkv":
        if cache is None:
            cache = _zero_state(rwk.rwkv_state_schema(cfg, x.shape[0]))
        out, new_state = rwk.rwkv_block(params, x, cache, cfg, mode=ctx.mode)
        return out, new_state, aux
    if kind == "mamba":
        if cache is None:
            cache = _zero_state(mam.mamba_state_schema(cfg, x.shape[0]))
        out, new_state = mam.mamba_block(params, x, cache, cfg, mode=ctx.mode)
        return out, new_state, aux
    if kind == "shared_attn":
        sp = ctx.shared
        h = L.apply_norm(sp["norm1"], x, kind=cfg.norm_type, eps=cfg.norm_eps)
        kv = (cache["k"], cache["v"]) if cache else None
        out, new_kv = att.attention_block(
            sp["attn"], h, cfg=cfg, positions=ctx.positions,
            causal=ctx.causal, cache=kv, cache_len=ctx.cache_len)
        x = x + out
        h = L.apply_norm(sp["norm2"], x, kind=cfg.norm_type, eps=cfg.norm_eps)
        x = x + L.apply_mlp(sp["mlp"], h, act=cfg.act)
        new_cache = {"k": new_kv[0], "v": new_kv[1]} if new_kv else cache
        return x, new_cache, aux
    raise ValueError(kind)


def _remat_policy(cfg):
    if cfg.remat == "none":
        return None
    if cfg.remat == "full":
        return jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint_policies.dots_with_no_batch_dims_saveable


def apply_stack(stack_params, x, plan, ctx, cache=None):
    """Run all segments. Returns (x, new_cache, total_aux)."""
    cfg = ctx.cfg
    total_aux = jnp.zeros((), jnp.float32)
    new_cache = {}

    for i, seg in enumerate(plan):
        seg_params = stack_params[f"seg{i}"]
        seg_cache = (cache or {}).get(f"seg{i}", {})

        def repeat_body(x, layer_params, layer_cache, seg=seg):
            aux = jnp.zeros((), jnp.float32)
            new_layer_cache = {}
            for li, layer in enumerate(seg.pattern):
                for kind in layer:
                    key = f"l{li}_{kind}"
                    p = layer_params.get(key, {})
                    c = layer_cache.get(key)
                    x, c_new, a = _apply_sublayer(kind, p, x, c, ctx)
                    aux = aux + a
                    if c_new is not None and key in layer_cache:
                        new_layer_cache[key] = c_new
            return x, new_layer_cache, aux

        policy = _remat_policy(cfg)
        if policy is not None:
            repeat_body = jax.checkpoint(
                repeat_body, policy=policy, static_argnums=())

        def scan_body(carry, xs):
            x, aux = carry
            layer_params, layer_cache = xs
            x = constrain(x, "btd")
            x, new_layer_cache, a = repeat_body(x, layer_params, layer_cache)
            return (x, aux + a), new_layer_cache

        (x, total_aux), seg_cache_new = jax.lax.scan(
            scan_body, (x, total_aux), (seg_params, seg_cache))
        new_cache[f"seg{i}"] = seg_cache_new

    return x, (new_cache if cache is not None else None), total_aux

"""Pallas TPU kernel: batched radix-2 Stockham FFT (paper §3.4 dataflow).

One grid step = one (rows x N) batch block staged into VMEM. The whole
log2(N)-stage pipeline runs on the staged block: butterflies on the VPU,
the inter-stage *words interleaving* as register reshapes — data makes ONE
HBM->VMEM round trip for the entire FFT, which is precisely the paper's
SPM->VWR->datapath staging claim, transplanted. Twiddles are a packed
(log2 N, N/2) table, computed host-side in f64 and staged once (the paper
stores them in the SPM; the FFT accelerator it compares against burns ROMs).

Working set: re + im + twiddles = 3 "VWR" blocks (core/vwr.py budget).
Compute is f32 regardless of I/O dtype (the 18-bit dynamic-scaling trick of
the paper's fixed-function rival lives in archsim only).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.vwr import VWRSpec, resolve_block_rows


def twiddle_table(n: int, inverse: bool = False) -> tuple[np.ndarray, np.ndarray]:
    """(stages, n//2) packed twiddles; stage s covers group length n >> s."""
    stages = int(np.log2(n))
    wr = np.zeros((stages, n // 2), np.float32)
    wi = np.zeros((stages, n // 2), np.float32)
    for s in range(stages):
        m = n >> s               # current group length
        j = np.arange(m // 2)
        ang = -2.0 * np.pi * j / m
        if inverse:
            ang = -ang
        # tile so every group in the stage reads lane-aligned twiddles
        wr[s] = np.tile(np.cos(ang), n // m).astype(np.float32)
        wi[s] = np.tile(np.sin(ang), n // m).astype(np.float32)
    return wr, wi


def fft_kernel(re_ref, im_ref, wr_ref, wi_ref, ore_ref, oim_ref, *,
               stages: int):
    re = re_ref[...].astype(jnp.float32)    # (rb, N)
    im = im_ref[...].astype(jnp.float32)
    rb, n_total = re.shape
    g, n = 1, n_total
    re = re.reshape(rb, 1, n_total)
    im = im.reshape(rb, 1, n_total)
    for s in range(stages):
        ar, ai = re[..., : n // 2], im[..., : n // 2]
        br, bi = re[..., n // 2:], im[..., n // 2:]
        wr = wr_ref[s, : n // 2].reshape(1, 1, n // 2)
        wi = wi_ref[s, : n // 2].reshape(1, 1, n // 2)
        t0r, t0i = ar + br, ai + bi
        dr, di = ar - br, ai - bi
        t1r = dr * wr - di * wi
        t1i = dr * wi + di * wr
        # words-interleaving regroup (self-sorting Stockham)
        re = jnp.concatenate([t0r[:, None], t1r[:, None]], axis=1).reshape(
            rb, 2 * g, n // 2)
        im = jnp.concatenate([t0i[:, None], t1i[:, None]], axis=1).reshape(
            rb, 2 * g, n // 2)
        g, n = 2 * g, n // 2
    ore_ref[...] = re.reshape(rb, n_total).astype(ore_ref.dtype)
    oim_ref[...] = im.reshape(rb, n_total).astype(oim_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("inverse", "interpret", "block_rows"))
def fft_pallas(re, im, *, inverse: bool = False, interpret: bool = True,
               block_rows: int | None = None):
    """Batched complex FFT. re/im: (R, N), N a power of two.

    ``block_rows`` overrides the static VWRSpec budget (core/autotune.py
    feeds a measured winner through here)."""
    R, N = re.shape
    stages = int(np.log2(N))
    assert 1 << stages == N, f"N={N} not a power of 2"
    wr, wi = twiddle_table(N, inverse)
    rb = resolve_block_rows(R, N * 4, spec=VWRSpec(n_vwrs=3),
                            override=block_rows)
    out = pl.pallas_call(
        functools.partial(fft_kernel, stages=stages),
        out_shape=(jax.ShapeDtypeStruct((R, N), re.dtype),
                   jax.ShapeDtypeStruct((R, N), re.dtype)),
        in_specs=[
            pl.BlockSpec((rb, N), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((rb, N), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((stages, N // 2), lambda i: (0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((stages, N // 2), lambda i: (0, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=(
            pl.BlockSpec((rb, N), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((rb, N), lambda i: (i, 0), memory_space=pltpu.VMEM),
        ),
        grid=(R // rb,),
        interpret=interpret,
    )(re, im, jnp.asarray(wr), jnp.asarray(wi))
    rr, ri = out
    if inverse:
        rr, ri = rr / N, ri / N
    return rr, ri

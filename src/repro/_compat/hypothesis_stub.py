"""Deterministic stand-in for `hypothesis` when it is not installed.

The test suite uses a small, well-behaved subset of hypothesis:
``@settings(max_examples=N, deadline=None)`` over ``@given(st.integers(...),
st.floats(...))`` with no pytest fixtures mixed in.  Hermetic containers
(no network, no pip) still need those modules to *collect and run*, so
``tests/conftest.py`` installs this stub into ``sys.modules`` only when the
real package is unavailable.  When hypothesis is installed (e.g. in CI via
``pip install -e ".[test]"``) the stub is never imported.

Semantics: each example draws one value per strategy.  Example 0 pins every
strategy to its minimum and example 1 to its maximum (edge coverage);
remaining examples are drawn from a NumPy Generator seeded from the test's
qualified name, so failures reproduce run-to-run and machine-to-machine.
No shrinking, no database — this is a fallback, not a replacement.
"""
from __future__ import annotations

import sys
import types
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw, lo=None, hi=None):
        self._draw = draw
        self._lo = lo
        self._hi = hi

    def example_at(self, i: int, rng: np.random.Generator):
        if i == 0 and self._lo is not None:
            return self._lo
        if i == 1 and self._hi is not None:
            return self._hi
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: int(r.integers(min_value, max_value + 1)),
                     min_value, max_value)


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda r: float(r.uniform(min_value, max_value)),
                     float(min_value), float(max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda r: bool(r.integers(0, 2)), False, True)


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda r: seq[int(r.integers(0, len(seq)))],
                     seq[0], seq[-1])


def given(*strategies):
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rng = np.random.default_rng([seed, i])
                args = [s.example_at(i, rng) for s in strategies]
                try:
                    fn(*args)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__qualname__} falsified on example {i}: "
                        f"args={args!r}") from e

        # NOTE: deliberately no functools.wraps — __wrapped__ would make
        # pytest see the original signature and demand fixtures for the
        # drawn arguments.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.hypothesis_stub = True
        return wrapper

    return deco


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def install():
    """Register this stub as `hypothesis` / `hypothesis.strategies`."""
    if "hypothesis" in sys.modules:          # real package won the race
        return
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.__stub__ = True
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from"):
        setattr(st, name, globals()[name])
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st

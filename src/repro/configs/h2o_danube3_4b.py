"""h2o-danube-3-4b [arXiv:2401.16818; unverified] — llama+mistral mix with
sliding-window attention. Window size is not pinned in the assignment; we use
4096 (mistral-style) and document the assumption in DESIGN.md."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    head_dim=120,
    rope_theta=10000.0,
    sliding_window=4096,
    source="arXiv:2401.16818 (danube family); window=4096 assumed",
))

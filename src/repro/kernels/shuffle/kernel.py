"""Pallas TPU kernel for the VWR2A shuffle unit (paper §3.3.1).

Each grid step stages one (rows x N) block of VWR A and B into VMEM (the
wide single-transaction fill of the paper) and applies one of the four
hardcoded permutations with register-level reshapes — no gathers:

  * interleave      — stack/reshape on the lane axis
  * prune even/odd  — reshape (N/2, 2) + component select
  * bit_reverse     — reshape to (2,)*m + axis reversal (a bit-reversal IS a
                      sequence of perfect shuffles; gather-free = TPU-native)
  * circular_shift  — two lane slices + concat (static amount)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.vwr import VWRSpec


def _interleave_vals(a, b):
    return jnp.stack([a, b], axis=-1).reshape(*a.shape[:-1], -1)


def _bit_reverse_vals(x):
    n = x.shape[-1]
    m = int(np.log2(n))
    lead = x.shape[:-1]
    x = x.reshape(lead + (2,) * m)
    perm = tuple(range(len(lead))) + tuple(
        len(lead) + m - 1 - i for i in range(m))
    return x.transpose(perm).reshape(lead + (n,))


def _take_half(x, half):
    n = x.shape[-1] // 2
    if half == "lower":
        return x[..., :n]
    if half == "upper":
        return x[..., n:]
    return x


def shuffle_kernel(a_ref, b_ref, o_ref, *, op: str, half: str, amount: int):
    a = a_ref[...]
    b = b_ref[...]
    if op == "interleave":
        out = _take_half(_interleave_vals(a, b), half)
    elif op in ("prune_even", "prune_odd"):
        comp = 1 if op == "prune_even" else 0  # drop even => keep odd
        ar = a.reshape(*a.shape[:-1], a.shape[-1] // 2, 2)[..., comp]
        br = b.reshape(*b.shape[:-1], b.shape[-1] // 2, 2)[..., comp]
        out = jnp.concatenate([ar, br], axis=-1)
    elif op == "bit_reverse":
        out = _take_half(_bit_reverse_vals(jnp.concatenate([a, b], axis=-1)),
                         half)
    elif op == "circular_shift":
        x = jnp.concatenate([a, b], axis=-1)
        k = amount % x.shape[-1]
        out = _take_half(jnp.concatenate([x[..., -k:], x[..., :-k]], axis=-1)
                         if k else x, half)
    else:
        raise ValueError(op)
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=("op", "half", "amount",
                                             "interpret"))
def shuffle_pallas(a, b, *, op: str, half: str = "both", amount: int = 32,
                   interpret: bool = True):
    """a, b: (R, N) with N a power of two. Returns the shuffled block."""
    R, N = a.shape
    out_n = N if (half != "both" or op.startswith("prune")) else 2 * N
    spec = VWRSpec()
    rb = min(R, max(1, spec.max_block_bytes(a.dtype.itemsize) //
                    max(1, 2 * N * a.dtype.itemsize)))
    while R % rb:
        rb -= 1
    grid = (R // rb,)
    kern = functools.partial(shuffle_kernel, op=op, half=half, amount=amount)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((R, out_n), a.dtype),
        in_specs=[
            pl.BlockSpec((rb, N), lambda i: (i, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((rb, N), lambda i: (i, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((rb, out_n), lambda i: (i, 0),
                               memory_space=pltpu.VMEM),
        grid=grid,
        interpret=interpret,
    )(a, b)

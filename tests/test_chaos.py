"""Chaos tests for fault-tolerant column serving (`serve/fault.py`).

THE INVARIANT under test everywhere: for ANY injected fault schedule —
column deaths at arbitrary dispatch steps, death mid-resident-sweep,
transient dispatch faults, stragglers, hangs — the recovered output is
**bit-identical** to the fault-free single-column run; only the work
distribution changes. Every scenario runs on the injected `VirtualClock`
so heartbeat timeouts, EWMA rates, and straggler medians replay
deterministically.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.biosignal import make_app
from repro.kernels.pipeline.shard import column_shares, requeue_ranges
from repro.runtime.fault import (InsufficientHealthyWorkers,
                                 StragglerDetector)
from repro.serve.engine import ColumnScheduler
from repro.serve.fault import (ColumnHungError, FaultInjector,
                               FaultTolerantColumnRunner, VirtualClock)
from repro.serve.resident import ResidentConfig
from repro.serve.stream import (BiosignalStream, StreamConfig,
                                StreamTelemetry)

WINDOW, HOP, BW = 512, 256, 2
CFG = StreamConfig(window=WINDOW, hop=HOP, batch_windows=BW)


@pytest.fixture(scope="module")
def app():
    return make_app()


def _signal(n_frames: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    n_samples = WINDOW + (n_frames - 1) * HOP
    return rng.normal(size=n_samples).astype(np.float32)


@pytest.fixture(scope="module")
def reference(app):
    """Fault-free single-column outputs, keyed by frame count."""
    cache = {}

    def get(n_frames: int):
        if n_frames not in cache:
            cache[n_frames] = BiosignalStream(app, CFG).process(
                _signal(n_frames))
        return cache[n_frames]

    return get


def _assert_identical(ref, out):
    assert set(ref) == set(out)
    for k in ref:
        a, b = jnp.asarray(ref[k]), jnp.asarray(out[k])
        assert a.dtype == b.dtype and a.shape == b.shape, k
        assert (a == b).all(), k


def _runner(app, n_columns, injector, clock, **kw):
    return FaultTolerantColumnRunner(app, CFG, n_columns=n_columns,
                                     injector=injector, clock=clock, **kw)


# ------------------------------------------------------- requeue algebra

def test_requeue_ranges_cover_exactly_and_stay_ordered():
    ranges = [(3, 4), (10, 1), (20, 7)]
    parts = requeue_ranges(ranges, 3, (1.0, 0.0, 2.0))
    assert parts[1] == []                          # zero weight: nothing
    flat = [r for col in parts for r in col]
    assert sum(c for _, c in flat) == 12
    # reassembled coverage equals the input coverage exactly
    covered = sorted(f for s, c in flat for f in range(s, s + c))
    wanted = sorted(f for s, c in ranges for f in range(s, s + c))
    assert covered == wanted
    # shares follow column_shares on the total
    assert [sum(c for _, c in col) for col in parts] == \
        list(column_shares(12, 3, (1.0, 0.0, 2.0)))


def test_requeue_ranges_degenerate():
    assert requeue_ranges([], 3) == [[], [], []]
    assert requeue_ranges([(5, 0)], 2) == [[], []]
    parts = requeue_ranges([(7, 3)], 1)
    assert parts == [[(7, 3)]]


# --------------------------------------------------------- death sweeps

@pytest.mark.parametrize("n_frames,n_columns,kill_step", [
    (9, 2, 0),       # D=2 degenerate, death on the very first dispatch
    (9, 2, 1),
    (13, 3, 0),
    (13, 3, 2),      # near the end of the column's share
    (21, 4, 1),
    (21, 4, 2),
])
def test_killed_column_recovers_bit_identical(app, reference, n_frames,
                                              n_columns, kill_step):
    clk = VirtualClock()
    inj = FaultInjector(kill={0: kill_step}, dispatch_s=0.01, clock=clk)
    r = _runner(app, n_columns, inj, clk)
    out = r.process(_signal(n_frames))
    _assert_identical(reference(n_frames), out)
    assert r.scheduler.dead == {0}
    # the killed dispatch's range was never retired, so it must requeue
    assert r.requeues >= 1


def test_multi_kill_recovers_bit_identical(app, reference):
    clk = VirtualClock()
    inj = FaultInjector(kill={0: 1, 2: 0}, dispatch_s=0.01, clock=clk)
    r = _runner(app, 4, inj, clk)
    out = r.process(_signal(21))
    _assert_identical(reference(21), out)
    assert r.scheduler.dead == {0, 2}


def test_kill_interleaved_with_transients(app, reference):
    """Transients on survivors while another column dies: the retry layer
    absorbs the former, the requeue layer the latter, independently."""
    clk = VirtualClock()
    inj = FaultInjector(kill={1: 1},
                        transient={(0, 0), (2, 1), (2, 2)},
                        dispatch_s=0.01, clock=clk)
    r = _runner(app, 3, inj, clk)
    out = r.process(_signal(13))
    _assert_identical(reference(13), out)
    assert r.scheduler.dead == {1}


def test_all_columns_dead_raises_typed_error(app):
    clk = VirtualClock()
    inj = FaultInjector(kill={0: 0, 1: 1}, dispatch_s=0.01, clock=clk)
    r = _runner(app, 2, inj, clk)
    with pytest.raises(InsufficientHealthyWorkers):
        r.process(_signal(9))


# ------------------------------------------------------- resident deaths

@pytest.mark.parametrize("kill_drain", [0, 1])
def test_death_mid_resident_sweep(app, reference, kill_drain):
    """A resident column dying at a counter drain: drains before the
    death already fed telemetry (heartbeats), the sweep's outputs are
    lost with the column, and the whole share requeues onto survivors."""
    clk = VirtualClock()
    inj = FaultInjector(kill_drain={1: kill_drain}, dispatch_s=0.01,
                        clock=clk)
    # ring_depth=1 + drain_interval=1: one drain per batch, so the
    # 4-frame share has two drain points and kill_drain=1 lands AFTER a
    # drain already fed telemetry
    r = FaultTolerantColumnRunner(
        app, CFG, n_columns=3, mode="resident",
        rcfg=ResidentConfig(ring_depth=1, drain_interval=1),
        injector=inj, clock=clk)
    out = r.process(_signal(13))
    _assert_identical(reference(13), out)
    assert r.scheduler.dead == {1}


def test_resident_fault_free_matches_reference(app, reference):
    clk = VirtualClock()
    inj = FaultInjector(dispatch_s=0.01, clock=clk)
    r = FaultTolerantColumnRunner(
        app, CFG, n_columns=3, mode="resident",
        rcfg=ResidentConfig(ring_depth=2, drain_interval=1),
        injector=inj, clock=clk)
    out = r.process(_signal(13))
    _assert_identical(reference(13), out)
    assert r.scheduler.dead == set()


# -------------------------------------------------- hangs and stragglers

def test_hung_column_dies_by_heartbeat_timeout(app, reference):
    """A wedged column (no retire, no error) is only resolvable through
    the heartbeat timeout: the retire feed goes quiet, supervision
    declares it dead, its queue requeues."""
    clk = VirtualClock()
    inj = FaultInjector(hang_from={2: 1}, dispatch_s=0.5, clock=clk)
    r = _runner(app, 4, inj, clk, heartbeat_timeout=2.0)
    out = r.process(_signal(21))
    _assert_identical(reference(21), out)
    assert 2 in r.scheduler.dead


def test_hung_column_without_supervision_stalls_loudly(app):
    clk = VirtualClock()
    inj = FaultInjector(hang_from={1: 0}, dispatch_s=0.5, clock=clk)
    r = _runner(app, 2, inj, clk, max_idle_passes=5)
    with pytest.raises(RuntimeError, match="stopped progressing"):
        r.process(_signal(9))


def test_straggler_column_is_evicted_and_work_requeued(app, reference):
    clk = VirtualClock()
    inj = FaultInjector(slow={3: 0.2}, dispatch_s=0.01, clock=clk)
    det = StragglerDetector(straggler_factor=2.0, evict_after=2)
    r = _runner(app, 4, inj, clk, straggler=det)
    out = r.process(_signal(21))
    _assert_identical(reference(21), out)
    assert r.scheduler.dead == {3}


# ------------------------------------------------- injector determinism

def test_injector_reset_replays_identically(app):
    clk = VirtualClock()
    inj = FaultInjector(kill={0: 1}, transient={(1, 0)},
                        dispatch_s=0.01, clock=clk)
    r1 = _runner(app, 3, inj, clk)
    out1 = r1.process(_signal(13))
    inj.reset()                            # counters rewind, clock doesn't
    r2 = _runner(app, 3, inj, clk)
    out2 = r2.process(_signal(13))
    _assert_identical(out1, out2)
    assert r1.scheduler.dead == r2.scheduler.dead == {0}


def test_injector_sequences_are_per_column():
    inj = FaultInjector(kill={1: 1})
    inj.on_dispatch(0)
    inj.on_dispatch(0)                     # column 0 seq advances alone
    inj.on_dispatch(1)                     # column 1 seq 0: alive
    with pytest.raises(Exception) as ei:
        inj.on_dispatch(1)                 # column 1 seq 1: dies
    assert ei.value.column == 1
    with pytest.raises(ColumnHungError):
        FaultInjector(hang_from={0: 0}).on_dispatch(0)


# --------------------------------------------------- scheduler contract

def test_scheduler_mark_dead_drains_and_requeues_admission():
    clk = VirtualClock()
    tel = StreamTelemetry(clock=clk)
    sched = ColumnScheduler(["d0", "d1", "d2"], telemetry=tel, clock=clk)
    for sid in ("a", "b", "c"):
        sched.admit(sid)
    assert sched.column_of("b") == 1
    moves = sched.mark_dead(1)
    assert set(moves) == {"b"}             # the dead column's stream moved
    assert sched.column_of("b") != 1
    assert sched.pop_moves() == moves      # drain moves ride pending_moves
    assert sched.healthy_columns() == [0, 2]
    # new admissions never land on the dead column
    for i in range(4):
        sched.admit(f"n{i}")
    assert all(sched.column_of(f"n{i}") != 1 for i in range(4))
    assert sched.mark_dead(1) == {}        # idempotent


def test_scheduler_deal_weights_zero_dead_columns():
    clk = VirtualClock()
    tel = StreamTelemetry(clock=clk)
    sched = ColumnScheduler(["d0", "d1", "d2"], telemetry=tel, clock=clk)
    for sid in ("a", "b", "c"):
        sched.admit(sid)
    for _ in range(3):                     # warm all EWMAs equally
        clk.advance(1.0)
        for sid in ("a", "b", "c"):
            tel.record_retire(sid, 8)
    sched.mark_dead(0)
    w = sched.deal_weights()
    assert w[0] == 0.0 and w[1] > 0.0 and w[2] > 0.0
    shares = column_shares(12, 3, w)
    assert shares[0] == 0 and sum(shares) == 12
    sched.mark_dead(2)
    with pytest.raises(InsufficientHealthyWorkers):
        sched.mark_dead(1)


def test_scheduler_supervise_heartbeat_and_straggler_paths():
    clk = VirtualClock()
    tel = StreamTelemetry(clock=clk)
    det = StragglerDetector(straggler_factor=2.0, evict_after=2)
    sched = ColumnScheduler(["d0", "d1", "d2", "d3"], telemetry=tel,
                            clock=clk, heartbeat_timeout=5.0, straggler=det)
    for sid in ("a", "b", "c", "d"):
        sched.admit(sid)
    # retires beat the stream's column; column 3 stays silent past the
    # timeout while the straggler detector condemns column 1
    for _ in range(3):
        clk.advance(1.0)
        for sid in ("a", "b", "c"):
            tel.record_retire(sid, 4)
        for col, dt in ((0, 0.1), (1, 0.9), (2, 0.1), (3, 0.1)):
            sched.record_batch_time(col, dt)
    clk.advance(3.0)                       # t=6: column 3 beat only at t=0
    for sid in ("a", "b", "c"):
        tel.record_retire(sid, 4)
    first = sched.supervise()
    assert first == [3]                    # heartbeat timeout; straggler
    #                                        strike 1 is below evict_after
    second = sched.supervise()
    assert second == [1]                   # straggler strike 2 evicts
    assert sched.healthy_columns() == [0, 2]
    assert sched.supervise() == []         # stable afterwards

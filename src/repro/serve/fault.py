"""Fault-tolerant column serving: heartbeats, dead-column drain, and
deterministic requeue.

The flexibility claim behind column replication only holds if columns are
INTERCHANGEABLE — and interchangeable must include "one of them died
mid-stream". This module connects the pure decision logic in
`runtime/fault.py` (heartbeat timeout, straggler eviction, capped-backoff
retry) to the live streaming runtime (`serve/stream.py`,
`serve/resident.py`, `serve/engine.py:ColumnScheduler`):

* the telemetry retire feed doubles as the HEARTBEAT source — every
  per-batch retire and every resident counter drain beats the column's
  `runtime.fault.HeartbeatMonitor` (no separate liveness channel);
* per-column dispatch wall times feed `runtime.fault.StragglerDetector`,
  so a column that is persistently slow gets evicted BEFORE it fails;
* a dead column's streams DRAIN onto survivors
  (`serve/engine.py:ColumnScheduler.mark_dead`) and its *unretired*
  hop-aligned frame ranges REQUEUE across them
  (`kernels/pipeline/shard.py:requeue_ranges`), with the degraded deal
  recomputed via `serve/engine.py:ColumnScheduler.deal_weights` — dead
  columns zeroed, riding `column_shares`' zero-weight path;
* transient dispatch failures are retried in place with capped
  exponential backoff (`runtime.fault.Supervisor.call`), never escalated
  to a death.

THE INVARIANT (the chaos property `tests/test_chaos.py` sweeps): for any
injected fault schedule — column deaths at arbitrary dispatch steps,
death mid-resident-sweep, transient faults, stragglers, hangs — the
recovered output is **bit-identical** to the fault-free run, just
redistributed across surviving columns. That holds because every unit of
requeued work is a HOP-ALIGNED frame range (frame i depends only on
samples ``[i*hop, i*hop + window)``; the chunk FIR's frame-local
transient patch makes each frame independent of where the signal is
cut — the same two facts that make the multi-column deal numerically
invisible, see `kernels/pipeline/shard.py`).

`FaultInjector` is the chaos harness: a deterministic fault schedule
keyed by (column, per-column dispatch/drain sequence number), injectable
into `serve/stream.py:BiosignalStream._dispatch_chunk` and the resident
drain path (`serve/resident.py:ResidentStream._drain`). The bench gate
(`run.py --check-fault`, `docs/BENCHMARKS.md`) pins the recovery cost:
killing one of D=4 columns mid-run must keep the modelled dispatch wall
within 1.5x of the fault-free run, outputs bit-identical.

The injector is SHARED ACROSS BOTH TRAFFIC CLASSES the repo serves: the
"column" key is just the supervised unit's index, so the fault-tolerant
LM engine (`serve/engine_fault.py:FaultTolerantEngine`) injects the same
schedules with an engine SLOT standing in as the column (a slot's
admission prefill is its seq 0, decode steps follow). One chaos
vocabulary — kill / transient / hang_from / slow, one `VirtualClock` —
drives both the frame-requeue property (`tests/test_chaos.py`) and the
request-replay property (`tests/test_engine_fault.py`).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp

from repro.core.biosignal import BiosignalApp, make_app
from repro.kernels.pipeline.kernel import empty_outputs
from repro.kernels.pipeline.shard import column_shares, requeue_ranges
from repro.runtime.fault import (ColumnDeadError, StragglerDetector,
                                 Supervisor, TransientDispatchError)
from repro.serve.engine import ColumnScheduler
from repro.serve.resident import ResidentConfig, ResidentStream
from repro.serve.stream import (BiosignalStream, StreamConfig,
                                StreamTelemetry, frame_count)

__all__ = ["VirtualClock", "ColumnHungError", "FaultInjector",
           "FaultTolerantColumnRunner"]


class VirtualClock:
    """A deterministic monotonic clock tests/benches advance by hand —
    the injectable time source `FaultInjector`, `StreamTelemetry`, and
    `ColumnScheduler` share so heartbeat timeouts and EWMA math replay
    exactly."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t


# ColumnHungError moved under the serve/errors.py taxonomy (ServeError
# root); re-imported here so its historical home keeps working
from repro.serve.errors import ColumnHungError  # noqa: E402,F401


@dataclasses.dataclass
class FaultInjector:
    """Deterministic fault schedule for the chaos harness.

    Faults are keyed by ``(column, seq)`` where ``seq`` is the
    per-column DISPATCH sequence number (0-based, incremented on every
    `on_dispatch` call — retried attempts count, so "two transient
    failures then success" is entries at seq s and s+1). Drain faults
    use the separate per-column DRAIN counter (`on_drain`, one tick per
    telemetry drain point of the resident path).

    * ``kill[column] = seq`` — the dispatch raises
      `runtime.fault.ColumnDeadError` (fatal; the serving layer drains
      and requeues).
    * ``kill_drain[column] = seq`` — the column dies at that counter
      DRAIN instead: the resident loop's outputs are lost with the
      column, but earlier drains already fed the telemetry — the "death
      mid-resident-sweep" scenario.
    * ``transient`` — set of ``(column, seq)`` dispatches that raise
      `runtime.fault.TransientDispatchError` (retryable; the stream's
      `runtime.fault.Supervisor.call` backoff absorbs them).
    * ``hang_from[column] = seq`` — from that dispatch on, the column is
      wedged (`ColumnHungError`): no result, no retire, no heartbeat.
      Requires heartbeat supervision (or a real wall-clock) to resolve.
    * ``slow[column] = extra_s`` — every dispatch on the column takes
      ``extra_s`` extra virtual seconds (straggler simulation).

    ``dispatch_s`` is the virtual cost of a healthy dispatch; when
    ``clock`` (a `VirtualClock`) is set, every `on_dispatch` advances it
    by ``dispatch_s + slow.get(column, 0)`` so heartbeat timeouts and
    straggler medians replay deterministically. `reset` rewinds the
    sequence counters (NOT the clock) so one schedule can be replayed
    across bench reps.
    """
    kill: dict = dataclasses.field(default_factory=dict)
    kill_drain: dict = dataclasses.field(default_factory=dict)
    transient: set = dataclasses.field(default_factory=set)
    hang_from: dict = dataclasses.field(default_factory=dict)
    slow: dict = dataclasses.field(default_factory=dict)
    dispatch_s: float = 0.0
    clock: VirtualClock | None = None
    _seq: dict = dataclasses.field(default_factory=dict)
    _drain_seq: dict = dataclasses.field(default_factory=dict)

    def reset(self) -> None:
        self._seq.clear()
        self._drain_seq.clear()

    def on_dispatch(self, column: int) -> None:
        seq = self._seq.get(column, 0)
        self._seq[column] = seq + 1
        if self.clock is not None:
            self.clock.advance(self.dispatch_s +
                               float(self.slow.get(column, 0.0)))
        if column in self.hang_from and seq >= self.hang_from[column]:
            raise ColumnHungError(column)
        if self.kill.get(column) == seq:
            raise ColumnDeadError(column)
        if (column, seq) in self.transient:
            raise TransientDispatchError(
                f"injected transient fault on column {column} seq {seq}")

    def on_drain(self, column: int) -> None:
        seq = self._drain_seq.get(column, 0)
        self._drain_seq[column] = seq + 1
        if self.kill_drain.get(column) == seq:
            raise ColumnDeadError(
                column, f"column {column} died at drain {seq}")


class FaultTolerantColumnRunner:
    """Drives ONE signal across D columns with fault-tolerant requeue —
    the serving front-end of the detection → drain → requeue → re-deal
    closed loop.

    The signal's frames are dealt into hop-aligned per-column ranges
    (`column_shares` exact-sum equal deal, or ``weights``), each range
    dispatched through the column's pinned stream — a
    `serve.stream.BiosignalStream` per range of ``cfg.batch_windows``
    frames (``mode="batch"``), or a `serve.resident.ResidentStream`
    covering the whole share in ring sweeps (``mode="resident"``). After
    every dispatch round `ColumnScheduler.supervise` runs: a column is
    declared dead on `runtime.fault.ColumnDeadError`, heartbeat timeout
    (the retire feed went quiet), or straggler eviction; its streams
    drain and its UNRETIRED ranges requeue across survivors via
    `requeue_ranges` under the degraded `ColumnScheduler.deal_weights`
    (dead columns zeroed; equal weights while telemetry is cold). The
    last column dying raises
    `runtime.fault.InsufficientHealthyWorkers`.

    `process` returns the full framed output dict, bit-identical to the
    fault-free single-column reference for ANY injected fault schedule
    (the chaos property). ``column_busy`` holds per-column busy seconds
    (sum of dispatch walls) — ``max(column_busy)`` is the modelled
    dispatch wall on a real D-device machine, the quantity the
    ``--check-fault`` bench gate bounds.
    """

    def __init__(self, app: BiosignalApp | None = None,
                 cfg: StreamConfig | None = None, *, n_columns: int,
                 mode: str = "batch", rcfg: ResidentConfig | None = None,
                 injector: FaultInjector | None = None,
                 weights=None, deal_band: float = 0.0,
                 heartbeat_timeout: float | None = None,
                 straggler: StragglerDetector | None = None,
                 retry: Supervisor | None = None, devices=None, clock=None,
                 max_idle_passes: int = 10_000):
        assert n_columns >= 1, n_columns
        assert mode in ("batch", "resident"), mode
        self.app = app or make_app()
        self.cfg = cfg or StreamConfig()
        assert self.cfg.n_columns == 1, \
            "the runner deals ranges itself; streams stay column-pinned"
        self.mode = mode
        self.rcfg = rcfg or ResidentConfig()
        self.injector = injector
        self.weights = weights
        self.deal_band = deal_band
        self.max_idle_passes = max_idle_passes
        self.clock = clock if clock is not None else (
            injector.clock if injector is not None and
            injector.clock is not None else time.perf_counter)
        self.telemetry = StreamTelemetry(clock=self.clock)
        if devices is None:
            devices = [jax.devices()[0]] * n_columns
        self.scheduler = ColumnScheduler(
            devices, telemetry=self.telemetry,
            heartbeat_timeout=heartbeat_timeout, straggler=straggler,
            clock=self.clock)
        # one pinned stream per column: an idle scheduler admits
        # round-robin, so stream "col d" lands on column d exactly
        self.streams = {}
        for d in range(n_columns):
            sid = f"col{d}"
            device = self.scheduler.admit(sid)
            common = dict(telemetry=self.telemetry, stream_id=sid,
                          column=d, injector=injector, retry=retry)
            self.streams[d] = (
                BiosignalStream(self.app, self.cfg, device=device, **common)
                if mode == "batch" else
                ResidentStream(self.app, self.cfg, self.rcfg,
                               device=device, **common))
        self.column_busy = [0.0] * n_columns
        self.dispatches = 0
        self.requeues = 0

    @property
    def n_columns(self) -> int:
        return len(self.streams)

    def live_columns(self) -> list[int]:
        return self.scheduler.healthy_columns()

    # ------------------------------------------------------------ deal

    def _initial_queues(self, n_frames: int) -> list[deque]:
        """Deal frames into per-column queues of hop-aligned ranges:
        batch mode splits a column's contiguous share into
        ``batch_windows``-frame dispatch ranges; resident mode keeps the
        share whole (the ring loop iterates it on-device)."""
        w = self.weights if self.weights is not None \
            else (1.0,) * self.n_columns
        shares = column_shares(n_frames, self.n_columns, w)
        queues = [deque() for _ in range(self.n_columns)]
        start = 0
        bw = self.cfg.batch_windows
        for d, share in enumerate(shares):
            if self.mode == "resident":
                if share:
                    queues[d].append((start, share))
            else:
                for s in range(start, start + share, bw):
                    queues[d].append((s, min(bw, start + share - s)))
            start += share
        return queues

    def _degraded_weights(self) -> list[float]:
        """The re-deal weight vector for requeued work: measured column
        rates with dead columns zeroed (`ColumnScheduler.deal_weights`),
        or the equal deal over survivors while telemetry is cold."""
        measured = self.scheduler.deal_weights(band=self.deal_band)
        if measured is not None:
            return list(measured)
        return [0.0 if c in self.scheduler.dead else 1.0
                for c in range(self.n_columns)]

    def _requeue_from(self, column: int, queues: list[deque]) -> None:
        """Drain a dead column's queue and deal its unretired ranges
        across the survivors (hop-aligned splits, degraded weights)."""
        unretired = list(queues[column])
        queues[column].clear()
        if not unretired:
            return
        parts = requeue_ranges(unretired, self.n_columns,
                               self._degraded_weights())
        for d, runs in enumerate(parts):
            queues[d].extend(runs)
        self.requeues += 1

    # -------------------------------------------------------- dispatch

    def _chunk(self, sig, start: int, count: int):
        cfg = self.cfg
        s0 = start * cfg.hop
        return sig[s0: s0 + (count - 1) * cfg.hop + cfg.window]

    def _dispatch(self, column: int, sig, start: int, count: int) -> dict:
        out = self.streams[column].process(self._chunk(sig, start, count))
        self.dispatches += 1
        return out

    # ---------------------------------------------------------- serve

    def process(self, signal) -> dict:
        """All framed outputs for ``signal`` under the injected fault
        schedule — bit-identical to the fault-free run. Raises
        `runtime.fault.InsufficientHealthyWorkers` if every column dies,
        and RuntimeError if the fleet stops progressing without a
        supervisable cause (a hung column with no heartbeat timeout)."""
        cfg = self.cfg
        sig = jnp.asarray(signal)
        assert sig.ndim == 1, sig.shape
        n = frame_count(sig.shape[0], cfg.window, cfg.hop)
        if n == 0:
            w = self.app.svm_w.shape
            return empty_outputs(cfg.window, w[0], w[1], sig.dtype,
                                 cfg.outputs)
        queues = self._initial_queues(n)
        results: dict[int, tuple[int, dict]] = {}
        idle = 0
        while True:
            pending = [d for d in self.live_columns() if queues[d]]
            if not pending:
                break
            progressed = False
            for d in pending:
                if d in self.scheduler.dead:    # died earlier this round
                    continue
                start, count = queues[d][0]
                t0 = self.clock()
                try:
                    out = self._dispatch(d, sig, start, count)
                except ColumnHungError:
                    continue        # wedged: no retire — only the
                    #                 heartbeat timeout can resolve this
                except ColumnDeadError:
                    self.scheduler.mark_dead(d)
                    self._requeue_from(d, queues)
                    continue
                dt = self.clock() - t0
                queues[d].popleft()
                results[start] = (count, out)
                self.column_busy[d] += dt
                self.scheduler.record_batch_time(d, dt)
                progressed = True
            newly = self.scheduler.supervise()
            for d in newly:
                self._requeue_from(d, queues)
            if progressed or newly:
                idle = 0
            else:
                idle += 1
                if idle > self.max_idle_passes:
                    raise RuntimeError(
                        "fleet stopped progressing (hung column without "
                        "heartbeat supervision?)")
        # assemble: the requeued ranges must tile [0, n) exactly once
        items = sorted(results.items())
        pos = 0
        for start, (count, _) in items:
            assert start == pos, (start, pos)
            pos += count
        assert pos == n, (pos, n)
        outs = [out for _, (_, out) in items]
        return {k: jnp.concatenate([o[k] for o in outs]) for k in outs[0]}

"""Pallas TPU kernel: the FULL MBioTracker pipeline fused into one kernel.

The paper's headline number is *application-level* (§4.4.2 / Table 5):
chaining kernels while the data stays resident in the SPM/VWRs is where the
energy goes away — the FIR output is consumed by the delineation, whose
window is consumed by the feature extraction, whose features feed the SVM,
and main memory is touched exactly twice (signal in, features out). Our
staged `BiosignalApp` runs those stages as separate jnp/pallas calls, so
every stage round-trips HBM. This kernel transplants the paper's staging to
the whole application, extending what `kernels/fft/kernel.py` does for one
kernel:

    one grid step = one (rb x S) window block staged into VMEM, then
      1. 11-tap FIR          — k unrolled shifted FMAs (paper §4.4.1),
      2. delineation         — the mask-algebra predicates of
                               `core.biosignal.delineate` (the paper's
                               predicated RC code), on the VMEM-resident
                               filtered block,
      3. time features       — masked interval statistics,
      4. 512-pt packed rFFT  — the Stockham stages of the FFT kernel with a
                               staged twiddle table + untangle epilogue,
                               reduced to 6 log-band powers,
      5. linear SVM          — margin + argmax class,
    and ONE HBM write of (filtered, features, margin, class).

Inter-stage tensors never leave the block: the working set is budgeted
against `VWRSpec(n_vwrs=4)` (raw + filtered + FFT planes + table/epilogue
scratch). Numerics follow `core.biosignal` op-for-op so the fused outputs
match the staged app to f32 tolerance. The delineation/median stage runs a
fixed-size odd-even sorting network off staged mask tables (no `sort` /
`take_along_axis` / gather anywhere in the kernel — the former
Mosaic-compile gap is closed).

`pipeline_stream_pallas` is the RAW-SIGNAL entry: the grid iterates
frame-blocks over a 1-D signal and the overlapping (window, hop) frames
are built in-kernel from a once-staged chunk — the streaming
single-residency analogue of the paper's §4.2 overlap reuse. Both entries
take an `outputs` selection that elides unrequested computation and HBM
writes.

**As of the stage-graph refactor** the three public entries
(`pipeline_pallas`, `pipeline_stream_pallas`, `pipeline_ring_pallas`)
keep their exact signatures but route through the generic graph compiler
(`graph.py:graph_stream_pallas` and siblings): the biosignal app is the
first registered `StageGraph` (stages ``fir -> delineate ->
biosignal_features -> svm``, registered below), and the compiled body is
**bit-identical** to the frozen legacy bodies this module retains
(`pipeline_kernel`, `pipeline_stream_kernel`) because it composes the
same helpers in the same op order — `tests/test_stage_graph.py` pins
that equality across (window, hop, outputs, ring_depth). The ASR
front-end (`asr.py`) is the second graph over the same machinery; see
`docs/STAGE_GRAPHS.md` for authoring more.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.biosignal import (INTERVAL_SLOTS, band_power_features,
                                  delineate, interval_time_features,
                                  make_app, oddeven_tables)
from repro.core.fft import untangle_rfft
from repro.kernels.fft.kernel import twiddle_table
from repro.kernels.pipeline.graph import (OutputSpec, _fir_stage,
                                          build_graph, graph_frames_call,
                                          graph_ring_call,
                                          graph_stream_call,
                                          register_graph_factory)
# the framing arithmetic lives in graph.py now; re-exported here because
# this module is its historical import location
from repro.kernels.pipeline.graph import min_stream_block_frames  # noqa: F401
from repro.kernels.pipeline.graph import resolve_stream_block_frames  # noqa: F401,E501
from repro.kernels.pipeline.graph import ring_chunk_samples  # noqa: F401
from repro.kernels.pipeline.graph import stream_frame_count  # noqa: F401
from repro.kernels.pipeline.stages import register_stage


def untangle_table(fft_size: int) -> np.ndarray:
    """(2, m) packed untangle factors e^{-2*pi*i*k/N} for the real-FFT
    epilogue — staged into VMEM alongside the twiddles (the paper keeps
    both in the SPM)."""
    m = fft_size // 2
    ang = -2.0 * np.pi * np.arange(m) / fft_size
    return np.stack([np.cos(ang), np.sin(ang)]).astype(np.float32)


def _packed_rfft(seg, wr_ref, wi_ref, u_ref, *, fft_size: int):
    """Packed real FFT of a VMEM-resident (rb, fft_size) block: N real ->
    N/2+1 complex via Stockham stages on the packed half-length signal +
    the untangle epilogue. The butterfly stages are the FFT kernel's body
    verbatim, reading the staged (stages, m/2) twiddle table and the
    (2, m) untangle table. Returns ``(Xr, Xi)``, each (rb, fft/2+1).
    Shared by the biosignal band-power stage (mean-subtracted input) and
    the ASR power-spectrum stage (raw windowed input) — the in-kernel
    mirror of `core.fft.rfft_packed`."""
    rb = seg.shape[0]
    zr, zi = seg[:, 0::2], seg[:, 1::2]            # pack: z = even + i*odd
    m = fft_size // 2
    stages = int(np.log2(m))
    g, n = 1, m
    re = zr.reshape(rb, 1, m)
    im = zi.reshape(rb, 1, m)
    for s in range(stages):
        ar, ai = re[..., : n // 2], im[..., : n // 2]
        br, bi = re[..., n // 2:], im[..., n // 2:]
        wr = wr_ref[s, : n // 2].reshape(1, 1, n // 2)
        wi = wi_ref[s, : n // 2].reshape(1, 1, n // 2)
        t0r, t0i = ar + br, ai + bi
        dr, di = ar - br, ai - bi
        t1r = dr * wr - di * wi
        t1i = dr * wi + di * wr
        # words-interleaving regroup (self-sorting Stockham)
        re = jnp.concatenate([t0r[:, None], t1r[:, None]], axis=1).reshape(
            rb, 2 * g, n // 2)
        im = jnp.concatenate([t0i[:, None], t1i[:, None]], axis=1).reshape(
            rb, 2 * g, n // 2)
        g, n = 2 * g, n // 2
    Zr = re.reshape(rb, m)
    Zi = im.reshape(rb, m)
    return untangle_rfft(Zr, Zi, u_ref[0, :], u_ref[1, :])


def _rfft_band_powers(seg, wr_ref, wi_ref, u_ref, *, fft_size: int):
    """Mean-subtracted `_packed_rfft` power reduced to the 6 log-band
    powers of `core.biosignal.extract_features`."""
    seg = seg - jnp.mean(seg, axis=-1, keepdims=True)
    Xr, Xi = _packed_rfft(seg, wr_ref, wi_ref, u_ref, fft_size=fft_size)
    power = jnp.square(Xr) + jnp.square(Xi)        # (rb, fft/2+1)
    return band_power_features(power, fft_size)


OUTPUTS = ("filtered", "features", "margin", "class")


def canonical_outputs(outputs) -> tuple:
    """Validate + canonically order an output selection. `None` means all
    four app outputs; any subset elides the unrequested HBM writes (the
    (R, S) `filtered` write is by far the largest — dropping it is the
    point for classification-only traffic)."""
    if outputs is None:
        return OUTPUTS
    sel = tuple(outputs)
    bad = [o for o in sel if o not in OUTPUTS]
    assert not bad, f"unknown outputs {bad}; choose from {OUTPUTS}"
    assert sel, "outputs selection must not be empty"
    return tuple(o for o in OUTPUTS if o in sel)


def _stages_from_filtered(filt, wr_ref, wi_ref, u_ref, w_ref, b_ref,
                          sort_tables, *, fft_size: int):
    """Stages 2-4 on a VMEM-resident filtered block: delineation mask
    algebra -> masked interval time features + packed-rFFT band powers ->
    linear SVM margin/class. Shared by the framed and raw-stream kernels.
    ``sort_tables`` are the staged odd-even network masks for the interval
    median (kept in VMEM beside the twiddles, like the paper's SPM
    tables)."""
    # --- stage 2: delineation (predicated mask algebra, never leaves VMEM)
    is_max, is_min = delineate(filt)
    # --- stage 3a: time features (masked interval statistics) ---
    f_time = interval_time_features(is_max, is_min, sort_tables=sort_tables)
    # --- stage 3b: frequency features (packed rFFT band powers) ---
    f_freq = _rfft_band_powers(filt[:, :fft_size], wr_ref, wi_ref, u_ref,
                               fft_size=fft_size)
    feats = jnp.stack(f_time + f_freq, axis=-1)    # (rb, 12)
    # --- stage 4: linear SVM margin + class ---
    margin = jnp.dot(feats, w_ref[...], preferred_element_type=jnp.float32
                     ) + b_ref[0]
    cls = jnp.argmax(margin, axis=-1).astype(jnp.int32)
    return feats, margin, cls


def _write_outputs(refs: dict, filt, feats, margin, cls):
    """The ONE HBM write per grid step — only the requested refs exist."""
    if "filtered" in refs:
        refs["filtered"][...] = filt.astype(refs["filtered"].dtype)
    if "features" in refs:
        refs["features"][...] = feats
    if "margin" in refs:
        refs["margin"][...] = margin
    if "class" in refs:
        refs["class"][...] = cls[:, None]


def pipeline_kernel(x_ref, taps_ref, wr_ref, wi_ref, u_ref, w_ref, b_ref,
                    lo_ref, hi_ref, ks_ref, *out_refs, n_taps: int,
                    fft_size: int, outputs: tuple = OUTPUTS):
    refs = dict(zip(outputs, out_refs))
    x = x_ref[...].astype(jnp.float32)             # (rb, S) staged once
    # --- stage 1: preprocessing (11-tap FIR) ---
    filt = _fir_stage(x, taps_ref, n_taps)
    feats = margin = cls = None
    if outputs != ("filtered",):
        feats, margin, cls = _stages_from_filtered(
            filt, wr_ref, wi_ref, u_ref, w_ref, b_ref,
            (lo_ref[...], hi_ref[...], ks_ref[...]), fft_size=fft_size)
    _write_outputs(refs, filt, feats, margin, cls)


def _table_operands(taps, w, b, fft_size: int):
    """The staged constant tables every pipeline kernel reads: FIR taps,
    Stockham twiddles, untangle factors, SVM weights/bias, and the
    fixed-size (INTERVAL_SLOTS) odd-even sorting-network stage masks for
    the interval median — with their (broadcast) VMEM BlockSpecs."""
    k = int(taps.shape[0])
    F, C = w.shape
    m = fft_size // 2
    stages = int(np.log2(m))
    assert 1 << stages == m, f"fft_size={fft_size} not a power of 2"
    wr, wi = twiddle_table(m)
    lo, hi, ks = oddeven_tables(INTERVAL_SLOTS)
    operands = (jnp.asarray(taps, jnp.float32).reshape(1, k),
                jnp.asarray(wr), jnp.asarray(wi),
                jnp.asarray(untangle_table(fft_size)),
                jnp.asarray(w, jnp.float32),
                jnp.asarray(b, jnp.float32).reshape(1, C),
                jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(ks))
    shapes = ((1, k), (stages, m // 2), (stages, m // 2), (2, m), (F, C),
              (1, C), lo.shape, hi.shape, ks.shape)
    # broadcast index_map takes *any* grid rank: the same tables serve the
    # 1-D framed/stream grids and the 2-D ring grid
    specs = [pl.BlockSpec(s, lambda *_: (0, 0), memory_space=pltpu.VMEM)
             for s in shapes]
    return operands, specs


def _out_shapes_specs(R: int, S: int, F: int, C: int, rb: int, dtype,
                      outputs: tuple, index_map=None):
    """Output ShapeDtypeStructs + BlockSpecs for an R-row result written in
    rb-row blocks. ``index_map`` defaults to the 1-D grid's row advance
    (block i -> rows [i*rb, (i+1)*rb)); the ring entry passes the 2-D
    (slot, block) -> flat-row map instead."""
    table = {
        "filtered": (jax.ShapeDtypeStruct((R, S), dtype), (rb, S)),
        "features": (jax.ShapeDtypeStruct((R, F), jnp.float32), (rb, F)),
        "margin": (jax.ShapeDtypeStruct((R, C), jnp.float32), (rb, C)),
        "class": (jax.ShapeDtypeStruct((R, 1), jnp.int32), (rb, 1)),
    }
    imap = index_map if index_map is not None else lambda i: (i, 0)
    out_shape = tuple(table[o][0] for o in outputs)
    out_specs = tuple(pl.BlockSpec(table[o][1], imap,
                                   memory_space=pltpu.VMEM) for o in outputs)
    return out_shape, out_specs


def _as_output_dict(outs: tuple, outputs: tuple, n: int) -> dict:
    res = {}
    for o, v in zip(outputs, outs):
        res[o] = v[:n, 0] if o == "class" else v[:n]
    return res


# ---------------------------------------------------------------------------
# The biosignal app as a registered stage graph
# ---------------------------------------------------------------------------

@register_stage("delineate", requires=("filtered",),
                produces=("is_max", "is_min"))
def _delineate_body(state, tables, params):
    """Delineation mask algebra (`core.biosignal.delineate`, the paper's
    predicated RC code) on the VMEM-resident filtered block."""
    is_max, is_min = delineate(state["filtered"])
    return {"is_max": is_max, "is_min": is_min}


@register_stage("biosignal_features",
                operands=("twiddle_re", "twiddle_im", "untangle",
                          "sort_lo", "sort_hi", "sort_ks"),
                requires=("filtered", "is_max", "is_min"),
                produces=("features",))
def _features_body(state, tables, params):
    """Masked interval time features (odd-even network median off the
    staged sort masks) + packed-rFFT band powers, stacked to (rb, 12)."""
    f_time = interval_time_features(
        state["is_max"], state["is_min"],
        sort_tables=(tables["sort_lo"][...], tables["sort_hi"][...],
                     tables["sort_ks"][...]))
    f_freq = _rfft_band_powers(
        state["filtered"][:, :params["fft_size"]], tables["twiddle_re"],
        tables["twiddle_im"], tables["untangle"],
        fft_size=params["fft_size"])
    return {"features": jnp.stack(f_time + f_freq, axis=-1)}


@register_stage("svm", operands=("svm_w", "svm_b"), requires=("features",),
                produces=("margin", "class"))
def _svm_body(state, tables, params):
    """Linear SVM margin + argmax class — the matmul epilogue stage."""
    margin = jnp.dot(state["features"], tables["svm_w"][...],
                     preferred_element_type=jnp.float32
                     ) + tables["svm_b"][0]
    return {"margin": margin,
            "class": jnp.argmax(margin, axis=-1).astype(jnp.int32)}


@functools.lru_cache(maxsize=None)
def biosignal_graph(n_taps: int, n_features: int, n_classes: int,
                    fft_size: int):
    """The biosignal app as a `StageGraph` — the first registered graph.
    Cached per static signature so the graph object is identical across
    calls (it is a static jit argument of the generic entries)."""
    return build_graph(
        "biosignal",
        ("fir", "delineate", "biosignal_features", "svm"),
        (("filtered", OutputSpec(("window",), "input")),
         ("features", OutputSpec(("n_features",), "float32")),
         ("margin", OutputSpec(("n_classes",), "float32")),
         ("class", OutputSpec((), "int32"))),
        # binding order == the `_table_operands` tuple order
        ("fir_taps", "twiddle_re", "twiddle_im", "untangle",
         "svm_w", "svm_b", "sort_lo", "sort_hi", "sort_ks"),
        (("n_taps", int(n_taps)), ("fft_size", int(fft_size)),
         ("n_features", int(n_features)), ("n_classes", int(n_classes))))


def _biosignal_graph_operands(taps, w, b, fft_size: int):
    """(graph, operand arrays) for the legacy (taps, w, b) signature."""
    operands, _ = _table_operands(taps, w, b, fft_size)
    F, C = w.shape
    return (biosignal_graph(int(taps.shape[0]), int(F), int(C),
                            int(fft_size)), operands)


def _biosignal_factory(app):
    """Graph factory (`graph.py:register_graph_factory`): bind a
    `core.biosignal.BiosignalApp`'s taps/weights to the graph operands."""
    return _biosignal_graph_operands(app.fir_taps, app.svm_w, app.svm_b,
                                     app.fft_size)


register_graph_factory("biosignal", _biosignal_factory,
                       default_app=make_app)


@functools.partial(jax.jit,
                   static_argnames=("fft_size", "interpret", "block_rows",
                                    "outputs"))
def pipeline_pallas(signal, taps, w, b, *, fft_size: int = 512,
                    interpret: bool = True, block_rows: int | None = None,
                    outputs: tuple = OUTPUTS):
    """Fused MBioTracker pipeline. signal: (R, S) windows, S >= fft_size.

    Returns the staged `BiosignalApp.__call__` dict restricted to
    `outputs` (default all four): {"filtered": (R,S), "features": (R,F),
    "margin": (R,C), "class": (R,)}. Exactly ONE `pallas_call` runs per
    window batch; unrequested outputs are never written to HBM. Compiles
    the biosignal `StageGraph` via `graph.py:graph_frames_call` —
    bit-identical to the frozen legacy `pipeline_kernel` body.
    """
    outputs = canonical_outputs(outputs)
    graph, operands = _biosignal_graph_operands(taps, w, b, fft_size)
    return graph_frames_call(signal, operands, graph=graph,
                             interpret=interpret, block_rows=block_rows,
                             outputs=outputs)


# ---------------------------------------------------------------------------
# Raw-signal streaming kernel: in-kernel framing, single residency
# ---------------------------------------------------------------------------

# stream_frame_count / min_stream_block_frames / resolve_stream_block_frames
# moved to graph.py (re-exported above): they are graph-generic framing
# arithmetic, not biosignal specifics.

def empty_outputs(window: int, F: int, C: int, dtype, outputs=None) -> dict:
    """The zero-frame result, with the SAME keys/shapes/dtypes as a
    non-empty call — the single source of truth for every degenerate path
    (short signal, empty stream batch)."""
    outputs = canonical_outputs(outputs)
    empty = {"filtered": jnp.zeros((0, window), dtype),
             "features": jnp.zeros((0, F), jnp.float32),
             "margin": jnp.zeros((0, C), jnp.float32),
             "class": jnp.zeros((0,), jnp.int32)}
    return {o: empty[o] for o in outputs}


def pipeline_stream_kernel(*refs, n_taps: int, fft_size: int, window: int,
                           hop: int, block_frames: int, outputs: tuple,
                           n_tails: int):
    """One grid step = one block of `block_frames` overlapping frames,
    built IN-KERNEL from the raw 1-D signal (the VWR/SPM single-residency
    analogue of the paper's §4.2 overlap reuse):

      * the body chunk (1, block_frames*hop) is this block's stride of raw
        samples — its BlockSpec index_map is the hop arithmetic: block j
        starts at sample j*block_frames*hop;
      * `n_tails` hop-sized chunks of the SAME signal, at the hop-blocks
        right after the body, supply the (window - hop) samples the last
        frames spill past it — so the staged bytes are exactly one
        contiguous chunk per block (~n_samples total), vs window/hop
        duplicated copies for host-side framing;
      * the 11-tap FIR runs ONCE over the chunk, frames are cut from the
        filtered chunk by static hop slices, and only the first
        n_taps - 1 columns of each frame are recomputed with frame-local
        zero history, which makes the result bit-identical to filtering
        host-framed windows;
      * stages 2-5 and the HBM writes are shared with `pipeline_kernel`.
    """
    body_ref, tail_refs = refs[0], refs[1: 1 + n_tails]
    i = 1 + n_tails
    (taps_ref, wr_ref, wi_ref, u_ref, w_ref, b_ref, lo_ref, hi_ref,
     ks_ref) = refs[i: i + 9]
    refs_out = dict(zip(outputs, refs[i + 9:]))
    chunk = jnp.concatenate(
        [r[0, :] for r in (body_ref,) + tuple(tail_refs)]
    )[: block_frames * hop + (window - hop)].astype(jnp.float32)
    # --- stage 1: FIR once over the chunk (overlap shared in VMEM) ---
    filt_chunk = _fir_stage(chunk[None, :], taps_ref, n_taps)[0]
    filt = jnp.stack([filt_chunk[r * hop: r * hop + window]
                      for r in range(block_frames)])
    # frame-local FIR transient: the framed reference zero-pads each
    # frame's history, the chunk FIR used real preceding samples — patch
    # the first n_taps-1 columns (the only ones that can differ)
    head = jnp.stack([chunk[r * hop: r * hop + n_taps - 1]
                      for r in range(block_frames)])
    filt = jnp.concatenate([_fir_stage(head, taps_ref, n_taps),
                            filt[:, n_taps - 1:]], axis=1)
    feats = margin = cls = None
    if outputs != ("filtered",):
        feats, margin, cls = _stages_from_filtered(
            filt, wr_ref, wi_ref, u_ref, w_ref, b_ref,
            (lo_ref[...], hi_ref[...], ks_ref[...]), fft_size=fft_size)
    _write_outputs(refs_out, filt, feats, margin, cls)


@functools.partial(jax.jit,
                   static_argnames=("window", "hop", "fft_size", "interpret",
                                    "block_frames", "outputs"))
def pipeline_stream_pallas(signal, taps, w, b, *, window: int, hop: int,
                           fft_size: int = 512, interpret: bool = True,
                           block_frames: int | None = None,
                           outputs: tuple = OUTPUTS):
    """Fused pipeline over a RAW 1-D signal: overlapping (window, hop)
    frames are built inside the kernel, so HBM traffic is ~n_samples
    instead of n_frames*window (§4.2/§4.4.2 single residency). Returns the
    framed `pipeline_pallas` dict over the signal's n_frames frames,
    restricted to `outputs`. Exactly ONE `pallas_call` per call. Compiles
    the biosignal `StageGraph` via `graph.py:graph_stream_call` — the
    in-kernel framing schedule is documented on the frozen legacy body
    `pipeline_stream_kernel` and pinned bit-identical against it.
    """
    outputs = canonical_outputs(outputs)
    graph, operands = _biosignal_graph_operands(taps, w, b, fft_size)
    return graph_stream_call(signal, operands, graph=graph, window=window,
                             hop=hop, interpret=interpret,
                             block_frames=block_frames, outputs=outputs)


# ---------------------------------------------------------------------------
# Ring-chunk kernel: one pallas_call over a ring of raw-signal chunks
# ---------------------------------------------------------------------------

# ring_chunk_samples moved to graph.py (re-exported above).

@functools.partial(jax.jit,
                   static_argnames=("window", "hop", "fft_size", "interpret",
                                    "block_frames", "outputs"))
def pipeline_ring_pallas(ring, taps, w, b, *, window: int, hop: int,
                         fft_size: int = 512, interpret: bool = True,
                         block_frames: int | None = None,
                         outputs: tuple = OUTPUTS):
    """Fused pipeline over a RING of raw-signal chunks in ONE `pallas_call`.

    ``ring`` is `(ring_depth, span)`: each row is one dispatch-sized raw
    chunk (what `pipeline_stream_pallas` takes one at a time — span =
    `ring_chunk_samples(window, hop, batch_windows)` for a
    `batch_windows`-frame slot). The grid is `(ring_depth, n_blocks)`:
    the first axis advances the ring slot, the second reuses the
    in-kernel framing index_maps of the single-chunk stream kernel
    VERBATIM — body BlockSpec `(r, j) -> (r, j)` is block j of slot r's
    hop arithmetic, the `window-hop` tail specs read the same row
    `j*rb + rb + i` hop-blocks ahead, and `pipeline_stream_kernel` is the
    kernel body unchanged. This is the kernel half of the device-resident
    streaming loop (`serve/resident.py`): a whole ring of batches
    advances frame-blocks inside one compiled dispatch, no host round
    trip between slots.

    Returns the `pipeline_stream_pallas` output dict per slot, stacked:
    each value has leading shape `(ring_depth, frames_per_slot)` and row r
    is bit-identical to `pipeline_stream_pallas(ring[r], ...)` — the
    property `tests/test_resident.py` pins. Compiles the biosignal
    `StageGraph` via `graph.py:graph_ring_call`.
    """
    outputs = canonical_outputs(outputs)
    graph, operands = _biosignal_graph_operands(taps, w, b, fft_size)
    return graph_ring_call(ring, operands, graph=graph, window=window,
                           hop=hop, interpret=interpret,
                           block_frames=block_frames, outputs=outputs)

"""Public jit'd API for the shuffle kernel (auto interpret off-TPU)."""
from __future__ import annotations

import jax

from repro.kernels.shuffle.kernel import shuffle_pallas
from repro.kernels.shuffle.ref import shuffle_ref  # noqa: F401


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def shuffle(a, b, op: str, *, half: str = "both", amount: int = 32):
    """VWR2A shuffle-unit op on (R, N) blocks (N = power of two)."""
    return shuffle_pallas(a, b, op=op, half=half, amount=amount,
                          interpret=_interpret())

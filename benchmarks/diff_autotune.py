"""Diff two autotune-winner artifacts (BENCH_autotune.json) across commits.

CI's bench smoke writes the measured block-size winners next to the
BENCH_*.json perf records; this tool compares the current commit's winners
against the previous run's artifact and prints added / removed / changed
entries, so a perf regression that traces back to a different measured
block choice is visible in the job log.

Usage:  python -m benchmarks.diff_autotune OLD.json NEW.json [--strict]

Exit status is 0 unless ``--strict`` is given and winners changed —
winner drift on shared CI runners is expected noise, not a failure.
"""
from __future__ import annotations

import argparse
import json


def _load(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    return {json.dumps(e["key"]): int(e["block_rows"])
            for e in data.get("autotune_winners", [])}


def diff(old: dict, new: dict) -> list[str]:
    lines = []
    for k in sorted(new.keys() - old.keys()):
        lines.append(f"+ {k} -> {new[k]}")
    for k in sorted(old.keys() - new.keys()):
        lines.append(f"- {k} (was {old[k]})")
    for k in sorted(old.keys() & new.keys()):
        if old[k] != new[k]:
            lines.append(f"~ {k}: {old[k]} -> {new[k]}")
    return lines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("old")
    ap.add_argument("new")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero when any winner changed")
    args = ap.parse_args()
    old, new = _load(args.old), _load(args.new)
    lines = diff(old, new)
    if not lines:
        print(f"autotune winners unchanged ({len(new)} entries)")
        return
    print(f"autotune winners changed ({len(old)} -> {len(new)} entries):")
    for line in lines:
        print(" ", line)
    if args.strict:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

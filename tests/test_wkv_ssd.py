"""RWKV6 WKV + Mamba2 SSD: chunked evaluators vs per-token scan oracles,
decode-step continuation, and stability under strong decay."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.mamba import causal_conv1d, ssd_chunked, ssd_scan
from repro.models.rwkv import wkv6_chunked, wkv6_scan, wkv6_step


def _wkv_inputs(rng, B=2, S=32, H=2, K=8, V=8, decay_scale=1.0):
    r = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, K)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, H, V)).astype(np.float32))
    lw = -jnp.exp(jnp.asarray(
        rng.normal(size=(B, S, H, K)).astype(np.float32))) * decay_scale
    u = jnp.asarray(rng.normal(size=(H, K)).astype(np.float32))
    s0 = jnp.asarray(rng.normal(size=(B, H, K, V)).astype(np.float32)) * 0.1
    return r, k, v, lw, u, s0


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_wkv6_chunked_matches_scan(chunk, rng):
    r, k, v, lw, u, s0 = _wkv_inputs(rng)
    o1, sf1 = wkv6_scan(r, k, v, lw, u, s0)
    o2, sf2 = wkv6_chunked(r, k, v, lw, u, s0, chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sf1), np.asarray(sf2),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.1, 30.0))
def test_wkv6_chunked_stable_any_decay(seed, decay_scale):
    """The log-space pairwise form must stay finite for arbitrarily strong
    data-dependent decay (the case that overflows the damped-factor form)."""
    rng = np.random.default_rng(seed)
    r, k, v, lw, u, s0 = _wkv_inputs(rng, decay_scale=decay_scale)
    o, sf = wkv6_chunked(r, k, v, lw, u, s0, 8)
    assert bool(jnp.isfinite(o).all()) and bool(jnp.isfinite(sf).all())
    o1, sf1 = wkv6_scan(r, k, v, lw, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o1), atol=1e-3,
                               rtol=1e-3)


def test_wkv6_decode_continues_scan(rng):
    r, k, v, lw, u, s0 = _wkv_inputs(rng, S=9)
    o_all, s_all = wkv6_scan(r, k, v, lw, u, s0)
    # scan first 8, then one decode step
    o8, s8 = wkv6_scan(r[:, :8], k[:, :8], v[:, :8], lw[:, :8], u, s0)
    o9, s9 = wkv6_step(r[:, 8], k[:, 8], v[:, 8], lw[:, 8], u, s8)
    np.testing.assert_allclose(np.asarray(o9), np.asarray(o_all[:, 8]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s9), np.asarray(s_all), atol=1e-5)


def _ssd_inputs(rng, B=2, S=32, H=3, P=8, N=4):
    xh = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (B, S, H)).astype(np.float32))
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)).astype(np.float32))
    B_ = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    C_ = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    s0 = jnp.asarray(rng.normal(size=(B, H, P, N)).astype(np.float32)) * 0.1
    return xh, dt, A, B_, C_, s0


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_scan(chunk, rng):
    xh, dt, A, B_, C_, s0 = _ssd_inputs(rng)
    y1, sf1 = ssd_scan(xh, dt, A, B_, C_, s0)
    y2, sf2 = ssd_chunked(xh, dt, A, B_, C_, s0, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sf1), np.asarray(sf2),
                               atol=1e-4, rtol=1e-4)


def test_causal_conv1d_matches_numpy(rng):
    x = jnp.asarray(rng.normal(size=(2, 16, 3)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))
    b = jnp.zeros((3,), jnp.float32)
    y, state = causal_conv1d(x, w, b)
    xn = np.asarray(x)
    wn = np.asarray(w)
    for c in range(3):
        # y[t] = sum_i w[i] x[t-(k-1)+i]  (w[k-1] multiplies the current x)
        ref = np.convolve(xn[0, :, c], wn[::-1, c])[:16]
        np.testing.assert_allclose(np.asarray(y[0, :, c]), ref, atol=1e-5)
    # state == last k-1 inputs
    np.testing.assert_allclose(np.asarray(state), np.asarray(x[:, -3:, :]))


def test_causal_conv1d_streaming_equivalence(rng):
    """Block-by-block with state == one shot (the prefill->decode handoff)."""
    x = jnp.asarray(rng.normal(size=(1, 24, 2)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(4, 2)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(2,)).astype(np.float32))
    y_full, _ = causal_conv1d(x, w, b)
    state = None
    outs = []
    for i in range(0, 24, 8):
        y, state = causal_conv1d(x[:, i:i + 8], w, b, state=state)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(y_full), atol=1e-6)

"""Logical-axis -> mesh-axis sharding rules.

Each parameter/cache/input tensor carries a tuple of logical axis names (see
models/layers.py). A Strategy maps those names to mesh axes with
divisibility-aware fallbacks, producing NamedShardings for pjit.

Train strategy (FSDP x TP, DP over pod+data):
    batch -> (pod, data);  heads/kv_heads/vocab/mlp/experts -> model (TP/EP);
    embed -> data (ZeRO-3 parameter sharding, gathered per-layer inside the
    scan over layers);  layers/head_dim/state/... -> replicated.

Serve strategy (TP only, weights replicated across data for low-latency):
    batch -> (pod, data);  heads/... -> model;  cache seq -> model when the
    kv-head count does not divide the TP degree (sequence-sharded KV cache =
    flash-decoding layout), or -> data when batch cannot use it (long_500k).

Uneven dims (e.g. 56 heads over 16-way model axis) are allowed on weight-
like axes — XLA SPMD pads internally; the padding waste is accounted in the
roofline "useful-FLOPs" ratio. Batch/seq axes require exact divisibility.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import NamedSharding, PartitionSpec


# logical name -> ordered candidate lists of mesh-axis groups
_TRAIN_CANDIDATES = {
    "batch": [("pod", "data"), ("data",), ("pod",)],
    "vocab": [("model",)],
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "mlp": [("model",)],
    "experts": [("model",)],
    "embed": [("data",)],          # FSDP / ZeRO-3
    "seq": [],
    "expert_mlp": [],
    "layers": [], "head_dim": [], "conv": [], "state": [], "pos": [],
}

_SERVE_CANDIDATES = {
    **_TRAIN_CANDIDATES,
    "embed": [],                   # weights replicated across data when serving
    "seq": [("model",), ("data",), ("pod",)],  # cache fallback (flash-decode)
}

# Pure-FSDP (ZeRO-3) layout: batch over EVERY axis, weights fully sharded
# for storage and all-gathered per layer (XLA inserts the AG when the
# batch-everywhere activation constraint meets sharded weights). Trades the
# per-layer Megatron activation all-reduce (2x tokens x d_model) for a
# per-layer weight all-gather (layer params, overlappable) — the better deal
# whenever tokens/device x 16 > params/layer, i.e. for all train_4k cells.
_FSDP_CANDIDATES = {
    "batch": [("pod", "data", "model"), ("data", "model"), ("data",)],
    "vocab": [("model",)],
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "mlp": [("model",)],
    "experts": [("model",)],
    "embed": [("data",)],
    "seq": [],
    "expert_mlp": [],
    "layers": [], "head_dim": [], "conv": [], "state": [], "pos": [],
}

# pjit requires argument dims to divide the mesh axis exactly; dims that
# don't (e.g. whisper's 51865 vocab) fall through to the next candidate or
# replication. Query-head counts are made divisible by grouped padding in
# models/attention.py (cfg.tp_pad).
_ALLOW_UNEVEN: set = set()

# assignment priority: lower = assigned first (gets first pick of mesh axes)
_PRIORITY = {"batch": 0, "vocab": 1, "heads": 1, "kv_heads": 1, "mlp": 1,
             "experts": 1, "seq": 2, "embed": 3}


@dataclasses.dataclass(frozen=True)
class Strategy:
    name: str = "train"            # train | serve

    def candidates(self):
        return {"train": _TRAIN_CANDIDATES,
                "serve": _SERVE_CANDIDATES,
                "fsdp": _FSDP_CANDIDATES,
                "serve_fsdp": _FSDP_CANDIDATES}[self.name]


def spec_for(axes, shape, mesh, strategy: Strategy) -> PartitionSpec:
    """Greedy divisibility-aware assignment of mesh axes to tensor dims."""
    return _spec(axes, shape, mesh, strategy)


def _spec(axes, shape, mesh, strategy):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    cands = strategy.candidates()
    order = sorted([i for i, n in enumerate(axes) if n],
                   key=lambda i: _PRIORITY.get(axes[i], 9))
    entries: dict[int, tuple] = {}
    used: set = set()
    for i in order:
        name = axes[i]
        for group in cands.get(name, []):
            if any(a not in sizes or a in used for a in group):
                continue
            prod = 1
            for a in group:
                prod *= sizes[a]
            if shape[i] < prod:
                continue
            if shape[i] % prod != 0 and name not in _ALLOW_UNEVEN:
                continue
            entries[i] = group
            used.update(group)
            break
    parts = []
    for i in range(len(axes)):
        if i not in entries:
            parts.append(None)
        elif len(entries[i]) == 1:
            parts.append(entries[i][0])
        else:
            parts.append(entries[i])
    return PartitionSpec(*parts)


def sharding_tree(schema_axes, abstract_tree, mesh, strategy: Strategy):
    """axes pytree (tuples) + ShapeDtypeStruct pytree -> NamedSharding pytree."""
    def one(axes, sds):
        return NamedSharding(mesh, _spec(axes, sds.shape, mesh, strategy))

    return jax.tree.map(
        one, schema_axes, abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec())


def batch_sharding(mesh, strategy: Strategy, *, ndim: int, batch_divisible: bool):
    """Sharding for a (B, ...) input tensor: batch over (pod,data) if it
    divides, else replicated."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for group in strategy.candidates()["batch"]:
        if all(a in sizes for a in group):
            spec = [group if len(group) > 1 else group[0]] + [None] * (ndim - 1)
            return NamedSharding(mesh, PartitionSpec(*spec)) if batch_divisible \
                else replicated(mesh)
    return replicated(mesh)

"""rwkv6-7b (Finch) [arXiv:2404.05892; hf] — attention-free, data-dependent
decay. head_size 64 => 64 heads at d_model 4096. channel-mix d_ff = 14336."""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,          # wkv heads = d_model / head_size
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    head_dim=64,
    rope_style="none",
    norm_type="layernorm",
    ssm=SSMConfig(kind="rwkv6", head_size=64, chunk_size=64, lora_rank=64),
    source="arXiv:2404.05892; hf:RWKV/rwkv-6-world-7b",
))

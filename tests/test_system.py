"""End-to-end behaviour tests: train -> checkpoint -> crash -> resume ->
serve, the serving engine, and a one-cell dry-run (subprocess, 512 forced
host devices)."""
import dataclasses
import json
import os
import shutil
import subprocess
import sys
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.serve.engine import Engine, Request
from repro.train import optim
from repro.train.loop import LoopConfig, train
from repro.train.step import init_state, make_train_step

ROOT = Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = dataclasses.replace(reduced(get_config("qwen1.5-0.5b")),
                              vocab_size=64)
    model = build_model(cfg)
    mesh = make_local_mesh(data=1, model=1)
    dc = DataConfig(vocab_size=64, seq_len=64, global_batch=4, structure=7)
    oc = optim.OptConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    abstract = {"tokens": jax.ShapeDtypeStruct((4, 64), np.int32),
                "labels": jax.ShapeDtypeStruct((4, 64), np.int32)}
    with mesh:
        bundle = make_train_step(model, oc, mesh, abstract)
        yield cfg, model, mesh, dc, oc, bundle


@pytest.mark.slow
def test_train_loss_decreases_and_resumes(tiny_setup):
    cfg, model, mesh, dc, oc, bundle = tiny_setup
    d = tempfile.mkdtemp()
    try:
        with mesh:
            state = init_state(model, oc)
            lc = LoopConfig(n_steps=20, ckpt_every=10, ckpt_dir=d,
                            log_every=5, async_ckpt=False)
            state, hist = train(model, bundle, dc, lc, state, log=None)
            assert hist[-1]["loss"] < hist[0]["loss"]
            # simulate a crash: resume from checkpoint, train further
            lc2 = LoopConfig(n_steps=30, ckpt_every=10, ckpt_dir=d,
                             log_every=5, async_ckpt=False)
            state2, hist2 = train(model, bundle, dc, lc2, None, log=None)
            assert hist2[-1]["step"] == 30
            assert hist2[-1]["loss"] < hist[-1]["loss"] + 0.5
    finally:
        shutil.rmtree(d)


def test_determinism_same_seed(tiny_setup):
    cfg, model, mesh, dc, oc, bundle = tiny_setup
    with mesh:
        losses = []
        for _ in range(2):
            state = init_state(model, oc, seed=3)
            lc = LoopConfig(n_steps=5, ckpt_every=0, log_every=5)
            _, hist = train(model, bundle, dc, lc, state, log=None)
            losses.append(hist[-1]["loss"])
    assert losses[0] == losses[1]


def test_engine_continuous_batching(tiny_setup):
    cfg, model, mesh, dc, oc, bundle = tiny_setup
    from repro.models import init_model_params

    params = init_model_params(model)
    eng = Engine(model, params, slots=2, max_len=64)
    for rid in range(4):                      # more requests than slots
        eng.submit(Request(rid, [1 + rid, 2 + rid], max_new=4))
    done = eng.run_to_completion()
    assert len(done) == 4
    assert all(len(r.out) == 4 for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.out)


def test_engine_temperature_sampling(tiny_setup):
    """Batched categorical sampling path: one key split per step, all
    slots sampled together."""
    cfg, model, mesh, dc, oc, bundle = tiny_setup
    from repro.models import init_model_params

    params = init_model_params(model)
    eng = Engine(model, params, slots=2, max_len=64, temperature=1.0, seed=7)
    for rid in range(3):
        eng.submit(Request(rid, [1 + rid, 2], max_new=3))
    done = eng.run_to_completion()
    assert len(done) == 3
    assert all(len(r.out) == 3 for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.out)


def test_engine_bucketed_batch_admission(tiny_setup):
    """Bursty mixed-length admission runs ONE padded prefill per prompt-
    length bucket (not one per request), and the merge semantics are
    unchanged: every request's greedy continuation equals argmax over
    model.forward on its own sequence."""
    cfg, model, mesh, dc, oc, bundle = tiny_setup
    from repro.models import init_model_params

    params = init_model_params(model, seed=2)
    prompts = [[3, 1], [7, 2], [4, 1, 5], [9, 2, 6, 5, 3]]
    eng = Engine(model, params, slots=4, max_len=64)
    prefill_calls = []
    real_prefill = eng._prefill
    eng._prefill = lambda *a, **kw: (prefill_calls.append(
        a[1]["tokens"].shape), real_prefill(*a, **kw))[1]
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid, p, max_new=3))
    done = {r.rid: r.out for r in eng.run_to_completion()}
    # buckets: len 2 (x2 requests), len 3 -> 4, len 5 -> 8; all admitted in
    # the first step => exactly 3 prefill dispatches for 4 requests
    assert len(prefill_calls) == 3, prefill_calls
    assert sorted(w for _, w in prefill_calls) == [2, 4, 8], prefill_calls
    for rid, prompt in enumerate(prompts):
        seq = list(prompt)
        for _ in range(3):
            logits, _ = model.forward(params, {
                "tokens": jnp.asarray([seq], jnp.int32)})
            seq.append(int(jnp.argmax(logits[0, -1])))
        assert done[rid] == seq[len(prompt):], rid


def test_engine_admission_bucket_capped_at_max_len(tiny_setup):
    """A prompt whose next-pow2 bucket exceeds max_len must still admit
    (the bucket is capped at the cache length)."""
    cfg, model, mesh, dc, oc, bundle = tiny_setup
    from repro.models import init_model_params

    params = init_model_params(model)
    eng = Engine(model, params, slots=2, max_len=12)
    eng.submit(Request(0, list(range(1, 10)), max_new=2))   # len 9 -> 16>12
    done = eng.run_to_completion()
    assert len(done) == 1 and len(done[0].out) == 2


def test_engine_rejects_oversized_prompt_typed(tiny_setup):
    """A prompt longer than max_len is rejected at submit() with the
    typed PromptTooLong — previously it crashed `_admit` with a raw
    NumPy broadcast ValueError mid-batch, wedging the whole admission
    bucket it shared with valid requests."""
    from repro.models import init_model_params
    from repro.serve.engine import PromptTooLong

    cfg, model, mesh, dc, oc, bundle = tiny_setup
    params = init_model_params(model)
    eng = Engine(model, params, slots=2, max_len=8)
    with pytest.raises(PromptTooLong) as ei:
        eng.submit(Request(0, list(range(1, 11)), max_new=2))   # len 10 > 8
    assert ei.value.rid == 0 and ei.value.n_tokens == 10
    assert ei.value.max_len == 8
    # the queue is untouched: a valid co-tenant still serves normally
    eng.submit(Request(1, [1, 2, 3], max_new=2))
    done = eng.run_to_completion()
    assert [r.rid for r in done] == [1] and len(done[0].out) == 2


def test_engine_stall_raises_typed_with_unfinished_rids(tiny_setup):
    """Exhausting max_steps with work still pending raises EngineStalled
    naming the unfinished rids (and carrying the finished subset) —
    previously run_to_completion silently returned only the finished
    requests and dropped the rest."""
    from repro.models import init_model_params
    from repro.serve.engine import EngineStalled

    cfg, model, mesh, dc, oc, bundle = tiny_setup
    params = init_model_params(model)
    eng = Engine(model, params, slots=1, max_len=64)
    eng.submit(Request(0, [1, 2], max_new=2))
    eng.submit(Request(1, [3, 4], max_new=30))
    with pytest.raises(EngineStalled) as ei:
        eng.run_to_completion(max_steps=4)
    assert ei.value.unfinished == [1]
    assert [r.rid for r in ei.value.done] == [0]


@pytest.mark.slow
def test_engine_matches_batch_decode(tiny_setup):
    """Engine greedy decode == argmax over model.forward continuation."""
    cfg, model, mesh, dc, oc, bundle = tiny_setup
    from repro.models import init_model_params

    params = init_model_params(model, seed=1)
    prompt = [3, 1, 4, 1, 5]
    eng = Engine(model, params, slots=1, max_len=64)
    eng.submit(Request(0, prompt, max_new=3))
    out = eng.run_to_completion()[0].out

    seq = list(prompt)
    for _ in range(3):
        logits, _ = model.forward(params, {
            "tokens": jnp.asarray([seq], jnp.int32)})
        seq.append(int(jnp.argmax(logits[0, -1])))
    assert out == seq[len(prompt):]


@pytest.mark.slow
def test_dryrun_one_cell_subprocess(tmp_path):
    """The real multi-pod dry-run path: 512 forced host devices, production
    mesh, lower+compile+roofline record for one cell."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "qwen1.5-0.5b", "--shape", "decode_32k", "--mesh", "single",
         "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads((tmp_path / "qwen1.5-0.5b__decode_32k__single.json"
                      ).read_text())
    assert rec["status"] == "ok"
    assert rec["devices"] == 256
    assert rec["hlo_cost"]["flops"] > 0

"""VWR2A (DAC '22) reproduced and scaled: JAX + Pallas framework.

See DESIGN.md for the architecture map and EXPERIMENTS.md for results.
"""

"""AdamW with dtype-configurable distributed state (built from scratch).

Distributed-optimization features:
  * optimizer states inherit the parameter sharding (ZeRO-style: with FSDP
    params the full optimizer state is sharded over the data axis),
  * first moment storable in bf16, second moment storable in block-scaled
    int8 (qint8) — needed to fit the 400B MoE config in 16 GiB/chip HBM,
  * global-norm clipping, linear-warmup + cosine schedule, decoupled WD.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    m_dtype: Any = jnp.float32          # or jnp.bfloat16
    v_dtype: Any = jnp.float32          # or "qint8"
    q_block: int = 128                  # int8 quantization block (last dim)


# ---------------------------------------------------------------------------
# Block-scaled int8 storage for the (non-negative) second moment
# ---------------------------------------------------------------------------

def _q8_encode(x: jax.Array, block: int):
    """x >= 0, any shape. Per-(last-dim block) scale; returns (q, scale)."""
    orig = x.shape
    last = orig[-1] if orig else 1
    b = min(block, max(1, last))
    pad = (-last) % b
    xp = jnp.pad(x.reshape(-1, last), ((0, 0), (0, pad)))
    xb = xp.reshape(xp.shape[0], -1, b)
    scale = jnp.max(xb, axis=-1, keepdims=True) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(xb / scale), 0, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _q8_decode(q, scale, shape):
    x = (q.astype(jnp.float32) * scale).reshape(q.shape[0], -1)
    last = shape[-1] if shape else 1
    return x[:, :last].reshape(shape)


def _v_init(p, cfg: OptConfig):
    if cfg.v_dtype == "qint8":
        q, s = _q8_encode(jnp.zeros(p.shape, jnp.float32), cfg.q_block)
        return {"q": q, "scale": s}
    return jnp.zeros(p.shape, cfg.v_dtype)


def _v_load(v, shape, cfg: OptConfig):
    if cfg.v_dtype == "qint8":
        return _q8_decode(v["q"], v["scale"], shape)
    return v.astype(jnp.float32)


def _v_store(v32, cfg: OptConfig):
    if cfg.v_dtype == "qint8":
        q, s = _q8_encode(v32, cfg.q_block)
        return {"q": q, "scale": s}
    return v32.astype(cfg.v_dtype)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def init_opt_state(params, cfg: OptConfig):
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.m_dtype), params),
        "v": jax.tree.map(lambda p: _v_init(p, cfg), params),
        "count": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params, cfg: OptConfig):
    return jax.eval_shape(lambda p: init_opt_state(p, cfg), abstract_params)


def schedule(step, cfg: OptConfig):
    warm = jnp.minimum(step / max(1, cfg.warmup_steps), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(grads, opt_state, params, cfg: OptConfig):
    """Returns (new_params, new_opt_state, stats)."""
    count = opt_state["count"] + 1
    lr = schedule(count, cfg)
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    bc1 = 1 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * _v_load(v, p.shape, cfg) + (1 - cfg.b2) * jnp.square(g)
        step_dir = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # decay matrices, not gains/biases
            step_dir = step_dir + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step_dir).astype(p.dtype)
        return new_p, m32.astype(cfg.m_dtype), _v_store(v32, cfg)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "count": count}, {
        "grad_norm": gn, "lr": lr}

"""Batched serving engine: continuous-batching decode over fixed slots.

Requests occupy slots of a fixed-capacity batch; each engine step decodes
one token for every live slot (one jit'd decode_fn call — padding slots
ride along). Prefill fills a slot's cache region. Greedy or temperature
sampling. The same engine drives the serve_lm example and the serving
integration tests.

SAMPLING IS A PER-REQUEST STREAM, not a shared sequential one: token t of
request ``rid`` is drawn from ``fold_in(fold_in(PRNGKey(seed), rid), t)``
(`_sample_per_request`). A shared split-per-engine-step key would make a
request's tokens depend on unrelated traffic interleaving — admission
order, co-tenants, slot placement — so an evicted request could never be
REPLAYED bit-identically. With per-request streams a request's output is
a pure function of (engine seed, rid, prompt, model), which is the
invariant the fault-tolerant supervision layer
(`serve/engine_fault.py:FaultTolerantEngine`) rests on: kill a slot
mid-decode, re-prefill prompt + generated prefix elsewhere, and the
continuation is bit-identical (property-tested in
`tests/test_engine_determinism.py`).

The dispatch path is factored into overridable hooks (`_admissible`,
`_pre_dispatch_prefill`, `_prefill_dispatch`, `_decode_dispatch`,
`_slot_retires`, `_on_retire`, `_on_finish`) so the supervision layer can
inject faults, heartbeats, and eviction without duplicating the
batching/bucketing logic. Typed errors at the admission boundary:
`PromptTooLong` (a prompt the cache cannot hold is rejected at `submit`,
never mid-bucket), `EngineStalled` (`run_to_completion` exhausted
``max_steps`` with work still queued/live — carries the unfinished rids
instead of silently dropping them).

`ColumnScheduler` is the admission policy for the OTHER traffic class the
repo serves — continuous biosignal streams: independent streams are placed
on distinct column replicas (devices), the multi-tenant complement of
sharding one stream across all columns (`StreamConfig.n_columns`). With a
`StreamTelemetry` attached it is load-aware: placement by least MEASURED
windows/s (stream count is only the cold-start fallback), a `rebalance`
work-stealing pass that re-pins streams when the max/min column-load
ratio blows a threshold, and `deal_weights` feeding measured per-column
rates into the non-uniform frame deal.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_cache
from repro.runtime.fault import (HeartbeatMonitor, InsufficientHealthyWorkers,
                                 StragglerDetector)
# the typed errors live in the serve/errors.py taxonomy (ServeError
# root) and are re-exported from here, their historical home
from repro.serve.errors import (EngineStalled, InsufficientPages,
                                PagedCacheUnsupported,  # noqa: F401 (re-export)
                                PromptTooLong)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # set by the supervision layer when the request was evicted from a
    # faulty slot and requeued for replay (serve/engine_fault.py)
    replayed: bool = False


@functools.partial(jax.jit, static_argnums=(3,))
def _merge_cache_leaves(old_leaves, new_leaves, mask, axes):
    """Slot-masked cache merge, one fused jit call for the whole tree.

    ``mask`` is a (slots,) bool vector of admitted slots; ``axes`` the
    per-leaf slot-axis indices (static — read off the cache schema's
    named "batch" axis, see `Engine.__init__`). A mask instead of an
    index list keeps the trace shape fixed across admission patterns, so
    every engine sharing a cache shape reuses ONE compilation — the
    eager per-leaf gather/scatter this replaces dominated admission
    wall time (~6ms per merge on CPU for a 2-leaf cache)."""
    out = []
    for ax, old, new in zip(axes, old_leaves, new_leaves):
        shape = [1] * old.ndim
        shape[ax] = old.shape[ax]
        out.append(jnp.where(mask.reshape(shape), new, old))
    return out


@jax.jit
def _sample_per_request(base_key, rids, steps, logits):
    """Batched per-request-stream categorical sample.

    Slot s draws from ``fold_in(fold_in(base_key, rids[s]), steps[s])``
    where ``steps[s]`` is the token's index WITHIN its request — the key
    depends only on (engine seed, rid, step), never on which slot the
    request occupies, what else is in flight, or how many engine steps
    have passed. That placement-invariance is what makes evicted-request
    replay bit-identical (`serve/engine_fault.py`). Callers divide the
    logits by temperature; dead slots ride along and are ignored."""
    def one(rid, step, lg):
        k = jax.random.fold_in(jax.random.fold_in(base_key, rid), step)
        return jax.random.categorical(k, lg)
    return jax.vmap(one)(rids, steps, logits)


class Engine:
    def __init__(self, model, params, *, slots: int = 4, max_len: int = 256,
                 temperature: float = 0.0, seed: int = 0, compiled=None):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.cache = init_cache(model, slots, max_len)
        self.live: list[Optional[Request]] = [None] * slots
        self.lens = np.zeros(slots, np.int32)
        self.queue: list[Request] = []
        # poisoned slots: masked out of admission, populated by the
        # supervision layer (serve/engine_fault.py); the base engine
        # never adds to it
        self.dead_slots: set[int] = set()
        self.base_key = jax.random.PRNGKey(seed)
        # per-leaf index of the SLOT axis, read from the cache schema's
        # named axes ("batch") — `_merge_slots` must not guess it from
        # shapes: a stacked-layer leaf is (layers, slots, ...) and with
        # n_layers == slots a shape probe picks the layer axis and merges
        # the wrong rows (zeroing live layers for partially-admitted
        # batches — placement-dependent logits)
        axis_tree = jax.tree.map(
            lambda p: p.axes.index("batch"),
            model.cache_schema(slots, max_len),
            is_leaf=lambda x: hasattr(x, "axes"))
        self._slot_axes = tuple(jax.tree.flatten(axis_tree)[0])
        # `compiled` shares one jitted (prefill, decode) pair across many
        # engine instances over the same model (tests/benches rebuild
        # engines per scenario; a fresh jax.jit wrapper per instance
        # would recompile every time) — see `compile_model`
        self._prefill, self._decode = (compiled if compiled is not None
                                       else self.compile_model(model))

    @staticmethod
    def compile_model(model):
        """One jitted (prefill, decode) pair, shareable across engines
        via ``Engine(..., compiled=...)``."""
        return jax.jit(model.prefill), jax.jit(model.decode)

    def add_request(self, req: Request):
        """Enqueue one request for admission (the canonical entry point;
        `serve/frontend.py:ServeFrontend.submit` routes LM work here).
        Raises the typed `PromptTooLong` for a prompt the cache cannot
        hold."""
        if len(req.prompt) > self.max_len:
            raise PromptTooLong(req.rid, len(req.prompt), self.max_len)
        self.queue.append(req)

    def submit(self, req: Request, **kwargs):
        """Deprecated alias of `add_request` — the session API is
        `serve/frontend.py:ServeFrontend.submit`, which fronts both
        traffic classes behind one queue. Thin shim; dispatches through
        ``self.add_request`` so subclass overrides (TTL-aware admission,
        page-bounded admission) apply."""
        warnings.warn(
            "Engine.submit is deprecated; use ServeFrontend.submit "
            "(unified admission) or Engine.add_request",
            DeprecationWarning, stacklevel=2)
        return self.add_request(req, **kwargs)

    def _length_bucket(self, n: int) -> int:
        """Pad prompt lengths up to the next power of two so bursty mixed-
        length traffic funnels into a handful of prefill trace shapes —
        capped at max_len: the cache has no rows past it, and a valid
        prompt of length <= max_len must not be padded beyond it."""
        return min(1 << max(n - 1, 0).bit_length(), self.max_len)

    def _admissible(self, s: int) -> bool:
        """Is slot ``s`` a legal admission target? Free AND not poisoned
        (the supervision layer masks faulty slots via ``dead_slots``)."""
        return self.live[s] is None and s not in self.dead_slots

    def _pad_ok(self) -> bool:
        """Is right-padding a prompt safe for this model's cache?

        Safe for LINEAR causal-attention caches (pad positions only
        write K/V beyond the prompt, which decode masks via cache_len
        and overwrites before it becomes visible), but NOT for recurrent
        state (every consumed token mutates it) nor for sliding-window
        RING caches (the kept k[-W:] tail and the slot rotation are
        computed from the padded length, so pad keys evict real prompt
        keys) — those bucket by exact length instead."""
        cfg = self.model.cfg
        return (getattr(cfg, "ssm", None) is None and
                getattr(cfg, "sliding_window", None) is None)

    def _work_pending(self) -> bool:
        """Unfinished work anywhere in the engine (queued or live; the
        paged engine adds its admitted-but-laneless set)."""
        return bool(self.queue) or any(r is not None for r in self.live)

    def _pending_rids(self) -> set:
        return ({r.rid for r in self.queue} |
                {r.rid for r in self.live if r is not None})

    def _pre_dispatch_prefill(self, admitted: list) -> list:
        """Hook called with the claimed ``(slot, request)`` pairs before
        any prefill dispatch; returns the pairs that actually prefill.
        The supervision layer injects prefill faults here."""
        return admitted

    def _prefill_dispatch(self, batch):
        """One prefill dispatch — the supervision layer wraps this in
        transient-fault retry."""
        return self._prefill(self.params, batch, self.cache)

    def _admit(self):
        # claim every free slot first, then admit them in as few prefill
        # dispatches as possible (one per prompt-length bucket) — under
        # bursty load the seed's request-at-a-time admission paid one
        # dispatch per request. A REPLAYED request (evicted from a faulty
        # slot) prefills its prompt + already-generated prefix in one
        # dispatch; for a fresh request `out` is empty and the sequence
        # is just the prompt.
        admitted = []
        for s in range(self.slots):
            if self._admissible(s) and self.queue:
                req = self.queue.pop(0)
                self.live[s] = req
                admitted.append((s, req))
        if not admitted:
            return
        admitted = self._pre_dispatch_prefill(admitted)
        if not admitted:
            return
        if getattr(self.model.cfg, "is_encdec", False):
            # enc-dec decoders have no engine-supplied encoder frames:
            # prefill mode would run _encode, so keep the token-at-a-time
            # decode-mode admission for them
            for s, req in admitted:
                seq = req.prompt + req.out
                for t, tok in enumerate(seq):
                    batch = {"tokens": jnp.full((self.slots, 1), tok,
                                                jnp.int32),
                             "cache_len": jnp.asarray(t, jnp.int32)}
                    _, cache = self._decode(self.params, batch, self.cache)
                    self.cache = self._merge_slots(cache, [s])
                self.lens[s] = len(seq)
            return
        pad_ok = self._pad_ok()
        buckets: dict[int, list] = {}
        for s, req in admitted:
            n = len(req.prompt) + len(req.out)
            buckets.setdefault(self._length_bucket(n) if pad_ok else n,
                               []).append((s, req))
        for width, group in sorted(buckets.items()):
            # one padded prefill for the whole bucket: every admitted
            # slot's prompt K/V written in a single dispatch; the cache
            # merge keeps only the group's rows (identical semantics to
            # per-request admission, len(group)x fewer dispatches)
            tokens = np.zeros((self.slots, width), np.int32)
            for s, req in group:
                seq = req.prompt + req.out
                tokens[s, : len(seq)] = seq
            _, cache = self._prefill_dispatch(
                {"tokens": jnp.asarray(tokens)})
            self.cache = self._merge_slots(cache, [s for s, _ in group])
            for s, req in group:
                self.lens[s] = len(req.prompt) + len(req.out)

    def _merge_slots(self, new_cache, slots: list):
        # admission updates every slot's cache row; keep only the admitted
        # `slots` rows from the new cache. The slot axis per leaf comes
        # from the cache schema's named "batch" axis (`self._slot_axes`),
        # never from shape probing — see __init__ and
        # `_merge_cache_leaves` for why axis and mask work the way they do.
        mask = np.zeros(self.slots, bool)
        mask[np.asarray(slots)] = True
        old_leaves, treedef = jax.tree.flatten(self.cache)
        new_leaves = jax.tree.flatten(new_cache)[0]
        merged = _merge_cache_leaves(old_leaves, new_leaves,
                                     jnp.asarray(mask), self._slot_axes)
        return jax.tree.unflatten(treedef, merged)

    def _decode_dispatch(self, batch):
        """One batched decode dispatch for all slots — the supervision
        layer injects per-slot decode faults and transient retry here."""
        return self._decode(self.params, batch, self.cache)

    def _slot_retires(self, s: int) -> bool:
        """Does slot ``s`` retire its sampled token this step? The
        supervision layer masks hung slots (no retire, no heartbeat)."""
        return True

    def _on_retire(self, s: int, req: Request) -> None:
        """Hook after slot ``s`` retires one token (heartbeat source)."""

    def _on_finish(self, s: int, req: Request) -> None:
        """Hook after ``req`` completes and frees slot ``s``."""

    def _on_evict(self, req: Request) -> None:
        """Hook when the supervision layer evicts ``req`` from a faulty
        slot, BEFORE it requeues (the paged engine frees its pages here
        so the replay re-admits against fresh ones)."""

    def step(self):
        """One decode step for all live slots; returns finished requests."""
        self._admit()
        live_mask = np.array([r is not None for r in self.live])
        if not live_mask.any():
            return []
        last_tokens = np.zeros((self.slots, 1), np.int32)
        rids = np.zeros(self.slots, np.int32)
        steps = np.zeros(self.slots, np.int32)
        for s, r in enumerate(self.live):
            if r is not None:
                seq = r.prompt + r.out
                last_tokens[s, 0] = seq[-1]
                rids[s] = r.rid
                steps[s] = len(r.out)
        # per-slot positions (continuous batching): slot s's last token sits
        # at index lens[s]-1; dead slots park at 0 (overwritten on admit)
        cl = np.maximum(self.lens - 1, 0).astype(np.int32)
        batch = {"tokens": jnp.asarray(last_tokens),
                 "cache_len": jnp.asarray(cl)}
        logits, self.cache = self._decode_dispatch(batch)
        # one batched sample over ALL slots (dead slots ride along and
        # are ignored below), each slot on its request's OWN key stream —
        # see `_sample_per_request` for why this is the replay enabler
        if self.temperature > 0:
            sampled = np.asarray(_sample_per_request(
                self.base_key, jnp.asarray(rids), jnp.asarray(steps),
                logits[:, 0, :] / self.temperature))
        else:
            sampled = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        finished = []
        for s, r in enumerate(self.live):
            if r is None or not self._slot_retires(s):
                continue
            tok = int(sampled[s])
            r.out.append(tok)
            self.lens[s] += 1
            self._on_retire(s, r)
            if len(r.out) >= r.max_new or self.lens[s] >= self.max_len - 1:
                r.done = True
                finished.append(r)
                self.live[s] = None
                self.lens[s] = 0
                self._on_finish(s, r)
        return finished

    def run_to_completion(self, max_steps: int = 10_000):
        """Step until every submitted request finishes; the finished
        requests are returned. Exhausting ``max_steps`` with work still
        queued/live raises the typed `EngineStalled` (carrying the
        unfinished rids and the done subset) instead of silently
        returning only what happened to finish."""
        done = []
        for _ in range(max_steps):
            done += self.step()
            if not self._work_pending():
                return done
        if not self._work_pending():
            return done
        raise EngineStalled(sorted(self._pending_rids()), done=done)


class PagedEngine(Engine):
    """`Engine` with a paged KV cache: ADMISSION IS BOUNDED BY FREE
    PAGES, not by ``slots``.

    The dense engine's per-slot caches reserve ``max_len`` rows per slot
    whether a request uses them or not, and the slot count doubles as
    the admission bound. Here every request's K/V lives in fixed-size
    pages of one preallocated pool (`serve/paged.py`: the SPM-bank
    analogue — one physical memory, time-shared through a block table),
    so:

    * ``slots`` becomes just the DECODE LANE count (the batch width of
      one decode dispatch). Admission pulls from the queue while the
      free-page count covers a request's worst-case footprint
      (``ceil(min(len + max_new, max_len) / page_size)`` pages, the max
      over cache leaves — a ring leaf never needs more than its W
      slots). Admitted requests beyond the lane count wait PREFILLED in
      ``paused``; when a lane frees, the refill is a block-table row
      swap — no prefill, no cache copy. With the default pool size
      (the dense engine's exact memory: ``slots * ceil(max_len /
      page_size)`` pages) short requests oversubscribe the lanes —
      ``peak_admitted`` > ``slots`` — which is the whole point.
    * prefill and decode read/write THROUGH the block table
      (`serve.paged.paged_prefill` / `paged_decode`, one fused dispatch
      each — same dispatch count as dense); the dense `_merge_slots`
      masked merge collapses into page assignment.
    * decode attends over each lane's ALLOCATED span instead of
      ``max_len`` — the paged compute saving the ``--check-paged``
      bench gate holds (`docs/BENCHMARKS.md`) — and masked positions
      contribute exactly zero, so output is BIT-identical to the dense
      path for greedy and temperature sampling alike
      (`tests/test_paged.py`).

    Models whose cache cannot be paged (recurrent state, enc-dec) raise
    the typed `PagedCacheUnsupported` at construction; a request whose
    footprint exceeds the POOL raises `InsufficientPages` at admission.
    The supervision layer stacks on top unchanged
    (`serve/engine_fault.py:FaultTolerantPagedEngine`)."""

    def __init__(self, model, params, *, slots: int = 4, max_len: int = 256,
                 temperature: float = 0.0, seed: int = 0, compiled=None,
                 page_size: int = 16, n_pages: Optional[int] = None):
        from repro.serve import paged as paged_mod
        self._paged = paged_mod
        if n_pages is None:
            # the dense engine's exact K/V memory, repartitioned into
            # pages (+1 for scratch): oversubscription comes from
            # requests shorter than max_len, not from extra memory
            n_pages = slots * (-(-max_len // page_size)) + 1
        self.pool = paged_mod.PagePool(model, page_size=page_size,
                                       n_pages=n_pages, max_len=max_len)
        self.table = paged_mod.PageTable(self.pool)
        super().__init__(model, params, slots=slots, max_len=max_len,
                         temperature=temperature, seed=seed,
                         compiled=compiled)
        self.cache = None        # every K/V row lives in the pool
        # admitted (pages held, prefilled) but waiting for a free lane
        self.paused: list[Request] = []
        self.peak_admitted = 0   # max concurrent admissions observed

    # ------------------------------------------------------- admission

    def _pages_for(self, req: Request) -> int:
        total = min(len(req.prompt) + len(req.out) + req.max_new,
                    self.max_len)
        return self.pool.pages_for(total)

    def add_request(self, req: Request):
        """Page-aware admission bound: a request whose worst-case
        footprint can NEVER fit the pool is rejected with the typed
        `InsufficientPages` (the paged twin of `PromptTooLong`); one
        that merely exceeds the current free count waits in the queue
        for pages to free."""
        need = self._pages_for(req)
        if need > self.pool.capacity:
            raise InsufficientPages(need, self.pool.n_free,
                                    self.pool.capacity)
        super().add_request(req)

    def _work_pending(self) -> bool:
        return bool(self.paused) or super()._work_pending()

    def _pending_rids(self) -> set:
        return super()._pending_rids() | {r.rid for r in self.paused}

    def _admit(self):
        # 1. refill free lanes from the paused set first: their K/V is
        # already paged in, so the "prefill" is a block-table row swap
        for s in range(self.slots):
            if not self.paused:
                break
            if self._admissible(s):
                req = self.paused.pop(0)
                self.live[s] = req
                self.lens[s] = len(req.prompt) + len(req.out)
        # 2. admit from the queue while free pages cover the head
        # request's footprint — THE admission bound; lanes don't gate it
        admitted: list[Request] = []
        while self.queue:
            need = self._pages_for(self.queue[0])
            if need > self.pool.n_free:
                break
            req = self.queue.pop(0)
            self.table.assign(req.rid, need)
            admitted.append(req)
        n_live = sum(r is not None for r in self.live)
        self.peak_admitted = max(
            self.peak_admitted, n_live + len(self.paused) + len(admitted))
        if not admitted:
            return
        # 3. claim free lanes for as many as fit; the rest decode later
        lane_pairs, pausing = [], []
        for req in admitted:
            s = next((s for s in range(self.slots)
                      if self._admissible(s)), None)
            if s is None:
                pausing.append(req)
            else:
                self.live[s] = req
                self.lens[s] = len(req.prompt) + len(req.out)
                lane_pairs.append((s, req))
        # the supervision hook probes LANE claims (a paused admission has
        # no slot identity yet; it is probed when it joins a lane's
        # decode dispatches)
        kept = self._pre_dispatch_prefill(lane_pairs)
        jobs = kept + [(None, r) for r in pausing]
        if not jobs:
            return
        # 4. prefill into pages, bucketed exactly like the dense engine
        pad_ok = self._pad_ok()
        buckets: dict[int, list] = {}
        for s, req in jobs:
            n = len(req.prompt) + len(req.out)
            buckets.setdefault(self._length_bucket(n) if pad_ok else n,
                               []).append((s, req))
        ps = self.pool.page_size
        for width, group in sorted(buckets.items()):
            qbt = self._paged.prefill_table_width(self.pool.specs, ps,
                                                  width)
            for i0 in range(0, len(group), self.slots):
                chunk = group[i0:i0 + self.slots]
                tokens = np.zeros((self.slots, width), np.int32)
                for row, (s, req) in enumerate(chunk):
                    seq = req.prompt + req.out
                    tokens[row, :len(seq)] = seq
                bt = self.table.block_table(
                    [req.rid for _, req in chunk] +
                    [None] * (self.slots - len(chunk)), width=qbt)
                self._prefill_dispatch(
                    {"tokens": jnp.asarray(tokens),
                     "block_table": jnp.asarray(bt)})
        self.paused.extend(pausing)

    # ------------------------------------------------------- dispatch

    def _prefill_dispatch(self, batch):
        logits, new_pools = self._paged.paged_prefill(
            self.model.prefill, self.pool.treedef, self.pool.specs,
            self.params, {"tokens": batch["tokens"]},
            tuple(self.pool.leaves), batch["block_table"])
        self.pool.leaves = list(new_pools)
        return logits, None

    def _decode_dispatch(self, batch):
        bt = self.table.block_table(
            [r.rid if r is not None else None for r in self.live])
        logits, new_pools = self._paged.paged_decode(
            self.model.decode, self.pool.treedef, self.pool.specs,
            self.params, batch, tuple(self.pool.leaves), jnp.asarray(bt))
        self.pool.leaves = list(new_pools)
        return logits, None

    # ------------------------------------------------------- lifecycle

    def _on_finish(self, s: int, req: Request) -> None:
        self.table.release(req.rid)
        super()._on_finish(s, req)

    def _on_evict(self, req: Request) -> None:
        # the replay re-admits against FRESH pages; stale ones free now
        if self.table.holds(req.rid):
            self.table.release(req.rid)
        super()._on_evict(req)

    def defrag(self) -> dict[int, int]:
        """Compact allocated pages onto the lowest ids (see
        `serve.paged.PageTable.defrag`); safe mid-decode — the
        continuation is bit-identical."""
        return self.table.defrag()


class ColumnScheduler:
    """Admission placement of independent biosignal streams onto column
    replicas (devices) — LOAD-AWARE when given telemetry.

    Two ways to use D columns: one heavy stream `shard_map`s each dispatch
    across all of them (`StreamConfig.n_columns=D`), or D independent
    streams each stay resident on ONE column — no cross-device halo, and
    per-column autotune winners stay valid because every column sees the
    single-column shape. This scheduler implements the second: `admit`
    pins a new stream to the least-loaded column, `release` frees it on
    stream close.

    "Least-loaded" is MEASURED when a `serve.stream.StreamTelemetry` is
    attached and warm: a column's load is the sum of its streams' EWMA
    windows/s, so a heavy sensor counts for what it actually consumes and
    a cheap one barely counts — balancing by live-stream count only when
    telemetry is cold (no inter-retire gap observed yet). Ties break by
    stream count then column index, so an idle machine still fills
    round-robin (the archsim pass deal).

    `rebalance` is the work-stealing step: when the max/min column-load
    ratio exceeds ``rebalance_ratio`` it re-pins streams from the most-
    to the least-loaded column (largest mover first, only while a move
    strictly shrinks the spread) and returns the
    ``{stream_id: new_device}`` moves for the caller to apply via
    `BiosignalStream.repin`. `deal_weights` is the sharded-stream
    complement: measured per-column throughput rates as a
    `column_shares` weight vector (`StreamConfig.column_weights`), so a
    column sharing its device with another tenant is dealt fewer frames.

    RETIRE-COUNT TRIGGER: pass ``rebalance_every=N`` (windows) and the
    scheduler subscribes to its telemetry's retire feed
    (`StreamTelemetry.add_retire_listener`) — `rebalance` then runs BY
    ITSELF once N windows have retired fleet-wide since the last pass,
    instead of a host-side poller calling it on a timer. The trigger
    consumes whatever the telemetry sees: per-batch retires from the
    host-driven path or counter DRAINS from the device-resident loop
    (`serve.resident.ResidentStream` — each drain reports the windows
    retired on-device since the previous drain), so moving the steady
    state on-device keeps the closed loop closed. Triggered moves queue
    in ``pending_moves``; drain them with `pop_moves` and apply via
    `BiosignalStream.repin`. See `docs/ARCHITECTURE.md`
    (serving-runtime control loop).

    SUPERVISION (the fault-tolerant layer): pass ``heartbeat_timeout``
    (seconds) and/or a ``straggler`` (`runtime.fault.StragglerDetector`)
    and the scheduler watches column LIVENESS through the same retire
    feed — every retire from a placed stream beats the column's
    `runtime.fault.HeartbeatMonitor` (resident counter drains included),
    per-dispatch wall times go in via `record_batch_time`, and
    `supervise` declares a column dead on heartbeat timeout or straggler
    eviction, draining its streams onto survivors (`mark_dead`) and
    zeroing it out of `deal_weights`. The last column dying raises the
    typed `runtime.fault.InsufficientHealthyWorkers`. The requeue of a
    dead column's unretired frame ranges is the serving front-end's job
    (`serve/fault.py`); see `docs/ARCHITECTURE.md` (fault-tolerance
    closed loop).

    >>> sched = ColumnScheduler(telemetry=StreamTelemetry(),
    ...                         rebalance_every=256)
    >>> stream = BiosignalStream(app, cfg, device=sched.admit("sensor-7"))
    >>> ...  # retires accumulate; sched.pop_moves() hands back any re-pins
    """

    def __init__(self, devices=None, *, telemetry=None,
                 rebalance_ratio: float = 2.0,
                 rebalance_every: int | None = None,
                 heartbeat_timeout: float | None = None,
                 straggler: StragglerDetector | None = None,
                 clock=time.monotonic):
        self.devices = list(devices) if devices is not None \
            else list(jax.devices())
        assert self.devices, "no devices to schedule columns on"
        assert rebalance_ratio >= 1.0, rebalance_ratio
        assert rebalance_every is None or rebalance_every >= 1
        self.telemetry = telemetry
        self.rebalance_ratio = rebalance_ratio
        self.rebalance_every = rebalance_every
        self.pending_moves: dict = {}
        self._retired_since_rebalance = 0
        self._load = [0] * len(self.devices)
        self._placement: dict = {}
        # SUPERVISION state: the retire feed doubles as the heartbeat
        # source (a column that retires work is alive — per-batch retires
        # and resident counter drains both count), per-column batch
        # times feed the straggler detector, and `supervise` turns both
        # into dead-column declarations + stream drains.
        self._clock = clock
        self.dead: set[int] = set()
        self.withdrawn: set[int] = set()   # drained for re-provisioning
        self.heartbeats = (HeartbeatMonitor(timeout_s=heartbeat_timeout)
                           if heartbeat_timeout is not None else None)
        self.straggler = straggler
        if self.heartbeats is not None:
            assert telemetry is not None, \
                "heartbeat supervision needs a telemetry retire feed"
            now = clock()
            for c in range(len(self.devices)):   # grace period from t0
                self.heartbeats.beat(c, now)
            telemetry.add_retire_listener(self._beat_on_retire)
        if rebalance_every is not None:
            assert telemetry is not None, \
                "the retire-count trigger needs a telemetry retire feed"
            telemetry.add_retire_listener(self._on_retire)

    @property
    def n_columns(self) -> int:
        return len(self.devices)

    def healthy_columns(self) -> list[int]:
        """Columns not declared dead — the only legal placement targets."""
        return [c for c in range(len(self.devices)) if c not in self.dead]

    def column_of(self, stream_id) -> int:
        return self._placement[stream_id]

    def loads(self) -> list:
        """Live-stream count per column (admission balance introspection)."""
        return list(self._load)

    def _warm(self) -> bool:
        return self.telemetry is not None and self.telemetry.warm

    def _stream_weights(self) -> dict:
        """Every placed stream's load contribution: its measured EWMA rate
        when warm. A cold (not-yet-measured) stream counts the MEAN
        warm-stream rate — the same unmeasured-is-not-zero substitution
        as `deal_weights`; a unitless placeholder against windows/s loads
        would make a burst of cold admissions nearly invisible and pile
        them onto one column. Computed in one pass (the mean once, not
        per stream)."""
        rates = {s: (self.telemetry.stream_rate(s) if self.telemetry
                     else 0.0) for s in self._placement}
        warm = [r for r in rates.values() if r > 0.0]
        mean = sum(warm) / len(warm) if warm else 1.0
        return {s: (r if r > 0.0 else mean) for s, r in rates.items()}

    def measured_loads(self) -> list[float] | None:
        """Measured windows/s demand per column (sum of the column's
        streams' EWMA rates, cold streams counted at the mean warm rate),
        or None while telemetry is cold — callers then balance by stream
        count."""
        if not self._warm():
            return None
        loads = [0.0] * len(self.devices)
        for sid, w in self._stream_weights().items():
            loads[self._placement[sid]] += w
        return loads

    def admit(self, stream_id):
        """Place a new stream; returns the device to pin it to
        (`BiosignalStream(..., device=...)`). Rate-based (least measured
        load) when telemetry is warm, least-stream-count otherwise. Dead
        columns are never placement targets; with every column dead the
        fleet cannot admit — the typed `InsufficientHealthyWorkers`."""
        assert stream_id not in self._placement, \
            f"stream {stream_id!r} already placed"
        healthy = self.healthy_columns()
        if not healthy:
            raise InsufficientHealthyWorkers(
                "every column is dead; nothing to admit onto")
        measured = self.measured_loads()
        if measured is None:
            col = min(healthy, key=lambda i: (self._load[i], i))
        else:
            col = min(healthy,
                      key=lambda i: (measured[i], self._load[i], i))
        self._load[col] += 1
        self._placement[stream_id] = col
        if self.telemetry is not None:
            self.telemetry.attach(stream_id, col)
        return self.devices[col]

    def release(self, stream_id) -> None:
        self._load[self._placement.pop(stream_id)] -= 1
        if self.telemetry is not None:
            self.telemetry.detach(stream_id)

    def _move(self, stream_id, col: int) -> None:
        old = self._placement[stream_id]
        self._load[old] -= 1
        self._load[col] += 1
        self._placement[stream_id] = col
        if self.telemetry is not None:
            self.telemetry.attach(stream_id, col)

    def _on_retire(self, stream_id, n_windows: int) -> None:
        """Telemetry retire listener: accumulate retired windows and run
        the work-stealing pass once ``rebalance_every`` of them landed —
        the retire-count trigger that replaces a host-side poller. Only
        streams this scheduler placed count toward the trigger (a foreign
        stream sharing the telemetry is not this scheduler's load)."""
        if stream_id not in self._placement:
            return
        self._retired_since_rebalance += n_windows
        if self._retired_since_rebalance >= self.rebalance_every:
            self._retired_since_rebalance = 0
            self.pending_moves.update(self.rebalance())

    def pop_moves(self) -> dict:
        """Drain the retire-triggered re-pins: {stream_id: new device},
        empty when the trigger hasn't fired (or found nothing to move).
        Callers apply each with `BiosignalStream.repin`."""
        moves, self.pending_moves = self.pending_moves, {}
        return moves

    def rebalance(self) -> dict:
        """One work-stealing pass. While the max/min column-load ratio
        exceeds ``rebalance_ratio`` (a zero-load column under a loaded one
        counts as exceeded), move the heaviest stream that strictly
        shrinks the max-min spread from the most- to the least-loaded
        column. Returns {stream_id: new device}; apply with
        `BiosignalStream.repin`."""
        moves: dict = {}
        healthy = self.healthy_columns()
        if len(healthy) < 2:
            return moves
        for _ in range(len(self._placement) or 1):
            loads = self.measured_loads()
            if loads is None:
                loads = [float(c) for c in self._load]
            hi = max(healthy, key=lambda i: (loads[i], -i))
            lo = min(healthy, key=lambda i: (loads[i], i))
            if loads[hi] <= 0.0 or \
                    (loads[lo] > 0.0 and
                     loads[hi] / loads[lo] <= self.rebalance_ratio):
                break
            weights = self._stream_weights()
            movers = sorted(
                (s for s, c in self._placement.items() if c == hi),
                key=weights.__getitem__, reverse=True)
            pick = next((s for s in movers
                         if loads[lo] + weights[s] < loads[hi]), None)
            if pick is None:        # no move shrinks the spread
                break
            self._move(pick, lo)
            moves[pick] = self.devices[lo]
        return moves

    # ------------------------------------------------------- supervision

    def _beat_on_retire(self, stream_id, n_windows: int) -> None:
        """Telemetry retire listener: a retire from one of THIS
        scheduler's streams is a heartbeat for its column — per-batch
        retires (`serve.stream.BiosignalStream._collect`) and resident
        counter drains (`serve.resident.ResidentStream._drain`) both
        land here, so moving the steady state on-device keeps the
        liveness signal alive."""
        if stream_id in self._placement:
            self.heartbeats.beat(self._placement[stream_id], self._clock())

    def record_batch_time(self, column: int, seconds: float) -> None:
        """Feed one column dispatch's wall time to the straggler
        detector (the serving analogue of a training step time)."""
        if self.straggler is not None and column not in self.dead:
            self.straggler.record(column, seconds)

    def mark_dead(self, column: int) -> dict:
        """Declare a column dead and DRAIN it: every stream pinned to it
        re-pins onto the least-loaded surviving column (the drain moves
        land in ``pending_moves`` like triggered rebalances — apply with
        `BiosignalStream.repin`). The column stops being a placement /
        rebalance / heartbeat target and its measured rate is zeroed out
        of future `deal_weights`. Raises `InsufficientHealthyWorkers`
        when the last column dies — the caller decides whether that is
        an outage or a wait-for-capacity."""
        if column in self.dead:
            return {}
        self.dead.add(column)
        if self.heartbeats is not None:
            self.heartbeats.forget(column)
        if self.straggler is not None:
            self.straggler.forget(column)
        healthy = self.healthy_columns()
        if not healthy:
            raise InsufficientHealthyWorkers(
                f"column {column} was the last healthy column")
        moves: dict = {}
        for sid, c in sorted(self._placement.items(), key=lambda kv: kv[0]):
            if c != column:
                continue
            measured = self.measured_loads()
            target = min(healthy,
                         key=(lambda i: (self._load[i], i)) if measured
                         is None else (lambda i: (measured[i],
                                                  self._load[i], i)))
            self._move(sid, target)
            moves[sid] = self.devices[target]
        self.pending_moves.update(moves)
        return moves

    def supervise(self, now: float | None = None) -> list[int]:
        """One supervision pass: declare dead every column whose
        heartbeat timed out (no retire for ``heartbeat_timeout``
        seconds) or that the straggler detector evicted (persistently
        slower than `StragglerDetector.straggler_factor` x the fleet
        median), drain each via `mark_dead`, and return the newly-dead
        columns. The closed loop is detection -> drain -> requeue ->
        re-deal; this method is the detection + drain half — the requeue
        half (unretired frame ranges onto survivors) lives in
        `serve/fault.py`, see `docs/ARCHITECTURE.md`."""
        suspects: list[int] = []
        if self.heartbeats is not None:
            suspects += self.heartbeats.dead(
                self._clock() if now is None else now)
        if self.straggler is not None:
            suspects += self.straggler.stragglers()
        newly = []
        for c in suspects:
            if 0 <= c < len(self.devices) and c not in self.dead:
                newly.append(c)
                self.mark_dead(c)
        return newly

    def deal_weights(self, band: float = 0.0) -> tuple | None:
        """Measured per-column throughput rates (the retire-rate EWMAs) as
        a weight vector for the non-uniform deal
        (`StreamConfig.column_weights` / `column_shares`), or None while
        telemetry is cold. A column that never retired anything gets the
        mean observed rate — unobserved is not the same as broken.

        ``band`` is the deal's deadband (same thrash-guard idea as
        ``rebalance_ratio``): columns whose measured rates differ by less
        than ``band`` (relative, walked over the rate-sorted columns) are
        considered EQUALLY capable and share their cluster's mean rate —
        EWMA jitter between identical columns must not deal them unequal
        shares; only a genuine rate gap wider than the band changes the
        deal. 0 disables it.

        DEAD columns are zeroed: a drained column's weight is exactly
        0.0 (never the stale pre-death EWMA, never the mean), so the
        degraded deal rides `column_shares`' zero-weight path and deals
        it nothing. All columns dead raises
        `InsufficientHealthyWorkers` — there is no deal to compute."""
        if self.telemetry is None:
            return None
        healthy = self.healthy_columns()
        if not healthy:
            raise InsufficientHealthyWorkers(
                "every column is dead; no deal weights to compute")
        rates = [self.telemetry.column_rate(c)
                 for c in range(len(self.devices))]
        seen = [rates[c] for c in healthy if rates[c] > 0.0]
        if not seen:
            return None
        mean = sum(seen) / len(seen)
        rates = [r if r > 0.0 else mean for r in rates]
        if band > 0.0:
            # cluster only the healthy columns: a dead column's stale
            # rate must not drag a cluster mean around
            order = sorted(healthy, key=lambda c: rates[c])
            clusters, cur = [], [order[0]]
            for c in order[1:]:
                if rates[c] <= rates[cur[0]] * (1.0 + band):
                    cur.append(c)       # within the band of the cluster
                else:                   # floor: same capability class
                    clusters.append(cur)
                    cur = [c]
            clusters.append(cur)
            for cl in clusters:
                m = sum(rates[c] for c in cl) / len(cl)
                for c in cl:
                    rates[c] = m
        for c in self.dead:
            rates[c] = 0.0
        return tuple(rates)

    # --------------------------------------------- class re-provisioning

    def withdraw(self, column: int):
        """Administratively DRAIN a column so its device can serve the
        other traffic class (the unified front-end lends columns to the
        LM engine under load — `serve/frontend.py:ServeFrontend`).
        Reuses the `mark_dead` drain machinery — streams re-pin onto
        survivors, the column leaves placement/heartbeat/deal targets —
        but records the column as WITHDRAWN, not failed, so `restore`
        can hand it back. Returns ``(device, moves)`` where ``moves`` is
        the `mark_dead`-style ``{stream_id: new_device}`` drain to apply
        via `BiosignalStream.repin`. Withdrawing the last healthy column
        raises `InsufficientHealthyWorkers` (the stream class keeps a
        quorum of one)."""
        if column in self.dead:
            raise ValueError(f"column {column} is already dead/withdrawn")
        if len(self.healthy_columns()) < 2:
            # checked BEFORE the drain so a refused withdraw leaves the
            # scheduler untouched (mark_dead declares first, then raises)
            raise InsufficientHealthyWorkers(
                f"column {column} is the last healthy column; "
                "cannot withdraw it for re-provisioning")
        moves = self.mark_dead(column)
        self.withdrawn.add(column)
        return self.devices[column], moves

    def restore(self, column: int) -> None:
        """Return a `withdraw`n column to the placement set: it becomes
        a placement/rebalance target again and its heartbeat restarts
        with a fresh grace period. Only withdrawn columns are
        restorable — a column that FAILED stays dead."""
        if column not in self.withdrawn:
            raise ValueError(f"column {column} was not withdrawn")
        self.withdrawn.discard(column)
        self.dead.discard(column)
        if self.heartbeats is not None:
            self.heartbeats.beat(column, self._clock())

    # ------------------------------------------------------ stream entry

    def place_stream(self, app=None, cfg=None, *, stream_id):
        """Admit + construct in one call: a `BiosignalStream` whose every
        dispatch is committed to the assigned column and (when the
        scheduler carries telemetry) reports its retires to it. (The
        unified admission path — `serve/frontend.py:ServeFrontend.submit`
        with a `StreamOpen` — lands here.)"""
        from repro.serve.stream import BiosignalStream

        device = self.admit(stream_id)
        return BiosignalStream(app, cfg, device=device,
                               telemetry=self.telemetry,
                               stream_id=stream_id,
                               column=self._placement[stream_id])

    def open_stream(self, app=None, cfg=None, *, stream_id):
        """Deprecated name for `place_stream` (kept as a shim for one
        release; the unified front-end made `submit` the public verb)."""
        warnings.warn(
            "ColumnScheduler.open_stream is deprecated; use "
            "ServeFrontend.submit (unified admission) or "
            "ColumnScheduler.place_stream",
            DeprecationWarning, stacklevel=2)
        return self.place_stream(app, cfg, stream_id=stream_id)

"""Config registry: importing this package registers every assigned arch."""
from repro.configs.base import (  # noqa: F401
    ArchConfig,
    MoEConfig,
    SSMConfig,
    ShapeSpec,
    SHAPES,
    applicable_shapes,
    get_config,
    input_specs,
    list_configs,
    reduced,
    register,
    smoke_shape,
)

# one module per assigned architecture (registration side effect)
from repro.configs import (  # noqa: F401
    deepseek_coder_33b,
    starcoder2_7b,
    qwen1_5_0_5b,
    h2o_danube3_4b,
    rwkv6_7b,
    whisper_medium,
    qwen2_vl_2b,
    llama4_maverick,
    deepseek_moe_16b,
    zamba2_7b,
    vwr2a_biosignal,
)

ASSIGNED = [
    "deepseek-coder-33b",
    "starcoder2-7b",
    "qwen1.5-0.5b",
    "h2o-danube-3-4b",
    "rwkv6-7b",
    "whisper-medium",
    "qwen2-vl-2b",
    "llama4-maverick-400b-a17b",
    "deepseek-moe-16b",
    "zamba2-7b",
]

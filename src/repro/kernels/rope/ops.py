"""Public jit'd API for the RoPE kernel."""
from __future__ import annotations

import jax

from repro.kernels.rope.kernel import rope_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def rope(x, positions, *, theta: float = 10000.0,
         layout: str = "interleaved"):
    """Apply rotary embedding. x: (..., S, H, dh) or (R, dh);
    positions broadcastable to the row dims."""
    if x.ndim == 2:
        return rope_pallas(x, positions, theta=theta, layout=layout,
                           interpret=_interpret())
    shape = x.shape
    dh = shape[-1]
    rows = 1
    for d in shape[:-1]:
        rows *= d
    pos = jax.numpy.broadcast_to(
        positions[..., None] if positions.ndim == x.ndim - 2 else positions,
        shape[:-1]).reshape(rows)
    out = rope_pallas(x.reshape(rows, dh), pos, theta=theta, layout=layout,
                      interpret=_interpret())
    return out.reshape(shape)

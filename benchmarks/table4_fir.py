"""Table 4 — FIR filter (11 taps) performance & energy (paper §5.1.2)."""
from __future__ import annotations

import numpy as np

from benchmarks.table2_fft import F_HZ

PAPER = {256: (24747, 0.37, 1849, 0.11), 512: (49253, 0.73, 3260, 0.21),
         1024: (98283, 1.45, 6091, 0.40)}  # n: cpu_cyc, cpu_uJ, v_cyc, v_uJ


def run():
    from repro.archsim.energy import vwr2a_energy_uj
    from repro.archsim.programs.fir import run_fir
    from repro.core.fir import fir_reference, lowpass_taps

    rows = []
    taps = lowpass_taps(11)
    for n, (cpu_cyc, cpu_uj, v_cyc, v_uj) in PAPER.items():
        x = np.sin(np.arange(n) * 0.1) * 0.5
        y, counters, cycles = run_fir(x, taps)
        ref = fir_reference(x[None, :], taps)[0]
        err = float(np.abs(y - ref).max())
        e = vwr2a_energy_uj(counters)
        rows.append((f"table4/fir_{n}", cycles / F_HZ * 1e6,
                     f"sim_cycles={cycles};paper_vwr2a={v_cyc};"
                     f"speedup_vs_cpu={cpu_cyc / cycles:.1f}x;"
                     f"sim_uJ={e:.3f};paper_uJ={v_uj};"
                     f"energy_savings_vs_cpu={100 * (1 - e / cpu_uj):.1f}%;"
                     f"q15_err={err:.1e}"))
    return rows

"""Trip-count-aware cost analysis of optimized (post-SPMD) HLO text.

XLA's built-in ``compiled.cost_analysis()`` visits every computation ONCE —
`while` bodies (lax.scan over layers, attention chunk loops) are not
multiplied by their trip counts, which under-counts a scanned 62-layer model
by ~62x. This walker parses the optimized HLO, builds the call graph, and
propagates multipliers:

  * while:        trip_count x (body + condition)   [trip count from
                  backend_config known_trip_count, else condition constant]
  * conditional:  0.5 x sum(branches)  — matches the ~half-live causal
                  chunk grid of blockwise attention (documented approximation)
  * fusion/call:  1 x called computation (FLOPs); fusion *bytes* are counted
                  at the fusion boundary only (internals live in registers —
                  exactly the VWR/VMEM model of the paper)

Outputs: MXU FLOPs (dot/conv), bytes accessed, transcendentals, and a
collective inventory {op: count, bytes, by link type} where ICI vs DCN is
decided by reconstructing each op's replica groups (iota or explicit form)
and checking whether any group crosses a pod boundary.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c128": 16, "c64": 8,
          "f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2, "s16": 2,
          "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
          "s4": 1, "u4": 1, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_ZERO_COST = {"parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "after-all", "partition-id", "replica-id",
              "add-dependency", "opt-barrier"}

_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all",
                "collective-broadcast"}


def shape_dims(type_str: str):
    """All (dtype, dims) array components of a (possibly tuple) type."""
    return [(dt, [int(x) for x in dims.split(",") if x])
            for dt, dims in _SHAPE_RE.findall(type_str)]


def type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in shape_dims(type_str):
        total += _BYTES.get(dt, 4) * int(np.prod(dims)) if dims else \
            _BYTES.get(dt, 4)
    return total


def type_elems(type_str: str) -> int:
    total = 0
    for _, dims in shape_dims(type_str):
        total += int(np.prod(dims)) if dims else 1
    return total


@dataclasses.dataclass
class Op:
    name: str
    rtype: str
    opcode: str
    operands: list
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    params: dict            # name -> type str
    ops: list               # [Op]


def _split_balanced(s: str):
    """Split a comma-separated list at paren/brace depth zero."""
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return parts


_COMP_HEADER = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s+->\s+.*\{\s*$")
_OP_LINE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(.*)$")


def parse_hlo(text: str):
    """-> (computations dict, entry computation name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        m = _COMP_HEADER.match(line)
        if m and "=" not in line.split("(")[0]:
            name, params_str = m.group(1), m.group(2)
            params = {}
            for p in _split_balanced(params_str):
                pm = re.match(r"%?([\w.\-]+):\s*(.*)", p)
                if pm:
                    params[pm.group(1)] = pm.group(2)
            cur = Computation(name, params, [])
            comps[name] = cur
            if line.startswith("ENTRY"):
                entry = name
            continue
        if line.startswith("}"):
            continue
        m = _OP_LINE.match(line)
        if m and cur is not None:
            name, rest = m.group(1), m.group(2)
            # type = balanced tuple or single token
            if rest.startswith("("):
                depth, i = 0, 0
                for i, ch in enumerate(rest):
                    depth += ch == "("
                    depth -= ch == ")"
                    if depth == 0:
                        break
                rtype, rest2 = rest[: i + 1], rest[i + 1:].strip()
            else:
                sp = rest.find(" ")
                rtype, rest2 = rest[:sp], rest[sp + 1:]
            om = re.match(r"([\w\-]+)\(", rest2)
            if not om:
                continue
            opcode = om.group(1)
            depth, j = 0, om.end() - 1
            for j in range(om.end() - 1, len(rest2)):
                depth += rest2[j] == "("
                depth -= rest2[j] == ")"
                if depth == 0:
                    break
            operand_str = rest2[om.end(): j]
            attrs = rest2[j + 1:]
            operands = re.findall(r"%([\w.\-]+)", operand_str)
            cur.ops.append(Op(name, rtype, opcode, operands, attrs))
    return comps, entry


# ---------------------------------------------------------------------------
# Replica-group reconstruction (ICI vs DCN)
# ---------------------------------------------------------------------------

_IOTA_RG = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_EXPL_RG = re.compile(r"replica_groups=\{(\{[\d,{}\s]*\})\}")


def replica_groups(attrs: str):
    m = _IOTA_RG.search(attrs)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        return ids.reshape(g, s)
    m = _EXPL_RG.search(attrs)
    if m:
        groups = re.findall(r"\{([\d,\s]*)\}", m.group(1))
        parsed = [[int(x) for x in g.split(",") if x.strip()] for g in groups]
        parsed = [g for g in parsed if g]
        if parsed:
            width = max(len(g) for g in parsed)
            return np.array([g + g[-1:] * (width - len(g)) for g in parsed])
    return None


def crosses_pod(groups, pod_size: int) -> bool:
    if groups is None or pod_size <= 0:
        return False
    return bool(np.any((groups // pod_size) !=
                       (groups[:, :1] // pod_size)))


# ---------------------------------------------------------------------------
# Cost walking
# ---------------------------------------------------------------------------

_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_CALL_RE = re.compile(r"(?:calls|body|to_apply|true_computation|"
                      r"false_computation)=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "logistic", "sine", "cosine", "exponential-minus-one",
                   "log-plus-one", "atan2", "cbrt", "erf"}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0          # MXU (dot/conv) flops
    bytes: float = 0.0          # raw fusion-boundary traffic (upper bound:
                                # CPU backend under-fuses vs TPU)
    hbm_bytes: float = 0.0      # fused-traffic model (TPU estimate): dots,
                                # data movement, collectives, dot-bearing
                                # fusions only — elementwise assumed fused
    transcendentals: float = 0.0
    vpu_elems: float = 0.0      # elementwise output elements (fusion level)
    collectives: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.transcendentals += other.transcendentals * mult
        self.vpu_elems += other.vpu_elems * mult
        for k, v in other.collectives.items():
            d = self.collectives.setdefault(
                k, {"count": 0.0, "bytes": 0.0, "dcn_bytes": 0.0,
                    "group_size": v.get("group_size", 0)})
            d["count"] += v["count"] * mult
            d["bytes"] += v["bytes"] * mult
            d["dcn_bytes"] += v.get("dcn_bytes", 0.0) * mult


# data-movement opcodes that must touch HBM even under perfect fusion
_MOVE_IN_OUT = {"copy", "transpose", "concatenate", "reduce", "sort",
                "reverse", "pad", "cholesky", "triangular-solve"}
_MOVE_OUT_ONLY = {"dynamic-slice", "slice", "gather", "iota",
                  "rng-bit-generator", "broadcast"}
_MOVE_RMW = {"dynamic-update-slice", "scatter", "select-and-scatter"}


class HloCost:
    def __init__(self, text: str, *, pod_size: int = 0):
        self.comps, self.entry = parse_hlo(text)
        self.pod_size = pod_size
        self._memo: dict[str, Cost] = {}
        self._has_dot: dict[str, bool] = {}

    def comp_has_dot(self, name: str) -> bool:
        if name in self._has_dot:
            return self._has_dot[name]
        self._has_dot[name] = False
        comp = self.comps.get(name)
        if comp is None:
            return False
        out = False
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                out = True
                break
            cm = _CALL_RE.search(op.attrs)
            if cm and cm.group(1) in self.comps and \
                    self.comp_has_dot(cm.group(1)):
                out = True
                break
        self._has_dot[name] = out
        return out

    def _operand_bytes(self, comp: Computation, op: Op, table: dict) -> int:
        total = 0
        for name in op.operands:
            t = table.get(name) or comp.params.get(name)
            if t:
                total += type_bytes(t)
        return total

    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps[name]
        table = {o.name: o.rtype for o in comp.ops}
        c = Cost()
        self._memo[name] = c  # guards (benign) recursion
        for op in comp.ops:
            oc = op.opcode
            if oc in _ZERO_COST:
                continue
            if oc == "while":
                trips = 1.0
                tm = _TRIP_RE.search(op.attrs)
                if tm:
                    trips = float(tm.group(1))
                bm, cm = _BODY_RE.search(op.attrs), _COND_RE.search(op.attrs)
                if bm:
                    c.add(self.comp_cost(bm.group(1)), trips)
                if cm:
                    c.add(self.comp_cost(cm.group(1)), trips)
                continue
            if oc == "conditional":
                brm = _BRANCH_RE.search(op.attrs)
                branches = (re.findall(r"%([\w.\-]+)", brm.group(1))
                            if brm else _CALL_RE.findall(op.attrs))
                for b in branches:
                    c.add(self.comp_cost(b), 1.0 / max(1, len(branches)) *
                          (len(branches) / 2.0 if len(branches) == 2 else 1.0))
                # operands+output at the boundary
                c.bytes += type_bytes(op.rtype) + self._operand_bytes(
                    comp, op, table)
                continue
            if oc in ("fusion", "call", "async-start"):
                cm = _CALL_RE.search(op.attrs)
                boundary = type_bytes(op.rtype) + self._operand_bytes(
                    comp, op, table)
                if cm and cm.group(1) in self.comps:
                    sub = self.comp_cost(cm.group(1))
                    c.flops += sub.flops
                    c.transcendentals += sub.transcendentals
                    c.vpu_elems += sub.vpu_elems
                    c.hbm_bytes += sub.hbm_bytes
                    if self.comp_has_dot(cm.group(1)):
                        c.hbm_bytes += boundary
                    for k, v in sub.collectives.items():
                        d = c.collectives.setdefault(
                            k, {"count": 0.0, "bytes": 0.0, "dcn_bytes": 0.0,
                                "group_size": v.get("group_size", 0)})
                        d["count"] += v["count"]
                        d["bytes"] += v["bytes"]
                        d["dcn_bytes"] += v.get("dcn_bytes", 0.0)
                c.bytes += boundary
                continue
            base = oc.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES:
                if oc.endswith("-done"):
                    continue
                out_b = type_bytes(op.rtype)
                in_b = self._operand_bytes(comp, op, table)
                moved = max(out_b, in_b)
                groups = replica_groups(op.attrs)
                gsz = int(groups.shape[1]) if groups is not None else 0
                dcn = crosses_pod(groups, self.pod_size)
                d = c.collectives.setdefault(
                    base, {"count": 0.0, "bytes": 0.0, "dcn_bytes": 0.0,
                           "group_size": gsz})
                d["count"] += 1
                d["bytes"] += moved
                d["group_size"] = max(d["group_size"], gsz)
                if dcn:
                    d["dcn_bytes"] += moved
                c.bytes += out_b + in_b
                c.hbm_bytes += out_b + in_b
                continue
            if oc in ("dot", "convolution"):
                out_elems = type_elems(op.rtype)
                contract = 1
                cd = _CDIMS.search(op.attrs)
                lhs_t = (table.get(op.operands[0])
                         or comp.params.get(op.operands[0]) if op.operands
                         else None)
                if cd and lhs_t:
                    dims = shape_dims(lhs_t)
                    if dims:
                        _, ldims = dims[0]
                        for di in cd.group(1).split(","):
                            if di and int(di) < len(ldims):
                                contract *= ldims[int(di)]
                if oc == "convolution":
                    # window size from attrs, e.g. window={size=3x3 ...}
                    wm = re.search(r"window=\{size=([\dx]+)", op.attrs)
                    if wm:
                        for w in wm.group(1).split("x"):
                            contract *= int(w)
                c.flops += 2.0 * out_elems * contract
                io = type_bytes(op.rtype) + self._operand_bytes(
                    comp, op, table)
                c.bytes += io
                c.hbm_bytes += io
                continue
            # generic elementwise / data movement
            if oc in _TRANSCENDENTAL:
                c.transcendentals += type_elems(op.rtype)
            c.vpu_elems += type_elems(op.rtype)
            out_b = type_bytes(op.rtype)
            c.bytes += out_b + self._operand_bytes(comp, op, table)
            if oc in _MOVE_IN_OUT:
                c.hbm_bytes += out_b + self._operand_bytes(comp, op, table)
            elif oc in _MOVE_OUT_ONLY:
                c.hbm_bytes += out_b
            elif oc in _MOVE_RMW:
                upd = 0
                if len(op.operands) > 1:
                    t = table.get(op.operands[1]) or comp.params.get(
                        op.operands[1])
                    upd = type_bytes(t) if t else 0
                c.hbm_bytes += 2 * upd
        self._memo[name] = c
        return c

    def entry_cost(self) -> Cost:
        return self.comp_cost(self.entry)


def analyze(hlo_text: str, *, pod_size: int = 0) -> dict:
    hc = HloCost(hlo_text, pod_size=pod_size)
    c = hc.entry_cost()
    return {
        "flops": c.flops,
        "bytes": c.hbm_bytes,
        "bytes_upper": c.bytes,
        "transcendentals": c.transcendentals,
        "vpu_elems": c.vpu_elems,
        "collectives": {k: {kk: (round(vv, 1) if isinstance(vv, float) else vv)
                            for kk, vv in v.items()}
                        for k, v in c.collectives.items()},
        "collective_bytes": sum(v["bytes"] for v in c.collectives.values()),
        "collective_dcn_bytes": sum(v["dcn_bytes"]
                                    for v in c.collectives.values()),
    }

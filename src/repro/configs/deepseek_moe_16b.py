"""deepseek-moe-16b [arXiv:2401.06066; hf] — fine-grained experts:
2 shared + 64 routed top-6, expert d_ff=1408, first layer dense (d_ff=10944
in HF; we use 4*2048*1.34~10944). 28 layers, d_model 2048."""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,            # the single dense layer
    vocab_size=102400,
    head_dim=128,
    rope_theta=10000.0,
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        d_ff_expert=1408,
        num_shared=2,
        d_ff_shared=1408,
        every_k_layers=1,
        first_dense=1,
        capacity_factor=1.25,
        group_size=128,
    ),
    source="arXiv:2401.06066; hf:deepseek-ai/deepseek-moe-16b-base",
))

"""qwen1.5-0.5b [hf:Qwen/Qwen1.5-0.5B] — QKV bias, tied embeddings."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    head_dim=64,
    rope_theta=1000000.0,
    qkv_bias=True,
    tie_embeddings=True,
    source="hf:Qwen/Qwen1.5-0.5B",
))

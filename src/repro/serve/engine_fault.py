"""Fault-tolerant LM engine serving: slot supervision, deterministic
request replay, and admission backpressure.

The LM-side twin of `serve/fault.py`: PR 7 made the biosignal column
fleet survive faults (heartbeats, dead-column drain, deterministic
requeue); this module extends the same supervision to the `Engine`'s
decode slots, the other traffic class the repo serves. The same decision
layer (`runtime/fault.py`) and the same chaos injector
(`serve/fault.py:FaultInjector`, with the engine SLOT playing the
injector's "column" role) drive both.

`FaultTolerantEngine` layers onto `serve/engine.py:Engine`'s dispatch
hooks:

* TOKEN RETIRES ARE HEARTBEATS — every token a slot retires beats its
  `runtime.fault.HeartbeatMonitor` entry (the PR 7 telemetry-as-heartbeat
  pattern: no separate liveness channel). A slot is monitored from its
  admission beat until its request finishes; silence past
  ``heartbeat_timeout`` virtual seconds declares it stuck.
* STUCK/POISONED-SLOT EVICTION — a heartbeat-timed-out or persistently
  slow slot (`runtime.fault.StragglerDetector` over per-slot dispatch
  walls) is evicted: the slot is POISONED (masked out of admission via
  `Engine.dead_slots`, never reused) and its request is requeued at the
  queue FRONT in rid order for deterministic replay.
* DETERMINISTIC REPLAY — a requeued request re-prefills its prompt PLUS
  the already-generated prefix in one dispatch (`Engine._admit` admits
  ``prompt + out``) and continues decoding at step ``len(out)``. Because
  sampling is a per-request key stream
  (`serve/engine.py:_sample_per_request` —
  ``fold_in(fold_in(seed, rid), step)``), the continuation is
  BIT-IDENTICAL to the fault-free run regardless of which slot it lands
  on or what else is in flight.
* TRANSIENT RETRY — injected transients and real ``RuntimeError``s from
  the prefill/decode dispatch are retried in place with capped
  exponential backoff (`runtime.fault.Supervisor.call`); an exhausted
  retry budget escalates to slot eviction, never a lost request.
* CHAOS SURFACE — the shared `serve/fault.py:FaultInjector` injects
  per-slot faults into the `Engine._prefill_dispatch` /
  `Engine._decode_dispatch` paths: ``kill`` at a slot's dispatch seq
  (`runtime.fault.ColumnDeadError` → poison + requeue), ``transient``
  one-shots (absorbed by retry), ``hang_from``
  (`serve/fault.py:ColumnHungError` → the slot wedges: no retire, no
  heartbeat — only the heartbeat timeout resolves it), ``slow`` (extra
  virtual seconds per dispatch → straggler eviction). A slot's dispatch
  seq counts every dispatch it participates in: its admission prefill is
  seq 0, decode steps follow, retried attempts count — exactly the
  column-runner convention.
* ADMISSION BACKPRESSURE — the queue is bounded (``max_queue``):
  `submit` raises the typed `QueueFull` instead of growing an unbounded
  list. Requests carry a TTL/deadline (``ttl``/``default_ttl``): a
  request not admitted by its deadline is dropped from the queue into
  ``expired`` (and a dead-on-arrival TTL raises `RequestExpired` at
  submit) — backpressure and shed load are engine signals, not silent
  queue growth.
* GRACEFUL DEGRADATION — every eviction shrinks the live-slot set; the
  engine keeps serving on the survivors. Only when NO healthy slot
  remains with work pending does it raise the typed
  `runtime.fault.InsufficientHealthyWorkers` (the same error the column
  fleet and `runtime/fault.py:elastic_plan` raise).

THE INVARIANT (chaos-tested in `tests/test_engine_fault.py`, gated by
``run.py --check-engine-fault``): for any injected fault schedule — slot
kills at prefill or any decode step, transient faults, hang → heartbeat
eviction, straggler eviction — every submitted request completes and its
token sequence is bit-identical to the fault-free run, greedy AND
temperature-sampled. See `docs/ARCHITECTURE.md` (engine supervision
closed loop) and `docs/BENCHMARKS.md` (the seventh gate).
"""
from __future__ import annotations

import time
from typing import Optional

from repro.runtime.fault import (ColumnDeadError, HeartbeatMonitor,
                                 InsufficientHealthyWorkers,
                                 StragglerDetector, Supervisor,
                                 TransientDispatchError)
from repro.serve.engine import Engine, PagedEngine, Request
# QueueFull/RequestExpired live in the serve/errors.py taxonomy
# (ServeError root) and are re-exported from here, their historical home
from repro.serve.errors import QueueFull, RequestExpired
from repro.serve.fault import ColumnHungError, FaultInjector, VirtualClock

__all__ = ["QueueFull", "RequestExpired", "FaultTolerantEngine",
           "FaultTolerantPagedEngine", "FaultInjector", "VirtualClock"]


class FaultTolerantEngine(Engine):
    """`Engine` + the supervision closed loop (see the module docstring).

    Construction mirrors `serve/fault.py:FaultTolerantColumnRunner`:
    ``injector`` is the shared chaos `FaultInjector` (slot = the
    injector's column), ``heartbeat_timeout`` arms decode-progress
    liveness, ``straggler`` arms slow-slot eviction, ``retry`` is the
    transient-fault `runtime.fault.Supervisor` (capped exponential
    backoff; default: 3 retries, no sleep), ``clock`` the injectable time
    source (defaults to the injector's `VirtualClock` when it has one,
    else wall time). ``max_queue``/``default_ttl`` bound admission.

    >>> eng = FaultTolerantEngine(model, params, slots=4,
    ...                           heartbeat_timeout=5.0,
    ...                           injector=FaultInjector(kill={0: 3}))
    >>> eng.add_request(Request(0, [1, 2, 3], max_new=8))
    >>> done = eng.run_to_completion()   # bit-identical to fault-free
    """

    def __init__(self, model, params, *, slots: int = 4, max_len: int = 256,
                 temperature: float = 0.0, seed: int = 0, compiled=None,
                 max_queue: Optional[int] = None,
                 default_ttl: Optional[float] = None,
                 heartbeat_timeout: Optional[float] = None,
                 straggler: Optional[StragglerDetector] = None,
                 injector: Optional[FaultInjector] = None,
                 retry: Optional[Supervisor] = None, clock=None, **kwargs):
        # extra kwargs flow to the next class in the MRO, so the paged
        # composition (`FaultTolerantPagedEngine`) can thread
        # page_size/n_pages through without re-declaring them here
        super().__init__(model, params, slots=slots, max_len=max_len,
                         temperature=temperature, seed=seed,
                         compiled=compiled, **kwargs)
        self.max_queue = max_queue
        self.default_ttl = default_ttl
        self.injector = injector
        self.retry = retry if retry is not None else Supervisor()
        self.clock = clock if clock is not None else (
            injector.clock if injector is not None and
            injector.clock is not None else time.monotonic)
        self.heartbeats = (HeartbeatMonitor(timeout_s=heartbeat_timeout)
                           if heartbeat_timeout is not None else None)
        self.straggler = straggler
        self.hung: set[int] = set()
        self.deadlines: dict = {}          # rid -> absolute deadline
        self.expired: list[Request] = []   # TTL-dropped while queued
        self.evictions = 0
        self.replays = 0
        self.decode_steps = 0
        self.prefill_dispatches = 0

    # ---------------------------------------------------- admission edge

    def healthy_slots(self) -> list[int]:
        """Slots not poisoned — the only legal admission targets."""
        return [s for s in range(self.slots) if s not in self.dead_slots]

    def add_request(self, req: Request, *, ttl: Optional[float] = None):
        """Bounded, TTL-aware admission. Raises `QueueFull` when the
        queue is at ``max_queue`` (backpressure — the unbounded
        ``queue.append`` is exactly what this replaces), `RequestExpired`
        for a dead-on-arrival TTL, and the base engine's `PromptTooLong`
        for a prompt the cache cannot hold. (The deprecated
        ``Engine.submit`` shim forwards here.)"""
        ttl = self.default_ttl if ttl is None else ttl
        if ttl is not None and ttl <= 0:
            raise RequestExpired(req.rid, ttl)
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            raise QueueFull(req.rid, len(self.queue), self.max_queue)
        super().add_request(req)
        if ttl is not None:
            self.deadlines[req.rid] = self.clock() + ttl

    def _expire_queued(self) -> list[Request]:
        """Drop queued requests whose deadline passed into ``expired``
        (typed shed-load accounting, not silent loss)."""
        if not self.deadlines:
            return []
        now = self.clock()
        dropped = [r for r in self.queue
                   if self.deadlines.get(r.rid, now) < now]
        if dropped:
            gone = {r.rid for r in dropped}
            self.queue = [r for r in self.queue if r.rid not in gone]
            for r in dropped:
                self.deadlines.pop(r.rid, None)
            self.expired.extend(dropped)
        return dropped

    # -------------------------------------------------- fault injection

    def _probe(self, s: int) -> str:
        """Consult the chaos injector for slot ``s``'s share of the next
        dispatch: ``"ok"``, ``"hung"`` (wedged — no result, no retire,
        no heartbeat), or ``"fault"`` (killed, or transient retry budget
        exhausted). Transients are retried through ``retry`` — each
        attempt advances the slot's injector seq, the column-runner
        convention — and the per-probe virtual wall feeds the straggler
        detector."""
        if self.injector is None:
            return "ok"
        t0 = self.clock()
        try:
            self.retry.call(self.injector.on_dispatch, s)
            return "ok"
        except ColumnHungError:
            return "hung"
        except (ColumnDeadError, TransientDispatchError):
            return "fault"
        finally:
            self._record_time(s, self.clock() - t0)

    def _record_time(self, s: int, dt: float) -> None:
        if self.straggler is not None and s not in self.dead_slots:
            self.straggler.record(s, dt)

    def _beat(self, s: int) -> None:
        if self.heartbeats is not None:
            self.heartbeats.beat(s, self.clock())

    # ------------------------------------------------------ engine hooks

    def _admissible(self, s: int) -> bool:
        # hung slots hold their (wedged) request, so the base free-slot
        # check already excludes them; dead_slots masks poisoned ones
        return super()._admissible(s)

    def _pre_dispatch_prefill(self, admitted: list) -> list:
        kept = []
        for s, req in admitted:
            # beat FIRST: admission registers the slot for liveness
            # monitoring, so a slot that wedges during its very first
            # prefill still times out (an unmonitored slot is neither
            # dead nor alive to `HeartbeatMonitor`)
            self._beat(s)
            status = self._probe(s)
            if status == "hung":
                self.hung.add(s)        # request occupies the slot with
                continue                # no cache effect; timeout resolves
            if status == "fault":
                self._evict(s)
                continue
            kept.append((s, req))
        return kept

    def _prefill_dispatch(self, batch):
        self.prefill_dispatches += 1
        return self.retry.call(super()._prefill_dispatch, batch)

    def _decode_dispatch(self, batch):
        # probe hung slots too: a wedged dispatch still burns virtual
        # time (`FaultInjector.on_dispatch` advances the clock before
        # raising), and that advance is what lets the heartbeat timeout
        # fire even when EVERY live slot is wedged
        for s, r in enumerate(self.live):
            if r is None:
                continue
            status = self._probe(s)
            if status == "hung":
                self.hung.add(s)
            elif status == "fault":
                self._evict(s)
        self.decode_steps += 1
        return self.retry.call(super()._decode_dispatch, batch)

    def _slot_retires(self, s: int) -> bool:
        return s not in self.hung

    def _on_retire(self, s: int, req: Request) -> None:
        self._beat(s)                   # a retired token IS a heartbeat

    def _on_finish(self, s: int, req: Request) -> None:
        if self.heartbeats is not None:
            self.heartbeats.forget(s)   # idle slots are not monitored
        self.deadlines.pop(req.rid, None)
        super()._on_finish(s, req)      # paged composition frees pages

    # -------------------------------------------------- the closed loop

    def _evict(self, s: int) -> None:
        """Poison slot ``s`` and requeue its request for replay: the slot
        leaves the admission set for good (degraded mode — the engine
        keeps serving on the survivors), monitors forget it, and its
        request goes back to the queue front carrying its generated
        prefix."""
        self.dead_slots.add(s)
        self.hung.discard(s)
        if self.heartbeats is not None:
            self.heartbeats.forget(s)
        if self.straggler is not None:
            self.straggler.forget(s)
        req = self.live[s]
        if req is not None:
            self.live[s] = None
            self.lens[s] = 0
            self._on_evict(req)   # paged composition frees stale pages
            self._requeue(req)
        self.evictions += 1

    def _requeue(self, req: Request) -> None:
        """Deterministic requeue: evicted requests re-enter at the queue
        FRONT (ahead of never-started work) in rid order among
        themselves, so the replay schedule is a pure function of the
        fault schedule."""
        req.replayed = True
        i = 0
        while (i < len(self.queue) and self.queue[i].replayed
               and self.queue[i].rid < req.rid):
            i += 1
        self.queue.insert(i, req)
        self.replays += 1

    def _supervise(self) -> list[int]:
        """Detection half of the loop: evict every slot whose heartbeat
        timed out (no token retired for ``heartbeat_timeout``) or that
        the straggler detector condemned. Returns the newly evicted
        slots; their requests are already requeued."""
        suspects: list[int] = []
        if self.heartbeats is not None:
            suspects += self.heartbeats.dead(self.clock())
        if self.straggler is not None:
            suspects += self.straggler.stragglers()
        newly = []
        for s in suspects:
            if 0 <= s < self.slots and s not in self.dead_slots:
                newly.append(s)
                self._evict(s)
        return newly

    def step(self):
        """One supervised engine step: expire stale queue entries, decode
        (with per-slot fault injection riding the dispatch hooks), then
        run the detection pass. Raises
        `runtime.fault.InsufficientHealthyWorkers` when work is pending
        and no healthy slot remains."""
        self._expire_queued()
        if not self.healthy_slots() and self._work_pending():
            raise InsufficientHealthyWorkers(
                "every engine slot is poisoned; pending requests cannot "
                "be served")
        finished = super().step()
        self._supervise()
        return finished


class FaultTolerantPagedEngine(FaultTolerantEngine, PagedEngine):
    """The paged engine under the full supervision closed loop — pure
    cooperative composition, no new code paths.

    The MRO stacks the two layers the way the hooks were designed for:
    admission runs FT's bounded/TTL `add_request` over the paged
    `InsufficientPages` check; `_prefill_dispatch`/`_decode_dispatch`
    wrap the paged fused dispatches in FT's probe/retry/counter;
    eviction (`_evict` → `_on_evict`) frees the dead slot's pages before
    the deterministic front-of-queue requeue, so a replay re-prefills
    prompt + generated prefix into FRESH pages; `_on_finish` releases
    pages after FT drops the monitors. Per-request sampling streams make
    the replayed continuation bit-identical to both the fault-free paged
    run and the dense run (`tests/test_engine_fault.py`).

    Accepts the union of both constructors' keyword arguments
    (``page_size``/``n_pages`` ride through `FaultTolerantEngine`'s
    ``**kwargs``)."""

"""Stage-graph layer: the biosignal graph must be BIT-IDENTICAL to the
pre-refactor fused kernel, and the registry/compiler error paths must be
typed.

The refactor's contract (`kernels/pipeline/graph.py` module docstring) is
that routing the legacy entries through the graph compiler changes ZERO
bits: the compiled body composes the same helpers in the same op order as
the frozen legacy bodies `kernel.py:pipeline_kernel` /
`kernel.py:pipeline_stream_kernel`, which this module keeps alive by
rebuilding the pre-refactor `pallas_call` from them verbatim and
comparing with `np.testing.assert_array_equal` (not allclose) across
(window, hop, outputs, ring_depth). The second half pins the
`stages.py` error taxonomy and exercises the authoring path end to end
with a brand-new throwaway graph — the `docs/STAGE_GRAPHS.md` recipe.
"""
import functools

import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.biosignal import make_app, synthetic_respiration
from repro.core.vwr import VWRSpec, resolve_block_rows
from repro.kernels.pipeline import asr as _asr  # noqa: F401 (registers the
#                                                 hann/power/logmel stages)
from repro.kernels.pipeline import graph as G
from repro.kernels.pipeline import stages as St
from repro.kernels.pipeline.kernel import (OUTPUTS, _as_output_dict,
                                           _out_shapes_specs,
                                           _table_operands, biosignal_graph,
                                           canonical_outputs, empty_outputs,
                                           min_stream_block_frames,
                                           pipeline_kernel, pipeline_pallas,
                                           pipeline_ring_pallas,
                                           pipeline_stream_kernel,
                                           pipeline_stream_pallas,
                                           resolve_stream_block_frames,
                                           ring_chunk_samples,
                                           stream_frame_count)


# ---------------------------------------------------------------------------
# The pre-refactor kernels, reconstructed from the frozen legacy bodies
# ---------------------------------------------------------------------------

def _legacy_frames(frames, taps, w, b, *, fft_size=512, block_rows=None,
                   outputs=OUTPUTS):
    """The pre-refactor `pipeline_pallas`: the frozen `pipeline_kernel`
    body behind the exact pallas_call the entry used to build itself."""
    outputs = canonical_outputs(outputs)
    R, S = frames.shape
    rb = resolve_block_rows(R, S * 4, spec=VWRSpec(n_vwrs=4),
                            override=block_rows)
    operands, op_specs = _table_operands(taps, w, b, fft_size)
    F, C = w.shape
    out_shape, out_specs = _out_shapes_specs(R, S, F, C, rb, frames.dtype,
                                             outputs)
    outs = pl.pallas_call(
        functools.partial(pipeline_kernel, n_taps=int(taps.shape[0]),
                          fft_size=fft_size, outputs=outputs),
        out_shape=out_shape,
        in_specs=[pl.BlockSpec((rb, S), lambda i: (i, 0),
                               memory_space=pltpu.VMEM)] + op_specs,
        out_specs=out_specs,
        grid=(R // rb,),
        interpret=True,
    )(jnp.asarray(frames), *operands)
    return _as_output_dict(outs, outputs, R)


def _legacy_stream(signal, taps, w, b, *, window, hop, fft_size=512,
                   block_frames=None, outputs=OUTPUTS):
    """The pre-refactor `pipeline_stream_pallas`: the frozen
    `pipeline_stream_kernel` body behind the identical framing/padding
    arithmetic the entry used to own (now `graph.py:graph_stream_call`)."""
    outputs = canonical_outputs(outputs)
    signal = jnp.asarray(signal)
    (S,) = signal.shape
    n = stream_frame_count(S, window, hop)
    F, C = w.shape
    if n == 0:
        return empty_outputs(window, F, C, signal.dtype, outputs)
    rb = resolve_stream_block_frames(n, window, hop, block_frames)
    n_blocks = -(-n // rb)
    L = rb * hop
    n_tails = min_stream_block_frames(window, hop) if window > hop else 0
    total = -(-(n_blocks * rb + n_tails) // rb) * L
    sig = signal[:min(S, total)]
    if total > sig.shape[0]:
        sig = jnp.concatenate(
            [sig, jnp.zeros((total - sig.shape[0],), sig.dtype)])
    sig2 = sig.reshape(1, total)
    in_specs = [pl.BlockSpec((1, L), lambda j: (0, j),
                             memory_space=pltpu.VMEM)]
    for i in range(n_tails):
        in_specs.append(pl.BlockSpec(
            (1, hop), lambda j, i=i: (0, j * rb + rb + i),
            memory_space=pltpu.VMEM))
    operands, op_specs = _table_operands(taps, w, b, fft_size)
    out_shape, out_specs = _out_shapes_specs(n_blocks * rb, window, F, C, rb,
                                             signal.dtype, outputs)
    outs = pl.pallas_call(
        functools.partial(pipeline_stream_kernel,
                          n_taps=int(taps.shape[0]), fft_size=fft_size,
                          window=window, hop=hop, block_frames=rb,
                          outputs=outputs, n_tails=n_tails),
        out_shape=out_shape,
        in_specs=in_specs + op_specs,
        out_specs=out_specs,
        grid=(n_blocks,),
        interpret=True,
    )(*((sig2,) * (1 + n_tails)), *operands)
    return _as_output_dict(outs, outputs, n)


def _assert_bitwise(out, ref):
    assert sorted(out) == sorted(ref), (sorted(out), sorted(ref))
    for k in ref:
        a, b = np.asarray(ref[k]), np.asarray(out[k])
        assert a.dtype == b.dtype, (k, a.dtype, b.dtype)
        np.testing.assert_array_equal(b, a, err_msg=k)


def _raw(n_samples, seed):
    sig, _ = synthetic_respiration(1, n_samples, seed=seed)
    return sig[0]


# ---------------------------------------------------------------------------
# Bit-identity: graph-compiled biosignal == pre-refactor kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,hop,n_samples", [
    (512, 128, 5000),              # deep overlap
    (512, 512, 3000),              # hop == window (no tail specs)
    (1024, 320, 7001),             # hop does not divide window
    (2048, 512, 2048 * 4 + 777),   # paper-default shape, ragged tail
    (2048, 512, 2048),             # exactly one frame
])
def test_stream_bit_identical_to_legacy_kernel(window, hop, n_samples):
    app = make_app()
    raw = _raw(n_samples, seed=window + hop)
    out = pipeline_stream_pallas(raw, app.fir_taps, app.svm_w, app.svm_b,
                                 window=window, hop=hop)
    ref = _legacy_stream(raw, app.fir_taps, app.svm_w, app.svm_b,
                         window=window, hop=hop)
    _assert_bitwise(out, ref)


@pytest.mark.parametrize("outputs", [
    None, ("filtered",), ("features", "class"), ("margin",),
    ("class", "filtered"),
])
def test_stream_outputs_subsets_bit_identical(outputs):
    """Every elision subset takes the same elided path on both sides —
    including ("filtered",), where the legacy body skipped stages 2-5
    via its special case and the graph compiler via `stages_to_run`."""
    app = make_app()
    raw = _raw(4000, seed=11)
    sel = canonical_outputs(outputs)
    out = pipeline_stream_pallas(raw, app.fir_taps, app.svm_w, app.svm_b,
                                 window=512, hop=160, outputs=sel)
    ref = _legacy_stream(raw, app.fir_taps, app.svm_w, app.svm_b,
                         window=512, hop=160, outputs=sel)
    assert sorted(out) == sorted(sel)
    _assert_bitwise(out, ref)


@pytest.mark.parametrize("outputs", [None, ("features", "class")])
def test_framed_bit_identical_to_legacy_kernel(outputs):
    app = make_app()
    sig, _ = synthetic_respiration(8, 2048, seed=5)
    sel = canonical_outputs(outputs)
    out = pipeline_pallas(sig, app.fir_taps, app.svm_w, app.svm_b,
                          outputs=sel)
    ref = _legacy_frames(sig, app.fir_taps, app.svm_w, app.svm_b,
                         outputs=sel)
    _assert_bitwise(out, ref)


@pytest.mark.parametrize("ring_depth", [1, 3])
def test_ring_bit_identical_to_legacy_per_slot(ring_depth):
    """The (slot, block) ring grid vs the legacy single-chunk kernel run
    slot by slot — the `ring_depth` leg of the bit-identity sweep."""
    window, hop, bw = 512, 128, 6
    span = ring_chunk_samples(window, hop, bw)
    app = make_app()
    ring = np.stack([np.asarray(_raw(span, seed=40 + r))
                     for r in range(ring_depth)])
    out = pipeline_ring_pallas(jnp.asarray(ring), app.fir_taps, app.svm_w,
                               app.svm_b, window=window, hop=hop)
    for r in range(ring_depth):
        ref = _legacy_stream(ring[r], app.fir_taps, app.svm_w, app.svm_b,
                             window=window, hop=hop)
        _assert_bitwise({k: v[r] for k, v in out.items()}, ref)


def test_zero_frame_path_matches_legacy_empty():
    app = make_app()
    out = pipeline_stream_pallas(jnp.zeros((100,), jnp.float32),
                                 app.fir_taps, app.svm_w, app.svm_b,
                                 window=2048, hop=512)
    F, C = app.svm_w.shape
    ref = empty_outputs(2048, F, C, jnp.float32)
    assert sorted(out) == sorted(ref)
    for k in ref:
        assert out[k].shape == ref[k].shape, k
        assert out[k].dtype == ref[k].dtype, k


# ---------------------------------------------------------------------------
# Authoring path end to end: a brand-new throwaway graph
# ---------------------------------------------------------------------------

@St.register_stage("_test_gain", operands=("gain",),
                   requires=("filtered",), produces=("gained",))
def _gain_body(state, tables, params):
    return {"gained": state["filtered"] * tables["gain"][0, 0]}


def test_new_graph_end_to_end():
    """The `docs/STAGE_GRAPHS.md` recipe on a minimal FIR+gain graph: a
    new registered stage, `build_graph`, and the generic stream entry —
    no edits to any shipped module."""
    g = G.build_graph(
        "_test_gain_graph", ("fir", "_test_gain"),
        (("gained", G.OutputSpec(("window",), "float32")),),
        ("fir_taps", "gain"),
        (("n_taps", 2), ("fft_size", 8)))
    assert g.n_taps == 2 and g.fft_size == 8
    assert g.output_names == ("gained",)
    taps = np.array([1.0, -0.5], np.float32)
    operands = (jnp.asarray(taps).reshape(1, 2),
                jnp.full((1, 1), 2.0, jnp.float32))
    window, hop, n = 16, 6, 100
    raw = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    out = G.graph_stream_pallas(jnp.asarray(raw), operands, graph=g,
                                window=window, hop=hop)
    n_frames = stream_frame_count(n, window, hop)
    assert out["gained"].shape == (n_frames, window)
    # host oracle: frame-local zero-history FIR, then the gain
    idx = np.arange(n_frames)[:, None] * hop + np.arange(window)[None, :]
    frames = raw[idx]
    xp = np.pad(frames, ((0, 0), (1, 0)))
    ref = 2.0 * (taps[0] * xp[:, 1:] + taps[1] * xp[:, :-1])
    np.testing.assert_allclose(np.asarray(out["gained"]), ref, rtol=1e-6)


def test_stages_to_run_elision():
    g = biosignal_graph(11, 12, 2, 512)

    def names(sel):
        return tuple(s.name for s in G.stages_to_run(g, sel))

    assert names(("filtered",)) == ()
    assert names(("features",)) == ("delineate", "biosignal_features")
    assert names(("class",)) == ("delineate", "biosignal_features", "svm")
    assert names(OUTPUTS) == ("delineate", "biosignal_features", "svm")


def test_graph_empty_outputs_shapes():
    g = biosignal_graph(11, 12, 2, 512)
    out = G.graph_empty_outputs(g, 2048, jnp.float32)
    assert out["filtered"].shape == (0, 2048)
    assert out["features"].shape == (0, 12)
    assert out["class"].shape == (0,) and out["class"].dtype == jnp.int32
    sub = G.graph_empty_outputs(g, 2048, jnp.float32, ("margin",))
    assert sorted(sub) == ["margin"] and sub["margin"].shape == (0, 2)


# ---------------------------------------------------------------------------
# Typed error taxonomy (`stages.py`)
# ---------------------------------------------------------------------------

_PARAMS = (("n_taps", 11), ("fft_size", 512))


def test_unknown_stage_error():
    with pytest.raises(St.UnknownStageError, match="unknown stage"):
        G.build_graph("g", ("fir", "nope"), (), ("fir_taps",), _PARAMS)
    with pytest.raises(St.UnknownStageError):
        St.get_stage("definitely_not_registered")


def test_operand_mismatch_unbound():
    with pytest.raises(St.OperandMismatchError, match="does not bind"):
        G.build_graph("g", ("fir", "hann"), (), ("fir_taps",), _PARAMS)


def test_operand_mismatch_unread():
    with pytest.raises(St.OperandMismatchError, match="read by no stage"):
        G.build_graph("g", ("fir",),
                      (("filtered", G.OutputSpec(("window",), "input")),),
                      ("fir_taps", "unused_table"), _PARAMS)


def test_operand_mismatch_unmet_dataflow():
    # power_spectrum requires "windowed", which nothing before it produces
    with pytest.raises(St.OperandMismatchError, match="no earlier stage"):
        G.build_graph("g", ("fir", "power_spectrum"), (),
                      ("fir_taps", "twiddle_re", "twiddle_im", "untangle"),
                      _PARAMS)


def test_graph_structure_errors():
    ok_out = (("filtered", G.OutputSpec(("window",), "input")),)
    with pytest.raises(St.StageGraphError, match="at least one stage"):
        G.build_graph("g", (), ok_out, (), _PARAMS)
    with pytest.raises(St.StageGraphError, match="first stage"):
        G.build_graph("g", ("delineate",), ok_out, (), _PARAMS)
    with pytest.raises(St.StageGraphError, match="only the first"):
        G.build_graph("g", ("fir", "fir"), ok_out, ("fir_taps",), _PARAMS)
    with pytest.raises(St.StageGraphError, match="missing param"):
        G.build_graph("g", ("fir",), ok_out, ("fir_taps",),
                      (("n_taps", 11),))
    with pytest.raises(St.StageGraphError, match="produced by no stage"):
        G.build_graph("g", ("fir",),
                      (("nope", G.OutputSpec(("window",))),),
                      ("fir_taps",), _PARAMS)


def test_duplicate_produces_error():
    @St.register_stage("_test_dup_filtered", requires=("filtered",),
                       produces=("filtered",))
    def _dup(state, tables, params):
        return {"filtered": state["filtered"]}

    with pytest.raises(St.StageGraphError, match="re-produces"):
        G.build_graph("g", ("fir", "_test_dup_filtered"),
                      (("filtered", G.OutputSpec(("window",), "input")),),
                      ("fir_taps",), _PARAMS)


def test_duplicate_stage_registration_error():
    with pytest.raises(St.StageGraphError, match="already registered"):
        St.register_stage("fir")(lambda state, tables, params: {})


def test_stage_kind_validation():
    with pytest.raises(St.StageGraphError, match="kind"):
        St.Stage("x", "bogus", (), (), (), lambda *a: {})
    with pytest.raises(St.OperandMismatchError, match="exactly one"):
        St.Stage("x", "fir", ("a", "b"), (), (), lambda *a: {})


def test_output_spec_dtype_validation():
    with pytest.raises(St.StageGraphError):
        G.OutputSpec((), "float64")
    spec = G.OutputSpec(("window", "n_mels"))
    assert spec.resolve(512, {"n_mels": 64}) == (512, 64)
    assert G.OutputSpec((), "input").np_dtype(jnp.int32) == jnp.int32


def test_canonical_graph_outputs_validation():
    g = biosignal_graph(11, 12, 2, 512)
    assert G.canonical_graph_outputs(g, None) == OUTPUTS
    assert G.canonical_graph_outputs(g, ("class", "filtered")) == \
        ("filtered", "class")
    with pytest.raises(St.StageGraphError, match="unknown outputs"):
        G.canonical_graph_outputs(g, ("bogus",))
    with pytest.raises(St.StageGraphError, match="not be empty"):
        G.canonical_graph_outputs(g, ())


def test_graph_registry():
    regs = G.registered_graphs()
    # force both lazy registrations through the lookup path
    G.get_graph_factory("biosignal"), G.get_graph_factory("asr")
    assert {"biosignal", "asr"} <= set(G.registered_graphs())
    with pytest.raises(St.UnknownGraphError, match="unknown graph"):
        G.get_graph_factory("not_a_graph")
    with pytest.raises(St.StageGraphError, match="already registered"):
        G.register_graph_factory("biosignal", lambda app: None)
    G.register_graph_factory("_test_nodefault", lambda app: None)
    with pytest.raises(St.StageGraphError, match="no default app"):
        G.default_app("_test_nodefault")
    del regs


def test_errors_are_value_errors():
    """Legacy ``except ValueError`` call sites keep catching."""
    for cls in (St.StageGraphError, St.UnknownStageError,
                St.OperandMismatchError, St.UnknownGraphError):
        assert issubclass(cls, ValueError), cls


def test_registered_stage_inventory():
    names = St.registered_stages()
    for want in ("fir", "delineate", "biosignal_features", "svm", "hann",
                 "power_spectrum", "logmel"):
        assert want in names, want

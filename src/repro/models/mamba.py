"""Mamba2 (SSD) block — scalar-per-head data-dependent decay + short conv.

Evaluators:
  * ``ssd_scan``    — per-token oracle.
  * ``ssd_chunked`` — chunk-parallel SSD (segsum decay matrix per head,
    lax.scan carries the (H,P,N) state across chunks).
Short causal conv1d(k=4) runs over the (x,B,C) channels; in the full system
it is served by the VWR-staged FIR Pallas kernel (kernels/fir) — the model
default uses the pure-jnp path so CPU tests and TPU kernels share one oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import P, fanin_std


def mamba_block_schema(cfg):
    d = cfg.d_model
    s = cfg.ssm
    d_in = s.expand * d
    H = d_in // s.head_size
    N = s.d_state
    conv_ch = d_in + 2 * N
    return {
        "norm": {"scale": P((d,), ("embed",), "ones")},
        "in_proj": P((d, 2 * d_in + 2 * N + H), ("embed", "mlp"), fanin_std(d)),
        "conv_w": P((s.conv_kernel, conv_ch), ("conv", "mlp"), fanin_std(s.conv_kernel)),
        "conv_b": P((conv_ch,), ("mlp",), 0.0),
        "A_log": P((H,), ("heads",), ("uniform", 0.0, 1.25)),
        "D": P((H,), ("heads",), "ones"),
        "dt_bias": P((H,), ("heads",), ("uniform", -4.6, -2.3)),
        "gn_scale": P((d_in,), ("mlp",), "ones"),
        "out_proj": P((d_in, d), ("mlp", "embed"), fanin_std(d_in)),
    }


def causal_conv1d(x, w, b, *, state=None):
    """x: (B,S,C); w: (k,C); depthwise causal conv.

    state: (B,k-1,C) trailing inputs from the previous call (decode), or None
    (train/prefill: left-pad with zeros). Returns (y, new_state).
    """
    B, S, C = x.shape
    k = w.shape[0]
    state_dtype = x.dtype if state is None else state.dtype
    if state is None:
        state = jnp.zeros((B, k - 1, C), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B,S+k-1,C)
    y = jnp.zeros((B, S, C), x.dtype)
    for i in range(k):  # k is tiny (4): unrolled taps == VWR circular shifts
        y = y + xp[:, i:i + S, :] * w[i].astype(x.dtype)
    y = y + b.astype(x.dtype)
    new_state = xp[:, S:, :].astype(state_dtype)
    return y, new_state


def ssd_scan(xh, dt, A, B_, C_, s0):
    """Oracle. xh: (B,S,H,P); dt: (B,S,H); B_,C_: (B,S,N); s0: (B,H,P,N)."""
    f32 = jnp.float32
    xs = (jnp.moveaxis(xh, 1, 0).astype(f32), jnp.moveaxis(dt, 1, 0).astype(f32),
          jnp.moveaxis(B_, 1, 0).astype(f32), jnp.moveaxis(C_, 1, 0).astype(f32))

    def step(S, t):
        x_, dt_, b_, c_ = t
        a = jnp.exp(dt_ * A[None])                          # (B,H) in (0,1)
        S = a[..., None, None] * S + jnp.einsum(
            "bhp,bn->bhpn", x_ * dt_[..., None], b_)
        y = jnp.einsum("bhpn,bn->bhp", S, c_)
        return S, y

    s_fin, y = jax.lax.scan(step, s0.astype(f32), xs)
    return jnp.moveaxis(y, 0, 1), s_fin                     # (B,S,H,P)


def ssd_chunked(xh, dt, A, B_, C_, s0, chunk: int):
    """Chunk-parallel SSD. Scalar per-head decay => (L,L) segsum matrix."""
    B, S_in, H, Pd = xh.shape
    N = B_.shape[-1]
    L = min(chunk, S_in)
    if S_in % L:  # pad: x=0 (no writes), dt=0 (decay 1) => state exact
        p2 = (0, -S_in % L)
        xh = jnp.pad(xh, ((0, 0), p2, (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), p2, (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), p2, (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), p2, (0, 0)))
    B, S, H, Pd = xh.shape
    nc = S // L
    f32 = jnp.float32
    xc = (xh.astype(f32) * dt[..., None].astype(f32)).reshape(B, nc, L, H, Pd)
    ac = (dt.astype(f32) * A[None, None].astype(f32)).reshape(B, nc, L, H)
    bc = B_.reshape(B, nc, L, N).astype(f32)
    cc = C_.reshape(B, nc, L, N).astype(f32)
    mask = jnp.tril(jnp.ones((L, L), bool))                 # inclusive

    def chunk_step(Sst, xs):
        xb, ab, bb, cb = xs                                 # (B,L,...)
        ca = jnp.cumsum(ab, axis=1)                         # (B,L,H) inclusive
        # decay matrix D[t,j] = exp(ca_t - ca_j), j <= t (y_t uses S_t)
        expo = ca[:, :, None] - ca[:, None, :, :]           # (B,L,L,H)
        Dm = jnp.where(mask[None, :, :, None], jnp.exp(expo), 0.0)
        cb_bt = jnp.einsum("bln,bmn->blm", cb, bb)          # (B,L,L)
        y = jnp.einsum("blm,blmh,bmhp->blhp", cb_bt, Dm, xb)
        # inter-chunk
        y = y + jnp.einsum("bln,bhpn,blh->blhp", cb, Sst, jnp.exp(ca))
        # state update
        tot = ca[:, -1]                                     # (B,H)
        kd = jnp.exp(tot[:, None] - ca)                     # (B,L,H)
        Snew = jnp.exp(tot)[..., None, None] * Sst + jnp.einsum(
            "blhp,bln,blh->bhpn", xb, bb, kd)
        return Snew, y

    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(ac, 1, 0),
          jnp.moveaxis(bc, 1, 0), jnp.moveaxis(cc, 1, 0))
    s_fin, y = jax.lax.scan(chunk_step, s0.astype(f32), xs)
    return jnp.moveaxis(y, 0, 1).reshape(B, S, H, Pd)[:, :S_in], s_fin


def mamba_block(params, x, state, cfg, *, mode: str):
    """x: (B,S,d). state: dict(conv: (B,k-1,C), s: (B,H,P,N))."""
    from repro.models.layers import apply_norm

    s = cfg.ssm
    B, S, d = x.shape
    d_in = s.expand * d
    H = d_in // s.head_size
    Pd, N = s.head_size, s.d_state
    cd = x.dtype

    h = apply_norm(params["norm"], x, kind="rmsnorm", eps=cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", h, params["in_proj"].astype(cd))
    z, xr, B_, C_, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)

    conv_in = jnp.concatenate([xr, B_, C_], axis=-1)
    conv_out, conv_state = causal_conv1d(
        conv_in, params["conv_w"], params["conv_b"], state=state["conv"])
    conv_out = jax.nn.silu(conv_out)
    xr, B_, C_ = jnp.split(conv_out, [d_in, d_in + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,S,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))              # (H,)
    xh = xr.reshape(B, S, H, Pd)

    if mode == "decode":
        a = jnp.exp(dt[:, 0] * A[None])
        Snew = a[..., None, None] * state["s"] + jnp.einsum(
            "bhp,bn->bhpn",
            xh[:, 0].astype(jnp.float32) * dt[:, 0, :, None],
            B_[:, 0].astype(jnp.float32))
        y = jnp.einsum("bhpn,bn->bhp", Snew, C_[:, 0].astype(jnp.float32))
        y = y[:, None]
        s_fin = Snew
    else:
        y, s_fin = ssd_chunked(xh, dt, A, B_, C_, state["s"], s.chunk_size)

    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_in)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.norm_eps) * params["gn_scale"].astype(jnp.float32)
    out = jnp.einsum("bse,ed->bsd", y.astype(cd), params["out_proj"].astype(cd))
    return x + out, {"conv": conv_state, "s": s_fin}


def mamba_state_schema(cfg, batch: int):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_size
    conv_ch = d_in + 2 * s.d_state
    return {
        "conv": P((batch, s.conv_kernel - 1, conv_ch),
                  ("batch", None, "mlp"), 0.0, jnp.float32),
        "s": P((batch, H, s.head_size, s.d_state),
               ("batch", "heads", None, None), 0.0, jnp.float32),
    }

"""One admission front-end for EVERY traffic class the repo serves.

Before this module the classes had unrelated front doors:
LM requests went through `Engine.submit` /
`FaultTolerantEngine.submit`, biosignal streams through
`ColumnScheduler.open_stream` — three verbs, two queues, no shared
policy. `ServeFrontend` replaces all three with ONE verb:

    front = ServeFrontend(engine=eng, scheduler=sched)
    t_lm = front.submit(Request(0, [3, 1, 4], max_new=8))
    t_bio = front.submit(StreamOpen(stream_id="sensor-7", app=app,
                                    cfg=cfg))
    t_asr = front.submit(AsrTranscribe(1, audio))
    front.run()
    tokens = t_lm.result().out       # the finished Request
    stream = t_bio.result()          # the placed BiosignalStream
    asr = t_asr.result()             # AsrResult: fused log-mel + tokens

Every submission returns a typed `Ticket` (id, class, status, result
accessor); the old entry points remain as `DeprecationWarning` shims for
one release (`Engine.submit`, `ColumnScheduler.open_stream`).

THE ASR CLASS — `AsrTranscribe` is speech work that spans BOTH halves
of the runtime: at dispatch the raw waveform runs through the fused
stage-graph feature front-end (`kernels/pipeline/asr.py:asr_graph` via
`kernels/pipeline/ops.py:graph_pipeline_stream` — one `pallas_call`,
in-kernel framing), then a decoder `Request` is admitted to the
enc-dec LM engine (the `whisper_medium` reduced config path); the
ticket resolves to an `AsrResult` pairing the log-mel features with
the finished request. It shares the LM engine's backpressure
(`QueueFull` leaves it queued) and its QoS weight is independent.

ADMISSION POLICY — one queue, per-class QoS weights. Work of all
classes waits in a single arrival-ordered queue; `pump` drains it by
WEIGHTED ROUND-ROBIN over the classes (default ``{"lm": 1,
"stream": 1, "asr": 1}``), so a burst of one class cannot starve the
others — a class with weight w dispatches at most w items per cycle
while another class has work waiting. Downstream backpressure is
respected,
not retried: a `QueueFull` from the fault-tolerant engine leaves the
ticket QUEUED for the next pump; a typed rejection (`PromptTooLong`,
`InsufficientPages`, `RequestExpired`, `InsufficientHealthyWorkers`)
fails the ticket and stores the error for `Ticket.result` to re-raise.

RE-PROVISIONING — the two classes share one device fleet. Under LM
load, `lend_columns` withdraws the least-loaded stream columns
(`ColumnScheduler.withdraw` — streams drain onto survivors, the device
is handed back to the caller for the LM class); `return_columns`
restores them (`ColumnScheduler.restore`). The supervision layers of
PR 7/8 ride along unchanged underneath — the front-end is policy, the
engines keep their own closed loops.

See `docs/ARCHITECTURE.md` (unified admission) for where this sits in
the serving-runtime map.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serve.engine import Request
from repro.serve.errors import (QueueFull, RequestExpired, ServeError,
                                TicketNotReady)

__all__ = ["StreamOpen", "AsrTranscribe", "AsrResult", "Ticket",
           "ServeFrontend"]


@dataclasses.dataclass(frozen=True)
class StreamOpen:
    """The stream-class work item: everything
    `ColumnScheduler.place_stream` needs to admit + construct a
    `BiosignalStream`. The stream-side twin of the LM `Request`."""
    stream_id: object
    app: object = None
    cfg: object = None


@dataclasses.dataclass(frozen=True)
class AsrTranscribe:
    """The asr-class work item: one utterance end to end.

    ``audio`` is the raw 1-D waveform; at dispatch it is featurized by
    the fused ``"asr"`` stage graph (pre-emphasis FIR -> Hann -> packed
    rFFT power -> log-mel, ONE `pallas_call` with in-kernel
    (window, hop) framing) and a decoder `Request` — ``prompt`` tokens
    (default ``[0]``, the start-of-transcript placeholder), ``max_new``
    budget — is admitted to the enc-dec engine under the same rid.
    ``app`` is an `asr.py:AsrFrontendApp` (None = registered default:
    16 kHz, 512-point FFT, 64 mels)."""
    rid: int
    audio: object
    window: int = 512
    hop: int = 160
    app: object = None
    max_new: int = 16
    prompt: object = None


@dataclasses.dataclass(frozen=True)
class AsrResult:
    """What an asr-class `Ticket.result` returns: the fused log-mel
    features (n_frames, n_mels) computed at dispatch, paired with the
    finished engine `Request` (decoded ids in ``request.out``)."""
    rid: int
    features: object
    request: object

    @property
    def tokens(self) -> list:
        return self.request.out


@dataclasses.dataclass
class Ticket:
    """Typed handle for one submission, either class.

    ``status`` walks queued -> running -> done (LM work decodes across
    engine steps) or queued -> done (a stream placement is synchronous),
    or lands on failed with the typed rejection stored. `result` is the
    only accessor: the finished `Request` for LM work, the placed
    `BiosignalStream` for stream work; it re-raises the stored error
    for failed tickets and raises `TicketNotReady` before completion."""
    tid: int
    work_class: str                 # "lm" | "stream" | "asr"
    status: str = "queued"
    _result: object = None
    _error: Optional[BaseException] = None

    def result(self):
        if self.status == "failed":
            raise self._error
        if self.status != "done":
            raise TicketNotReady(self.tid, self.status)
        return self._result

    def _finish(self, result) -> None:
        self._result, self.status = result, "done"

    def _fail(self, err: BaseException) -> None:
        self._error, self.status = err, "failed"


class ServeFrontend:
    """The unified front door (see the module docstring).

    ``engine`` serves the LM class (`Engine` or any of its supervised /
    paged subclasses), ``scheduler`` the stream class; either may be
    None when only one class is deployed. ``qos`` maps class name to
    round-robin weight."""

    def __init__(self, *, engine=None, scheduler=None,
                 qos: Optional[dict] = None):
        self.engine = engine
        self.scheduler = scheduler
        self.qos = dict(qos) if qos is not None else \
            {"lm": 1, "stream": 1, "asr": 1}
        assert all(w >= 1 for w in self.qos.values()), self.qos
        self.tickets: list[Ticket] = []
        self._pending: list[tuple] = []   # (ticket, work, kwargs)
        self._by_rid: dict = {}           # live LM/ASR rid -> ticket
        self._features: dict = {}         # live ASR rid -> log-mel array
        self.lent: list[tuple] = []       # (column, device) on loan to LM

    # ---------------------------------------------------------- admission

    def submit(self, work, **kwargs) -> Ticket:
        """THE admission verb for every class: an LM `Request`, a
        `StreamOpen`, or an `AsrTranscribe`. Returns the `Ticket`
        immediately; dispatch happens on the next `pump` (so QoS
        weighting sees the whole arrival batch, and downstream
        backpressure never raises out of submit)."""
        if isinstance(work, Request):
            cls = "lm"
            if self.engine is None:
                raise ValueError("no engine configured for LM work")
        elif isinstance(work, StreamOpen):
            cls = "stream"
            if self.scheduler is None:
                raise ValueError("no scheduler configured for stream work")
        elif isinstance(work, AsrTranscribe):
            cls = "asr"
            if self.engine is None:
                raise ValueError("no engine configured for ASR work")
        else:
            raise TypeError(
                f"submit() takes a Request, a StreamOpen, or an "
                f"AsrTranscribe, got {type(work).__name__}")
        t = Ticket(len(self.tickets), cls)
        self.tickets.append(t)
        self._pending.append((t, work, kwargs))
        return t

    def _dispatch(self, ticket: Ticket, work, kwargs) -> None:
        if ticket.work_class == "lm":
            self.engine.add_request(work, **kwargs)
            self._by_rid[work.rid] = ticket
            ticket.status = "running"
        elif ticket.work_class == "asr":
            self._dispatch_asr(ticket, work, kwargs)
        else:
            stream = self.scheduler.place_stream(
                work.app, work.cfg, stream_id=work.stream_id, **kwargs)
            ticket._finish(stream)

    def _dispatch_asr(self, ticket: Ticket, work: AsrTranscribe,
                      kwargs) -> None:
        """Featurize on the fused stage-graph path, then admit the
        decoder request. Features are computed BEFORE `add_request` so
        engine backpressure (`QueueFull`) re-dispatches cheaply: the
        stash under the rid survives and is reused on the retry."""
        if work.rid not in self._features:
            from repro.kernels.pipeline.ops import graph_pipeline_stream

            feats = graph_pipeline_stream(
                "asr", work.app, work.audio, window=work.window,
                hop=work.hop, outputs=("logmel",))["logmel"]
            self._features[work.rid] = feats
        prompt = list(work.prompt) if work.prompt is not None else [0]
        self.engine.add_request(Request(work.rid, prompt,
                                        max_new=work.max_new), **kwargs)
        self._by_rid[work.rid] = ticket
        ticket.status = "running"

    def pump(self) -> int:
        """Drain the unified queue by weighted round-robin over the
        classes. Returns the number of submissions dispatched. A
        `QueueFull` leaves the remaining LM tickets queued (backpressure
        — the engine will make room as requests finish); any other
        `ServeError` fails that ticket and keeps pumping."""
        dispatched = 0
        blocked: set[str] = set()
        progress = True
        while progress and len(blocked) < len(self.qos):
            progress = False
            for cls, weight in self.qos.items():
                if cls in blocked:
                    continue
                for _ in range(weight):
                    item = next((p for p in self._pending
                                 if p[0].work_class == cls), None)
                    if item is None:
                        break
                    try:
                        self._dispatch(*item)
                    except QueueFull:
                        blocked.add(cls)
                        break
                    except ServeError as e:
                        item[0]._fail(e)
                        self._features.pop(getattr(item[1], "rid", None),
                                           None)
                    self._pending.remove(item)
                    dispatched += 1
                    progress = True
        return dispatched

    # --------------------------------------------------------- completion

    def _resolve_engine(self, done) -> None:
        for req in done:
            t = self._by_rid.pop(req.rid, None)
            if t is None:
                continue
            if t.work_class == "asr":
                t._finish(AsrResult(req.rid,
                                    self._features.pop(req.rid, None), req))
            else:
                t._finish(req)
        # TTL-shed requests surface as failed tickets, not silent loss
        for req in getattr(self.engine, "expired", ()):
            t = self._by_rid.pop(req.rid, None)
            if t is not None:
                self._features.pop(req.rid, None)
                t._fail(RequestExpired(req.rid, 0.0))

    def run(self, max_steps: int = 1000) -> list[Ticket]:
        """Pump + serve until every LM ticket resolves (stream tickets
        resolve at dispatch). Alternates admission pumps with
        `Engine.run_to_completion` so backpressured tickets re-enter as
        the engine frees queue space. Returns all tickets ever issued."""
        while True:
            n = self.pump()
            inflight = bool(self._by_rid)
            if self.engine is not None and inflight:
                done = self.engine.run_to_completion(max_steps=max_steps)
                self._resolve_engine(done)
            queued = any(t.status == "queued" for t in self.tickets)
            if not queued and not self._by_rid:
                break
            if n == 0 and not inflight:
                break   # wedged: nothing dispatched, nothing in flight
        return list(self.tickets)

    # ----------------------------------------------------- re-provisioning

    def lend_columns(self, n: int = 1) -> list:
        """Withdraw the ``n`` least-loaded healthy stream columns and
        hand their DEVICES to the LM class (the drain moves re-pin the
        columns' streams onto survivors first). The loans stack in
        ``lent`` until `return_columns`."""
        devices = []
        for _ in range(n):
            loads = self.scheduler.loads()
            col = min(self.scheduler.healthy_columns(),
                      key=lambda c: (loads[c], c))
            device, _moves = self.scheduler.withdraw(col)
            self.lent.append((col, device))
            devices.append(device)
        return devices

    def return_columns(self) -> list[int]:
        """Restore every lent column to the stream scheduler (LIFO —
        the reverse of the lend order). Returns the restored columns."""
        restored = []
        while self.lent:
            col, _device = self.lent.pop()
            self.scheduler.restore(col)
            restored.append(col)
        return restored

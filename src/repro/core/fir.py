"""FIR filtering on the VWR dataflow (paper §4.4.1: 11-tap FIR).

The paper maps the FIR across both RC columns working on different slices of
the input; each tap is a shifted multiply-accumulate, with the shuffle
unit's *circular shift* providing the slice boundary words. In JAX the taps
unroll to k shifted FMAs over the staged block — the same structure the
Pallas kernel (kernels/fir) executes per VMEM tile.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def fir_direct(x, taps):
    """Causal FIR: y[t] = sum_i taps[i] * x[t - i]. x: (..., S)."""
    k = taps.shape[-1]
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(k - 1, 0)])
    y = jnp.zeros_like(x)
    for i in range(k):  # unrolled taps == VWR circular shifts
        y = y + taps[i] * xp[..., k - 1 - i: k - 1 - i + x.shape[-1]]
    return y


def fir_reference(x, taps):
    """Oracle via np.convolve semantics ('full' truncated to causal)."""
    x_np = np.asarray(x, np.float64)
    t_np = np.asarray(taps, np.float64)
    out = np.apply_along_axis(
        lambda row: np.convolve(row, t_np)[: row.shape[0]], -1, x_np)
    return out.astype(np.asarray(x).dtype)


def lowpass_taps(n_taps: int = 11, cutoff: float = 0.15) -> np.ndarray:
    """Hamming-windowed sinc low-pass — the biosignal preprocessing filter
    (the paper's MBioTracker preprocess step uses an 11-tap FIR)."""
    m = n_taps - 1
    t = np.arange(n_taps) - m / 2
    h = np.sinc(2 * cutoff * t) * 2 * cutoff
    w = 0.54 - 0.46 * np.cos(2 * np.pi * np.arange(n_taps) / m)
    h = h * w
    return (h / h.sum()).astype(np.float32)

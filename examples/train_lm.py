"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
the structured synthetic stream, with checkpoints and resume.

The config is qwen1.5-0.5b's family scaled to ~100M params (8 layers,
d_model 512, vocab 32k). On CPU this takes a few minutes for 200 steps;
pass --steps 30 for a quick look. Loss must drop well below ln(vocab).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.models.layers import param_count
from repro.train import optim
from repro.train.loop import LoopConfig, train
from repro.train.step import init_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

cfg = dataclasses.replace(
    get_config("qwen1.5-0.5b"),
    name="qwen-100m", num_layers=12, d_model=768, num_heads=12,
    num_kv_heads=12, head_dim=64, d_ff=2048, vocab_size=32768,
    tie_embeddings=True, q_chunk=128, kv_chunk=128, tp_pad=1,
    param_dtype=jax.numpy.float32, compute_dtype=jax.numpy.float32)
model = build_model(cfg)
print(f"params: {param_count(model.schema) / 1e6:.1f} M")

mesh = make_local_mesh(data=len(jax.devices()), model=1)
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                global_batch=args.batch, structure=23)
oc = optim.OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
abstract = {"tokens": jax.ShapeDtypeStruct((args.batch, args.seq), np.int32),
            "labels": jax.ShapeDtypeStruct((args.batch, args.seq), np.int32)}
with mesh:
    bundle = make_train_step(model, oc, mesh, abstract)
    state = init_state(model, oc)
    lc = LoopConfig(n_steps=args.steps, ckpt_every=max(50, args.steps // 4),
                    ckpt_dir=args.ckpt_dir, log_every=10)
    state, hist = train(model, bundle, dc, lc, state)
first, last = hist[0]["loss"], hist[-1]["loss"]
print(f"loss {first:.3f} -> {last:.3f} (ln vocab = "
      f"{np.log(cfg.vocab_size):.2f})")
if len(hist) >= 3:
    assert last < first, "loss must decrease"
print("train_lm OK")

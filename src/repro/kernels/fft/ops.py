"""Public jit'd API for the FFT kernel + real-FFT packing wrapper."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fft.kernel import fft_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fft(re, im=None, *, inverse: bool = False,
        block_rows: int | None = None, autotune: bool = False):
    """Batched complex FFT (R, N) via the Pallas kernel.

    ``autotune=True`` picks the row-block from measured candidates (cached
    per shape) instead of the static VWRSpec budget."""
    if im is None:
        im = jnp.zeros_like(re)
    interp = _interpret()
    if autotune and block_rows is None:
        from repro.core.autotune import tuned_block_rows

        R, N = re.shape
        block_rows = tuned_block_rows(
            "fft", R, (N, str(re.dtype), inverse),
            lambda rb: fft_pallas(re, im, inverse=inverse, interpret=interp,
                                  block_rows=rb))
    return fft_pallas(re, im, inverse=inverse, interpret=interp,
                      block_rows=block_rows)


def rfft(x):
    """Real FFT via the paper's N-real -> N/2-complex packing; untangle on
    the host side of the kernel (cheap O(N) epilogue)."""
    from repro.core.fft import untangle_rfft

    n = x.shape[-1]
    zr, zi = x[..., 0::2], x[..., 1::2]
    Zr, Zi = fft(zr, zi)
    m = n // 2
    ang = -2.0 * np.pi * np.arange(m) / n
    wr = jnp.asarray(np.cos(ang), Zr.dtype)
    wi = jnp.asarray(np.sin(ang), Zr.dtype)
    return untangle_rfft(Zr, Zi, wr, wi)

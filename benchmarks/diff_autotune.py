"""Diff two autotune artifacts (BENCH_autotune.json) across commits, and
GATE the pinned-shape perf records.

CI's bench smoke writes the measured block-size winners next to the
BENCH_*.json perf records; this tool compares the current commit's winners
against the previous run's artifact and prints added / removed / changed
entries, so a perf regression that traces back to a different measured
block choice is visible in the job log.

``--gate`` promotes the diff from informational to a failure on the PINNED
shapes: entries `core.autotune.record_pinned` wrote from the bench run's
own paired reps (table5's stream/pipeline headline shapes). A pinned shape
fails when its runner-normalized metric regresses beyond a variance
threshold derived from the two runs' own rep spreads:

* only entries carrying a paired ``ratio`` in BOTH runs (fused-vs-baseline
  speedup, measured ALTERNATELY in one rep loop) are gated — absolute wall
  times are not comparable across heterogeneous CI runners, a same-run
  paired ratio is;
* ratio-less or mixed records are reported informationally, never failed
  (gating raw us across different runner hardware would flap).

Raw winner drift (a different measured block choice) stays informational
even under ``--gate`` — on shared runners near-tied candidates flip on
machine noise; the gate fires only when the pinned perf actually moved.

A MISSING or UNREADABLE baseline is never a silent pass: the gate prints
an explicit "no baseline, gate SKIPPED" warning and exits with the
distinct code ``EXIT_NO_BASELINE`` (3) — so a broken artifact download
cannot masquerade as a green gate. CI (where the first run on a fresh
repo legitimately has no baseline) passes ``--missing-baseline-ok`` to
turn that path into a loudly-labelled success instead.

Usage:  python -m benchmarks.diff_autotune OLD.json NEW.json
            [--strict|--gate] [--missing-baseline-ok]
"""
from __future__ import annotations

import argparse
import json
import sys

# tolerance floor: rep spread on a quiet machine is a few %, but CI
# neighbours can inflate it — never gate tighter than this
RATIO_FLOOR = 0.10
SPREAD_MULT = 3.0
# distinct exit path for "the baseline artifact never arrived": neither
# the green 0 nor the regression 1
EXIT_NO_BASELINE = 3


def _read(path: str):
    """Parsed artifact, or None when missing/unreadable (the caller turns
    that into the explicit no-baseline path — never a silent pass)."""
    try:
        with open(path) as f:
            return json.load(f)
    except Exception as e:
        print(f"WARNING: cannot read {path}: {type(e).__name__}: {e}",
              file=sys.stderr)
        return None


def _winners(data: dict) -> dict:
    return {json.dumps(e["key"]): int(e["block_rows"])
            for e in data.get("autotune_winners", [])}


def diff(old: dict, new: dict) -> list[str]:
    lines = []
    for k in sorted(new.keys() - old.keys()):
        lines.append(f"+ {k} -> {new[k]}")
    for k in sorted(old.keys() - new.keys()):
        lines.append(f"- {k} (was {old[k]})")
    for k in sorted(old.keys() & new.keys()):
        if old[k] != new[k]:
            lines.append(f"~ {k}: {old[k]} -> {new[k]}")
    return lines


def gate_pinned(old: dict, new: dict) -> tuple[list[str], list[str]]:
    """Compare pinned perf records; returns (report, failures)."""
    report, failures = [], []
    for name in sorted(old.keys() & new.keys()):
        o, n = old[name], new[name]
        spread = max(o.get("spread", 0.0), n.get("spread", 0.0))
        if "ratio" in o and "ratio" in n:
            tol = max(RATIO_FLOOR, SPREAD_MULT * spread)
            drop = 1.0 - n["ratio"] / max(o["ratio"], 1e-9)
            line = (f"{name}: paired ratio {o['ratio']:.2f}x -> "
                    f"{n['ratio']:.2f}x (tol {tol:.0%}, rep spread "
                    f"{spread:.0%})")
            if drop > tol:
                failures.append(f"{line}  REGRESSED {drop:.0%}")
            else:
                report.append(f"{line}  ok")
        else:
            # no paired ratio on one side: raw us across (possibly
            # different) runner hardware is not gateable — report only
            report.append(f"{name}: {o['us']:.1f}us -> {n['us']:.1f}us "
                          f"(no paired ratio; informational)")
    for name in sorted(new.keys() - old.keys()):
        report.append(f"{name}: new pinned shape (no previous record)")
    for name in sorted(old.keys() - new.keys()):
        failures.append(f"{name}: pinned record DISAPPEARED — the bench "
                        f"no longer measures this shape")
    return report, failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("old")
    ap.add_argument("new")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero when any winner changed")
    ap.add_argument("--gate", action="store_true",
                    help="fail on pinned-shape perf regressions beyond the "
                         "paired-rep variance threshold (winner drift "
                         "alone stays informational)")
    ap.add_argument("--missing-baseline-ok", action="store_true",
                    help="exit 0 (instead of the distinct no-baseline code "
                         f"{EXIT_NO_BASELINE}) when the OLD artifact is "
                         "missing/unreadable — for the legitimate "
                         "first-run-on-a-fresh-repo case; the skip is "
                         "still printed loudly")
    args = ap.parse_args()
    old_data = _read(args.old)
    # the current run's artifact must always parse: a broken NEW file is
    # a bench bug, not a missing baseline
    new_data = _read(args.new)
    if new_data is None:
        print(f"diff_autotune: current artifact {args.new} unreadable")
        raise SystemExit(1)
    if old_data is None:
        print(f"WARNING: no baseline ({args.old} missing/unreadable), "
              f"gate SKIPPED - nothing was compared")
        raise SystemExit(0 if args.missing_baseline_ok else EXIT_NO_BASELINE)
    old, new = _winners(old_data), _winners(new_data)
    lines = diff(old, new)
    if not lines:
        print(f"autotune winners unchanged ({len(new)} entries)")
    else:
        print(f"autotune winners changed ({len(old)} -> {len(new)} entries):")
        for line in lines:
            print(" ", line)
    if args.gate:
        report, failures = gate_pinned(old_data.get("pinned", {}),
                                       new_data.get("pinned", {}))
        for line in report:
            print("  pinned:", line)
        for line in failures:
            print("  pinned:", line)
        if failures:
            print(f"pinned-shape gate FAILED ({len(failures)} regression(s))")
            raise SystemExit(1)
        print(f"pinned-shape gate ok ({len(report)} shape(s))")
    if lines and args.strict:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

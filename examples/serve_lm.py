"""Serve a small model with batched requests through the unified
admission front-end (typed tickets over the continuous-batching engine,
greedy decode over 4 slots), then re-serve the same traffic through the
fault-tolerant supervision layer with a slot killed mid-decode — the
replayed outputs must be bit-identical.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model, init_model_params
from repro.serve.engine import Engine, Request
from repro.serve.engine_fault import (FaultInjector, FaultTolerantEngine,
                                      VirtualClock)
from repro.serve.frontend import ServeFrontend

cfg = reduced(get_config("h2o-danube-3-4b"))   # exercises SWA decode
model = build_model(cfg)
params = init_model_params(model)
compiled = Engine.compile_model(model)
eng = Engine(model, params, slots=4, max_len=96, compiled=compiled)

rng = np.random.default_rng(0)
prompts = {rid: rng.integers(1, cfg.vocab_size,
                             size=int(rng.integers(2, 6))).tolist()
           for rid in range(6)}
front = ServeFrontend(engine=eng)
tickets = [front.submit(Request(rid, list(p), max_new=12))
           for rid, p in prompts.items()]

t0 = time.perf_counter()
front.run()
done = [t.result() for t in tickets]
dt = time.perf_counter() - t0
for r in sorted(done, key=lambda r: r.rid):
    print(f"req {r.rid}: {r.prompt} -> {r.out}")
tok = sum(len(r.out) for r in done)
print(f"{len(done)} requests, {tok} tokens in {dt:.1f}s "
      f"({tok / dt:.1f} tok/s, CPU)")
assert len(done) == 6 and all(len(r.out) == 12 for r in done)

# same traffic, supervised, with slot 0 killed at its 4th dispatch
# (mid-decode): the poisoned slot's request requeues and replays on the
# 3 survivors — bit-identical to the fault-free run above
inj = FaultInjector(kill={0: 3}, clock=VirtualClock())
ft = FaultTolerantEngine(model, params, slots=4, max_len=96,
                         compiled=compiled, injector=inj)
for rid, p in prompts.items():
    ft.add_request(Request(rid, list(p), max_new=12))
recovered = ft.run_to_completion()
assert {r.rid: r.out for r in recovered} == {r.rid: r.out for r in done}
print(f"chaos replay: slot 0 killed mid-decode, {ft.replays} request "
      f"replayed on {len(ft.healthy_slots())} survivors, bit-identical")
print("serve_lm OK")

"""Table 5 — MBioTracker biosignal application (paper §5.2).

Per-step cycles/energy from the simulator vs the paper's CPU / CPU+FFT-ACCEL
/ CPU+VWR2A columns. The CPU and accelerator columns are the paper's
measurements; `savings` compares our simulated VWR2A against them.

Also times the fused single-`pallas_call` application kernel against the
staged per-stage execution (the software analogue of the paper's
whole-application SPM residency vs kernel-at-a-time offload); the CI bench
smoke gates on fused <= staged via ``run.py --check-fused``.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.table2_fft import F_HZ

PAPER_CPU = {"preprocessing": (49760, 0.74), "delineation": (46268, 0.74),
             "feat_extraction": (70639, 1.1), "total": (166667, 2.6)}
PAPER_VWR2A = {"preprocessing": (3763, 0.26), "delineation": (2723, 0.13),
               "feat_extraction": (8627, 0.47), "total": (15113, 0.86)}


def _paired_best(fns: list, reps: int = 15) -> list[float]:
    """Paired min-of-reps wall times in us: the candidates are timed
    ALTERNATELY inside one loop so machine noise hits all of them equally
    (an unpaired comparison at the ~3%-level is a coin flip)."""
    import jax

    for fn in fns:
        jax.block_until_ready(fn())          # compile + warm
    best = [float("inf")] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best[i] = min(best[i], time.perf_counter() - t0)
    return [b * 1e6 for b in best]


def _pipeline_rows():
    """Fused application kernel vs the staged executions (paper Table 5's
    execution models: whole-app residency vs kernel-at-a-time offload)."""
    from repro.core.biosignal import make_app, synthetic_respiration
    from repro.kernels.pipeline.ops import app_pipeline
    from repro.kernels.pipeline.ref import staged_kernel_fns, staged_stage_fns

    app = make_app()
    sig, _ = synthetic_respiration(32, 2048, seed=0)
    staged = staged_kernel_fns(app.fir_taps, app.svm_w, app.svm_b,
                               fft_size=app.fft_size)
    fir_fn, feat_fn, svm_fn = staged_stage_fns(
        app.fir_taps, app.svm_w, app.svm_b, fft_size=app.fft_size)
    us_fused, us_staged, us_jnp = _paired_best([
        lambda: app_pipeline(app, sig),
        lambda: staged(sig),
        lambda: svm_fn(feat_fn(fir_fn(sig))),
    ])
    return [
        ("table5/pipeline_staged", us_staged,
         "kernel-at-a-time: 4 launches/batch (FIR kernel; delineation; "
         "rFFT kernel; SVM) with per-stage HBM round trips"),
        ("table5/pipeline_staged_jnp", us_jnp,
         "3 jnp-only jit calls/batch (no per-kernel staging); info only"),
        ("table5/pipeline_fused", us_fused,
         f"ONE pallas_call per batch;speedup_vs_staged="
         f"{us_staged / us_fused:.2f}x"),
    ]


def _stream_rows():
    """Raw-signal single-residency streaming vs host-framed feeds at the
    default overlap (hop = window/4, every sample duplicated 4x by host
    framing). Candidates are timed PAIRED (alternating min-of-reps); the CI
    bench smoke gates on stream-fused >= 1.25x framed-fused via
    ``run.py --check-stream``."""
    from repro.core.biosignal import make_app, synthetic_respiration
    from repro.kernels.pipeline.ops import (app_pipeline,
                                            app_pipeline_stream)
    from repro.kernels.pipeline.ref import staged_kernel_fns
    from repro.serve.stream import frame_signal

    app = make_app()
    window, hop, n_frames = 2048, 512, 32
    sig, _ = synthetic_respiration(1, (n_frames - 1) * hop + window, seed=1)
    raw = sig[0]
    cls_outputs = ("features", "margin", "class")   # elide filtered write
    staged = staged_kernel_fns(app.fir_taps, app.svm_w, app.svm_b,
                               fft_size=app.fft_size)
    # populate the autotune cache (these warmup calls are what lands in
    # BENCH_autotune.json), but GATE on pinned whole-batch blocks: the
    # near-tied candidates make autotune's pick a coin flip under CI load,
    # and a flapping gate is worse than a fixed one
    app_pipeline_stream(app, raw, window=window, hop=hop,
                        outputs=cls_outputs, autotune=True)
    app_pipeline(app, frame_signal(raw, window, hop), autotune=True)
    us_stream, us_framed, us_staged = _paired_best([
        lambda: app_pipeline_stream(app, raw, window=window, hop=hop,
                                    outputs=cls_outputs,
                                    block_frames=n_frames),
        lambda: app_pipeline(app, frame_signal(raw, window, hop),
                             block_rows=n_frames),
        lambda: staged(frame_signal(raw, window, hop)),
    ], reps=25)
    return [
        ("table5/stream_fused", us_stream,
         f"raw {raw.shape[0]}-sample feed, frames built in-kernel "
         f"(window={window},hop={hop}), outputs=features+margin+class;"
         f"speedup_vs_framed={us_framed / us_stream:.2f}x"),
        ("table5/stream_framed_fused", us_framed,
         f"host frame gather ({window // hop}x HBM duplication) + fused "
         f"kernel, all outputs"),
        ("table5/stream_framed_staged", us_staged,
         "host frame gather + kernel-at-a-time staged execution"),
    ]


def run():
    from repro.archsim.energy import vwr2a_energy_uj
    from repro.archsim.programs.app import run_app
    from repro.core.fir import lowpass_taps

    rng = np.random.default_rng(0)
    t = np.arange(1024) / 64.0
    sig = 0.4 * np.sin(2 * np.pi * 0.3 * t) + 0.05 * rng.standard_normal(1024)
    out = run_app(sig, lowpass_taps(11), rng.normal(size=(12, 2)) * 0.3,
                  np.zeros(2))
    rows = []
    tot_c, tot_e = 0, 0.0
    steps = ("preprocessing", "delineation", "feat_extraction", "svm")
    for step in steps:
        counters, cycles = out[step]
        e = vwr2a_energy_uj(counters)
        key = step if step != "svm" else "feat_extraction"
        tot_c += cycles
        tot_e += e
        if step == "svm":
            rows.append((f"table5/svm", cycles / F_HZ * 1e6,
                         f"sim_cycles={cycles};sim_uJ={e:.4f}"))
            continue
        cpu_c, cpu_e = PAPER_CPU[step]
        v_c, v_e = PAPER_VWR2A[step]
        rows.append((f"table5/{step}", cycles / F_HZ * 1e6,
                     f"sim_cycles={cycles};paper_vwr2a={v_c};"
                     f"cycle_savings_vs_cpu={100 * (1 - cycles / cpu_c):.1f}%"
                     f"(paper {100 * (1 - v_c / cpu_c):.1f}%);"
                     f"sim_uJ={e:.3f};"
                     f"energy_savings_vs_cpu={100 * (1 - e / cpu_e):.1f}%"))
    cpu_c, cpu_e = PAPER_CPU["total"]
    v_c, v_e = PAPER_VWR2A["total"]
    rows.append(("table5/total", tot_c / F_HZ * 1e6,
                 f"sim_cycles={tot_c};paper_vwr2a={v_c};"
                 f"cycle_savings_vs_cpu={100 * (1 - tot_c / cpu_c):.1f}%"
                 f"(paper 90.9%);sim_uJ={tot_e:.3f};"
                 f"energy_savings_vs_cpu={100 * (1 - tot_e / cpu_e):.1f}%"
                 f"(paper 66.3%)"))
    rows += _pipeline_rows()
    rows += _stream_rows()
    return rows

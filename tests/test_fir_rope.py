"""FIR + RoPE kernels: oracle sweeps + LTI / rotation properties."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.fir import fir_direct, fir_reference, lowpass_taps
from repro.kernels.fir.ops import fir as kfir
from repro.kernels.rope.ops import rope as krope
from repro.kernels.rope.ref import rope_ref


@pytest.mark.parametrize("shape,seq_block", [((4, 512), 128), ((1, 2048), 512),
                                             ((8, 1024), 1024), ((2, 256), 256)])
@pytest.mark.parametrize("k", [3, 11])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fir_kernel_sweep(shape, seq_block, k, dtype, rng):
    x = jnp.asarray(rng.normal(size=shape)).astype(dtype)
    taps = jnp.asarray(lowpass_taps(k))
    got = kfir(x, taps, seq_block=seq_block)
    want = fir_direct(x.astype(jnp.float32), taps)
    tol = 1e-5 if dtype == jnp.float32 else 0.02
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=tol, rtol=tol)


def test_fir_direct_vs_convolve(rng):
    x = rng.normal(size=(3, 300)).astype(np.float32)
    taps = lowpass_taps(11)
    got = fir_direct(jnp.asarray(x), jnp.asarray(taps))
    want = fir_reference(x, taps)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 30))
def test_fir_shift_invariance(seed, shift):
    """LTI: delaying the input delays the output (up to edge effects)."""
    r = np.random.default_rng(seed)
    x = r.normal(size=256).astype(np.float32)
    taps = jnp.asarray(lowpass_taps(7))
    y = np.asarray(fir_direct(jnp.asarray(x), taps))
    xs = np.concatenate([np.zeros(shift, np.float32), x])[:256]
    ys = np.asarray(fir_direct(jnp.asarray(xs), taps))
    np.testing.assert_allclose(ys[shift:], y[: 256 - shift], atol=1e-5)


@pytest.mark.parametrize("dh", [32, 64, 128])
@pytest.mark.parametrize("layout", ["interleaved", "neox"])
def test_rope_kernel_sweep(dh, layout, rng):
    x = jnp.asarray(rng.normal(size=(96, dh)).astype(np.float32))
    pos = jnp.asarray(rng.integers(0, 4096, 96).astype(np.int32))
    got = krope(x, pos, layout=layout)
    want = rope_ref(x, pos, layout=layout)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-3, rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 512))
def test_rope_preserves_norm(seed, p):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(4, 64)).astype(np.float32))
    pos = jnp.full((4,), p, jnp.int32)
    out = rope_ref(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(out), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 100), st.integers(0, 100),
       st.integers(0, 50))
def test_rope_relative_position(seed, m, n, d):
    """<rope(q,m+d), rope(k,n+d)> == <rope(q,m), rope(k,n)> — the defining
    relative-position property."""
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.normal(size=(1, 64)).astype(np.float32))
    k = jnp.asarray(r.normal(size=(1, 64)).astype(np.float32))
    dot = lambda mm, nn: float(np.sum(
        np.asarray(rope_ref(q, jnp.asarray([mm], jnp.int32)))
        * np.asarray(rope_ref(k, jnp.asarray([nn], jnp.int32)))))
    assert abs(dot(m + d, n + d) - dot(m, n)) < 5e-3 * max(
        1.0, abs(dot(m, n)))

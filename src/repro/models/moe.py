"""Mixture-of-Experts layer: GShard-style capacity-based dispatch.

Tokens are split into groups; per group each token picks top-k experts and a
slot in that expert's capacity buffer. Dispatch/combine are expressed as
einsums so the expert dimension shards cleanly over the `model` mesh axis
(expert parallelism) — XLA SPMD materializes the dispatch resharding as an
all-to-all. Overflowing tokens are dropped (standard GShard semantics);
capacity_factor controls the drop rate.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import P, fanin_std, _act


def moe_schema(cfg):
    m = cfg.moe
    d, E, f = cfg.d_model, m.num_experts, m.d_ff_expert
    s = {
        "router": P((d, E), ("embed", "experts"), fanin_std(d), jnp.float32),
        "w_gate": P((E, d, f), ("experts", "embed", "expert_mlp"), fanin_std(d)),
        "w_in": P((E, d, f), ("experts", "embed", "expert_mlp"), fanin_std(d)),
        "w_out": P((E, f, d), ("experts", "expert_mlp", "embed"), fanin_std(f)),
    }
    if m.num_shared:
        fs = m.d_ff_shared * m.num_shared  # fuse shared experts into one MLP
        s["shared"] = {
            "w_gate": P((d, fs), ("embed", "mlp"), fanin_std(d)),
            "w_in": P((d, fs), ("embed", "mlp"), fanin_std(d)),
            "w_out": P((fs, d), ("mlp", "embed"), fanin_std(fs)),
        }
    return s


def _capacity(sg: int, k: int, E: int, factor: float) -> int:
    c = int(math.ceil(sg * k * factor / E))
    return max(4, ((c + 3) // 4) * 4)


def moe_layer(params, x, cfg):
    """x: (B, S, d) -> (y, aux_loss). Drops overflow tokens (identity path
    via the residual connection owned by the caller)."""
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.top_k
    T = B * S
    sg = min(m.group_size, T)
    while T % sg:  # largest divisor of T <= group_size (odd seq lengths)
        sg -= 1
    G = T // sg
    xg = x.reshape(G, sg, d)

    # --- routing (f32 for stable softmax) ---
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (G,sg,E)
    gates, idx = jax.lax.top_k(probs, k)     # (G,sg,k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # --- aux load-balancing loss (Switch-style) ---
    me = jnp.mean(probs, axis=(0, 1))                       # mean router prob
    onehot_top1 = jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32)
    ce = jnp.mean(onehot_top1, axis=(0, 1))                 # top-1 load share
    aux = E * jnp.sum(me * ce) * m.aux_loss_weight

    # --- capacity assignment: sequential over the k slots ---
    C = _capacity(sg, k, E, m.capacity_factor)
    counts = jnp.zeros((G, E), jnp.float32)
    combine = jnp.zeros((G, sg, E, C), jnp.float32)
    for slot in range(k):
        oh = jax.nn.one_hot(idx[..., slot], E, dtype=jnp.float32)  # (G,sg,E)
        pos = jnp.cumsum(oh, axis=1) - 1.0 + counts[:, None, :]
        keep = (pos < C) & (oh > 0)
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)
        combine = combine + (
            gates[..., slot, None, None]
            * jnp.where(keep, oh, 0.0)[..., None]
            * pos_oh
        )
        counts = counts + jnp.sum(oh, axis=1)

    cd = cfg.compute_dtype
    dispatch = (combine > 0).astype(cd)                      # (G,sg,E,C)
    # --- dispatch -> expert FFN -> combine ---
    xin = jnp.einsum("gsec,gsd->egcd", dispatch, xg.astype(cd))
    h = _act(cfg.act)(jnp.einsum("egcd,edf->egcf", xin,
                                 params["w_gate"].astype(cd)))
    h = h * jnp.einsum("egcd,edf->egcf", xin, params["w_in"].astype(cd))
    eo = jnp.einsum("egcf,efd->egcd", h, params["w_out"].astype(cd))
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(cd), eo)

    if "shared" in params:
        sp = params["shared"]
        hs = _act(cfg.act)(jnp.einsum("gsd,df->gsf", xg, sp["w_gate"].astype(cd)))
        hs = hs * jnp.einsum("gsd,df->gsf", xg, sp["w_in"].astype(cd))
        y = y + jnp.einsum("gsf,fd->gsd", hs, sp["w_out"].astype(cd))

    return y.reshape(B, S, d).astype(x.dtype), aux


def moe_layer_dense_oracle(params, x, cfg):
    """O(E) oracle: run EVERY expert on every token, weight by full top-k
    gates, no capacity drops. For tests (small configs only)."""
    m = cfg.moe
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    w = jnp.zeros_like(probs).at[
        jnp.arange(x.shape[0])[:, None, None],
        jnp.arange(x.shape[1])[None, :, None], idx].set(gates)
    h = _act(cfg.act)(jnp.einsum("bsd,edf->besf", x, params["w_gate"]))
    h = h * jnp.einsum("bsd,edf->besf", x, params["w_in"])
    eo = jnp.einsum("besf,efd->besd", h, params["w_out"])
    y = jnp.einsum("bse,besd->bsd", w.astype(x.dtype), eo)
    if "shared" in params:
        sp = params["shared"]
        hs = _act(cfg.act)(jnp.einsum("bsd,df->bsf", x, sp["w_gate"]))
        hs = hs * jnp.einsum("bsd,df->bsf", x, sp["w_in"])
        y = y + jnp.einsum("bsf,fd->bsd", hs, sp["w_out"])
    return y

"""VWR2A slot ISA (paper §3.1-3.3, Table 1).

One configuration word per cycle per slot; bits == control signals (no
decode stage). We model each slot's instruction as a small dataclass; a
column executes one instruction per slot per cycle under a shared PC.

Slots per column: LCU (loops/branches), LSU (SPM<->VWR/SRF + shuffle unit),
MXCU (VWR word index k + masks), RC0..RC3 (32-bit ALU, 2-entry regfile).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---- operand sources / destinations for RC ops -----------------------------
# ("vwr", name)        word k of VWR slice for this RC (MXCU-controlled k)
# ("srf", i)           scalar register file entry i
# ("reg", 0|1)         RC-local register
# ("imm", value)       immediate
# ("rc", delta)        previous-cycle result of neighbour RC (delta = +-1)
# ("zero",)            constant 0

RC_OPS = ("NOP", "ADD", "SUB", "MUL", "FXMUL", "SLL", "SRL", "SRA",
          "AND", "OR", "XOR", "MAX", "MIN", "MOV")


@dataclasses.dataclass(frozen=True)
class RCInstr:
    op: str = "NOP"
    a: Tuple = ("zero",)
    b: Tuple = ("zero",)
    dest: Optional[Tuple] = None          # ("reg",i) | ("vwr",name) | ("srf",i)

    def __post_init__(self):
        assert self.op in RC_OPS, self.op


@dataclasses.dataclass(frozen=True)
class LSUInstr:
    op: str = "NOP"     # NOP | LOAD | STORE | LOAD_SRF | STORE_SRF | SHUFFLE
    vwr: str = "A"      # target VWR (LOAD/STORE) or shuffle half selector
    addr: Tuple = ("imm", 0)   # SPM line address source: ("imm",v)|("srf",i)
    shuffle_op: str = ""       # interleave|prune_even|prune_odd|bit_reverse|circular_shift
    half: str = "lower"


@dataclasses.dataclass(frozen=True)
class MXCUInstr:
    op: str = "NOP"     # NOP | SETK | INCK | ADDK
    k: int = 0          # immediate for SETK/ADDK


@dataclasses.dataclass(frozen=True)
class LCUInstr:
    op: str = "NOP"     # NOP | SETI | ADDI | BLT | BGE | JUMP | EXIT
    reg: int = 0        # LCU register index (4 regs)
    val: int = 0        # immediate / compare bound
    target: int = 0     # branch target PC


@dataclasses.dataclass(frozen=True)
class SlotWord:
    """One VLIW-style configuration word: all slots for one PC."""
    lcu: LCUInstr = LCUInstr()
    lsu: LSUInstr = LSUInstr()
    mxcu: MXCUInstr = MXCUInstr()
    rcs: Tuple[RCInstr, RCInstr, RCInstr, RCInstr] = (
        RCInstr(), RCInstr(), RCInstr(), RCInstr())


NOP_WORD = SlotWord()

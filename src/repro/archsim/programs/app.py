"""The MBioTracker application on the VWR2A simulator (paper §4.4.2 /
Table 5): preprocessing -> delineation -> feature extraction (+FFT) -> SVM.

Delineation runs as a REAL generated program (predicate algebra on the RCs:
the paper's control-heavy 'if' cascade becomes MAX/MIN/SUB mask ops — the
same ILP argument the paper makes).  Interval statistics (irregular,
data-dependent gather) are evaluated host-side with an RC-op cycle charge;
the SVM margin is a real generated MAC program on one column.  Every stage
accepts the machine's column count (``n_columns``): independent blocks are
dealt round-robin across columns, like the FFT/FIR mappings.
"""
from __future__ import annotations

import numpy as np

from repro.archsim.isa import LSUInstr, RCInstr, SlotWord, sweep_words
from repro.archsim.machine import RC_SLICE, VWR_WORDS, VWR2A, split_work, \
    to_q15, to_q15_arr
from repro.archsim.programs.fft import run_rfft
from repro.archsim.programs.fir import run_fir


def _delineate_instrs(thr_q15: int):
    """Per word k: is_max = (x>prev) & (x>=next) & (x-min(prev,next) > thr).
    6 RC ops per sample (plus parallel MXCU/LCU); output mask in C."""
    return (
        RCInstr("SUB", ("win", 0), ("win", -1), ("reg", 0)),   # x - prev
        RCInstr("SUB", ("win", 0), ("win", 1), ("reg", 1)),    # x - next
        RCInstr("MIN", ("win", -1), ("win", 1), None),         # min nbr
        RCInstr("SUB", ("win", 0), ("rc", 0), None),           # prominence
        RCInstr("SUB", ("rc", 0), ("imm", thr_q15), None),     # - thr
        RCInstr("MIN", ("reg", 0), ("reg", 1), ("vwr", "C", 0)),
    )


def gen_delineate_block(x_line: int, prev_line: int, out_line: int,
                        thr_q15: int):
    instrs = _delineate_instrs(thr_q15)
    words = [
        SlotWord(lsu=LSUInstr("LOAD", "A", ("imm", x_line))),
        SlotWord(lsu=LSUInstr("LOAD", "B", ("imm", prev_line))),
    ]
    for k in range(RC_SLICE):
        words += sweep_words(k, instrs)
    words.append(SlotWord(lsu=LSUInstr("STORE", "C", ("imm", out_line))))
    return words


def run_delineate(filtered: np.ndarray, *, machine: VWR2A | None = None,
                  n_columns: int | None = None):
    """Simulate delineation; returns (is_max, is_min, counters, cycles).
    The RC program computes the mask ingredients; the final boolean
    reduction is host-checked against the numerically identical jnp oracle
    (core/biosignal.delineate)."""
    m = machine or VWR2A(n_columns or 2)
    nc = m.n_columns
    n = filtered.shape[0]
    n_lines = n // VWR_WORDS
    xq = to_q15_arr(filtered)
    m.spm[:n_lines] = xq.reshape(n_lines, VWR_WORDS)
    m.spm[63] = 0
    rng_ = float(filtered.max() - filtered.min())
    thr = to_q15(0.05 * rng_)
    for ln in range(n_lines):
        prev = 63 if ln == 0 else ln - 1
        prog = gen_delineate_block(ln, prev, 24 + ln, thr)
        progs = [[] for _ in range(nc)]
        progs[ln % nc] = prog
        m.run(progs)
    # host-side boolean assembly (same semantics as core.biosignal.delineate)
    x = filtered
    prev = np.roll(x, 1)
    nxt = np.roll(x, -1)
    mu, hi, lo = x.mean(), x.max(), x.min()
    is_max = (x > prev) & (x >= nxt) & (x > mu + 0.3 * (hi - mu))
    is_min = (x < prev) & (x <= nxt) & (x < mu - 0.3 * (mu - lo))
    is_max[0] = is_max[-1] = is_min[0] = is_min[-1] = False
    cycles = max(c.counters.cycles for c in m.cols)
    return is_max, is_min, m.counters(), cycles


def gen_svm(n_features: int, n_classes: int, w_q15, b_q15):
    """Margin MACs on RC0 (scalar tail work; paper: SVM prediction)."""
    words = []
    rc0 = (True, False, False, False)
    for c in range(n_classes):
        seq = [RCInstr("FXMUL", ("vwr", "A", 0), ("imm", w_q15[0][c]),
                       ("reg", 0))]
        for f in range(1, n_features):
            seq.append(RCInstr("FXMUL", ("vwr", "A", f), ("imm", w_q15[f][c]),
                               None))
            seq.append(RCInstr("ADD", ("reg", 0), ("rc", 0), ("reg", 0)))
        seq.append(RCInstr("ADD", ("reg", 0), ("imm", b_q15[c]),
                           ("vwr", "C", c)))
        words += sweep_words(0, tuple(seq), rc0)
    return words


def run_app(signal: np.ndarray, taps: np.ndarray, svm_w: np.ndarray,
            svm_b: np.ndarray, *, fft_size: int = 512,
            n_columns: int = 2, engine: str = "vector"):
    """Full pipeline; returns dict of per-step (counters, cycles)."""
    out = {}

    def fresh():
        return VWR2A(n_columns, engine=engine)

    m1 = fresh()
    filtered, c1, cyc1 = run_fir(signal, taps, machine=m1)
    out["preprocessing"] = (c1, cyc1)

    m2 = fresh()
    is_max, is_min, c2, cyc2 = run_delineate(np.asarray(filtered), machine=m2)
    out["delineation"] = (c2, cyc2)

    # features: 512-pt real FFT (simulated) + interval stats (host, charged)
    m3 = fresh()
    seg = np.asarray(filtered)[:fft_size]
    seg = seg - seg.mean()
    X, c3, cyc3 = run_rfft(fft_size, seg, machine=m3)
    power = np.abs(X) ** 2
    # interval stats charge: ~8 RC ops per extremum, dealt over all
    # n_columns x 4 RCs; totals are conserved for any column count and
    # identical to the seed charge at n_columns=2
    n_ext = int(is_max.sum() + is_min.sum())
    for col, ops in zip(m3.cols, split_work(8 * n_ext, n_columns)):
        col.counters.cycles += max(1, -(-ops // 4))
        col.counters.rc_ops += ops
        col.counters.vwr_reads += ops
    # band powers: 6 bands, ~2 ops per bin, same split
    nb = fft_size // 2 + 1
    for col, ops in zip(m3.cols, split_work(2 * nb, n_columns)):
        col.counters.cycles += ops // 4
        col.counters.rc_ops += ops
        col.counters.vwr_reads += ops
    c3 = m3.counters()
    cyc3 = max(c.counters.cycles for c in m3.cols)

    # SVM margin (real program on column 0 of a small machine)
    m4 = fresh()
    feats = np.concatenate([
        [is_max.sum(), is_min.sum()],
        np.log1p([power[1:43].sum(), power[43:86].sum(), power[86:128].sum(),
                  power[128:171].sum(), power[171:214].sum(),
                  power[214:].sum()]),
        [power.argmax() / nb, float(power.max() > 1.0),
         float(seg.std()), float(np.abs(seg).mean())],
    ]).astype(np.float64)
    feats = feats / max(1e-9, np.abs(feats).max())      # q15-safe
    fq = [int(v) for v in to_q15_arr(feats)]
    m4.spm[0, : len(fq)] = fq
    m4.cols[0].vwr["A"][: len(fq)] = fq
    wq = [[to_q15(v) for v in row] for row in svm_w[: len(fq)]]
    bq = [to_q15(v) for v in svm_b]
    prog = gen_svm(len(fq), len(bq), wq, bq)
    m4.run([prog])
    margin = m4.cols[0].vwr["C"][: len(bq)].astype(np.float64) / (1 << 15)
    c4 = m4.counters()
    cyc4 = max(c.counters.cycles for c in m4.cols)
    out["feat_extraction"] = (c3, cyc3)
    out["svm"] = (c4, cyc4)
    out["prediction"] = int(np.argmax(margin))
    return out

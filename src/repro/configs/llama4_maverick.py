"""llama4-maverick-400b-a17b [hf:meta-llama; unverified] — MoE 128 routed
experts top-1 + 1 shared expert, MoE every 2nd layer (interleave), expert/shared/dense d_ff=8192 (assigned). This realizes the
published ~400B-total / ~17B-active shape with the assigned dims; the derived
interleave is documented in DESIGN.md. Early fusion is a frontend concern;
per the brief this entry is the text backbone."""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,             # dense (non-MoE) layers, per assignment
    vocab_size=202048,
    head_dim=128,
    rope_theta=500000.0,
    moe=MoEConfig(
        num_experts=128,
        top_k=1,
        d_ff_expert=8192,
        num_shared=1,
        d_ff_shared=8192,
        every_k_layers=2,
        capacity_factor=1.25,
        group_size=128,
    ),
    source="hf:meta-llama/Llama-4-Maverick-17B-128E (dims per assignment)",
))

"""Streaming ASR feature front-end (`kernels/pipeline/asr.py`): the fused
``"asr"`` stage graph must match the independent host oracle
(`asr_reference`: frame-local numpy FIR, np.fft.rfft with float64
twiddles, slaney mel matmul) to scale-relative f32 tolerance on every
(window, hop, n_samples) shape — dividing and non-dividing hops,
window > fft_size, single-frame, zero-frame, and tail-pad — and the
graph must ride the shared machinery exactly like the biosignal graph:
ring slots bit-identical to single-chunk streams, `outputs=` elision
bit-identical to the full run, the serving runtime
(`serve/stream.py:StreamConfig(graph="asr")`) equal to the one-call
kernel, and graph-scoped autotune keys."""
import numpy as np
import pytest

from repro.core import autotune
from repro.kernels.pipeline.asr import (AsrFrontendApp, asr_reference,
                                        asr_reference_frames, asr_staged,
                                        hann_window, host_frames,
                                        make_asr_frontend, mel_filterbank)
from repro.kernels.pipeline.graph import (ring_chunk_samples,
                                          stream_frame_count)
from repro.kernels.pipeline.ops import (default_app, graph_pipeline,
                                        graph_pipeline_ring,
                                        graph_pipeline_stream)
from repro.serve.stream import BiosignalStream, StreamConfig


def _audio(n, seed):
    """Synthetic speech-band stand-in: a chirp + noise, f32 in [-1, 1]."""
    rng = np.random.default_rng(seed)
    t = np.arange(n) / 16000.0
    x = np.sin(2 * np.pi * (200 + 40 * t) * t) + 0.1 * rng.standard_normal(n)
    return x.astype(np.float32)


def _assert_close(out, ref, tol=1e-5):
    assert sorted(out) == sorted(ref), (sorted(out), sorted(ref))
    for k in ref:
        a = np.asarray(ref[k], np.float64)
        b = np.asarray(out[k], np.float64)
        assert a.shape == b.shape, (k, a.shape, b.shape)
        if a.size == 0:
            continue
        scale = max(1.0, float(np.abs(a).max()))
        assert float(np.abs(a - b).max()) / scale < tol, k


# ---------------------------------------------------------------------------
# Fused graph vs the independent host oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,hop,n_samples", [
    (512, 160, 512 * 10 + 37),   # whisper-style hop, ragged tail
    (512, 512, 2048),            # hop == window (no tail specs)
    (1024, 256, 5000),           # window > fft_size: hann on the prefix
    (512, 128, 512),             # exactly one frame
    (512, 160, 5000),            # hop does not divide window, tail pad
])
def test_fused_matches_host_reference(window, hop, n_samples):
    app = make_asr_frontend()
    raw = _audio(n_samples, seed=window + hop)
    out = graph_pipeline_stream("asr", app, raw, window=window, hop=hop)
    ref = asr_reference(app, raw, window=window, hop=hop)
    n = stream_frame_count(n_samples, window, hop)
    assert out["logmel"].shape == (n, app.n_mels)
    _assert_close(out, ref)


def test_zero_frame_shapes():
    app = make_asr_frontend()
    out = graph_pipeline_stream("asr", app, _audio(100, seed=1),
                                window=512, hop=160)
    assert out["filtered"].shape == (0, 512)
    assert out["logmel"].shape == (0, app.n_mels)
    assert out["logmel"].dtype == np.float32
    ref = asr_reference(app, _audio(100, seed=1), window=512, hop=160)
    assert ref["logmel"].shape == (0, app.n_mels)


def test_framed_entry_matches_reference_frames():
    app = make_asr_frontend()
    frames = host_frames(_audio(512 * 8 + 91, seed=3), 512, 256)
    out = graph_pipeline("asr", app, frames)
    _assert_close(out, asr_reference_frames(app, frames))
    # the app's __call__ is the host reference on frames
    _assert_close(out, app(frames))


def test_staged_baseline_matches_fused():
    """The 4-launch `asr_staged` baseline the `--check-asr` gate pairs
    against computes the same numbers as the fused graph."""
    app = make_asr_frontend()
    raw = _audio(512 * 6 + 17, seed=5)
    fused = graph_pipeline_stream("asr", app, raw, window=512, hop=160)
    staged = asr_staged(app, raw, window=512, hop=160)
    _assert_close(fused, staged, tol=1e-5)


@pytest.mark.parametrize("block_frames", [None, 4, 32])
def test_block_frames_tile_without_seams(block_frames):
    app = make_asr_frontend()
    raw = _audio(512 * 12 + 13, seed=7)
    out = graph_pipeline_stream("asr", app, raw, window=512, hop=160,
                                block_frames=block_frames)
    _assert_close(out, asr_reference(app, raw, window=512, hop=160))


def test_ring_slots_bit_identical_to_stream():
    """The device-resident dispatch contract, graph-generic: ring slot r
    == the single-chunk stream on ring[r], BITWISE."""
    window, hop, bw, depth = 512, 160, 6, 3
    span = ring_chunk_samples(window, hop, bw)
    app = make_asr_frontend()
    ring = np.stack([_audio(span, seed=20 + r) for r in range(depth)])
    out = graph_pipeline_ring("asr", app, ring, window=window, hop=hop)
    for r in range(depth):
        ref = graph_pipeline_stream("asr", app, ring[r], window=window,
                                    hop=hop)
        for k in ref:
            np.testing.assert_array_equal(np.asarray(out[k][r]),
                                          np.asarray(ref[k]), err_msg=k)


def test_outputs_elision_bit_identical():
    app = make_asr_frontend()
    raw = _audio(512 * 5, seed=9)
    full = graph_pipeline_stream("asr", app, raw, window=512, hop=160)
    only_mel = graph_pipeline_stream("asr", app, raw, window=512, hop=160,
                                     outputs=("logmel",))
    assert sorted(only_mel) == ["logmel"]
    np.testing.assert_array_equal(np.asarray(only_mel["logmel"]),
                                  np.asarray(full["logmel"]))
    only_filt = graph_pipeline_stream("asr", app, raw, window=512, hop=160,
                                      outputs=("filtered",))
    assert sorted(only_filt) == ["filtered"]
    np.testing.assert_array_equal(np.asarray(only_filt["filtered"]),
                                  np.asarray(full["filtered"]))


# ---------------------------------------------------------------------------
# Table construction properties
# ---------------------------------------------------------------------------

def test_hann_window_properties():
    h = hann_window(512)
    assert h.shape == (512,) and h.dtype == np.float32
    assert h[0] == 0.0                       # periodic, not symmetric
    np.testing.assert_allclose(h[256], 1.0, atol=1e-6)   # peak mid-window
    np.testing.assert_allclose(h[1:], h[1:][::-1], atol=1e-6)


def test_mel_filterbank_properties():
    fb = mel_filterbank(512, 64, 16000.0)
    assert fb.shape == (257, 64) and fb.dtype == np.float32
    assert float(fb.min()) >= 0.0
    # every filter has support; every filter is a contiguous triangle
    assert (np.count_nonzero(fb, axis=0) >= 1).all()
    # slaney area norm: filter weight sums shrink as bands widen upward
    # only in hz terms; just pin totals are finite and positive
    sums = fb.sum(axis=0)
    assert (sums > 0).all() and np.isfinite(sums).all()


def test_default_app_registered():
    app = default_app("asr")
    assert isinstance(app, AsrFrontendApp)
    assert app.fft_size == 512 and app.n_mels == 64
    taps = app.fir_taps
    np.testing.assert_allclose(taps, [1.0, -0.97], rtol=1e-6)
    # app=None resolves the registered default inside the entry
    raw = _audio(2048, seed=13)
    out = graph_pipeline_stream("asr", None, raw, window=512, hop=160)
    ref = graph_pipeline_stream("asr", app, raw, window=512, hop=160)
    np.testing.assert_array_equal(np.asarray(out["logmel"]),
                                  np.asarray(ref["logmel"]))


# ---------------------------------------------------------------------------
# Serving integration: graph="asr" through the stream runtime
# ---------------------------------------------------------------------------

def test_stream_runtime_serves_asr_graph():
    """`StreamConfig(graph="asr")` drives the SAME batched runtime as the
    biosignal class and equals the one-call fused kernel bitwise (batch
    boundaries are hop-aligned — the requeue/replay invariant)."""
    app = make_asr_frontend()
    raw = _audio(512 * 9 + 77, seed=15)
    cfg = StreamConfig(window=512, hop=160, batch_windows=8, graph="asr")
    stream = BiosignalStream(app, cfg)
    out = stream.process(raw)
    ref = graph_pipeline_stream("asr", app, raw, window=512, hop=160)
    assert sorted(out) == sorted(ref)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(ref[k]), err_msg=k)


def test_stream_runtime_asr_outputs_and_default_app():
    cfg = StreamConfig(window=512, hop=160, batch_windows=4, graph="asr",
                       outputs=("logmel",))
    stream = BiosignalStream(None, cfg)      # default app resolves
    assert isinstance(stream.app, AsrFrontendApp)
    raw = _audio(512 * 4 + 100, seed=17)
    out = stream.process(raw)
    assert sorted(out) == ["logmel"]
    ref = graph_pipeline_stream("asr", stream.app, raw, window=512,
                                hop=160, outputs=("logmel",))
    np.testing.assert_array_equal(np.asarray(out["logmel"]),
                                  np.asarray(ref["logmel"]))
    # zero-frame degenerate path keeps the selected keys/shapes
    empty = stream.process(raw[:100])
    assert sorted(empty) == ["logmel"]
    assert empty["logmel"].shape == (0, stream.app.n_mels)


def test_asr_graph_is_single_column():
    with pytest.raises(AssertionError, match="single-column"):
        BiosignalStream(None, StreamConfig(window=512, hop=160,
                                           graph="asr", n_columns=2))


def test_resident_loop_serves_asr_graph():
    """`process_resident` (the on-device steady-state loop) stays
    bit-identical to the host-driven path for the second graph too."""
    app = make_asr_frontend()
    raw = _audio(512 * 8, seed=19)
    cfg = StreamConfig(window=512, hop=256, batch_windows=4, graph="asr")
    stream = BiosignalStream(app, cfg)
    host = stream.process(raw)
    res = stream.process_resident(raw)
    for k in host:
        np.testing.assert_array_equal(np.asarray(res[k]),
                                      np.asarray(host[k]), err_msg=k)


def test_autotune_key_is_graph_scoped(tmp_path):
    autotune.clear_cache()
    app = make_asr_frontend()
    raw = _audio(512 * 6, seed=21)
    out = graph_pipeline_stream("asr", app, raw, window=512, hop=160,
                                autotune=True, outputs=("logmel",))
    ref = asr_reference(app, raw, window=512, hop=160)
    _assert_close({"logmel": out["logmel"]}, {"logmel": ref["logmel"]})
    cache = autotune.cache_snapshot()
    (key, rb), = cache.items()
    assert key[0] == "asr_pipeline_stream"
    assert key[2:5] == (512, 160, ("logmel",))
    assert rb in autotune.candidate_stream_block_frames(key[1], 512, 160)
    autotune.clear_cache()

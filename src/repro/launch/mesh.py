"""Production mesh builders.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
everything else sees the real (single-CPU) device set.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; the multi-pod mesh adds a leading DCN
    "pod" axis (2 pods = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(*, data: int = 1, model: int = 1):
    """Tiny mesh over the actually-present devices (tests/examples)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))

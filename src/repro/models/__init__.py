from repro.models.api import (  # noqa: F401
    Model,
    abstract_cache,
    build_model,
    init_cache,
    init_model_params,
)

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — tests and benches must see the real
# (single-CPU) device set; only launch/dryrun.py forces 512 host devices.

# Hermetic containers have no `hypothesis`; fall back to the deterministic
# stub so all property-test modules collect and run (see _compat docstring).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from repro._compat import hypothesis_stub

    hypothesis_stub.install()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)

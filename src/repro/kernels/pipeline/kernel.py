"""Pallas TPU kernel: the FULL MBioTracker pipeline fused into one kernel.

The paper's headline number is *application-level* (§4.4.2 / Table 5):
chaining kernels while the data stays resident in the SPM/VWRs is where the
energy goes away — the FIR output is consumed by the delineation, whose
window is consumed by the feature extraction, whose features feed the SVM,
and main memory is touched exactly twice (signal in, features out). Our
staged `BiosignalApp` runs those stages as separate jnp/pallas calls, so
every stage round-trips HBM. This kernel transplants the paper's staging to
the whole application, extending what `kernels/fft/kernel.py` does for one
kernel:

    one grid step = one (rb x S) window block staged into VMEM, then
      1. 11-tap FIR          — k unrolled shifted FMAs (paper §4.4.1),
      2. delineation         — the mask-algebra predicates of
                               `core.biosignal.delineate` (the paper's
                               predicated RC code), on the VMEM-resident
                               filtered block,
      3. time features       — masked interval statistics,
      4. 512-pt packed rFFT  — the Stockham stages of the FFT kernel with a
                               staged twiddle table + untangle epilogue,
                               reduced to 6 log-band powers,
      5. linear SVM          — margin + argmax class,
    and ONE HBM write of (filtered, features, margin, class).

Inter-stage tensors never leave the block: the working set is budgeted
against `VWRSpec(n_vwrs=4)` (raw + filtered + FFT planes + table/epilogue
scratch). Numerics follow `core.biosignal` op-for-op so the fused outputs
match the staged app to f32 tolerance. The delineation/median stage runs a
fixed-size odd-even sorting network off staged mask tables (no `sort` /
`take_along_axis` / gather anywhere in the kernel — the former
Mosaic-compile gap is closed).

`pipeline_stream_pallas` is the RAW-SIGNAL entry: the grid iterates
frame-blocks over a 1-D signal and the overlapping (window, hop) frames
are built in-kernel from a once-staged chunk — the streaming
single-residency analogue of the paper's §4.2 overlap reuse. Both entries
take an `outputs` selection that elides unrequested computation and HBM
writes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.biosignal import (INTERVAL_SLOTS, band_power_features,
                                  delineate, interval_time_features,
                                  oddeven_tables)
from repro.core.fft import untangle_rfft
from repro.core.vwr import VWRSpec, resolve_block_rows
from repro.kernels.fft.kernel import twiddle_table


def _fir_stage(x, taps_ref, k: int):
    """Causal k-tap FIR on the staged block — unrolled shifted FMAs, the
    in-VMEM mirror of `core.fir.fir_direct`."""
    rb, S = x.shape
    xp = jnp.pad(x, ((0, 0), (k - 1, 0)))
    y = jnp.zeros_like(x)
    for i in range(k):                   # unrolled taps == circular shifts
        y = y + taps_ref[0, i] * xp[:, k - 1 - i: k - 1 - i + S]
    return y


def untangle_table(fft_size: int) -> np.ndarray:
    """(2, m) packed untangle factors e^{-2*pi*i*k/N} for the real-FFT
    epilogue — staged into VMEM alongside the twiddles (the paper keeps
    both in the SPM)."""
    m = fft_size // 2
    ang = -2.0 * np.pi * np.arange(m) / fft_size
    return np.stack([np.cos(ang), np.sin(ang)]).astype(np.float32)


def _rfft_band_powers(seg, wr_ref, wi_ref, u_ref, *, fft_size: int):
    """Packed real FFT (N real -> N/2 complex, Stockham stages, untangle)
    reduced to the 6 log-band powers of `core.biosignal.extract_features`.

    The butterfly stages are the FFT kernel's body verbatim, reading the
    staged (stages, m/2) twiddle table and the (2, m) untangle table.
    """
    rb = seg.shape[0]
    seg = seg - jnp.mean(seg, axis=-1, keepdims=True)
    zr, zi = seg[:, 0::2], seg[:, 1::2]            # pack: z = even + i*odd
    m = fft_size // 2
    stages = int(np.log2(m))
    g, n = 1, m
    re = zr.reshape(rb, 1, m)
    im = zi.reshape(rb, 1, m)
    for s in range(stages):
        ar, ai = re[..., : n // 2], im[..., : n // 2]
        br, bi = re[..., n // 2:], im[..., n // 2:]
        wr = wr_ref[s, : n // 2].reshape(1, 1, n // 2)
        wi = wi_ref[s, : n // 2].reshape(1, 1, n // 2)
        t0r, t0i = ar + br, ai + bi
        dr, di = ar - br, ai - bi
        t1r = dr * wr - di * wi
        t1i = dr * wi + di * wr
        # words-interleaving regroup (self-sorting Stockham)
        re = jnp.concatenate([t0r[:, None], t1r[:, None]], axis=1).reshape(
            rb, 2 * g, n // 2)
        im = jnp.concatenate([t0i[:, None], t1i[:, None]], axis=1).reshape(
            rb, 2 * g, n // 2)
        g, n = 2 * g, n // 2
    Zr = re.reshape(rb, m)
    Zi = im.reshape(rb, m)
    Xr, Xi = untangle_rfft(Zr, Zi, u_ref[0, :], u_ref[1, :])
    power = jnp.square(Xr) + jnp.square(Xi)        # (rb, fft/2+1)
    return band_power_features(power, fft_size)


OUTPUTS = ("filtered", "features", "margin", "class")


def canonical_outputs(outputs) -> tuple:
    """Validate + canonically order an output selection. `None` means all
    four app outputs; any subset elides the unrequested HBM writes (the
    (R, S) `filtered` write is by far the largest — dropping it is the
    point for classification-only traffic)."""
    if outputs is None:
        return OUTPUTS
    sel = tuple(outputs)
    bad = [o for o in sel if o not in OUTPUTS]
    assert not bad, f"unknown outputs {bad}; choose from {OUTPUTS}"
    assert sel, "outputs selection must not be empty"
    return tuple(o for o in OUTPUTS if o in sel)


def _stages_from_filtered(filt, wr_ref, wi_ref, u_ref, w_ref, b_ref,
                          sort_tables, *, fft_size: int):
    """Stages 2-4 on a VMEM-resident filtered block: delineation mask
    algebra -> masked interval time features + packed-rFFT band powers ->
    linear SVM margin/class. Shared by the framed and raw-stream kernels.
    ``sort_tables`` are the staged odd-even network masks for the interval
    median (kept in VMEM beside the twiddles, like the paper's SPM
    tables)."""
    # --- stage 2: delineation (predicated mask algebra, never leaves VMEM)
    is_max, is_min = delineate(filt)
    # --- stage 3a: time features (masked interval statistics) ---
    f_time = interval_time_features(is_max, is_min, sort_tables=sort_tables)
    # --- stage 3b: frequency features (packed rFFT band powers) ---
    f_freq = _rfft_band_powers(filt[:, :fft_size], wr_ref, wi_ref, u_ref,
                               fft_size=fft_size)
    feats = jnp.stack(f_time + f_freq, axis=-1)    # (rb, 12)
    # --- stage 4: linear SVM margin + class ---
    margin = jnp.dot(feats, w_ref[...], preferred_element_type=jnp.float32
                     ) + b_ref[0]
    cls = jnp.argmax(margin, axis=-1).astype(jnp.int32)
    return feats, margin, cls


def _write_outputs(refs: dict, filt, feats, margin, cls):
    """The ONE HBM write per grid step — only the requested refs exist."""
    if "filtered" in refs:
        refs["filtered"][...] = filt.astype(refs["filtered"].dtype)
    if "features" in refs:
        refs["features"][...] = feats
    if "margin" in refs:
        refs["margin"][...] = margin
    if "class" in refs:
        refs["class"][...] = cls[:, None]


def pipeline_kernel(x_ref, taps_ref, wr_ref, wi_ref, u_ref, w_ref, b_ref,
                    lo_ref, hi_ref, ks_ref, *out_refs, n_taps: int,
                    fft_size: int, outputs: tuple = OUTPUTS):
    refs = dict(zip(outputs, out_refs))
    x = x_ref[...].astype(jnp.float32)             # (rb, S) staged once
    # --- stage 1: preprocessing (11-tap FIR) ---
    filt = _fir_stage(x, taps_ref, n_taps)
    feats = margin = cls = None
    if outputs != ("filtered",):
        feats, margin, cls = _stages_from_filtered(
            filt, wr_ref, wi_ref, u_ref, w_ref, b_ref,
            (lo_ref[...], hi_ref[...], ks_ref[...]), fft_size=fft_size)
    _write_outputs(refs, filt, feats, margin, cls)


def _table_operands(taps, w, b, fft_size: int):
    """The staged constant tables every pipeline kernel reads: FIR taps,
    Stockham twiddles, untangle factors, SVM weights/bias, and the
    fixed-size (INTERVAL_SLOTS) odd-even sorting-network stage masks for
    the interval median — with their (broadcast) VMEM BlockSpecs."""
    k = int(taps.shape[0])
    F, C = w.shape
    m = fft_size // 2
    stages = int(np.log2(m))
    assert 1 << stages == m, f"fft_size={fft_size} not a power of 2"
    wr, wi = twiddle_table(m)
    lo, hi, ks = oddeven_tables(INTERVAL_SLOTS)
    operands = (jnp.asarray(taps, jnp.float32).reshape(1, k),
                jnp.asarray(wr), jnp.asarray(wi),
                jnp.asarray(untangle_table(fft_size)),
                jnp.asarray(w, jnp.float32),
                jnp.asarray(b, jnp.float32).reshape(1, C),
                jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(ks))
    shapes = ((1, k), (stages, m // 2), (stages, m // 2), (2, m), (F, C),
              (1, C), lo.shape, hi.shape, ks.shape)
    # broadcast index_map takes *any* grid rank: the same tables serve the
    # 1-D framed/stream grids and the 2-D ring grid
    specs = [pl.BlockSpec(s, lambda *_: (0, 0), memory_space=pltpu.VMEM)
             for s in shapes]
    return operands, specs


def _out_shapes_specs(R: int, S: int, F: int, C: int, rb: int, dtype,
                      outputs: tuple, index_map=None):
    """Output ShapeDtypeStructs + BlockSpecs for an R-row result written in
    rb-row blocks. ``index_map`` defaults to the 1-D grid's row advance
    (block i -> rows [i*rb, (i+1)*rb)); the ring entry passes the 2-D
    (slot, block) -> flat-row map instead."""
    table = {
        "filtered": (jax.ShapeDtypeStruct((R, S), dtype), (rb, S)),
        "features": (jax.ShapeDtypeStruct((R, F), jnp.float32), (rb, F)),
        "margin": (jax.ShapeDtypeStruct((R, C), jnp.float32), (rb, C)),
        "class": (jax.ShapeDtypeStruct((R, 1), jnp.int32), (rb, 1)),
    }
    imap = index_map if index_map is not None else lambda i: (i, 0)
    out_shape = tuple(table[o][0] for o in outputs)
    out_specs = tuple(pl.BlockSpec(table[o][1], imap,
                                   memory_space=pltpu.VMEM) for o in outputs)
    return out_shape, out_specs


def _as_output_dict(outs: tuple, outputs: tuple, n: int) -> dict:
    res = {}
    for o, v in zip(outputs, outs):
        res[o] = v[:n, 0] if o == "class" else v[:n]
    return res


@functools.partial(jax.jit,
                   static_argnames=("fft_size", "interpret", "block_rows",
                                    "outputs"))
def pipeline_pallas(signal, taps, w, b, *, fft_size: int = 512,
                    interpret: bool = True, block_rows: int | None = None,
                    outputs: tuple = OUTPUTS):
    """Fused MBioTracker pipeline. signal: (R, S) windows, S >= fft_size.

    Returns the staged `BiosignalApp.__call__` dict restricted to
    `outputs` (default all four): {"filtered": (R,S), "features": (R,F),
    "margin": (R,C), "class": (R,)}. Exactly ONE `pallas_call` runs per
    window batch; unrequested outputs are never written to HBM.
    """
    outputs = canonical_outputs(outputs)
    R, S = signal.shape
    k = int(taps.shape[0])
    F, C = w.shape
    assert S >= fft_size, (S, fft_size)
    # raw + filtered + two FFT planes ~= 4 live VWR blocks
    rb = resolve_block_rows(R, S * 4, spec=VWRSpec(n_vwrs=4),
                            override=block_rows)
    tables, table_specs = _table_operands(taps, w, b, fft_size)
    out_shape, out_specs = _out_shapes_specs(R, S, F, C, rb, signal.dtype,
                                             outputs)
    outs = pl.pallas_call(
        functools.partial(pipeline_kernel, n_taps=k, fft_size=fft_size,
                          outputs=outputs),
        out_shape=out_shape,
        in_specs=[pl.BlockSpec((rb, S), lambda i: (i, 0),
                               memory_space=pltpu.VMEM)] + table_specs,
        out_specs=out_specs,
        grid=(R // rb,),
        interpret=interpret,
    )(signal, *tables)
    return _as_output_dict(outs, outputs, R)


# ---------------------------------------------------------------------------
# Raw-signal streaming kernel: in-kernel framing, single residency
# ---------------------------------------------------------------------------

def stream_frame_count(n_samples: int, window: int, hop: int) -> int:
    return 0 if n_samples < window else 1 + (n_samples - window) // hop


def min_stream_block_frames(window: int, hop: int) -> int:
    """Smallest legal frame-block: the tail chunk supplies the
    (window - hop) overlap spill, so the body chunk (block_frames * hop
    samples) must be at least that long."""
    return 1 if window <= hop else -(-(window - hop) // hop)


def resolve_stream_block_frames(n_frames: int, window: int, hop: int,
                                override: int | None = None) -> int:
    """Frames staged per grid step. Unlike the framed kernel the block
    need not divide (or even stay below) the frame count — the signal is
    zero-padded and the garbage tail frames are trimmed after the call.
    Never below `min_stream_block_frames`: the tail chunk holds only
    block_frames*hop samples, which must cover the window-hop spill."""
    rb = override or min(max(n_frames, 1), 8)
    return max(1, rb, min_stream_block_frames(window, hop))


def empty_outputs(window: int, F: int, C: int, dtype, outputs=None) -> dict:
    """The zero-frame result, with the SAME keys/shapes/dtypes as a
    non-empty call — the single source of truth for every degenerate path
    (short signal, empty stream batch)."""
    outputs = canonical_outputs(outputs)
    empty = {"filtered": jnp.zeros((0, window), dtype),
             "features": jnp.zeros((0, F), jnp.float32),
             "margin": jnp.zeros((0, C), jnp.float32),
             "class": jnp.zeros((0,), jnp.int32)}
    return {o: empty[o] for o in outputs}


def pipeline_stream_kernel(*refs, n_taps: int, fft_size: int, window: int,
                           hop: int, block_frames: int, outputs: tuple,
                           n_tails: int):
    """One grid step = one block of `block_frames` overlapping frames,
    built IN-KERNEL from the raw 1-D signal (the VWR/SPM single-residency
    analogue of the paper's §4.2 overlap reuse):

      * the body chunk (1, block_frames*hop) is this block's stride of raw
        samples — its BlockSpec index_map is the hop arithmetic: block j
        starts at sample j*block_frames*hop;
      * `n_tails` hop-sized chunks of the SAME signal, at the hop-blocks
        right after the body, supply the (window - hop) samples the last
        frames spill past it — so the staged bytes are exactly one
        contiguous chunk per block (~n_samples total), vs window/hop
        duplicated copies for host-side framing;
      * the 11-tap FIR runs ONCE over the chunk, frames are cut from the
        filtered chunk by static hop slices, and only the first
        n_taps - 1 columns of each frame are recomputed with frame-local
        zero history, which makes the result bit-identical to filtering
        host-framed windows;
      * stages 2-5 and the HBM writes are shared with `pipeline_kernel`.
    """
    body_ref, tail_refs = refs[0], refs[1: 1 + n_tails]
    i = 1 + n_tails
    (taps_ref, wr_ref, wi_ref, u_ref, w_ref, b_ref, lo_ref, hi_ref,
     ks_ref) = refs[i: i + 9]
    refs_out = dict(zip(outputs, refs[i + 9:]))
    chunk = jnp.concatenate(
        [r[0, :] for r in (body_ref,) + tuple(tail_refs)]
    )[: block_frames * hop + (window - hop)].astype(jnp.float32)
    # --- stage 1: FIR once over the chunk (overlap shared in VMEM) ---
    filt_chunk = _fir_stage(chunk[None, :], taps_ref, n_taps)[0]
    filt = jnp.stack([filt_chunk[r * hop: r * hop + window]
                      for r in range(block_frames)])
    # frame-local FIR transient: the framed reference zero-pads each
    # frame's history, the chunk FIR used real preceding samples — patch
    # the first n_taps-1 columns (the only ones that can differ)
    head = jnp.stack([chunk[r * hop: r * hop + n_taps - 1]
                      for r in range(block_frames)])
    filt = jnp.concatenate([_fir_stage(head, taps_ref, n_taps),
                            filt[:, n_taps - 1:]], axis=1)
    feats = margin = cls = None
    if outputs != ("filtered",):
        feats, margin, cls = _stages_from_filtered(
            filt, wr_ref, wi_ref, u_ref, w_ref, b_ref,
            (lo_ref[...], hi_ref[...], ks_ref[...]), fft_size=fft_size)
    _write_outputs(refs_out, filt, feats, margin, cls)


@functools.partial(jax.jit,
                   static_argnames=("window", "hop", "fft_size", "interpret",
                                    "block_frames", "outputs"))
def pipeline_stream_pallas(signal, taps, w, b, *, window: int, hop: int,
                           fft_size: int = 512, interpret: bool = True,
                           block_frames: int | None = None,
                           outputs: tuple = OUTPUTS):
    """Fused pipeline over a RAW 1-D signal: overlapping (window, hop)
    frames are built inside the kernel, so HBM traffic is ~n_samples
    instead of n_frames*window (§4.2/§4.4.2 single residency). Returns the
    framed `pipeline_pallas` dict over the signal's n_frames frames,
    restricted to `outputs`. Exactly ONE `pallas_call` per call.
    """
    outputs = canonical_outputs(outputs)
    (S,) = signal.shape
    k = int(taps.shape[0])
    F, C = w.shape
    assert window >= fft_size, (window, fft_size)
    assert 0 < hop <= window, (hop, window)
    n = stream_frame_count(S, window, hop)
    if n == 0:
        return empty_outputs(window, F, C, signal.dtype, outputs)
    rb = resolve_stream_block_frames(n, window, hop, block_frames)
    n_blocks = -(-n // rb)
    L = rb * hop                     # body chunk: one block's sample stride
    n_tails = min_stream_block_frames(window, hop) if window > hop else 0
    # hop-granular padding: every spec must tile the padded signal, so pad
    # the hop count up to a multiple of rb (zeros; garbage frames trimmed)
    total = -(-(n_blocks * rb + n_tails) // rb) * L
    sig = signal[:min(S, total)]
    if total > sig.shape[0]:
        sig = jnp.concatenate(
            [sig, jnp.zeros((total - sig.shape[0],), sig.dtype)])
    sig2 = sig.reshape(1, total)
    in_specs = [pl.BlockSpec((1, L), lambda j: (0, j),
                             memory_space=pltpu.VMEM)]
    for i in range(n_tails):         # the SAME signal, i hop-blocks ahead
        in_specs.append(pl.BlockSpec(
            (1, hop), lambda j, i=i: (0, j * rb + rb + i),
            memory_space=pltpu.VMEM))
    tables, table_specs = _table_operands(taps, w, b, fft_size)
    out_shape, out_specs = _out_shapes_specs(n_blocks * rb, window, F, C,
                                             rb, signal.dtype, outputs)
    outs = pl.pallas_call(
        functools.partial(pipeline_stream_kernel, n_taps=k,
                          fft_size=fft_size, window=window, hop=hop,
                          block_frames=rb, outputs=outputs,
                          n_tails=n_tails),
        out_shape=out_shape,
        in_specs=in_specs + table_specs,
        out_specs=out_specs,
        grid=(n_blocks,),
        interpret=interpret,
    )(*((sig2,) * (1 + n_tails)), *tables)
    return _as_output_dict(outs, outputs, n)


# ---------------------------------------------------------------------------
# Ring-chunk kernel: one pallas_call over a ring of raw-signal chunks
# ---------------------------------------------------------------------------

def ring_chunk_samples(window: int, hop: int, batch_windows: int) -> int:
    """Samples per ring slot: one `batch_windows`-frame dispatch's span —
    the same arithmetic as `serve.stream.BiosignalStream.chunk_samples`."""
    return (batch_windows - 1) * hop + window


@functools.partial(jax.jit,
                   static_argnames=("window", "hop", "fft_size", "interpret",
                                    "block_frames", "outputs"))
def pipeline_ring_pallas(ring, taps, w, b, *, window: int, hop: int,
                         fft_size: int = 512, interpret: bool = True,
                         block_frames: int | None = None,
                         outputs: tuple = OUTPUTS):
    """Fused pipeline over a RING of raw-signal chunks in ONE `pallas_call`.

    ``ring`` is `(ring_depth, span)`: each row is one dispatch-sized raw
    chunk (what `pipeline_stream_pallas` takes one at a time — span =
    `ring_chunk_samples(window, hop, batch_windows)` for a
    `batch_windows`-frame slot). The grid is `(ring_depth, n_blocks)`:
    the first axis advances the ring slot, the second reuses the
    in-kernel framing index_maps of the single-chunk stream kernel
    VERBATIM — body BlockSpec `(r, j) -> (r, j)` is block j of slot r's
    hop arithmetic, the `window-hop` tail specs read the same row
    `j*rb + rb + i` hop-blocks ahead, and `pipeline_stream_kernel` is the
    kernel body unchanged. This is the kernel half of the device-resident
    streaming loop (`serve/resident.py`): a whole ring of batches
    advances frame-blocks inside one compiled dispatch, no host round
    trip between slots.

    Returns the `pipeline_stream_pallas` output dict per slot, stacked:
    each value has leading shape `(ring_depth, frames_per_slot)` and row r
    is bit-identical to `pipeline_stream_pallas(ring[r], ...)` — the
    property `tests/test_resident.py` pins.
    """
    outputs = canonical_outputs(outputs)
    D, span = ring.shape
    k = int(taps.shape[0])
    F, C = w.shape
    assert window >= fft_size, (window, fft_size)
    assert 0 < hop <= window, (hop, window)
    n = stream_frame_count(span, window, hop)      # frames per ring slot
    assert n > 0, f"ring span {span} shorter than one {window}-window"
    rb = resolve_stream_block_frames(n, window, hop, block_frames)
    n_blocks = -(-n // rb)
    L = rb * hop                     # body chunk: one block's sample stride
    n_tails = min_stream_block_frames(window, hop) if window > hop else 0
    # pad every slot row to the block tiling (same hop-granular arithmetic
    # as the single-chunk entry; the pad frames are trimmed per slot)
    total = -(-(n_blocks * rb + n_tails) // rb) * L
    if total > span:
        ring = jnp.concatenate(
            [ring, jnp.zeros((D, total - span), ring.dtype)], axis=1)
    else:
        ring = ring[:, :total]
    in_specs = [pl.BlockSpec((1, L), lambda r, j: (r, j),
                             memory_space=pltpu.VMEM)]
    for i in range(n_tails):         # the SAME slot row, i hop-blocks ahead
        in_specs.append(pl.BlockSpec(
            (1, hop), lambda r, j, i=i: (r, j * rb + rb + i),
            memory_space=pltpu.VMEM))
    tables, table_specs = _table_operands(taps, w, b, fft_size)
    out_shape, out_specs = _out_shapes_specs(
        D * n_blocks * rb, window, F, C, rb, ring.dtype, outputs,
        index_map=lambda r, j: (r * n_blocks + j, 0))
    outs = pl.pallas_call(
        functools.partial(pipeline_stream_kernel, n_taps=k,
                          fft_size=fft_size, window=window, hop=hop,
                          block_frames=rb, outputs=outputs,
                          n_tails=n_tails),
        out_shape=out_shape,
        in_specs=in_specs + table_specs,
        out_specs=out_specs,
        grid=(D, n_blocks),
        interpret=interpret,
    )(*((ring,) * (1 + n_tails)), *tables)
    res = _as_output_dict(outs, outputs, D * n_blocks * rb)
    # per-slot trim: every slot framed n_blocks*rb rows, keep its n real
    # frames and restore the (ring_depth, n, ...) slot structure
    return {key: v.reshape((D, n_blocks * rb) + v.shape[1:])[:, :n]
            for key, v in res.items()}

"""Training CLI.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --steps 200 --batch 8 --seq 256 --reduced --ckpt-dir ckpts

On a real pod this process runs per host under `jax.distributed`; here it
drives the same code on the local device(s). `--reduced` selects the smoke
config; full configs are exercised via the dry-run on the production mesh.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.train import optim
from repro.train.loop import LoopConfig, train
from repro.train.step import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--data", default="synthetic")
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)

    n_dev = len(jax.devices())
    mesh = make_local_mesh(data=n_dev, model=1)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch, seed=args.seed,
                    source=args.data, path=args.data_path)
    oc = optim.OptConfig(lr=args.lr, warmup_steps=max(10, args.steps // 20),
                         total_steps=args.steps)
    abstract_batch = {
        "tokens": jax.ShapeDtypeStruct((args.batch, args.seq), np.int32),
        "labels": jax.ShapeDtypeStruct((args.batch, args.seq), np.int32)}
    if cfg.is_encdec:
        abstract_batch["frames"] = jax.ShapeDtypeStruct(
            (args.batch, cfg.enc_ctx, cfg.d_model), cfg.compute_dtype)
    with mesh:
        bundle = make_train_step(model, oc, mesh, abstract_batch)
        state = init_state(model, oc, args.seed)
        lc = LoopConfig(n_steps=args.steps,
                        ckpt_every=args.ckpt_every,
                        ckpt_dir=args.ckpt_dir or "checkpoints",
                        log_every=max(1, args.steps // 20))
        train(model, bundle, dc, lc, state)


if __name__ == "__main__":
    main()

"""Load-aware column runtime: non-uniform deal, telemetry, scheduler,
trajectory accumulation, and the no-baseline gate path.

The deal properties mirror the PR-4 equal-deal suite: whatever weight
vector the scheduler produces, the deal must stay hop-aligned, cover
every frame exactly once, and be numerically invisible (sharded ==
single-device). Telemetry and scheduler tests run on an injected virtual
clock so the EWMA math is deterministic."""
import json
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core.biosignal import make_app, synthetic_respiration
from repro.kernels.pipeline.ops import app_pipeline_stream
from repro.kernels.pipeline.shard import column_chunks, column_shares
from repro.serve.engine import ColumnScheduler
from repro.serve.stream import (BiosignalStream, ColumnStats, StreamConfig,
                                StreamTelemetry, column_mesh, frame_count)

ROOT = Path(__file__).resolve().parent.parent

# weight sweeps: uniform, skewed, zero-weight (cold column), float mix,
# single-column degenerate — paired with dividing and non-dividing
# (n_frames, D) combinations below
WEIGHTS = [
    (1, (1.0,)),
    (2, (3, 1)),
    (3, (0, 1, 0)),
    (4, (1, 1, 1, 1)),
    (4, (0.5, 2.0, 1.0, 0.25)),
    (4, (0, 1, 1, 2)),
    (8, (1, 3, 0, 1, 1, 0, 2, 1)),
]


class VirtualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt
        return self.t


# ---------------------------------------------------------------- shares

@pytest.mark.parametrize("n_frames", [1, 7, 16, 64, 101])
@pytest.mark.parametrize("n_columns,weights", WEIGHTS)
def test_column_shares_cover_exactly(n_frames, n_columns, weights):
    shares = column_shares(n_frames, n_columns, weights)
    assert len(shares) == n_columns
    assert sum(shares) == n_frames          # full coverage, no overlap
    assert all(s >= 0 for s in shares)
    total = sum(weights)
    for s, w in zip(shares, weights):
        if w == 0:
            assert s == 0                   # cold column gets nothing
        else:                               # quantization error < 1 frame
            assert abs(s - n_frames * w / total) < 1.0 + 1e-9


def test_column_shares_equal_deal_and_errors():
    assert column_shares(10, 4) == (3, 3, 3, 3)      # padded equal deal
    assert column_shares(10, 1) == (10,)
    assert column_shares(5, 2, (1, 1)) == (3, 2)     # ties -> lower index
    with pytest.raises(AssertionError):
        column_shares(10, 2, (1,))                   # wrong length
    with pytest.raises(AssertionError):
        column_shares(10, 2, (-1, 2))                # negative weight
    with pytest.raises(AssertionError):
        column_shares(10, 2, (0, 0))                 # all-zero


@pytest.mark.parametrize("window,hop,n_samples", [
    (512, 128, 512 * 9),
    (512, 512, 512 * 5 + 17),
    (1024, 320, 7001),
])
@pytest.mark.parametrize("n_columns,weights", WEIGHTS)
def test_weighted_chunks_hop_aligned_and_cover(window, hop, n_samples,
                                               n_columns, weights):
    """Chunk d starts exactly at its first owned frame's sample (a hop
    multiple), frames to >= its share, and the in-signal part matches the
    signal (zero-pad past the end)."""
    sig = np.arange(n_samples, dtype=np.float32)
    n = frame_count(n_samples, window, hop)
    deal = column_chunks(sig, window, hop, n_columns, weights)
    chunks, n_out, shares = deal.chunks, deal.n_frames, deal.shares
    assert n_out == n and sum(shares) == n
    n_max = max(shares)
    assert chunks.shape == (n_columns, n_max * hop + window - hop)
    offsets = np.concatenate([[0], np.cumsum(shares)[:-1]])
    for d in range(n_columns):
        start = int(offsets[d]) * hop           # hop-aligned by construction
        got = np.asarray(chunks[d])
        want = sig[start: start + got.shape[0]]
        np.testing.assert_array_equal(got[: want.shape[0]], want)
        assert (got[want.shape[0]:] == 0).all()
        if shares[d]:
            own = got[: shares[d] * hop + window - hop]
            assert frame_count(own.shape[0], window, hop) == shares[d]


@pytest.mark.parametrize("window,hop,n_samples", [
    (512, 128, 512 * 9),        # deep overlap
    (512, 512, 512 * 5 + 17),   # no overlap, non-dividing signal
])
@pytest.mark.parametrize("n_columns,weights", WEIGHTS)
def test_weighted_sharded_matches_single_device(window, hop, n_samples,
                                                n_columns, weights):
    """THE property: arbitrary valid weight vectors are numerically
    invisible — sharded output bit-matches the single-device kernel."""
    app = make_app()
    sig, _ = synthetic_respiration(1, n_samples, seed=n_samples + n_columns)
    raw = sig[0]
    ref = app_pipeline_stream(app, raw, window=window, hop=hop)
    # real shard_map when the device set allows (the CI multi-device leg
    # forces 8 host devices), serial fallback everywhere else
    out = app_pipeline_stream(app, raw, window=window, hop=hop,
                              n_columns=n_columns, column_weights=weights,
                              mesh=column_mesh(n_columns))
    assert sorted(out) == sorted(ref)
    for k in ref:
        a, b = np.asarray(ref[k]), np.asarray(out[k])
        assert a.shape == b.shape, (k, a.shape, b.shape)
        if k == "class":
            np.testing.assert_array_equal(b, a)
        else:
            np.testing.assert_allclose(b, a, atol=1e-4)


def test_weighted_autotune_key_carries_share_signature():
    """A winner measured on a weighted deal must not leak onto the equal
    deal of the same traffic shape (and vice versa)."""
    from repro.core import autotune

    autotune.clear_cache()
    app = make_app()
    sig, _ = synthetic_respiration(1, 512 * 8, seed=21)
    raw = sig[0]
    app_pipeline_stream(app, raw, window=512, hop=256, autotune=True,
                        n_columns=4)
    app_pipeline_stream(app, raw, window=512, hop=256, autotune=True,
                        n_columns=4, column_weights=(1, 2, 2, 3))
    keys = sorted(autotune.cache_snapshot(), key=len)
    assert len(keys) == 2
    n = frame_count(512 * 8, 512, 256)
    assert "w" not in keys[0]
    sig_tail = keys[1][keys[1].index("w") + 1:]
    assert sig_tail == column_shares(n, 4, (1, 2, 2, 3))
    autotune.clear_cache()


# ------------------------------------------------------------- telemetry

def test_telemetry_ewma_math_and_column_aggregation():
    clk = VirtualClock()
    tel = StreamTelemetry(alpha=0.5, clock=clk)
    tel.attach("a", 0)
    tel.attach("b", 1)
    assert not tel.warm
    tel.record_retire("a", 8)           # first retire: seeds the clock only
    assert not tel.warm and tel.stream_rate("a") == 0.0
    clk.advance(1.0)
    tel.record_retire("a", 8)           # 8 windows / 1 s
    assert tel.warm
    assert tel.stream_rate("a") == pytest.approx(8.0)
    clk.advance(0.5)
    tel.record_retire("a", 8)           # inst 16 w/s -> EWMA 0.5*16+0.5*8
    assert tel.stream_rate("a") == pytest.approx(12.0)
    assert tel.column_rate(0) == pytest.approx(12.0)
    assert tel.column_rate(1) == 0.0    # b never retired
    stats = tel.column_stats(2)
    assert stats[0] == ColumnStats(column=0, streams=1, windows=24,
                                   rate=pytest.approx(12.0),
                                   load=pytest.approx(12.0))
    assert stats[1].streams == 1 and stats[1].rate == 0.0
    # two streams on one column: load sums their rates
    tel.attach("b", 0)
    clk.advance(1.0)
    tel.record_retire("b", 4)
    clk.advance(1.0)
    tel.record_retire("b", 4)
    assert tel.column_load(0) == pytest.approx(tel.stream_rate("a") + 4.0)
    tel.detach("a")
    assert tel.column_load(0) == pytest.approx(4.0)
    assert tel.column_stats(1)[0].streams == 1


def test_stream_reports_retires_to_telemetry():
    """The runtime integration: every processed batch retires through the
    telemetry under the stream's id/column."""
    app = make_app()
    tel = StreamTelemetry()
    sig, _ = synthetic_respiration(1, 512 * 10 + 3, seed=17)
    raw = sig[0]
    cfg = StreamConfig(window=512, hop=256, batch_windows=4)
    stream = BiosignalStream(app, cfg, telemetry=tel, stream_id="s0",
                             column=2)
    n = frame_count(raw.shape[0], 512, 256)
    stream.process(raw)
    stats = tel.column_stats(3)
    assert stats[2].windows == n
    assert stats[2].streams == 1
    assert tel.warm                     # >= 2 batches retired -> real rate
    assert tel.stream_rate("s0") > 0.0


def test_stream_column_weights_runtime_equivalence_and_repin():
    app = make_app()
    sig, _ = synthetic_respiration(1, 512 * 21 + 77, seed=19)
    raw = sig[0]
    ref = BiosignalStream(app, StreamConfig(
        window=512, hop=256, batch_windows=6)).process(raw)
    cfg = StreamConfig(window=512, hop=256, batch_windows=2, n_columns=3,
                       column_weights=(1.0, 2.5, 0.5))
    out = BiosignalStream(app, cfg).process(raw)
    for k in ref:
        a, b = np.asarray(ref[k]), np.asarray(out[k])
        assert a.shape == b.shape
        if k == "class":
            np.testing.assert_array_equal(b, a)
        else:
            np.testing.assert_allclose(b, a, atol=1e-4)
    # weights demand a kernel framing and a matching length
    with pytest.raises(AssertionError):
        BiosignalStream(app, StreamConfig(n_columns=2,
                                          column_weights=(1,)))
    with pytest.raises(AssertionError):
        BiosignalStream(app, StreamConfig(n_columns=2, framing="host",
                                          column_weights=(1, 1)))
    # repin moves future dispatches (pinned streams only)
    dev = jax.devices()[0]
    s = BiosignalStream(app, StreamConfig(window=512, hop=256))
    s.repin(dev)
    assert s.device is dev
    with pytest.raises(AssertionError):
        BiosignalStream(app, cfg).repin(dev)


# ------------------------------------------------------------- scheduler

def _warm_scheduler(rates, *, alpha=0.5, ratio=2.0):
    """A D-column scheduler with one stream per column retiring at the
    given windows/s on a virtual clock."""
    clk = VirtualClock()
    tel = StreamTelemetry(alpha=alpha, clock=clk)
    devs = [jax.devices()[0]] * len(rates)
    sched = ColumnScheduler(devs, telemetry=tel, rebalance_ratio=ratio)
    for i in range(len(rates)):
        sched.admit(f"s{i}")
    for _ in range(3):
        for i, r in enumerate(rates):
            # each stream's inter-retire gap is one full 1.0 s cycle, so
            # retiring r windows per cycle measures r windows/s
            clk.advance(1.0 / len(rates))
            tel.record_retire(f"s{i}", r)
    return sched, tel, clk


def test_scheduler_cold_falls_back_to_counts():
    sched = ColumnScheduler([jax.devices()[0]] * 3,
                            telemetry=StreamTelemetry())
    assert sched.measured_loads() is None
    for i in range(4):
        sched.admit(f"s{i}")
    # round-robin fill, then double up on the lowest index
    assert [sched.column_of(f"s{i}") for i in range(4)] == [0, 1, 2, 0]


def test_scheduler_places_by_measured_load():
    """Column 0 hosts one HEAVY stream (24 w/s), columns 1-2 one light
    stream each (4 w/s): counts tie everywhere but measured load says the
    new stream belongs anywhere but column 0."""
    sched, tel, clk = _warm_scheduler([24.0, 4.0, 4.0])
    loads = sched.measured_loads()
    assert loads == pytest.approx([24.0, 4.0, 4.0], rel=1e-3)
    sched.admit("new")
    assert sched.column_of("new") == 1      # least load, tie -> low index
    # count-based would have put it on column 0 (all counts were 1)


def test_scheduler_rebalance_moves_from_hot_to_cold():
    """Two heavies pile on column 0 while column 2 idles: rebalance
    re-pins one of them and reports the move for repin()."""
    clk = VirtualClock()
    tel = StreamTelemetry(alpha=0.5, clock=clk)
    devs = [jax.devices()[0]] * 3
    sched = ColumnScheduler(devs, telemetry=tel, rebalance_ratio=1.5)
    for sid, col in [("h0", 0), ("h1", 0), ("l0", 1)]:
        sched.admit(sid)
        sched._move(sid, col)               # force the pathological layout
    for _ in range(3):
        for sid, r in [("h0", 10.0), ("h1", 10.0), ("l0", 2.0)]:
            clk.advance(0.33)
            tel.record_retire(sid, r * 0.33)
    before = sched.measured_loads()
    assert max(before) / min(b for b in before if b > 0) > 1.5 \
        or min(before) == 0.0
    moves = sched.rebalance()
    assert moves                            # something moved...
    assert all(sched.column_of(s) != 0 for s in moves)
    after = sched.measured_loads()
    assert max(after) < max(before)         # ...and the spread shrank
    # a balanced scheduler is a no-op
    sched2, _, _ = _warm_scheduler([8.0, 8.0, 8.0], ratio=2.0)
    assert sched2.rebalance() == {}


def test_scheduler_rebalance_count_fallback():
    """Cold telemetry: rebalance still evens out raw stream counts."""
    sched = ColumnScheduler([jax.devices()[0]] * 2, rebalance_ratio=1.5)
    for i in range(4):
        sched.admit(f"s{i}")
        sched._move(f"s{i}", 0)             # all four on column 0
    moves = sched.rebalance()
    assert sched.loads() == [2, 2]
    assert len(moves) == 2


def test_scheduler_deal_weights_from_column_rates():
    sched, tel, clk = _warm_scheduler([6.0, 12.0, 12.0])
    w = sched.deal_weights()
    assert w == pytest.approx((6.0, 12.0, 12.0), rel=1e-3)
    # unobserved column gets the mean observed rate, not zero
    tel2 = StreamTelemetry(alpha=0.5, clock=clk)
    sched2 = ColumnScheduler([jax.devices()[0]] * 3, telemetry=tel2)
    assert sched2.deal_weights() is None    # cold
    tel2.attach("a", 0)
    tel2.record_retire("a", 4)
    clk.advance(1.0)
    tel2.record_retire("a", 4)
    assert sched2.deal_weights() == pytest.approx((4.0, 4.0, 4.0))


def test_cold_streams_count_at_mean_warm_rate():
    """A burst of cold admissions must not pile onto one column: against
    measured windows/s loads each cold stream weighs the MEAN warm rate
    (not a unitless 1.0), so the burst spreads."""
    sched, tel, clk = _warm_scheduler([50.0, 60.0, 70.0])
    for i in range(6):                  # 6 cold streams, none retired yet
        sched.admit(f"cold{i}")
    # each cold stream weighed ~60 w/s -> 2 land on every column
    assert sorted(sched.loads()) == [3, 3, 3]
    loads = sched.measured_loads()
    assert max(loads) / min(loads) < 1.5


def test_manual_repin_reattributes_telemetry():
    app = make_app()
    tel = StreamTelemetry()
    # batch_windows=5: the default 8 would pre-trace the exact dispatch
    # shape test_stream_kernel's one-pallas_call-per-batch contract test
    # counts traces on
    s = BiosignalStream(app, StreamConfig(window=512, hop=256,
                                          batch_windows=5),
                        telemetry=tel, stream_id="s0", column=0)
    sig, _ = synthetic_respiration(1, 512 * 4, seed=31)
    s.process(sig[0])
    assert tel.column_stats(2)[0].windows > 0
    w0 = tel.column_stats(2)[0].windows
    s.repin(jax.devices()[0], column=1)     # manual move: new column
    assert s.column == 1
    s.process(sig[0])
    stats = tel.column_stats(2)
    assert stats[0].windows == w0           # old column stopped accruing
    assert stats[1].windows == w0           # ...the new one took over


def test_deal_weights_band_clusters_near_ties():
    """The deadband: rates within the band collapse to their cluster
    mean (EWMA jitter between identical columns must not deal them
    unequal shares); a genuinely slow column stays its own cluster."""
    sched, tel, clk = _warm_scheduler([5.0, 10.0, 11.0, 9.5])
    w = sched.deal_weights(band=0.3)
    assert w[0] == pytest.approx(5.0, rel=1e-3)      # 2x away: own cluster
    assert w[1] == w[2] == w[3] == pytest.approx(10.17, rel=1e-2)
    # band=0 keeps the raw rates
    raw = sched.deal_weights()
    assert raw == pytest.approx((5.0, 10.0, 11.0, 9.5), rel=1e-3)
    # the clustered weights deal the three equal columns equal shares
    assert column_shares(64, 4, w) == (9, 19, 18, 18)


def test_open_stream_wires_telemetry_through():
    app = make_app()
    tel = StreamTelemetry()
    sched = ColumnScheduler(telemetry=tel)
    sig, _ = synthetic_respiration(1, 512 * 6, seed=23)
    cfg = StreamConfig(window=512, hop=256, batch_windows=4)
    stream = sched.open_stream(app, cfg, stream_id="sensor-a")
    stream.process(sig[0])
    col = sched.column_of("sensor-a")
    assert tel.column_stats(col + 1)[col].windows == \
        frame_count(512 * 6, 512, 256)
    sched.release("sensor-a")
    assert tel.column_load(col) == 0.0      # detached on release


# ----------------------------------------------------- trajectory + gate

def _bench_json(path, rows):
    path.write_text(json.dumps(
        {"rows": [{"name": n, "us_per_call": us, "derived": ""}
                  for n, us in rows], "failed": 0}))


def test_trajectory_accumulates_replaces_and_survives_corruption(tmp_path):
    from benchmarks.trajectory import _load_trajectory, append

    traj = tmp_path / "BENCH_trajectory.json"
    bench = tmp_path / "BENCH_smoke.json"
    _bench_json(bench, [("table5/stream_fused", 100.0)])
    auto = tmp_path / "BENCH_autotune.json"
    auto.write_text(json.dumps(
        {"autotune_winners": [],
         "pinned": {"table5/stream_fused": {"us": 100.0, "ratio": 1.4,
                                            "spread": 0.02, "reps": 5}}}))
    assert append(str(traj), str(bench), commit="aaa", branch="main",
                  autotune_path=str(auto), timestamp=1.0) == 1
    _bench_json(bench, [("table5/stream_fused", 90.0)])
    assert append(str(traj), str(bench), commit="bbb", branch="main",
                  timestamp=2.0) == 2
    entries = _load_trajectory(str(traj))
    assert [e["commit"] for e in entries] == ["aaa", "bbb"]
    assert entries[0]["pinned"]["table5/stream_fused"]["ratio"] == 1.4
    assert entries[1]["rows"]["table5/stream_fused"] == 90.0
    # re-running a commit replaces, not duplicates
    _bench_json(bench, [("table5/stream_fused", 95.0)])
    assert append(str(traj), str(bench), commit="bbb", branch="main",
                  timestamp=3.0) == 2
    entries = _load_trajectory(str(traj))
    assert entries[-1]["rows"]["table5/stream_fused"] == 95.0
    # max-entries cap drops the oldest
    assert append(str(traj), str(bench), commit="ccc", branch="main",
                  max_entries=2, timestamp=4.0) == 2
    assert [e["commit"] for e in _load_trajectory(str(traj))] == \
        ["bbb", "ccc"]
    # corrupt restore re-seeds instead of crashing
    traj.write_text("{not json")
    assert append(str(traj), str(bench), commit="ddd", branch="main",
                  timestamp=5.0) == 1


def _run_diff(tmp_path, *args):
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.diff_autotune", *args],
        capture_output=True, text=True, cwd=ROOT, timeout=120)


def test_diff_autotune_missing_baseline_is_loud(tmp_path):
    """A vanished/broken baseline artifact must not look like a green
    gate: distinct exit code by default, explicit SKIPPED warning with
    --missing-baseline-ok (the first-run case)."""
    new = tmp_path / "new.json"
    new.write_text(json.dumps({"autotune_winners": [], "pinned": {}}))
    missing = str(tmp_path / "nope.json")
    r = _run_diff(tmp_path, missing, str(new), "--gate")
    assert r.returncode == 3, r.stdout + r.stderr
    assert "gate SKIPPED" in r.stdout
    r = _run_diff(tmp_path, missing, str(new), "--gate",
                  "--missing-baseline-ok")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "gate SKIPPED" in r.stdout and "no baseline" in r.stdout
    # unreadable (corrupt) baseline takes the same explicit path
    bad = tmp_path / "bad.json"
    bad.write_text("{corrupt")
    r = _run_diff(tmp_path, str(bad), str(new), "--gate")
    assert r.returncode == 3
    assert "gate SKIPPED" in r.stdout
    # a broken CURRENT artifact is a bench bug -> hard failure
    r = _run_diff(tmp_path, str(bad), str(bad), "--gate",
                  "--missing-baseline-ok")
    assert r.returncode == 1
    # intact baseline still gates regressions
    old = tmp_path / "old.json"
    old.write_text(json.dumps(
        {"autotune_winners": [],
         "pinned": {"p": {"us": 100.0, "ratio": 2.0, "spread": 0.01}}}))
    new.write_text(json.dumps(
        {"autotune_winners": [],
         "pinned": {"p": {"us": 100.0, "ratio": 1.0, "spread": 0.01}}}))
    r = _run_diff(tmp_path, str(old), str(new), "--gate",
                  "--missing-baseline-ok")
    assert r.returncode == 1
    assert "REGRESSED" in r.stdout

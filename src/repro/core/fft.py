"""Radix-2 FFT on the VWR2A shuffle-unit dataflow (paper §3.4), in JAX.

The paper's kernel: log2(N) identical stages of butterflies; the shuffle
unit's *words interleaving* fixes the data layout between stages and a final
*bit-reversal* shuffle restores natural order. We implement exactly that
dataflow (decimation-in-frequency):

    stage:  a, b = x[:n/2], x[n/2:]          (two VWRs)
            t0 = a + b
            t1 = (a - b) * w(n)              (butterflies on the RC array)
            x  = regroup[t0; t1]             (shuffle-unit interleave)
    after log2(N) stages the result is in BIT-REVERSED order;
    a final bit-reversal shuffle (paper: "the shuffle unit is again used to
    reorder the data") yields natural order.

Real-valued input uses the paper's packing trick: N reals -> N/2 complex
(evens + i*odds), one N/2 FFT, then an untangle pass — "approximately a
factor of 2" saving (paper §3.4).

Arrays are kept as separate (re, im) float planes — the TPU-friendly layout
used by the Pallas kernel (kernels/fft); complex dtypes appear only in tests.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.shuffle import bit_reverse_indices


def _twiddle(n: int, dtype=np.float32):
    """w_n^j = exp(-2*pi*i*j/n), j < n/2, in f64 then cast (precision)."""
    j = np.arange(n // 2)
    ang = -2.0 * np.pi * j / n
    return np.cos(ang).astype(dtype), np.sin(ang).astype(dtype)


def fft_stages(re, im, *, inverse: bool = False):
    """DIF butterfly stages; output in bit-reversed order. re/im: (..., N)."""
    n_total = re.shape[-1]
    assert (n_total & (n_total - 1)) == 0, f"N={n_total} not a power of 2"
    g = 1
    re = re[..., None, :]
    im = im[..., None, :]
    n = n_total
    while n > 1:
        ar, ai = re[..., :, : n // 2], im[..., :, : n // 2]
        br, bi = re[..., :, n // 2:], im[..., :, n // 2:]
        wr_np, wi_np = _twiddle(n, np.float32)
        wr = jnp.asarray(wr_np)
        wi = jnp.asarray(-wi_np if inverse else wi_np)
        t0r, t0i = ar + br, ai + bi
        dr, di = ar - br, ai - bi
        t1r = dr * wr - di * wi
        t1i = dr * wi + di * wr
        # regroup == shuffle-unit interleave to per-stage layout
        re = jnp.concatenate([t0r[..., None, :, :], t1r[..., None, :, :]],
                             axis=-3).reshape(*re.shape[:-2], 2 * g, n // 2)
        im = jnp.concatenate([t0i[..., None, :, :], t1i[..., None, :, :]],
                             axis=-3).reshape(*im.shape[:-2], 2 * g, n // 2)
        g *= 2
        n //= 2
    return re.reshape(*re.shape[:-2], n_total), im.reshape(
        *im.shape[:-2], n_total)


def fft(re, im=None, *, inverse: bool = False, natural_order: bool = True):
    """Complex radix-2 FFT. re/im: (..., N) float. Returns (re, im).

    The staged interleave-regroup is SELF-SORTING (Stockham): the shuffle
    applied every stage progressively realizes the bit-reversal, so the
    output is already in natural order — the TPU-native form of the paper's
    dataflow (DESIGN.md §2 deviation 1). ``fft_bitrev`` below is the paper's
    literal in-place variant (bit-reversed order + explicit final shuffle).
    """
    if im is None:
        im = jnp.zeros_like(re)
    rr, ri = fft_stages(re, im, inverse=inverse)
    if inverse:
        rr = rr / rr.shape[-1]
        ri = ri / ri.shape[-1]
    return rr, ri


def fft_bitrev(re, im=None, *, inverse: bool = False):
    """The paper's in-place mapping: DIT butterflies on bit-reversed input
    (the explicit `bit_reverse` shuffle-unit pass), natural-order output.
    Numerically identical to fft(); exercised by archsim and tests."""
    if im is None:
        im = jnp.zeros_like(re)
    n_total = re.shape[-1]
    rev = jnp.asarray(bit_reverse_indices(n_total))
    re, im = re[..., rev], im[..., rev]            # shuffle-unit bit-reversal
    n = 2
    while n <= n_total:
        rr = re.reshape(*re.shape[:-1], n_total // n, n)
        ri = im.reshape(*im.shape[:-1], n_total // n, n)
        ar, ai = rr[..., : n // 2], ri[..., : n // 2]
        br, bi = rr[..., n // 2:], ri[..., n // 2:]
        wr_np, wi_np = _twiddle(n, np.float32)
        wr = jnp.asarray(wr_np)
        wi = jnp.asarray(-wi_np if inverse else wi_np)
        tbr = br * wr - bi * wi
        tbi = br * wi + bi * wr
        re = jnp.concatenate([ar + tbr, ar - tbr], axis=-1).reshape(re.shape)
        im = jnp.concatenate([ai + tbi, ai - tbi], axis=-1).reshape(im.shape)
        n *= 2
    if inverse:
        re = re / n_total
        im = im / n_total
    return re, im


def untangle_rfft(Zr, Zi, wr, wi):
    """Untangle the packed N/2 spectrum Z into the length-(N/2 + 1) rfft:
    X[k] = (Z[k]+conj(Z[-k]))/2 - i/2 * e^{-2pi i k/N} (Z[k]-conj(Z[-k])),
    Nyquist bin X[N/2] = Re(Z[0]) - Im(Z[0]).

    wr/wi: the (m,) cos/sin of -2*pi*k/N. The single source of the epilogue
    math — shared by this module, kernels/fft/ops.py, and the fused
    application kernel (kernels/pipeline)."""
    m = Zr.shape[-1]
    idx = (-jnp.arange(m)) % m                     # Z[N/2 - k] with wrap
    Zcr, Zci = Zr[..., idx], -Zi[..., idx]         # conj(Z[-k])
    er, ei = (Zr + Zcr) * 0.5, (Zi + Zci) * 0.5
    or_, oi = (Zr - Zcr) * 0.5, (Zi - Zci) * 0.5
    # prod = w * o; then (-i*prod).re = prod.im, (-i*prod).im = -prod.re
    pr = wr * or_ - wi * oi
    pi = wr * oi + wi * or_
    nyq = Zr[..., :1] - Zi[..., :1]
    Xr = jnp.concatenate([er + pi, nyq], axis=-1)
    Xi = jnp.concatenate([ei - pr, jnp.zeros_like(nyq)], axis=-1)
    return Xr, Xi


def rfft_packed(x, *, natural_order: bool = True):
    """Real-valued FFT via the paper's N-real -> N/2-complex packing.

    x: (..., N) real. Returns (re, im) of length N//2 + 1 (like np.fft.rfft).
    """
    n = x.shape[-1]
    zr, zi = x[..., 0::2], x[..., 1::2]            # pack: z = even + i*odd
    Zr, Zi = fft(zr, zi, natural_order=natural_order)
    m = n // 2
    ang = -2.0 * np.pi * np.arange(m) / n
    wr, wi = jnp.asarray(np.cos(ang), x.dtype), jnp.asarray(np.sin(ang), x.dtype)
    return untangle_rfft(Zr, Zi, wr, wi)


def fft_reference(x_complex):
    """Oracle via jnp.fft (tests only)."""
    X = jnp.fft.fft(x_complex)
    return jnp.real(X), jnp.imag(X)

"""Streaming window runtime: continuous biosignal traffic through the fused
pipeline kernel.

The paper's deployment model (§4.4.2) is a sensor feeding windows to the
accelerator forever; ours is the serving analogue. The default feed is
ZERO-COPY: the runtime hands the kernel contiguous RAW signal chunks and the
kernel builds the overlapping (window, hop) frames in VMEM itself
(`kernels/pipeline.pipeline_stream_pallas`) — no host gather, no duplicated
overlap bytes in HBM, no materialized zero-padding frames for the tail
batch. The pre-framed path (`framing="host"`) is kept as the fallback and
cross-check reference. Dispatch is pipelined: while batch k's outputs are
being consumed on the host, up to `depth` later batches are already in
flight (JAX async dispatch is the host-side ping-pong buffer, mirroring the
SPM's double-buffered line fills; depth=2 measured WITHIN NOISE of the
depth=1 double buffer on the CPU interpret path — ±4% across trials, see
table5/stream_depth* rows — so the default stays 1 and the knob is there
for real accelerators with wider dispatch gaps). An ``outputs``
selection drops unrequested HBM writes — classification-only traffic never
writes filtered windows — and the kernel row-block can be autotuned from
measured candidates (`core/autotune.py`) instead of the static VWRSpec
formula.

MULTI-COLUMN: ``n_columns > 1`` is the VWR2A column-replication analogue
for this path (archsim deals passes round-robin across columns; we deal
hop-aligned raw chunks across devices). Each dispatch covers
``batch_windows`` frames PER COLUMN, `shard_map`ped over the `data` axis of
a local mesh when the process has >= n_columns devices (on a laptop/CI box:
run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``), and
falls back to bit-identical serial column execution otherwise. Independent
streams can instead be pinned to distinct columns via ``device=`` — that is
what `serve.engine.ColumnScheduler` hands out.

TELEMETRY: `StreamTelemetry` measures per-stream and per-column throughput
(an EWMA of windows/s, updated on every batch retire — the moment
`_collect` blocks until a dispatch's outputs are ready). The measurements
are what make the runtime LOAD-AWARE: `serve.engine.ColumnScheduler`
places new streams on the column with the least measured load (not just
the fewest streams), its `rebalance` step re-pins streams when the
max/min column-load ratio blows past a threshold, and `deal_weights`
turns measured per-column rates into the non-uniform `column_shares`
deal (`StreamConfig.column_weights`) — a column sharing its device with
another tenant retires slower, so it is dealt proportionally fewer
frames.

DEVICE-RESIDENT MODE: this module's dispatch loop is host-driven — one
Python round trip per batch, kept as the REFERENCE path. The steady-state
sibling lives in `serve/resident.py` (`ResidentStream`, reachable from
here via `BiosignalStream.process_resident`): a `lax.scan` iterates ring
sweeps of the donated signal buffer inside one compiled computation and
drains the retire counters into the same `StreamTelemetry` at a low,
configurable frequency. Outputs are bit-identical to this path.
`docs/ARCHITECTURE.md` shows both control loops side by side.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.biosignal import BiosignalApp, make_app
from repro.kernels.pipeline.graph import (canonical_graph_outputs,
                                          get_graph_factory,
                                          graph_empty_outputs)
from repro.kernels.pipeline.kernel import empty_outputs
from repro.kernels.pipeline.ops import (OUTPUTS, app_pipeline,
                                        app_pipeline_stream,
                                        canonical_outputs, default_app,
                                        graph_pipeline,
                                        graph_pipeline_stream,
                                        stream_frame_count)


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Shape + policy of one stream's dispatches (shared verbatim by the
    host-driven `BiosignalStream` and the device-resident
    `serve.resident.ResidentStream`; the resident loop's own knobs live
    in `serve.resident.ResidentConfig`).

    Invariants the runtimes assert: ``window >= app.fft_size`` (stage 4
    reads the first fft_size samples of each frame), ``0 < hop <=
    window`` (frames advance by whole hops; every chunk/deal boundary in
    the kernel and the multi-column split is HOP-ALIGNED, which is what
    makes raw-chunk feeds bit-identical to host framing), and
    ``column_weights`` — when set — has exactly ``n_columns`` entries and
    requires ``framing="kernel"``. See `docs/ARCHITECTURE.md` (paper →
    code map) for how these knobs correspond to VWR2A's column/VWR
    geometry.
    """
    window: int = 2048          # samples per frame (the processing window)
    hop: int = 512              # frame stride; < window => overlapping frames
    batch_windows: int = 8      # frames per fused-kernel dispatch PER COLUMN
    autotune: bool = False      # measure the kernel row-block (cached)
    block_rows: int | None = None   # pin the row-block explicitly
    outputs: tuple = OUTPUTS    # which app outputs to compute/write
    framing: str = "kernel"     # "kernel": raw chunks, frames built in VMEM
    #                             "host": gather-framed fallback/reference
    n_columns: int = 1          # column replicas a dispatch is dealt across
    depth: int = 1              # max in-flight batches (1 = classic double
    #                             buffer, the measured CPU winner; 2+ for
    #                             accelerators with wider dispatch gaps)
    column_weights: tuple | None = None   # non-uniform deal weights (one
    #                             per column, e.g. measured rates from
    #                             StreamTelemetry / deal_weights); None =
    #                             the equal deal
    graph: str = "biosignal"    # which registered stage graph runs
    #                             (graph.py:get_graph_factory name; the
    #                             ASR front-end is graph="asr"). The
    #                             default `outputs` then means ALL of
    #                             that graph's outputs. Non-biosignal
    #                             graphs are single-column for now.


# single source of the framing arithmetic (shared with the kernel, whose
# trim logic depends on the same count)
frame_count = stream_frame_count


def frame_signal(signal, window: int, hop: int):
    """(S,) continuous signal -> (n_frames, window) overlapping frames.

    Host-side gather: every sample is duplicated ~window/hop times. Kept
    for the `framing="host"` fallback and as the reference the raw-chunk
    kernel path is tested against."""
    sig = jnp.asarray(signal)
    assert sig.ndim == 1, sig.shape
    n = frame_count(sig.shape[0], window, hop)
    if n == 0:
        return jnp.zeros((0, window), sig.dtype)
    idx = np.arange(n)[:, None] * hop + np.arange(window)[None, :]
    return sig[jnp.asarray(idx)]


def column_mesh(n_columns: int):
    """A `data`-axis mesh over the first n_columns local devices, or None
    when the process doesn't have that many (the sharded entry then runs
    its bit-identical serial-column fallback)."""
    if n_columns <= 1 or len(jax.devices()) < n_columns:
        return None
    from repro.launch.mesh import make_local_mesh

    return make_local_mesh(data=n_columns)


@dataclasses.dataclass(frozen=True)
class ColumnStats:
    """One column's measured-throughput snapshot (see `StreamTelemetry`)."""
    column: int
    streams: int        # live streams attached to the column
    windows: int        # total windows retired on the column
    rate: float         # EWMA of the column's retire throughput, windows/s
    load: float         # sum of the column's live streams' EWMA rates —
    #                     the demand signal ColumnScheduler balances on


class StreamTelemetry:
    """Per-stream and per-column throughput telemetry.

    Every batch retire (`BiosignalStream._collect`, the block-until-ready
    point) reports ``(stream_id, n_windows)``; the telemetry turns the
    inter-retire gap into an instantaneous windows/s sample and folds it
    into an EWMA (``alpha`` = weight of the newest sample) per stream and
    per column. The first retire of a stream/column only seeds the
    timestamp — a rate needs a gap — so a telemetry with no *gap* yet is
    COLD (`warm` is False) and schedulers fall back to counting streams.

    ``clock`` is injectable (defaults to `time.perf_counter`) so tests
    and benchmarks can replay measured timings deterministically.

    Retires arrive from BOTH serving modes: the host-driven path reports
    one per batch (`BiosignalStream._collect`), the device-resident path
    one per counter drain (`serve.resident.ResidentStream._drain` — the
    windows retired since the previous drain, so totals match the
    per-batch accounting exactly). ``add_retire_listener`` lets a
    consumer observe every retire as it lands — that is how
    `serve.engine.ColumnScheduler`'s retire-count rebalance trigger
    replaces a host-side poller.
    """

    def __init__(self, alpha: float = 0.3, clock=time.perf_counter):
        assert 0.0 < alpha <= 1.0, alpha
        self.alpha = alpha
        self._clock = clock
        self._stream_col: dict = {}       # stream_id -> column
        self._stream_rate: dict = {}      # stream_id -> EWMA windows/s
        self._stream_last: dict = {}      # stream_id -> last retire t
        self._stream_windows: dict = {}   # stream_id -> total windows
        self._col_rate: dict[int, float] = {}
        self._col_last: dict[int, float] = {}
        self._col_windows: dict[int, int] = {}
        self._listeners: list = []        # fns called (stream_id, n) per
        #                                   retire, AFTER the EWMA update

    def add_retire_listener(self, fn) -> None:
        """Register ``fn(stream_id, n_windows)`` to run on every recorded
        retire (after the EWMA fold, so the listener sees warm rates).
        The hook is how retire-count triggers subscribe —
        `ColumnScheduler(rebalance_every=...)` registers itself here."""
        self._listeners.append(fn)

    def attach(self, stream_id, column: int = 0) -> None:
        """Register a stream on a column (idempotent re-attach moves it —
        that is how a rebalance re-pin shows up here)."""
        self._stream_col[stream_id] = int(column)
        self._stream_rate.setdefault(stream_id, 0.0)
        self._stream_windows.setdefault(stream_id, 0)

    def detach(self, stream_id) -> None:
        for d in (self._stream_col, self._stream_rate, self._stream_last,
                  self._stream_windows):
            d.pop(stream_id, None)

    def column_of(self, stream_id) -> int:
        return self._stream_col[stream_id]

    @staticmethod
    def _ewma(old: float | None, inst: float, alpha: float) -> float:
        return inst if old is None or old == 0.0 else \
            alpha * inst + (1.0 - alpha) * old

    def record_retire(self, stream_id, n_windows: int) -> None:
        """Fold one retired batch (``n_windows`` valid frames) into the
        stream's and its column's EWMAs, then notify retire listeners.
        In resident mode a "batch" is one counter drain — the delta since
        the previous drain."""
        if stream_id not in self._stream_col:
            self.attach(stream_id)
        t = self._clock()
        col = self._stream_col[stream_id]
        self._stream_windows[stream_id] += int(n_windows)
        self._col_windows[col] = self._col_windows.get(col, 0) + int(n_windows)
        last = self._stream_last.get(stream_id)
        if last is not None and t > last:
            inst = n_windows / (t - last)
            self._stream_rate[stream_id] = self._ewma(
                self._stream_rate.get(stream_id), inst, self.alpha)
        self._stream_last[stream_id] = t
        last_c = self._col_last.get(col)
        if last_c is not None and t > last_c:
            inst = n_windows / (t - last_c)
            self._col_rate[col] = self._ewma(
                self._col_rate.get(col), inst, self.alpha)
        self._col_last[col] = t
        for fn in self._listeners:
            fn(stream_id, int(n_windows))

    @property
    def warm(self) -> bool:
        """True once ANY stream has a measured rate (>= 2 retires)."""
        return any(r > 0.0 for r in self._stream_rate.values())

    def stream_rate(self, stream_id) -> float:
        return self._stream_rate.get(stream_id, 0.0)

    def column_rate(self, column: int) -> float:
        return self._col_rate.get(column, 0.0)

    def column_load(self, column: int) -> float:
        """Sum of the column's live streams' EWMA rates (demand)."""
        return sum(self._stream_rate.get(s, 0.0)
                   for s, c in self._stream_col.items() if c == column)

    def column_stats(self, n_columns: int | None = None) -> list[ColumnStats]:
        """Snapshot over columns 0..n-1 (default: every column seen)."""
        cols = range(n_columns) if n_columns is not None else sorted(
            set(self._col_windows) | set(self._stream_col.values()) or {0})
        return [ColumnStats(
            column=c,
            streams=sum(1 for v in self._stream_col.values() if v == c),
            windows=self._col_windows.get(c, 0),
            rate=self.column_rate(c),
            load=self.column_load(c)) for c in cols]


class BiosignalStream:
    """Drives a continuous signal through the fused pipeline kernel in
    pipelined window batches (up to `cfg.depth` in flight).

    >>> stream = BiosignalStream(make_app(), StreamConfig(hop=256))
    >>> out = stream.process(signal)          # dict over all frames

    ``device`` pins every dispatch of THIS stream to one device (column) —
    how the serving layer places independent streams on distinct columns —
    and is mutually exclusive with ``cfg.n_columns > 1`` (which spreads
    each dispatch of one stream across all columns).

    ``telemetry`` (a `StreamTelemetry`) makes the stream report every
    batch retire under ``stream_id`` on ``column`` — the measurements the
    load-aware scheduler places and rebalances on. `repin` moves the
    stream to another device mid-flight (a `ColumnScheduler.rebalance`
    move); in-flight batches finish on the old device, later dispatches
    go to the new one.

    Args: ``app`` — the `core.biosignal.BiosignalApp` whose taps/weights
    the kernel stages (default `make_app()`); ``cfg`` — the
    `StreamConfig` dispatch shape (see its invariants). Guarantees:
    `process` equals running the fused kernel on
    `frame_signal(signal, window, hop)` in one call — bit-identical
    across framing modes, column counts, batch sizes, AND the
    device-resident mode (`process_resident`); the zero-frame degenerate
    path returns the same keys/dtypes as the hot path. The control-loop
    structure (what runs on host vs device) is diagrammed in
    `docs/ARCHITECTURE.md`; the CI gates pinning the speedups are in
    `docs/BENCHMARKS.md`.
    """

    def __init__(self, app: BiosignalApp | None = None,
                 cfg: StreamConfig | None = None, *, device=None,
                 telemetry: StreamTelemetry | None = None,
                 stream_id=None, column: int = 0,
                 injector=None, retry=None):
        cfg = cfg or StreamConfig()
        if cfg.graph == "biosignal":
            self.app = app or make_app()
            self._graph = None          # biosignal keeps its sharded path
            cfg = dataclasses.replace(
                cfg, outputs=canonical_outputs(cfg.outputs))
        else:
            self.app = app if app is not None else default_app(cfg.graph)
            self._graph, _ = get_graph_factory(cfg.graph)(self.app)
            # the config default (the biosignal 4-tuple) means "all of
            # THIS graph's outputs" for a non-biosignal graph
            sel = None if cfg.outputs is OUTPUTS else cfg.outputs
            cfg = dataclasses.replace(
                cfg, outputs=canonical_graph_outputs(self._graph, sel))
            assert cfg.n_columns == 1 and cfg.column_weights is None, \
                "non-biosignal graphs are single-column (no sharded entry)"
        self.cfg = cfg
        assert self.cfg.window >= self.app.fft_size, (
            self.cfg.window, self.app.fft_size)
        assert 0 < self.cfg.hop <= self.cfg.window
        assert self.cfg.batch_windows > 0
        assert self.cfg.framing in ("kernel", "host"), self.cfg.framing
        assert self.cfg.n_columns >= 1
        assert self.cfg.depth >= 1
        assert device is None or self.cfg.n_columns == 1, \
            "pin a stream to one column OR shard it across columns, not both"
        if self.cfg.column_weights is not None:
            assert len(self.cfg.column_weights) == self.cfg.n_columns, \
                (self.cfg.column_weights, self.cfg.n_columns)
            assert self.cfg.framing == "kernel", \
                "the load-aware deal is a raw-chunk (framing='kernel') path"
        self.device = device
        self.mesh = column_mesh(self.cfg.n_columns)
        self.telemetry = telemetry
        self.stream_id = stream_id if stream_id is not None else id(self)
        self.column = column
        self._resident = None       # lazy ResidentStream sibling (cached)
        # fault hooks: ``injector`` (a `serve.fault.FaultInjector`) is
        # consulted before every raw-chunk dispatch and may raise
        # TransientDispatchError (retried below) or ColumnDeadError
        # (propagates — the serving layer drains + requeues). ``retry``
        # is the `runtime.fault.Supervisor` whose capped-exponential
        # `call` wraps the dispatch; default: 3 retries, no sleep.
        self.injector = injector
        self._retry = retry
        if injector is not None and retry is None:
            from repro.runtime.fault import (Supervisor,
                                             TransientDispatchError)

            self._retry = Supervisor(max_retries=3,
                                     retry_on=(TransientDispatchError,))
        if telemetry is not None:
            telemetry.attach(self.stream_id, column)

    def repin(self, device, column: int | None = None) -> None:
        """Move the stream's future dispatches to another device (the
        rebalance hand-off). Only meaningful for pinned (n_columns == 1)
        streams, like ``device=`` itself. Pass ``column`` when repinning
        MANUALLY so the telemetry re-attributes later retires to the new
        column (`ColumnScheduler.rebalance` already re-attaches through
        its own move bookkeeping, so its moves can omit it)."""
        assert self.cfg.n_columns == 1, \
            "repin applies to column-pinned streams"
        self.device = device
        if column is not None:
            self.column = column
            if self.telemetry is not None:
                self.telemetry.attach(self.stream_id, column)

    @property
    def dispatch_windows(self) -> int:
        """Frames per dispatch across all columns."""
        return self.cfg.batch_windows * self.cfg.n_columns

    @property
    def chunk_samples(self) -> int:
        """Raw samples per kernel-framed dispatch: one batch's span."""
        cfg = self.cfg
        return (self.dispatch_windows - 1) * cfg.hop + cfg.window

    def _place(self, x):
        return x if self.device is None else jax.device_put(x, self.device)

    def _dispatch_chunk(self, chunk):
        """Raw-chunk dispatch: the kernel does the framing in VMEM. With a
        fault ``injector`` attached, the injector fires first (simulated
        transient faults are retried through the supervisor's capped
        backoff; a column death propagates to the serving layer)."""
        cfg = self.cfg

        def dispatch():
            if self.injector is not None:
                self.injector.on_dispatch(self.column)
            if self._graph is not None:
                return graph_pipeline_stream(
                    cfg.graph, self.app, self._place(chunk),
                    window=cfg.window, hop=cfg.hop,
                    block_frames=cfg.block_rows, autotune=cfg.autotune,
                    outputs=cfg.outputs)
            return app_pipeline_stream(self.app, self._place(chunk),
                                       window=cfg.window, hop=cfg.hop,
                                       block_frames=cfg.block_rows,
                                       autotune=cfg.autotune,
                                       outputs=cfg.outputs,
                                       n_columns=cfg.n_columns,
                                       mesh=self.mesh,
                                       column_weights=cfg.column_weights)
        if self._retry is not None:
            return self._retry.call(dispatch)
        return dispatch()

    def _dispatch_frames(self, frames):
        """Pre-framed dispatch (fallback/reference path)."""
        if self._graph is not None:
            return graph_pipeline(self.cfg.graph, self.app,
                                  self._place(frames),
                                  block_rows=self.cfg.block_rows,
                                  autotune=self.cfg.autotune,
                                  outputs=self.cfg.outputs)
        return app_pipeline(self.app, self._place(frames),
                            block_rows=self.cfg.block_rows,
                            autotune=self.cfg.autotune,
                            outputs=self.cfg.outputs,
                            n_columns=self.cfg.n_columns, mesh=self.mesh)

    def _batches(self, signal) -> Iterator[tuple]:
        """(in-flight output dict, n valid frames) per window batch."""
        cfg = self.cfg
        sig = jnp.asarray(signal)
        n = frame_count(sig.shape[0], cfg.window, cfg.hop)
        bw = self.dispatch_windows
        if cfg.framing == "host":
            frames = frame_signal(sig, cfg.window, cfg.hop)
            for start in range(0, n, bw):
                batch = frames[start: start + bw]
                valid = batch.shape[0]
                if valid < bw:      # pad the tail batch to the fixed shape
                    batch = jnp.concatenate(
                        [batch, jnp.zeros((bw - valid, cfg.window),
                                          batch.dtype)], axis=0)
                yield self._dispatch_frames(batch), valid
            return
        # raw-chunk feed: batch k's frames live in one contiguous slice of
        # the signal — no gather, and the tail batch (frames % (bw*D) != 0)
        # pads with at most chunk_samples raw zeros instead of bw-valid
        # whole zero frames; the sharded entry trims the pad columns
        span = self.chunk_samples
        for start in range(0, n, bw):
            s0 = start * cfg.hop
            chunk = sig[s0: s0 + span]
            if chunk.shape[0] < span:
                chunk = jnp.concatenate(
                    [chunk, jnp.zeros((span - chunk.shape[0],), sig.dtype)])
            yield self._dispatch_chunk(chunk), min(bw, n - start)

    def stream(self, signal) -> Iterator[dict]:
        """Yields one output dict per window batch (trimmed to the real
        frames). Up to `cfg.depth` later batches are dispatched before
        batch k is yielded, so the consumer always overlaps with
        `depth` in-flight batches (depth=1 is the classic double buffer:
        consume k while k+1 runs)."""
        inflight: deque[tuple[dict, int]] = deque()
        for nxt in self._batches(signal):       # async: in flight now
            inflight.append(nxt)
            if len(inflight) > self.cfg.depth:
                yield self._collect(*inflight.popleft())
        while inflight:
            yield self._collect(*inflight.popleft())

    def _collect(self, out: dict, valid: int) -> dict:
        out = jax.block_until_ready(out)        # the batch retires HERE
        if self.telemetry is not None:
            self.telemetry.record_retire(self.stream_id, valid)
        return {k: v[:valid] for k, v in out.items()}

    def _empty(self, dtype) -> dict:
        """Zero-frame result: same keys/shapes/dtypes as the kernel path."""
        if self._graph is not None:
            return graph_empty_outputs(self._graph, self.cfg.window, dtype,
                                       self.cfg.outputs)
        w = self.app.svm_w.shape
        return empty_outputs(self.cfg.window, w[0], w[1], dtype,
                             self.cfg.outputs)

    def process(self, signal) -> dict:
        """One-call convenience: all framed outputs concatenated, equal to
        running the app on `frame_signal(signal, window, hop)` at once."""
        chunks = list(self.stream(signal))
        if not chunks:
            return self._empty(jnp.asarray(signal).dtype)
        return {k: jnp.concatenate([c[k] for c in chunks], axis=0)
                for k in chunks[0]}

    def process_resident(self, signal, rcfg=None) -> dict:
        """`process`, but with the steady-state loop ON-DEVICE: delegates
        to a cached `serve.resident.ResidentStream` sharing this stream's
        app, config, column pin, telemetry, and stream_id. Outputs are
        bit-identical to `process`; telemetry sees counter drains (every
        ``rcfg.drain_interval`` ring sweeps) instead of per-batch
        retires. ``rcfg`` is a `serve.resident.ResidentConfig` (default:
        its defaults). Only valid for single-column streams — the same
        constraint the resident loop asserts."""
        from repro.serve.resident import ResidentConfig, ResidentStream

        rcfg = rcfg or ResidentConfig()
        if self._resident is None or self._resident.rcfg != rcfg or \
                self._resident.device is not self.device:
            self._resident = ResidentStream(
                self.app, self.cfg, rcfg, device=self.device,
                telemetry=self.telemetry, stream_id=self.stream_id,
                column=self.column, injector=self.injector,
                retry=self._retry)
        return self._resident.process(signal)

"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call: simulated kernels run
at the paper's 80 MHz clock; Pallas kernels report interpret-mode wall time
on CPU — the structural stand-in for the TPU target).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (kernel_bench, table2_fft, table3_power,
                            table4_fir, table5_app)

    print("name,us_per_call,derived")
    failed = 0
    for mod in (table2_fft, table3_power, table4_fir, table5_app,
                kernel_bench):
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # pragma: no cover
            failed += 1
            print(f"{mod.__name__},nan,ERROR:{type(e).__name__}:{e}",
                  file=sys.stderr)
            traceback.print_exc()
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

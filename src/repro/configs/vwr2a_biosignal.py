"""The paper's own workload: MBioTracker biosignal application configuration
(VWR2A, DAC'22 §4.4). Not an LM arch — consumed by core/biosignal.py,
archsim, and the paper-table benchmarks."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class BiosignalConfig:
    name: str = "vwr2a-biosignal"
    sample_rate_hz: int = 64
    window_samples: int = 2048       # processing window
    fir_taps: int = 11               # paper: 11-tap FIR preprocess
    fft_size: int = 512              # paper: real-valued 512-point FFT features
    svm_features: int = 12           # time + frequency features
    svm_classes: int = 2             # cognitive workload binary estimate
    fixed_point: str = "q16.15"      # VWR2A single-cycle fixed-point format


CONFIG = BiosignalConfig()

"""Table 5 — MBioTracker biosignal application (paper §5.2).

Per-step cycles/energy from the simulator vs the paper's CPU / CPU+FFT-ACCEL
/ CPU+VWR2A columns. The CPU and accelerator columns are the paper's
measurements; `savings` compares our simulated VWR2A against them.
"""
from __future__ import annotations

import numpy as np

from benchmarks.table2_fft import F_HZ

PAPER_CPU = {"preprocessing": (49760, 0.74), "delineation": (46268, 0.74),
             "feat_extraction": (70639, 1.1), "total": (166667, 2.6)}
PAPER_VWR2A = {"preprocessing": (3763, 0.26), "delineation": (2723, 0.13),
               "feat_extraction": (8627, 0.47), "total": (15113, 0.86)}


def run():
    from repro.archsim.energy import vwr2a_energy_uj
    from repro.archsim.programs.app import run_app
    from repro.core.fir import lowpass_taps

    rng = np.random.default_rng(0)
    t = np.arange(1024) / 64.0
    sig = 0.4 * np.sin(2 * np.pi * 0.3 * t) + 0.05 * rng.standard_normal(1024)
    out = run_app(sig, lowpass_taps(11), rng.normal(size=(12, 2)) * 0.3,
                  np.zeros(2))
    rows = []
    tot_c, tot_e = 0, 0.0
    steps = ("preprocessing", "delineation", "feat_extraction", "svm")
    for step in steps:
        counters, cycles = out[step]
        e = vwr2a_energy_uj(counters)
        key = step if step != "svm" else "feat_extraction"
        tot_c += cycles
        tot_e += e
        if step == "svm":
            rows.append((f"table5/svm", cycles / F_HZ * 1e6,
                         f"sim_cycles={cycles};sim_uJ={e:.4f}"))
            continue
        cpu_c, cpu_e = PAPER_CPU[step]
        v_c, v_e = PAPER_VWR2A[step]
        rows.append((f"table5/{step}", cycles / F_HZ * 1e6,
                     f"sim_cycles={cycles};paper_vwr2a={v_c};"
                     f"cycle_savings_vs_cpu={100 * (1 - cycles / cpu_c):.1f}%"
                     f"(paper {100 * (1 - v_c / cpu_c):.1f}%);"
                     f"sim_uJ={e:.3f};"
                     f"energy_savings_vs_cpu={100 * (1 - e / cpu_e):.1f}%"))
    cpu_c, cpu_e = PAPER_CPU["total"]
    v_c, v_e = PAPER_VWR2A["total"]
    rows.append(("table5/total", tot_c / F_HZ * 1e6,
                 f"sim_cycles={tot_c};paper_vwr2a={v_c};"
                 f"cycle_savings_vs_cpu={100 * (1 - tot_c / cpu_c):.1f}%"
                 f"(paper 90.9%);sim_uJ={tot_e:.3f};"
                 f"energy_savings_vs_cpu={100 * (1 - tot_e / cpu_e):.1f}%"
                 f"(paper 66.3%)"))
    return rows

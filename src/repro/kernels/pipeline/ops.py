"""Public API for the fused biosignal pipeline kernel."""
from __future__ import annotations

import jax

from repro.kernels.pipeline.kernel import pipeline_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def biosignal_pipeline(signal, taps, w, b, *, fft_size: int = 512,
                       block_rows: int | None = None,
                       autotune: bool = False):
    """Run the full MBioTracker pipeline on (R, S) windows in ONE fused
    Pallas call. Returns the staged app's output dict.

    ``block_rows`` pins the per-grid-step row-block; ``autotune=True``
    instead picks it from measured candidates (cached per shape) — the
    measured replacement for the static VWRSpec budget formula.
    """
    interpret = _interpret()
    if autotune and block_rows is None:
        from repro.core.autotune import tuned_block_rows

        R, S = signal.shape
        block_rows = tuned_block_rows(
            "biosignal_pipeline", R, (S, fft_size, str(signal.dtype)),
            lambda rb: pipeline_pallas(signal, taps, w, b, fft_size=fft_size,
                                       interpret=interpret, block_rows=rb))
    return pipeline_pallas(signal, taps, w, b, fft_size=fft_size,
                           interpret=interpret, block_rows=block_rows)


def app_pipeline(app, signal, *, block_rows: int | None = None,
                 autotune: bool = False):
    """Fused execution of a `core.biosignal.BiosignalApp` instance."""
    return biosignal_pipeline(signal, app.fir_taps, app.svm_w, app.svm_b,
                              fft_size=app.fft_size, block_rows=block_rows,
                              autotune=autotune)

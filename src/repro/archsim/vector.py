"""NumPy-vectorized execution engine for the VWR2A simulator.

The scalar interpreter (``machine.Column.step``) executes one SlotWord at
a time, one RC at a time, in pure Python.  Generated kernel programs are
dominated by *k-sweeps*: the same per-RC instruction sequence repeated
for every MXCU word index k (a ``SETK`` word followed by mxcu-NOP body
words).  This module compiles a straight-line program into groups of such
packets and executes every instance of a group simultaneously as NumPy
array ops over (instances x 4 RC lanes).

Equivalence guarantee: the vectorized engine is *bit-exact* against the
scalar engine — identical int32-wraparound / q16.15 numerics AND identical
activity counters (cycles, rc_ops, vwr/spm accesses, ...), so the
Table-3-calibrated energy model is unchanged.  A static hazard analysis
(`_analyze`) proves, per group, that the reordering from "instance 0
fully, then instance 1, ..." to "step 0 for all instances, then step 1,
..." is unobservable; anything it cannot prove falls back to the scalar
path word-for-word.  All RC addressing is k-static (no data-dependent
addresses), which is what makes the analysis exact rather than
heuristic.

Hazard rules (all checked statically, per candidate group):
  * register / previous-result reads must be defined earlier in the same
    packet instance (no cross-instance register carry);
  * a lane reading a lower lane's result in the same cycle is rejected
    out of conservatism (the scalar engine reads ("rc", d) from rc_last,
    i.e. the *previous* cycle, so forwarding never happens there — do
    not "match scalar" by forwarding here);
  * no VWR word written by one instance may be read or written by any
    other instance, and no same-cycle cross-lane VWR forwarding (VWR
    writes DO land within the scalar cycle, lane-ascending);
  * RC dests other than registers/VWR words (SRF is shared state) are
    rejected.
"""
from __future__ import annotations

import dataclasses

import numpy as np


# geometry (mirrors machine.py; imported lazily there to avoid a cycle)
VWR_WORDS = 128
RC_SLICE = VWR_WORDS // 4
Q15 = 15

_I32_MASK = np.int64(0xFFFFFFFF)
_BIAS = np.int64(1) << 31


def _wrap32v(x: np.ndarray) -> np.ndarray:
    """Vectorized twin of machine._wrap32 (two's-complement int32)."""
    return ((x + _BIAS) & _I32_MASK) - _BIAS


def _alu_vec(op: str, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    if op in ("NOP", "MOV"):
        return a
    if op == "ADD":
        return _wrap32v(a + b)
    if op == "SUB":
        return _wrap32v(a - b)
    if op == "MUL":
        return _wrap32v(a * b)
    if op == "FXMUL":
        return _wrap32v((a * b) >> Q15)
    if op == "SLL":
        return _wrap32v(a << (b & 31))
    if op == "SRL":
        return _wrap32v((a & _I32_MASK) >> (b & 31))
    if op == "SRA":
        return _wrap32v(a >> (b & 31))
    if op == "AND":
        return a & b
    if op == "OR":
        return a | b
    if op == "XOR":
        return a ^ b
    if op == "MAX":
        return np.maximum(a, b)
    if op == "MIN":
        return np.minimum(a, b)
    raise ValueError(op)


def _resolve_vwr(src, r: int, k: int):
    """Static (vwr_name, word_index) for a k-addressed operand/dest."""
    kind = src[0]
    if kind == "vwr":
        off = src[2] if len(src) > 2 else 0
        return src[1], (r * RC_SLICE + k + off) % VWR_WORDS
    if kind == "win":
        g = VWR_WORDS + r * RC_SLICE + k + src[1]
        return ("B" if g < VWR_WORDS else "A"), g % VWR_WORDS
    raise ValueError(src)


@dataclasses.dataclass
class _Packet:
    """One SETK-headed k-sweep instance: shared instr per step + lane mask."""
    k: int
    instrs: tuple                # per-step RCInstr or None (all lanes NOP)
    mask: np.ndarray             # (P, 4) bool
    words: list                  # original SlotWords (scalar fallback)


@dataclasses.dataclass
class VecGroup:
    """A hazard-checked batch of packet instances, executable step-major."""
    instrs: tuple                # per-step RCInstr or None
    ks: np.ndarray               # (K,) int
    mask: np.ndarray             # (K, P, 4) bool
    deltas: dict                 # counter increments (exact scalar match)
    reg_commit: list             # [((r, j), instance), ...]
    last_commit: list            # [(r, instance), ...]
    final_k: int
    plans: list = None           # per-step precomputed gather/scatter plans


def _make_packet(words, k: int):
    """Packet iff every cycle's non-NOP RCs share one instruction."""
    instrs, mask = [], np.zeros((len(words), 4), bool)
    for s, w in enumerate(words):
        instr = None
        for r, rc in enumerate(w.rcs):
            if rc.op == "NOP":
                continue
            if instr is None:
                instr = rc
            elif rc is not instr and rc != instr:
                return None
            mask[s, r] = True
        instrs.append(instr)
    return _Packet(k, tuple(instrs), mask, list(words))


def _analyze(instrs, ks, masks):
    """Prove instance-major == step-major for this group; compute the exact
    counter deltas and final register/result commits.  Returns None when
    any hazard rule fails (caller falls back to the scalar engine)."""
    P, K = len(instrs), len(ks)
    writes = {}                       # (vwr, idx) -> writer instance
    reads = {}                        # (vwr, idx) -> set of instances
    d_rc_ops = d_mults = d_vwr_r = d_vwr_w = d_srf = 0
    reg_writer, last_writer = {}, {}
    for i in range(K):
        k = ks[i]
        reg_def, last_def = set(), set()
        for s in range(P):
            ins = instrs[s]
            if ins is None:
                continue
            row = masks[i][s]
            step_writes = {}
            for r in range(4):
                if not row[r]:
                    continue
                for src in (ins.a, ins.b):
                    kind = src[0]
                    if kind == "reg":
                        if (r, src[1]) not in reg_def:
                            return None
                    elif kind == "rc":
                        sr = (r + src[1]) % 4
                        if sr not in last_def:
                            return None
                        if sr < r and row[sr]:   # conservative (see
                            return None          # module docstring)
                    elif kind in ("vwr", "win"):
                        addr = _resolve_vwr(src, r, k)
                        if addr in step_writes:  # written by a lower lane
                            return None          # this same cycle
                        reads.setdefault(addr, set()).add(i)
                        d_vwr_r += 1
                    elif kind == "srf":
                        d_srf += 1
                d = ins.dest
                if d is not None:
                    if d[0] == "reg":
                        reg_def.add((r, d[1]))
                        reg_writer[(r, d[1])] = i
                    elif d[0] == "vwr":
                        addr = _resolve_vwr(d, r, k)
                        if addr in step_writes:  # same-cycle double write
                            return None
                        step_writes[addr] = r
                        prev = writes.get(addr)
                        if prev is not None and prev != i:
                            return None
                        writes[addr] = i
                        d_vwr_w += 1
                    else:
                        # srf writes touch shared state; any other dest
                        # kind is outside the proven subset — scalar path
                        return None
                last_def.add(r)
                last_writer[r] = i
                d_rc_ops += 1
                if ins.op in ("MUL", "FXMUL"):
                    d_mults += 1
    for addr, wi in writes.items():
        if any(j != wi for j in reads.get(addr, ())):
            return None                          # cross-instance RAW/WAR
    deltas = {"cycles": K * P, "rc_ops": d_rc_ops, "rc_mults": d_mults,
              "vwr_reads": d_vwr_r, "vwr_writes": d_vwr_w,
              "srf_accesses": d_srf}
    return (deltas, sorted(reg_writer.items()), sorted(last_writer.items()))


def _build_plans(instrs, ks, mask):
    """Precompute per-step gather/scatter index arrays (k-static)."""
    K = len(ks)
    base = np.arange(4) * RC_SLICE                        # (4,)
    kcol = np.asarray(ks, np.int64)[:, None]              # (K, 1)

    def operand_plan(src):
        kind = src[0]
        if kind == "zero":
            return ("imm", np.int64(0))
        if kind == "imm":
            return ("imm", np.int64(src[1]))
        if kind == "reg":
            return ("reg", src[1])
        if kind == "srf":
            return ("srf", src[1])
        if kind == "rc":
            return ("rc", (np.arange(4) + src[1]) % 4)
        if kind == "vwr":
            off = src[2] if len(src) > 2 else 0
            idx = (base[None, :] + kcol + off) % VWR_WORDS
            return ("vwr", src[1], idx)
        if kind == "win":
            g = VWR_WORDS + base[None, :] + kcol + src[1]
            return ("win", g < VWR_WORDS, g % VWR_WORDS)
        raise ValueError(src)

    plans = []
    for s, ins in enumerate(instrs):
        if ins is None or not mask[:, s, :].any():
            plans.append(None)
            continue
        m = mask[:, s, :]
        dest = None
        if ins.dest is not None:
            if ins.dest[0] == "reg":
                dest = ("reg", ins.dest[1])
            else:                                          # ("vwr", ...)
                off = ins.dest[2] if len(ins.dest) > 2 else 0
                idx = (base[None, :] + kcol + off) % VWR_WORDS
                dest = ("vwr", ins.dest[1], idx[m])        # flat, masked
        plans.append((ins.op, operand_plan(ins.a), operand_plan(ins.b),
                      dest, m))
    return plans


# Group-level compile cache: identical k-sweeps recur across passes/blocks
# (every FFT stage pass, every FIR block).  Keyed by value, bounded.
_GROUP_CACHE: dict = {}
_GROUP_CACHE_MAX = 256

# Packet cache keyed by word identity: isa.sweep_words hands every pass the
# same SlotWord objects for a repeated sweep, so the (id, ...) tuple is a
# stable key.  Values pin the word list, keeping the ids valid.
_PACKET_CACHE: dict = {}
_PACKET_CACHE_MAX = 4096


def _packet_for(words, k: int):
    key = (k,) + tuple(map(id, words))
    hit = _PACKET_CACHE.get(key)
    if hit is not None:
        return hit[1]
    p = _make_packet(words, k)
    if len(_PACKET_CACHE) < _PACKET_CACHE_MAX:
        _PACKET_CACHE[key] = (list(words), p)
    return p


def _group_packets(packets):
    """Greedy grouping of consecutive same-instruction packets; each safe
    group becomes a VecGroup, anything else degrades to scalar words."""
    items = []
    i = 0
    while i < len(packets):
        j = i + 1
        while j < len(packets) and packets[j].instrs == packets[i].instrs:
            j += 1
        run = packets[i:j]
        if len(run) < 2:                       # no win batching 1 instance
            for p in run:
                items.extend(p.words)
            i = j
            continue
        ks = tuple(p.k for p in run)
        mask = np.stack([p.mask for p in run])              # (K, P, 4)
        key = (run[0].instrs, ks, mask.tobytes())
        group = _GROUP_CACHE.get(key)
        if group is None and key not in _GROUP_CACHE:
            res = _analyze(run[0].instrs, ks, mask)
            if res is not None:
                deltas, reg_commit, last_commit = res
                group = VecGroup(run[0].instrs, np.asarray(ks, np.int64),
                                 mask, deltas, reg_commit, last_commit,
                                 ks[-1])
                group.plans = _build_plans(group.instrs, ks, mask)
            if len(_GROUP_CACHE) < _GROUP_CACHE_MAX:
                _GROUP_CACHE[key] = group      # None caches "unsafe" too
        if group is None:
            for p in run:
                items.extend(p.words)
        else:
            items.append(group)
        i = j
    return items


def compile_program(prog):
    """Compile a straight-line program into [SlotWord | VecGroup] items.
    Returns None if the program needs the scalar control-flow loop."""
    if any(w.lcu.op != "NOP" for w in prog):
        return None                            # loops/branches: scalar only
    items, packets = [], []

    def flush():
        nonlocal packets
        if packets:
            items.extend(_group_packets(packets))
            packets = []

    i, n = 0, len(prog)
    while i < n:
        w = prog[i]
        if w.lsu.op != "NOP" or w.mxcu.op != "SETK":
            flush()
            items.append(w)
            i += 1
            continue
        j = i + 1
        while (j < n and prog[j].lsu.op == "NOP"
               and prog[j].mxcu.op == "NOP"):
            j += 1
        p = _packet_for(prog[i:j], w.mxcu.k)
        if p is None:
            flush()
            items.extend(prog[i:j])
        else:
            packets.append(p)
        i = j
    flush()
    return items


def exec_group(col, g: VecGroup):
    """Run one VecGroup on a Column's state, committing the exact scalar
    end-state (VWR words, registers, last-results, k, counters)."""
    K = g.ks.shape[0]
    vwr = col.vwr
    regs = np.zeros((K, 4, 2), np.int64)
    last = np.zeros((K, 4), np.int64)
    srf = col.srf

    for plan in g.plans:
        if plan is None:
            continue
        op, pa, pb, dest, m = plan

        def gather(p):
            kind = p[0]
            if kind == "imm":
                return np.full((K, 4), p[1], np.int64)
            if kind == "reg":
                return regs[:, :, p[1]].copy()
            if kind == "srf":
                return np.full((K, 4), srf[p[1]], np.int64)
            if kind == "rc":
                return last[:, p[1]]
            if kind == "vwr":
                return vwr[p[1]][p[2]]
            # ("win", is_b, idx)
            _, is_b, idx = p
            return np.where(is_b, vwr["B"][idx], vwr["A"][idx])

        r = _alu_vec(op, gather(pa), gather(pb))
        if dest is not None:
            if dest[0] == "reg":
                regs[:, :, dest[1]][m] = r[m]
            else:
                vwr[dest[1]][dest[2]] = r[m]
        last[m] = r[m]

    for (rr, j), i in g.reg_commit:
        col.rc_regs[rr, j] = regs[i, rr, j]
    for rr, i in g.last_commit:
        col.rc_last[rr] = last[i, rr]
    col.k = g.final_k

    c = col.counters
    for name, v in g.deltas.items():
        setattr(c, name, getattr(c, name) + v)


def run_compiled(col, prog, items):
    """Execute a compiled straight-line program on one column."""
    col.pc = 0
    col.halted = not prog
    for item in items:
        if isinstance(item, VecGroup):
            exec_group(col, item)
        else:
            col.step(item)
    col.pc = len(prog)
    col.halted = True

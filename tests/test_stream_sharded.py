"""Multi-column sharded streaming: the `data`-axis column deal must be
invisible in the numbers.

Property-style sweeps pin sharded == single-device outputs across dividing
and non-dividing (n_frames, D) and (window, hop) combinations, including
the zero-frame and tail-padding paths. The serial-column fallback makes
every property testable on one device; when the process actually has >=
n_columns devices (the CI multi-device leg runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) the same sweeps
exercise the real `shard_map` path — plus one subprocess test that forces
8 host devices regardless of the outer environment, so the shard_map path
is covered even in a default single-device run."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core.biosignal import make_app, synthetic_respiration
from repro.kernels.pipeline.ops import app_pipeline, app_pipeline_stream
from repro.kernels.pipeline.shard import (column_chunks, column_frames,
                                          data_mesh_size,
                                          pipeline_stream_sharded)
from repro.serve.engine import ColumnScheduler
from repro.serve.stream import (BiosignalStream, StreamConfig, column_mesh,
                                frame_count, frame_signal)

ROOT = Path(__file__).resolve().parent.parent
N_DEV = len(jax.devices())


def _assert_matches(out, ref, tol=1e-4):
    assert sorted(out) == sorted(ref)
    for k in ref:
        a = np.asarray(ref[k], np.float64)
        b = np.asarray(out[k], np.float64)
        assert a.shape == b.shape, (k, a.shape, b.shape)
        if k == "class":
            np.testing.assert_array_equal(b, a)
        elif a.size:
            scale = max(1.0, float(np.abs(a).max()))
            assert float(np.abs(a - b).max()) / scale < tol, k


def _mesh_for(d):
    """Real mesh when the device set allows, else None (serial fallback) —
    so the same sweep covers shard_map on the multi-device CI leg and the
    fallback everywhere."""
    return column_mesh(d)


@pytest.mark.parametrize("n_columns", [1, 2, 3, 4, 8])
@pytest.mark.parametrize("window,hop,n_samples", [
    (512, 128, 512 * 9),        # deep overlap, frames % D varies
    (512, 512, 512 * 5 + 17),   # no overlap -> no halo
    (1024, 320, 7001),          # hop divides neither window nor signal
])
def test_sharded_stream_matches_single_device(window, hop, n_samples,
                                              n_columns):
    app = make_app()
    sig, _ = synthetic_respiration(1, n_samples, seed=n_samples + n_columns)
    raw = sig[0]
    ref = app_pipeline_stream(app, raw, window=window, hop=hop)
    out = app_pipeline_stream(app, raw, window=window, hop=hop,
                              n_columns=n_columns, mesh=_mesh_for(n_columns))
    _assert_matches(out, ref)


@pytest.mark.parametrize("n_columns", [2, 4])
@pytest.mark.parametrize("rows", [1, 7, 8, 30])
def test_sharded_framed_matches_single_device(rows, n_columns):
    """Pre-framed row deal: dividing (8/2) and non-dividing (7/4, 30/4)
    row counts, including rows < D (1/2: pad columns all-garbage)."""
    app = make_app()
    sig, _ = synthetic_respiration(rows, 512, seed=rows)
    ref = app_pipeline(app, sig)
    out = app_pipeline(app, sig, n_columns=n_columns,
                       mesh=_mesh_for(n_columns))
    _assert_matches(out, ref)


@pytest.mark.parametrize("n_columns", [1, 3, 8])
@pytest.mark.parametrize("n_samples", [0, 100, 511])
def test_sharded_zero_frame_paths(n_samples, n_columns):
    """Signals shorter than one window: every D returns the canonical
    empty dict, same keys/dtypes as the hot path."""
    app = make_app()
    raw = np.zeros(n_samples, np.float32)
    out = app_pipeline_stream(app, raw, window=512, hop=256,
                              n_columns=n_columns,
                              outputs=("features", "class"))
    assert sorted(out) == ["class", "features"]
    assert out["features"].shape == (0, 12)
    assert out["class"].shape == (0,)
    assert out["class"].dtype == np.int32


def test_column_chunk_arithmetic():
    """The hop-boundary split: chunk d starts at frame d*n_d's first
    sample, carries the window-hop halo, and frames to exactly n_d
    windows — so per-device staged bytes are ~n_samples/D + halo."""
    window, hop, D = 512, 128, 4
    sig = np.arange(512 * 9, dtype=np.float32)
    n = frame_count(sig.shape[0], window, hop)
    n_d = column_frames(n, D)
    deal = column_chunks(sig, window, hop, D)
    chunks, n_out, shares = deal.chunks, deal.n_frames, deal.shares
    assert n_out == n
    assert shares == (n_d,) * D
    assert chunks.shape == (D, n_d * hop + window - hop)
    for d in range(D):
        start = d * n_d * hop
        got = np.asarray(chunks[d])
        want = sig[start: start + got.shape[0]]
        np.testing.assert_array_equal(got[: want.shape[0]], want)
        assert (got[want.shape[0]:] == 0).all()     # zero-padded tail
        assert frame_count(got.shape[0], window, hop) == n_d
    # no-frame signal: the named Deal still unpacks like the old 3-tuple
    empty = column_chunks(sig[:100], window, hop, D)
    assert empty.chunks is None and empty.n_frames == 0
    assert empty.shares == (0,) * D
    assert tuple(empty) == (None, 0, (0,) * D)


def test_sharded_autotune_key_carries_device_count():
    """Winners are per-(shape, D): the same traffic tuned at D=1 and D=4
    lands in distinct cache entries, and only the sharded one carries D."""
    from repro.core import autotune

    autotune.clear_cache()
    app = make_app()
    sig, _ = synthetic_respiration(1, 512 * 8, seed=11)
    raw = sig[0]
    for d in (1, 4):
        app_pipeline_stream(app, raw, window=512, hop=256, autotune=True,
                            n_columns=d, mesh=_mesh_for(d))
    keys = sorted(autotune.cache_snapshot(), key=len)
    assert len(keys) == 2
    assert keys[0][:2] == ("biosignal_pipeline_stream",
                           frame_count(512 * 8, 512, 256))
    assert keys[1][-1] == 4 and len(keys[1]) == len(keys[0]) + 1
    autotune.clear_cache()


def test_stream_runtime_columns_match_and_tail(monkeypatch):
    """BiosignalStream(n_columns=D): each dispatch deals batch_windows
    frames per column, the tail batch (frames % (bw*D) != 0) is padded
    and trimmed, and outputs equal the single-column runtime's."""
    app = make_app()
    sig, _ = synthetic_respiration(1, 512 * 21 + 77, seed=13)
    raw = sig[0]
    ref = BiosignalStream(app, StreamConfig(
        window=512, hop=256, batch_windows=4)).process(raw)
    cfg = StreamConfig(window=512, hop=256, batch_windows=2, n_columns=3)
    stream = BiosignalStream(app, cfg)
    assert stream.dispatch_windows == 6
    out = stream.process(raw)
    _assert_matches(out, ref)


@pytest.mark.parametrize("depth", [1, 2, 3])
def test_stream_depth_pipelining(depth):
    """Any in-flight depth yields identical, identically-ordered batches."""
    app = make_app()
    sig, _ = synthetic_respiration(1, 512 * 13 + 5, seed=29)
    raw = sig[0]
    cfg = StreamConfig(window=512, hop=512, batch_windows=4, depth=depth)
    out = BiosignalStream(app, cfg).process(raw)
    ref = app_pipeline(app, frame_signal(raw, 512, 512))
    _assert_matches(out, ref)


def test_column_scheduler_places_streams_on_distinct_columns():
    devs = jax.devices() * 3          # synthetic 3x replica of the host set
    sched = ColumnScheduler(devs)
    assert sched.n_columns == len(devs)
    placed = [sched.admit(f"s{i}") for i in range(len(devs))]
    # one stream per column before any column doubles up (round-robin fill)
    assert [sched.column_of(f"s{i}") for i in range(len(devs))] == \
        list(range(len(devs)))
    assert placed == devs
    # next admit doubles up on the least-loaded (lowest-index) column
    sched.admit("extra")
    assert sched.column_of("extra") == 0
    assert sched.loads()[0] == 2
    # release rebalances: the freed column is preferred again
    sched.release("s1")
    sched.admit("reuse")
    assert sched.column_of("reuse") == 1
    with pytest.raises(AssertionError):
        sched.admit("reuse")


def test_column_scheduler_opens_pinned_streams():
    """open_stream admits + constructs; the pinned stream's outputs match
    an unpinned run (placement must be numerically invisible)."""
    app = make_app()
    sched = ColumnScheduler()
    sig, _ = synthetic_respiration(1, 512 * 6, seed=3)
    raw = sig[0]
    cfg = StreamConfig(window=512, hop=256, batch_windows=4)
    stream = sched.open_stream(app, cfg, stream_id="sensor-a")
    assert stream.device is sched.devices[sched.column_of("sensor-a")]
    out = stream.process(raw)
    ref = BiosignalStream(app, cfg).process(raw)
    _assert_matches(out, ref)
    sched.release("sensor-a")
    assert sched.loads() == [0] * sched.n_columns
    with pytest.raises(AssertionError):
        BiosignalStream(app, StreamConfig(n_columns=2),
                        device=sched.devices[0])


@pytest.mark.skipif(N_DEV < 8, reason="needs 8 devices (CI multi-device "
                    "leg sets xla_force_host_platform_device_count=8)")
def test_shard_map_path_is_active_on_multidevice():
    """On a real >= 8-device process the mesh is built and the shard_map
    path (not the serial fallback) must produce the reference numbers."""
    mesh = column_mesh(8)
    assert mesh is not None and data_mesh_size(mesh) == 8
    app = make_app()
    sig, _ = synthetic_respiration(1, 512 * 17 + 131, seed=8)
    raw = sig[0]
    out = pipeline_stream_sharded(raw, app.fir_taps, app.svm_w, app.svm_b,
                                  window=512, hop=128, n_columns=8,
                                  mesh=mesh)
    ref = app_pipeline_stream(app, raw, window=512, hop=128)
    _assert_matches(out, ref)
    # the non-uniform (load-aware) deal must be just as invisible under
    # real shard_map, including a zero-weight column
    out_w = pipeline_stream_sharded(raw, app.fir_taps, app.svm_w, app.svm_b,
                                    window=512, hop=128, n_columns=8,
                                    mesh=mesh,
                                    weights=(1, 1, 2, 1, 0, 1, 1, 3))
    _assert_matches(out_w, ref)
    # runtime plumbing picks the mesh up on its own
    cfg = StreamConfig(window=512, hop=128, batch_windows=2, n_columns=8)
    stream = BiosignalStream(app, cfg)
    assert stream.mesh is not None
    _assert_matches(stream.process(raw), ref)


@pytest.mark.slow
def test_sharded_d8_subprocess_forced_devices(tmp_path):
    """D=8 shard_map equivalence under forced 8 host devices — covered
    even when the outer pytest runs single-device (the laptop/CI-default
    case). Mirrors the launch/dryrun.py trick: XLA_FLAGS must be set
    before any jax import, hence the subprocess."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
assert len(jax.devices()) == 8, jax.devices()
from repro.core.biosignal import make_app, synthetic_respiration
from repro.kernels.pipeline.ops import app_pipeline_stream
from repro.launch.mesh import make_local_mesh

app = make_app()
sig, _ = synthetic_respiration(1, 512 * 19 + 77, seed=42)
raw = sig[0]
ref = app_pipeline_stream(app, raw, window=512, hop=128)
for d, w in ((2, None), (8, None), (4, (1, 2, 0, 3))):
    out = app_pipeline_stream(app, raw, window=512, hop=128, n_columns=d,
                              mesh=make_local_mesh(data=d),
                              column_weights=w)
    np.testing.assert_array_equal(np.asarray(out["class"]),
                                  np.asarray(ref["class"]))
    err = float(np.abs(np.asarray(out["margin"]) -
                       np.asarray(ref["margin"])).max())
    assert err < 1e-4, (d, err)
print("sharded-subprocess-ok")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "sharded-subprocess-ok" in r.stdout

"""RWKV6 (Finch) block — attention-free, data-dependent per-channel decay.

Faithful structure: data-dependent token-shift (LoRA), 5 mixed streams
(r,k,v,g,w), per-channel decay w_t = exp(-exp(w0 + lora(x))), bonus u for
the current token, head-wise groupnorm, silu(g) gate.

Two WKV evaluators:
  * ``wkv6_scan``    — per-token lax.scan oracle (always numerically exact).
  * ``wkv6_chunked`` — chunk-parallel evaluator; within a chunk the decay
    matrix is built in log space with pairwise exponents <= 0 (stable for any
    decay), across chunks the state is carried by a lax.scan. This is the
    paper's VWR dataflow transplanted: a chunk = one "VWR fill", the state
    never leaves "registers" between fills.

The chunked form is the default for train/prefill; decode is a single-step
state update. State = (S: (B,H,K,V) f32, x_prev_att, x_prev_ffn).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import P, apply_norm, fanin_std

NUM_MIX = 5  # r, k, v, g, w


def rwkv_block_schema(cfg):
    d = cfg.d_model
    K = cfg.ssm.head_size
    H = d // K  # wkv heads are tied to d_model/head_size
    r = cfg.ssm.lora_rank
    ff = cfg.d_ff
    return {
        "ln1": {"scale": P((d,), ("embed",), "ones"),
                "bias": P((d,), ("embed",), 0.0)},
        "ln2": {"scale": P((d,), ("embed",), "ones"),
                "bias": P((d,), ("embed",), 0.0)},
        "att": {
            "mu_x": P((d,), ("embed",), 0.0),
            "mu": P((NUM_MIX, d), (None, "embed"), 0.0),
            "lora_A": P((NUM_MIX, d, 32), (None, "embed", None), fanin_std(d)),
            "lora_B": P((NUM_MIX, 32, d), (None, None, "embed"), 0.0),
            "w0": P((d,), ("embed",), ("uniform", -8.0, -6.0)),
            "wA": P((d, r), ("embed", None), fanin_std(d)),
            "wB": P((r, d), (None, "embed"), 0.0),
            "u": P((H, K), ("heads", "head_dim"), 0.02),
            "wr": P((d, d), ("embed", "mlp"), fanin_std(d)),
            "wk": P((d, d), ("embed", "mlp"), fanin_std(d)),
            "wv": P((d, d), ("embed", "mlp"), fanin_std(d)),
            "wg": P((d, d), ("embed", "mlp"), fanin_std(d)),
            "wo": P((d, d), ("mlp", "embed"), fanin_std(d)),
            "gn_scale": P((H, K), ("heads", "head_dim"), "ones"),
            "gn_bias": P((H, K), ("heads", "head_dim"), 0.0),
        },
        "ffn": {
            "mu_r": P((d,), ("embed",), 0.0),
            "mu_k": P((d,), ("embed",), 0.0),
            "wr": P((d, d), ("embed", "mlp"), fanin_std(d)),
            "wk": P((d, ff), ("embed", "mlp"), fanin_std(d)),
            "wv": P((ff, d), ("mlp", "embed"), fanin_std(ff)),
        },
    }


# ---------------------------------------------------------------------------
# WKV6 evaluators
# ---------------------------------------------------------------------------

def wkv6_scan(r, k, v, lw, u, s0):
    """Oracle. r,k,lw: (B,S,H,K); v: (B,S,H,V); u: (H,K); s0: (B,H,K,V)."""
    rt = jnp.moveaxis(r, 1, 0).astype(jnp.float32)
    kt = jnp.moveaxis(k, 1, 0).astype(jnp.float32)
    vt = jnp.moveaxis(v, 1, 0).astype(jnp.float32)
    wt = jnp.moveaxis(lw, 1, 0).astype(jnp.float32)
    u = u.astype(jnp.float32)

    def step(S, xs):
        r_, k_, v_, lw_ = xs
        kv = k_[..., None] * v_[..., None, :]              # (B,H,K,V)
        o = jnp.einsum("bhk,bhkv->bhv", r_, S + u[None, :, :, None] * kv)
        S = jnp.exp(lw_)[..., None] * S + kv
        return S, o

    s_fin, o = jax.lax.scan(step, s0.astype(jnp.float32), (rt, kt, vt, wt))
    return jnp.moveaxis(o, 0, 1), s_fin  # (B,S,H,V), (B,H,K,V)


def wkv6_chunked(r, k, v, lw, u, s0, chunk: int):
    """Chunk-parallel WKV6, numerically stable for arbitrary decay."""
    B, S_in, H, K = r.shape
    V = v.shape[-1]
    L = min(chunk, S_in)
    if S_in % L:  # pad: k=v=0 (no kv writes), lw=0 (decay 1) => state exact
        pad = ((0, 0), (0, -S_in % L), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, pad), jnp.pad(k, pad), jnp.pad(v, pad)
        lw = jnp.pad(lw, pad)
    B, S, H, K = r.shape
    nc = S // L
    f32 = jnp.float32
    rc = r.reshape(B, nc, L, H, K).astype(f32)
    kc = k.reshape(B, nc, L, H, K).astype(f32)
    vc = v.reshape(B, nc, L, H, V).astype(f32)
    wc = lw.reshape(B, nc, L, H, K).astype(f32)
    u = u.astype(f32)
    mask = jnp.tril(jnp.ones((L, L), bool), -1)            # strict lower

    def chunk_step(Sst, xs):
        rb, kb, vb, wb = xs                                # (B,L,H,*)
        ce = jnp.cumsum(wb, axis=1)                        # inclusive
        ec = ce - wb                                       # exclusive
        # intra-chunk: A[t,j] = sum_d r_t k_j exp(ec_t - ce_j),  j < t
        expo = ec[:, :, None] - ce[:, None, :, :, :]       # (B,L,L,H,K) <= 0
        E = jnp.exp(jnp.where(mask[None, :, :, None, None], expo, -jnp.inf))
        A = jnp.einsum("blhk,bmhk,blmhk->blmh", rb, kb, E)
        bonus = jnp.einsum("blhk,hk,blhk->blh", rb, u, kb)  # current token
        A = A + jnp.eye(L, dtype=f32)[None, :, :, None] * bonus[:, :, None, :]
        o = jnp.einsum("blmh,bmhv->blhv", A, vb)
        # inter-chunk: state contribution
        q = rb * jnp.exp(ec)                               # damped, <= |r|
        o = o + jnp.einsum("blhk,bhkv->blhv", q, Sst)
        # state update
        tot = ce[:, -1]                                    # (B,H,K)
        kd = kb * jnp.exp(tot[:, None] - ce)               # damped
        Snew = jnp.exp(tot)[..., None] * Sst + jnp.einsum(
            "blhk,blhv->bhkv", kd, vb)
        return Snew, o

    xs = (jnp.moveaxis(rc, 1, 0), jnp.moveaxis(kc, 1, 0),
          jnp.moveaxis(vc, 1, 0), jnp.moveaxis(wc, 1, 0))
    s_fin, o = jax.lax.scan(chunk_step, s0.astype(f32), xs)
    o = jnp.moveaxis(o, 0, 1).reshape(B, S, H, V)
    return o[:, :S_in], s_fin


def wkv6_step(r, k, v, lw, u, s0):
    """Single-token decode. r,k,lw: (B,H,K); v: (B,H,V); s0: (B,H,K,V)."""
    f32 = jnp.float32
    r, k, v, lw = (t.astype(f32) for t in (r, k, v, lw))
    kv = k[..., None] * v[..., None, :]
    o = jnp.einsum("bhk,bhkv->bhv", r, s0 + u[None, :, :, None].astype(f32) * kv)
    s = jnp.exp(lw)[..., None] * s0 + kv
    return o, s


# ---------------------------------------------------------------------------
# Block forward
# ---------------------------------------------------------------------------

def _token_shift(x, x_prev):
    """x: (B,S,d). x_prev: (B,d) carry from the previous segment/step."""
    return jnp.concatenate(
        [x_prev[:, None, :].astype(x.dtype), x[:, :-1, :]], axis=1)


def _ddlerp(att, x, xs):
    """Data-dependent token-shift (Finch): per-stream mix of x and shift(x)."""
    sx = xs - x
    base = x + sx * att["mu_x"].astype(x.dtype)
    lo = jnp.einsum("bsd,ndr->bsnr", base, att["lora_A"].astype(x.dtype))
    lo = jnp.einsum("bsnr,nrd->bsnd", jnp.tanh(lo), att["lora_B"].astype(x.dtype))
    mix = att["mu"].astype(x.dtype)[None, None] + lo       # (B,S,5,d)
    return x[:, :, None, :] + sx[:, :, None, :] * mix      # (B,S,5,d)


def rwkv_time_mix(att, x, x_prev, s0, cfg, *, mode: str):
    B, S, d = x.shape
    K = cfg.ssm.head_size
    H = d // K
    xs = _token_shift(x, x_prev)
    m = _ddlerp(att, x, xs)
    xr, xk, xv, xg, xw = (m[:, :, i, :] for i in range(NUM_MIX))
    r = jnp.einsum("bsd,de->bse", xr, att["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", xk, att["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", xv, att["wv"].astype(x.dtype))
    g = jnp.einsum("bsd,de->bse", xg, att["wg"].astype(x.dtype))
    # data-dependent log-decay (f32; exp(w0+lora) is the decay *rate*)
    dw = jnp.einsum("bsr,rd->bsd",
                    jnp.tanh(jnp.einsum("bsd,dr->bsr", xw.astype(jnp.float32),
                                        att["wA"].astype(jnp.float32))),
                    att["wB"].astype(jnp.float32))
    lw = -jnp.exp(att["w0"].astype(jnp.float32) + dw)      # (B,S,d) <= 0

    hs = lambda t: t.reshape(B, S, H, K)
    if mode == "decode":
        o, s_fin = wkv6_step(hs(r)[:, 0], hs(k)[:, 0], hs(v)[:, 0],
                             hs(lw)[:, 0], att["u"], s0)
        o = o[:, None]
    elif cfg.ssm.impl == "matmul":
        o, s_fin = wkv6_chunked_mm(hs(r), hs(k), hs(v), hs(lw), att["u"],
                                   s0, cfg.ssm.chunk_size, cfg.ssm.wkv_clamp)
    else:
        o, s_fin = wkv6_chunked(hs(r), hs(k), hs(v), hs(lw), att["u"], s0,
                                cfg.ssm.chunk_size)
    # head-wise groupnorm
    mu = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    o = (o - mu) * jax.lax.rsqrt(var + 64e-5)
    o = o * att["gn_scale"].astype(o.dtype) + att["gn_bias"].astype(o.dtype)
    o = o.reshape(B, S, d).astype(x.dtype) * jax.nn.silu(g)
    out = jnp.einsum("bse,ed->bsd", o, att["wo"].astype(x.dtype))
    return out, x[:, -1, :], s_fin


def rwkv_channel_mix(ffn, x, x_prev):
    xs = _token_shift(x, x_prev)
    xr = x + (xs - x) * ffn["mu_r"].astype(x.dtype)
    xk = x + (xs - x) * ffn["mu_k"].astype(x.dtype)
    rg = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, ffn["wr"].astype(x.dtype)))
    k = jnp.einsum("bsd,df->bsf", xk, ffn["wk"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    return rg * jnp.einsum("bsf,fd->bsd", k, ffn["wv"].astype(x.dtype)), x[:, -1, :]


def rwkv_block(params, x, state, cfg, *, mode: str):
    """state: dict(s, att_prev, ffn_prev). Returns (x_out, new_state)."""
    h = apply_norm(params["ln1"], x, kind="layernorm", eps=cfg.norm_eps)
    att_out, att_prev, s_fin = rwkv_time_mix(
        params["att"], h, state["att_prev"], state["s"], cfg, mode=mode)
    x = x + att_out
    h = apply_norm(params["ln2"], x, kind="layernorm", eps=cfg.norm_eps)
    ffn_out, ffn_prev = rwkv_channel_mix(params["ffn"], h, state["ffn_prev"])
    x = x + ffn_out
    return x, {"s": s_fin,
               "att_prev": att_prev.astype(state["att_prev"].dtype),
               "ffn_prev": ffn_prev.astype(state["ffn_prev"].dtype)}


def rwkv_state_schema(cfg, batch: int):
    d = cfg.d_model
    K = cfg.ssm.head_size
    H = d // K
    return {
        "s": P((batch, H, K, K), ("batch", "heads", None, None), 0.0, jnp.float32),
        "att_prev": P((batch, d), ("batch", "embed"), 0.0, jnp.float32),
        "ffn_prev": P((batch, d), ("batch", "embed"), 0.0, jnp.float32),
    }


def wkv6_chunked_mm(r, k, v, lw, u, s0, chunk: int, lw_min: float = -2.0):
    """MXU-friendly chunk-parallel WKV6 (the beyond-paper §Perf variant).

    The stable evaluator materializes a (L,L,K) pairwise-exponent tensor —
    exact for any decay but pure VPU work and ~K x the memory traffic. Here
    the intra-chunk matrix factors into two damped operands and ONE matmul:

        A[t,j] = sum_d (r_t exp(ec_t - m))_d * (k_j exp(m - ce_j))_d

    (m = mid-chunk cumulative decay). Bounded-exponent safety comes from
    clamping the per-step log-decay at `lw_min`: factors stay within
    exp(L*|lw_min|/2 + |lw_min|) < f32 range for chunk <= 64, and tokens
    whose true decay is stronger than e^{lw_min}/step contribute ~e^{-2L}
    ~ 0 anyway, so the clamp is semantically negligible (tested vs scan).
    """
    lw = jnp.maximum(lw, lw_min)
    B, S_in, H, K = r.shape
    V = v.shape[-1]
    L = min(chunk, S_in)
    if S_in % L:
        pad = ((0, 0), (0, -S_in % L), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, pad), jnp.pad(k, pad), jnp.pad(v, pad)
        lw = jnp.pad(lw, pad)
    B, S, H, K = r.shape
    nc = S // L
    f32 = jnp.float32
    rc = r.reshape(B, nc, L, H, K).astype(f32)
    kc = k.reshape(B, nc, L, H, K).astype(f32)
    vc = v.reshape(B, nc, L, H, V).astype(f32)
    wc = lw.reshape(B, nc, L, H, K).astype(f32)
    u = u.astype(f32)
    mask = jnp.tril(jnp.ones((L, L), f32), -1)             # strict lower

    def chunk_step(Sst, xs):
        rb, kb, vb, wb = xs                                # (B,L,H,*)
        ce = jnp.cumsum(wb, axis=1)
        ec = ce - wb
        m = ce[:, L // 2][:, None]                         # (B,1,H,K)
        qf = rb * jnp.exp(ec - m)                          # bounded
        kf = kb * jnp.exp(m - ce)                          # bounded
        A = jnp.einsum("blhk,bmhk->blmh", qf, kf)          # ONE MXU matmul
        A = A * mask[None, :, :, None]
        bonus = jnp.einsum("blhk,hk,blhk->blh", rb, u, kb)
        A = A + jnp.eye(L, dtype=f32)[None, :, :, None] * bonus[:, :, None, :]
        o = jnp.einsum("blmh,bmhv->blhv", A, vb)
        o = o + jnp.einsum("blhk,bhkv->blhv", rb * jnp.exp(ec), Sst)
        tot = ce[:, -1]
        kd = kb * jnp.exp(tot[:, None] - ce)
        Snew = jnp.exp(tot)[..., None] * Sst + jnp.einsum(
            "blhk,blhv->bhkv", kd, vb)
        return Snew, o

    xs = (jnp.moveaxis(rc, 1, 0), jnp.moveaxis(kc, 1, 0),
          jnp.moveaxis(vc, 1, 0), jnp.moveaxis(wc, 1, 0))
    s_fin, o = jax.lax.scan(chunk_step, s0.astype(f32), xs)
    o = jnp.moveaxis(o, 0, 1).reshape(B, S, H, V)
    return o[:, :S_in], s_fin

"""End-to-end MBioTracker biosignal application (paper §4.4.2) — the
paper's own workload running on the JAX core library, cross-checked against
the cycle-accurate archsim, with a tiny SVM fit.

Run:  PYTHONPATH=src python examples/biosignal_app.py
"""
import jax
import numpy as np

from repro.core.biosignal import (extract_features, make_app,
                                  svm_fit_least_squares, svm_predict,
                                  synthetic_respiration)
from repro.core.fir import fir_direct, lowpass_taps

print("== generate 64 synthetic respiration windows ==")
sig, labels = synthetic_respiration(64, 2048, seed=3)

print("== preprocess + features (jit) ==")
taps = lowpass_taps(11)
pipeline = jax.jit(lambda s: extract_features(fir_direct(s, taps)))
feats = pipeline(sig)
print("features:", feats.shape)

print("== fit the linear SVM head on half, evaluate on the rest ==")
w, b = svm_fit_least_squares(feats[:32], labels[:32])
_, pred = svm_predict(feats[32:], w, b)
acc = float((pred == labels[32:]).mean())
print(f"holdout accuracy: {acc:.2f} (chance 0.5)")

print("== archsim cross-check: same pipeline, cycle/energy costs ==")
from repro.archsim.energy import vwr2a_energy_uj, cpu_energy_uj
from repro.archsim.programs.app import run_app

out = run_app(np.asarray(sig[0]) * 0.5, taps, np.asarray(w), np.asarray(b))
total_cycles = sum(out[k][1] for k in
                   ("preprocessing", "delineation", "feat_extraction", "svm"))
total_uj = sum(vwr2a_energy_uj(out[k][0]) for k in
               ("preprocessing", "delineation", "feat_extraction", "svm"))
print(f"VWR2A: {total_cycles} cycles, {total_uj:.3f} uJ per window")
print(f"paper CPU app: 166667 cycles, 2.6 uJ  ->  "
      f"savings {100 * (1 - total_cycles / 166667):.1f}% cycles, "
      f"{100 * (1 - total_uj / 2.6):.1f}% energy (paper: 90.9% / 66.3%)")
print("biosignal app OK")

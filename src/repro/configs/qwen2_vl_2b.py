"""qwen2-vl-2b [arXiv:2409.12191; hf] — M-RoPE (3D t/h/w rotary streams),
dynamic-resolution vision. The vision tower is a STUB: input_specs()
provides precomputed patch embeddings + (B,S,3) positions. mrope_sections
(2,1,1) splits head_dim/2 rotary freqs between t/h/w like the HF config
(16,24,24 of 64 ~ coarse 2:1:1 split at our granularity)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    rope_style="mrope",
    rope_theta=1000000.0,
    mrope_sections=(2, 1, 1),
    qkv_bias=True,
    vlm_patches=256,
    source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-2B",
))

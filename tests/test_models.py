"""Per-arch smoke tests (reduced configs, all 10 assigned architectures) +
attention/MoE/decode consistency checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, reduced
from repro.models import build_model, init_cache, init_model_params
from repro.models.attention import blockwise_attention, reference_attention
from repro.models.moe import moe_layer, moe_layer_dense_oracle
from repro.models import layers as L

B, S = 2, 64

# Per-arch sweeps dominate suite wall time; the fast CI job keeps two
# representative archs and defers the rest to the full job (@slow).
_FAST_ARCHS = {"qwen1.5-0.5b", "h2o-danube-3-4b"}
ARCH_PARAMS = [a if a in _FAST_ARCHS
               else pytest.param(a, marks=pytest.mark.slow)
               for a in ASSIGNED]


def _batch(cfg, with_labels=True):
    rng = np.random.default_rng(0)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32)}
    if with_labels:
        b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                  jnp.int32)
    if cfg.is_encdec:
        b["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_ctx, cfg.d_model)) * 0.02,
            cfg.compute_dtype)
    if cfg.vlm_patches:
        b["patch_emb"] = jnp.asarray(
            rng.normal(size=(B, cfg.vlm_patches, cfg.d_model)) * 0.02,
            cfg.compute_dtype)
        b["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, :, None], (B, S, 3)).astype(jnp.int32)
    return b


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_arch_smoke_train_step(arch):
    """One forward/loss+grad step on CPU: correct shapes, finite values."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = init_model_params(model)
    batch = _batch(cfg)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch
    (loss, metrics), grads = jax.jit(jax.value_and_grad(
        model.loss, has_aux=True))(params, batch)
    assert bool(jnp.isfinite(loss)), arch
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_arch_prefill_then_decode_matches_forward(arch):
    """Greedy next-token from (prefill + decode) must match the full
    forward pass — the cache path is semantically equivalent."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = init_model_params(model)
    batch = _batch(cfg, with_labels=False)

    logits_full, _ = jax.jit(model.forward)(params, batch)

    cache = init_cache(model, B, S + 8)
    last, cache = jax.jit(model.prefill)(params, batch, cache)
    np.testing.assert_allclose(
        np.asarray(last[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32), atol=2e-2, rtol=2e-2)

    # decode the next token and compare against forward on the extended seq
    nxt = jnp.argmax(last[:, 0], axis=-1).astype(jnp.int32)[:, None]
    dbatch = {"tokens": nxt, "cache_len": jnp.asarray(S, jnp.int32)}
    if cfg.vlm_patches:
        dbatch["positions"] = jnp.full((B, 1, 3), S, jnp.int32)
    dlogits, cache = jax.jit(model.decode)(params, dbatch, cache)
    assert dlogits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(dlogits).all())

    if cfg.is_encdec or cfg.vlm_patches:
        return  # extended-forward comparison needs matching frontends
    ext = {"tokens": jnp.concatenate([batch["tokens"], nxt], axis=1)}
    logits_ext, _ = jax.jit(model.forward)(params, ext)
    np.testing.assert_allclose(
        np.asarray(dlogits[:, 0], np.float32),
        np.asarray(logits_ext[:, -1], np.float32), atol=5e-2, rtol=5e-2)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 24])
@pytest.mark.parametrize("chunks", [(16, 16), (32, 64), (64, 37)])
def test_blockwise_attention_vs_reference(causal, window, chunks, rng):
    if window is not None and not causal:
        pytest.skip("SWA is causal")
    q = jnp.asarray(rng.normal(size=(2, 128, 8, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 128, 4, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 128, 4, 32)).astype(np.float32))
    got = blockwise_attention(q, k, v, causal=causal, window=window,
                              q_chunk=chunks[0], kv_chunk=chunks[1])
    want = reference_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_blockwise_cross_attention_uneven_kv(rng):
    q = jnp.asarray(rng.normal(size=(1, 5, 4, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1500 % 97, 4, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 1500 % 97, 4, 16)).astype(np.float32))
    got = blockwise_attention(q, k, v, causal=False, q_chunk=32, kv_chunk=32)
    want = reference_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_moe_capacity_matches_dense_oracle_when_undropped(rng):
    """With capacity >= group size the GShard dispatch must equal the
    run-every-expert oracle exactly."""
    cfg = dataclasses.replace(
        reduced(get_config("deepseek-moe-16b")),
        moe=dataclasses.replace(reduced(get_config("deepseek-moe-16b")).moe,
                                capacity_factor=8.0, group_size=16))
    from repro.models.moe import moe_schema
    params = L.init_params(jax.random.PRNGKey(0), moe_schema(cfg),
                           jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)).astype(np.float32))
    got, aux = moe_layer(params, x, cfg)
    want = moe_layer_dense_oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-3)
    assert float(aux) >= 0


def test_padded_heads_equivalence(rng):
    """tp_pad > 1 must not change the math (masked padded heads)."""
    from repro.models.attention import head_mask, padded_heads
    cfg = reduced(get_config("deepseek-coder-33b"))
    cfg_p = dataclasses.replace(cfg, tp_pad=8)
    Hp, Gp = padded_heads(cfg_p)
    assert Hp % 8 == 0
    mask = np.asarray(head_mask(cfg_p))
    assert mask.sum() == cfg.num_heads
    # end-to-end equivalence is covered by injecting weights (see DESIGN);
    # here: padded model still runs and is finite
    m = build_model(cfg_p)
    p = init_model_params(m)
    logits, _ = jax.jit(m.forward)(p, _batch(cfg_p))
    assert bool(jnp.isfinite(logits).all())


def test_swa_ring_cache_matches_full_forward(rng):
    """Sliding-window ring cache (window-sized slots) must reproduce the
    full-forward logits during decode past the window boundary."""
    import jax
    cfg = reduced(get_config("h2o-danube-3-4b"))     # window 48 reduced
    model = build_model(cfg)
    params = init_model_params(model)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    cache = init_cache(model, B, 96)                 # 96 > window => ring
    slots = jax.tree.leaves(cache)[0].shape[2]
    assert slots == cfg.sliding_window               # ring allocated
    last, cache = jax.jit(model.prefill)(params, {"tokens": toks}, cache)
    seq = toks
    dec = jax.jit(model.decode)
    for t in range(4):                               # crosses S=64 -> 68
        nxt = jnp.argmax(last[:, 0], -1).astype(jnp.int32)[:, None]
        last, cache = dec(params, {"tokens": nxt,
                                   "cache_len": jnp.asarray(S + t, jnp.int32)},
                          cache)
        seq = jnp.concatenate([seq, nxt], axis=1)
    lf, _ = jax.jit(model.forward)(params, {"tokens": seq})
    np.testing.assert_allclose(np.asarray(last[:, 0], np.float32),
                               np.asarray(lf[:, -1], np.float32),
                               atol=5e-2, rtol=5e-2)

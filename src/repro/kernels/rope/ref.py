"""Pure-jnp oracle for the RoPE kernel.

Two layouts:
  * 'interleaved' (GPT-J): pairs are adjacent lanes (x0,x1), (x2,x3)... —
    this is the layout the VWR2A shuffle unit manipulates directly
    (even/odd prune -> rotate -> interleave).
  * 'neox' (rotate-half): pairs are (x_i, x_{i+d/2}) — the layout used by
    models/attention.apply_rope.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def _angles(positions, dh, theta):
    inv = 1.0 / (theta ** (np.arange(0, dh, 2, dtype=np.float64) / dh))
    ang = positions.astype(jnp.float32)[..., None] * jnp.asarray(
        inv, jnp.float32)
    return jnp.cos(ang), jnp.sin(ang)          # (..., dh/2)


def rope_ref(x, positions, *, theta: float = 10000.0,
             layout: str = "interleaved"):
    """x: (R, dh); positions: (R,)."""
    dh = x.shape[-1]
    cos, sin = _angles(positions, dh, theta)
    xf = x.astype(jnp.float32)
    if layout == "interleaved":
        x1 = xf[..., 0::2]
        x2 = xf[..., 1::2]
        o1 = x1 * cos - x2 * sin
        o2 = x1 * sin + x2 * cos
        out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    else:  # neox rotate-half
        x1, x2 = jnp.split(xf, 2, axis=-1)
        out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                              axis=-1)
    return out.astype(x.dtype)
